"""Multi-tenant serving demo: continuous batching over per-tenant
composed models.

  PYTHONPATH=src python examples/serve_demo.py [--arch xlstm-350m]

Builds a CompositionStore of N personalized base blocks sharing one
modular block, serves staggered requests through the per-arch lane
engine, and checks every served continuation bitwise against its
fixed-batch oracle (the engine's correctness contract).  For the
recurrent archs the per-slot cache is O(1) in context length.
"""

import argparse
import time

import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.data.synthetic import SyntheticLM
from repro.launch.serve import build_demo_store
from repro.serve import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen1.5-0.5b")
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--width", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--horizon", type=int, default=8,
                    help="fused decode ticks per engine step")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if cfg.is_encdec:
        raise SystemExit("enc-dec archs: use `python -m repro.launch.serve`"
                         " (fixed-batch fallback)")
    print(f"== serving {cfg.name}: {args.tenants} tenants, "
          f"lane width {args.width} ==")
    store = build_demo_store(cfg, args.arch, args.tenants)
    engine = ServeEngine(store, width=args.width,
                         cache_len=args.prompt_len + args.gen,
                         horizon=args.horizon)

    stream = SyntheticLM(cfg.vocab_size, seed=1)
    prompts = stream.sample(args.tenants, args.prompt_len, step=0)
    reqs = [
        Request(rid=i, tenant=f"tenant{i}",
                prompt=[int(t) for t in prompts[i]],
                max_new_tokens=args.gen, arrival=i)  # staggered arrivals
        for i in range(args.tenants)
    ]

    t0 = time.time()
    comps = engine.run(list(reqs))
    warm = time.time() - t0
    total_new = sum(len(c.tokens) for c in comps)
    t0 = time.time()
    comps = engine.fresh_clone().run(list(reqs))
    hot = time.time() - t0
    print(f"{len(comps)} requests / {total_new} new tokens: "
          f"warm {warm:.2f}s, hot {hot:.2f}s "
          f"({total_new / hot:.1f} new tok/s)")

    by_rid = {c.rid: c for c in comps}
    ok = all(by_rid[r.rid].tokens == engine.oracle(r).tokens for r in reqs)
    print("bitwise parity vs fixed-batch oracle:", ok)
    c0 = by_rid[0]
    print(f"tenant0 continuation (admitted@t{c0.admitted_tick}):",
          np.asarray(c0.tokens)[:12])


if __name__ == "__main__":
    main()
