"""Serve a small model with batched requests through the cached decode
path (the same serve_step the decode_32k/long_500k dry-runs lower).

  PYTHONPATH=src python examples/serve_demo.py [--arch xlstm-350m]

Shows prefill + generation for a batch of prompts and reports per-token
latency; for the recurrent arch the cache is O(1) in context length.
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.data.synthetic import SyntheticLM
from repro.launch.serve import generate
from repro.models.transformer import init_lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen1.5-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    print(f"== serving {cfg.name} (reduced): {args.batch} requests ==")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    cross_kvs = None
    if cfg.is_encdec:
        from repro.models.transformer import build_cross_caches, encoder_forward

        frames = jnp.asarray(np.random.default_rng(0).normal(
            size=(args.batch, cfg.enc_seq_len, cfg.d_model)
        ).astype(np.float32))
        enc_out = encoder_forward(params["base"]["encoder"], cfg, frames)
        cross_kvs = build_cross_caches(params, cfg, enc_out)

    stream = SyntheticLM(cfg.vocab_size, seed=1)
    prompts = jnp.asarray(stream.sample(args.batch, args.prompt_len, step=0))
    t0 = time.time()
    out = generate(params, cfg, prompts, args.gen, cross_kvs)
    warm = time.time() - t0
    t0 = time.time()
    out = generate(params, cfg, prompts, args.gen, cross_kvs)
    hot = time.time() - t0
    steps = args.prompt_len + args.gen
    print(f"batch {args.batch}, {steps} cached decode steps: "
          f"warm {warm:.2f}s, hot {hot:.2f}s "
          f"({hot / steps * 1e3:.1f} ms/step, "
          f"{args.batch * args.gen / hot:.1f} new tok/s)")
    print("first request tokens:", np.asarray(out[0])[-args.gen:][:12])


if __name__ == "__main__":
    main()
