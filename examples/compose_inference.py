"""Cross-vendor modular composition at inference (paper Fig. 1b / eq. 11),
at BOTH scales:

  1. Table II CNN/MLP vendors: quick IFL training, then deploy vendor A's
     base block with every vendor's modular block.
  2. LLM scale: two *different architecture families* (olmo-style dense
     and xlstm-style recurrent) that share vocab + d_fusion compose
     across the fusion interface — base of one, modular of the other —
     which is exactly the interoperability the standardized fusion dim
     buys.

  PYTHONPATH=src python examples/compose_inference.py
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import IFLConfig, LayerSpec, ModelConfig
from repro.core import Client, IFLTrainer
from repro.data import dirichlet_partition, make_synth_kmnist
from repro.models.small import (
    client_base_apply,
    client_modular_apply,
    init_client_model,
)
from repro.models.transformer import base_forward, init_lm, modular_forward


def small_scale():
    print("== Table II vendors: composition after 10 IFL rounds ==")
    tx, ty, ex, ey = make_synth_kmnist(4000, 1000)
    cfg = IFLConfig(tau=10, lr_base=0.03, lr_modular=0.03)
    shards = dirichlet_partition(ty, 4, alpha=0.5, seed=0)
    clients = [
        Client(
            cid=c, params=init_client_model(jax.random.PRNGKey(c), c),
            base_apply=functools.partial(
                lambda p, x, cc: client_base_apply({"base": p}, cc, x), cc=c),
            modular_apply=functools.partial(
                lambda p, z, cc: client_modular_apply({"modular": p}, cc, z),
                cc=c),
            data_x=tx[shards[c - 1]], data_y=ty[shards[c - 1]],
        )
        for c in [1, 2, 3, 4]
    ]
    tr = IFLTrainer(clients, cfg)
    for _ in range(10):
        tr.run_round()
    mat = tr.accuracy_matrix(ex[:1000], ey[:1000])
    names = "ABCD"
    for i in range(4):
        row = " ".join(f"{names[i]}1-{names[j]}2:{mat[i, j]:.2f}"
                       for j in range(4))
        print("  " + row)


def llm_scale():
    print("\n== Cross-FAMILY LLM composition: dense base + recurrent "
          "modular (and vice versa) via the standardized fusion dim ==")
    common = dict(vocab_size=512, d_fusion=128, d_model=192, num_heads=4,
                  num_kv_heads=4, compute_dtype="float32", remat="none",
                  q_block=32, mlstm_chunk=8)
    dense = ModelConfig(
        name="vendor-dense", num_layers=4, d_ff=384,
        base_pattern=(LayerSpec(),), base_groups=2,
        mod_pattern=(LayerSpec(),), mod_groups=2, **common,
    ).validate()
    recur = ModelConfig(
        name="vendor-xlstm", num_layers=4, d_ff=0, rope_type="none",
        base_pattern=(LayerSpec(mixer="mlstm", ffn="none"),), base_groups=2,
        mod_pattern=(LayerSpec(mixer="slstm", ffn="none"),), mod_groups=2,
        **common,
    ).validate()

    pd = init_lm(jax.random.PRNGKey(0), dense)
    pr = init_lm(jax.random.PRNGKey(1), recur)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0, 512)

    for bname, bcfg, bp in [("dense", dense, pd), ("xlstm", recur, pr)]:
        z, _ = base_forward(bp["base"], bcfg, {"tokens": toks})
        for mname, mcfg, mp in [("dense", dense, pd), ("xlstm", recur, pr)]:
            logits, _ = modular_forward(mp["modular"], mcfg, z)
            ok = bool(jnp.all(jnp.isfinite(logits)))
            print(f"  base[{bname}] -> z{tuple(z.shape)} -> "
                  f"modular[{mname}] -> logits{tuple(logits.shape)} "
                  f"finite={ok}")
    print("  (any base composes with any modular: the interface is only "
          "(B, S, d_fusion))")


if __name__ == "__main__":
    small_scale()
    llm_scale()
