"""Cross-vendor modular composition at inference (paper Fig. 1b / eq. 11),
at BOTH scales:

  1. Table II CNN/MLP vendors: quick IFL training, then deploy vendor A's
     base block with every vendor's modular block.
  2. LLM scale: two *different architecture families* (olmo-style dense
     and xlstm-style recurrent) that share vocab + d_fusion compose
     across the fusion interface — base of one, modular of the other —
     which is exactly the interoperability the standardized fusion dim
     buys.

  PYTHONPATH=src python examples/compose_inference.py
"""

import jax
import jax.numpy as jnp

from repro.api import DataSpec, ExperimentSpec, run_experiment
from repro.config import LayerSpec, ModelConfig
from repro.models.transformer import base_forward, init_lm, modular_forward


def small_scale():
    print("== Table II vendors: composition after 10 IFL rounds ==")
    spec = ExperimentSpec(
        scheme="ifl", rounds=10, tau=10, lr=0.03, eval_every=0, seed=0,
        data=DataSpec(n_train=4000, n_test=1000),
    )
    result = run_experiment(spec)
    mat = result.final["matrix"]
    names = "ABCD"
    for i in range(4):
        row = " ".join(f"{names[i]}1-{names[j]}2:{mat[i][j]:.2f}"
                       for j in range(4))
        print("  " + row)


def llm_scale():
    print("\n== Cross-FAMILY LLM composition: dense base + recurrent "
          "modular (and vice versa) via the standardized fusion dim ==")
    common = dict(vocab_size=512, d_fusion=128, d_model=192, num_heads=4,
                  num_kv_heads=4, compute_dtype="float32", remat="none",
                  q_block=32, mlstm_chunk=8)
    dense = ModelConfig(
        name="vendor-dense", num_layers=4, d_ff=384,
        base_pattern=(LayerSpec(),), base_groups=2,
        mod_pattern=(LayerSpec(),), mod_groups=2, **common,
    ).validate()
    recur = ModelConfig(
        name="vendor-xlstm", num_layers=4, d_ff=0, rope_type="none",
        base_pattern=(LayerSpec(mixer="mlstm", ffn="none"),), base_groups=2,
        mod_pattern=(LayerSpec(mixer="slstm", ffn="none"),), mod_groups=2,
        **common,
    ).validate()

    pd = init_lm(jax.random.PRNGKey(0), dense)
    pr = init_lm(jax.random.PRNGKey(1), recur)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0, 512)

    for bname, bcfg, bp in [("dense", dense, pd), ("xlstm", recur, pr)]:
        z, _ = base_forward(bp["base"], bcfg, {"tokens": toks})
        for mname, mcfg, mp in [("dense", dense, pd), ("xlstm", recur, pr)]:
            logits, _ = modular_forward(mp["modular"], mcfg, z)
            ok = bool(jnp.all(jnp.isfinite(logits)))
            print(f"  base[{bname}] -> z{tuple(z.shape)} -> "
                  f"modular[{mname}] -> logits{tuple(logits.shape)} "
                  f"finite={ok}")
    print("  (any base composes with any modular: the interface is only "
          "(B, S, d_fusion))")


if __name__ == "__main__":
    small_scale()
    llm_scale()
