"""Quickstart: collaborative IFL training through the `repro.api` front
door, end to end, in ~a minute.

Four vendors with the paper's Table II architectures collaboratively
train on non-IID synthetic KMNIST while exchanging ONLY fusion-layer
outputs, then compose each other's modular blocks at inference. The
whole experiment is one declarative spec:

    from repro.api import ExperimentSpec, run_experiment
    result = run_experiment(ExperimentSpec(scheme="ifl", codec="int8"))

  PYTHONPATH=src python examples/quickstart.py
  PYTHONPATH=src python examples/quickstart.py --codec int8        # ~4x less wire
  PYTHONPATH=src python examples/quickstart.py --codec "ef(int4)"  # ~8x + EF21
  PYTHONPATH=src python examples/quickstart.py --participation k2  # 2-of-4/round

``--codec`` picks the fusion-payload wire format (repro.core.codec):
fp32 (baseline) | bf16 | fp16 | int8 | int8_channel | int8_row | int4 |
topk | topk<r> | sketch<r> — or ``ef(<codec>)`` to add EF21 error
feedback: each vendor keeps a private residual of what compression
dropped and folds it into the next round's payload, recovering
fp32-level accuracy at the compressed wire size.

``--participation`` picks the client schedule (repro.core.rounds):
full | k<K> | bern<p> | straggle(<frac>,<period>). Under e.g. ``k2``
only 2 of the 4 vendors train/upload per round; the server's fusion
cache re-broadcasts absent vendors' last payloads (bounded by
``--max-staleness``) so modular updates still see all four, while the
ledger pays only for the fresh uploads.

``--broadcast`` picks the downlink policy (repro.core.exchange): full
(every participant receives the whole valid cache) | delta (vendors
mirror the server cache, so the server ships each entry at most once
per round — identical training signal, far fewer downlink bytes).

``--mode async`` retires the round barrier: vendors upload on their own
clocks drawn from ``--trace`` (periodic(<T>) | poisson(<rate>) |
pareto(<alpha>,<scale>) | replay:<path> — repro.core.rounds), and the
server fuses whatever arrived every ``--tick`` simulated seconds on the
staleness-bounded cache. The run reports simulated wall-clock and
uploads/sec absorbed alongside the ledger totals.

``--scheme`` swaps the whole algorithm (anything in
``repro.api.available_schemes()``: ifl | fsl | fl1 | fl2 | ifl_spmd) —
the point of the registry is that baselines are a flag, not a fork.
"""

import argparse

import numpy as np

from repro.api import (
    DataSpec,
    ExperimentSpec,
    FleetSpec,
    available_schemes,
    run_experiment,
)
from repro.core import ifl_round_bytes


def main(scheme: str = "ifl", codec: str = "fp32",
         participation: str = "full", max_staleness=None, rounds: int = 20,
         broadcast: str = "full", mode: str = "sync", trace: str = "",
         tick: float = 1.0, n_population: int = 0, cohort: int = 0):
    if mode == "async" and not trace:
        trace = "pareto(1.2,0.5)"  # heavy-tail default: infinite-mean gaps
    data_name = ("synthetic LM tokens" if scheme == "ifl_spmd"
                 else "synthetic KMNIST")
    clock = (f"async trace {trace} tick {tick}" if mode == "async"
             else f"participation {participation}")
    fleet = FleetSpec(n_population=n_population, cohort=cohort)
    vendors = (f"{fleet.population} vendors, cohort {cohort}/round"
               if cohort else "4 vendors")
    print(f"== {scheme} quickstart: {vendors}, {data_name}, "
          f"wire codec {codec}, {clock}, "
          f"broadcast {broadcast} ==")
    spmd = scheme == "ifl_spmd"
    spec = ExperimentSpec(
        scheme=scheme, rounds=rounds, tau=10, lr=0.05, batch_size=32,
        codec=codec, participation=participation, broadcast=broadcast,
        mode=mode, trace=trace, tick=tick, fleet=fleet,
        max_staleness=max_staleness, eval_every=5, seed=0,
        # The SPMD demo runs the smoke LM: match its 32-dim fusion cut
        # (the spec's d_fusion is authoritative over the model config).
        d_fusion=32 if spmd else 432,
        data=(DataSpec(dataset="synth_tokens", n_test=32) if spmd
              else DataSpec(n_train=6000, n_test=1500)),
    )
    print(f"   spec {spec.spec_hash()}: {spec.canonical_json()[:72]}...")

    def progress(rec, report):
        accs = rec.get("accs", [rec["acc_mean"]])
        parts = report.participants
        extra = (f"base_loss {report['base_loss']:.3f}, "
                 if "base_loss" in report.metrics else "")
        clock = (f"t={rec['sim_time']:.1f}s, " if "sim_time" in rec else "")
        print(f"round {rec['round']:3d}: {clock}{extra}"
              f"uplink {rec['uplink_mb']:.2f} MB, "
              f"up {len(parts)}/{spec.fleet.population} vendors "
              f"(cache {report.metrics.get('cache_size', '-')}), "
              f"accs {[f'{a:.2f}' for a in accs]}")

    result = run_experiment(spec, keep_trainer=True, on_record=progress)
    trainer = result.trainer

    if mode == "async":
        eng = trainer.engine
        print(f"\nasync summary: {eng.total_uploads} uploads "
              f"({eng.total_arrivals} arrivals, coalesced per tick) "
              f"absorbed over {eng.sim_time:.1f} simulated s "
              f"= {eng.total_uploads / eng.sim_time:.2f} uploads/sec")
        print(f"ledger totals: uplink {trainer.ledger.uplink_mb:.3f} MB, "
              f"downlink {trainer.ledger.downlink_mb:.3f} MB, "
              f"total {trainer.ledger.total_mb:.3f} MB")

    if "matrix" in result.records[-1]:
        # Population fleets skip the N x N composition sweep
        # (trainer.eval_matrix is False there).
        print("\ncross-vendor composition matrix (eq. 11):")
        mat = np.asarray(result.records[-1]["matrix"])
        print(np.round(mat, 3))

    if scheme == "ifl":
        m0 = trainer.engine.history[0]
        exp = ifl_round_bytes(spec.fleet.population, spec.batch_size,
                              spec.d_fusion, codec=codec,
                              participating=len(m0["participants"]),
                              broadcast_entries=m0["cache_size"],
                              broadcast=spec.broadcast,
                              delta_entries=m0.metrics.get(
                                  "shipped_entries"))
        got = trainer.ledger.per_round[0]
        print(f"\nper-round bytes measured {got} == analytic {exp}: "
              f"{got['up'] == exp['up'] and got['down'] == exp['down']}")
        if spec.broadcast == "delta":
            full_down = ifl_round_bytes(
                spec.fleet.population, spec.batch_size, spec.d_fusion,
                codec=codec, participating=len(m0["participants"]),
                broadcast_entries=m0["cache_size"])["down"]
            if got["down"]:
                print(f"delta downlink saving vs full broadcast: "
                      f"{full_down / got['down']:.2f}x this round")
        if codec != "fp32" and exp["up"]:  # an empty round 0 has no uplink
            fp32 = ifl_round_bytes(spec.fleet.population, spec.batch_size,
                                   spec.d_fusion,
                                   participating=len(m0["participants"]),
                                   broadcast_entries=m0["cache_size"])
            print(f"wire saving vs fp32: {fp32['up'] / exp['up']:.2f}x uplink")
        if trainer.codec.has_state:
            # sorted(): population EF state is a lazy dict in touch
            # order — slot order keeps the print stable across draws.
            norms = {trainer.clients[k].cid: float(np.linalg.norm(np.asarray(e)))
                     for k, e in sorted(trainer.ef_state.items())}
            print("EF residual norms (client-private, 0 wire bytes): "
                  + ", ".join(f"{c}: {n:.1f}" for c, n in norms.items()))


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scheme", default="ifl",
                    help="registered scheme to run: "
                         + " | ".join(available_schemes()))
    ap.add_argument("--codec", default="fp32",
                    help="fusion-payload wire codec (see repro.core.codec)")
    ap.add_argument("--participation", default="full",
                    help="client schedule (see repro.core.rounds): "
                         "full | k<K> | bern<p> | straggle(<frac>,<period>)")
    ap.add_argument("--max-staleness", type=int, default=None,
                    help="fusion-cache staleness bound in rounds "
                         "(default: never evict)")
    ap.add_argument("--broadcast", default="full",
                    choices=["full", "delta"],
                    help="downlink policy (repro.core.exchange): full "
                         "cache to every participant, or delta "
                         "mirror-sync (each entry ships once)")
    ap.add_argument("--mode", default="sync", choices=["sync", "async"],
                    help="round clocking: sync barrier, or async "
                         "arrival-driven server ticks")
    ap.add_argument("--trace", default="",
                    help="async arrival trace (repro.core.rounds): "
                         "periodic(<T>) | poisson(<rate>) | "
                         "pareto(<alpha>,<scale>) | replay:<path> "
                         "(default under --mode async: pareto(1.2,0.5))")
    ap.add_argument("--tick", type=float, default=1.0,
                    help="async server fuse period in simulated seconds")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--n-population", type=int, default=0,
                    help="fleet size N in the population regime "
                         "(requires --cohort; 0 = the 4-vendor fleet)")
    ap.add_argument("--cohort", type=int, default=0,
                    help="cohort width C: each round trains a C-of-N "
                         "draw; per-round bytes and clock scale in C, "
                         "not N (0 = every vendor every round)")
    args = ap.parse_args()
    main(args.scheme, args.codec, args.participation, args.max_staleness,
         args.rounds, args.broadcast, args.mode, args.trace, args.tick,
         args.n_population, args.cohort)
