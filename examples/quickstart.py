"""Quickstart: one IFL communication round, end to end, in ~a minute.

Four vendors with the paper's Table II architectures collaboratively
train on non-IID synthetic KMNIST while exchanging ONLY fusion-layer
outputs, then compose each other's modular blocks at inference.

  PYTHONPATH=src python examples/quickstart.py
  PYTHONPATH=src python examples/quickstart.py --codec int8        # ~4x less wire
  PYTHONPATH=src python examples/quickstart.py --codec "ef(int4)"  # ~8x + EF21
  PYTHONPATH=src python examples/quickstart.py --participation k2  # 2-of-4/round

``--codec`` picks the fusion-payload wire format (repro.core.codec):
fp32 (baseline) | bf16 | fp16 | int8 | int8_channel | int8_row | int4 |
topk | topk<r> | sketch<r> — or ``ef(<codec>)`` to add EF21 error
feedback: each vendor keeps a private residual of what compression
dropped and folds it into the next round's payload, recovering
fp32-level accuracy at the compressed wire size.

``--participation`` picks the client schedule (repro.core.rounds):
full | k<K> | bern<p> | straggle(<frac>,<period>). Under e.g. ``k2``
only 2 of the 4 vendors train/upload per round; the server's fusion
cache re-broadcasts absent vendors' last payloads (bounded by
``--max-staleness``) so modular updates still see all four, while the
ledger pays only for the fresh uploads.
"""

import argparse
import functools

import jax
import numpy as np

from repro.config import IFLConfig
from repro.core import Client, IFLTrainer, ifl_round_bytes
from repro.data import dirichlet_partition, make_synth_kmnist
from repro.models.small import (
    client_base_apply,
    client_modular_apply,
    init_client_model,
)


def main(codec: str = "fp32", participation: str = "full",
         max_staleness=None):
    print(f"== IFL quickstart: 4 heterogeneous vendors, synthetic KMNIST, "
          f"wire codec {codec}, participation {participation} ==")
    tx, ty, ex, ey = make_synth_kmnist(6000, 1500)
    cfg = IFLConfig(tau=10, batch_size=32, lr_base=0.05, lr_modular=0.05,
                    codec=codec, participation=participation,
                    max_staleness=max_staleness)
    shards = dirichlet_partition(ty, cfg.n_clients, alpha=0.5, seed=0)

    clients = []
    for k in range(cfg.n_clients):
        cid = k + 1
        clients.append(Client(
            cid=cid,
            params=init_client_model(jax.random.PRNGKey(cid), cid),
            base_apply=functools.partial(
                lambda p, x, c: client_base_apply({"base": p}, c, x), c=cid),
            modular_apply=functools.partial(
                lambda p, z, c: client_modular_apply({"modular": p}, c, z),
                c=cid),
            data_x=tx[shards[k]], data_y=ty[shards[k]],
        ))
        print(f"  vendor {cid}: {len(shards[k])} non-IID samples, "
              f"private architecture #{cid}")

    trainer = IFLTrainer(clients, cfg, seed=0)
    for r in range(20):
        m = trainer.run_round()
        if r % 5 == 0 or r == 19:
            accs = trainer.evaluate(ex, ey)
            print(f"round {r:3d}: base_loss {m['base_loss']:.3f}, "
                  f"uplink {m['uplink_mb']:.2f} MB, "
                  f"up {len(m['participants'])}/{cfg.n_clients} vendors "
                  f"(cache {m['cache_size']}), "
                  f"accs {[f'{a:.2f}' for a in accs]}")

    print("\ncross-vendor composition matrix (eq. 11):")
    mat = trainer.accuracy_matrix(ex[:1000], ey[:1000])
    print(np.round(mat, 3))
    m0 = trainer.engine.history[0]
    exp = ifl_round_bytes(cfg.n_clients, cfg.batch_size, cfg.d_fusion,
                          codec=codec,
                          participating=len(m0["participants"]),
                          broadcast_entries=m0["cache_size"])
    got = trainer.ledger.per_round[0]
    print(f"\nper-round bytes measured {got} == analytic {exp}: "
          f"{got['up'] == exp['up'] and got['down'] == exp['down']}")
    if codec != "fp32" and exp["up"]:  # an empty round 0 has no uplink
        fp32 = ifl_round_bytes(cfg.n_clients, cfg.batch_size, cfg.d_fusion,
                               participating=len(m0["participants"]),
                               broadcast_entries=m0["cache_size"])
        print(f"wire saving vs fp32: {fp32['up'] / exp['up']:.2f}x uplink")
    if trainer.codec.has_state:
        norms = {cid: float(np.linalg.norm(np.asarray(e)))
                 for cid, e in trainer.ef_state.items()}
        print("EF residual norms (client-private, 0 wire bytes): "
              + ", ".join(f"{c}: {n:.1f}" for c, n in norms.items()))


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--codec", default="fp32",
                    help="fusion-payload wire codec (see repro.core.codec)")
    ap.add_argument("--participation", default="full",
                    help="client schedule (see repro.core.rounds): "
                         "full | k<K> | bern<p> | straggle(<frac>,<period>)")
    ap.add_argument("--max-staleness", type=int, default=None,
                    help="fusion-cache staleness bound in rounds "
                         "(default: never evict)")
    args = ap.parse_args()
    main(args.codec, args.participation, args.max_staleness)
