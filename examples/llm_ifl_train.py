"""End-to-end driver (deliverable b): IFL-train a ~100M-param LM for a
few hundred rounds on CPU.

Four clients share one architecture (olmo-1b family at ~100M reduced
scale: 8 layers, d_model 512) with private weights and private synthetic
dialects; every round is the SAME jitted ifl_round_step the 256-chip
dry-run lowers. Loss on both blocks falls; cumulative uplink is reported
against what FedAvg would have cost.

  PYTHONPATH=src python examples/llm_ifl_train.py [--rounds 200]
"""

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.models import modules as nn
from repro.train.loop import train_ifl_lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40,
                    help="one round = tau+1 base/fusion steps + 4 modular steps per client; 40 rounds ≈ 15-20 min on one CPU core; scale up freely on real hardware")
    ap.add_argument("--tau", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    # ~100M-param member of the olmo family.
    cfg = get_config("olmo-1b").replace(
        name="olmo-100m",
        num_layers=8, d_model=512, num_heads=8, num_kv_heads=8,
        d_ff=2048, vocab_size=16384, d_fusion=512,
        base_pattern=get_config("olmo-1b").base_pattern, base_groups=4,
        mod_pattern=get_config("olmo-1b").mod_pattern, mod_groups=4,
        compute_dtype="float32", remat="none", q_block=128,
    ).validate()
    from repro.models.transformer import init_lm

    n_params = nn.param_count(init_lm(jax.random.PRNGKey(0), cfg))
    print(f"== IFL LM training: {cfg.name}, {n_params/1e6:.1f}M params, "
          f"{args.rounds} rounds x (tau={args.tau} base steps + fusion "
          f"exchange + 4 modular steps) ==")

    out = train_ifl_lm(
        cfg, rounds=args.rounds, n_clients=4, tau=args.tau,
        batch=args.batch, seq=args.seq, lr_base=0.05, lr_modular=0.05,
        log_every=max(1, args.rounds // 20),
    )
    h = out["history"]
    print(f"\nbase loss {h[0]['base_loss']:.3f} -> {h[-1]['base_loss']:.3f}; "
          f"modular loss {h[0]['mod_loss']:.3f} -> {h[-1]['mod_loss']:.3f}")
    fedavg_round_mb = 2 * 4 * n_params * 4 / 1e6  # up+down, fp32
    print(f"uplink total {out['ledger'].uplink_mb:.1f} MB over "
          f"{len(h)} rounds; FedAvg would ship "
          f"{fedavg_round_mb * len(h):.0f} MB "
          f"({fedavg_round_mb * len(h) / max(out['ledger'].uplink_mb, 1e-9):.0f}x more)")


if __name__ == "__main__":
    main()
