"""Fleet scale-out (ISSUE 7): population store, cohort engines, spec
axes, and the async-simulation bugfixes that rode along.

Covers, in order:
  - ``parse_participation`` strict normalization (one place, tested
    error messages: 'k+2' must never parse as k2 again),
  - ``ReplayTrace.cursor`` slot-range regression (out-of-range slots
    used to be silently dropped),
  - ``simulate_sync_wall_clock`` inf-barrier propagation regression
    (rounds after a never-closing barrier used to look finite),
  - Zipf / diurnal population schedules + cohort expectations,
  - ``PopulationStore`` properties (gather/scatter identity on
    untouched slots, page-in == eager init bitwise, staleness-bounded
    memory on a 10k-slot fleet) and ``LazyFleet``,
  - cohort-capped sync/async engines, with the bitwise-preservation
    guarantee that ``cohort=None`` changes nothing,
  - ``FleetSpec`` validation + spec-hash elision at defaults,
  - end-to-end cohort rounds for both IFL trainers with exact
    analytic<->ledger parity.
"""

import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.api.runner import build_trainer
from repro.api.spec import DataSpec, ExperimentSpec, FleetSpec
from repro.core import ifl_round_bytes
from repro.core.population import LazyFleet, PopulationStore
from repro.core.rounds import (
    AsyncRoundEngine,
    DiurnalSchedule,
    ParticipationSchedule,
    ReplayTrace,
    RoundEngine,
    ZipfSchedule,
    expected_cohort_participants,
    parse_participation,
    simulate_sync_wall_clock,
)

# ------------------------------------------------- participation parsing


def test_parse_strips_whitespace_everywhere():
    assert parse_participation(" full ").name == "full"
    assert parse_participation("  k2  ").name == parse_participation(
        "k2").name
    assert parse_participation(" zipf( 1.1 ) ").name == "zipf(1.1)"
    assert parse_participation(" diurnal( 24 , 4 ) ").name == \
        "diurnal(24,4)"


@pytest.mark.parametrize("bad", ["k+2", "k-1", "k 2"])
def test_parse_rejects_signed_k(bad):
    # Regression: int('+2') == 2, so 'k+2' used to parse as UniformK(2).
    with pytest.raises(ValueError,
                       match="plain positive integer"):
        parse_participation(bad)


@pytest.mark.parametrize("bad", ["bern+0.5", "bern-0.1", "bern 0.5"])
def test_parse_rejects_signed_bern(bad):
    with pytest.raises(ValueError, match="plain decimal"):
        parse_participation(bad)


def test_parse_unknown_spec_lists_every_family():
    with pytest.raises(ValueError) as ei:
        parse_participation("uniform5")
    msg = str(ei.value)
    for family in ("full", "k<K>", "bern<p>", "straggle", "zipf",
                   "diurnal"):
        assert family in msg


def test_zipf_diurnal_round_trip_and_validation():
    z = parse_participation("zipf(1.5)")
    assert isinstance(z, ZipfSchedule) and z.a == 1.5
    assert parse_participation(z.name).name == z.name
    d = parse_participation("diurnal(24)")
    assert isinstance(d, DiurnalSchedule)
    assert (d.period, d.zones) == (24, 4)  # default zones
    assert parse_participation(d.name).name == d.name
    with pytest.raises(ValueError, match="a must be >= 0"):
        ZipfSchedule(-0.5)
    with pytest.raises(ValueError, match="period must be >= 2"):
        DiurnalSchedule(1)
    with pytest.raises(ValueError, match="zones must be >= 1"):
        DiurnalSchedule(24, 0)


def test_zipf_skews_availability_toward_low_slots():
    rng = np.random.default_rng(0)
    z = ZipfSchedule(1.0)
    counts = np.zeros(64)
    for r in range(200):
        counts += z.mask(r, 64, rng)
    assert counts[0] == 200  # p = 1 for slot 0
    # The head of the popularity curve dominates the tail.
    assert counts[:8].sum() > 4 * counts[-8:].sum()
    assert abs(z.expected_participants(64)
               - ((np.arange(64) + 1.0) ** -1.0).sum()) < 1e-9


def test_diurnal_is_deterministic_waves():
    d = DiurnalSchedule(4, 2)  # 2 zones, awake 2 of every 4 rounds
    rng = np.random.default_rng(0)
    masks = [d.mask(r, 8, rng) for r in range(8)]
    # No rng draws at all: a second replay is identical.
    rng2 = np.random.default_rng(123)
    assert all((m == d.mask(r, 8, rng2)).all()
               for r, m in enumerate(masks))
    # Zone 0 (slots 0-3) awake at phase 0,1; zone 1 shifted by 2.
    assert masks[0][:4].all() and not masks[2][:4].any()
    assert masks[2][4:].all() and not masks[0][4:].any()
    assert d.expected_participants(8) == 4.0


def test_expected_cohort_participants_caps_at_cohort():
    assert expected_cohort_participants("full", 50, 10) == 10.0
    assert expected_cohort_participants("full", 50, None) == 50.0
    # A thin schedule stays under the cap.
    thin = expected_cohort_participants("bern0.05", 100, 50)
    assert 0 < thin < 10


# ------------------------------------------ replay-trace slot regression


def test_replay_cursor_rejects_out_of_range_slots():
    # Regression: a trace built WITHOUT n_clients skipped the range
    # check, and cursor() silently dropped slot-7 arrivals on a
    # 4-client fleet — a mis-sized fleet just looked quiet.
    tr = ReplayTrace([(0.5, 7), (1.0, 1)])
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError, match="slot 7.*only 4 clients"):
        tr.cursor(4, rng)
    cur = tr.cursor(8, rng)  # exactly wide enough is fine
    assert cur.next_after(7, 0.0, rng) == 0.5


def test_replay_constructor_check_still_applies():
    with pytest.raises(ValueError, match="slot 7"):
        ReplayTrace([(0.5, 7)], n_clients=4)


# ------------------------------------- sync barrier inf propagation fix


class _ScriptedSchedule(ParticipationSchedule):
    """Everyone for two rounds, then only client 1 (who keeps
    arriving) — the shape that exposed the finite-after-inf bug."""

    name = "scripted"

    def mask(self, round_idx, n, rng):
        m = np.zeros(n, bool)
        if round_idx < 2:
            m[:] = True
        else:
            m[1] = True
        return m

    def expected_participants(self, n):
        return float(n)


def test_sync_wall_clock_inf_barrier_sticks():
    # Client 0 uploads once then vanishes; client 1 keeps arriving.
    trace = ReplayTrace(
        [(1.0, 0), (1.0, 1), (2.0, 1), (3.0, 1), (4.0, 1)], 2)
    durations = simulate_sync_wall_clock(
        trace, 2, 4, participation=_ScriptedSchedule())
    assert durations[0] == 1.0
    # Round 1's barrier waits on client 0 forever; round 2 schedules
    # only the live client 1, but it is STILL stuck behind round 1's
    # unclosed barrier — the regression reported it finite.
    assert all(math.isinf(d) for d in durations[1:])
    assert len(durations) == 4


def test_sync_wall_clock_finite_replay_unchanged():
    trace = ReplayTrace([(1.0, 0), (2.0, 1), (3.0, 0), (3.5, 1)], 2)
    durations = simulate_sync_wall_clock(trace, 2, 2)
    assert durations == [2.0, 1.5]


# ------------------------------------------------------ population store


def _slot_tree(slot: int):
    return {"w": np.full((3,), float(slot), np.float32),
            "b": np.asarray(slot, np.int32)}


def test_page_in_matches_eager_init_bitwise():
    store = PopulationStore(100, _slot_tree)
    cohort = store.page_in([7, 3, 7])  # repeats legal (mask padding)
    assert cohort["w"].shape == (3, 3)
    for i, s in enumerate([7, 3, 7]):
        np.testing.assert_array_equal(np.asarray(cohort["w"][i]),
                                      _slot_tree(s)["w"])
        assert int(cohort["b"][i]) == s


@given(seed=st.integers(0, 5), c=st.integers(1, 6))
@settings(max_examples=10, deadline=None)
def test_scatter_touches_exactly_the_named_slots(seed, c):
    n = 32
    store = PopulationStore(n, _slot_tree)
    rng = np.random.default_rng(seed)
    slots = sorted(rng.choice(n, size=c, replace=False).tolist())
    cohort = store.page_in(slots)
    bumped = {"w": np.asarray(cohort["w"]) + 1.0,
              "b": np.asarray(cohort["b"])}
    store.page_out(slots, bumped, round_idx=0)
    for s in range(n):
        expect = _slot_tree(s)["w"] + (1.0 if s in slots else 0.0)
        np.testing.assert_array_equal(store.get(s)["w"], expect)


def test_page_out_drops_trailing_padding_and_copies():
    store = PopulationStore(10, _slot_tree)
    slots = [4, 9]
    padded = store.page_in(slots + [slots[0]] * 2)  # width-4 cohort
    host = {"w": np.asarray(padded["w"]).copy(),
            "b": np.asarray(padded["b"]).copy()}
    store.page_out(slots, host, round_idx=1)
    # Trailing pad positions never wrote anywhere...
    assert store.slots() == [4, 9]
    # ...and the stored leaves are decoupled from the cohort buffer.
    host["w"][0, :] = -1.0
    np.testing.assert_array_equal(store.get(4)["w"], _slot_tree(4)["w"])


def test_store_aging_bounds_memory_on_10k_fleet():
    store = PopulationStore(10_000, _slot_tree, max_staleness=2)
    rng = np.random.default_rng(0)
    peak_slots = peak_bytes = 0
    for r in range(40):
        slots = sorted(rng.choice(10_000, size=16, replace=False))
        cohort = store.page_in(slots)
        store.page_out(slots, cohort, round_idx=r)
        store.prune(r)
        peak_slots = max(peak_slots, len(store))
        peak_bytes = max(peak_bytes, store.memory_bytes())
    bound = 16 * (2 + 2)  # cohort x (staleness window + this round + 1)
    assert peak_slots <= bound
    per_slot = sum(leaf.nbytes
                   for leaf in _slot_tree(0).values())
    assert peak_bytes <= bound * per_slot
    # Eviction re-inits deterministically: rejoin == fresh.
    s = store.slots()[0]
    store.put(s, {"w": np.zeros(3, np.float32),
                  "b": np.asarray(-1, np.int32)}, round_idx=0)
    store._last_seen[s] = -100
    store.prune(200)
    np.testing.assert_array_equal(store.get(s)["w"], _slot_tree(s)["w"])


def test_store_validation():
    with pytest.raises(ValueError, match="n_population"):
        PopulationStore(0, _slot_tree)
    with pytest.raises(ValueError, match="max_staleness"):
        PopulationStore(4, _slot_tree, max_staleness=-1)
    store = PopulationStore(4, _slot_tree)
    with pytest.raises(IndexError, match="slot 4 out of range"):
        store.get(4)
    with pytest.raises(IndexError):
        store.put(-1, _slot_tree(0))
    with pytest.raises(ValueError, match="at least one slot"):
        store.page_in([])


def test_lazy_fleet_materializes_on_touch():
    built = []

    def build(k):
        built.append(k)
        return f"client-{k}"

    fleet = LazyFleet(100, build)
    assert len(fleet) == 100 and built == []
    assert fleet[7] == "client-7" and fleet[-1] == "client-99"
    assert fleet[7] == "client-7" and built == [7, 99]  # cached
    assert fleet[2:4] == ["client-2", "client-3"]
    assert fleet.materialized == [2, 3, 7, 99]
    with pytest.raises(IndexError):
        fleet[100]
    with pytest.raises(ValueError):
        LazyFleet(0, build)


# ------------------------------------------------------- cohort engines


def test_sync_engine_cohort_draw():
    eng = RoundEngine(20, "full", seed=0, cohort=5)
    seen = set()
    for _ in range(10):
        parts = eng.participants()
        assert len(parts) == 5
        assert (np.diff(parts) > 0).all()  # sorted, distinct
        assert parts.min() >= 0 and parts.max() < 20
        seen.update(int(p) for p in parts)
        eng.end_round({})
    assert len(seen) > 5  # the draw rotates over the population


def test_cohort_none_is_bitwise_identical():
    # The preservation guarantee: cohort=None must not perturb the rng
    # stream — every legacy run replays exactly.
    a = RoundEngine(8, "bern0.5", seed=3)
    b = RoundEngine(8, "bern0.5", seed=3, cohort=None)
    for _ in range(10):
        pa, pb = a.participants(), b.participants()
        np.testing.assert_array_equal(pa, pb)
        a.end_round({})
        b.end_round({})
    assert a.rng.bit_generator.state == b.rng.bit_generator.state


def test_cohort_wider_than_need_draws_nothing_extra():
    # k2 of 8 never exceeds a cohort of 4: the cap must not consume rng.
    a = RoundEngine(8, "k2", seed=1)
    b = RoundEngine(8, "k2", seed=1, cohort=4)
    for _ in range(6):
        np.testing.assert_array_equal(a.participants(), b.participants())
        a.end_round({})
        b.end_round({})


def test_cohort_validation():
    with pytest.raises(ValueError, match="cohort must be >= 1"):
        RoundEngine(8, "full", cohort=0)
    with pytest.raises(ValueError, match="cannot exceed the population"):
        RoundEngine(8, "full", cohort=9)


def test_async_engine_admits_earliest_cohort():
    trace = ReplayTrace(
        [(0.1, 5), (0.2, 0), (0.3, 3), (0.4, 1), (1.5, 2)], 6)
    eng = AsyncRoundEngine(6, trace, tick=1.0, cohort=2)
    parts = eng.participants()
    # Four distinct arrivals in tick 0; the two earliest (5 then 0) win.
    np.testing.assert_array_equal(parts, [0, 5])
    rep = eng.end_round({})
    assert rep.metrics["arrivals"] == 4  # turned-away events still count
    np.testing.assert_array_equal(eng.participants(), [2])


# ----------------------------------------------------- spec + registry


def test_fleet_spec_validation():
    with pytest.raises(ValueError, match="n_population"):
        FleetSpec(n_population=10)  # population requires a cohort
    with pytest.raises(ValueError, match="cohort"):
        FleetSpec(n_population=4, cohort=5)
    with pytest.raises(ValueError):
        FleetSpec(cohort=-1)
    f = FleetSpec(n_population=100, cohort=8)
    assert f.population == 100 and f.cohort_size == 8
    assert FleetSpec().population == FleetSpec().n_clients
    assert FleetSpec().cohort_size is None


def test_spec_hash_elides_population_defaults():
    # Old specs must stay addressable: at the defaults the new fleet
    # fields vanish from the canonical dict, so every pre-cohort hash
    # (and its cached fixture) is unchanged.
    default = ExperimentSpec()
    explicit = ExperimentSpec(fleet=FleetSpec(n_population=0, cohort=0))
    assert default.spec_hash() == explicit.spec_hash()
    d = default.to_dict()
    assert "n_population" not in d["fleet"] and "cohort" not in d["fleet"]
    pop = ExperimentSpec(fleet=FleetSpec(n_population=64, cohort=4))
    assert pop.spec_hash() != default.spec_hash()
    pd = pop.to_dict()
    assert pd["fleet"]["n_population"] == 64
    assert pd["fleet"]["cohort"] == 4
    cfg = pop.run_config()
    assert cfg.n_clients == 64 and cfg.cohort == 4


@pytest.mark.parametrize("scheme", ["fsl", "fl1", "fl2"])
def test_baselines_reject_population_fleets(scheme):
    spec = ExperimentSpec(
        scheme=scheme, rounds=1,
        data=DataSpec(n_train=64, n_test=32),
        fleet=FleetSpec(n_population=16, cohort=2),
    )
    with pytest.raises(ValueError, match="no cohort-shaped path"):
        build_trainer(spec)


# ------------------------------------------------- end-to-end cohorts


def test_eager_ifl_cohort_rounds_with_parity():
    spec = ExperimentSpec(
        scheme="ifl", rounds=2, tau=1, batch_size=8, eval_every=0,
        seed=0, codec="int8", max_staleness=2,
        data=DataSpec(n_train=256, n_test=64),
        fleet=FleetSpec(n_population=32, cohort=4),
    )
    trainer = build_trainer(spec)
    for r in range(2):
        rep = trainer.run_round()
        assert len(rep["participants"]) == 4
        # Cohort-fresh broadcast: the cache serves this round's uploads.
        assert rep["cache_size"] == 4
        exp = ifl_round_bytes(
            32, spec.batch_size, spec.d_fusion, codec=spec.codec,
            participating=4, broadcast_entries=4)
        got = trainer.ledger.per_round[r]
        assert got["up"] == exp["up"] and got["down"] == exp["down"]
    # Only the touched slots ever paid model init.
    assert len(trainer.clients.materialized) <= 8
    accs = trainer.evaluate(np.zeros((8, 28, 28, 1), np.float32),
                            np.zeros((8,), np.int32))
    assert 0 < len(accs) <= 8
    # Population snapshots are sparse (PR 9): only materialized slots.
    tree, aux = trainer.snapshot()
    assert set(tree["clients"]) == {
        str(k) for k in trainer.clients.materialized}
    assert aux["population"]["clients"] == sorted(
        trainer.clients.materialized)


def test_spmd_ifl_cohort_rounds_with_parity():
    spec = ExperimentSpec(
        scheme="ifl_spmd", rounds=2, tau=1, batch_size=2, d_fusion=32,
        eval_every=0, seed=0,
        data=DataSpec(dataset="synth_tokens", n_test=8),
        fleet=FleetSpec(n_population=16, cohort=2),
    )
    trainer = build_trainer(spec)
    for _ in range(2):
        trainer.run_round()
    assert trainer.ledger.uplink == 2 * 2 * trainer._entry_bytes
    assert trainer.ledger.downlink == 2 * 2 * 2 * trainer._entry_bytes
    # The population store holds exactly the slots that trained.
    assert 2 <= len(trainer.store) <= 4
    assert all(0 <= s < 16 for s in trainer.store.slots())
    accs = trainer.evaluate(None, None)
    assert 0 < len(accs) <= 2
    # Population snapshots are sparse (PR 9): only the trained slots.
    tree, aux = trainer.snapshot()
    assert set(tree["slots"]) == {str(s) for s in trainer.store.slots()}
    assert aux["population"]["slots"] == sorted(trainer.store.slots())
