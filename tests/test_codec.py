"""Wire-codec invariants: round-trip error bounds, exact byte parity
between the analytic formula and the measured ledger, and the codec path
through both trainers."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import RunConfig, ModelConfig
from repro.core import Client, IFLTrainer, get_codec, ifl_round_bytes
from repro.core.codec import available_codecs
from repro.core.comm import nbytes
from repro.data import dirichlet_partition, make_synth_kmnist
from repro.models.small import (
    client_base_apply,
    client_modular_apply,
    init_client_model,
)

PARITY_CODECS = ["fp32", "bf16", "fp16", "int8", "int8_channel",
                 "int8_row", "topk", "int4", "sketch", "sketch0.5",
                 "ef(int8_row)", "ef(int4)", "ef(topk0.1)",
                 "ef(sketch0.25)"]


def _z(shape=(8, 432), seed=0, scale=2.0):
    return (jax.random.normal(jax.random.PRNGKey(seed), shape)
            * scale).astype(jnp.float32)


# ------------------------------------------------------------ round trips


@pytest.mark.parametrize("name", PARITY_CODECS)
@pytest.mark.parametrize("shape", [(8, 432), (2, 16, 64), (4, 3, 8, 128)])
def test_shape_dtype_preserved(name, shape):
    codec = get_codec(name)
    z = _z(shape)
    zh = codec.decode(codec.encode(z), shape=z.shape, dtype=z.dtype)
    assert zh.shape == z.shape
    assert zh.dtype == z.dtype


def test_fp32_is_lossless():
    z = _z()
    codec = get_codec("fp32")
    np.testing.assert_array_equal(
        np.asarray(codec.decode(codec.encode(z), shape=z.shape)),
        np.asarray(z),
    )


@pytest.mark.parametrize("name,rel", [("bf16", 2 ** -8), ("fp16", 2 ** -10)])
def test_cast_codecs_relative_error(name, rel):
    z = _z()
    codec = get_codec(name)
    zh = codec.decode(codec.encode(z), shape=z.shape)
    err = np.abs(np.asarray(zh - z))
    assert err.max() <= rel * np.abs(np.asarray(z)).max() + 1e-6


@pytest.mark.parametrize("name", ["int8", "int8_channel"])
def test_int8_affine_error_bound(name):
    """Affine int8 error is bounded by scale/2 = (max-min)/510 (per
    tensor or per channel)."""
    z = _z()
    codec = get_codec(name)
    zh = codec.decode(codec.encode(z), shape=z.shape)
    zn = np.asarray(z)
    if name == "int8":
        bound = (zn.max() - zn.min()) / 510.0
    else:
        bound = (zn.max(0) - zn.min(0)) / 510.0  # per-channel
    assert np.all(np.abs(np.asarray(zh) - zn) <= bound + 1e-6)


def test_int8_row_error_bound():
    z = _z()
    codec = get_codec("int8_row")
    zh = codec.decode(codec.encode(z), shape=z.shape)
    bound = np.abs(np.asarray(z)).max(-1, keepdims=True) / 254.0
    assert np.all(np.abs(np.asarray(zh - z)) <= bound + 1e-6)


def test_int8_constant_tensor_no_nan():
    """Zero dynamic range must not divide by zero."""
    z = jnp.full((4, 32), 3.5)
    for name in ["int8", "int8_channel", "int8_row"]:
        zh = get_codec(name).decode(get_codec(name).encode(z), shape=z.shape)
        assert np.all(np.isfinite(np.asarray(zh)))


def test_topk_keeps_largest_exactly_and_zeros_rest():
    z = _z((6, 64))
    codec = get_codec("topk0.25")
    k = codec.k_of(64)
    zh = np.asarray(codec.decode(codec.encode(z), shape=z.shape))
    zn = np.asarray(z)
    for r in range(zn.shape[0]):
        top = np.argsort(-np.abs(zn[r]))[:k]
        np.testing.assert_allclose(zh[r, top], zn[r, top], rtol=1e-6)
        rest = np.setdiff1d(np.arange(64), top)
        np.testing.assert_array_equal(zh[r, rest], 0.0)


def test_topk_ratio_parsing_and_registry_errors():
    assert get_codec("topk0.1").k_of(100) == 10
    assert get_codec(None).name == "fp32"
    c = get_codec("int8")
    assert get_codec(c) is c
    with pytest.raises(ValueError):
        get_codec("gzip")
    with pytest.raises(ValueError):
        get_codec("topk7.5")
    assert "int8" in available_codecs()
    assert "sketch" in available_codecs()
    assert get_codec("sketch0.1").w_of(100) == 10
    with pytest.raises(ValueError):
        get_codec("sketch7.5")


def test_sketch_bucket_mean_decode_and_no_sidecar():
    """Count-sketch: the wire payload is ONLY the w bucket sums (no
    index sidecar, unlike topk); decode is the bucket-mean estimator,
    which reconstructs each feature as the signed mean of its bucket —
    and is therefore non-expansive (the projection property the
    registry-wide energy bound relies on)."""
    from repro.core.codec import _sketch_tables

    codec = get_codec("sketch0.25")
    z = _z((6, 64))
    payload = codec.encode(z)
    assert set(payload) == {"sketch"}  # nothing else crosses the wire
    w = codec.w_of(64)
    assert payload["sketch"].shape == (6, w)
    h, s, inv_counts = _sketch_tables(64, w, codec.seed)
    zn = np.asarray(z)
    # Hand-built sketch: bucket sums of the signed features.
    expect = np.zeros((6, w), np.float32)
    for i in range(64):
        expect[:, h[i]] += zn[:, i] * s[i]
    np.testing.assert_allclose(np.asarray(payload["sketch"]), expect,
                               rtol=1e-5, atol=1e-5)
    zh = np.asarray(codec.decode(payload, shape=z.shape))
    np.testing.assert_allclose(
        zh, (expect * inv_counts)[:, h] * s, rtol=1e-5, atol=1e-5)
    # Non-expansive, deterministically (not just in expectation).
    assert np.linalg.norm(zh - zn) <= np.linalg.norm(zn) + 1e-5
    # decode without the original shape must refuse (w is not
    # invertible to d).
    with pytest.raises(ValueError):
        codec.decode(payload)
    # Same shared tables on both ends: a fresh codec instance decodes.
    zh2 = get_codec("sketch0.25").decode(payload, shape=z.shape)
    np.testing.assert_array_equal(zh, np.asarray(zh2))


# ------------------------------------------------------------ byte parity


@pytest.mark.parametrize("name", PARITY_CODECS)
def test_wire_bytes_measured_equals_analytic(name):
    """wire_bytes(encode(z)) == encoded_nbytes(z.shape), exactly."""
    codec = get_codec(name)
    for shape in [(32, 432), (2, 8, 128), (1, 431)]:
        z = _z(shape)
        payload = codec.encode(z)
        assert codec.wire_bytes(payload) == codec.encoded_nbytes(shape)
        assert codec.wire_bytes(payload) == nbytes(payload)


def test_int4_error_bound_and_packing():
    """Packed int4: |err| <= row-absmax/14, odd dims pad exactly one
    nibble, and the packed payload is byte-sized."""
    for shape in [(8, 432), (3, 431)]:
        z = _z(shape)
        codec = get_codec("int4")
        payload = codec.encode(z)
        assert payload["q4"].dtype == jnp.uint8
        assert payload["q4"].shape[-1] == (shape[-1] + 1) // 2
        zh = codec.decode(payload, shape=z.shape)
        bound = np.abs(np.asarray(z)).max(-1, keepdims=True) / 14.0
        assert np.all(np.abs(np.asarray(zh - z)) <= bound + 1e-6)


def test_ef_wrapping_preserves_wire_format():
    """ef(<codec>) is invisible on the wire: same payload structure and
    bytes, stateless encode identical to the inner codec's."""
    z = _z()
    for inner in ["int8_row", "int4", "topk0.1"]:
        ef = get_codec(f"ef({inner})")
        base = get_codec(inner)
        assert ef.has_state and not base.has_state
        assert ef.encoded_nbytes(z.shape) == base.encoded_nbytes(z.shape)
        pe, pb = ef.encode(z), base.encode(z)
        assert jax.tree.structure(pe) == jax.tree.structure(pb)
        for a, b in zip(jax.tree.leaves(pe), jax.tree.leaves(pb)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("name", ["fp32", "bf16", "int8", "topk",
                                  "int4", "sketch", "ef(int8_row)",
                                  "ef(topk0.1)", "ef(sketch0.25)"])
def test_ledger_parity_two_client_round(name):
    """CommLedger measured bytes == ifl_round_bytes(..., codec=) on a
    real 2-client round — the acceptance-criteria parity check."""
    tx, ty, _, _ = make_synth_kmnist(600, 100)
    cfg = RunConfig(tau=2, batch_size=16, codec=name)
    shards = dirichlet_partition(ty, 2, alpha=0.5, seed=0)
    clients = []
    for k in range(2):
        cid = k + 1
        clients.append(Client(
            cid=cid,
            params=init_client_model(jax.random.PRNGKey(cid), cid),
            base_apply=functools.partial(
                lambda p, x, c: client_base_apply({"base": p}, c, x), c=cid),
            modular_apply=functools.partial(
                lambda p, z, c: client_modular_apply({"modular": p}, c, z),
                c=cid),
            data_x=tx[shards[k]], data_y=ty[shards[k]],
        ))
    tr = IFLTrainer(clients, cfg, seed=3)
    m = tr.run_round()
    assert np.isfinite(m["base_loss"]) and np.isfinite(m["mod_loss"])
    exp = ifl_round_bytes(2, cfg.batch_size, cfg.d_fusion, codec=name)
    got = tr.ledger.per_round[0]
    assert got["up"] == exp["up"], (name, got, exp)
    assert got["down"] == exp["down"], (name, got, exp)
    if tr.codec.has_state:
        # EF residual: per client, z-shaped, fp32, updated by the round
        # — and invisible to the ledger (asserted by the parity above).
        for e in tr.ef_state.values():
            assert e.shape == (cfg.batch_size, cfg.d_fusion)
            assert e.dtype == jnp.float32
            assert np.any(np.asarray(e))


def test_compressed_uplink_ratios():
    """The Fig.-2 acceptance ratios, analytically: int8 >= 3.5x, bf16 ~2x."""
    fp32 = ifl_round_bytes(4, 32, 432, codec="fp32")["up"]
    assert fp32 / ifl_round_bytes(4, 32, 432, codec="int8")["up"] >= 3.5
    assert fp32 / ifl_round_bytes(4, 32, 432, codec="bf16")["up"] >= 1.9
    assert fp32 / ifl_round_bytes(4, 32, 432, codec="topk0.1")["up"] >= 4.5
    assert fp32 / ifl_round_bytes(4, 32, 432, codec="int4")["up"] >= 7.0
    # EF changes the payload's content, never its size.
    assert (ifl_round_bytes(4, 32, 432, codec="ef(int4)")
            == ifl_round_bytes(4, 32, 432, codec="int4"))
    # codec=None keeps the legacy act_bytes formula (fp32-identical).
    assert ifl_round_bytes(4, 32, 432)["up"] == fp32


# ------------------------------------------------------------ SPMD path


def test_spmd_round_step_with_codec():
    """encode -> 'client' all-gather -> decode inside the jitted round."""
    from jax.sharding import Mesh

    from repro.core.ifl_spmd import init_ifl_state, make_ifl_round_step

    cfg = ModelConfig(
        num_layers=2, d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
        vocab_size=64, d_fusion=32, q_block=16, compute_dtype="float32",
        remat="none",
    ).validate()
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1, 1),
                ("client", "data", "model"))
    params, opt_state = init_ifl_state(jax.random.PRNGKey(0), cfg,
                                       n_clients=2)
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (2, 2, 2, 16), 0, 64)}
    for codec in ["int8", "topk"]:
        step = jax.jit(make_ifl_round_step(
            cfg, mesh, n_clients=2, tau=1, lr_base=1e-2, lr_modular=1e-2,
            codec=codec,
        ))
        with mesh:
            _, _, m = step(params, opt_state, batch)
        assert np.isfinite(float(m["base_loss"])), codec
        assert np.isfinite(float(m["mod_loss"])), codec
