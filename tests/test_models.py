"""Model substrate unit + property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.config import LayerSpec, ModelConfig
from repro.models.attention import blocked_attention
from repro.models.rope import apply_mrope, apply_rope, default_mrope_positions
from repro.models.ssm import init_mamba, mamba_decode, mamba_forward, init_mamba_cache
from repro.models.moe import init_moe, moe_forward
from repro.models.transformer import init_lm, lm_apply
from repro.kernels import ref


# ------------------------------------------------------------ attention


@given(
    s=st.sampled_from([32, 64, 96]),
    qb=st.sampled_from([16, 32]),
    window=st.sampled_from([-1, 24]),
    kvh=st.sampled_from([1, 2]),
)
@settings(max_examples=10)
def test_blocked_attention_equals_ref(s, qb, window, kvh):
    h, hd = 4, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (2, s, h, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, s, kvh, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, s, kvh, hd))
    out = blocked_attention(q, k, v, window=window, q_block=qb)
    # ref wants (B,H,S,hd)
    g = h // kvh
    want = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3),
        jnp.repeat(k.transpose(0, 2, 1, 3), g, 1),
        jnp.repeat(v.transpose(0, 2, 1, 3), g, 1),
        causal=True, window=window,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_lm_causality():
    """Changing token t must not affect logits at positions < t."""
    cfg = ModelConfig(num_layers=2, d_model=32, num_heads=2, num_kv_heads=2,
                      d_ff=64, vocab_size=64, q_block=16,
                      compute_dtype="float32", remat="none").validate()
    params = init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 32), 0, 64)
    l1, _, _ = lm_apply(params, cfg, {"tokens": toks})
    toks2 = toks.at[0, 20].set((toks[0, 20] + 7) % 64)
    l2, _, _ = lm_apply(params, cfg, {"tokens": toks2})
    np.testing.assert_allclose(np.asarray(l1[:, :20]),
                               np.asarray(l2[:, :20]), atol=1e-5)
    assert not np.allclose(np.asarray(l1[:, 20:]), np.asarray(l2[:, 20:]))


# ------------------------------------------------------------ rope


def test_rope_relative_position_property():
    """RoPE inner products depend only on relative positions."""
    hd = 32
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, hd))

    def score(pq, pk):
        qr = apply_rope(q, jnp.array([[pq]]), 1e4)
        kr = apply_rope(k, jnp.array([[pk]]), 1e4)
        return float(jnp.sum(qr * kr))

    assert abs(score(5, 3) - score(10, 8)) < 1e-4
    assert abs(score(5, 3) - score(6, 3)) > 1e-6


def test_mrope_equals_rope_for_text():
    """Text tokens (equal ids on all 3 axes) make M-RoPE = 1-D RoPE."""
    hd = 32
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, hd))
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8)).astype(jnp.int32)
    pos3 = jnp.stack([pos, pos, pos])
    a = apply_rope(x, pos, 1e4)
    b = apply_mrope(x, pos3, 1e4, (8, 4, 4))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_default_mrope_positions_grid():
    pos = default_mrope_positions(1, 24, 16)
    assert pos.shape == (3, 1, 24)
    # image tokens: temporal id 0, grid ids < 4 for a 4x4 grid
    assert int(pos[0, 0, :16].max()) == 0
    assert int(pos[1, 0, :16].max()) == 3
    # text continues from the grid max
    assert int(pos[0, 0, 16]) == 4


# ------------------------------------------------------------ mamba


def test_mamba_chunked_scan_equals_stepwise():
    """Chunked associative scan == sequential recurrence (decode path)."""
    cfg = ModelConfig(d_model=32, num_heads=2, num_kv_heads=2,
                      compute_dtype="float32").validate()
    p = init_mamba(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 32)) * 0.5
    full = mamba_forward(p, cfg, x)
    cache = init_mamba_cache(cfg, 2, jnp.float32)
    outs = []
    for t in range(24):
        y, cache = mamba_decode(p, cfg, x[:, t : t + 1], cache)
        outs.append(y[:, 0])
    step = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                               atol=2e-4, rtol=2e-3)


# ------------------------------------------------------------ moe


def test_moe_all_tokens_routed_with_slack_capacity():
    cfg = ModelConfig(d_model=32, num_experts=4, num_experts_per_tok=2,
                      moe_d_ff=64, capacity_factor=8.0, num_heads=2,
                      num_kv_heads=2, compute_dtype="float32").validate()
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    y, aux = moe_forward(p, cfg, x)
    assert y.shape == x.shape
    assert float(aux) > 0  # load-balance loss active
    # with high capacity, output must differ from zero for every token
    norms = jnp.linalg.norm(y, axis=-1)
    assert float(jnp.min(norms)) > 0


def test_moe_capacity_drops_tokens_deterministically():
    cfg_hi = ModelConfig(d_model=32, num_experts=4, num_experts_per_tok=1,
                         moe_d_ff=64, capacity_factor=8.0, num_heads=2,
                         num_kv_heads=2, compute_dtype="float32").validate()
    cfg_lo = cfg_hi.replace(capacity_factor=0.25)
    p = init_moe(jax.random.PRNGKey(0), cfg_hi)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 32))
    y_hi, _ = moe_forward(p, cfg_hi, x)
    y_lo, _ = moe_forward(p, cfg_lo, x)
    # low capacity zeroes some tokens' routed contribution
    dropped = jnp.sum(jnp.linalg.norm(y_lo, axis=-1) < 1e-9)
    kept = jnp.sum(jnp.linalg.norm(y_hi, axis=-1) < 1e-9)
    assert int(dropped) > int(kept)


# ------------------------------------------------------------ base/modular


def test_base_modular_partition_is_exhaustive():
    """Every param leaf lives in exactly one of base/modular."""
    cfg = ModelConfig(num_layers=4, d_model=32, num_heads=2, num_kv_heads=2,
                      d_ff=64, vocab_size=64,
                      compute_dtype="float32").validate()
    params = init_lm(jax.random.PRNGKey(0), cfg)
    assert set(params.keys()) == {"base", "modular"}
    n_all = len(jax.tree.leaves(params))
    n_b = len(jax.tree.leaves(params["base"]))
    n_m = len(jax.tree.leaves(params["modular"]))
    assert n_all == n_b + n_m


def test_z_is_only_interface():
    """Modular forward needs ONLY z (privacy: no base params, no raw x)."""
    from repro.models.transformer import modular_forward

    cfg = ModelConfig(num_layers=2, d_model=32, num_heads=2, num_kv_heads=2,
                      d_ff=64, vocab_size=64, d_fusion=16,
                      compute_dtype="float32").validate()
    params = init_lm(jax.random.PRNGKey(0), cfg)
    z = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    logits, aux = modular_forward(params["modular"], cfg, z)
    assert logits.shape == (2, 8, 64)
