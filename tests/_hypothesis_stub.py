"""Deterministic fallback for `hypothesis` in minimal environments.

CI and dev containers without hypothesis installed must still collect
and run the tier-1 suite (the property tests are load-bearing kernel
oracles). conftest.py installs this module into ``sys.modules`` as
``hypothesis`` / ``hypothesis.strategies`` ONLY when the real package is
absent. ``@given`` then expands each test into a small fixed sweep of
examples drawn deterministically from the declared strategies — no
shrinking, no randomization, but every strategy's boundary values are
exercised. With real hypothesis installed this file is never imported.
"""

from __future__ import annotations

import inspect
import itertools
import random
import types
from typing import Any, List

MAX_EXAMPLES = 15


class _Strategy:
    """A strategy is just an ordered list of representative examples."""

    def __init__(self, examples: List[Any]):
        seen, uniq = set(), []
        for e in examples:
            key = repr(e)
            if key not in seen:
                seen.add(key)
                uniq.append(e)
        self.examples = uniq


def floats(min_value: float, max_value: float, **_kw) -> _Strategy:
    lo, hi = float(min_value), float(max_value)
    mid = (lo + hi) / 2.0
    return _Strategy([lo, hi, mid, lo + (hi - lo) * 0.25,
                      lo + (hi - lo) * 0.75])


def integers(min_value: int, max_value: int, **_kw) -> _Strategy:
    lo, hi = int(min_value), int(max_value)
    mid = (lo + hi) // 2
    return _Strategy([lo, hi, mid, min(lo + 1, hi), max(hi - 1, lo)])


def booleans() -> _Strategy:
    return _Strategy([False, True])


def sampled_from(seq) -> _Strategy:
    return _Strategy(list(seq))


def given(*arg_strats: _Strategy, **kw_strats: _Strategy):
    """Run the test once per deterministic example tuple.

    Draws from the full cartesian product of the strategies' example
    lists: the all-first-values tuple always runs, the rest is a
    fixed-seed sample of the product — so every strategy contributes
    every one of its values somewhere in the sweep (no index pinning),
    and the selection is identical on every run.
    """

    def deco(fn):
        # Like real hypothesis: positional strategies bind to the
        # function's RIGHTMOST parameters (in order), keyword strategies
        # by name; everything else (parametrize args, fixtures) comes
        # from pytest.
        sig = inspect.signature(fn)
        all_names = [p.name for p in sig.parameters.values()]
        pos_names = all_names[len(all_names) - len(arg_strats):] \
            if arg_strats else []
        strat_names = pos_names + list(kw_strats)

        def wrapper(*args, **kwargs):
            pools = [s.examples for s in arg_strats] + [
                kw_strats[n].examples for n in kw_strats
            ]
            if not pools:
                fn(*args, **kwargs)
                return
            combos = list(itertools.product(*pools))
            picked = combos[:1]
            rest = combos[1:]
            n_extra = min(MAX_EXAMPLES, len(combos)) - 1
            if n_extra > 0:
                picked += random.Random(0).sample(rest, n_extra)
            # Guarantee no value is left out entirely: append one combo
            # per missing (slot, value) pair.
            for j, pool in enumerate(pools):
                seen = {c[j] for c in picked}
                for v in pool:
                    if v not in seen:
                        base = list(picked[0])
                        base[j] = v
                        picked.append(tuple(base))
            for combo in picked:
                fn(*args, **kwargs, **dict(zip(strat_names, combo)))

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        # Expose the non-strategy parameters to pytest's collection,
        # exactly as real hypothesis does: strategy-supplied names
        # vanish from the reported signature, everything else stays.
        wrapper.__signature__ = sig.replace(parameters=[
            p for p in sig.parameters.values() if p.name not in strat_names
        ])
        return wrapper

    return deco


class settings:
    """No-op stand-in: profiles and per-test overrides are accepted and
    ignored (the stub's example count is already CI-sized)."""

    _profiles: dict = {}

    def __init__(self, *args, **kwargs):
        pass

    def __call__(self, fn):
        return fn

    @classmethod
    def register_profile(cls, name, parent=None, **kwargs):
        cls._profiles[name] = kwargs

    @classmethod
    def load_profile(cls, name):
        pass


def install(sys_modules) -> None:
    """Register this module as `hypothesis` (+ `.strategies`)."""
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.HealthCheck = types.SimpleNamespace(all=staticmethod(lambda: []))
    st = types.ModuleType("hypothesis.strategies")
    st.floats = floats
    st.integers = integers
    st.booleans = booleans
    st.sampled_from = sampled_from
    hyp.strategies = st
    sys_modules["hypothesis"] = hyp
    sys_modules["hypothesis.strategies"] = st
