"""Exchange-plane invariants: the refactor is behavior-preserving
(run_experiment under broadcast='full' reproduces the tracked PR-4
fixtures bit for bit), delta-broadcast downlink is in exact
analytic↔ledger parity for every schedule × codec on both backends,
delta and full broadcast produce identical training (same decoded cache
state by construction), and the fusion cache now snapshots/restores —
including mid-staleness entries and delta-mirror state."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import RunConfig
from repro.core import (
    DELTA_SIDECAR_BYTES,
    Client,
    FusionExchange,
    IFLTrainer,
    ifl_round_bytes,
    parse_broadcast,
)
from repro.core.rounds import ParticipationSchedule

D_FUSION = 32
N_CLIENTS = 4
BATCH = 4


def _tiny_clients(n=N_CLIENTS, d=D_FUSION, samples=64, seed=0):
    """Linear toy vendors (as in test_rounds): base is an elementwise
    gain, so d_fusion is satisfied with near-zero compute."""
    rng = np.random.default_rng(seed)
    clients = []
    for k in range(n):
        x = rng.normal(size=(samples, d)).astype(np.float32)
        y = rng.integers(0, 10, size=samples).astype(np.int32)
        params = {
            "base": jnp.ones((d,)) * (1.0 + 0.1 * k),
            "modular": jnp.asarray(
                rng.normal(size=(d, 10)).astype(np.float32) * 0.05),
        }
        clients.append(Client(
            cid=k, params=params,
            base_apply=lambda p, x: x * p,
            modular_apply=lambda m, z: z @ m,
            data_x=x, data_y=y,
        ))
    return clients


def _leaves_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------------------------ policy


def test_parse_broadcast():
    assert parse_broadcast(None) == "full"
    assert parse_broadcast("full") == "full"
    assert parse_broadcast("delta") == "delta"
    with pytest.raises(ValueError, match="unknown broadcast"):
        parse_broadcast("gzip")
    with pytest.raises(ValueError, match="unknown broadcast"):
        # Surfaces at trainer construction, through the plane.
        IFLTrainer(_tiny_clients(), RunConfig(broadcast="multicast"))
    with pytest.raises(ValueError, match="unknown broadcast"):
        ifl_round_bytes(4, BATCH, D_FUSION, broadcast="gzip")


# --------------------------------------------------- delta ledger parity

SCHEDULES = ["full", "k2", "bern0.5", "straggle(0.5,2)"]
CODECS = ["fp32", "int8_row", "ef(int4)", "sketch"]


@pytest.mark.parametrize("schedule", SCHEDULES)
@pytest.mark.parametrize("codec", CODECS)
def test_delta_ledger_parity_under_schedule(schedule, codec):
    """EXACT analytic↔ledger parity under delta broadcast, every round,
    for every participation schedule × codec: uplink is unchanged (K
    fresh payloads), downlink is the shipped-entry count E times
    (entry + slot-index sidecar) — E rides in the round metrics."""
    cfg = RunConfig(n_clients=N_CLIENTS, tau=1, batch_size=BATCH,
                    d_fusion=D_FUSION, codec=codec,
                    participation=schedule, broadcast="delta")
    tr = IFLTrainer(_tiny_clients(), cfg, seed=11)
    full_cfg = RunConfig(n_clients=N_CLIENTS, tau=1, batch_size=BATCH,
                         d_fusion=D_FUSION, codec=codec,
                         participation=schedule)
    tr_full = IFLTrainer(_tiny_clients(), full_cfg, seed=11)
    for r in range(6):
        m = tr.run_round()
        m_full = tr_full.run_round()
        k = len(m["participants"])
        exp = ifl_round_bytes(
            N_CLIENTS, BATCH, D_FUSION, codec=codec,
            participating=k, broadcast_entries=m["cache_size"],
            broadcast="delta", delta_entries=m["shipped_entries"],
        )
        got = tr.ledger.per_round[r]
        assert got["up"] == exp["up"], (r, got, exp)
        assert got["down"] == exp["down"], (r, got, exp)
        # Same seed => same schedule draws; uplink identical to full.
        assert m["participants"] == m_full["participants"]
        assert got["up"] == tr_full.ledger.per_round[r]["up"]
        # Steady state at full participation: E == K exactly (the
        # acceptance formula K*(payload) + sidecar).
        if schedule == "full" and r > 0:
            assert m["shipped_entries"] == k
    # Delta never ships more than full unicast pays for.
    assert tr.ledger.downlink <= tr_full.ledger.downlink


def test_delta_steady_state_matches_acceptance_formula():
    """Full participation, round r>0: per-round downlink == K * (encoded
    payload + labels) + K * sidecar — the issue's acceptance expression
    — for every registered codec family."""
    for codec in ["fp32", "bf16", "int8", "int8_row", "int4", "topk",
                  "sketch", "ef(int4)", "ef(topk0.25)"]:
        cfg = RunConfig(n_clients=N_CLIENTS, tau=0, batch_size=BATCH,
                        d_fusion=D_FUSION, codec=codec, broadcast="delta")
        tr = IFLTrainer(_tiny_clients(), cfg, seed=0)
        tr.run_round()
        m = tr.run_round()
        k = N_CLIENTS
        entry = ifl_round_bytes(1, BATCH, D_FUSION, codec=codec,
                                participating=1, broadcast_entries=0)["up"]
        assert tr.ledger.per_round[1]["down"] == \
            k * entry + k * DELTA_SIDECAR_BYTES, codec
        assert m["shipped_entries"] == k


def test_delta_empty_round_ships_nothing():
    class Nobody(ParticipationSchedule):
        name = "nobody"

        def mask(self, round_idx, n, rng):
            return np.zeros(n, bool)

    cfg = RunConfig(n_clients=2, tau=1, batch_size=BATCH,
                    d_fusion=D_FUSION, participation=Nobody(),
                    broadcast="delta")
    tr = IFLTrainer(_tiny_clients(n=2), cfg, seed=0)
    m = tr.run_round()
    assert m["shipped_entries"] == 0
    assert tr.ledger.per_round[0] == {"up": 0, "down": 0}


def test_delta_rejoin_ships_catch_up_entries():
    """A client that missed rounds has a stale mirror: the round it
    rejoins, the shipped set includes the entries it missed (catch-up),
    and afterwards its mirror equals the server's valid cache — the
    construction that makes delta == full training exact."""

    class Absent1(ParticipationSchedule):
        """Round 0: everyone. Rounds 1-2: all but slot 1. Round 3: all."""

        name = "absent1"

        def mask(self, round_idx, n, rng):
            m = np.ones(n, bool)
            if round_idx in (1, 2):
                m[1] = False
            return m

    cfg = RunConfig(n_clients=3, tau=0, batch_size=BATCH,
                    d_fusion=D_FUSION, participation=Absent1(),
                    broadcast="delta")
    tr = IFLTrainer(_tiny_clients(n=3), cfg, seed=0)
    ship = [tr.run_round()["shipped_entries"] for _ in range(4)]
    # r0: 3 fresh. r1/r2: 2 fresh only (slot 1 offline; its stale entry
    # is already mirrored by the others). r3: slot 1 rejoins, but the
    # other slots re-upload fresh this round, so the 3 fresh entries
    # already cover its catch-up — no extra shipping.
    assert ship == [3, 2, 2, 3]
    # The invariant behind delta == full: after every sync, each
    # participant's mirror equals the server's valid cache.
    for p in range(3):
        assert tr.exchange.mirrors.versions[p] == {
            s: e.round_idx
            for s, e in tr.engine.cache.valid_entries(tr.engine.round_idx)
        }


def test_delta_rejoin_catch_up_exceeds_fresh_set():
    """Force a genuine catch-up: the rejoining client needs an entry
    that did NOT refresh this round, so E > K_fresh-entries-only."""

    class Trace(ParticipationSchedule):
        """r0: all. r1: slots {0,1} (2 uploads). r2: slot 2 rejoins with
        slot 0; slot 1 absent. Slot 2's mirror misses slot 1's round-1
        payload -> it must ship as catch-up although it is not fresh."""

        name = "trace"

        def mask(self, round_idx, n, rng):
            rows = {0: [1, 1, 1], 1: [1, 1, 0], 2: [1, 0, 1]}
            m = np.array(rows.get(round_idx, [1, 1, 1]), bool)
            return m

    cfg = RunConfig(n_clients=3, tau=0, batch_size=BATCH,
                    d_fusion=D_FUSION, participation=Trace(),
                    broadcast="delta")
    tr = IFLTrainer(_tiny_clients(n=3), cfg, seed=0)
    ships = [tr.run_round() for _ in range(3)]
    assert [m["shipped_entries"] for m in ships] == [3, 2, 3]
    # Round 2: fresh = {0, 2}; catch-up = slot 1's round-1 entry.
    m2 = ships[2]
    assert len(m2["participants"]) == 2 and m2["shipped_entries"] == 3
    exp = ifl_round_bytes(3, BATCH, D_FUSION, participating=2,
                          broadcast="delta", delta_entries=3)
    assert tr.ledger.per_round[2] == exp


def test_delta_k1_eager_spmd_accounting_agree():
    """Regression: K=1 rounds must not re-ship the sole fresh entry to
    its own producer, on EITHER backend — the SPMD host accounting used
    to skip note_upload and overcount exactly there. Feed the SPMD
    plane the eager trainer's participant trace; the ledgers must agree
    round for round."""
    from repro.core import SPMDFusionExchange

    cfg = RunConfig(n_clients=2, tau=0, batch_size=BATCH,
                    d_fusion=D_FUSION, participation="k1",
                    broadcast="delta")
    tr = IFLTrainer(_tiny_clients(n=2), cfg, seed=2)
    ex = SPMDFusionExchange("fp32", None, n_clients=2, broadcast="delta")
    entry = ifl_round_bytes(1, BATCH, D_FUSION, participating=1,
                            broadcast_entries=0)["up"]
    for r in range(6):
        m = tr.run_round()
        valid, shipped = ex.account_round(m["participants"], r, entry)
        ex.ledger.end_round()
        assert valid == m["cache_size"]
        assert shipped == m["shipped_entries"], r
        assert ex.ledger.per_round[r] == tr.ledger.per_round[r], r
    # And the K=1 base case explicitly: a repeat participant with a
    # current mirror ships nothing at all.
    ex2 = SPMDFusionExchange("fp32", None, n_clients=2, broadcast="delta")
    assert ex2.account_round([0], 0, entry) == (1, 0)  # own entry only
    assert ex2.account_round([0], 1, entry) == (1, 0)  # nothing new
    assert ex2.account_round([1], 2, entry) == (2, 1)  # needs slot 0's


def test_expected_delta_entries_matches_measured():
    """The dry-run's analytic mean shipped-entry count: exactly N at
    full participation, strictly above the K-fresh best case under
    partial schedules (rejoin catch-up), and — for a deterministic
    schedule — EQUAL to a real trainer's measured mean."""
    from repro.core.exchange import expected_delta_entries
    from repro.core.rounds import parse_participation

    n, R = 4, 8
    assert expected_delta_entries(parse_participation("full"), n) == n
    k2 = expected_delta_entries(parse_participation("k2"), n)
    assert 2.0 < k2 <= n  # catch-up makes it > K
    sched = "straggle(0.5,2)"
    exp = expected_delta_entries(parse_participation(sched), n, rounds=R)
    cfg = RunConfig(n_clients=n, tau=0, batch_size=BATCH,
                    d_fusion=D_FUSION, participation=sched,
                    broadcast="delta")
    tr = IFLTrainer(_tiny_clients(), cfg, seed=0)
    shipped = [tr.run_round()["shipped_entries"] for _ in range(R)]
    assert exp == sum(shipped) / R


# --------------------------------------------- delta == full convergence


def test_delta_equals_full_training_bitwise():
    """The convergence smoke: delta and full broadcast produce the SAME
    decoded cache state by construction, hence bitwise-identical params
    and identical accuracy — only the downlink bytes differ."""
    accs = {}
    params = {}
    ex = np.random.default_rng(3).normal(
        size=(64, D_FUSION)).astype(np.float32)
    ey = np.random.default_rng(4).integers(
        0, 10, size=64).astype(np.int32)
    down = {}
    for policy in ("full", "delta"):
        cfg = RunConfig(n_clients=N_CLIENTS, tau=2, batch_size=BATCH,
                        d_fusion=D_FUSION, codec="ef(int4)",
                        participation="k2", broadcast=policy)
        tr = IFLTrainer(_tiny_clients(), cfg, seed=7)
        for _ in range(8):
            tr.run_round()
        accs[policy] = tr.evaluate(ex, ey)
        params[policy] = [c.params for c in tr.clients]
        down[policy] = tr.ledger.downlink
    assert accs["delta"] == accs["full"]
    _leaves_equal(params["delta"], params["full"])
    assert down["delta"] < down["full"]


# ------------------------------------------- PR-4 fixture bit-parity

_FIXTURES = os.path.join(os.path.dirname(__file__), "..",
                         "results", "paper")

_PR4_CASES = [
    ("ifl", "full", "fp32"),
    ("ifl", "k2", "fp32"),
    ("ifl", "full", "ef(int4)"),
    ("fsl", "full", "fp32"),
    ("fsl", "k2", "fp32"),
    ("fl1", "full", "fp32"),
    ("fl1", "k2", "fp32"),
    ("fl2", "full", "fp32"),
    ("fl2", "k2", "fp32"),
]


def _legacy_name(scheme, participation, codec):
    tag = f"{scheme}_r4_n800_tau2_s0_lr0.05"
    if codec != "fp32":
        tag += f"_c{codec}"
    if participation != "full":
        tag += f"_p{participation}"
    return tag + ".json"


@pytest.mark.parametrize("scheme,participation,codec", _PR4_CASES)
def test_run_experiment_reproduces_pr4_fixtures(scheme, participation,
                                                codec):
    """THE refactor acceptance: under broadcast='full' (the default —
    note the spec hash is unchanged, so these fixtures stay
    addressable), a live run_experiment reproduces the tracked PR-4
    fixture records bit for bit on every scheme × schedule × ef(int4)
    smoke combination."""
    from repro.api import DataSpec, ExperimentSpec, run_experiment

    path = os.path.join(_FIXTURES, _legacy_name(scheme, participation,
                                                codec))
    with open(path) as f:
        fixture = json.load(f)
    spec = ExperimentSpec(scheme=scheme, rounds=4, tau=2, eval_every=1,
                          participation=participation, codec=codec,
                          data=DataSpec(n_train=800, n_test=200))
    res = run_experiment(spec)  # no cache_dir: always a live run
    assert res.records == fixture["records"]


# ------------------------------------- cache snapshot / restore (bitwise)


@pytest.mark.parametrize("broadcast", ["full", "delta"])
def test_snapshot_restore_covers_mid_staleness_cache(tmp_path, broadcast):
    """Snapshot at a point where the cache holds MID-STALENESS entries
    (slot 3 uploaded two rounds ago under straggle(0.25,4) with
    max_staleness=2): the restored trainer replays the continuation bit
    for bit — cache contents, ages, downlink bytes, delta mirrors and
    all. A cold-started cache would broadcast fewer entries and diverge
    immediately."""
    from repro.api import load_trainer, save_trainer

    def build():
        cfg = RunConfig(n_clients=4, tau=1, batch_size=BATCH,
                        d_fusion=D_FUSION, codec="ef(int8_row)",
                        participation="straggle(0.25,4)",
                        max_staleness=2, broadcast=broadcast)
        return IFLTrainer(_tiny_clients(), cfg, seed=5)

    tr = build()
    for _ in range(5):  # slot 3 uploads at t=3 -> age 1 at snapshot
        tr.run_round()
    stale = tr.engine.cache.staleness(tr.engine.round_idx)
    assert max(stale.values()) >= 1, stale  # genuinely mid-staleness
    path = str(tmp_path / "ck")
    save_trainer(path, tr)
    cont = [tr.run_round() for _ in range(4)]

    tr2 = load_trainer(path, build())
    # The cache came back: same slots, same ages.
    assert tr2.engine.cache.staleness(tr2.engine.round_idx) == stale
    replay = [tr2.run_round() for _ in range(4)]
    for a, b in zip(cont, replay):
        assert a["base_loss"] == b["base_loss"]
        assert a["mod_loss"] == b["mod_loss"]
        assert a["participants"] == b["participants"]
        assert a["cache_size"] == b["cache_size"]
        assert a["uplink_mb"] == b["uplink_mb"]
        assert a["downlink_mb"] == b["downlink_mb"]  # cache+mirrors back
        if broadcast == "delta":
            assert a["shipped_entries"] == b["shipped_entries"]
    _leaves_equal([c.params for c in tr.clients],
                  [c.params for c in tr2.clients])
    _leaves_equal(tr.snapshot()[0], tr2.snapshot()[0])


def test_restored_cache_entries_bitwise(tmp_path):
    """The restored entries decode to exactly the snapshot's z_hat/y
    (not just matching metadata)."""
    from repro.api import load_trainer, save_trainer

    def build():
        cfg = RunConfig(n_clients=3, tau=0, batch_size=BATCH,
                        d_fusion=D_FUSION, codec="int8_row",
                        participation="k2")
        return IFLTrainer(_tiny_clients(n=3), cfg, seed=9)

    tr = build()
    for _ in range(3):
        tr.run_round()
    before = {s: (np.asarray(e.z_hat), np.asarray(e.y), e.round_idx)
              for s, e in tr.engine.cache.valid_entries(3)}
    assert before  # something to restore
    path = str(tmp_path / "ck")
    save_trainer(path, tr)
    tr2 = load_trainer(path, build())
    after = {s: (np.asarray(e.z_hat), np.asarray(e.y), e.round_idx)
             for s, e in tr2.engine.cache.valid_entries(3)}
    assert before.keys() == after.keys()
    for s in before:
        np.testing.assert_array_equal(before[s][0], after[s][0])
        np.testing.assert_array_equal(before[s][1], after[s][1])
        assert before[s][2] == after[s][2]


# ------------------------------------------------------- SPMD delta parity


@pytest.mark.parametrize("codec", ["int8_row", "ef(int4)"])
def test_spmd_adapter_delta_ledger_parity(codec):
    """The SPMD front-door adapter under broadcast='delta': per-round
    ledger == ifl_round_bytes(broadcast='delta', delta_entries=E) with
    the plane's host accounting, E and the valid-entry count riding in
    the report metrics — and the host cache_valid replay agrees with the
    jitted program's (same mask stream by construction)."""
    from repro.api import DataSpec, ExperimentSpec, run_experiment

    B, S, dF = 2, 32, 32
    spec = ExperimentSpec(
        scheme="ifl_spmd", rounds=4, tau=1, batch_size=B, d_fusion=dF,
        lr=0.05, eval_every=0, seed=0, participation="k2", codec=codec,
        broadcast="delta",
        data=DataSpec(dataset="synth_tokens", n_test=8))
    res = run_experiment(spec, keep_trainer=True)
    tr = res.trainer
    for r, rep in enumerate(tr.engine.history):
        exp = ifl_round_bytes(
            4, B * S, dF, codec=codec,
            participating=len(rep["participants"]),
            broadcast_entries=rep["cache_size"],
            broadcast="delta", delta_entries=rep["shipped_entries"])
        assert tr.ledger.per_round[r] == exp, (r, exp)
    # Identical training to the full-broadcast run, cheaper downlink.
    full = run_experiment(spec.replace(broadcast="full"),
                          keep_trainer=True)
    _leaves_equal(tr.params, full.trainer.params)
    assert res.downlink_mb < full.downlink_mb
    assert res.uplink_mb == full.uplink_mb
    # Host staleness replay == in-program cache_valid metric.
    for a, b in zip(tr.engine.history, full.trainer.engine.history):
        assert a["cache_size"] == b["cache_size"]


def test_spmd_snapshot_restores_delta_mirrors(tmp_path):
    """SPMD resume under delta: the plane's host state (last-upload
    replica + mirrors) checkpoints, so the replayed rounds ledger the
    same delta bytes."""
    from repro.api import (DataSpec, ExperimentSpec, build_trainer,
                           load_trainer, save_trainer)

    spec = ExperimentSpec(
        scheme="ifl_spmd", rounds=8, tau=1, batch_size=2, d_fusion=32,
        lr=0.05, eval_every=0, seed=1, participation="k2",
        codec="int8_row", broadcast="delta",
        data=DataSpec(dataset="synth_tokens", n_test=8))
    tr = build_trainer(spec)
    for _ in range(2):
        tr.run_round()
    path = str(tmp_path / "ck")
    save_trainer(path, tr)
    cont = [tr.run_round() for _ in range(2)]
    tr2 = load_trainer(path, build_trainer(spec))
    replay = [tr2.run_round() for _ in range(2)]
    for a, b in zip(cont, replay):
        assert a["participants"] == b["participants"]
        assert a["shipped_entries"] == b["shipped_entries"]
        assert a["uplink_mb"] == b["uplink_mb"]
        assert a["downlink_mb"] == b["downlink_mb"]
        assert a["base_loss"] == b["base_loss"]


def test_legacy_tag_cache_never_serves_a_delta_spec(tmp_path):
    """Regression: legacy filename tags predate the broadcast axis, so
    a delta spec must NOT be served the (full-broadcast) legacy fixture
    its tag would collide with — while the full spec still reads it."""
    from repro.api import DataSpec, ExperimentSpec, run_experiment

    spec = ExperimentSpec(rounds=1, tau=1, batch_size=8, lr=0.05,
                          eval_every=0, broadcast="delta",
                          data=DataSpec(n_train=256, n_test=64))
    legacy = tmp_path / "ifl_r1_n256_tau1_s0_lr0.05.json"
    legacy.write_text(json.dumps(
        {"scheme": "ifl", "records": [{"round": 0, "acc_mean": -1.0}]}))
    res = run_experiment(spec, cache_dir=str(tmp_path))
    assert res.records[0]["acc_mean"] != -1.0  # a live run, not the fixture
    full = run_experiment(spec.replace(broadcast="full"),
                          cache_dir=str(tmp_path))
    assert full.records[0]["acc_mean"] == -1.0  # legacy path still serves


def test_spmd_legacy_aux_restore_rebuilds_age_replica():
    """Regression: restoring a pre-exchange-plane SPMD checkpoint (aux
    without the 'exchange' key) brings the carried cache back warm —
    the host accounting must rebuild its age replica from the restored
    ages rather than under-ledger the broadcasts the program runs."""
    from repro.api import DataSpec, ExperimentSpec, build_trainer

    spec = ExperimentSpec(
        scheme="ifl_spmd", rounds=8, tau=1, batch_size=2, d_fusion=32,
        lr=0.05, eval_every=0, seed=3, participation="k2",
        data=DataSpec(dataset="synth_tokens", n_test=8))
    tr = build_trainer(spec)
    for _ in range(3):
        tr.run_round()
    tree, aux = tr.snapshot()
    assert "exchange" in aux
    legacy_aux = {k: v for k, v in aux.items() if k != "exchange"}
    tr2 = build_trainer(spec)
    tr2.restore(tree, legacy_aux)
    assert tr2.exchange._last_upload == tr.exchange._last_upload
    a, b = tr.run_round(), tr2.run_round()
    assert a["cache_size"] == b["cache_size"]
    assert a["downlink_mb"] == b["downlink_mb"]


# --------------------------------------------------- spec hash stability


def test_broadcast_axis_preserves_default_spec_hash():
    """broadcast='full' is elided from the canonical dict, so every
    pre-existing spec hash — and the tracked results/paper fixtures —
    stays addressable; only non-default values hash as new experiments."""
    from repro.api import ExperimentSpec

    base = ExperimentSpec()
    assert base.spec_hash() == "07ebadbcf790"  # the PR-4 pin, unmoved
    assert "broadcast" not in base.to_dict()
    delta = base.replace(broadcast="delta")
    assert delta.to_dict()["broadcast"] == "delta"
    assert delta.spec_hash() != base.spec_hash()
    # Round trips, both through dicts missing and carrying the field.
    assert ExperimentSpec.from_dict(base.to_dict()) == base
    assert ExperimentSpec.from_dict(delta.to_dict()) == delta
    assert base.run_config().broadcast == "full"
    assert delta.run_config().broadcast == "delta"
