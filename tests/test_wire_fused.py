"""Fused wire-path kernels (Pallas, interpret mode) vs their jnp oracles.

The contract under test: every fused encode variant — int8/int4 row
quant, top-k select, count-sketch, and the EF21 epilogue around each —
is BITWISE identical to the jnp codec it replaces (payload, sidecar,
and carried EF residual), with the jnp path as silent fallback wherever
no fused scheme exists. The oracle side is always jitted: that is what
the exchange planes execute, and op-by-op eager XLA may legitimately
differ in the last bit (constant-divisor reciprocal rewrites).

``CODEC_MATRIX=1`` (the CI kernel-matrix leg) widens the arch sweep
from the distinct d_fusion values to the full per-arch config list.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.core.codec import get_codec, quantize_rows_sym
from repro.core.exchange import FusionExchange, SPMDFusionExchange
from repro.kernels import ops, ref, wire_fused
from repro.kernels.fusion_proj import fusion_proj_encode_pallas

MATRIX = bool(os.environ.get("CODEC_MATRIX"))

# Every arch config under CODEC_MATRIX; the distinct d_fusion values
# (one arch each) otherwise — same kernels, fewer interpret-mode runs.
_D_OF = {a: get_config(a).d_fusion for a in ARCH_IDS}
if MATRIX:
    ARCHES = list(ARCH_IDS)
else:
    seen, ARCHES = set(), []
    for a in ARCH_IDS:
        if _D_OF[a] not in seen:
            seen.add(_D_OF[a])
            ARCHES.append(a)


def _z(shape, seed=0, scale=1.0):
    return (jax.random.normal(jax.random.PRNGKey(seed), shape)
            * scale).astype(jnp.float32)


def _assert_bitwise(a, b, label):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb), label
    for x, y in zip(la, lb):
        assert x.shape == y.shape and x.dtype == y.dtype, label
        assert np.asarray(x).tobytes() == np.asarray(y).tobytes(), label


# ------------------------------------------------- encode bitwise parity


@pytest.mark.parametrize("arch", ARCHES)
@pytest.mark.parametrize("name", ["ef(int4)", "topk"])
def test_arch_configs_bitwise(arch, name):
    """The acceptance pair — fused ef(int4) and topk — is bitwise-equal
    to the jnp oracle at every arch's d_fusion, EF residual included."""
    codec = get_codec(name)
    z = _z((4, _D_OF[arch]), seed=hash(arch) % 1000, scale=2.0)
    if codec.has_state:
        e = codec.init_state(z.shape)
        p_f, e_f = codec.fused_encode_with_state(z, e, interpret=True)
        p_o, e_o = jax.jit(codec.encode_with_state)(z, e)
        _assert_bitwise(e_f, e_o, (arch, name, "residual"))
    else:
        p_f = codec.fused_encode(z, interpret=True)
        p_o = jax.jit(codec.encode)(z)
    _assert_bitwise(p_f, p_o, (arch, name, "payload"))


@pytest.mark.parametrize("name", ["int8_row", "int4", "topk", "topk0.1",
                                  "sketch"])
@pytest.mark.parametrize("d", [432, 433])
def test_full_codec_set_bitwise(name, d):
    """All fused schemes at the paper d_fusion and at odd d (int4
    nibble padding, topk/sketch width rounding)."""
    codec = get_codec(name)
    for shape in [(12, d), (3, 4, d), (d,)]:
        z = _z(shape, seed=d, scale=3.0)
        p_f = codec.fused_encode(z, interpret=True)
        assert p_f is not None, (name, shape)
        _assert_bitwise(p_f, jax.jit(codec.encode)(z), (name, shape))


@pytest.mark.parametrize("name", ["ef(int8_row)", "ef(int4)", "ef(topk)",
                                  "ef(sketch)"])
def test_ef_recurrence_identity(name):
    """The EF21 recurrence stays bitwise-locked over rounds: feeding the
    fused path its own residual reproduces the oracle's payload AND
    residual at every step — no drift accumulates."""
    codec = get_codec(name)
    z0 = _z((6, 432), seed=5)
    e_o = codec.init_state((6, 432))
    e_f = e_o
    for t in range(4):
        z = z0 * (0.37 * (t + 1))
        p_o, e_o = jax.jit(codec.encode_with_state)(z, e_o)
        p_f, e_f = codec.fused_encode_with_state(z, e_f, interpret=True)
        _assert_bitwise(p_f, p_o, (name, t, "payload"))
        _assert_bitwise(e_f, e_o, (name, t, "residual"))


def test_zero_row_guard():
    """All-zero fusion rows: quantize_rows_sym must emit scale 1.0 (not
    the 1e-12 epsilon that round-trips garbage magnitudes), q == 0, and
    the fused path must inherit the guard from the shared helper."""
    z = jnp.zeros((4, 432), jnp.float32)
    q, scale = quantize_rows_sym(z)
    assert np.all(np.asarray(scale) == 1.0)
    assert not np.any(np.asarray(q))
    mixed = jnp.concatenate([z[:2], _z((2, 432), seed=9)], axis=0)
    for name in ["int8_row", "int4"]:
        codec = get_codec(name)
        dec = codec.decode(codec.encode(z), shape=z.shape,
                           dtype=jnp.float32)
        assert not np.any(np.asarray(dec))
        _assert_bitwise(codec.fused_encode(mixed, interpret=True),
                        jax.jit(codec.encode)(mixed), name)


def test_fallback_is_never_an_error():
    """Codecs without a fused scheme return None from every fused_*
    entry point — and the exchange plane silently keeps the jnp path."""
    z = _z((4, 432))
    for name in ["bf16", "fp16", "fp32", "int8", "int8_channel"]:
        codec = get_codec(name)
        assert codec.fused_encode(z, interpret=True) is None
        assert codec.fused_spec(z.shape) is None
    assert get_codec("ef(bf16)").fused_encode_with_state(
        z, get_codec("ef(bf16)").init_state(z.shape), interpret=True
    ) is None
    # Over-wide d: scheme refuses, jnp path still serves.
    wide = _z((2, wire_fused.MAX_FUSED_D + 1))
    assert get_codec("int8_row").fused_encode(wide, interpret=True) is None
    ex = FusionExchange("bf16", 2, (4, 432), fused=True)
    ex.upload(0, z, jnp.zeros((4,), jnp.int32), 0)  # must not raise


# ------------------------------------------------- exchange-plane parity


@pytest.mark.parametrize("name", ["int8_row", "ef(int4)"])
def test_fusion_exchange_fused_parity(name):
    """fused=True and fused=False planes stay bitwise-locked through
    rounds: cached payload, decoded z_hat, EF residual, ledger bytes."""
    exs = [FusionExchange(name, 2, (8, 432), fused=f)
           for f in (False, True)]
    for t in range(3):
        z = _z((8, 432), seed=t, scale=t + 1.0)
        y = jnp.arange(8, dtype=jnp.int32)
        for ex in exs:
            ex.upload(0, z, y, t)
    e0, e1 = exs
    c0, c1 = e0.cache._entries[0], e1.cache._entries[0]
    _assert_bitwise(c0.payload, c1.payload, name)
    _assert_bitwise(c0.z_hat, c1.z_hat, name)
    if e0.codec.has_state:
        _assert_bitwise(e0.ef_state[0], e1.ef_state[0], name)
    assert e0.ledger.uplink_mb == e1.ledger.uplink_mb


@pytest.mark.parametrize("name", ["int8_row", "ef(int4)"])
def test_spmd_wire_fused_parity(name):
    """The jitted SPMD wire() block: fused flattening of the (client,
    batch) axes equals the vmapped per-client oracle bitwise."""
    from jax.sharding import Mesh

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1, 1),
                ("client", "data", "model"))
    N, B, D = 4, 8, 432
    z = _z((N, B, D), seed=11)
    tok = jnp.zeros((N, B, 16), jnp.int32)
    outs = []
    with mesh:
        for f in (False, True):
            ex = SPMDFusionExchange(name, mesh, n_clients=N, fused=f)
            ef = jax.vmap(lambda _: ex.codec.init_state((B, D)))(
                jnp.arange(N))
            step = jax.jit(
                lambda z, tok, ef, _ex=ex: _ex.wire(z, tok, None, None, ef))
            outs.append(step(z, tok, ef))
    (zg0, _, _, _, ef0), (zg1, _, _, _, ef1) = outs
    _assert_bitwise(zg0, zg1, name)
    _assert_bitwise(ef0, ef1, name)


# ------------------------------------------- consumer prologue + epilogue


@pytest.mark.parametrize("name", ["int8_row", "int4", "topk", "sketch"])
def test_decode_proj_matches_ref(name):
    """Decode-as-prologue: one launch == decode-then-project oracle."""
    codec = get_codec(name)
    rows, d, n = 12, 432, 256
    z = _z((rows, d), seed=3)
    w = _z((d, n), seed=4, scale=0.05)
    b = _z((n,), seed=5, scale=0.1)
    p = codec.encode(z)
    y_ref = ref.decode_proj_ref(p, w, b, "relu", codec=codec,
                                shape=(rows, d))
    y = ops.decode_proj(p, w, b, "relu", codec=codec, shape=(rows, d),
                        use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-5)


@pytest.mark.parametrize("name", ["int8_row", "int4", "topk", "sketch"])
def test_proj_encode_epilogue_matches_ref(name):
    """Projection+encode epilogue: K-tiled accumulation reorders float
    sums, so values get allclose and discrete leaves a <2% round-off
    flip budget (same tolerance as the int8 quant kernel suite)."""
    codec = get_codec(name)
    m, k, n = 16, 96, 432
    x = _z((m, k), seed=6)
    w = _z((k, n), seed=7, scale=0.05)
    scheme = wire_fused.scheme_for(codec, n)
    outs = fusion_proj_encode_pallas(x, w, None, "none", scheme=scheme,
                                     bm=8, bk=32, interpret=True)
    p_f = dict(zip(scheme.leaf_names, outs))
    p_ref = ref.fusion_proj_encode_ref(x, w, None, "none", codec=codec)
    for key in p_ref:
        a, b = np.asarray(p_f[key]), np.asarray(p_ref[key])
        if a.dtype.kind == "f":
            np.testing.assert_allclose(a, b, atol=1e-4)
        else:
            assert np.mean(a != b) < 0.02, (name, key)


def test_proj_encode_ef_epilogue():
    codec = get_codec("ef(int8_row)")
    m, k, n = 16, 96, 432
    x, w = _z((m, k), seed=8), _z((k, n), seed=9, scale=0.05)
    e = _z((m, n), seed=10, scale=0.01)
    scheme = wire_fused.scheme_for(codec.inner, n)
    outs = fusion_proj_encode_pallas(
        x, w, None, "none", scheme=scheme, e=e, max_ratio=codec.max_ratio,
        bm=8, bk=32, interpret=True)
    _, e_ref = ref.fusion_proj_encode_ref(x, w, None, "none",
                                          codec=codec, e=e)
    np.testing.assert_allclose(np.asarray(outs[-1]), np.asarray(e_ref),
                               atol=1e-4)


# ----------------------------------------------- autotuner + accounting


def test_autotuner_cache_roundtrip(tmp_path, monkeypatch):
    path = tmp_path / "wire_blocks.json"
    monkeypatch.setenv("REPRO_WIRE_BLOCKS_CACHE", str(path))
    sel = ops.autotune_wire_blocks("int8_row", 64, kind="encode",
                                   rows=32, reps=1, interpret=True)
    assert sel["bm"] in (8, 16, 32) and sel["us"] > 0
    on_disk = json.loads(path.read_text())
    assert any(k.endswith("|encode|int8_row|d64") for k in on_disk)
    # Read side returns the tuned entry; a re-tune without force is a
    # pure cache hit (identical entry, no re-timing).
    assert ops.wire_blocks("int8_row", 64)["bm"] == sel["bm"]
    assert ops.autotune_wire_blocks("int8_row", 64, kind="encode",
                                    rows=32, reps=1,
                                    interpret=True) == sel
    # Unknown (codec, d): defaults, never an error.
    assert ops.wire_blocks("int8_row", 12345) == {"bm": 256}


def test_hbm_accounting_and_spec():
    """encode_spec/encode_hbm_bytes: the dryrun-facing metadata is
    self-consistent, and the fused EF path moves strictly less HBM
    than the unfused stage chain at every arch d_fusion."""
    for name in ["int8_row", "ef(int4)"]:
        codec = get_codec(name)
        for d in sorted({v for v in _D_OF.values()}):
            hbm = wire_fused.encode_hbm_bytes(codec, (64, d))
            assert hbm["fused_bytes"] <= hbm["unfused_bytes"]
            if codec.has_state:
                assert hbm["fused_bytes"] < hbm["unfused_bytes"]
            spec = codec.fused_spec((64, d))
            assert spec["kernel"] == f"wire_encode[{name}]"
            assert spec["block_rows"] * spec["grid"][0] >= 64
    assert get_codec("bf16").fused_spec((64, 432)) is None


def test_fused_wire_report_shapes():
    rep = ops.fused_wire_report("int8_row", (32, 432))
    assert rep["fused"] and rep["path"] == "pallas"
    assert rep["kernel"] == "wire_encode[int8_row]"
    rep_off = ops.fused_wire_report("int8_row", (32, 432), fused=False)
    assert not rep_off["fused"] and rep_off["path"] == "jnp"
    rep_none = ops.fused_wire_report("bf16", (32, 432))
    assert not rep_none["fused"] and "no fused scheme" in rep_none["fallback"]
