"""The device-resident serving hot loop (ISSUE 10).

Covers, in order:
  - the horizon contract: random workloads served at fused horizons are
    bitwise equal (tokens AND completion metadata) to the horizon=1
    engine and to the fixed-batch oracle — the property suite drives
    random arrival patterns, prompt lengths, EOS positions, and
    horizons through all three (runs identically under real hypothesis
    and the in-repo deterministic stub),
  - the frozen pre-PR fixture: the horizon=1 engine reproduces the
    recorded PR-9 engine streams bitwise (and so do fused horizons),
  - edge battery: empty ticks between sparse arrivals, every slot
    evicted mid-horizon, EOS on the prefill token, max_new_tokens=1,
  - ONE host sync per engine step: a counting wrapper around
    ``jax.device_get`` proves the per-token `np.asarray` and the
    per-admission `int(first)` syncs are gone,
  - the exact run() step budget: a full-queue run drains strictly
    within ``step_budget()`` at every horizon,
  - non-greedy sampling: temperature/top-k streams are deterministic,
    horizon-invariant, engine == oracle bitwise, and greedy rows in the
    same lane are untouched,
  - bucketed batch admission: mixed prompt-length buckets in one
    boundary stay bitwise-exact; bucket edges don't change tokens,
  - the serve-plan autotuner cache round-trip (tmp JSON cache,
    ``horizon="auto"`` pickup).
"""

import json
import os

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from test_serve import _smoke_store
from repro.serve import Request, ServeEngine

VOCAB = 128

STORE = _smoke_store(6)

# Donor engines keyed by (width, cache_len): fresh_clone shares the
# lanes' compiled horizon/admission programs, so the property sweep
# compiles each (S, bucket) program once, not once per example.
_DONORS = {}


def make_engine(width=3, cache_len=32, horizon=1, bucket_edges=None):
    key = (width, cache_len)
    donor = _DONORS.get(key)
    if donor is None:
        eng = ServeEngine(STORE, width=width, cache_len=cache_len,
                          horizon=horizon, bucket_edges=bucket_edges)
        _DONORS[key] = eng
        return eng
    eng = donor.fresh_clone()
    eng.horizon = int(horizon)
    if bucket_edges:
        eng.bucket_edges = list(bucket_edges)
        for lane in eng._lanes.values():
            lane.bucket_edges = sorted(bucket_edges)
    return eng


def _workload(seed, n, *, eos_mode="none", max_new_lo=1, max_new_hi=8,
              spread=3, temperature=0.0, top_k=0):
    """Deterministic random workload: prompt lengths 1..9, arrivals in
    bursts ``spread`` ticks apart, optional EOS ids drawn from the
    vocab so some streams hit them by chance."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(1, 10))
        eos = -1
        if eos_mode == "random":
            eos = int(rng.integers(0, VOCAB))
        reqs.append(Request(
            rid=i, tenant=f"t{int(rng.integers(0, 6))}",
            prompt=[int(x) for x in rng.integers(0, VOCAB, plen)],
            max_new_tokens=int(rng.integers(max_new_lo, max_new_hi + 1)),
            arrival=int(rng.integers(0, 3)) * spread + (i // 4),
            eos_id=eos, temperature=temperature, top_k=top_k,
            seed=seed,
        ))
    return reqs


def _meta(c):
    return (c.rid, tuple(c.tokens), c.finish_reason, c.prompt_len)


def _serve_all(reqs, horizon, width=3, cache_len=32, bucket_edges=None):
    eng = make_engine(width, cache_len, horizon, bucket_edges)
    comps = eng.run(list(reqs))
    return eng, [_meta(c) for c in comps]


# ------------------------------------------------- the horizon contract


@settings(deadline=None, max_examples=8)
@given(seed=st.integers(min_value=0, max_value=5),
       horizon=st.sampled_from([2, 3, 5, 8]),
       eos=st.booleans())
def test_property_fused_equals_h1_equals_oracle(seed, horizon, eos):
    """Random arrivals x prompt lengths x EOS positions x horizons:
    fused, horizon=1, and the oracle agree bitwise on tokens AND
    completion metadata (finish_reason, prompt_len)."""
    reqs = _workload(seed, 8, eos_mode="random" if eos else "none")
    eng1, m1 = _serve_all(reqs, 1)
    _, mh = _serve_all(reqs, horizon)
    assert mh == m1
    oracle = [_meta(eng1.oracle(r)) for r in reqs]
    assert m1 == oracle


def test_fixture_pre_pr_engine_bitwise():
    """The tracked serving fixture was captured from the PR-9 engine
    BEFORE this refactor: horizon=1 must reproduce it bitwise, and any
    fused horizon must match too (admission granularity changes ticks,
    never tokens)."""
    with open(os.path.join(os.path.dirname(__file__), "data",
                           "serving_fixture.json")) as f:
        fix = json.load(f)
    reqs = [Request(**r) for r in fix["requests"]]
    want = [(c["rid"], tuple(c["tokens"]), c["finish_reason"],
             c["prompt_len"]) for c in fix["completions"]]
    for horizon in (1, 4):
        eng = ServeEngine(STORE, width=fix["config"]["width"],
                          cache_len=fix["config"]["cache_len"],
                          horizon=horizon)
        got = [_meta(c) for c in eng.run(list(reqs))]
        assert got == want, f"horizon={horizon} diverged from fixture"


# ------------------------------------------------------- edge battery


def test_empty_steps_between_sparse_arrivals():
    """Arrivals far sparser than the horizon: the engine spins empty
    boundary steps without launching decode, then serves normally."""
    reqs = [Request(rid=i, tenant=f"t{i}", prompt=[7, i + 1],
                    max_new_tokens=3, arrival=i * 40) for i in range(3)]
    eng, m = _serve_all(reqs, 8)
    _, m1 = _serve_all(reqs, 1)
    assert m == m1
    assert all(lane.n_active == 0 for lane in eng._lanes.values())


def test_all_slots_evicted_mid_horizon():
    """Every in-flight request finishes mid-window while later arrivals
    still queue: the lane fully drains, then re-admits at the next
    boundary — streams stay bitwise."""
    reqs = [Request(rid=i, tenant=f"t{i % 3}", prompt=[3 + i],
                    max_new_tokens=2, arrival=0) for i in range(3)]
    reqs += [Request(rid=3 + i, tenant=f"t{3 + i}", prompt=[11, 5 + i],
                     max_new_tokens=3, arrival=25) for i in range(2)]
    _, m = _serve_all(reqs, 8, width=3)
    _, m1 = _serve_all(reqs, 1, width=3)
    assert m == m1


def test_done_on_prefill_and_eos_first_token():
    """max_new_tokens=1 and EOS-on-first-token requests complete from
    the admission transfer alone and free their slots."""
    eng0 = make_engine(3, 32, 1)
    probe = eng0.run([Request(rid=0, tenant="t0", prompt=[9, 9],
                              max_new_tokens=2)])
    first = probe[0].tokens[0]
    reqs = [
        Request(rid=0, tenant="t0", prompt=[9, 9], max_new_tokens=1),
        Request(rid=1, tenant="t0", prompt=[9, 9], max_new_tokens=4,
                eos_id=first),
        Request(rid=2, tenant="t1", prompt=[5], max_new_tokens=3),
    ]
    for horizon in (1, 4):
        eng, m = _serve_all(reqs, horizon)
        by = {t[0]: t for t in m}
        assert by[0][2] == "length" and len(by[0][1]) == 1
        assert by[1][2] == "eos" and by[1][1] == (first,)
        assert all(lane.n_active == 0 for lane in eng._lanes.values())


# ------------------------------------------- one device_get per step


def test_one_device_get_per_engine_step(monkeypatch):
    """The hot loop's host-sync regression gate: an engine step makes
    EXACTLY one ``jax.device_get`` call — no per-token ``np.asarray``,
    no per-admission ``int(first)``."""
    reqs = _workload(3, 7, eos_mode="random")
    eng = make_engine(3, 32, 4)
    for r in reqs:
        eng.submit(r)
    calls = {"n": 0}
    real = jax.device_get

    def counting(x):
        calls["n"] += 1
        return real(x)

    monkeypatch.setattr(jax, "device_get", counting)
    steps = 0
    while eng.inflight > 0:
        eng.step()
        steps += 1
    assert steps > 1
    assert calls["n"] == steps, (
        f"{calls['n']} device_get calls over {steps} steps")


# ----------------------------------------------------- exact budget


@pytest.mark.parametrize("horizon", [1, 4])
def test_full_queue_run_within_step_budget(horizon):
    """A queue much deeper than total slots drains strictly within the
    exact ``step_budget()`` bound."""
    reqs = _workload(11, 18, eos_mode="random", max_new_hi=6)
    eng = make_engine(2, 32, horizon)
    for r in reqs:
        eng.submit(r)
    budget = eng.step_budget()
    steps = 0
    while eng.inflight > 0:
        assert steps < budget, "exceeded the exact step budget"
        eng.step()
        steps += 1
    assert steps < budget  # strictly within
    # and run() itself accepts its own bound:
    eng2 = make_engine(2, 32, horizon)
    assert len(eng2.run(list(reqs))) == len(reqs)


# ------------------------------------------------- non-greedy sampling


def test_sampling_engine_equals_oracle_and_horizon_invariant():
    reqs = _workload(5, 6, max_new_lo=3, temperature=0.8, top_k=5)
    eng1, m1 = _serve_all(reqs, 1)
    _, m4 = _serve_all(reqs, 4)
    assert m1 == m4
    assert m1 == [_meta(eng1.oracle(r)) for r in reqs]
    # deterministic: same seed reruns bitwise; different seed diverges
    _, again = _serve_all(reqs, 4)
    assert again == m4
    bumped = [Request(rid=r.rid, tenant=r.tenant, prompt=r.prompt,
                      max_new_tokens=r.max_new_tokens, arrival=r.arrival,
                      temperature=r.temperature, top_k=r.top_k,
                      seed=r.seed + 1) for r in reqs]
    _, other = _serve_all(bumped, 4)
    assert other != m4


def test_greedy_rows_untouched_by_sampling_neighbors():
    """Admitting non-greedy requests upgrades the lane to the sampling
    program; greedy rows in the same lane keep their exact streams."""
    greedy = _workload(9, 4, max_new_lo=3)
    mixed = list(greedy) + [
        Request(rid=100 + i, tenant=f"t{i}", prompt=[13, 7],
                max_new_tokens=4, temperature=1.2, top_k=3, seed=i)
        for i in range(2)
    ]
    _, solo = _serve_all(greedy, 4)
    _, both = _serve_all(mixed, 4)
    by = {t[0]: t for t in both}
    assert all(by[t[0]] == t for t in solo)


# --------------------------------------------------- bucketed admission


def test_bucket_edges_do_not_change_tokens():
    """Mixed prompt lengths land in different buckets in one boundary;
    collapsing to a single max-length bucket is bitwise identical
    (ragged prefill freezes padded steps)."""
    reqs = _workload(13, 8, eos_mode="random")
    _, m_pow2 = _serve_all(reqs, 4)
    _, m_one = _serve_all(reqs, 4, bucket_edges=[32])
    _, m_fine = _serve_all(reqs, 4, bucket_edges=[2, 4, 6, 8, 16, 32])
    assert m_pow2 == m_one == m_fine


# ------------------------------------------------- serve-plan autotune


def test_serve_plan_cache_roundtrip(tmp_path, monkeypatch):
    from repro.kernels import ops

    monkeypatch.setenv("REPRO_SERVE_PLAN_CACHE",
                       str(tmp_path / "serve_plan.json"))
    ops._serve_cache_mem = None
    try:
        calls = []

        def timer(h, edges):
            calls.append((h, tuple(edges)))
            return {1: 3.0, 2: 1.0, 4: 2.0}[h]

        plan = ops.autotune_serve_plan(
            "unit|W3|L32", timer, horizons=(1, 2, 4),
            edge_sets=((8, 32),))
        assert plan["horizon"] == 2 and plan["bucket_edges"] == [8, 32]
        assert len(calls) == 3
        # read side + cache hit (no re-timing)
        assert ops.serve_plan("unit|W3|L32")["horizon"] == 2
        again = ops.autotune_serve_plan("unit|W3|L32", timer,
                                        horizons=(1, 2, 4),
                                        edge_sets=((8, 32),))
        assert again["horizon"] == 2 and len(calls) == 3
        # horizon="auto" picks the tuned plan up for a matching engine
        eng = ServeEngine(STORE, width=3, cache_len=32, horizon="auto")
        assert eng.horizon == 8  # different plan_key -> default
        monkeypatch.setattr(ServeEngine, "plan_key",
                            lambda self: "unit|W3|L32")
        eng = ServeEngine(STORE, width=3, cache_len=32, horizon="auto")
        assert eng.horizon == 2 and eng.bucket_edges == [8, 32]
    finally:
        ops._serve_cache_mem = None
