"""Pallas kernels vs pure-jnp oracles (interpret mode), incl. hypothesis
shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


def _key(i=0):
    return jax.random.PRNGKey(i)


# ------------------------------------------------------------ fusion_proj


@given(
    m=st.integers(1, 96),
    k=st.sampled_from([32, 64, 432]),
    n=st.sampled_from([16, 64, 128]),
    act=st.sampled_from(["none", "relu", "silu"]),
    bias=st.booleans(),
    dtype=st.sampled_from(["float32", "bfloat16"]),
)
def test_fusion_proj_matches_ref(m, k, n, act, bias, dtype):
    dt = jnp.dtype(dtype)
    x = (jax.random.normal(_key(0), (m, k)) * 0.5).astype(dt)
    w = (jax.random.normal(_key(1), (k, n)) * 0.1).astype(dt)
    b = (jax.random.normal(_key(2), (n,)) * 0.1).astype(dt) if bias else None
    got = ops.fusion_proj(x, w, b, act, interpret=True)
    want = ref.fusion_proj_ref(x, w, b, act)
    tol = 1e-5 if dtype == "float32" else 3e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol,
    )


def test_fusion_proj_batched_leading_dims():
    x = jax.random.normal(_key(0), (2, 3, 64))
    w = jax.random.normal(_key(1), (64, 32)) * 0.1
    got = ops.fusion_proj(x, w, None, "none", interpret=True)
    assert got.shape == (2, 3, 32)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(x @ w), atol=1e-5, rtol=1e-5
    )


# ------------------------------------------------------- fused quantize


@given(
    m=st.integers(1, 64),
    # 1000 exercises the multi-K-tile accumulator + zero-pad branch
    # (nk > 1, K % bk != 0); the small Ks fit one 512-wide tile.
    k=st.sampled_from([32, 64, 432, 1000]),
    n=st.sampled_from([128, 432]),
    act=st.sampled_from(["none", "relu", "silu"]),
    bias=st.booleans(),
)
@settings(max_examples=10)
def test_fusion_proj_quant_matches_ref(m, k, n, act, bias):
    x = jax.random.normal(_key(0), (m, k)) * 0.5
    w = jax.random.normal(_key(1), (k, n)) * 0.1
    b = (jax.random.normal(_key(2), (n,)) * 0.1) if bias else None
    qg, sg = ops.fusion_proj_quant(x, w, b, act, interpret=True)
    qr, sr = ref.fusion_proj_quant_ref(x, w, b, act)
    assert qg.dtype == jnp.int8 and qg.shape == (m, n)
    assert sg.shape == (m, 1)
    np.testing.assert_allclose(np.asarray(sg), np.asarray(sr),
                               rtol=1e-5, atol=1e-12)
    # fp32 accumulation order can differ at K-tile boundaries: allow one
    # quantization step of disagreement.
    assert np.abs(np.asarray(qg, np.int32) - np.asarray(qr, np.int32)).max() <= 1


def test_fusion_proj_quant_is_the_wire_codec():
    """Fused kernel == int8_row codec applied to the fp32 projection, so
    the TPU path emits exactly the bytes the all-gather moves."""
    from repro.core.codec import get_codec

    x = jax.random.normal(_key(0), (48, 432)) * 0.5
    w = jax.random.normal(_key(1), (432, 432)) * 0.1
    qg, sg = ops.fusion_proj_quant(x, w, None, "silu", interpret=True)
    payload = get_codec("int8_row").encode(
        ref.fusion_proj_ref(x, w, None, "silu")
    )
    assert np.abs(np.asarray(qg, np.int32)
                  - np.asarray(payload["q"], np.int32)).max() <= 1
    np.testing.assert_allclose(np.asarray(sg), np.asarray(payload["scale"]),
                               rtol=1e-5, atol=1e-12)


def test_fusion_proj_quant_dequant_close():
    """q * scale reconstructs the fp32 projection within one row-scale."""
    x = jax.random.normal(_key(0), (32, 64))
    w = jax.random.normal(_key(1), (64, 128)) * 0.2
    q, s = ops.fusion_proj_quant(x, w, None, "none", interpret=True)
    y = np.asarray(ref.fusion_proj_ref(x, w, None, "none"))
    zh = np.asarray(q, np.float32) * np.asarray(s)
    assert np.all(np.abs(zh - y) <= np.asarray(s) * 0.51 + 1e-6)


# ------------------------------------------------------------ flash attn


@given(
    b=st.integers(1, 2),
    h=st.sampled_from([1, 2, 4]),
    kv_div=st.sampled_from([1, 2]),
    s=st.sampled_from([64, 128, 192]),
    hd=st.sampled_from([32, 64]),
    window=st.sampled_from([-1, 16, 48]),
    dtype=st.sampled_from(["float32", "bfloat16"]),
)
@settings(max_examples=12)
def test_flash_attention_matches_ref(b, h, kv_div, s, hd, window, dtype):
    if h % kv_div:
        kv_div = 1
    kvh = h // kv_div
    dt = jnp.dtype(dtype)
    q = jax.random.normal(_key(0), (b, h, s, hd)).astype(dt)
    k = jax.random.normal(_key(1), (b, kvh, s, hd)).astype(dt)
    v = jax.random.normal(_key(2), (b, kvh, s, hd)).astype(dt)
    got = ops.flash_attention(q, k, v, causal=True, window=window,
                              interpret=True)
    g = h // kvh
    want = ref.flash_attention_ref(
        q, jnp.repeat(k, g, 1), jnp.repeat(v, g, 1),
        causal=True, window=window,
    )
    tol = 2e-5 if dtype == "float32" else 4e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol,
    )


def test_flash_attention_causality():
    """Perturbing future keys/values must not change earlier outputs."""
    q = jax.random.normal(_key(0), (1, 2, 128, 32))
    k = jax.random.normal(_key(1), (1, 2, 128, 32))
    v = jax.random.normal(_key(2), (1, 2, 128, 32))
    base = ops.flash_attention(q, k, v, interpret=True)
    k2 = k.at[:, :, 100:].add(10.0)
    v2 = v.at[:, :, 100:].add(-5.0)
    pert = ops.flash_attention(q, k2, v2, interpret=True)
    np.testing.assert_allclose(
        np.asarray(base[:, :, :100]), np.asarray(pert[:, :, :100]),
        atol=1e-6,
    )
    assert not np.allclose(np.asarray(base[:, :, 100:]),
                           np.asarray(pert[:, :, 100:]))


def test_flash_attention_window_blocks_far_context():
    """With window w, keys more than w positions back are invisible."""
    s, w = 128, 16
    q = jax.random.normal(_key(0), (1, 1, s, 32))
    k = jax.random.normal(_key(1), (1, 1, s, 32))
    v = jax.random.normal(_key(2), (1, 1, s, 32))
    base = ops.flash_attention(q, k, v, window=w, interpret=True)
    k2 = k.at[:, :, :64].add(7.0)  # far past for rows >= 64+w
    pert = ops.flash_attention(q, k2, v, window=w, interpret=True)
    np.testing.assert_allclose(
        np.asarray(base[:, :, 64 + w :]), np.asarray(pert[:, :, 64 + w :]),
        atol=1e-6,
    )


# ------------------------------------------------------------ rmsnorm


@given(
    m=st.integers(1, 64),
    d=st.sampled_from([32, 256, 432]),
    dtype=st.sampled_from(["float32", "bfloat16"]),
)
def test_rmsnorm_matches_ref(m, d, dtype):
    dt = jnp.dtype(dtype)
    x = (jax.random.normal(_key(0), (m, d)) * 2.0).astype(dt)
    s = jax.random.normal(_key(1), (d,)).astype(dt)
    got = ops.rmsnorm(x, s, interpret=True)
    want = ref.rmsnorm_ref(x, s)
    tol = 2e-5 if dtype == "float32" else 3e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol,
    )


def test_rmsnorm_scale_invariance():
    """RMSNorm(a*x) == RMSNorm(x) for a > 0 (scale invariance)."""
    x = jax.random.normal(_key(0), (16, 64))
    s = jnp.ones((64,))
    y1 = ops.rmsnorm(x, s, interpret=True)
    y2 = ops.rmsnorm(3.7 * x, s, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
