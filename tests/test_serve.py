"""The serving plane (ISSUE 9): multi-tenant continuous batching.

Covers, in order:
  - the engine's correctness contract: a composed, continuously-batched
    served output is BITWISE equal to the same request's fixed-batch
    oracle, under interleaved arrivals/evictions and mixed lengths,
  - lane-capacity semantics (never more than W in flight per lane,
    FIFO admission by arrival) and EOS eviction (slot freed the tick
    the eos token is emitted),
  - cross-arch composition lanes (dense base + recurrent modular),
  - artifact round-trip: train (SPMD IFL) -> from_spmd_trainer ->
    save -> load -> serve, bitwise vs the in-memory store,
  - flash-decode vs jnp decode parity (the cached_attn_decode
    dispatcher's two paths),
  - sparse population snapshots (satellite: population-mode
    snapshot/restore paging through PopulationStore, bitwise resume,
    export-after-restore).
"""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api.spec import DataSpec, ExperimentSpec, FleetSpec
from repro.api.spmd import SPMDIFLTrainer, smoke_model_config
from repro.api.trainer import load_trainer, save_trainer
from repro.config import LayerSpec, ModelConfig
from repro.models.transformer import init_lm
from repro.serve import CompositionStore, Request, ServeEngine

VOCAB = 128


# ----------------------------------------------------------- fixtures


def _smoke_store(n_tenants: int = 6) -> CompositionStore:
    cfg = smoke_model_config()
    store = CompositionStore()
    store.add_arch(cfg)  # name 'spmd-smoke' resolves on load
    key = jax.random.PRNGKey(7)
    for k in range(n_tenants):
        params = init_lm(jax.random.fold_in(key, k), cfg)
        if k == 0:
            store.set_modular("spmd-smoke", params["modular"])
        store.add_tenant(f"t{k}", "spmd-smoke", params["base"])
    return store


def _requests(n, *, seed=0, arrival=None, max_new=None, tenants=6):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        out.append(Request(
            rid=i, tenant=f"t{i % tenants}",
            prompt=[int(x) for x in rng.integers(0, VOCAB, 3 + (i % 4))],
            max_new_tokens=(max_new or (3 + (i % 5))),
            arrival=(arrival(i) if arrival else i // 2),
        ))
    return out


# --------------------------------------------- parity vs oracle


def test_served_output_bitwise_equals_oracle_interleaved():
    """The tentpole contract: interleaved arrivals, mixed prompt and
    generation lengths, evictions mid-stream — every served output is
    bitwise its fixed-batch oracle's."""
    store = _smoke_store()
    eng = ServeEngine(store, width=3, cache_len=32)
    reqs = _requests(9)
    comps = eng.run(list(reqs))
    assert len(comps) == len(reqs)
    for r, c in zip(reqs, comps):
        o = eng.oracle(r)
        assert c.rid == r.rid == o.rid
        assert c.tokens == o.tokens, (
            f"rid {r.rid}: served {c.tokens} != oracle {o.tokens}"
        )
        assert len(c.tokens) == r.max_new_tokens  # no eos configured


def test_same_tenant_twice_same_prompt_same_tokens():
    store = _smoke_store()
    eng = ServeEngine(store, width=2, cache_len=32)
    prompt = [3, 1, 4, 1, 5]
    reqs = [Request(rid=i, tenant="t1", prompt=prompt, max_new_tokens=6,
                    arrival=i) for i in range(2)]
    c0, c1 = eng.run(reqs)
    assert c0.tokens == c1.tokens  # greedy + same model + same prompt


# ------------------------------------------- lane capacity / eviction


def test_lane_capacity_never_exceeds_width():
    store = _smoke_store()
    width = 2
    eng = ServeEngine(store, width=width, cache_len=32)
    for r in _requests(5, arrival=lambda i: 0, max_new=4):
        eng.submit(r)
    peak = 0
    while eng.inflight:
        eng.step()
        peak = max(peak, sum(l.n_active for l in eng._lanes.values()))
    assert peak <= width
    assert peak == width  # saturation was actually reached


def test_admission_is_fifo_by_arrival():
    store = _smoke_store()
    eng = ServeEngine(store, width=1, cache_len=32)
    # Submitted out of order; arrival order must win.
    reqs = [Request(rid=0, tenant="t0", prompt=[1, 2], max_new_tokens=3,
                    arrival=5),
            Request(rid=1, tenant="t1", prompt=[3, 4], max_new_tokens=3,
                    arrival=0)]
    comps = eng.run(reqs)
    by_rid = {c.rid: c for c in comps}
    assert by_rid[1].admitted_tick < by_rid[0].admitted_tick


def test_eos_evicts_and_frees_slot():
    """Pick the oracle's 3rd generated token as eos: the engine must
    stop there (tokens include the eos), finish_reason='eos', and the
    freed slot must admit the next queued request."""
    store = _smoke_store()
    eng = ServeEngine(store, width=1, cache_len=32)
    probe = Request(rid=0, tenant="t2", prompt=[9, 8, 7], max_new_tokens=8)
    oracle_tokens = eng.oracle(probe).tokens
    eos = oracle_tokens[2]
    reqs = [
        Request(rid=0, tenant="t2", prompt=[9, 8, 7], max_new_tokens=8,
                eos_id=eos),
        Request(rid=1, tenant="t3", prompt=[1, 2, 3], max_new_tokens=3,
                arrival=0),
    ]
    comps = eng.run(reqs)
    c0, c1 = comps
    assert c0.finish_reason == "eos"
    assert c0.tokens == oracle_tokens[:3]       # eos token included
    assert c1.finish_reason == "length"
    assert len(c1.tokens) == 3
    # Width 1: rid 1 could only start after rid 0's eviction.
    assert c1.admitted_tick >= c0.finished_tick


def test_eos_on_prefill_token_never_occupies_slot():
    store = _smoke_store()
    eng = ServeEngine(store, width=1, cache_len=32)
    probe = Request(rid=0, tenant="t4", prompt=[5, 5], max_new_tokens=4)
    first = eng.oracle(probe).tokens[0]
    comps = eng.run([Request(rid=0, tenant="t4", prompt=[5, 5],
                             max_new_tokens=4, eos_id=first)])
    assert comps[0].finish_reason == "eos"
    assert comps[0].tokens == [first]
    assert all(l.n_active == 0 for l in eng._lanes.values())


def test_submit_validation():
    store = _smoke_store()
    eng = ServeEngine(store, width=2, cache_len=16)
    with pytest.raises(KeyError):
        eng.submit(Request(rid=0, tenant="nope", prompt=[1]))
    with pytest.raises(ValueError, match="exceeds cache_len"):
        eng.submit(Request(rid=1, tenant="t0", prompt=[1] * 12,
                           max_new_tokens=8))
    with pytest.raises(ValueError, match="vocab"):
        eng.submit(Request(rid=2, tenant="t0", prompt=[VOCAB + 5],
                           max_new_tokens=2))


# ----------------------------------------- cross-arch composition


def test_cross_arch_lane_dense_base_recurrent_modular():
    """Interoperability at serve time: a dense base block composed with
    a RECURRENT modular block (different family) shares a lane, with
    the usual bitwise-oracle contract."""
    common = dict(vocab_size=VOCAB, d_fusion=32, d_model=48, num_heads=2,
                  num_kv_heads=2, compute_dtype="float32", remat="none",
                  q_block=16, mlstm_chunk=8)
    dense = ModelConfig(
        name="vendor-dense", num_layers=4, d_ff=96,
        base_pattern=(LayerSpec(),), base_groups=2,
        mod_pattern=(LayerSpec(),), mod_groups=2, **common,
    ).validate()
    recur = ModelConfig(
        name="vendor-xlstm", num_layers=4, d_ff=0, rope_type="none",
        base_pattern=(LayerSpec(mixer="mlstm", ffn="none"),),
        base_groups=2,
        mod_pattern=(LayerSpec(mixer="slstm", ffn="none"),),
        mod_groups=2, **common,
    ).validate()
    pd = init_lm(jax.random.PRNGKey(0), dense)
    pr = init_lm(jax.random.PRNGKey(1), recur)
    store = CompositionStore()
    store.add_arch(dense)
    store.add_arch(recur)
    store.set_modular("vendor-xlstm", pr["modular"])
    store.add_tenant("cross", "vendor-dense", pd["base"],
                     modular_arch="vendor-xlstm")
    eng = ServeEngine(store, width=2, cache_len=24)
    req = Request(rid=0, tenant="cross", prompt=[1, 2, 3, 4],
                  max_new_tokens=5)
    comp = eng.run([req])[0]
    assert comp.tokens == eng.oracle(req).tokens
    assert all(0 <= t < VOCAB for t in comp.tokens)


def test_add_tenant_rejects_fusion_dim_mismatch():
    cfg_a = smoke_model_config()
    cfg_b = cfg_a.replace(name="other", d_fusion=16).validate()
    p = init_lm(jax.random.PRNGKey(0), cfg_a)
    store = CompositionStore()
    store.add_arch(cfg_a)
    store.add_arch(cfg_b)
    store.set_modular("other", init_lm(jax.random.PRNGKey(1),
                                       cfg_b)["modular"])
    with pytest.raises(ValueError, match="d_fusion"):
        store.add_tenant("t", "spmd-smoke", p["base"],
                         modular_arch="other")


# ------------------------------------------------- artifact round-trip


def test_artifact_roundtrip_train_save_load_serve(tmp_path):
    """Train -> export (cache_tree fusion state) -> save -> load on a
    'fresh box' -> serve: loaded-artifact outputs bitwise equal the
    in-memory store's, fusion state preserved exactly."""
    spec = ExperimentSpec(scheme="ifl_spmd", rounds=2, tau=1, lr=0.05,
                          seed=0, fleet=FleetSpec(n_clients=3),
                          batch_size=2, participation="k2", codec="int8")
    tr = SPMDIFLTrainer(spec, seq=8)
    for _ in range(2):
        tr.run_round()
    store = CompositionStore.from_spmd_trainer(tr)
    assert store.tenants() == ["client0", "client1", "client2"]
    path = os.path.join(str(tmp_path), "artifact.npz")
    store.save(path)
    loaded = CompositionStore.load(path)
    assert loaded.tenants() == store.tenants()
    for t in store.tenants():
        a, b = store.entry(t), loaded.entry(t)
        assert a.arch == b.arch and a.modular_arch == b.modular_arch
        for x, y in zip(jax.tree.leaves(a.base), jax.tree.leaves(b.base)):
            assert np.array_equal(np.asarray(x), np.asarray(y))
        if a.fusion is not None:  # trained fusion cache rides along
            assert np.array_equal(np.asarray(a.fusion["z_hat"]),
                                  np.asarray(b.fusion["z_hat"]))
            assert np.array_equal(np.asarray(a.fusion["y"]),
                                  np.asarray(b.fusion["y"]))
    # at least the last round's k2 participants carry fusion state
    n_fusion = sum(store.entry(t).fusion is not None
                   for t in store.tenants())
    assert n_fusion >= 2
    reqs = _requests(4, tenants=3)
    reqs = [Request(rid=r.rid, tenant=f"client{r.rid % 3}",
                    prompt=r.prompt, max_new_tokens=r.max_new_tokens,
                    arrival=r.arrival) for r in reqs]
    c_mem = ServeEngine(store, width=3, cache_len=32).run(list(reqs))
    c_load = ServeEngine(loaded, width=3, cache_len=32).run(list(reqs))
    for a, b in zip(c_mem, c_load):
        assert a.tokens == b.tokens


def test_artifact_refuses_custom_unnamed_arch(tmp_path):
    cfg = smoke_model_config().replace(name="my-custom").validate()
    p = init_lm(jax.random.PRNGKey(0), cfg)
    store = CompositionStore()
    store.add_arch(cfg)
    store.set_modular("my-custom", p["modular"])
    store.add_tenant("t", "my-custom", p["base"])
    with pytest.raises(ValueError, match="cannot be serialized"):
        store.save(os.path.join(str(tmp_path), "a.npz"))


def test_tenant_id_with_slash_rejected():
    store = _smoke_store(1)
    p = init_lm(jax.random.PRNGKey(0), smoke_model_config())
    with pytest.raises(ValueError, match="must not contain"):
        store.add_tenant("a/b", "spmd-smoke", p["base"])


# ------------------------------------------- flash vs jnp decode


def test_cached_attn_decode_flash_matches_ref():
    """The serving decode dispatcher: Pallas flash-decode (interpret
    mode) against the jnp oracle, multi-kv-block, ragged validity."""
    from repro.kernels import ops, ref

    key = jax.random.PRNGKey(0)
    B, KVH, G, L, hd = 3, 2, 2, 512, 16
    q = jax.random.normal(key, (B, 1, KVH, G, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, L, KVH, hd),
                          jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, L, KVH, hd),
                          jnp.float32)
    # Live rows only: real decode always marks the current token valid.
    valid = jnp.stack([jnp.arange(L) < 5, jnp.arange(L) < L,
                       jnp.arange(L) < 300])
    want = ref.cached_attn_decode_ref(q, k, v, valid)
    got = ops.cached_attn_decode(q, k, v, valid, use_kernel=True,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-6, rtol=2e-6)
    # The jnp fallback branch IS the oracle, bitwise.
    jnp_out = ops.cached_attn_decode(q, k, v, valid, use_kernel=False)
    assert np.array_equal(np.asarray(jnp_out), np.asarray(want))


def test_flash_decode_fully_masked_row_flushes_zeros():
    from repro.kernels.flash_attention import flash_decode_pallas

    BH, L, hd = 2, 64, 16
    q = jnp.ones((BH, hd))
    k = jnp.ones((BH, L, hd))
    v = jnp.ones((BH, L, hd))
    valid = jnp.stack([jnp.zeros(L, bool), jnp.ones(L, bool)])
    out = flash_decode_pallas(q, k, v, valid, interpret=True)
    assert np.all(np.asarray(out[0]) == 0.0)
    assert np.allclose(np.asarray(out[1]), 1.0, atol=1e-6)


# --------------------------------------- sparse population snapshots


def test_spmd_population_snapshot_bitwise_resume(tmp_path):
    spec = ExperimentSpec(scheme="ifl_spmd", rounds=8, tau=1, lr=0.05,
                          seed=3, fleet=FleetSpec(n_population=6, cohort=2),
                          batch_size=2, codec="ef(int8)", max_staleness=3)
    A = SPMDIFLTrainer(spec, seq=8)
    for _ in range(3):
        A.run_round()
    path = os.path.join(str(tmp_path), "ck.npz")
    save_trainer(path, A)
    B = SPMDIFLTrainer(spec, seq=8)
    load_trainer(path, B)
    for _ in range(2):
        assert A.run_round().metrics == B.run_round().metrics
    sa, la = A.store.snapshot_state()
    sb, lb = B.store.snapshot_state()
    assert sorted(sa) == sorted(sb) and la == lb
    for s in sa:
        for x, y in zip(jax.tree.leaves(sa[s]), jax.tree.leaves(sb[s])):
            assert np.array_equal(x, y)
    ea, _ = A.ef_store.snapshot_state()
    eb, _ = B.ef_store.snapshot_state()
    assert sorted(ea) == sorted(eb)
    for s in ea:
        for x, y in zip(jax.tree.leaves(ea[s]), jax.tree.leaves(eb[s])):
            assert np.array_equal(x, y)


def test_eager_population_snapshot_bitwise_resume(tmp_path):
    from repro.api.runner import build_trainer

    spec = ExperimentSpec(scheme="ifl", rounds=8, tau=2, lr=0.03, seed=1,
                          fleet=FleetSpec(n_population=8, cohort=3),
                          codec="ef(int8)", max_staleness=2,
                          data=DataSpec(n_train=400, n_test=100))
    C = build_trainer(spec)
    for _ in range(3):
        C.run_round()
    path = os.path.join(str(tmp_path), "ck.npz")
    save_trainer(path, C)
    D = build_trainer(spec)
    load_trainer(path, D)
    for _ in range(2):
        assert C.run_round().metrics == D.run_round().metrics
    # Sparse: the checkpoint carries the touched working set only.
    touched = C.clients.materialized
    assert 0 < len(touched) <= spec.fleet.population


def test_population_restore_then_export_serves(tmp_path):
    """The satellite's acceptance story: a trained population run is
    checkpointed sparsely, restored on a fresh trainer, exported as a
    serving artifact, and served with the bitwise-oracle contract."""
    spec = ExperimentSpec(scheme="ifl_spmd", rounds=4, tau=1, lr=0.05,
                          seed=5, fleet=FleetSpec(n_population=5, cohort=2),
                          batch_size=2, codec="int8", max_staleness=3)
    A = SPMDIFLTrainer(spec, seq=8)
    for _ in range(3):
        A.run_round()
    path = os.path.join(str(tmp_path), "ck.npz")
    save_trainer(path, A)
    B = SPMDIFLTrainer(spec, seq=8)
    load_trainer(path, B)
    sa = CompositionStore.from_spmd_trainer(A)
    sb = CompositionStore.from_spmd_trainer(B)
    assert sa.tenants() == sb.tenants()
    eng = ServeEngine(sb, width=2, cache_len=32)
    t = sb.tenants()[0]
    req = Request(rid=0, tenant=t, prompt=[1, 2, 3], max_new_tokens=4)
    comp = eng.run([req])[0]
    assert comp.tokens == eng.oracle(req).tokens
    # Restored export == original export, bitwise.
    for tid in sa.tenants():
        for x, y in zip(jax.tree.leaves(sa.entry(tid).base),
                        jax.tree.leaves(sb.entry(tid).base)):
            assert np.array_equal(np.asarray(x), np.asarray(y))


def test_legacy_snapshot_paths_unchanged(tmp_path):
    """cohort=0 snapshots keep their fixed-shape template semantics
    (no snapshot_template surprises)."""
    spec = ExperimentSpec(scheme="ifl_spmd", rounds=4, tau=1, lr=0.05,
                          seed=0, fleet=FleetSpec(n_clients=2),
                          batch_size=2, codec="int8")
    A = SPMDIFLTrainer(spec, seq=8)
    A.run_round()
    path = os.path.join(str(tmp_path), "ck.npz")
    save_trainer(path, A)
    B = SPMDIFLTrainer(spec, seq=8)
    load_trainer(path, B)
    assert A.run_round().metrics == B.run_round().metrics
