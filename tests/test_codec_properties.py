"""Registry-wide wire-codec contract, property-tested.

Parametrized over ``available_codecs()`` — plus the ``ef(...)`` wrapping
of every registered codec — so any codec added to the registry later is
covered automatically, with zero per-codec test code. Properties:

  1. decode(encode(z)) keeps shape/dtype and stays finite,
  2. round-trip error obeys the codec family's analytic bound,
  3. encoded_nbytes(shape) == wire_bytes(encode(z)) — EXACT byte parity
     (what keeps the analytic formulas and the CommLedger in lockstep),
  4. the EF21 contraction invariant for stateful codecs:
     ||e'|| <= ||z + e||, and z_hat + e' reconstructs z + e,
  5. the stateless state API is a true passthrough.

Runs identically under real hypothesis and the in-repo deterministic
stub (tests/_hypothesis_stub.py) — only `integers` / `floats` /
`sampled_from` strategies, no shrinking-dependent logic. Set
``CODEC_MATRIX=1`` (the CI codec-matrix leg) to widen the shape sweep.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.codec import EFCodec, available_codecs, get_codec
from repro.core.comm import nbytes

BASE_CODECS = list(available_codecs())
EF_CODECS = [f"ef({n})" for n in BASE_CODECS] + ["ef(topk0.1)"]
ALL_CODECS = BASE_CODECS + EF_CODECS

# d choices cover: tiny, odd (exercises int4 nibble padding + topk
# rounding), and the paper's fusion dim. CODEC_MATRIX widens the sweep.
_D = [8, 431, 432] if os.environ.get("CODEC_MATRIX") else [8, 431]
_LEADS = [(4,), (2, 3)] if os.environ.get("CODEC_MATRIX") else [(4,)]


def _z(lead, d, seed, scale):
    z = jax.random.normal(jax.random.PRNGKey(seed), (*lead, d))
    return (z * scale).astype(jnp.float32)


def _max_err_bound(name, zn):
    """Analytic worst-case |z_hat - z| per element, by codec family.

    Global (not per-row/channel) form of each scheme's bound — valid
    because every per-row/channel scale is <= the global one. topk has
    no per-element bound (dropped entries err by their own magnitude);
    it is covered by the energy bound instead."""
    absmax = np.abs(zn).max()
    if name == "fp32":
        return 0.0
    if name == "bf16":
        return 2.0 ** -8 * absmax
    if name == "fp16":
        return 2.0 ** -10 * absmax
    if name in ("int8", "int8_channel"):
        return (zn.max() - zn.min()) / 510.0
    if name == "int8_row":
        return absmax / 254.0
    if name == "int4":
        return absmax / 14.0
    return None


@pytest.mark.parametrize("name", ALL_CODECS)
@given(seed=st.integers(0, 3), di=st.integers(0, len(_D) - 1),
       li=st.integers(0, len(_LEADS) - 1), scale=st.floats(0.01, 8.0))
@settings(max_examples=10, deadline=None)
def test_round_trip_contract(name, seed, di, li, scale):
    codec = get_codec(name)
    z = _z(_LEADS[li], _D[di], seed, scale)
    zh = codec.decode(codec.encode(z), shape=z.shape, dtype=z.dtype)
    assert zh.shape == z.shape
    assert zh.dtype == z.dtype
    zn, zhn = np.asarray(z), np.asarray(zh)
    assert np.all(np.isfinite(zhn))
    # Universal energy bound: a wire codec never amplifies the signal's
    # error past the signal itself (exact for fp32, loose for the rest,
    # the only bound that holds for topk's dropped coordinates).
    assert np.linalg.norm(zhn - zn) <= np.linalg.norm(zn) + 1e-5
    inner = codec.inner.name if isinstance(codec, EFCodec) else name
    bound = _max_err_bound(inner, zn)
    if bound is not None:
        assert np.abs(zhn - zn).max() <= bound + 1e-6, (name, bound)


@pytest.mark.parametrize("name", ALL_CODECS)
@given(seed=st.integers(0, 3), di=st.integers(0, len(_D) - 1),
       li=st.integers(0, len(_LEADS) - 1))
@settings(max_examples=10, deadline=None)
def test_exact_byte_parity(name, seed, di, li):
    """encoded_nbytes == wire_bytes(encode(z)) == ledger nbytes, exactly
    — for every codec, every shape, including odd d (int4 padding)."""
    codec = get_codec(name)
    z = _z(_LEADS[li], _D[di], seed, 1.0)
    payload = codec.encode(z)
    analytic = codec.encoded_nbytes(z.shape)
    assert codec.wire_bytes(payload) == analytic, name
    assert nbytes(payload) == analytic, name


@pytest.mark.parametrize("name", ALL_CODECS)
def test_state_api_contract(name):
    """Stateless codecs: () state, passthrough. EF codecs: zeros init,
    contraction ||e'|| <= ||z + e||, and (z + e) == z_hat + e' — the
    EF21 bookkeeping identity that makes the cumulative signal unbiased."""
    codec = get_codec(name)
    z = _z((4,), 64, 7, 2.0)
    if not codec.has_state:
        state = codec.init_state(z.shape)
        assert state == ()
        payload, state2 = codec.encode_with_state(z, state)
        assert state2 == ()
        np.testing.assert_array_equal(
            np.asarray(codec.decode(payload, shape=z.shape)),
            np.asarray(codec.decode(codec.encode(z), shape=z.shape)),
        )
        return
    e = codec.init_state(z.shape)
    assert e.shape == z.shape and e.dtype == jnp.float32
    assert not np.any(np.asarray(e))
    for rnd in range(3):  # the invariants must hold with a warm residual
        zr = _z((4,), 64, 10 + rnd, 2.0)
        c = np.asarray(zr.astype(jnp.float32) + e)
        payload, e = codec.encode_with_state(zr, e)
        z_hat = np.asarray(
            codec.decode(payload, shape=zr.shape, dtype=jnp.float32))
        en = np.asarray(e)
        assert e.shape == zr.shape
        # Contraction: the carried residual never exceeds what went in.
        assert np.linalg.norm(en) <= np.linalg.norm(c) + 1e-5
        # EF21 recurrence: e' = clip(c - decode(encode(c))) with the
        # per-row trust region ||e'|| <= max_ratio * ||z||.
        raw = c - z_hat
        factor = 1.0
        if codec.max_ratio is not None and np.isfinite(codec.max_ratio):
            zn = np.linalg.norm(np.asarray(zr), axis=-1, keepdims=True)
            rn = np.linalg.norm(raw, axis=-1, keepdims=True)
            factor = np.minimum(1.0, codec.max_ratio * zn
                                / np.maximum(rn, 1e-12))
            assert np.all(
                np.linalg.norm(en, axis=-1)
                <= codec.max_ratio * zn[..., 0] + 1e-4
            )
        np.testing.assert_allclose(en, raw * factor, atol=1e-4)


@given(di=st.integers(0, len(_D) - 1), seed=st.integers(0, 3))
@settings(max_examples=6, deadline=None)
def test_ef_reduces_cumulative_bias(di, seed):
    """The reason EF exists: over R rounds, mean(decode) under ef(topk)
    tracks the true mean signal strictly better than plain topk."""
    d = _D[di]
    plain = get_codec("topk0.1")
    # max_ratio=None: the textbook recurrence, whose cumulative decode
    # error telescopes to exactly the final residual.
    ef = EFCodec(inner=plain, max_ratio=None)
    e = ef.init_state((4, d))
    acc_p = jnp.zeros((4, d))
    acc_e = jnp.zeros((4, d))
    acc_z = jnp.zeros((4, d))
    base = _z((4,), d, seed, 2.0)
    for r in range(12):
        zr = base + _z((4,), d, 100 + 13 * seed + r, 0.5)
        acc_z = acc_z + zr
        acc_p = acc_p + plain.decode(plain.encode(zr), shape=zr.shape)
        payload, e = ef.encode_with_state(zr, e)
        acc_e = acc_e + ef.decode(payload, shape=zr.shape)
    # EF's cumulative decode differs from the true cumulative signal by
    # exactly the final residual; plain topk's bias grows with rounds.
    err_p = float(jnp.linalg.norm(acc_p - acc_z))
    err_e = float(jnp.linalg.norm(acc_e - acc_z))
    assert err_e < err_p
    np.testing.assert_allclose(
        np.asarray(acc_z - acc_e), np.asarray(e), atol=1e-3,
    )


def _assert_trees_bitwise(a, b, label):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb), label
    for x, y in zip(la, lb):
        assert x.shape == y.shape and x.dtype == y.dtype, label
        assert np.asarray(x).tobytes() == np.asarray(y).tobytes(), label


@pytest.mark.parametrize("name", ALL_CODECS)
@given(seed=st.integers(0, 3), di=st.integers(0, len(_D) - 1),
       li=st.integers(0, len(_LEADS) - 1), scale=st.floats(0.01, 8.0))
@settings(max_examples=6, deadline=None)
def test_fused_encode_matches_oracle(name, seed, di, li, scale):
    """Any codec exposing a fused (Pallas) encode must be bitwise-equal
    to its own jnp oracle — payload, sidecar, and (stateful) EF residual
    — on every shape; None means no fused scheme and the jnp path runs,
    never an error. The oracle is jitted because that is what the
    exchange planes execute (op-by-op eager XLA may differ in the last
    bit, e.g. constant-divisor reciprocal rewrites). Auto-covers any
    codec added to the registry later."""
    codec = get_codec(name)
    z = _z(_LEADS[li], _D[di], seed, scale)
    if codec.has_state:
        e = codec.init_state(z.shape)
        out = codec.fused_encode_with_state(z, e, interpret=True)
        if out is None:
            return
        p_f, e_f = out
        p_o, e_o = jax.jit(codec.encode_with_state)(z, e)
        _assert_trees_bitwise(p_f, p_o, (name, z.shape, "payload"))
        _assert_trees_bitwise(e_f, e_o, (name, z.shape, "residual"))
    else:
        p_f = codec.fused_encode(z, interpret=True)
        if p_f is None:
            return
        p_o = jax.jit(codec.encode)(z)
        _assert_trees_bitwise(p_f, p_o, (name, z.shape, "payload"))


def test_ef_registry_spelling():
    ef = get_codec("ef(int8_row)")
    assert ef.name == "ef(int8_row)" and ef.has_state
    assert ef.encoded_nbytes((32, 432)) == \
        get_codec("int8_row").encoded_nbytes((32, 432))
    assert get_codec("ef(topk0.1)").inner.ratio == 0.1
    nested = get_codec("ef(ef(int4))")  # harmless, still int4-sized wire
    assert nested.encoded_nbytes((8, 432)) == \
        get_codec("int4").encoded_nbytes((8, 432))
    with pytest.raises(ValueError):
        get_codec("ef(gzip)")
    with pytest.raises(ValueError):
        get_codec("ef()")
