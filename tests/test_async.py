"""Event-driven (async) mode: arrival traces, the AsyncRoundEngine's
tick coalescing, exact analytic↔ledger parity per tick, delta-broadcast
rejoin catch-up, bitwise checkpoint resume, and the FusionCache memory
bound (entries age OUT of server memory, not just out of the
broadcast).

Everything here is hypothesis-stub compatible (no @given): traces are
seeded renewal processes or replayed logs — deterministic by design.
"""

import math
import os

import numpy as np
import pytest

from repro.api import (
    DataSpec,
    ExperimentSpec,
    build_trainer,
    load_trainer,
    run_experiment,
    save_trainer,
)
from repro.core import ifl_round_bytes
from repro.core.rounds import (
    ArrivalTrace,
    AsyncRoundEngine,
    BernoulliSchedule,
    FullParticipation,
    FusionCache,
    ParetoTrace,
    PeriodicTrace,
    PoissonTrace,
    ReplayTrace,
    RoundEngine,
    StragglerSchedule,
    UniformK,
    expected_async_participants,
    parse_participation,
    parse_trace,
    simulate_sync_wall_clock,
)

FIXTURE = os.path.join(os.path.dirname(__file__), "data",
                       "arrivals_real.jsonl")

ASYNC_SMOKE = ExperimentSpec(
    scheme="ifl", rounds=6, tau=1, batch_size=8, lr=0.05, codec="int8",
    broadcast="delta", mode="async", trace="pareto(1.2,0.5)", tick=1.0,
    eval_every=0, seed=0, data=DataSpec(n_train=256, n_test=64),
)


# ------------------------------------------------------------ trace parsing


def test_parse_trace_round_trips():
    """A trace's ``name`` IS its spec string — parse(name) == original,
    exactly like the participation schedules."""
    for spec, cls in [("periodic(2)", PeriodicTrace),
                      ("poisson(0.5)", PoissonTrace),
                      ("pareto(1.5,0.5)", ParetoTrace)]:
        tr = parse_trace(spec)
        assert isinstance(tr, cls)
        assert parse_trace(tr.name) == tr  # frozen dataclasses: eq
    # Instances pass through untouched.
    tr = ParetoTrace(1.2, 0.25)
    assert parse_trace(tr) is tr
    assert tr.name == "pareto(1.2,0.25)"


def test_parse_participation_round_trips():
    """Same round-trip law on the schedule side (the PR-3 remnant this
    trace grammar extends)."""
    for sched in [FullParticipation(), UniformK(3), BernoulliSchedule(0.25),
                  StragglerSchedule(0.5, 4)]:
        again = parse_participation(sched.name)
        assert type(again) is type(sched)
        assert again == sched
        assert again.name == sched.name


def test_parse_trace_malformed():
    for bad in ["", "periodic", "periodic()", "periodic(a)", "poisson",
                "poisson(1,2)", "pareto(1.5)", "pareto(x,y)", "gzip",
                "pareto 1.5 0.5"]:
        with pytest.raises(ValueError):
            parse_trace(bad)
    # Well-formed specs with out-of-range values surface the trace's own
    # constraint, not a misleading 'unknown spec' error.
    with pytest.raises(ValueError, match="period must be > 0"):
        parse_trace("periodic(0)")
    with pytest.raises(ValueError, match="rate must be > 0"):
        parse_trace("poisson(-1)")
    with pytest.raises(ValueError, match="alpha must be > 0"):
        parse_trace("pareto(0,0.5)")


def test_trace_mean_gaps():
    assert parse_trace("periodic(3)").mean_gap() == 3
    assert parse_trace("poisson(0.5)").mean_gap() == 2
    assert parse_trace("pareto(1.5,0.5)").mean_gap() == pytest.approx(1.5)
    # alpha <= 1: the tail has no mean — the barrier-killing regime.
    assert math.isinf(parse_trace("pareto(1,0.5)").mean_gap())


# ------------------------------------------------------------ replay traces


def test_replay_trace_validation_and_sorting():
    # Unsorted input + duplicate timestamps: sorted stably by (t, slot),
    # duplicates kept (same client back-to-back, or two clients at the
    # same instant — both appear in real logs).
    tr = ReplayTrace([(2.0, 1), (0.5, 0), (2.0, 0), (0.5, 0)])
    assert tr.events == [(0.5, 0), (0.5, 0), (2.0, 0), (2.0, 1)]
    assert tr.n_slots == 2
    # An empty log is legal: every tick is simply empty.
    empty = ReplayTrace([])
    assert empty.events == [] and math.isinf(empty.mean_gap())
    with pytest.raises(ValueError, match="finite"):
        ReplayTrace([(math.inf, 0)])
    with pytest.raises(ValueError, match=">= 0"):
        ReplayTrace([(-1.0, 0)])
    with pytest.raises(ValueError, match="slot"):
        ReplayTrace([(1.0, -2)])
    with pytest.raises(ValueError, match="slot 7.*only 4"):
        ReplayTrace([(1.0, 7)], n_clients=4)


def test_replay_from_file_fixture():
    tr = ReplayTrace.from_file(FIXTURE, n_clients=4)
    assert len(tr.events) == 37
    assert tr.n_slots == 4
    # The duplicate timestamps survive parsing.
    assert tr.events.count((2.75, 1)) == 1 and tr.events.count((2.75, 2)) == 1
    assert tr.events.count((6.5, 2)) == 2
    assert 0 < tr.mean_gap() < math.inf
    # parse_trace's replay: prefix resolves the same file.
    again = parse_trace(f"replay:{FIXTURE}", n_clients=4)
    assert again.events == tr.events


def test_replay_fixture_drives_the_engine():
    eng = AsyncRoundEngine(4, f"replay:{FIXTURE}", tick=1.0, seed=0)
    # Hand-checked against the log: tick windows are (r, r+1].
    assert list(eng.participants()) == [0, 1]          # 0.62, 0.85
    eng.end_round({})
    assert list(eng.participants()) == [0, 1]          # 1.31, 1.90
    eng.end_round({})
    rep = None
    assert list(eng.participants()) == [0, 1, 2]       # 2.08..2.75 (x4)
    rep = eng.end_round({})
    assert rep.metrics["arrivals"] == 4                # coalesced to 3
    assert rep.metrics["sim_time"] == 3.0
    # The straggler (client 3) first shows up in tick (9, 10].
    for _ in range(6):
        eng.end_round({})
    assert 3 in list(eng.participants())               # 9.27
    # Past the end of the log every tick is empty — legal, costs nothing.
    eng2 = AsyncRoundEngine(4, ReplayTrace([(0.5, 0)]), tick=1.0, seed=0)
    assert list(eng2.participants()) == [0]
    eng2.end_round({})
    assert list(eng2.participants()) == []
    rep = eng2.end_round({})
    assert rep.metrics["arrivals"] == 0


def test_replay_from_file_malformed(tmp_path):
    p = tmp_path / "log.csv"
    p.write_text("# comment\n0.5,0\n1.5,1\n\nnot-a-line\n")
    with pytest.raises(ValueError, match=r"log\.csv:5.*not-a-line"):
        ReplayTrace.from_file(str(p))
    p.write_text('{"t": 0.5}\n')  # JSON missing the client key
    with pytest.raises(ValueError, match="malformed"):
        ReplayTrace.from_file(str(p))
    # The CSV happy path parses (comments and blanks skipped).
    p.write_text("# t,slot\n0.5,0\n\n1.5,1\n")
    tr = ReplayTrace.from_file(str(p))
    assert tr.events == [(0.5, 0), (1.5, 1)]


# ------------------------------------------------------------- async engine


def test_async_engine_coalescing_and_metrics():
    tr = ReplayTrace([(0.5, 0), (0.5, 0), (0.7, 1), (2.5, 0)])
    eng = AsyncRoundEngine(4, tr, tick=1.0, seed=0)
    assert list(eng.participants()) == [0, 1]
    # participants() is idempotent within a tick.
    assert list(eng.participants()) == [0, 1]
    rep = eng.end_round({})
    assert rep.metrics["arrivals"] == 3      # two coalesce on client 0
    assert rep.metrics["sim_time"] == 1.0
    assert rep.metrics["uploads_per_sec"] == 2.0
    assert list(eng.participants()) == []    # empty tick is legal
    eng.end_round({})
    assert list(eng.participants()) == [0]
    rep = eng.end_round({})
    assert eng.total_uploads == 3 and eng.total_arrivals == 4
    assert rep.metrics["uploads_per_sec"] == pytest.approx(1.0)
    assert eng.sim_time == 3.0


def test_async_engine_deterministic_under_seed():
    def stream(seed):
        eng = AsyncRoundEngine(4, "pareto(1.5,0.5)", tick=1.0, seed=seed)
        out = []
        for _ in range(8):
            out.append(list(eng.participants()))
            eng.end_round({})
        return out

    assert stream(7) == stream(7)
    assert stream(7) != stream(8)  # a different seed must move the draws


def test_async_engine_validation():
    with pytest.raises(ValueError, match="tick"):
        AsyncRoundEngine(4, "periodic(1)", tick=0.0)
    with pytest.raises(ValueError, match="arrival trace"):
        AsyncRoundEngine(4, "")


def test_expected_async_participants_matches_engine_regime():
    up, arr = expected_async_participants("periodic(1)", 4, 1.0)
    # Deterministic clocks: every client lands exactly once per tick.
    assert up == pytest.approx(4.0) and arr == pytest.approx(4.0)
    up, arr = expected_async_participants("pareto(1.2,0.5)", 4, 1.0)
    assert 0 < up <= 4 and arr >= up


# --------------------------------------------------- sync wall-clock model


def test_simulate_sync_wall_clock_periodic():
    # periodic(1), 4 clients, full barrier: every round waits for the
    # slot staggered to the full period — each round costs exactly 1.
    durs = simulate_sync_wall_clock("periodic(1)", 4, 5)
    assert durs == pytest.approx([1.0] * 5)


def test_simulate_sync_wall_clock_replay_exhausts_to_inf():
    tr = ReplayTrace([(0.5, 0), (0.7, 1), (1.2, 0), (1.4, 1)])
    durs = simulate_sync_wall_clock(tr, 2, 3)
    assert durs[0] == pytest.approx(0.7)
    assert durs[1] == pytest.approx(0.7)   # lands at 1.4
    assert math.isinf(durs[2])             # the log ended: barrier never closes
    # Heavy tail: the barrier's max-over-clients dwarfs the tick regime.
    heavy = simulate_sync_wall_clock("pareto(1.2,0.5)", 4, 20, seed=0)
    up, _ = expected_async_participants("pareto(1.2,0.5)", 4, 1.0, seed=0)
    assert np.mean(heavy) > 5.0 > 1.0 / max(up, 1e-9)


# ------------------------------------------------- FusionCache memory bound


def test_fusion_cache_prune_evicts_from_memory():
    """ISSUE-6 small fix: expired entries leave server MEMORY, not just
    the valid-entry view — long async runs must not grow the cache."""
    cache = FusionCache(max_staleness=1)
    cache.put(0, payload="p0", z_hat="z0", y="y0", round_idx=0)
    cache.put(1, payload="p1", z_hat="z1", y="y1", round_idx=1)
    assert set(cache._entries) == {0, 1}
    evicted = cache.prune(round_idx=3)  # ages: 3, 2 — both expired
    assert evicted == [0, 1]
    assert cache._entries == {}         # gone from memory, not masked
    # No bound: prune is a no-op.
    unbounded = FusionCache(max_staleness=None)
    unbounded.put(0, payload="p", z_hat="z", y="y", round_idx=0)
    assert unbounded.prune(round_idx=10 ** 6) == []
    assert set(unbounded._entries) == {0}


def test_engine_end_round_prunes_stale_entries():
    """The engine ages entries out every round — eviction must not be
    contingent on a broadcast consulting the cache that tick."""
    eng = RoundEngine(4, "full", seed=0, max_staleness=1)
    eng.cache.put(2, payload="p", z_hat="z", y="y", round_idx=0)
    eng.end_round({})   # round 0: age 0, stays
    eng.end_round({})   # round 1: age 1, stays
    assert set(eng.cache._entries) == {2}
    eng.end_round({})   # round 2: age 2 > 1 — pruned from memory
    assert eng.cache._entries == {}


def test_async_long_run_cache_stays_bounded():
    # A client that uploads once and vanishes: with a staleness bound
    # the server must forget it; the cache can never exceed the fleet.
    tr = ReplayTrace([(0.5, 3)] + [(t + 0.5, t % 2) for t in range(1, 40)])
    ex_spec = ASYNC_SMOKE.replace(trace=tr.name)  # validated below
    eng = AsyncRoundEngine(4, tr, tick=1.0, max_staleness=2, seed=0)
    sizes = []
    for _ in range(40):
        for k in eng.participants():
            eng.cache.put(int(k), payload="p", z_hat="z", y="y",
                          round_idx=eng.round_idx)
        eng.end_round({})
        sizes.append(len(eng.cache._entries))
    assert 3 not in eng.cache._entries   # the one-shot client aged out
    assert max(sizes) <= 3               # bounded well under n_clients
    assert ex_spec.mode == "async"


# ------------------------------------------------------- front door (eager)


def test_async_spec_validation_and_hash_isolation():
    # Sync specs don't even carry the new axes in canonical form: every
    # pre-PR-6 hash (and tracked fixture) stays addressable.
    sync = ExperimentSpec(rounds=2)
    d = sync.to_dict()
    assert "mode" not in d and "trace" not in d and "tick" not in d
    # An async spec hashes differently and dict-round-trips exactly.
    a = ASYNC_SMOKE
    assert a.spec_hash() != sync.spec_hash()
    again = ExperimentSpec.from_dict(a.to_dict())
    assert again == a and again.spec_hash() == a.spec_hash()
    with pytest.raises(ValueError, match="needs an arrival trace"):
        ExperimentSpec(mode="async")
    with pytest.raises(ValueError, match="expected 'sync' or 'async'"):
        ExperimentSpec(mode="weird")
    with pytest.raises(ValueError, match="only drive async"):
        ExperimentSpec(trace="poisson(1)")
    with pytest.raises(ValueError, match="participation"):
        ExperimentSpec(mode="async", trace="poisson(1)", participation="k2")
    with pytest.raises(ValueError, match="tick"):
        ExperimentSpec(mode="async", trace="poisson(1)", tick=-1.0)


def test_async_schemes_guard():
    for scheme in ("fl1", "fl2", "fsl"):
        with pytest.raises(ValueError, match="only supports mode='sync'"):
            build_trainer(ASYNC_SMOKE.replace(scheme=scheme))


def test_async_run_experiment_reports_event_clock_and_exact_parity():
    spec = ASYNC_SMOKE.replace(eval_every=3)
    res = run_experiment(spec, keep_trainer=True)
    trainer = res.trainer
    # Every tick report carries the event clock.
    for i, rep in enumerate(res.reports):
        assert rep["sim_time"] == pytest.approx((i + 1) * spec.tick)
        assert "arrivals" in rep and "uploads_per_sec" in rep
    # Eval records surface it too (the Fig.-2-style x-axis for async).
    assert "sim_time" in res.records[-1]
    assert "uploads_per_sec" in res.records[-1]
    # Exact analytic↔ledger parity at every tick, including empty ones
    # and delta catch-up shipping.
    for i, rep in enumerate(res.reports):
        exp = ifl_round_bytes(
            4, spec.batch_size, spec.d_fusion, codec=spec.codec,
            participating=len(rep["participants"]),
            broadcast_entries=rep["cache_size"],
            broadcast=spec.broadcast,
            delta_entries=rep.get("shipped_entries"),
        )
        got = trainer.ledger.per_round[i]
        assert got["up"] == exp["up"] and got["down"] == exp["down"], i


def test_async_delta_rejoin_ships_catch_up_entries():
    # Client 2 uploads in ticks 0 and 1; client 1 participates in tick
    # 0, misses tick 1, rejoins in tick 2 — its mirror of client 2 is
    # one version behind, so the delta broadcast must ship a catch-up
    # entry on top of the tick's fresh ones (the PR-5 rejoin machinery,
    # now driven by the arrival trace).
    tr = ReplayTrace([(0.5, 0), (0.6, 1), (0.7, 2),
                      (1.5, 0), (1.7, 2),
                      (2.5, 0), (2.6, 1)])
    spec = ASYNC_SMOKE.replace(rounds=3, trace="replay:ignored")
    trainer = build_trainer(spec.replace(trace=f"replay:{FIXTURE}"))
    # Swap in the inline trace: build through the spec path, then rewire
    # the engine's cursor to the crafted log (same seed/rng machinery).
    trainer.engine.trace = tr
    trainer.engine.cursor = tr.cursor(4, trainer.engine.rng)
    reports = [trainer.run_round() for _ in range(3)]
    assert reports[0].participants == [0, 1, 2]
    assert reports[1].participants == [0, 2]
    assert reports[2].participants == [0, 1]
    assert reports[0].metrics["shipped_entries"] == 3   # all fresh
    assert reports[1].metrics["shipped_entries"] == 2   # both mirrored
    # Tick 2: fresh {0, 1} + client 2's newer entry for the rejoiner.
    assert reports[2].metrics["shipped_entries"] == 3


def test_async_checkpoint_resume_is_bitwise(tmp_path):
    spec = ASYNC_SMOKE.replace(rounds=4)
    tr = build_trainer(spec)
    for _ in range(2):
        tr.run_round()
    path = str(tmp_path / "ckpt")
    save_trainer(path, tr)
    ref_reports = [tr.run_round() for _ in range(2)]
    ref_eval = tr.evaluate(*_kmnist_test(spec))

    tr2 = load_trainer(path, build_trainer(spec))
    assert tr2.engine.round_idx == 2
    assert tr2.engine.total_uploads == tr2.engine.total_uploads
    got_reports = [tr2.run_round() for _ in range(2)]
    for a, b in zip(ref_reports, got_reports):
        assert a.to_dict() == b.to_dict()
    assert tr2.evaluate(*_kmnist_test(spec)) == ref_eval
    assert tr2.ledger.uplink == tr.ledger.uplink
    assert tr2.ledger.downlink == tr.ledger.downlink


def _kmnist_test(spec):
    from repro.api import schemes

    data = schemes.load_data(spec)
    return data.test_x, data.test_y


# -------------------------------------------------------- front door (SPMD)


def test_async_spmd_ticks_and_accounting():
    spec = ASYNC_SMOKE.replace(
        scheme="ifl_spmd", rounds=3, batch_size=2, d_fusion=32,
        data=DataSpec(dataset="synth_tokens", n_test=8),
    )
    trainer = build_trainer(spec)
    assert trainer.partial  # async always lowers the masked program
    reports = [trainer.run_round() for _ in range(3)]
    for i, rep in enumerate(reports):
        assert rep["sim_time"] == pytest.approx(i + 1.0)
        # Host accounting: uplink bytes == coalesced uploads x analytic
        # per-entry bytes (the codec property suite pins entry bytes to
        # measured wire bytes).
        got = trainer.ledger.per_round[i]
        assert got["up"] == len(rep.participants) * trainer._entry_bytes
    # The same trace + seed drives eager and SPMD to the same arrival
    # stream on the first tick (before minibatch draws diverge the rng).
    eager = AsyncRoundEngine(4, spec.trace, tick=1.0, seed=spec.seed)
    assert list(eager.participants()) == reports[0].participants
