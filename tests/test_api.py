"""The repro.api front door: scheme registry, ExperimentSpec,
RunResult, the unified Trainer protocol, and run_experiment.

Everything here is hypothesis-stub compatible (no @given): the spec
machinery is deterministic by design — that's the point of it.
"""

import json
import os
import warnings

import numpy as np
import pytest

from repro.api import (
    DataSpec,
    ExperimentSpec,
    FleetSpec,
    RoundReport,
    RunResult,
    Trainer,
    available_schemes,
    build_trainer,
    get_scheme,
    load_trainer,
    register_scheme,
    run_experiment,
    save_trainer,
)
from repro.api.registry import SCHEMES

EAGER_SMOKE = ExperimentSpec(
    rounds=2, tau=1, batch_size=8, lr=0.05, eval_every=0, seed=0,
    data=DataSpec(n_train=256, n_test=64),
)
SPMD_SMOKE = EAGER_SMOKE.replace(
    scheme="ifl_spmd", batch_size=2, d_fusion=32,
    data=DataSpec(dataset="synth_tokens", n_test=8),
)


def _smoke_spec(scheme: str) -> ExperimentSpec:
    return SPMD_SMOKE if scheme == "ifl_spmd" else \
        EAGER_SMOKE.replace(scheme=scheme)


# ----------------------------------------------------------------- registry


def test_registry_has_the_paper_schemes():
    assert {"fl1", "fl2", "fsl", "ifl", "ifl_spmd"} <= set(available_schemes())


def test_registry_lookup_and_unknown_scheme():
    entry = get_scheme("ifl")
    assert entry.name == "ifl" and callable(entry.builder)
    with pytest.raises(ValueError, match="unknown scheme 'fedmd'.*ifl"):
        get_scheme("fedmd")


def test_register_scheme_is_open():
    """A new baseline is one decorator away (the FedMD/HeteroFL path)."""

    @register_scheme("_test_scheme", summary="registry openness probe")
    def build(spec, data):  # pragma: no cover - never built
        raise AssertionError

    try:
        assert get_scheme("_test_scheme").summary.startswith("registry")
        assert "_test_scheme" in available_schemes()
    finally:
        del SCHEMES["_test_scheme"]


# --------------------------------------------------------------------- spec


def test_spec_dict_round_trip():
    spec = ExperimentSpec(
        scheme="fsl", rounds=7, tau=3, lr=0.123, codec="ef(int4)",
        participation="k2", max_staleness=2, seed=9,
        data=DataSpec(n_train=100, n_test=10),
        fleet=FleetSpec(n_clients=3, heterogeneous=False, arch=2, alpha=0.1),
    )
    again = ExperimentSpec.from_dict(spec.to_dict())
    assert again == spec
    assert again.spec_hash() == spec.spec_hash()
    # ...and through an actual JSON wire, which is what the cache does.
    assert ExperimentSpec.from_dict(
        json.loads(json.dumps(spec.to_dict()))) == spec


def test_spec_hash_stability_and_sensitivity():
    # Pinned digest: accidental canonical-form changes (field rename,
    # float formatting, key order) must fail loudly — cached results
    # (including the committed results/paper fixtures) are addressed by
    # this. If this assert fires, you changed the cache-key format:
    # regenerate/re-key the fixtures deliberately, don't just repin.
    assert ExperimentSpec().spec_hash() == "07ebadbcf790"
    h = EAGER_SMOKE.spec_hash()
    assert len(h) == 12 and all(c in "0123456789abcdef" for c in h)
    assert EAGER_SMOKE.replace(lr=0.051).spec_hash() != h
    assert EAGER_SMOKE.replace(codec="int8").spec_hash() != h
    assert EAGER_SMOKE.replace(seed=1).spec_hash() != h
    # hash is filename-safe even for shell-hostile codec strings
    assert "(" not in EAGER_SMOKE.replace(codec="ef(int4)").spec_hash()


def test_spec_from_dict_rejects_unknown_fields():
    with pytest.raises(ValueError, match="unknown ExperimentSpec field"):
        ExperimentSpec.from_dict({"scheme": "ifl", "round": 50})  # typo


def test_spec_lowers_to_run_config():
    cfg = EAGER_SMOKE.run_config()
    assert cfg.tau == 1 and cfg.batch_size == 8
    assert cfg.lr_base == cfg.lr_modular == 0.05


def test_iflconfig_is_a_deprecated_alias():
    import repro.config as config_mod

    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        alias = config_mod.IFLConfig
    assert alias is config_mod.RunConfig
    assert any(issubclass(x.category, DeprecationWarning) for x in w)


# ------------------------------------------------------------------ results


def test_run_result_json_round_trip(tmp_path):
    res = RunResult(
        spec=EAGER_SMOKE,
        records=[{"round": 0, "uplink_mb": 0.1, "acc_mean": 0.5}],
        reports=[RoundReport(0, 0.1, 0.4, [0, 1],
                             {"base_loss": 2.0}).to_dict()],
        uplink_mb=0.1, downlink_mb=0.4,
    )
    path = str(tmp_path / "r.json")
    res.to_json(path)
    again = RunResult.from_json(path)
    assert again.spec == res.spec
    assert again.records == res.records
    assert again.reports == res.reports
    assert again.uplink_mb == res.uplink_mb
    # and from a JSON string
    assert RunResult.from_json(res.to_json()).records == res.records


def test_round_report_mapping_view():
    rep = RoundReport(3, 1.5, 6.0, [0, 2], {"loss": 0.25})
    assert rep["round"] == 3 and rep["loss"] == 0.25
    assert rep["participants"] == [0, 2]
    assert set(rep.to_dict()) == {"round", "uplink_mb", "downlink_mb",
                                  "participants", "loss"}
    assert RoundReport.from_dict(rep.to_dict()) == rep


# ----------------------------------------------------------- cross-scheme


@pytest.mark.parametrize("scheme", ["ifl", "fsl", "fl1", "fl2", "ifl_spmd"])
def test_every_scheme_runs_and_reports_bytes(scheme):
    """The cross-scheme contract: every registered scheme builds from a
    spec, satisfies the Trainer protocol, runs rounds, and accounts
    bytes on the ledger."""
    spec = _smoke_spec(scheme)
    trainer = build_trainer(spec)
    assert isinstance(trainer, Trainer)
    result = run_experiment(spec)
    assert len(result.reports) == spec.rounds
    assert result.uplink_mb > 0 and result.downlink_mb > 0
    assert 0.0 <= result.final["acc_mean"] <= 1.0
    for rep in result.reports:
        assert rep["participants"] == [0, 1, 2, 3]


def test_partial_participation_through_the_front_door():
    result = run_experiment(
        EAGER_SMOKE.replace(participation="k2", rounds=3))
    for rep in result.reports:
        assert len(rep["participants"]) == 2
    full = run_experiment(EAGER_SMOKE.replace(rounds=3))
    assert result.uplink_mb < full.uplink_mb  # 2-of-4 pays half the uplink


# ------------------------------------------------------------------ caching


def test_cache_is_spec_hash_keyed_and_shell_safe(tmp_path):
    spec = EAGER_SMOKE.replace(rounds=1, codec="ef(int4)")
    cache = str(tmp_path)
    run_experiment(spec, cache_dir=cache)
    (f,) = os.listdir(cache)
    assert f == f"ifl_{spec.spec_hash()}.json"
    assert "(" not in f and ")" not in f  # the old tags embedded ef(int4)
    # second call is served from the cache, identically
    again = run_experiment(spec, cache_dir=cache)
    assert again.records == RunResult.from_json(
        os.path.join(cache, f)).records


def test_legacy_tag_cache_still_read(tmp_path):
    """Pre-hash fixture files keep serving (read-only back compat)."""
    spec = EAGER_SMOKE.replace(rounds=1)
    legacy = tmp_path / "ifl_r1_n256_tau1_s0_lr0.05.json"
    legacy.write_text(json.dumps(
        {"scheme": "ifl", "records": [{"round": 0, "acc_mean": 0.42}]}))
    res = run_experiment(spec, cache_dir=str(tmp_path))
    assert res.records[0]["acc_mean"] == 0.42
    assert res.spec == spec  # the located spec rides on the result


# --------------------------------------------------------- snapshot/resume


def test_snapshot_restore_resumes_bitwise(tmp_path):
    """Trainer-protocol checkpointing: run 2 rounds, snapshot, run 2
    more; a freshly built trainer restored from the snapshot replays
    the SAME two rounds bit for bit (params, rng, and ledger resume)."""
    spec = EAGER_SMOKE.replace(rounds=10)  # rounds ignored: we drive it
    tr = build_trainer(spec)
    for _ in range(2):
        tr.run_round()
    path = str(tmp_path / "ckpt")
    save_trainer(path, tr)
    cont = [tr.run_round() for _ in range(2)]

    tr2 = load_trainer(path, build_trainer(spec))
    replay = [tr2.run_round() for _ in range(2)]
    for a, b in zip(cont, replay):
        assert a["round"] == b["round"]
        assert a["base_loss"] == b["base_loss"]  # exact float equality
        assert a["uplink_mb"] == b["uplink_mb"]
        assert a["participants"] == b["participants"]
    import jax

    for a, b in zip(jax.tree.leaves(tr.snapshot()[0]),
                    jax.tree.leaves(tr2.snapshot()[0])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_in_place_restore_rewinds_cleanly(tmp_path):
    """Restoring the SAME instance rewinds history/ledger/cache too —
    the replay must match a fresh-built restore exactly. The fusion
    cache rewinds to its snapshot-time entries (payloads uploaded AFTER
    the snapshot round must not survive the rewind), so the replayed
    broadcasts are the original ones bit for bit."""
    spec = EAGER_SMOKE.replace(participation="k2", rounds=10)
    tr = build_trainer(spec)
    for _ in range(2):
        tr.run_round()
    snap_state = {s: e.round_idx
                  for s, e in tr.engine.cache.valid_entries(2)}
    path = str(tmp_path / "ck")
    save_trainer(path, tr)
    fresh = load_trainer(path, build_trainer(spec))
    assert {s: e.round_idx
            for s, e in fresh.engine.cache.valid_entries(2)} == snap_state
    fresh_replay = [fresh.run_round() for _ in range(2)]

    for _ in range(3):  # advance past the snapshot, then rewind in place
        tr.run_round()
    load_trainer(path, tr)
    assert tr.engine.round_idx == 2
    assert len(tr.engine.history) == 2
    assert len(tr.ledger.per_round) == 2
    # No future payloads: the cache is exactly the snapshot-time one.
    assert {s: e.round_idx
            for s, e in tr.engine.cache.valid_entries(2)} == snap_state
    replay = [tr.run_round() for _ in range(2)]
    for a, b in zip(fresh_replay, replay):
        assert a["base_loss"] == b["base_loss"]
        assert a["participants"] == b["participants"]
        assert a["uplink_mb"] == b["uplink_mb"]
        assert a.metrics.get("max_staleness_seen", 0) >= 0


def test_cache_file_not_clobbered_without_force(tmp_path):
    spec = EAGER_SMOKE.replace(rounds=1)
    cache = str(tmp_path)
    run_experiment(spec, cache_dir=cache)
    path = os.path.join(cache, f"ifl_{spec.spec_hash()}.json")
    sentinel = json.load(open(path))
    sentinel["records"][0]["acc_mean"] = -1.0  # detectable mutation
    json.dump(sentinel, open(path, "w"))
    # keep_trainer bypasses the cache READ but must not rewrite the file
    run_experiment(spec, cache_dir=cache, keep_trainer=True)
    assert json.load(open(path))["records"][0]["acc_mean"] == -1.0
    # force does overwrite
    run_experiment(spec, cache_dir=cache, force=True)
    assert json.load(open(path))["records"][0]["acc_mean"] != -1.0
