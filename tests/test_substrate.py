"""Optimizers, data pipeline, checkpointing, sharding rules."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st
from jax.sharding import Mesh, PartitionSpec as P

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.data import dirichlet_partition, make_synth_kmnist
from repro.data.synthetic import SyntheticLM
from repro.optim import adamw_init, adamw_update, make_optimizer, sgd_init, sgd_update, cosine_schedule
from repro.sharding.rules import param_pspecs, sanitize_pspec, cache_pspecs


# ------------------------------------------------------------ optim


@given(lr=st.floats(1e-4, 1.0), g=st.floats(-3, 3))
def test_sgd_step_exact(lr, g):
    p = {"w": jnp.ones((4,))}
    grads = {"w": jnp.full((4,), g)}
    new, _ = sgd_update(p, grads, {}, lr=lr)
    np.testing.assert_allclose(np.asarray(new["w"]),
                               np.full(4, 1 - lr * g, np.float32), rtol=1e-5)


def test_sgd_momentum_accumulates():
    p = {"w": jnp.zeros((2,))}
    g = {"w": jnp.ones((2,))}
    s = sgd_init(p, momentum=0.9)
    p1, s = sgd_update(p, g, s, lr=1.0, momentum=0.9)
    p2, s = sgd_update(p1, g, s, lr=1.0, momentum=0.9)
    # mu1 = 1; mu2 = 1.9 -> w = -1, then -2.9
    np.testing.assert_allclose(np.asarray(p2["w"]), [-2.9, -2.9], rtol=1e-6)


def test_adamw_update_bounded():
    """AdamW per-step update magnitude ~ lr regardless of grad scale."""
    p = {"w": jnp.zeros((4,))}
    s = adamw_init(p)
    for scale in [1e-6, 1.0, 1e6]:
        g = {"w": jnp.full((4,), scale)}
        new, _ = adamw_update(p, g, s, lr=0.1)
        assert float(jnp.max(jnp.abs(new["w"]))) < 0.11


def test_cosine_schedule_shape():
    f = cosine_schedule(1.0, warmup=10, total=110)
    assert float(f(0)) == 0.0
    assert abs(float(f(10)) - 1.0) < 1e-6
    assert float(f(110)) < 1e-6
    assert float(f(60)) < float(f(20))


# ------------------------------------------------------------ data


def test_dirichlet_partition_properties():
    rng = np.random.default_rng(0)
    labels = rng.integers(0, 10, 5000).astype(np.int64)
    shards = dirichlet_partition(labels, 4, alpha=0.5, seed=3)
    all_idx = np.concatenate(shards)
    assert len(all_idx) >= len(labels) * 0.99  # near-cover (top-up allowed)
    # skew: per-client class distributions differ materially
    dists = np.stack([
        np.bincount(labels[s], minlength=10) / len(s) for s in shards
    ])
    assert np.max(np.abs(dists - dists.mean(0))) > 0.05


def test_dirichlet_alpha_controls_skew():
    labels = np.random.default_rng(0).integers(0, 10, 5000)
    def skew(alpha):
        sh = dirichlet_partition(labels, 4, alpha=alpha, seed=1)
        d = np.stack([np.bincount(labels[s], minlength=10) / len(s)
                      for s in sh])
        return float(np.abs(d - d.mean(0)).mean())
    assert skew(0.1) > skew(100.0)


def test_synthetic_lm_deterministic_and_zipfian():
    s = SyntheticLM(512, seed=7)
    a = s.sample(4, 32, step=3, client=1)
    b = s.sample(4, 32, step=3, client=1)
    np.testing.assert_array_equal(a, b)
    c = s.sample(4, 32, step=4, client=1)
    assert not np.array_equal(a, c)
    big = s.sample(64, 128, step=0)
    counts = np.bincount(big.ravel(), minlength=512)
    top = np.sort(counts)[::-1]
    assert top[0] > 5 * max(np.median(counts), 1)  # heavy head


def test_synth_kmnist_shapes_and_classes():
    tx, ty, ex, ey = make_synth_kmnist(500, 100)
    assert tx.shape == (500, 28, 28, 1) and ex.shape == (100, 28, 28, 1)
    assert set(np.unique(ty)) <= set(range(10))


# ------------------------------------------------------------ checkpoint


def test_checkpoint_roundtrip():
    tree = {
        "a": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
        "b": [jnp.ones((4,), jnp.bfloat16), jnp.zeros((2, 2), jnp.int32)],
    }
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck")
        save_checkpoint(path, tree, step=7)
        got = load_checkpoint(path, jax.tree.map(jnp.zeros_like, tree))
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(x, np.float32),
                                          np.asarray(y, np.float32))


# ------------------------------------------------------------ sharding


def _fake_mesh(shape=(4, 2), axes=("data", "model")):
    import itertools

    devs = np.array(jax.devices() * int(np.prod(shape)))[: int(np.prod(shape))]
    return Mesh(devs.reshape(shape), axes)


def test_param_pspecs_match_tree_ranks():
    from repro.config import ModelConfig
    from repro.models.transformer import init_lm

    cfg = ModelConfig(num_layers=2, d_model=32, num_heads=2, num_kv_heads=2,
                      d_ff=64, vocab_size=64,
                      compute_dtype="float32").validate()
    params = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), cfg))
    specs = param_pspecs(params, fsdp=True)
    for leaf, spec in zip(jax.tree.leaves(params),
                          jax.tree.leaves(specs,
                                          is_leaf=lambda x: isinstance(x, P))):
        assert len(spec) <= len(leaf.shape), (spec, leaf.shape)


def test_sanitize_pspec_drops_indivisible():
    mesh = _fake_mesh((4, 2))
    s = sanitize_pspec(P("data", "model"), (6, 8), mesh)
    assert s == P(None, "model")  # 6 % 4 != 0 -> dropped; 8 % 2 == 0 kept
    s2 = sanitize_pspec(P(("data", "model"), None), (8, 3), mesh)
    assert s2 == P(("data", "model"), None)


def test_cache_pspecs_kv_rule():
    cache = {"l0": {"mix": {
        "k": jax.ShapeDtypeStruct((3, 8, 16, 2, 64), jnp.bfloat16),
        "slot_pos": jax.ShapeDtypeStruct((16,), jnp.int32),
    }}}
    specs = cache_pspecs(cache)
    assert specs["l0"]["mix"]["k"] == P(None, "data", None, "model", None)
    assert specs["l0"]["mix"]["slot_pos"] == P(None)
