"""IFL algorithm invariants (the paper's Table I properties, as tests)."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.config import RunConfig
from repro.core import (
    Client,
    CommLedger,
    FLTrainer,
    FSLTrainer,
    IFLTrainer,
    composition_accuracy,
    fl_round_bytes,
    fsl_round_bytes,
    ifl_round_bytes,
)
from repro.data import dirichlet_partition, make_synth_kmnist
from repro.models.small import (
    CLIENT_ARCHS,
    client_base_apply,
    client_modular_apply,
    init_client_model,
)


def _mk_clients(tx, ty, n=4, seed=0):
    shards = dirichlet_partition(ty, n, alpha=0.5, seed=seed)
    clients = []
    for k in range(n):
        cid = k + 1
        clients.append(Client(
            cid=cid,
            params=init_client_model(jax.random.PRNGKey(cid), cid),
            base_apply=functools.partial(
                lambda p, x, c: client_base_apply({"base": p}, c, x), c=cid),
            modular_apply=functools.partial(
                lambda p, z, c: client_modular_apply({"modular": p}, c, z),
                c=cid),
            data_x=tx[shards[k]], data_y=ty[shards[k]],
        ))
    return clients


@pytest.fixture(scope="module")
def small_data():
    return make_synth_kmnist(1200, 300)


@pytest.fixture(scope="module")
def trained_round(small_data):
    tx, ty, ex, ey = small_data
    cfg = RunConfig(tau=3, batch_size=16)
    tr = IFLTrainer(_mk_clients(tx, ty), cfg, seed=1)
    before = jax.tree.map(jnp.copy, {c.cid: c.params for c in tr.clients})
    tr.run_round()
    return tr, before, (ex, ey)


def _tree_equal(a, b):
    return all(
        bool(jnp.all(x == y))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


def test_round_updates_both_blocks(trained_round):
    tr, before, _ = trained_round
    for c in tr.clients:
        assert not _tree_equal(c.params["base"], before[c.cid]["base"])
        assert not _tree_equal(c.params["modular"], before[c.cid]["modular"])


def test_comm_matches_analytic_formula(trained_round):
    """The ledger (measured array bytes) must equal the analytic model."""
    tr, _, _ = trained_round
    exp = ifl_round_bytes(4, tr.cfg.batch_size, tr.cfg.d_fusion)
    got = tr.ledger.per_round[0]
    assert got["up"] == exp["up"]
    assert got["down"] == exp["down"]


def test_fusion_interface_standardized(trained_round):
    """Every client's z has the standardized dim — the paper's key
    interoperability requirement — despite heterogeneous fusion types."""
    tr, _, _ = trained_round
    x = jnp.zeros((2, 28, 28, 1))
    for c in tr.clients:
        z = c.base_apply(c.params["base"], x)
        assert z.shape == (2, tr.cfg.d_fusion)


def test_any_composition_runs(trained_round):
    """Eq. (11): all N x N base/modular compositions are well-formed."""
    tr, _, (ex, ey) = trained_round
    mat = tr.accuracy_matrix(ex[:64], ey[:64], batch=64)
    assert mat.shape == (4, 4)
    assert np.all(mat >= 0) and np.all(mat <= 1)


def test_parameters_never_leave_client(trained_round):
    """Privacy: uplink bytes per round << smallest client model bytes."""
    tr, _, _ = trained_round
    from repro.models.small import model_bytes

    smallest = min(model_bytes(c.params) for c in tr.clients)
    per_client_up = tr.ledger.per_round[0]["up"] / 4
    assert per_client_up < smallest / 4  # z-exchange ≪ any model upload


def test_tau_zero_round_is_fusion_only(small_data):
    """Regression: cfg.tau=0 used to raise NameError (`loss` unbound) in
    run_round. A τ=0 round is legal — fusion exchange + modular updates
    only: base params untouched, base_loss NaN by convention."""
    tx, ty, _, _ = small_data
    cfg = RunConfig(tau=0, batch_size=8)
    tr = IFLTrainer(_mk_clients(tx, ty), cfg, seed=2)
    before = jax.tree.map(jnp.copy, {c.cid: c.params for c in tr.clients})
    m = tr.run_round()  # must not raise
    assert np.isnan(m["base_loss"])
    assert np.isfinite(m["mod_loss"])
    for c in tr.clients:
        assert _tree_equal(c.params["base"], before[c.cid]["base"])
        assert not _tree_equal(c.params["modular"], before[c.cid]["modular"])


def test_base_loss_averages_all_tau_steps(small_data):
    """Regression: base_loss used to record only the LAST of the τ local
    losses. Replay the trainer's exact sampling stream and check the
    reported value equals the mean over every (client, step) loss."""
    tx, ty, _, _ = small_data
    cfg = RunConfig(tau=3, batch_size=16)
    seed = 5
    clients = _mk_clients(tx, ty)
    params0 = jax.tree.map(jnp.copy, {c.cid: c.params for c in clients})
    tr = IFLTrainer(clients, cfg, seed=seed)
    m = tr.run_round()

    rng = np.random.default_rng(seed)  # same stream as the trainer's
    expected = []
    for c in _mk_clients(tx, ty):
        params = params0[c.cid]
        step = jax.jit(functools.partial(
            IFLTrainer._base_step_impl, c.base_apply, c.modular_apply,
            c.loss_fn))
        client_losses = []
        for _ in range(cfg.tau):
            idx = rng.integers(0, c.num_samples, size=cfg.batch_size)
            x, y = jnp.asarray(c.data_x[idx]), jnp.asarray(c.data_y[idx])
            params, loss = step(params, x, y, cfg.lr_base)
            client_losses.append(float(loss))
        expected.append(np.mean(client_losses))
    np.testing.assert_allclose(m["base_loss"], np.mean(expected), rtol=1e-5)


# ------------------------------------------------------------ baselines


def test_fsl_round_and_costs(small_data):
    tx, ty, ex, ey = small_data
    cfg = RunConfig(tau=3, batch_size=16)
    clients = _mk_clients(tx, ty)
    # shared server model = client 1's modular arch
    server = init_client_model(jax.random.PRNGKey(99), 1)["modular"]
    tr = FSLTrainer(
        clients, cfg, server,
        server_apply=lambda sp, h: client_modular_apply(
            {"modular": sp}, 1, h),
    )
    m = tr.run_round()
    assert np.isfinite(m["loss"])
    exp = fsl_round_bytes(4, cfg.batch_size, cfg.d_fusion)
    got = tr.ledger.per_round[0]
    assert got["up"] == exp["up"] and got["down"] == exp["down"]
    accs = tr.evaluate(ex[:128], ey[:128])
    assert len(accs) == 4


def test_fl_round_and_costs(small_data):
    tx, ty, _, _ = small_data
    cfg = RunConfig(tau=2, batch_size=16)
    shards = dirichlet_partition(ty, 4, alpha=0.5, seed=0)
    # FL-1: everyone runs client 1's architecture.
    clients = []
    for k in range(4):
        clients.append(Client(
            cid=1, params=init_client_model(jax.random.PRNGKey(k), 1),
            base_apply=lambda p, x: client_base_apply({"base": p}, 1, x),
            modular_apply=lambda p, z: client_modular_apply(
                {"modular": p}, 1, z),
            data_x=tx[shards[k]], data_y=ty[shards[k]],
        ))
    tr = FLTrainer(clients, cfg)
    m = tr.run_round()
    assert np.isfinite(m["loss"])
    from repro.models.small import model_bytes

    exp = fl_round_bytes(4, model_bytes(tr.global_params))
    got = tr.ledger.per_round[0]
    assert got["up"] == exp["up"] and got["down"] == exp["down"]


def test_comm_ordering_ifl_cheapest_per_round(small_data):
    """Table I / Fig 2 premise: per-round uplink IFL == FSL << FL."""
    cfg = RunConfig()
    ifl = ifl_round_bytes(4, cfg.batch_size, 432)["up"]
    fsl = fsl_round_bytes(4, cfg.batch_size, 432)["up"]
    model_b = 4_000_000  # ~1M params fp32 (client 2 scale)
    fl = fl_round_bytes(4, model_b)["up"]
    assert ifl == fsl  # same uplink payload per round...
    assert ifl * 10 < fl  # ...but FL ships the full model


# ------------------------------------------------------------ FedAvg math


@given(
    w1=st.floats(0.05, 0.95),
    a=st.floats(-5, 5),
    b=st.floats(-5, 5),
)
def test_fedavg_is_weighted_mean(w1, a, b):
    """Eq. (4): aggregation = sample-count weighted mean (property)."""
    p1 = {"w": jnp.full((3,), a)}
    p2 = {"w": jnp.full((3,), b)}
    agg = jax.tree.map(
        lambda x, y: w1 * x + (1 - w1) * y, p1, p2
    )
    expect = w1 * a + (1 - w1) * b
    np.testing.assert_allclose(np.asarray(agg["w"]),
                               np.full(3, expect, np.float32),
                               rtol=1e-5, atol=1e-5)


def test_ledger_round_boundaries():
    led = CommLedger()
    led.send_up((jnp.zeros((4, 8), jnp.float32),))
    led.end_round()
    led.send_down((jnp.zeros((2,), jnp.int32),))
    led.end_round()
    assert led.per_round == [
        {"up": 128, "down": 0}, {"up": 0, "down": 8}
    ]
    assert led.total == 136
