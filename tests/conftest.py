import os
import sys

# Tests must see the real single CPU device (the 512-device override is
# exclusively for launch/dryrun.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

try:
    from hypothesis import settings
except ImportError:
    # Minimal environments (CI cold caches, slim containers) must still
    # collect and run the suite: install the deterministic stub, which
    # expands @given into a fixed example sweep. See tests/_hypothesis_stub.
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub

    _hypothesis_stub.install(sys.modules)
    from hypothesis import settings

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")
