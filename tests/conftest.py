import os

# Tests must see the real single CPU device (the 512-device override is
# exclusively for launch/dryrun.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

from hypothesis import settings

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")
