"""Round-engine invariants: participation schedules, the staleness-
bounded FusionCache, CommLedger helpers, and exact analytic↔ledger byte
parity under every participation schedule × codec (including ef(...))
for all three trainers."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import RunConfig
from repro.core import (
    Client,
    CommLedger,
    FLTrainer,
    FSLTrainer,
    IFLTrainer,
    fl_round_bytes,
    fsl_round_bytes,
    ifl_round_bytes,
)
from repro.core.rounds import (
    BernoulliSchedule,
    FullParticipation,
    FusionCache,
    ParticipationSchedule,
    RoundEngine,
    StragglerSchedule,
    UniformK,
    parse_participation,
)

# ------------------------------------------------------------- schedules


def test_parse_participation_specs():
    assert isinstance(parse_participation(None), FullParticipation)
    assert isinstance(parse_participation("full"), FullParticipation)
    k = parse_participation("k2")
    assert isinstance(k, UniformK) and k.k == 2 and k.name == "k2"
    b = parse_participation("bern0.5")
    assert isinstance(b, BernoulliSchedule) and b.p == 0.5
    s = parse_participation("straggle(0.2,3)")
    assert isinstance(s, StragglerSchedule)
    assert s.frac == 0.2 and s.period == 3
    assert s.name == "straggle(0.2,3)"
    # Schedules pass through untouched.
    assert parse_participation(k) is k
    for bad in ["k", "kX", "bern", "bern2.0", "straggle(0.2)", "gzip"]:
        with pytest.raises(ValueError):
            parse_participation(bad)
    # Well-formed specs with out-of-range values surface the schedule's
    # own constraint, not a misleading 'unknown spec' error.
    with pytest.raises(ValueError, match="k must be >= 1"):
        parse_participation("k0")
    with pytest.raises(ValueError, match="p must be in"):
        parse_participation("bern0.0")


def test_schedule_mask_shapes_and_counts():
    rng = np.random.default_rng(0)
    n = 6
    assert parse_participation("full").mask(0, n, rng).sum() == n
    for r in range(5):
        m = UniformK(2).mask(r, n, rng)
        assert m.shape == (n,) and m.dtype == bool and m.sum() == 2
    # k >= n degrades to full participation.
    assert UniformK(99).mask(0, n, rng).sum() == n
    for r in range(5):
        m = BernoulliSchedule(0.5).mask(r, n, rng)
        assert m.shape == (n,) and 0 <= m.sum() <= n


def test_straggler_trace_is_deterministic_and_staggered():
    s = StragglerSchedule(0.5, 3)  # slots 2,3 of 4 are stragglers
    rng = np.random.default_rng(0)
    masks = [s.mask(t, 4, rng) for t in range(6)]
    # Deterministic: identical regardless of rng state.
    masks2 = [s.mask(t, 4, np.random.default_rng(99)) for t in range(6)]
    for a, b in zip(masks, masks2):
        np.testing.assert_array_equal(a, b)
    # Non-stragglers always up; straggler slot i up iff t % 3 == i % 3.
    for t, m in enumerate(masks):
        assert m[0] and m[1]
        assert m[2] == (t % 3 == 2)
        assert m[3] == (t % 3 == 0)


def test_schedules_deterministic_under_fixed_seed():
    for spec in ["k2", "bern0.5"]:
        a = RoundEngine(4, spec, seed=7)
        b = RoundEngine(4, spec, seed=7)
        seq_a = [list(a.participants()) for _ in range(8)]
        seq_b = [list(b.participants()) for _ in range(8)]
        assert seq_a == seq_b, spec
        c = RoundEngine(4, spec, seed=8)
        seq_c = [list(c.participants()) for _ in range(8)]
        assert seq_a != seq_c, spec  # a different seed must move the draw


def test_full_schedule_consumes_no_rng():
    """A 'full' run must replay the exact pre-engine sampling stream:
    the schedule takes zero draws from the engine rng."""
    eng = RoundEngine(4, "full", seed=5)
    eng.participants()
    ref = np.random.default_rng(5)
    got = eng.rng.integers(0, 1000, size=8)
    np.testing.assert_array_equal(got, ref.integers(0, 1000, size=8))


def test_schedule_validation():
    with pytest.raises(ValueError):
        UniformK(0)
    with pytest.raises(ValueError):
        BernoulliSchedule(0.0)
    with pytest.raises(ValueError):
        StragglerSchedule(1.5, 3)
    with pytest.raises(ValueError):
        StragglerSchedule(0.2, 0)


# ----------------------------------------------------------- fusion cache


def test_fusion_cache_put_valid_staleness():
    cache = FusionCache(max_staleness=2)
    cache.put(0, payload="p0", z_hat="z0", y="y0", round_idx=0)
    cache.put(1, payload="p1", z_hat="z1", y="y1", round_idx=1)
    entries = cache.valid_entries(1)
    assert [s for s, _ in entries] == [0, 1]
    assert cache.staleness(1) == {0: 1, 1: 0}
    # Round 3: slot 0 is 3 rounds old > bound 2 -> evicted for good.
    entries = cache.valid_entries(3)
    assert [s for s, _ in entries] == [1]
    assert len(cache) == 1 and 0 not in cache and 1 in cache
    # Re-upload resurrects the slot.
    cache.put(0, payload="p0'", z_hat="z0'", y="y0'", round_idx=3)
    assert [s for s, _ in cache.valid_entries(3)] == [0, 1]
    assert cache.valid_entries(3)[0][1].payload == "p0'"


def test_fusion_cache_bounds():
    # max_staleness=0: only same-round (fresh) entries are valid.
    cache = FusionCache(max_staleness=0)
    cache.put(0, payload="p", z_hat="z", y="y", round_idx=0)
    assert [s for s, _ in cache.valid_entries(0)] == [0]
    assert cache.valid_entries(1) == []
    # None: never evicts.
    cache = FusionCache(None)
    cache.put(0, payload="p", z_hat="z", y="y", round_idx=0)
    assert [s for s, _ in cache.valid_entries(10 ** 6)] == [0]
    with pytest.raises(ValueError):
        FusionCache(-1)


# ---------------------------------------------------------- ledger helpers


def test_ledger_downlink_and_round_mb():
    led = CommLedger()
    led.send_up((jnp.zeros((250, 1000), jnp.float32),))  # 1e6 B up
    led.send_down((jnp.zeros((500, 1000), jnp.float32),))  # 2e6 B down
    led.end_round()
    led.send_down((jnp.zeros((125, 1000), jnp.float32),))  # 5e5 B down
    led.end_round()
    assert led.uplink_mb == 1.0
    assert led.downlink_mb == 2.5
    assert led.total_mb == 3.5
    assert led.round_mb(0) == 3.0
    assert led.round_mb(1) == 0.5
    assert led.round_mb(-1) == 0.5  # list-style negative indexing


# ------------------------------------------------- trainers, tiny clients

D_FUSION = 32
N_CLIENTS = 4
BATCH = 4


def _tiny_clients(n=N_CLIENTS, d=D_FUSION, samples=64, seed=0):
    """Linear toy vendors: base is an elementwise gain (z = x * g), so
    d_fusion is satisfied with near-zero compute and full grad flow."""
    rng = np.random.default_rng(seed)
    clients = []
    for k in range(n):
        x = rng.normal(size=(samples, d)).astype(np.float32)
        y = rng.integers(0, 10, size=samples).astype(np.int32)
        params = {
            "base": jnp.ones((d,)) * (1.0 + 0.1 * k),
            "modular": jnp.asarray(
                rng.normal(size=(d, 10)).astype(np.float32) * 0.05),
        }
        clients.append(Client(
            cid=k, params=params,
            base_apply=lambda p, x: x * p,
            modular_apply=lambda m, z: z @ m,
            data_x=x, data_y=y,
        ))
    return clients


SCHEDULES = ["full", "k2", "bern0.5", "straggle(0.5,2)"]
CODECS = ["fp32", "int8_row", "sketch", "ef(int4)", "ef(topk0.25)"]


@pytest.mark.parametrize("schedule", SCHEDULES)
@pytest.mark.parametrize("codec", CODECS)
def test_ifl_ledger_parity_under_schedule(schedule, codec):
    """EXACT analytic↔ledger byte parity, every round, for every
    participation schedule × codec: uplink = K fresh payloads, downlink
    = the M-entry cache broadcast to the K participants."""
    cfg = RunConfig(n_clients=N_CLIENTS, tau=1, batch_size=BATCH,
                    d_fusion=D_FUSION, codec=codec,
                    participation=schedule)
    tr = IFLTrainer(_tiny_clients(), cfg, seed=11)
    for r in range(5):
        m = tr.run_round()
        k = len(m["participants"])
        exp = ifl_round_bytes(
            N_CLIENTS, BATCH, D_FUSION, codec=codec,
            participating=k, broadcast_entries=m["cache_size"],
        )
        got = tr.ledger.per_round[r]
        assert got["up"] == exp["up"], (r, got, exp)
        assert got["down"] == exp["down"], (r, got, exp)
        if schedule == "full":
            assert k == N_CLIENTS and m["cache_size"] == N_CLIENTS
        elif schedule == "k2":
            assert k == 2


def test_ifl_absent_clients_fully_frozen():
    """An absent client is offline: params AND EF residual untouched,
    zero bytes attributed, while the cache serves its stale payload."""

    class FirstOnly(ParticipationSchedule):
        name = "first-only"

        def mask(self, round_idx, n, rng):
            m = np.zeros(n, bool)
            m[0 if round_idx else slice(None)] = True
            return m  # round 0: everyone; later rounds: slot 0 only

    cfg = RunConfig(n_clients=N_CLIENTS, tau=2, batch_size=BATCH,
                    d_fusion=D_FUSION, codec="ef(int8_row)",
                    participation=FirstOnly())
    tr = IFLTrainer(_tiny_clients(), cfg, seed=0)
    tr.run_round()
    frozen_params = jax.tree.map(
        jnp.copy, {k: tr.clients[k].params for k in range(1, N_CLIENTS)})
    frozen_ef = {k: jnp.copy(tr.ef_state[k])  # ef_state is slot-keyed
                 for k in range(1, N_CLIENTS)}
    m = tr.run_round()
    assert m["participants"] == [0]
    assert m["cache_size"] == N_CLIENTS  # stale slots still broadcast
    assert m["max_staleness_seen"] == 1
    for k in range(1, N_CLIENTS):
        for a, b in zip(jax.tree.leaves(frozen_params[k]),
                        jax.tree.leaves(tr.clients[k].params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(
            np.asarray(frozen_ef[k]), np.asarray(tr.ef_state[k]))
    # The participant trained on all four cached pairs.
    assert np.isfinite(m["base_loss"]) and np.isfinite(m["mod_loss"])


def test_ifl_staleness_bound_evicts():
    """straggle(0.25,4): slot 3 uploads at t=3,7,... With
    max_staleness=1 its entry serves exactly one extra round, then the
    broadcast (and the ledger) shrink to 3 entries."""
    cfg = RunConfig(n_clients=4, tau=0, batch_size=BATCH,
                    d_fusion=D_FUSION, participation="straggle(0.25,4)",
                    max_staleness=1)
    tr = IFLTrainer(_tiny_clients(), cfg, seed=0)
    sizes, started = [], []
    for r in range(8):
        m = tr.run_round()
        sizes.append(m["cache_size"])
        started.append(len(m["participants"]))
    # t=0..2: slot 3 never seen (3 entries). t=3: uploads (4). t=4: one
    # round stale, still valid (4). t=5,6: evicted (3). t=7: fresh (4).
    assert started == [3, 3, 3, 4, 3, 3, 3, 4]
    assert sizes == [3, 3, 3, 4, 4, 3, 3, 4]


def test_ifl_empty_round_is_noop():
    class Nobody(ParticipationSchedule):
        name = "nobody"

        def mask(self, round_idx, n, rng):
            return np.zeros(n, bool)

    cfg = RunConfig(n_clients=2, tau=1, batch_size=BATCH,
                    d_fusion=D_FUSION, participation=Nobody())
    tr = IFLTrainer(_tiny_clients(n=2), cfg, seed=0)
    before = jax.tree.map(jnp.copy, {c.cid: c.params for c in tr.clients})
    m = tr.run_round()  # must not raise
    assert np.isnan(m["base_loss"]) and np.isnan(m["mod_loss"])
    assert m["participants"] == [] and m["cache_size"] == 0
    assert tr.ledger.per_round[0] == {"up": 0, "down": 0}
    for c in tr.clients:
        for a, b in zip(jax.tree.leaves(before[c.cid]),
                        jax.tree.leaves(c.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ifl_trainer_schedule_deterministic():
    """Same seed => same participant trace AND same final params."""
    runs = []
    for _ in range(2):
        cfg = RunConfig(n_clients=4, tau=1, batch_size=BATCH,
                        d_fusion=D_FUSION, participation="k2")
        tr = IFLTrainer(_tiny_clients(), cfg, seed=3)
        ms = [tr.run_round() for _ in range(4)]
        runs.append((
            [m["participants"] for m in ms],
            np.asarray(tr.clients[0].params["modular"]),
        ))
    assert runs[0][0] == runs[1][0]
    np.testing.assert_array_equal(runs[0][1], runs[1][1])


# ------------------------------------------------------------- baselines


def _fl_clients(n=4, samples=64, seed=0):
    return _tiny_clients(n=n, samples=samples, seed=seed)


@pytest.mark.parametrize("schedule", SCHEDULES)
def test_fl_ledger_parity_under_schedule(schedule):
    from repro.core.comm import nbytes

    cfg = RunConfig(n_clients=4, tau=1, batch_size=BATCH,
                    d_fusion=D_FUSION, participation=schedule)
    tr = FLTrainer(_fl_clients(), cfg, seed=5)
    model_b = nbytes(tr.global_params)
    for r in range(4):
        m = tr.run_round()
        exp = fl_round_bytes(4, model_b,
                             participating=len(m["participants"]))
        assert tr.ledger.per_round[r] == exp, (schedule, r)


@pytest.mark.parametrize("schedule", SCHEDULES)
def test_fsl_ledger_parity_under_schedule(schedule):
    cfg = RunConfig(n_clients=4, tau=1, batch_size=BATCH,
                    d_fusion=D_FUSION, participation=schedule)
    clients = _tiny_clients()
    server = jnp.asarray(
        np.random.default_rng(1).normal(size=(D_FUSION, 10))
        .astype(np.float32) * 0.05)
    tr = FSLTrainer(clients, cfg, server,
                    server_apply=lambda sp, h: h @ sp, seed=5)
    for r in range(4):
        m = tr.run_round()
        exp = fsl_round_bytes(4, BATCH, D_FUSION,
                              participating=len(m["participants"]))
        assert tr.ledger.per_round[r] == exp, (schedule, r)


def test_fl_tau_zero_round_reports_nan():
    """Regression: FLTrainer.run_round used to raise NameError at τ=0
    (`loss` unbound) — same bug class fixed for IFL in PR 2. A τ=0 FL
    round is a no-op: loss NaN by convention, global model EXACTLY
    unchanged (not re-averaged through float round-off), bytes still
    ledgered (download + upload of the untouched model)."""
    cfg = RunConfig(n_clients=4, tau=0, batch_size=BATCH,
                    d_fusion=D_FUSION)
    tr = FLTrainer(_fl_clients(), cfg, seed=0)
    before = jax.tree.map(jnp.copy, tr.global_params)
    m = tr.run_round()  # must not raise
    assert np.isnan(m["loss"])
    for a, b in zip(jax.tree.leaves(before),
                    jax.tree.leaves(tr.global_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    from repro.core.comm import nbytes

    assert tr.ledger.per_round[0] == fl_round_bytes(
        4, nbytes(tr.global_params))


def test_fl_partial_round_aggregates_participants_only():
    """Under k2, FedAvg weights are sample counts normalized over the 2
    participants, and absent clients contribute nothing."""
    cfg = RunConfig(n_clients=4, tau=2, batch_size=BATCH,
                    d_fusion=D_FUSION, participation="k2")
    tr = FLTrainer(_fl_clients(), cfg, seed=9)
    m = tr.run_round()
    assert len(m["participants"]) == 2
    assert np.isfinite(m["loss"])
