"""IFL SPMD round-step invariants (1-device mesh; same code the dry-run
lowers at 256/512 chips)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.config import LayerSpec, ModelConfig
from repro.core.ifl_spmd import (
    init_ifl_state,
    make_dp_train_step,
    make_ifl_round_step,
)
from repro.models.transformer import init_lm
from repro.optim import make_optimizer

N, TAU, B, S = 2, 2, 2, 32


@pytest.fixture(scope="module")
def setup():
    cfg = ModelConfig(
        num_layers=4, d_model=48, num_heads=2, num_kv_heads=2, d_ff=96,
        vocab_size=128, d_fusion=32, q_block=16, compute_dtype="float32",
        remat="none",
    ).validate()
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1, 1),
                ("client", "data", "model"))
    params, opt_state = init_ifl_state(jax.random.PRNGKey(0), cfg,
                                       n_clients=N)
    step = jax.jit(make_ifl_round_step(cfg, mesh, n_clients=N, tau=TAU,
                                       lr_base=1e-2, lr_modular=1e-2))
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (N, TAU + 1, B, S), 0, 128)}
    return cfg, mesh, params, opt_state, step, batch


def test_round_runs_and_losses_finite(setup):
    cfg, mesh, params, opt_state, step, batch = setup
    with mesh:
        new_params, _, m = step(params, opt_state, batch)
    assert np.isfinite(float(m["base_loss"]))
    assert np.isfinite(float(m["mod_loss"]))


def test_stacked_client_params_diverge(setup):
    """Clients see different data -> their updated params differ."""
    cfg, mesh, params, opt_state, step, batch = setup
    with mesh:
        new_params, _, _ = step(params, opt_state, batch)
    wq = None
    for path, leaf in jax.tree_util.tree_flatten_with_path(
            new_params["base"])[0]:
        if leaf.ndim >= 3:
            wq = leaf
            break
    assert wq is not None
    assert not bool(jnp.allclose(wq[0], wq[1]))


def test_base_phase_touches_only_base(setup):
    """After a round with lr_modular=0, modular params are unchanged
    (and vice versa for lr_base=0) — the two-stage decoupling."""
    cfg, mesh, params, opt_state, batch = (
        setup[0], setup[1], setup[2], setup[3], setup[5]
    )
    step_b = jax.jit(make_ifl_round_step(cfg, mesh, n_clients=N, tau=TAU,
                                         lr_base=1e-2, lr_modular=0.0))
    with mesh:
        p2, _, _ = step_b(params, opt_state, batch)
    for a, b in zip(jax.tree.leaves(params["modular"]),
                    jax.tree.leaves(p2["modular"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    changed = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params["base"]),
                        jax.tree.leaves(p2["base"]))
    )
    assert changed

    step_m = jax.jit(make_ifl_round_step(cfg, mesh, n_clients=N, tau=TAU,
                                         lr_base=0.0, lr_modular=1e-2))
    with mesh:
        p3, _, _ = step_m(params, opt_state, batch)
    for a, b in zip(jax.tree.leaves(params["base"]),
                    jax.tree.leaves(p3["base"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_rounds_reduce_loss(setup):
    cfg, mesh, params, opt_state, step, _ = setup
    key = jax.random.PRNGKey(7)
    losses = []
    with mesh:
        for r in range(6):
            key, sub = jax.random.split(key)
            batch = {"tokens": jax.random.randint(
                sub, (N, TAU + 1, B, S), 0, 128)}
            params, opt_state, m = step(params, opt_state, batch)
            losses.append(float(m["base_loss"]))
    assert losses[-1] < losses[0]


def test_ef_round_state_eager_spmd_parity(setup):
    """One ef(int8_row) round: the SPMD program's carried residual and
    decoded z_hat are BITWISE identical to what the eager IFLTrainer's
    jitted encode/decode machinery produces on the same z.

    int8_row quantizes each (…, d_fusion) row independently, so the
    SPMD (N, B, S, dF) z and the eager (B*S, dF) z are the same rows —
    any drift between the two trainers' EF arithmetic shows up here.
    """
    import functools

    from repro.config import RunConfig
    from repro.core import Client, IFLTrainer
    from repro.core.ifl_spmd import init_ef_state

    cfg, mesh, params, opt_state, _, batch = setup
    codec = "ef(int8_row)"
    step = jax.jit(make_ifl_round_step(
        cfg, mesh, n_clients=N, tau=TAU, lr_base=1e-2, lr_modular=1e-2,
        codec=codec, debug_return_zhat=True,
    ))
    e0 = init_ef_state(codec, (N, B, S, cfg.d_fusion))
    with mesh:
        _, _, m, e1 = step(params, opt_state, batch, e0)
    z = np.asarray(m["z"])          # (N, B, S, dF) pre-encode
    z_hat = np.asarray(m["z_hat"])  # decoded from the gathered payload
    e1 = np.asarray(e1)

    # The eager trainer, configured for the same codec and row count;
    # its _encode_state/_decode are the exact jitted callables run_round
    # uses, and its ef_state holds the same zeros-init residual.
    eager_cfg = RunConfig(n_clients=N, batch_size=B * S,
                          d_fusion=cfg.d_fusion, codec=codec)
    dummy = np.zeros((4, 28, 28, 1), np.float32)
    clients = [Client(cid=k, params={},
                      base_apply=lambda p, x: x,
                      modular_apply=lambda p, z: z,
                      data_x=dummy, data_y=np.zeros((4,), np.int32))
               for k in range(N)]
    tr = IFLTrainer(clients, eager_cfg, seed=0)
    for k in range(N):
        zk = jnp.asarray(z[k].reshape(B * S, cfg.d_fusion))
        payload, ek = tr._encode_state(zk, tr.ef_state[k])
        zhk = tr._decode(payload)
        np.testing.assert_array_equal(
            np.asarray(ek), e1[k].reshape(B * S, cfg.d_fusion))
        np.testing.assert_array_equal(
            np.asarray(zhk), z_hat[k].reshape(B * S, cfg.d_fusion))


def test_ef_spmd_residual_decays_topk(setup):
    """Carried EF state round over round: the residual stays finite and
    the round remains one jitted program (no signature drift)."""
    from repro.core.ifl_spmd import init_ef_state

    cfg, mesh, params, opt_state, _, _ = setup
    codec = "ef(topk0.1)"
    step = jax.jit(make_ifl_round_step(
        cfg, mesh, n_clients=N, tau=TAU, lr_base=1e-2, lr_modular=1e-2,
        codec=codec,
    ))
    ef = init_ef_state(codec, (N, B, S, cfg.d_fusion))
    key = jax.random.PRNGKey(11)
    with mesh:
        for _ in range(3):
            key, sub = jax.random.split(key)
            batch = {"tokens": jax.random.randint(
                sub, (N, TAU + 1, B, S), 0, 128)}
            params, opt_state, m, ef = step(params, opt_state, batch, ef)
            assert np.isfinite(float(m["mod_loss"]))
            assert np.all(np.isfinite(np.asarray(ef)))
    assert float(jnp.linalg.norm(ef)) > 0.0  # topk really drops mass


def _eager_codec_rig(codec, broadcast="full"):
    """The eager trainer's exact jitted encode/decode machinery, as in
    test_ef_round_state_eager_spmd_parity."""
    from repro.config import RunConfig
    from repro.core import Client, IFLTrainer

    eager_cfg = RunConfig(n_clients=N, batch_size=B * S,
                          d_fusion=32, codec=codec, broadcast=broadcast)
    dummy = np.zeros((4, 28, 28, 1), np.float32)
    clients = [Client(cid=k, params={},
                      base_apply=lambda p, x: x,
                      modular_apply=lambda p, z: z,
                      data_x=dummy, data_y=np.zeros((4,), np.int32))
               for k in range(N)]
    return IFLTrainer(clients, eager_cfg, seed=0)


@pytest.mark.parametrize("broadcast", ["full", "delta"])
@pytest.mark.parametrize("codec", ["int8_row", "ef(int8_row)"])
def test_masked_round_eager_spmd_parity(setup, codec, broadcast):
    """Bitwise eager↔SPMD parity for a PARTIAL round, one stateless and
    one ef(...) codec, under BOTH broadcast policies (delta changes the
    ledger, never the decoded training signal — asserted here at the
    bit level): round 1 runs with everyone up (fills the payload
    cache), round 2 masks client 1 out. The SPMD program's decoded
    z_hat must equal — bit for bit — what the eager engine's jitted
    encode/decode produces for the participant's fresh z plus the
    cached round-1 payload for the absent client, the absent client's
    EF residual must stay frozen, and its params must not move."""
    from repro.core.exchange import SPMDFusionExchange
    from repro.core.ifl_spmd import init_ef_state, init_payload_cache

    cfg, mesh, params, opt_state, _, batch = setup
    has_state = codec.startswith("ef(")
    exchange = SPMDFusionExchange(codec, mesh, n_clients=N,
                                  max_staleness=2, broadcast=broadcast)
    step = jax.jit(make_ifl_round_step(
        cfg, mesh, n_clients=N, tau=TAU, lr_base=1e-2, lr_modular=1e-2,
        debug_return_zhat=True,
        partial_participation=True, exchange=exchange,
    ))
    cache = init_payload_cache(codec, (N, B, S, cfg.d_fusion), (N, B, S))
    full = jnp.ones((N,), bool)
    part = jnp.array([True, False])
    ef = init_ef_state(codec, (N, B, S, cfg.d_fusion))
    with mesh:
        if has_state:
            p1, o1, m1, c1, ef1 = step(params, opt_state, batch, full,
                                       cache, ef)
            p2, o2, m2, c2, ef2 = step(p1, o1, batch, part, c1, ef1)
        else:
            p1, o1, m1, c1 = step(params, opt_state, batch, full, cache)
            p2, o2, m2, c2 = step(p1, o1, batch, part, c1)
    assert float(m2["participating"]) == 1.0
    assert float(m2["cache_valid"]) == 2.0  # stale slot inside the bound
    np.testing.assert_array_equal(np.asarray(c2["age"]), [0, 1])

    # Eager replay on the SPMD program's own z tensors.
    tr = _eager_codec_rig(codec, broadcast)
    z1 = np.asarray(m1["z"])
    z2 = np.asarray(m2["z"])
    dF = cfg.d_fusion
    ef_np = {k: tr.ef_state[k] for k in range(N)}
    pay1 = {}
    for k in range(N):
        pay1[k], ef_np[k] = tr._encode_state(
            jnp.asarray(z1[k].reshape(B * S, dF)), ef_np[k])
    # Round 2: only client 0 re-encodes; client 1 serves its cache.
    pay2_0, ef2_0 = tr._encode_state(
        jnp.asarray(z2[0].reshape(B * S, dF)), ef_np[0])
    expected = {0: tr._decode(pay2_0), 1: tr._decode(pay1[1])}
    z_hat2 = np.asarray(m2["z_hat"])
    for k in range(N):
        np.testing.assert_array_equal(
            np.asarray(expected[k]), z_hat2[k].reshape(B * S, dF))
    if has_state:
        # Participant's residual advanced; absent client's is frozen at
        # its round-1 value — bitwise.
        np.testing.assert_array_equal(
            np.asarray(ef2_0), np.asarray(ef2)[0].reshape(B * S, dF))
        np.testing.assert_array_equal(
            np.asarray(ef1)[1], np.asarray(ef2)[1])
    # Absent client bitwise frozen across params and optimizer state.
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a)[1], np.asarray(b)[1])


@pytest.mark.parametrize("optimizer", ["sgd", "adamw"])
def test_masked_round_staleness_excludes_expired(setup, optimizer):
    """max_staleness=0: an expired cache chunk is a true NO-OP in the
    modular scan, for stateful optimizers too — the participant's
    modular params match a single hand-rolled update on the one valid
    chunk to jit-fusion epsilon (regression: zero-weighting the grads
    instead of skipping let adamw's bias-corrected momentum move params
    by ~1e-1, four orders of magnitude above this tolerance)."""
    from repro.core.ifl_spmd import _modular_loss, init_payload_cache
    from repro.optim import make_optimizer

    cfg, mesh, params, opt_state, _, batch = setup
    opt = make_optimizer(optimizer)
    opt_state = {"base": jax.vmap(opt.init)(params["base"]),
                 "modular": jax.vmap(opt.init)(params["modular"])}
    step = jax.jit(make_ifl_round_step(
        cfg, mesh, n_clients=N, tau=TAU, lr_base=1e-2, lr_modular=1e-2,
        optimizer=optimizer, partial_participation=True, max_staleness=0,
        debug_return_zhat=True,
    ))
    cache = init_payload_cache("fp32", (N, B, S, cfg.d_fusion), (N, B, S))
    with mesh:
        p1, o1, m1, c1 = step(params, opt_state, batch,
                              jnp.ones((N,), bool), cache)
        p2, o2, m2, c2 = step(p1, o1, batch, jnp.array([True, False]), c1)
    assert float(m1["cache_valid"]) == 2.0
    assert float(m2["cache_valid"]) == 1.0  # age-1 slot expired at bound 0
    assert np.isfinite(float(m2["mod_loss"]))

    # Hand-rolled expectation for the participant (client 0): exactly
    # ONE modular update, on the valid chunk (its own fresh payload).
    z0 = jnp.asarray(np.asarray(m2["z_hat"])[0])
    y0 = batch["tokens"][0, TAU]
    mp0 = jax.tree.map(lambda a: a[0], p1["modular"])
    os0 = jax.tree.map(lambda a: a[0], o1["modular"])
    grads = jax.grad(_modular_loss)(mp0, cfg, z0, y0)
    exp_mp, _ = opt.update(mp0, grads, os0, 1e-2)
    for a, b in zip(jax.tree.leaves(exp_mp),
                    jax.tree.leaves(jax.tree.map(lambda x: x[0],
                                                 p2["modular"]))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=0)


def test_masked_round_empty_is_noop_with_nan_losses(setup):
    """All-False mask (a legal Bernoulli draw): params, opt state and
    cache bitwise unchanged except ages +1, losses NaN — the eager
    trainers' empty-round convention, not a spurious 0.0."""
    from repro.core.ifl_spmd import init_payload_cache

    cfg, mesh, params, opt_state, _, batch = setup
    step = jax.jit(make_ifl_round_step(
        cfg, mesh, n_clients=N, tau=TAU, lr_base=1e-2, lr_modular=1e-2,
        partial_participation=True,
    ))
    cache = init_payload_cache("fp32", (N, B, S, cfg.d_fusion), (N, B, S))
    with mesh:
        p1, o1, m1, c1 = step(params, opt_state, batch,
                              jnp.ones((N,), bool), cache)
        p2, o2, m2, c2 = step(p1, o1, batch, jnp.zeros((N,), bool), c1)
    assert np.isnan(float(m2["base_loss"]))
    assert np.isnan(float(m2["mod_loss"]))
    assert float(m2["participating"]) == 0.0
    for a, b in zip(jax.tree.leaves((p1, o1, c1["payload"])),
                    jax.tree.leaves((p2, o2, c2["payload"]))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(c2["age"]),
                                  np.asarray(c1["age"]) + 1)


def test_dp_step_matches_manual_sgd():
    cfg = ModelConfig(num_layers=2, d_model=32, num_heads=2, num_kv_heads=2,
                      d_ff=64, vocab_size=64, compute_dtype="float32",
                      remat="none").validate()
    params = init_lm(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16),
                                          0, 64)}
    from repro.models.transformer import lm_loss

    step = jax.jit(make_dp_train_step(cfg, lr=0.1))
    new_params, _, m = step(params, {}, batch)
    grads = jax.grad(lambda p: lm_loss(p, cfg, batch))(params)
    manual = jax.tree.map(lambda p, g: p - 0.1 * g, params, grads)
    for a, b in zip(jax.tree.leaves(new_params), jax.tree.leaves(manual)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
