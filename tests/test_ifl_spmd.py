"""IFL SPMD round-step invariants (1-device mesh; same code the dry-run
lowers at 256/512 chips)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.config import LayerSpec, ModelConfig
from repro.core.ifl_spmd import (
    init_ifl_state,
    make_dp_train_step,
    make_ifl_round_step,
)
from repro.models.transformer import init_lm
from repro.optim import make_optimizer

N, TAU, B, S = 2, 2, 2, 32


@pytest.fixture(scope="module")
def setup():
    cfg = ModelConfig(
        num_layers=4, d_model=48, num_heads=2, num_kv_heads=2, d_ff=96,
        vocab_size=128, d_fusion=32, q_block=16, compute_dtype="float32",
        remat="none",
    ).validate()
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1, 1),
                ("client", "data", "model"))
    params, opt_state = init_ifl_state(jax.random.PRNGKey(0), cfg,
                                       n_clients=N)
    step = jax.jit(make_ifl_round_step(cfg, mesh, n_clients=N, tau=TAU,
                                       lr_base=1e-2, lr_modular=1e-2))
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (N, TAU + 1, B, S), 0, 128)}
    return cfg, mesh, params, opt_state, step, batch


def test_round_runs_and_losses_finite(setup):
    cfg, mesh, params, opt_state, step, batch = setup
    with mesh:
        new_params, _, m = step(params, opt_state, batch)
    assert np.isfinite(float(m["base_loss"]))
    assert np.isfinite(float(m["mod_loss"]))


def test_stacked_client_params_diverge(setup):
    """Clients see different data -> their updated params differ."""
    cfg, mesh, params, opt_state, step, batch = setup
    with mesh:
        new_params, _, _ = step(params, opt_state, batch)
    wq = None
    for path, leaf in jax.tree_util.tree_flatten_with_path(
            new_params["base"])[0]:
        if leaf.ndim >= 3:
            wq = leaf
            break
    assert wq is not None
    assert not bool(jnp.allclose(wq[0], wq[1]))


def test_base_phase_touches_only_base(setup):
    """After a round with lr_modular=0, modular params are unchanged
    (and vice versa for lr_base=0) — the two-stage decoupling."""
    cfg, mesh, params, opt_state, batch = (
        setup[0], setup[1], setup[2], setup[3], setup[5]
    )
    step_b = jax.jit(make_ifl_round_step(cfg, mesh, n_clients=N, tau=TAU,
                                         lr_base=1e-2, lr_modular=0.0))
    with mesh:
        p2, _, _ = step_b(params, opt_state, batch)
    for a, b in zip(jax.tree.leaves(params["modular"]),
                    jax.tree.leaves(p2["modular"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    changed = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params["base"]),
                        jax.tree.leaves(p2["base"]))
    )
    assert changed

    step_m = jax.jit(make_ifl_round_step(cfg, mesh, n_clients=N, tau=TAU,
                                         lr_base=0.0, lr_modular=1e-2))
    with mesh:
        p3, _, _ = step_m(params, opt_state, batch)
    for a, b in zip(jax.tree.leaves(params["base"]),
                    jax.tree.leaves(p3["base"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_rounds_reduce_loss(setup):
    cfg, mesh, params, opt_state, step, _ = setup
    key = jax.random.PRNGKey(7)
    losses = []
    with mesh:
        for r in range(6):
            key, sub = jax.random.split(key)
            batch = {"tokens": jax.random.randint(
                sub, (N, TAU + 1, B, S), 0, 128)}
            params, opt_state, m = step(params, opt_state, batch)
            losses.append(float(m["base_loss"]))
    assert losses[-1] < losses[0]


def test_dp_step_matches_manual_sgd():
    cfg = ModelConfig(num_layers=2, d_model=32, num_heads=2, num_kv_heads=2,
                      d_ff=64, vocab_size=64, compute_dtype="float32",
                      remat="none").validate()
    params = init_lm(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16),
                                          0, 64)}
    from repro.models.transformer import lm_loss

    step = jax.jit(make_dp_train_step(cfg, lr=0.1))
    new_params, _, m = step(params, {}, batch)
    grads = jax.grad(lambda p: lm_loss(p, cfg, batch))(params)
    manual = jax.tree.map(lambda p, g: p - 0.1 * g, params, grads)
    for a, b in zip(jax.tree.leaves(new_params), jax.tree.leaves(manual)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
