"""Per-architecture smoke tests (deliverable f).

Each assigned arch is instantiated as the REDUCED variant of the same
family (1 base + 1 modular pattern group, d_model<=256, <=4 experts) and
runs a real forward + train-grad step and one decode step on CPU,
asserting output shapes and absence of NaNs.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import INPUT_SHAPES
from repro.configs import ARCH_IDS, get_config, supports_shape
from repro.models.transformer import (
    init_decode_cache,
    init_lm,
    lm_apply,
    lm_decode_step,
    lm_loss,
)

B, S = 2, 64


def _smoke_batch(cfg, key):
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    }
    if cfg.num_image_tokens:
        batch["image_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 1), (B, cfg.num_image_tokens, cfg.d_model)
        )
    if cfg.is_encdec:
        batch["frame_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 2), (B, cfg.enc_seq_len, cfg.d_model)
        )
    return batch


@pytest.fixture(scope="module")
def smoke_state():
    """Cache reduced params per arch across tests in this module."""
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_config(arch).reduced()
            params = init_lm(jax.random.PRNGKey(0), cfg)
            cache[arch] = (cfg, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_grad(arch, smoke_state):
    cfg, params = smoke_state(arch)
    batch = _smoke_batch(cfg, jax.random.PRNGKey(1))
    logits, aux, _ = lm_apply(params, cfg, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))
    loss, grads = jax.value_and_grad(
        lambda p: lm_loss(p, cfg, batch)
    )(params)
    assert np.isfinite(float(loss))
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                      for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gn)) and float(gn) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step_reduces_loss_direction(arch, smoke_state):
    """SGD step along the gradient strictly reduces loss at small lr."""
    cfg, params = smoke_state(arch)
    batch = _smoke_batch(cfg, jax.random.PRNGKey(2))
    loss0, grads = jax.value_and_grad(
        lambda p: lm_loss(p, cfg, batch)
    )(params)
    new_params = jax.tree.map(lambda p, g: p - 1e-2 * g, params, grads)
    loss1 = lm_loss(new_params, cfg, batch)
    assert float(loss1) < float(loss0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch, smoke_state):
    cfg, params = smoke_state(arch)
    cache = init_decode_cache(cfg, B, S)
    if cfg.is_encdec:
        from repro.models.transformer import build_cross_caches, encoder_forward

        frames = jax.random.normal(jax.random.PRNGKey(3),
                                   (B, cfg.enc_seq_len, cfg.d_model))
        enc_out = encoder_forward(params["base"]["encoder"], cfg, frames)
        ckvs = build_cross_caches(params, cfg, enc_out)
    else:
        ckvs = None
    token = jnp.zeros((B, 1), jnp.int32)
    logits, cache = lm_decode_step(params, cfg, cache, token,
                                   jnp.int32(0), ckvs)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))
    logits2, _ = lm_decode_step(params, cfg, cache,
                                jnp.ones((B, 1), jnp.int32),
                                jnp.int32(1), ckvs)
    assert not bool(jnp.any(jnp.isnan(logits2)))


def test_long_context_skip_table():
    """The long_500k support table matches DESIGN.md §4."""
    ok = {a for a in ARCH_IDS if supports_shape(a, "long_500k")}
    assert ok == {
        "xlstm-350m", "jamba-1.5-large-398b", "gemma3-27b",
        "llama4-maverick-400b-a17b",
    }
    for a in ARCH_IDS:
        for s in INPUT_SHAPES:
            if s != "long_500k":
                assert supports_shape(a, s)
