"""End-to-end behaviour tests for the IFL system."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import IFLConfig
from repro.core import Client, IFLTrainer
from repro.data import dirichlet_partition, make_synth_kmnist
from repro.models.small import (
    client_base_apply,
    client_modular_apply,
    init_client_model,
    model_bytes,
)


@pytest.fixture(scope="module")
def trained():
    """30 IFL rounds at the calibrated lr on a small shard — enough for
    the system-level claims (incl. the slower conv clients) to become
    measurable in CI time."""
    tx, ty, ex, ey = make_synth_kmnist(4000, 1000)
    cfg = IFLConfig(tau=10, batch_size=32, lr_base=0.05, lr_modular=0.05)
    shards = dirichlet_partition(ty, 4, alpha=0.5, seed=0)
    clients = [
        Client(
            cid=c, params=init_client_model(jax.random.PRNGKey(c), c),
            base_apply=functools.partial(
                lambda p, x, cc: client_base_apply({"base": p}, cc, x), cc=c),
            modular_apply=functools.partial(
                lambda p, z, cc: client_modular_apply({"modular": p}, cc, z),
                cc=c),
            data_x=tx[shards[c - 1]], data_y=ty[shards[c - 1]],
        )
        for c in [1, 2, 3, 4]
    ]
    tr = IFLTrainer(clients, cfg, seed=0)
    acc0 = np.mean(tr.evaluate(ex, ey))
    for _ in range(30):
        tr.run_round()
    return tr, acc0, (ex, ey)


def test_training_improves_all_clients(trained):
    """30-round CI regime: mean improves markedly and at least one client
    reaches the >50% band (the 200-round benchmark reproduces the full
    accuracy claims; this guards the training loop end-to-end)."""
    tr, acc0, (ex, ey) = trained
    accs = tr.evaluate(ex, ey)
    assert np.mean(accs) > acc0 + 0.25, (acc0, accs)
    assert min(accs) > 0.12  # conv clients move slowest but must move
    assert max(accs) > 0.5


def test_uplink_is_activation_sized(trained):
    """30 rounds of IFL cost ~6.7MB uplink — not model-sized."""
    tr, _, _ = trained
    assert tr.ledger.uplink_mb < 10.0
    fl_equiv = 30 * sum(model_bytes(c.params) for c in tr.clients) / 1e6
    assert tr.ledger.uplink_mb < fl_equiv / 5


def test_composition_matrix_consistent(trained):
    """Cross compositions in the same accuracy regime as local ones."""
    tr, _, (ex, ey) = trained
    mat = tr.accuracy_matrix(ex[:1000], ey[:1000])
    local = np.diag(mat).mean()
    cross = mat[~np.eye(4, dtype=bool)].mean()
    assert cross > local - 0.25  # same regime (tightens with training)
