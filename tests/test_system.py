"""End-to-end behaviour tests for the IFL system."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import RunConfig
from repro.core import Client, IFLTrainer
from repro.data import dirichlet_partition, make_synth_kmnist
from repro.models.small import (
    client_base_apply,
    client_modular_apply,
    init_client_model,
    model_bytes,
)


@pytest.fixture(scope="module")
def trained():
    """30 IFL rounds at the calibrated lr on a small shard — enough for
    the system-level claims (incl. the slower conv clients) to become
    measurable in CI time."""
    tx, ty, ex, ey = make_synth_kmnist(4000, 1000)
    cfg = RunConfig(tau=10, batch_size=32, lr_base=0.05, lr_modular=0.05)
    shards = dirichlet_partition(ty, 4, alpha=0.5, seed=0)
    clients = [
        Client(
            cid=c, params=init_client_model(jax.random.PRNGKey(c), c),
            base_apply=functools.partial(
                lambda p, x, cc: client_base_apply({"base": p}, cc, x), cc=c),
            modular_apply=functools.partial(
                lambda p, z, cc: client_modular_apply({"modular": p}, cc, z),
                cc=c),
            data_x=tx[shards[c - 1]], data_y=ty[shards[c - 1]],
        )
        for c in [1, 2, 3, 4]
    ]
    tr = IFLTrainer(clients, cfg, seed=0)
    acc0 = np.mean(tr.evaluate(ex, ey))
    for _ in range(30):
        tr.run_round()
    return tr, acc0, (ex, ey)


def test_training_improves_all_clients(trained):
    """30-round CI regime: mean improves markedly and at least one client
    reaches the >50% band (the 200-round benchmark reproduces the full
    accuracy claims; this guards the training loop end-to-end)."""
    tr, acc0, (ex, ey) = trained
    accs = tr.evaluate(ex, ey)
    assert np.mean(accs) > acc0 + 0.25, (acc0, accs)
    assert min(accs) > 0.12  # conv clients move slowest but must move
    assert max(accs) > 0.5


def test_uplink_is_activation_sized(trained):
    """30 rounds of IFL cost ~6.7MB uplink — not model-sized."""
    tr, _, _ = trained
    assert tr.ledger.uplink_mb < 10.0
    fl_equiv = 30 * sum(model_bytes(c.params) for c in tr.clients) / 1e6
    assert tr.ledger.uplink_mb < fl_equiv / 5


def test_composition_matrix_consistent(trained):
    """Cross compositions in the same accuracy regime as local ones."""
    tr, _, (ex, ey) = trained
    mat = tr.accuracy_matrix(ex[:1000], ey[:1000])
    local = np.diag(mat).mean()
    cross = mat[~np.eye(4, dtype=bool)].mean()
    assert cross > local - 0.25  # same regime (tightens with training)


# ---------------------------------------------------------- EF recovery


def _run_ifl(codec, *, data, cids, tau, rounds, seed,
             participation="full", max_staleness=None, return_trainer=False):
    tx, ty, ex, ey = data
    shards = dirichlet_partition(ty, len(cids), alpha=0.5, seed=0)
    clients = [
        Client(
            cid=c, params=init_client_model(jax.random.PRNGKey(c), c),
            base_apply=functools.partial(
                lambda p, x, cc: client_base_apply({"base": p}, cc, x), cc=c),
            modular_apply=functools.partial(
                lambda p, z, cc: client_modular_apply({"modular": p}, cc, z),
                cc=c),
            data_x=tx[shards[k]], data_y=ty[shards[k]],
        )
        for k, c in enumerate(cids)
    ]
    cfg = RunConfig(tau=tau, batch_size=32, lr_base=0.05, lr_modular=0.05,
                    codec=codec, participation=participation,
                    max_staleness=max_staleness)
    tr = IFLTrainer(clients, cfg, seed=seed)
    for _ in range(rounds):
        tr.run_round()
    acc = float(np.mean(tr.evaluate(ex, ey)))
    return (acc, tr) if return_trainer else acc


@pytest.fixture(scope="module")
def kmnist_4k():
    return make_synth_kmnist(4000, 1000)


def test_ef_closes_compression_gap(kmnist_4k):
    """The EF21 acceptance claim, 30-round CI regime: ef(topk0.1) closes
    >= half of the accuracy gap plain topk0.1 leaves against fp32 — at
    identical wire bytes (parity asserted in test_codec.py). Seeds are
    pinned: per-seed trajectories are chaotic, but at a fixed seed the
    run is deterministic and the measured closure (~70%) has margin."""
    kw = dict(data=kmnist_4k, cids=[1, 2, 3, 4], tau=10, rounds=30, seed=2)
    fp32 = _run_ifl("fp32", **kw)
    plain = _run_ifl("topk0.1", **kw)
    ef = _run_ifl("ef(topk0.1)", **kw)
    gap = fp32 - plain
    assert gap > 0.04, (fp32, plain)  # topk0.1 must actually hurt
    assert ef >= plain + 0.5 * gap, (fp32, plain, ef)


def test_ef_recovers_int4_quantization_bias():
    """ef(int4): int4's per-row quantization bias is systematic, so the
    textbook EF recurrence (trust region inactive — the residual is far
    below max_ratio * ||z||) removes nearly all of it (~99% measured).
    Smaller shards than the topk test: int4's bias only bites when the
    model isn't data-rich enough to average it out."""
    data = make_synth_kmnist(3000, 800)
    kw = dict(data=data, cids=[3, 4], tau=5, rounds=30, seed=0)
    fp32 = _run_ifl("fp32", **kw)
    plain = _run_ifl("int4", **kw)
    ef = _run_ifl("ef(int4)", **kw)
    gap = fp32 - plain
    assert gap > 0.03, (fp32, plain)  # int4 alone must leave a gap
    assert ef >= plain + 0.5 * gap, (fp32, plain, ef)


# ------------------------------------------------ partial participation


def test_k2_participation_matches_full_at_equal_uplink(kmnist_4k):
    """The partial-participation acceptance claim: IFL with uniform
    2-of-4 sampling and the fusion cache on pays exactly K/N = 1/2 of
    the full-participation per-round uplink (exact analytic parity,
    asserted per round), so at the SAME cumulative uplink budget —
    Fig. 2's x-axis — it runs twice the rounds and reaches accuracy
    within 2 points of full participation (measured: it comes out
    ~10 pts ahead at seeds 0/1; asserted with the 2-pt margin)."""
    from repro.core import ifl_round_bytes

    kw = dict(data=kmnist_4k, cids=[1, 2, 3, 4], tau=10, seed=0,
              return_trainer=True)
    acc_full, tr_full = _run_ifl("fp32", rounds=20, **kw)
    acc_k2, tr_k2 = _run_ifl("fp32", rounds=40, participation="k2", **kw)

    # Per-round uplink: every k2 round is exactly the K-participant
    # formula = K/N of the full-participation round.
    full_up = ifl_round_bytes(4, 32, 432)["up"]
    for r, m in enumerate(tr_k2.engine.history):
        exp = ifl_round_bytes(
            4, 32, 432, participating=len(m["participants"]),
            broadcast_entries=m["cache_size"])
        assert tr_k2.ledger.per_round[r]["up"] == exp["up"] == full_up // 2
        assert tr_k2.ledger.per_round[r]["down"] == exp["down"]
    # Equal cumulative uplink: 40 half-rounds == 20 full rounds.
    assert tr_k2.ledger.uplink == tr_full.ledger.uplink
    # The unbounded cache keeps all 4 pairs in play once everyone has
    # uploaded at least once.
    assert tr_k2.engine.history[-1]["cache_size"] == 4
    assert acc_k2 >= acc_full - 0.02, (acc_full, acc_k2)
