"""End-to-end behaviour tests for the IFL system."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import IFLConfig
from repro.core import Client, IFLTrainer
from repro.data import dirichlet_partition, make_synth_kmnist
from repro.models.small import (
    client_base_apply,
    client_modular_apply,
    init_client_model,
    model_bytes,
)


@pytest.fixture(scope="module")
def trained():
    """30 IFL rounds at the calibrated lr on a small shard — enough for
    the system-level claims (incl. the slower conv clients) to become
    measurable in CI time."""
    tx, ty, ex, ey = make_synth_kmnist(4000, 1000)
    cfg = IFLConfig(tau=10, batch_size=32, lr_base=0.05, lr_modular=0.05)
    shards = dirichlet_partition(ty, 4, alpha=0.5, seed=0)
    clients = [
        Client(
            cid=c, params=init_client_model(jax.random.PRNGKey(c), c),
            base_apply=functools.partial(
                lambda p, x, cc: client_base_apply({"base": p}, cc, x), cc=c),
            modular_apply=functools.partial(
                lambda p, z, cc: client_modular_apply({"modular": p}, cc, z),
                cc=c),
            data_x=tx[shards[c - 1]], data_y=ty[shards[c - 1]],
        )
        for c in [1, 2, 3, 4]
    ]
    tr = IFLTrainer(clients, cfg, seed=0)
    acc0 = np.mean(tr.evaluate(ex, ey))
    for _ in range(30):
        tr.run_round()
    return tr, acc0, (ex, ey)


def test_training_improves_all_clients(trained):
    """30-round CI regime: mean improves markedly and at least one client
    reaches the >50% band (the 200-round benchmark reproduces the full
    accuracy claims; this guards the training loop end-to-end)."""
    tr, acc0, (ex, ey) = trained
    accs = tr.evaluate(ex, ey)
    assert np.mean(accs) > acc0 + 0.25, (acc0, accs)
    assert min(accs) > 0.12  # conv clients move slowest but must move
    assert max(accs) > 0.5


def test_uplink_is_activation_sized(trained):
    """30 rounds of IFL cost ~6.7MB uplink — not model-sized."""
    tr, _, _ = trained
    assert tr.ledger.uplink_mb < 10.0
    fl_equiv = 30 * sum(model_bytes(c.params) for c in tr.clients) / 1e6
    assert tr.ledger.uplink_mb < fl_equiv / 5


def test_composition_matrix_consistent(trained):
    """Cross compositions in the same accuracy regime as local ones."""
    tr, _, (ex, ey) = trained
    mat = tr.accuracy_matrix(ex[:1000], ey[:1000])
    local = np.diag(mat).mean()
    cross = mat[~np.eye(4, dtype=bool)].mean()
    assert cross > local - 0.25  # same regime (tightens with training)


# ---------------------------------------------------------- EF recovery


def _run_ifl(codec, *, data, cids, tau, rounds, seed):
    tx, ty, ex, ey = data
    shards = dirichlet_partition(ty, len(cids), alpha=0.5, seed=0)
    clients = [
        Client(
            cid=c, params=init_client_model(jax.random.PRNGKey(c), c),
            base_apply=functools.partial(
                lambda p, x, cc: client_base_apply({"base": p}, cc, x), cc=c),
            modular_apply=functools.partial(
                lambda p, z, cc: client_modular_apply({"modular": p}, cc, z),
                cc=c),
            data_x=tx[shards[k]], data_y=ty[shards[k]],
        )
        for k, c in enumerate(cids)
    ]
    cfg = IFLConfig(tau=tau, batch_size=32, lr_base=0.05, lr_modular=0.05,
                    codec=codec)
    tr = IFLTrainer(clients, cfg, seed=seed)
    for _ in range(rounds):
        tr.run_round()
    return float(np.mean(tr.evaluate(ex, ey)))


@pytest.fixture(scope="module")
def kmnist_4k():
    return make_synth_kmnist(4000, 1000)


def test_ef_closes_compression_gap(kmnist_4k):
    """The EF21 acceptance claim, 30-round CI regime: ef(topk0.1) closes
    >= half of the accuracy gap plain topk0.1 leaves against fp32 — at
    identical wire bytes (parity asserted in test_codec.py). Seeds are
    pinned: per-seed trajectories are chaotic, but at a fixed seed the
    run is deterministic and the measured closure (~70%) has margin."""
    kw = dict(data=kmnist_4k, cids=[1, 2, 3, 4], tau=10, rounds=30, seed=2)
    fp32 = _run_ifl("fp32", **kw)
    plain = _run_ifl("topk0.1", **kw)
    ef = _run_ifl("ef(topk0.1)", **kw)
    gap = fp32 - plain
    assert gap > 0.04, (fp32, plain)  # topk0.1 must actually hurt
    assert ef >= plain + 0.5 * gap, (fp32, plain, ef)


def test_ef_recovers_int4_quantization_bias():
    """ef(int4): int4's per-row quantization bias is systematic, so the
    textbook EF recurrence (trust region inactive — the residual is far
    below max_ratio * ||z||) removes nearly all of it (~99% measured).
    Smaller shards than the topk test: int4's bias only bites when the
    model isn't data-rich enough to average it out."""
    data = make_synth_kmnist(3000, 800)
    kw = dict(data=data, cids=[3, 4], tau=5, rounds=30, seed=0)
    fp32 = _run_ifl("fp32", **kw)
    plain = _run_ifl("int4", **kw)
    ef = _run_ifl("ef(int4)", **kw)
    gap = fp32 - plain
    assert gap > 0.03, (fp32, plain)  # int4 alone must leave a gap
    assert ef >= plain + 0.5 * gap, (fp32, plain, ef)
