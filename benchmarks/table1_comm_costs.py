"""Paper Table I, quantified: per-round communication cost of each
scheme at the paper's configuration (N=4, B=32, d_fusion=432), plus the
feature matrix and the compressed-IFL wire codecs (repro.core.codec).
Prints CSV: scheme,up_bytes,down_bytes,notes.
"""

from __future__ import annotations

import jax

from repro.config import RunConfig
from repro.core import fl_round_bytes, fsl_round_bytes, ifl_round_bytes
from repro.models.small import init_client_model, model_bytes

FEATURES = [
    ("client params private", {"fl": 0, "fsl": 1, "ifl": 1}),
    ("local e2e inference", {"fl": 1, "fsl": 0, "ifl": 1}),
    ("lightweight uplink", {"fl": 0, "fsl": 1, "ifl": 1}),
    ("multiple updates/round", {"fl": 1, "fsl": 0, "ifl": 1}),
    ("full arch privacy", {"fl": 0, "fsl": 0, "ifl": 1}),
    ("heterogeneous models", {"fl": 0, "fsl": 0, "ifl": 1}),
    ("cross-client composition", {"fl": 0, "fsl": 0, "ifl": 1}),
]


def run(quiet: bool = False):
    cfg = RunConfig()
    m1 = model_bytes(init_client_model(jax.random.PRNGKey(0), 1))
    m2 = model_bytes(init_client_model(jax.random.PRNGKey(0), 2))
    fp32_up = ifl_round_bytes(4, cfg.batch_size, cfg.d_fusion)["up"]
    rows = [
        ("ifl", ifl_round_bytes(4, cfg.batch_size, cfg.d_fusion),
         f"tau={cfg.tau} local steps amortized per upload"),
    ]
    for codec in ["bf16", "int8", "topk", "int4"]:
        b = ifl_round_bytes(4, cfg.batch_size, cfg.d_fusion, codec=codec)
        rows.append((f"ifl+{codec}", b,
                     f"wire codec; {fp32_up / b['up']:.1f}x less uplink"))
    for codec in ["ef(topk0.1)", "ef(int4)"]:
        b = ifl_round_bytes(4, cfg.batch_size, cfg.d_fusion, codec=codec)
        rows.append((f"ifl+{codec}", b,
                     f"EF21 residual; {fp32_up / b['up']:.1f}x less uplink"
                     " at near-fp32 accuracy"))
    rows += [
        ("fsl", fsl_round_bytes(4, cfg.batch_size, cfg.d_fusion),
         "1 update per round"),
        ("fl1", fl_round_bytes(4, m1), f"model={m1/1e6:.2f}MB (client 1)"),
        ("fl2", fl_round_bytes(4, m2), f"model={m2/1e6:.2f}MB (client 2)"),
    ]
    if not quiet:
        print("scheme,up_bytes_per_round,down_bytes_per_round,notes")
        for name, b, note in rows:
            print(f"{name},{b['up']},{b['down']},{note}")
        print("\nfeature," + ",".join(["fl", "fsl", "ifl"]))
        for feat, v in FEATURES:
            print(f"{feat},{v['fl']},{v['fsl']},{v['ifl']}")
    return rows


if __name__ == "__main__":
    run()
