"""Fleet scale-out: cohort-shaped rounds at N >> C (ISSUE 7).

The claim: with a C-of-N cohort drawn per round, every per-round cost —
wall-clock, uplink/downlink bytes, server-side live state — scales in
the cohort width C and is FLAT in the fleet size N.  The device/client
working set is C-shaped; N lives only in the host-side population
store, whose footprint is bounded by the staleness window, never by N.

The sweep runs the eager IFL trainer on synth-KMNIST population fleets
(`FleetSpec(n_population=N, cohort=C)`) for each N at fixed C, then one
extra arm at C/2 on the largest N to show the costs DO scale in C:

  bytes   — per-round ledger bytes identical across N at fixed C
            (full participation => K == C every round), up scaling
            linearly and full-broadcast down quadratically in C;
            exact analytic<->ledger parity (`ifl_round_bytes`) on
            every round of every arm.
  clock   — mean measured round wall-clock flat in N (ratio between
            the largest and smallest fleet under ``--time-tol``).
  memory  — max live server slots (fusion cache entries + EF residuals
            + upload stamps + delta mirrors) bounded by
            C * (max_staleness + 2), independent of N.

  PYTHONPATH=src python -m benchmarks.fleet_scale --smoke --check

``--check`` exits nonzero unless all three hold.  Results land in
``BENCH_fleet_scale.json`` (``--out``), the nightly artifact.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.api import DataSpec, ExperimentSpec
from repro.api.runner import build_trainer
from repro.api.spec import FleetSpec
from repro.core import ifl_round_bytes


def _spec(args, n: int, cohort: int) -> ExperimentSpec:
    return ExperimentSpec(
        scheme="ifl", rounds=args.rounds, tau=args.tau, lr=0.05,
        codec=args.codec, broadcast=args.broadcast, seed=args.seed,
        participation="full", max_staleness=args.max_staleness,
        eval_every=0,
        data=DataSpec(n_train=args.n_train, n_test=args.n_test),
        fleet=FleetSpec(n_population=n, cohort=cohort),
    )


def _live_server_slots(trainer) -> int:
    """Live per-slot state on the server, in slots — the quantity the
    staleness window must bound at N >> C."""
    ex = trainer.exchange
    mirror_slots = sum(1 for v in ex.mirrors.versions if v)
    return max(len(ex.cache._entries), len(ex.ef_state),
               len(ex._last_upload), mirror_slots)


def run_arm(args, n: int, cohort: int):
    spec = _spec(args, n, cohort)
    trainer = build_trainer(spec)
    rounds, parity = [], True
    for r in range(args.rounds):
        t0 = time.perf_counter()
        rep = trainer.run_round()
        dt = time.perf_counter() - t0
        got = trainer.ledger.per_round[r]
        exp = ifl_round_bytes(
            n, spec.batch_size, spec.d_fusion, codec=spec.codec,
            participating=len(rep["participants"]),
            broadcast_entries=rep["cache_size"],
            broadcast=spec.broadcast,
            delta_entries=rep.get("shipped_entries"),
        )
        if got["up"] != exp["up"] or got["down"] != exp["down"]:
            print(f"  PARITY MISMATCH N={n} C={cohort} round {r}: "
                  f"ledger {got} != analytic {exp}")
            parity = False
        rounds.append({
            "round": r, "wall_s": dt,
            "participants": len(rep["participants"]),
            "up_bytes": got["up"], "down_bytes": got["down"],
            "live_server_slots": _live_server_slots(trainer),
        })
    # Warm-up excluded from the clock: round 0 pays every jit compile.
    timed = rounds[1:] or rounds
    arm = {
        "n_population": n, "cohort": cohort,
        "mean_round_s": float(np.mean([r["wall_s"] for r in timed])),
        "up_bytes_per_round": rounds[-1]["up_bytes"],
        "down_bytes_per_round": rounds[-1]["down_bytes"],
        "max_live_server_slots": max(r["live_server_slots"]
                                     for r in rounds),
        "materialized_clients": len(trainer.clients.materialized),
        "parity_exact": parity,
        "rounds": rounds,
    }
    print(f"N={n:>6} C={cohort:>4}: {arm['mean_round_s']*1e3:8.1f} ms/round, "
          f"up {arm['up_bytes_per_round']/1e6:.3f} MB, "
          f"down {arm['down_bytes_per_round']/1e6:.3f} MB, "
          f"server slots <= {arm['max_live_server_slots']}, "
          f"clients touched {arm['materialized_clients']}/{n}, "
          f"parity {'exact' if parity else 'BROKEN'}")
    return arm


def run(args):
    ns = sorted(args.ns)
    print(f"fleet scale sweep: N in {ns} at C={args.cohort}, "
          f"{args.rounds} rounds, codec {args.codec}, "
          f"broadcast {args.broadcast}, "
          f"max_staleness {args.max_staleness}")
    arms = [run_arm(args, n, args.cohort) for n in ns]
    # One narrower arm on the biggest fleet: shows the costs scale in
    # C while N stands still.
    c_half = max(2, args.cohort // 2)
    half = run_arm(args, ns[-1], c_half) if c_half < args.cohort else None

    result = {
        "ns": ns, "cohort": args.cohort, "rounds": args.rounds,
        "codec": args.codec, "broadcast": args.broadcast,
        "max_staleness": args.max_staleness, "seed": args.seed,
        "smoke": args.smoke, "arms": arms, "half_cohort_arm": half,
    }
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1)
        print(f"wrote {args.out}")

    if args.check:
        failures = []
        if not all(a["parity_exact"] for a in arms + [half] if a):
            failures.append("analytic<->ledger byte parity broken")
        base = arms[0]
        for a in arms[1:]:
            if (a["up_bytes_per_round"] != base["up_bytes_per_round"] or
                    a["down_bytes_per_round"] !=
                    base["down_bytes_per_round"]):
                failures.append(
                    f"bytes not flat in N: N={a['n_population']} rounds "
                    f"cost {a['up_bytes_per_round']}/"
                    f"{a['down_bytes_per_round']} B vs "
                    f"N={base['n_population']}'s "
                    f"{base['up_bytes_per_round']}/"
                    f"{base['down_bytes_per_round']} B at the same C")
            ratio = a["mean_round_s"] / max(base["mean_round_s"], 1e-9)
            if ratio > args.time_tol:
                failures.append(
                    f"wall-clock not flat in N: {ratio:.2f}x slower at "
                    f"N={a['n_population']} than N={base['n_population']} "
                    f"(tolerance {args.time_tol}x)")
        bound = args.cohort * ((args.max_staleness or 0) + 2)
        for a in arms:
            if a["max_live_server_slots"] > bound:
                failures.append(
                    f"server memory unbounded: {a['max_live_server_slots']}"
                    f" live slots at N={a['n_population']} exceeds "
                    f"C*(max_staleness+2) = {bound}")
        if half is not None:
            big = arms[-1]
            cr = args.cohort // c_half
            if half["up_bytes_per_round"] * cr != big["up_bytes_per_round"]:
                failures.append(
                    f"uplink not linear in C: C={args.cohort} pays "
                    f"{big['up_bytes_per_round']} B, C={c_half} pays "
                    f"{half['up_bytes_per_round']} B")
            if (args.broadcast == "full" and
                    half["down_bytes_per_round"] * cr * cr !=
                    big["down_bytes_per_round"]):
                failures.append(
                    f"full-broadcast downlink not quadratic in C: "
                    f"C={args.cohort} pays {big['down_bytes_per_round']} "
                    f"B, C={c_half} pays {half['down_bytes_per_round']} B")
        if failures:
            for msg in failures:
                print(f"CHECK FAILED: {msg}")
            raise SystemExit(1)
        print("all fleet-scale acceptance checks passed")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ns", type=int, nargs="+", default=[1000, 10000],
                    help="fleet sizes N to sweep at fixed cohort")
    ap.add_argument("--cohort", type=int, default=256,
                    help="cohort width C (the paper-scale headline "
                         "regime is N=10^4, C=256)")
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--tau", type=int, default=1)
    ap.add_argument("--codec", default="int8")
    ap.add_argument("--broadcast", default="full",
                    choices=["full", "delta"])
    ap.add_argument("--max-staleness", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--n-train", type=int, default=4096)
    ap.add_argument("--n-test", type=int, default=256)
    ap.add_argument("--time-tol", type=float, default=2.0,
                    help="max allowed slowdown between the largest and "
                         "smallest N (flat-in-N tolerance; generous "
                         "for shared CI runners)")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-long CI mode: tiny fleets and cohort")
    ap.add_argument("--nightly", action="store_true",
                    help="the 10^4-client nightly: full N sweep at a "
                         "cohort sized for an eager CPU runner")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless bytes/clock are flat in "
                         "N, scale in C, parity is exact, and server "
                         "memory is staleness-bounded")
    ap.add_argument("--out", default="results/bench/BENCH_fleet_scale.json")
    args = ap.parse_args()
    if args.smoke:
        args.ns = [64, 256]
        args.cohort = 8
        args.rounds = 3
        args.n_train, args.n_test = 512, 128
    elif args.nightly:
        # The eager modular phase is O(C^2) dispatches, so the nightly
        # keeps the full 10^4-client fleet but a CPU-sized cohort; the
        # flat-in-N / scale-in-C claims are width-independent.
        args.ns = [1000, 10000]
        args.cohort = 32
        args.rounds = 3
    run(args)


if __name__ == "__main__":
    main()
