"""Paper Fig. 4: test-accuracy matrix over all (base block k, modular
block i) combinations after training.

Claim under test: cross-client compositions are comparable to (sometimes
better than) local compositions — e.g. A1-B2 >= A1-A2 in the paper.
Prints CSV rows of the 4x4 matrix.
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.api import ExperimentSpec, PAPER_RESULTS, run_experiment

NAMES = ["A", "B", "C", "D"]


def run(rounds: int = 60, force: bool = False, quiet: bool = False,
        participation: str = "full"):
    spec = ExperimentSpec(scheme="ifl", rounds=rounds,
                          eval_every=max(1, rounds // 40),
                          participation=participation)
    out = run_experiment(spec, cache_dir=PAPER_RESULTS, force=force)
    mat = np.array(out.final["matrix"])
    if not quiet:
        print("base\\modular," + ",".join(f"{n}2" for n in NAMES))
        for k in range(4):
            print(f"{NAMES[k]}1," + ",".join(f"{mat[k, i]:.4f}"
                                             for i in range(4)))
    return mat


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--participation", default="full",
                    help="client schedule (repro.core.rounds), e.g. k2")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    mat = run(args.rounds, args.force, participation=args.participation)
    local = np.diag(mat)
    cross = mat[~np.eye(4, dtype=bool)]
    n_better = int((mat - local[:, None] >= -0.005).sum() - 4)
    print(f"# local mean {local.mean():.3f}, cross mean {cross.mean():.3f}, "
          f"{n_better}/12 cross combos within 0.5pt of (or above) local")
