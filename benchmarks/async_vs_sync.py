"""Sync barrier vs async event-driven IFL at matched cumulative uplink.

The claim (ISSUE 6 / ROADMAP async tier): on a heavy-tailed availability
trace, a synchronous round barrier pins every round's wall-clock to the
slowest scheduled client's next arrival, while the async engine fuses
whatever arrived each fixed tick — so at the SAME cumulative uplink
bytes the async run reaches comparable accuracy in a fraction of the
simulated wall-clock, with throughput measured in uploads/sec absorbed.

Both arms share one arrival trace and seed:

  sync  — the ordinary barriered `run_experiment`; its wall-clock is
          priced by `simulate_sync_wall_clock` (round duration = max
          over scheduled participants of their next arrival after the
          round starts — the barrier IS the straggler).
  async — `ExperimentSpec(mode='async', trace=...)` run tick by tick
          until its ledger has absorbed at least the sync arm's
          cumulative uplink; its wall-clock is ticks x tick by
          construction.

Per-tick analytic<->ledger byte parity (`ifl_round_bytes` vs
`CommLedger.per_round`) is checked on the async arm — the acceptance
criterion that async accounting is exact, not approximate.

  PYTHONPATH=src python -m benchmarks.async_vs_sync --smoke --check

``--check`` exits nonzero unless (a) async final accuracy is within 2
points of sync at matched uplink, (b) async strictly reduces
wall-clock-per-accuracy, and (c) byte parity is exact. Results land in
``BENCH_async_vs_sync.json`` (``--out``), the nightly artifact.
"""

from __future__ import annotations

import argparse
import json
import os

from repro.api import DataSpec, ExperimentSpec, run_experiment
from repro.api.runner import build_trainer
from repro.core import ifl_round_bytes
from repro.core.rounds import simulate_sync_wall_clock


def _spec(args, **overrides) -> ExperimentSpec:
    base = dict(
        scheme="ifl", rounds=args.rounds, tau=args.tau, lr=0.05,
        codec=args.codec, broadcast=args.broadcast, seed=args.seed,
        eval_every=args.eval_every,
        data=DataSpec(n_train=args.n_train, n_test=args.n_test),
    )
    base.update(overrides)
    return ExperimentSpec(**base)


def _check_parity(trainer, spec, reports) -> bool:
    """Exact analytic<->ledger parity, every async tick."""
    n = spec.fleet.n_clients
    for i, rep in enumerate(reports):
        exp = ifl_round_bytes(
            n, spec.batch_size, spec.d_fusion, codec=spec.codec,
            participating=len(rep["participants"]),
            broadcast_entries=rep["cache_size"],
            broadcast=spec.broadcast,
            delta_entries=rep.get("shipped_entries"),
        )
        got = trainer.ledger.per_round[i]
        if got["up"] != exp["up"] or got["down"] != exp["down"]:
            print(f"  PARITY MISMATCH tick {i}: ledger {got} != "
                  f"analytic {exp}")
            return False
    return True


def run(args):
    # ---------------------------------------------------------- sync arm
    sync_spec = _spec(args, mode="sync", participation="full")
    sync_res = run_experiment(sync_spec)
    sync_acc = sync_res.records[-1]["acc_mean"]
    sync_uplink = sync_res.uplink_mb
    # The barrier's clock: replay the SAME trace the async arm trains
    # on — each sync round waits for every scheduled client.
    durations = simulate_sync_wall_clock(
        args.trace, sync_spec.fleet.n_clients, args.rounds,
        seed=args.seed)
    sync_clock = sum(durations)
    print(f"sync : {args.rounds} rounds, uplink {sync_uplink:.3f} MB, "
          f"final acc {sync_acc:.4f}, simulated wall-clock "
          f"{sync_clock:.1f}s (worst round {max(durations):.1f}s)")

    # --------------------------------------------------------- async arm
    # Run tick by tick until the ledger has absorbed the sync arm's
    # cumulative uplink (matched-budget comparison), capped at a
    # generous tick budget so a sparse trace can't spin forever.
    async_spec = _spec(args, mode="async", trace=args.trace,
                       tick=args.tick, participation="full",
                       rounds=args.rounds)
    trainer = build_trainer(async_spec)
    from repro.api import schemes as _schemes

    data = _schemes.load_data(async_spec)
    max_ticks = args.max_ticks or 50 * args.rounds
    reports, curve = [], []
    while trainer.ledger.uplink_mb < sync_uplink and \
            len(reports) < max_ticks:
        rep = trainer.run_round()
        reports.append(rep)
        if len(reports) % max(args.eval_every, 1) == 0:
            import numpy as np

            acc = float(np.mean(trainer.evaluate(data.test_x, data.test_y)))
            curve.append({"tick": len(reports),
                          "sim_time": rep["sim_time"],
                          "uplink_mb": trainer.ledger.uplink_mb,
                          "acc_mean": acc})
    import numpy as np

    async_acc = float(np.mean(trainer.evaluate(data.test_x, data.test_y)))
    eng = trainer.engine
    async_clock = eng.sim_time
    ups = eng.total_uploads / max(async_clock, 1e-12)
    matched = trainer.ledger.uplink_mb >= sync_uplink
    print(f"async: {len(reports)} ticks, uplink "
          f"{trainer.ledger.uplink_mb:.3f} MB "
          f"({'matched' if matched else 'NOT matched'}), "
          f"final acc {async_acc:.4f}, simulated wall-clock "
          f"{async_clock:.1f}s, {ups:.2f} uploads/sec absorbed "
          f"({eng.total_arrivals} raw arrivals)")

    parity = _check_parity(trainer, async_spec, reports)
    print(f"async analytic<->ledger byte parity: "
          f"{'exact' if parity else 'BROKEN'}")

    # Wall-clock-per-accuracy: simulated seconds paid per accuracy
    # point — the figure of merit the barrier loses on.
    sync_wpa = sync_clock / max(sync_acc, 1e-12)
    async_wpa = async_clock / max(async_acc, 1e-12)
    print(f"wall-clock per accuracy point: sync {sync_wpa:.1f}s, "
          f"async {async_wpa:.1f}s "
          f"({sync_wpa / max(async_wpa, 1e-12):.1f}x reduction)")

    result = {
        "trace": args.trace, "tick": args.tick, "codec": args.codec,
        "broadcast": args.broadcast, "rounds": args.rounds,
        "seed": args.seed, "smoke": args.smoke,
        "sync": {"rounds": args.rounds, "uplink_mb": sync_uplink,
                 "final_acc": sync_acc, "wall_clock_s": sync_clock,
                 "round_durations_s": durations,
                 "records": sync_res.records},
        "async": {"ticks": len(reports),
                  "uplink_mb": trainer.ledger.uplink_mb,
                  "final_acc": async_acc, "wall_clock_s": async_clock,
                  "uploads_per_sec": ups,
                  "total_uploads": eng.total_uploads,
                  "total_arrivals": eng.total_arrivals,
                  "matched_uplink": matched, "curve": curve},
        "parity_exact": parity,
        "acc_delta_pts": (async_acc - sync_acc) * 100,
        "wall_clock_per_acc": {"sync": sync_wpa, "async": async_wpa},
    }
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1)
        print(f"wrote {args.out}")

    if args.check:
        failures = []
        if not matched:
            failures.append("async never matched the sync uplink budget "
                            f"within {max_ticks} ticks")
        if async_acc < sync_acc - 0.02:
            failures.append(f"async acc {async_acc:.4f} more than 2 pts "
                            f"below sync {sync_acc:.4f} at matched uplink")
        if not async_wpa < sync_wpa:
            failures.append(f"async wall-clock/acc {async_wpa:.1f}s not "
                            f"strictly below sync {sync_wpa:.1f}s")
        if not parity:
            failures.append("async analytic<->ledger byte parity broken")
        if failures:
            for msg in failures:
                print(f"CHECK FAILED: {msg}")
            raise SystemExit(1)
        print("all async-vs-sync acceptance checks passed")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", default="pareto(1.2,0.5)",
                    help="heavy-tail arrival trace shared by both arms "
                         "(repro.core.rounds.parse_trace)")
    ap.add_argument("--tick", type=float, default=1.0)
    ap.add_argument("--rounds", type=int, default=40,
                    help="sync rounds (async runs until uplink matches)")
    ap.add_argument("--tau", type=int, default=10)
    ap.add_argument("--codec", default="int8")
    ap.add_argument("--broadcast", default="delta",
                    choices=["full", "delta"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--eval-every", type=int, default=5)
    ap.add_argument("--n-train", type=int, default=6000)
    ap.add_argument("--n-test", type=int, default=1500)
    ap.add_argument("--max-ticks", type=int, default=0,
                    help="async tick cap (0 = 50x rounds)")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-long CI mode: tiny data, few rounds")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless the ISSUE-6 acceptance "
                         "criteria hold")
    ap.add_argument("--out", default="results/bench/BENCH_async_vs_sync.json")
    args = ap.parse_args()
    if args.smoke:
        args.rounds = min(args.rounds, 8)
        args.tau = min(args.tau, 2)
        args.n_train, args.n_test = 800, 200
        args.eval_every = 2
    run(args)


if __name__ == "__main__":
    main()
