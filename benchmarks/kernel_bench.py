"""Kernel micro-benchmarks: wall-time of the jnp reference path on CPU
(this container's only runtime) plus the analytic TPU roofline estimate
for the Pallas kernel at production tiles — including the fused
projection+int8 wire-encode kernel (codec 'int8_row') vs the unfused
project-then-quantize two-pass. Prints CSV:
name,us_per_call,derived (derived = achieved CPU GFLOP/s | TPU-bound us).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.roofline.analysis import HW


def _time(fn, *args, reps=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # us


def run(quiet: bool = False):
    rows = []
    key = jax.random.PRNGKey(0)

    # fusion_proj at the paper-scale and LLM-scale shapes.
    for (m, k, n) in [(1024, 432, 432), (4096, 4096, 2048)]:
        x = jax.random.normal(key, (m, k), jnp.float32)
        w = jax.random.normal(key, (k, n), jnp.float32) * 0.02
        b = jnp.zeros((n,))
        f = jax.jit(lambda x, w, b: ref.fusion_proj_ref(x, w, b, "silu"))
        us = _time(f, x, w, b)
        flops = 2 * m * k * n
        tpu_us = max(flops / HW.peak_flops,
                     (x.nbytes + w.nbytes + m * n * 4) / HW.hbm_bw) * 1e6
        rows.append((f"fusion_proj_{m}x{k}x{n}", us,
                     f"cpu {flops/us/1e3:.1f}GF/s | tpu-bound {tpu_us:.1f}us"))

    # fused projection+int8 wire encode (codec 'int8_row') vs the unfused
    # two-pass (project, then quantize). The fused epilogue never writes
    # the fp32 (M, N) activation to HBM: output traffic drops from
    # M*N*4 B to M*N*1 + M*4 B, on top of the matmul's input traffic.
    for (m, k, n) in [(1024, 432, 432), (4096, 4096, 2048)]:
        x = jax.random.normal(key, (m, k), jnp.float32)
        w = jax.random.normal(key, (k, n), jnp.float32) * 0.02
        b = jnp.zeros((n,))
        f = jax.jit(lambda x, w, b: ref.fusion_proj_quant_ref(x, w, b, "silu"))
        us = _time(f, x, w, b)
        flops = 2 * m * k * n
        out_fused = m * n * 1 + m * 4
        tpu_us = max(flops / HW.peak_flops,
                     (x.nbytes + w.nbytes + out_fused) / HW.hbm_bw) * 1e6
        tpu_us_unfused = max(
            flops / HW.peak_flops,
            (x.nbytes + w.nbytes + m * n * 4) / HW.hbm_bw
        ) * 1e6 + (m * n * 5 + m * 4) / HW.hbm_bw * 1e6  # + quant pass
        rows.append((
            f"fusion_proj_quant_{m}x{k}x{n}", us,
            f"cpu {flops/us/1e3:.1f}GF/s | tpu-bound fused {tpu_us:.1f}us "
            f"vs unfused {tpu_us_unfused:.1f}us",
        ))

    # flash attention (ref path) at a serving-ish shape.
    b_, h, s, hd = 1, 8, 1024, 128
    q = jax.random.normal(key, (b_, h, s, hd))
    k_ = jax.random.normal(key, (b_, h, s, hd))
    v = jax.random.normal(key, (b_, h, s, hd))
    f = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
    us = _time(f, q, k_, v)
    flops = 4 * b_ * h * s * s * hd
    tpu_us = flops / HW.peak_flops * 1e6
    rows.append((f"flash_attn_b{b_}h{h}s{s}", us,
                 f"cpu {flops/us/1e3:.1f}GF/s | tpu-bound {tpu_us:.1f}us"))

    # rmsnorm (memory-bound).
    x = jax.random.normal(key, (8192, 4096))
    sc = jnp.ones((4096,))
    f = jax.jit(lambda x, s: ref.rmsnorm_ref(x, s))
    us = _time(f, x, sc)
    byts = 2 * x.nbytes
    rows.append((f"rmsnorm_8192x4096", us,
                 f"cpu {byts/us/1e3:.1f}GB/s | tpu-bound {byts/HW.hbm_bw*1e6:.1f}us"))

    if not quiet:
        print("name,us_per_call,derived")
        for n, us, d in rows:
            print(f"{n},{us:.1f},{d}")
    return rows


if __name__ == "__main__":
    run()
