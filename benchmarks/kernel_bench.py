"""Kernel micro-benchmarks: wall-time of the jnp reference path on CPU
(this container's only runtime) plus the analytic TPU roofline estimate
for the Pallas kernel at production tiles — and, for the whole fused
wire-path family (codec encode epilogues + EF21), the HBM bytes each
fused kernel moves vs its jnp oracle: the oracle's traffic is measured
off XLA's ``compiled.cost_analysis()`` (analytic fallback when the
backend reports nothing), the kernel's is its exact DMA schedule from
the BlockSpecs.  Prints CSV; ``--check`` asserts every fused variant
moves strictly less HBM traffic than its oracle at the fig2 shapes;
``--out BENCH_kernels.json`` records the rows plus the autotuner's
block selections; ``--smoke`` shrinks shapes/reps for CI.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.core.codec import get_codec
from repro.kernels import ops, ref, wire_fused
from repro.roofline.analysis import HW

# The codecs with a fused wire scheme, at the fig2 wire shape
# (batch 1024 rows into the d_fusion=432 fusion layer) plus the two
# extreme arch d_fusions from repro.configs.
WIRE_CODECS = ("int8_row", "int4", "topk", "sketch",
               "ef(int4)", "ef(int8_row)")
FIG2_MKN = (1024, 432, 432)


def _time(fn, *args, reps=5):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # us


def _measured_bytes(compiled) -> float:
    """'bytes accessed' from cost_analysis, 0.0 when unreported."""
    try:
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, (list, tuple)) else ca
        return float(ca.get("bytes accessed", 0.0) or 0.0)
    except Exception:
        return 0.0


def bench_wire_encode(shapes, reps=5):
    """Fused wire_encode[codec] vs the jnp oracle: HBM bytes + CPU us."""
    recs = []
    key = jax.random.PRNGKey(0)
    for name in WIRE_CODECS:
        cd = get_codec(name)
        for shape in shapes:
            hbm = wire_fused.encode_hbm_bytes(cd, shape)
            if hbm is None:
                continue
            z = jax.random.normal(key, shape, jnp.float32)
            if cd.has_state:
                e = cd.init_state(shape)
                f = jax.jit(cd.encode_with_state)
                compiled = f.lower(z, e).compile()
                us = _time(lambda z, e: f(z, e), z, e, reps=reps)
            else:
                f = jax.jit(cd.encode)
                compiled = f.lower(z).compile()
                us = _time(f, z, reps=reps)
            oracle = _measured_bytes(compiled)
            oracle_src = "cost_analysis"
            if not oracle:
                oracle, oracle_src = float(hbm["unfused_bytes"]), "analytic"
            inner = getattr(cd, "inner", cd)
            recs.append({
                "kernel": hbm["kernel"],
                "codec": name,
                "shape": list(shape),
                "oracle_us": us,
                "fused_hbm_bytes": hbm["fused_bytes"],
                "oracle_hbm_bytes": int(oracle),
                "oracle_hbm_source": oracle_src,
                "payload_bytes": hbm["payload_bytes"],
                "blocks": ops.wire_blocks(inner.name, shape[-1]),
            })
    return recs


def bench_proj_encode(mkns, reps=5):
    """Fused projection+encode epilogue vs the two-graph oracle."""
    recs = []
    key = jax.random.PRNGKey(1)
    for name in WIRE_CODECS:
        cd = get_codec(name)
        for (m, k, n) in mkns:
            inner = getattr(cd, "inner", cd)
            blocks = ops.wire_blocks(inner.name, n, kind="proj_encode")
            bm = blocks.get("bm", 256)
            hbm = wire_fused.proj_encode_hbm_bytes(cd, m, k, n, bm=bm)
            if hbm is None:
                continue
            x = jax.random.normal(key, (m, k), jnp.float32)
            w = jax.random.normal(key, (k, n), jnp.float32) * 0.02
            if cd.has_state:
                e = cd.init_state((m, n))
                f = jax.jit(lambda x, w, e: ref.fusion_proj_encode_ref(
                    x, w, codec=cd, e=e))
                compiled = f.lower(x, w, e).compile()
                us = _time(f, x, w, e, reps=reps)
            else:
                f = jax.jit(lambda x, w: ref.fusion_proj_encode_ref(
                    x, w, codec=cd))
                compiled = f.lower(x, w).compile()
                us = _time(f, x, w, reps=reps)
            oracle = _measured_bytes(compiled)
            oracle_src = "cost_analysis"
            if not oracle:
                # Analytic floor: matmul in/out + activation re-read +
                # payload (+ EF residual round-trips).
                enc = wire_fused.encode_hbm_bytes(cd, (m, n))
                oracle = float(m * k * 4 + k * n * 4 + m * n * 4
                               + enc["unfused_bytes"])
                oracle_src = "analytic"
            recs.append({
                "kernel": hbm["kernel"],
                "codec": name,
                "shape": [m, k, n],
                "oracle_us": us,
                "fused_hbm_bytes": hbm["fused_bytes"],
                "oracle_hbm_bytes": int(oracle),
                "oracle_hbm_source": oracle_src,
                "payload_bytes": hbm["payload_bytes"],
                "blocks": blocks,
            })
    return recs


def check_wire(recs):
    """Every fused variant must move strictly less HBM than its oracle."""
    bad = [r for r in recs
           if r["fused_hbm_bytes"] >= r["oracle_hbm_bytes"]]
    if bad:
        lines = "\n".join(
            f"  {r['kernel']} {tuple(r['shape'])}: fused "
            f"{r['fused_hbm_bytes']} >= oracle {r['oracle_hbm_bytes']} "
            f"({r['oracle_hbm_source']})" for r in bad)
        raise AssertionError(f"fused kernels not saving HBM traffic:\n{lines}")


def run(quiet: bool = False, smoke: bool = False, check: bool = False,
        out: str = ""):
    rows = []
    key = jax.random.PRNGKey(0)

    # fusion_proj at the paper-scale and (full mode) LLM-scale shapes.
    proj_shapes = [(1024, 432, 432)] if smoke else \
        [(1024, 432, 432), (4096, 4096, 2048)]
    reps = 2 if smoke else 5
    for (m, k, n) in proj_shapes:
        x = jax.random.normal(key, (m, k), jnp.float32)
        w = jax.random.normal(key, (k, n), jnp.float32) * 0.02
        b = jnp.zeros((n,))
        f = jax.jit(lambda x, w, b: ref.fusion_proj_ref(x, w, b, "silu"))
        us = _time(f, x, w, b, reps=reps)
        flops = 2 * m * k * n
        tpu_us = max(flops / HW.peak_flops,
                     (x.nbytes + w.nbytes + m * n * 4) / HW.hbm_bw) * 1e6
        rows.append((f"fusion_proj_{m}x{k}x{n}", us,
                     f"cpu {flops/us/1e3:.1f}GF/s | tpu-bound {tpu_us:.1f}us"))

    # flash attention (ref path) at a serving-ish shape.
    if not smoke:
        b_, h, s, hd = 1, 8, 1024, 128
        q = jax.random.normal(key, (b_, h, s, hd))
        k_ = jax.random.normal(key, (b_, h, s, hd))
        v = jax.random.normal(key, (b_, h, s, hd))
        f = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
        us = _time(f, q, k_, v, reps=reps)
        flops = 4 * b_ * h * s * s * hd
        tpu_us = flops / HW.peak_flops * 1e6
        rows.append((f"flash_attn_b{b_}h{h}s{s}", us,
                     f"cpu {flops/us/1e3:.1f}GF/s | tpu-bound {tpu_us:.1f}us"))

        # rmsnorm (memory-bound).
        x = jax.random.normal(key, (8192, 4096))
        sc = jnp.ones((4096,))
        f = jax.jit(lambda x, s: ref.rmsnorm_ref(x, s))
        us = _time(f, x, sc, reps=reps)
        byts = 2 * x.nbytes
        rows.append((
            "rmsnorm_8192x4096", us,
            f"cpu {byts/us/1e3:.1f}GB/s | "
            f"tpu-bound {byts/HW.hbm_bw*1e6:.1f}us"))

    # The fused wire path: encode-only kernels at the fig2 wire shape
    # (plus the arch d_fusion extremes in full mode), and the
    # projection+encode epilogue family at the fig2 matmul shape.
    m_fig2, _, d_fig2 = FIG2_MKN
    enc_shapes = [(256 if smoke else m_fig2, d_fig2)]
    if not smoke:
        enc_shapes += [(m_fig2, 1024), (m_fig2, 4096)]
    wire = bench_wire_encode(enc_shapes, reps=reps)
    wire += bench_proj_encode(
        [(256, 432, 432)] if smoke else [FIG2_MKN], reps=reps)
    if check:
        check_wire(wire)

    if not quiet:
        print("name,us_per_call,derived")
        for n, us, d in rows:
            print(f"{n},{us:.1f},{d}")
        print()
        print("kernel,codec,shape,oracle_us,fused_hbm_bytes,"
              "oracle_hbm_bytes,oracle_hbm_source,blocks")
        for r in wire:
            print(f"{r['kernel']},{r['codec']},{'x'.join(map(str, r['shape']))},"
                  f"{r['oracle_us']:.1f},{r['fused_hbm_bytes']},"
                  f"{r['oracle_hbm_bytes']},{r['oracle_hbm_source']},"
                  f"{json.dumps(r['blocks'])}")
        if check:
            print("\ncheck OK: every fused variant moves less HBM "
                  "traffic than its jnp oracle")

    if out:
        with open(out, "w") as fh:
            json.dump({
                "rows": [{"name": n, "us": us, "derived": d}
                         for n, us, d in rows],
                "wire": wire,
                "checked": bool(check),
            }, fh, indent=2)
    return rows, wire


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes / few reps (CI)")
    ap.add_argument("--check", action="store_true",
                    help="assert fused HBM traffic < oracle per variant")
    ap.add_argument("--out", default="",
                    help="write BENCH_kernels.json-style artifact here")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)
    run(quiet=args.quiet, smoke=args.smoke, check=args.check, out=args.out)


if __name__ == "__main__":
    main()
