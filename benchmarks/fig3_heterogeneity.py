"""Paper Fig. 3: SD of test accuracy for each client's base block
combined with ALL modular blocks, over communication rounds.

Claim under test: by end of training every SD falls below 0.6 accuracy
points — heterogeneous modular blocks converge to interchangeable
behavior because they train on the same broadcast (Z, Y).
Prints CSV: round,sd_A1,sd_B1,sd_C1,sd_D1.
"""

from __future__ import annotations

import argparse

from repro.api import ExperimentSpec, PAPER_RESULTS, run_experiment

LABELS = ["A1-X2", "B1-X2", "C1-X2", "D1-X2"]


def run(rounds: int = 60, force: bool = False, quiet: bool = False,
        participation: str = "full"):
    spec = ExperimentSpec(scheme="ifl", rounds=rounds,
                          eval_every=max(1, rounds // 40),
                          participation=participation)
    out = run_experiment(spec, cache_dir=PAPER_RESULTS, force=force)
    rows = []
    for rec in out.records:
        if "sd_per_base" in rec:
            rows.append((rec["round"], *rec["sd_per_base"]))
    if not quiet:
        print("round," + ",".join(f"sd_{l}" for l in LABELS))
        for r in rows:
            print(f"{r[0]}," + ",".join(f"{x:.3f}" for x in r[1:]))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--participation", default="full",
                    help="client schedule (repro.core.rounds), e.g. k2")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    rows = run(args.rounds, args.force, participation=args.participation)
    final = rows[-1][1:]
    print(f"# final SDs (acc points): {[f'{x:.2f}' for x in final]} "
          f"(paper: all < 0.6 by end of training)")
