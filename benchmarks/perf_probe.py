"""§Perf probe: bisect a dry-run combo's memory/collective terms by
lowering controlled config variants and diffing the accounting.

  PYTHONPATH=src python -m benchmarks.perf_probe --arch jamba-1.5-large-398b \
      --shape train_4k --probe remat_layer ce_chunk no_fsdp tau1

Each probe is one hypothesis about the dominant term; results print as a
compact before/after table (and are saved as --variant runs, so
gen_experiments picks them up).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

# Must set device count before jax init — reuse dryrun's entry guard by
# importing it first.
sys.argv0_hack = None
import repro.launch.dryrun as dr  # noqa: E402  (sets XLA_FLAGS)

PROBES = {
    "remat_layer": {"overrides": {"remat": "layer"}},
    "remat_none": {"overrides": {"remat": "none"}},
    "ce_chunk": {"overrides": {"ce_chunk": 512}},
    "no_fsdp": {"fsdp": False},
    "tau1": {"tau": 1},
    "qblock_256": {"overrides": {"q_block": 256}},
    "qblock_1024": {"overrides": {"q_block": 1024}},
    "mlstm_chunk_128": {"overrides": {"mlstm_chunk": 128}},
    "embed_dshard": {"env": {"REPRO_EMBED_SHARD": "dmodel"}},
    "ce_chunk_embed": {"overrides": {"ce_chunk": 512},
                       "env": {"REPRO_EMBED_SHARD": "dmodel"}},
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--step", default="ifl")
    ap.add_argument("--probe", nargs="+", required=True,
                    choices=list(PROBES))
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    base_path = os.path.join(
        args.out, f"{args.arch}__{args.shape}__16x16__{args.step}.json")
    base = json.load(open(base_path)) if os.path.exists(base_path) else None

    rows = []
    if base:
        rows.append(("baseline", base))
    for name in args.probe:
        spec = PROBES[name]
        for k, v in spec.get("env", {}).items():
            os.environ[k] = v
        try:
            r = dr.run_one(
                args.arch, args.shape, multi_pod=False, step_kind=args.step,
                n_clients=4, tau=spec.get("tau", 2), variant=name,
                out_dir=args.out, force=True,
                overrides=spec.get("overrides"),
                fsdp_override=spec.get("fsdp"),
            )
            rows.append((name, r))
        finally:
            for k in spec.get("env", {}):
                os.environ.pop(k, None)

    print(f"\n{'variant':16s} {'compute_s':>10s} {'memory_s':>10s} "
          f"{'coll_s':>10s} {'temp_GB':>8s} {'coll_MB':>8s}")
    for name, r in rows:
        t = r["roofline"]
        print(f"{name:16s} {t['compute_s']:10.3f} {t['memory_s']:10.3f} "
              f"{t['collective_s']:10.3f} "
              f"{(r['memory']['temp_bytes'] or 0)/1e9:8.1f} "
              f"{r['collectives']['total']/1e6:8.0f}")


if __name__ == "__main__":
    main()
