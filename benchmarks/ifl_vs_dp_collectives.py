"""Framework-scale communication comparison (the paper's Fig. 2 claim
restated for the production mesh): per-round cross-client/pod traffic of
the IFL round step vs the FL-equivalent dense DP step, from the dry-run
collective measurements. Prints CSV:
arch,mesh,ifl_coll_ms,dp_coll_ms,ifl_z_bytes,dp_grad_bytes,ratio.
"""

from __future__ import annotations

import glob
import json
import os

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def _load(tag):
    p = os.path.join(DRYRUN, tag + ".json")
    return json.load(open(p)) if os.path.exists(p) else None


def run(quiet: bool = False):
    rows = []
    for f in sorted(glob.glob(os.path.join(DRYRUN, "*__train_4k__*__ifl.json"))):
        r = json.load(open(f))
        if r.get("variant") not in (None, "baseline"):
            continue
        dp = _load(f"{r['arch']}__train_4k__{r['mesh']}__dp")
        if dp is None:
            continue
        rows.append({
            "arch": r["arch"],
            "mesh": r["mesh"],
            "ifl_coll_ms": r["roofline"]["collective_s"] * 1e3,
            "dp_coll_ms": dp["roofline"]["collective_s"] * 1e3,
            "ifl_coll_bytes": r["collectives"]["total"],
            "dp_coll_bytes": dp["collectives"]["total"],
        })
    if not quiet:
        print("arch,mesh,ifl_coll_ms,dp_coll_ms,ifl_bytes,dp_bytes")
        for r in rows:
            print(f"{r['arch']},{r['mesh']},{r['ifl_coll_ms']:.2f},"
                  f"{r['dp_coll_ms']:.2f},{r['ifl_coll_bytes']:.3e},"
                  f"{r['dp_coll_bytes']:.3e}")
    return rows


if __name__ == "__main__":
    run()
