"""§Roofline report: aggregate results/dryrun/*.json into the per-(arch,
shape, mesh) three-term table. Prints CSV:
arch,shape,mesh,step,variant,compute_ms,memory_ms,collective_ms,dominant,
model_gflops,useful_ratio,mfu_bound,temp_gb_per_chip
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load_all(dirpath: str = DRYRUN) -> List[Dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        try:
            out.append(json.load(open(f)))
        except Exception:
            pass
    return out


def rows(results=None):
    results = results if results is not None else load_all()
    out = []
    for r in results:
        t = r["roofline"]
        temp = (r["memory"].get("temp_bytes") or 0) / 1e9
        out.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "step": r["step"], "variant": r.get("variant", "baseline"),
            "compute_ms": t["compute_s"] * 1e3,
            "memory_ms": t["memory_s"] * 1e3,
            "collective_ms": t["collective_s"] * 1e3,
            "dominant": t["dominant"],
            "model_gflops": t.get("model_flops_total", 0) / 1e9,
            "useful_ratio": t.get("useful_flops_ratio", 0.0),
            "mfu_bound": t.get("mfu_bound", 0.0),
            "temp_gb": temp,
        })
    return out


def run(quiet: bool = False):
    rs = rows()
    if not quiet:
        cols = ["arch", "shape", "mesh", "step", "variant", "compute_ms",
                "memory_ms", "collective_ms", "dominant", "model_gflops",
                "useful_ratio", "mfu_bound", "temp_gb"]
        print(",".join(cols))
        for r in rs:
            print(",".join(
                f"{r[c]:.3f}" if isinstance(r[c], float) else str(r[c])
                for c in cols
            ))
    return rs


if __name__ == "__main__":
    run()
