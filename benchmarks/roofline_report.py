"""§Roofline report: aggregate results/dryrun/*.json into the per-(arch,
shape, mesh) three-term table, plus the wire-path HBM table — per codec,
the bytes the fused encode kernel moves (exact DMA schedule off its
BlockSpecs) vs the unfused jnp oracle, at every arch's d_fusion. Prints
CSV:
arch,shape,mesh,step,variant,compute_ms,memory_ms,collective_ms,dominant,
model_gflops,useful_ratio,mfu_bound,temp_gb_per_chip
codec,d_fusion,fused_hbm_bytes,oracle_hbm_bytes,payload_bytes,savings
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")

WIRE_CODECS = ("int8_row", "int4", "topk", "sketch",
               "ef(int4)", "ef(int8_row)")


def wire_rows(batch: int = 1024) -> List[Dict]:
    """Per-(codec, d_fusion) HBM traffic of the fused wire encode vs
    the jnp oracle across the repro arch configs (analytic, no run
    artifacts needed)."""
    from repro.configs import ARCH_IDS, get_config
    from repro.core.codec import get_codec
    from repro.kernels import wire_fused

    d_fusions = sorted({get_config(a).d_fusion for a in ARCH_IDS})
    out = []
    for name in WIRE_CODECS:
        cd = get_codec(name)
        for d in d_fusions:
            hbm = wire_fused.encode_hbm_bytes(cd, (batch, d))
            if hbm is None:
                continue
            out.append({
                "codec": name, "d_fusion": d,
                "fused_hbm_bytes": hbm["fused_bytes"],
                "oracle_hbm_bytes": hbm["unfused_bytes"],
                "payload_bytes": hbm["payload_bytes"],
                "savings": 1.0 - hbm["fused_bytes"] / hbm["unfused_bytes"],
            })
    return out


def load_all(dirpath: str = DRYRUN) -> List[Dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        try:
            out.append(json.load(open(f)))
        except Exception:
            pass
    return out


def rows(results=None):
    results = results if results is not None else load_all()
    out = []
    for r in results:
        t = r["roofline"]
        temp = (r["memory"].get("temp_bytes") or 0) / 1e9
        out.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "step": r["step"], "variant": r.get("variant", "baseline"),
            "compute_ms": t["compute_s"] * 1e3,
            "memory_ms": t["memory_s"] * 1e3,
            "collective_ms": t["collective_s"] * 1e3,
            "dominant": t["dominant"],
            "model_gflops": t.get("model_flops_total", 0) / 1e9,
            "useful_ratio": t.get("useful_flops_ratio", 0.0),
            "mfu_bound": t.get("mfu_bound", 0.0),
            "temp_gb": temp,
        })
    return out


def run(quiet: bool = False):
    rs = rows()
    if not quiet:
        cols = ["arch", "shape", "mesh", "step", "variant", "compute_ms",
                "memory_ms", "collective_ms", "dominant", "model_gflops",
                "useful_ratio", "mfu_bound", "temp_gb"]
        print(",".join(cols))
        for r in rs:
            print(",".join(
                f"{r[c]:.3f}" if isinstance(r[c], float) else str(r[c])
                for c in cols
            ))
        print()
        wcols = ["codec", "d_fusion", "fused_hbm_bytes",
                 "oracle_hbm_bytes", "payload_bytes", "savings"]
        print(",".join(wcols))
        for r in wire_rows():
            print(",".join(
                f"{r[c]:.3f}" if isinstance(r[c], float) else str(r[c])
                for c in wcols
            ))
    return rs


if __name__ == "__main__":
    run()
