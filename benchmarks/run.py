"""Benchmark suite entry point — one benchmark per paper table/figure,
plus the framework-scale roofline/communication reports.

  PYTHONPATH=src python -m benchmarks.run [--rounds N] [--skip-training]

Every training benchmark routes through the repro.api front door
(ExperimentSpec -> run_experiment); results are cached under
results/paper/ keyed by spec_hash (delete to re-run); roofline sections
read results/dryrun/ (produced by repro.launch.dryrun).
"""

from __future__ import annotations

import argparse
import time


def _section(title):
    print(f"\n### {title}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40,
                    help="communication rounds for the paper experiments "
                         "(paper uses 200; 40 keeps CPU runtime modest)")
    ap.add_argument("--skip-training", action="store_true",
                    help="only run cached/static benchmarks")
    ap.add_argument("--codec", default="fp32",
                    help="wire codec for a compressed-IFL Fig.-2 curve "
                         "(repro.core.codec; fp32 = baseline only; "
                         "ef(<codec>) adds EF21 error feedback, e.g. "
                         "ef(topk0.1), ef(int4))")
    ap.add_argument("--participation", default="full",
                    help="client schedule for the paper experiments "
                         "(repro.core.rounds: full | k<K> | bern<p> | "
                         "straggle(<frac>,<period>), e.g. k2)")
    ap.add_argument("--broadcast", default="full",
                    choices=["full", "delta"],
                    help="downlink policy for the IFL curves "
                         "(repro.core.exchange): full cache per "
                         "participant, or delta mirror-sync — the "
                         "spec-hash cache keys the variant "
                         "automatically")
    ap.add_argument("--mode", default="sync", choices=["sync", "async"],
                    help="round clocking for the IFL Fig.-2 curves "
                         "(repro.core.rounds): sync barrier or async "
                         "arrival-driven ticks")
    ap.add_argument("--trace", default="",
                    help="async arrival trace, e.g. pareto(1.2,0.5)")
    ap.add_argument("--tick", type=float, default=1.0,
                    help="async server fuse period in simulated seconds")
    args = ap.parse_args()
    t0 = time.time()

    _section("table1_comm_costs (paper Table I)")
    from benchmarks import table1_comm_costs

    table1_comm_costs.run()

    _section("kernel_bench (Pallas kernel shapes, CPU ref timing)")
    from benchmarks import kernel_bench

    kernel_bench.run()

    if not args.skip_training:
        _section(f"fig2_comm_efficiency (paper Fig. 2, rounds={args.rounds})")
        from benchmarks import fig2_comm_efficiency

        rows = fig2_comm_efficiency.run(args.rounds, codec=args.codec,
                                        participation=args.participation,
                                        broadcast=args.broadcast,
                                        mode=args.mode, trace=args.trace,
                                        tick=args.tick)
        budget, hl = fig2_comm_efficiency.headline(rows)
        print(f"# at IFL-90% uplink budget {budget:.2f} MB: "
              + ", ".join(f"{k}={v:.3f}" for k, v in hl.items()))
        if args.codec != "fp32":
            last, ratio, dacc = fig2_comm_efficiency.codec_headline(
                rows, args.codec)
            print(f"# ifl+{args.codec} @ round {last}: {ratio:.2f}x lower "
                  f"uplink, acc delta {dacc*100:+.2f} pts")

        _section("fig3_heterogeneity (paper Fig. 3)")
        from benchmarks import fig3_heterogeneity

        r3 = fig3_heterogeneity.run(args.rounds,
                                    participation=args.participation)
        print(f"# final SDs: {[f'{x:.2f}' for x in r3[-1][1:]]}")

        _section("fig4_matrix (paper Fig. 4)")
        from benchmarks import fig4_matrix

        fig4_matrix.run(args.rounds, participation=args.participation)

    _section("roofline_report (dry-run artifacts)")
    from benchmarks import roofline_report

    rr = roofline_report.run()
    print(f"# {len(rr)} dry-run records")

    _section("ifl_vs_dp_collectives (cross-boundary traffic)")
    from benchmarks import ifl_vs_dp_collectives

    ifl_vs_dp_collectives.run()

    print(f"\n# benchmarks done in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
