"""Back-compat shim over the ``repro.api`` front door.

The de-facto experiment API used to live here: a string-dispatch
``run_scheme`` with five copies of make-data -> dirichlet-partition ->
build-Client-list -> loop-rounds boilerplate and a filename-keyed JSON
cache.  All of that is now ``repro.api`` (scheme registry +
``ExperimentSpec`` + ``run_experiment`` with spec-hash caching);
``run_scheme``/``make_clients`` remain as thin delegates so existing
notebooks and scripts keep working.  New code should build an
``ExperimentSpec`` directly — see benchmarks/fig2_comm_efficiency.py.
"""

from __future__ import annotations

from typing import Dict, List

from repro.api import (
    DataBundle,
    DataSpec,
    ExperimentSpec,
    FleetSpec,
    build_fleet,
    run_experiment,
)
from repro.api import PAPER_RESULTS as RESULTS  # noqa: F401  (old name)
from repro.core import Client


def make_clients(tx, ty, *, heterogeneous: bool = True, arch: int = 1,
                 alpha: float = 0.5, seed: int = 0) -> List[Client]:
    """Deprecated — use ``repro.api.build_fleet`` (same construction)."""
    spec = ExperimentSpec(
        seed=seed,
        fleet=FleetSpec(n_clients=4, heterogeneous=heterogeneous,
                        arch=arch, alpha=alpha),
    )
    return build_fleet(spec, DataBundle(tx, ty, None, None))


def run_scheme(scheme: str, rounds: int, *, eval_every: int = 5,
               n_train: int = 20000, n_test: int = 4000,
               tau: int = 10, seed: int = 0, lr: float = 0.05,
               codec: str = "fp32", participation: str = "full",
               max_staleness=None, force: bool = False) -> Dict:
    """Deprecated — ``run_experiment(ExperimentSpec(...))`` is the API.

    NOTE on lr: the paper uses η=0.01 on real KMNIST. On the offline
    synthetic stand-in, 0.01 undertrains badly within 200 rounds (58%
    after 2000 base steps), so the default here is the calibrated 0.05 —
    applied identically to every scheme, preserving the paper's
    *comparative* claims (see EXPERIMENTS.md §Paper calibration note).

    Results are cached under results/paper/ keyed by ``spec_hash()``;
    the old filename-tag caches are still read (never written).
    """
    spec = ExperimentSpec(
        scheme=scheme, rounds=rounds, tau=tau, lr=lr, codec=codec,
        participation=participation, max_staleness=max_staleness,
        eval_every=eval_every, seed=seed,
        data=DataSpec(n_train=n_train, n_test=n_test),
    )
    return run_experiment(spec, cache_dir=RESULTS, force=force).to_dict()
