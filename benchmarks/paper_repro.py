"""Shared harness for the paper's §IV experiments (Figs 2-4, Table I).

Trains IFL / FSL / FL-1 / FL-2 on the synthetic-KMNIST setup (N=4
heterogeneous Table II clients, Dirichlet α=0.5, τ=10, B=32, SGD 0.01)
and caches round-by-round metrics in results/paper/*.json so the figure
benchmarks are reproducible and re-runnable incrementally.
"""

from __future__ import annotations

import functools
import json
import os
from typing import Dict, List

import jax
import numpy as np

from repro.config import IFLConfig
from repro.core import Client, FLTrainer, FSLTrainer, IFLTrainer
from repro.data import dirichlet_partition, make_synth_kmnist
from repro.models.small import (
    client_base_apply,
    client_modular_apply,
    init_client_model,
)

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "paper")


def _apply_fns(cid: int):
    return (
        functools.partial(
            lambda p, x, c: client_base_apply({"base": p}, c, x), c=cid),
        functools.partial(
            lambda p, z, c: client_modular_apply({"modular": p}, c, z), c=cid),
    )


def make_clients(tx, ty, *, heterogeneous: bool = True, arch: int = 1,
                 alpha: float = 0.5, seed: int = 0) -> List[Client]:
    shards = dirichlet_partition(ty, 4, alpha=alpha, seed=seed)
    clients = []
    for k in range(4):
        cid = (k + 1) if heterogeneous else arch
        base_fn, mod_fn = _apply_fns(cid)
        clients.append(Client(
            cid=cid,
            params=init_client_model(jax.random.PRNGKey(100 + k), cid),
            base_apply=base_fn, modular_apply=mod_fn,
            data_x=tx[shards[k]], data_y=ty[shards[k]],
        ))
    return clients


def run_scheme(scheme: str, rounds: int, *, eval_every: int = 5,
               n_train: int = 20000, n_test: int = 4000,
               tau: int = 10, seed: int = 0, lr: float = 0.05,
               codec: str = "fp32", participation: str = "full",
               max_staleness=None, force: bool = False) -> Dict:
    """NOTE on lr: the paper uses η=0.01 on real KMNIST. On the offline
    synthetic stand-in, 0.01 undertrains badly within 200 rounds (58%
    after 2000 base steps), so the default here is the calibrated 0.05 —
    applied identically to every scheme, preserving the paper's
    *comparative* claims (see EXPERIMENTS.md §Paper calibration note).

    ``codec`` selects the fusion-payload wire format (repro.core.codec);
    it only affects the IFL scheme — FL ships parameters and FSL ships
    cut activations+grads, both at their native fp32.

    ``participation`` selects the round engine's client schedule
    (repro.core.rounds: 'full' | 'k<K>' | 'bern<p>' |
    'straggle(<frac>,<period>)') and applies to EVERY scheme — partial
    rounds are a property of the deployment, not of the algorithm. For
    IFL, ``max_staleness`` bounds the server fusion cache."""
    os.makedirs(RESULTS, exist_ok=True)
    tag = f"{scheme}_r{rounds}_n{n_train}_tau{tau}_s{seed}"
    if lr != 0.01:
        tag += f"_lr{lr}"
    if codec != "fp32":
        tag += f"_c{codec}"
    if participation != "full":
        tag += f"_p{participation}"
        if max_staleness is not None:
            tag += f"_st{max_staleness}"
    path = os.path.join(RESULTS, tag + ".json")
    if os.path.exists(path) and not force:
        return json.load(open(path))

    tx, ty, ex, ey = make_synth_kmnist(n_train, n_test)
    cfg = IFLConfig(tau=tau, rounds=rounds, lr_base=lr, lr_modular=lr,
                    codec=codec, participation=participation,
                    max_staleness=max_staleness)
    recs: List[Dict] = []

    if scheme == "ifl":
        tr = IFLTrainer(make_clients(tx, ty, seed=seed), cfg, seed=seed)
        for r in range(rounds):
            m = tr.run_round()
            if r % eval_every == 0 or r == rounds - 1:
                accs = tr.evaluate(ex, ey)
                mat = tr.accuracy_matrix(ex[:2000], ey[:2000])
                recs.append({
                    "round": r,
                    "uplink_mb": tr.ledger.uplink_mb,
                    "total_mb": tr.ledger.total_mb,
                    "acc_mean": float(np.mean(accs)),
                    "accs": accs,
                    "matrix": mat.tolist(),
                    # Fig 3: per-base-block SD across modular compositions.
                    "sd_per_base": np.std(mat * 100, axis=1).tolist(),
                })
    elif scheme == "fsl":
        clients = make_clients(tx, ty, seed=seed)
        server = init_client_model(jax.random.PRNGKey(999), 1)["modular"]
        _, server_apply = _apply_fns(1)
        tr = FSLTrainer(clients, cfg, server, server_apply, seed=seed)
        for r in range(rounds):
            tr.run_round()
            if r % eval_every == 0 or r == rounds - 1:
                accs = tr.evaluate(ex, ey)
                recs.append({
                    "round": r,
                    "uplink_mb": tr.ledger.uplink_mb,
                    "total_mb": tr.ledger.total_mb,
                    "acc_mean": float(np.mean(accs)),
                    "accs": accs,
                })
    elif scheme in ("fl1", "fl2"):
        arch = 1 if scheme == "fl1" else 2
        tr = FLTrainer(
            make_clients(tx, ty, heterogeneous=False, arch=arch, seed=seed),
            cfg, seed=seed,
        )
        for r in range(rounds):
            tr.run_round()
            if r % eval_every == 0 or r == rounds - 1:
                acc = tr.evaluate(ex, ey)
                recs.append({
                    "round": r,
                    "uplink_mb": tr.ledger.uplink_mb,
                    "total_mb": tr.ledger.total_mb,
                    "acc_mean": acc,
                })
    else:
        raise ValueError(scheme)

    out = {"scheme": scheme, "rounds": rounds, "tau": tau, "codec": codec,
           "participation": participation, "records": recs}
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    return out
