"""Serving-plane throughput: device-resident hot loop (ISSUE 10).

Three arms per (lane width W, tenant count T) grid point, all on the
smoke-config composition store (one personalized base block per tenant
sharing one modular block):

  sequential — width-1 engine, requests back to back (no batching).
  horizon=1  — the tick-exact continuous-batching engine of PR 9:
               one host sync per token.
  fused      — the same engine at ``--horizon S`` (default 8): an
               S-tick ``lax.scan`` decode with on-device stop state,
               ONE coalesced ``jax.device_get`` per engine step, and
               bucketed batch prefill at horizon boundaries.

Every arm is timed on a ``fresh_clone`` after a throwaway compile run
(steady-state serving, not jit), and every served continuation is
checked bitwise against its fixed-batch oracle.  Per-token latency is
attributed by the step clock: each Completion stamps every token with
its tick, the harness times each engine step, and a token's latency is
the wall duration of the step (``tick // horizon``) that emitted it.

  PYTHONPATH=src python -m benchmarks.serving_bench --smoke --check

``--check`` exits nonzero unless (a) parity holds on every arm,
(b) every batched arm at >= 8 tenants strictly beats sequential, and
(c) the fused arm beats horizon=1 by >= --min-speedup (1.5x) at the
W=8, T>=8 grid point — the ISSUE-10 acceptance gate.

``--load`` switches to trace-driven open-loop load generation:
``repro.core.rounds.ArrivalTrace`` streams staggered requests into the
engine at each offered rate (``--rates``, requests/tick/tenant) and the
harness reports delivered tok/s, p50/p99 per-token latency, and queue
depth vs offered load — the saturation curve, a nightly artifact.

``--autotune`` runs the serve-plan autotuner (``repro.kernels.ops``)
before the sweep and uses its persisted (horizon, bucket edges).
Results land in ``BENCH_serving.json`` (``--out``).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.api.spmd import smoke_model_config
from repro.core.rounds import parse_trace
from repro.data.synthetic import SyntheticLM
from repro.launch.serve import build_demo_store
from repro.serve import Request, ServeEngine

__all__ = ["run"]


def _requests(args, n_tenants: int, stagger: int):
    stream = SyntheticLM(smoke_model_config().vocab_size, seed=args.seed)
    prompts = stream.sample(n_tenants, args.prompt_len, step=0)
    return [
        Request(rid=i, tenant=f"tenant{i}",
                prompt=[int(t) for t in prompts[i]],
                max_new_tokens=args.gen, arrival=i * stagger)
        for i in range(n_tenants)
    ]


def _timed_run(engine: ServeEngine, requests):
    """Drive the engine step by step, timing each step.  Returns
    (completions, per-step wall seconds, total wall seconds)."""
    for r in requests:
        engine.submit(r)
    step_wall, comps = [], []
    t0 = time.perf_counter()
    while engine.inflight > 0:
        s = time.perf_counter()
        comps.extend(engine.step())
        step_wall.append(time.perf_counter() - s)
    total = time.perf_counter() - t0
    return sorted(comps, key=lambda c: c.rid), step_wall, total


def _token_latencies(comps, step_wall, horizon: int):
    """Map every emitted token to the wall duration of the engine step
    (``tick // horizon``) that emitted it."""
    lat = []
    for c in comps:
        lat.extend(step_wall[t // horizon] for t in c.token_ticks)
    return lat


def _serve(store, requests, width: int, cache_len: int, horizon: int,
           bucket_edges=None):
    """Compile-run then hot-run on a fresh clone; returns the warm
    engine (for oracles) plus the hot run's measurements."""
    warm = ServeEngine(store, width=width, cache_len=cache_len,
                       horizon=horizon, bucket_edges=bucket_edges)
    warm.run(list(requests))
    hot = warm.fresh_clone()
    comps, step_wall, total = _timed_run(hot, list(requests))
    return warm, comps, step_wall, total


def run_arm(args, store, width: int, n_tenants: int, horizon: int,
            seq_baseline, h1_tok_per_s=None):
    cache_len = args.prompt_len + args.gen
    requests = _requests(args, n_tenants, args.stagger)
    warm, comps, step_wall, total = _serve(
        store, requests, width, cache_len, horizon, args.bucket_edges)
    new_tokens = sum(len(c.tokens) for c in comps)
    lat = _token_latencies(comps, step_wall, horizon)
    parity = all(
        comps[i].tokens == warm.oracle(r).tokens
        for i, r in enumerate(requests)
    )
    tok_per_s = new_tokens / max(total, 1e-9)
    arm = {
        "width": width, "tenants": n_tenants, "horizon": horizon,
        "new_tokens": new_tokens, "steps": len(step_wall),
        "wall_s": total,
        "tok_per_s": tok_per_s,
        "p50_token_s": float(np.percentile(lat, 50)),
        "p99_token_s": float(np.percentile(lat, 99)),
        "seq_tok_per_s": seq_baseline["tok_per_s"],
        "speedup_vs_sequential":
            tok_per_s / max(seq_baseline["tok_per_s"], 1e-9),
        "parity_exact": parity,
    }
    if h1_tok_per_s is not None:
        arm["h1_tok_per_s"] = h1_tok_per_s
        arm["speedup_vs_h1"] = tok_per_s / max(h1_tok_per_s, 1e-9)
    extra = (f", x{arm['speedup_vs_h1']:.2f} vs h=1"
             if h1_tok_per_s is not None else "")
    print(f"W={width:>3} T={n_tenants:>3} S={horizon:>2}: "
          f"{tok_per_s:8.1f} tok/s "
          f"(seq {arm['seq_tok_per_s']:8.1f}, "
          f"x{arm['speedup_vs_sequential']:.2f}{extra}), "
          f"p50 {arm['p50_token_s']*1e3:.2f} ms "
          f"p99 {arm['p99_token_s']*1e3:.2f} ms, "
          f"parity {'exact' if parity else 'BROKEN'}")
    return arm


def run_sequential(args, store, n_tenants: int):
    """The per-request baseline: same requests, no batching — a width-1
    engine serves them back to back (arrivals zeroed so it never idles
    waiting on the stagger; it is purely serialized decode)."""
    cache_len = args.prompt_len + args.gen
    requests = [
        Request(rid=r.rid, tenant=r.tenant, prompt=r.prompt,
                max_new_tokens=r.max_new_tokens, arrival=0)
        for r in _requests(args, n_tenants, args.stagger)
    ]
    _, comps, step_wall, total = _serve(store, requests, 1, cache_len, 1)
    new_tokens = sum(len(c.tokens) for c in comps)
    lat = _token_latencies(comps, step_wall, 1)
    base = {
        "tenants": n_tenants, "new_tokens": new_tokens,
        "wall_s": total,
        "tok_per_s": new_tokens / max(total, 1e-9),
        "p50_token_s": float(np.percentile(lat, 50)),
        "p99_token_s": float(np.percentile(lat, 99)),
    }
    print(f"seq T={n_tenants:>3}: {base['tok_per_s']:8.1f} tok/s "
          f"(width-1, back to back)")
    return base


# ------------------------------------------------ trace-driven load


def trace_requests(args, n_tenants: int, rate: float, n_requests: int):
    """Open-loop arrivals: one ArrivalTrace clock per tenant at
    ``rate`` requests/tick, streamed until ``n_requests`` exist.  The
    trace's float times become engine ticks (floor)."""
    trace = parse_trace(args.trace.format(rate=rate))
    rng = np.random.default_rng(args.seed)
    cur = trace.cursor(n_tenants, rng)
    stream = SyntheticLM(smoke_model_config().vocab_size, seed=args.seed)
    prompts = stream.sample(n_tenants, args.prompt_len, step=0)
    events, t_end = [], 0.0
    while len(events) < n_requests:
        t_end += 64.0
        events.extend(cur.pop_until(t_end, rng))
    events = events[:n_requests]
    return [
        Request(rid=i, tenant=f"tenant{slot}",
                prompt=[int(x) for x in prompts[slot]],
                max_new_tokens=args.gen, arrival=int(t))
        for i, (t, slot) in enumerate(events)
    ]


def run_load_point(args, store, width: int, n_tenants: int,
                   horizon: int, rate: float):
    """One offered-load point: stream ``--load-requests`` trace-driven
    arrivals through the engine and measure delivered throughput,
    per-token latency, and queue depth (sampled once per step)."""
    cache_len = args.prompt_len + args.gen
    requests = trace_requests(args, n_tenants, rate, args.load_requests)
    warm = ServeEngine(store, width=width, cache_len=cache_len,
                       horizon=horizon, bucket_edges=args.bucket_edges)
    warm.run(list(requests))          # compile pass
    hot = warm.fresh_clone()
    for r in requests:
        hot.submit(r)
    step_wall, comps, depth = [], [], []
    t0 = time.perf_counter()
    while hot.inflight > 0:
        s = time.perf_counter()
        comps.extend(hot.step())
        step_wall.append(time.perf_counter() - s)
        depth.append(hot.queue_depth())
    total = time.perf_counter() - t0
    comps.sort(key=lambda c: c.rid)
    new_tokens = sum(len(c.tokens) for c in comps)
    lat = _token_latencies(comps, step_wall, horizon)
    wait = [c.admitted_tick - c.arrival for c in comps]
    point = {
        "rate": rate, "width": width, "tenants": n_tenants,
        "horizon": horizon, "requests": len(comps),
        "offered_tok_per_tick": rate * n_tenants * args.gen,
        "new_tokens": new_tokens, "wall_s": total,
        "tok_per_s": new_tokens / max(total, 1e-9),
        "p50_token_s": float(np.percentile(lat, 50)),
        "p99_token_s": float(np.percentile(lat, 99)),
        "mean_queue_depth": float(np.mean(depth)),
        "max_queue_depth": int(np.max(depth)),
        "p50_admit_wait_ticks": float(np.percentile(wait, 50)),
        "p99_admit_wait_ticks": float(np.percentile(wait, 99)),
    }
    print(f"load rate={rate:g}: {point['tok_per_s']:8.1f} tok/s, "
          f"p99 {point['p99_token_s']*1e3:.2f} ms, "
          f"queue mean {point['mean_queue_depth']:.1f} "
          f"max {point['max_queue_depth']}, "
          f"admit wait p99 {point['p99_admit_wait_ticks']:.0f} ticks")
    return point


def run(args):
    cfg = smoke_model_config()
    max_t = max(args.tenants)
    store = build_demo_store(cfg, cfg.name, max_t, seed=args.seed)
    cache_len = args.prompt_len + args.gen

    if args.autotune:
        eng = ServeEngine(store, width=max(args.widths),
                          cache_len=cache_len)
        plan = eng.autotune(_requests(args, min(max_t, 8), args.stagger),
                            force=args.autotune == "force")
        print(f"serve plan: {plan}")
        if plan:
            args.horizon = plan["horizon"]
            args.bucket_edges = plan["bucket_edges"]

    result = {
        "widths": sorted(args.widths), "tenants": sorted(args.tenants),
        "prompt_len": args.prompt_len, "gen": args.gen,
        "stagger": args.stagger, "seed": args.seed, "smoke": args.smoke,
        "horizon": args.horizon, "arch": cfg.name,
    }

    if args.load:
        print(f"trace-driven load sweep: trace {args.trace!r}, rates "
              f"{args.rates}, W={max(args.widths)} T={max_t}, "
              f"horizon {args.horizon}")
        result["load"] = [
            run_load_point(args, store, max(args.widths), max_t,
                           args.horizon, rate)
            for rate in args.rates
        ]
    else:
        print(f"serving sweep: widths {sorted(args.widths)} x tenants "
              f"{sorted(args.tenants)}, prompt {args.prompt_len} + gen "
              f"{args.gen}, stagger {args.stagger} ticks, fused horizon "
              f"{args.horizon}")
        arms, baselines = [], {}
        for t in sorted(args.tenants):
            baselines[t] = run_sequential(args, store, t)
            for w in sorted(args.widths):
                h1 = run_arm(args, store, w, t, 1, baselines[t])
                arms.append(h1)
                arms.append(run_arm(args, store, w, t, args.horizon,
                                    baselines[t], h1["tok_per_s"]))
        result["sequential"] = [baselines[t] for t in sorted(args.tenants)]
        result["arms"] = arms

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1)
        print(f"wrote {args.out}")

    if args.check and not args.load:
        arms = result["arms"]
        failures = []
        if not all(a["parity_exact"] for a in arms):
            failures.append("served output != fixed-batch oracle "
                            "(bitwise contract broken)")
        batched = [a for a in arms
                   if a["tenants"] >= 8 and a["width"] > 1
                   and a["horizon"] > 1]
        if not batched:
            failures.append("no fused batched arm at >= 8 tenants to "
                            "check (widen --tenants/--widths)")
        for a in batched:
            if a["tok_per_s"] <= a["seq_tok_per_s"]:
                failures.append(
                    f"engine does not beat sequential at W={a['width']} "
                    f"T={a['tenants']}: {a['tok_per_s']:.1f} <= "
                    f"{a['seq_tok_per_s']:.1f} tok/s")
        gate = [a for a in arms
                if a["width"] == 8 and a["tenants"] >= 8
                and a.get("speedup_vs_h1") is not None]
        if not gate:
            failures.append("no W=8, T>=8 fused arm for the horizon "
                            "gate (widen --widths/--tenants)")
        for a in gate:
            if a["speedup_vs_h1"] < args.min_speedup:
                failures.append(
                    f"fused horizon {a['horizon']} only "
                    f"x{a['speedup_vs_h1']:.2f} over horizon=1 at "
                    f"W={a['width']} T={a['tenants']} "
                    f"(need >= x{args.min_speedup:g})")
        if failures:
            for msg in failures:
                print(f"CHECK FAILED: {msg}")
            raise SystemExit(1)
        print("all serving acceptance checks passed")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--widths", type=int, nargs="+", default=[2, 4, 8],
                    help="lane widths W to sweep")
    ap.add_argument("--tenants", type=int, nargs="+", default=[8, 16],
                    help="concurrent tenant counts T to sweep")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--stagger", type=int, default=2,
                    help="ticks between consecutive arrivals")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--horizon", type=int, default=8,
                    help="fused decode ticks per engine step")
    ap.add_argument("--bucket-edges", type=int, nargs="+", default=None,
                    help="prompt-length bucket edges for batch prefill")
    ap.add_argument("--min-speedup", type=float, default=1.5,
                    help="--check: required fused/h1 tok/s ratio at "
                         "the W=8, T>=8 grid point")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-long CI mode: one batched width, "
                         "8 tenants, short generations")
    ap.add_argument("--nightly", action="store_true",
                    help="the full W x T grid at longer generations")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless every arm is bitwise "
                         "equal to its oracle, batched arms at >= 8 "
                         "tenants beat sequential, and the fused arm "
                         "beats horizon=1 by >= --min-speedup at W=8")
    ap.add_argument("--load", action="store_true",
                    help="trace-driven open-loop load sweep instead of "
                         "the W x T grid")
    ap.add_argument("--trace", default="poisson({rate})",
                    help="ArrivalTrace spec with a {rate} placeholder "
                         "(per-tenant requests/tick)")
    ap.add_argument("--rates", type=float, nargs="+",
                    default=[0.01, 0.03, 0.1],
                    help="--load: offered per-tenant request rates")
    ap.add_argument("--load-requests", type=int, default=32,
                    help="--load: requests per offered-load point")
    ap.add_argument("--autotune", nargs="?", const=True, default=False,
                    help="run the serve-plan autotuner first (pass "
                         "'force' to retune over a cached plan)")
    ap.add_argument("--out", default="results/bench/BENCH_serving.json")
    args = ap.parse_args()
    if args.smoke:
        # Decode-bound lengths: prefill cost is identical in both arms,
        # so short generations understate the batching win.
        args.widths = [8]
        args.tenants = [8]
        args.gen = 48
    elif args.nightly:
        args.widths = [2, 4, 8]
        args.tenants = [8, 16]
        args.gen = 48
    run(args)


if __name__ == "__main__":
    main()
