"""Serving-plane throughput: continuous batching vs sequential (ISSUE 9).

The claim: at >= 8 concurrent tenants with staggered arrivals, the
lane engine's continuous batching (one vmapped dispatch advances every
occupied slot a token) strictly beats serving the same trace one
request at a time — WITHOUT giving up the correctness contract: every
served continuation stays bitwise equal to its fixed-batch oracle (the
request alone in an empty lane of the same width, same compiled step).

The sweep runs a (lane width W) x (tenant count T) grid over the
smoke-config composition store (one personalized base block per tenant
sharing one modular block).  Each arm:

  throughput — hot tokens/sec of the width-W engine on a staggered
               trace vs the width-1 sequential baseline on the same
               requests back to back.  Both are timed on a
               ``fresh_clone`` after a throwaway compile run, so the
               number is steady-state serving, not jit compiles.
  latency    — p50/p99 per-token wall latency.  The engine's step-count
               clock makes attribution exact: every Completion stamps
               each token with its tick, the harness times each tick,
               and a token's latency is its tick's wall duration.
  parity     — every engine completion bitwise equal to its oracle.

  PYTHONPATH=src python -m benchmarks.serving_bench --smoke --check

``--check`` exits nonzero unless parity holds on every arm and every
batched (W > 1) arm at >= 8 tenants strictly beats sequential.
Results land in ``BENCH_serving.json`` (``--out``), a nightly artifact.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.api.spmd import smoke_model_config
from repro.data.synthetic import SyntheticLM
from repro.launch.serve import build_demo_store
from repro.serve import Request, ServeEngine


def _requests(args, n_tenants: int, stagger: int):
    stream = SyntheticLM(smoke_model_config().vocab_size, seed=args.seed)
    prompts = stream.sample(n_tenants, args.prompt_len, step=0)
    return [
        Request(rid=i, tenant=f"tenant{i}",
                prompt=[int(t) for t in prompts[i]],
                max_new_tokens=args.gen, arrival=i * stagger)
        for i in range(n_tenants)
    ]


def _timed_run(engine: ServeEngine, requests):
    """Drive the engine tick by tick, timing each tick.  Returns
    (completions, per-tick wall seconds, total wall seconds)."""
    for r in requests:
        engine.submit(r)
    tick_wall, comps = [], []
    t0 = time.perf_counter()
    while engine.inflight > 0:
        s = time.perf_counter()
        comps.extend(engine.step())
        tick_wall.append(time.perf_counter() - s)
    total = time.perf_counter() - t0
    return sorted(comps, key=lambda c: c.rid), tick_wall, total


def _token_latencies(comps, tick_wall):
    """Map every emitted token to the wall duration of its tick."""
    lat = []
    for c in comps:
        lat.extend(tick_wall[t] for t in c.token_ticks)
    return lat


def _serve(store, requests, width: int, cache_len: int):
    """Compile-run then hot-run on a fresh clone; returns the warm
    engine (for oracles) plus the hot run's measurements."""
    warm = ServeEngine(store, width=width, cache_len=cache_len)
    warm.run(list(requests))
    hot = warm.fresh_clone()
    comps, tick_wall, total = _timed_run(hot, list(requests))
    return warm, comps, tick_wall, total


def run_arm(args, store, width: int, n_tenants: int, seq_baseline):
    cache_len = args.prompt_len + args.gen
    requests = _requests(args, n_tenants, args.stagger)
    warm, comps, tick_wall, total = _serve(store, requests, width,
                                           cache_len)
    new_tokens = sum(len(c.tokens) for c in comps)
    lat = _token_latencies(comps, tick_wall)
    parity = all(
        comps[i].tokens == warm.oracle(r).tokens
        for i, r in enumerate(requests)
    )
    arm = {
        "width": width, "tenants": n_tenants,
        "new_tokens": new_tokens, "ticks": len(tick_wall),
        "wall_s": total,
        "tok_per_s": new_tokens / max(total, 1e-9),
        "p50_token_s": float(np.percentile(lat, 50)),
        "p99_token_s": float(np.percentile(lat, 99)),
        "seq_tok_per_s": seq_baseline["tok_per_s"],
        "speedup_vs_sequential":
            (new_tokens / max(total, 1e-9)) /
            max(seq_baseline["tok_per_s"], 1e-9),
        "parity_exact": parity,
    }
    print(f"W={width:>3} T={n_tenants:>3}: "
          f"{arm['tok_per_s']:8.1f} tok/s "
          f"(seq {arm['seq_tok_per_s']:8.1f}, "
          f"x{arm['speedup_vs_sequential']:.2f}), "
          f"p50 {arm['p50_token_s']*1e3:.2f} ms "
          f"p99 {arm['p99_token_s']*1e3:.2f} ms, "
          f"parity {'exact' if parity else 'BROKEN'}")
    return arm


def run_sequential(args, store, n_tenants: int):
    """The per-request baseline: same requests, no batching — a width-1
    engine serves them back to back (arrivals zeroed so it never idles
    waiting on the stagger; it is purely serialized decode)."""
    cache_len = args.prompt_len + args.gen
    requests = [
        Request(rid=r.rid, tenant=r.tenant, prompt=r.prompt,
                max_new_tokens=r.max_new_tokens, arrival=0)
        for r in _requests(args, n_tenants, args.stagger)
    ]
    _, comps, tick_wall, total = _serve(store, requests, 1, cache_len)
    new_tokens = sum(len(c.tokens) for c in comps)
    lat = _token_latencies(comps, tick_wall)
    base = {
        "tenants": n_tenants, "new_tokens": new_tokens,
        "wall_s": total,
        "tok_per_s": new_tokens / max(total, 1e-9),
        "p50_token_s": float(np.percentile(lat, 50)),
        "p99_token_s": float(np.percentile(lat, 99)),
    }
    print(f"seq T={n_tenants:>3}: {base['tok_per_s']:8.1f} tok/s "
          f"(width-1, back to back)")
    return base


def run(args):
    cfg = smoke_model_config()
    max_t = max(args.tenants)
    print(f"serving sweep: widths {sorted(args.widths)} x tenants "
          f"{sorted(args.tenants)}, prompt {args.prompt_len} + gen "
          f"{args.gen}, stagger {args.stagger} ticks")
    store = build_demo_store(cfg, cfg.name, max_t, seed=args.seed)

    arms, baselines = [], {}
    for t in sorted(args.tenants):
        baselines[t] = run_sequential(args, store, t)
        for w in sorted(args.widths):
            arms.append(run_arm(args, store, w, t, baselines[t]))

    result = {
        "widths": sorted(args.widths), "tenants": sorted(args.tenants),
        "prompt_len": args.prompt_len, "gen": args.gen,
        "stagger": args.stagger, "seed": args.seed, "smoke": args.smoke,
        "arch": cfg.name,
        "sequential": [baselines[t] for t in sorted(args.tenants)],
        "arms": arms,
    }
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1)
        print(f"wrote {args.out}")

    if args.check:
        failures = []
        if not all(a["parity_exact"] for a in arms):
            failures.append("served output != fixed-batch oracle "
                            "(bitwise contract broken)")
        checked = [a for a in arms
                   if a["tenants"] >= 8 and a["width"] > 1]
        if not checked:
            failures.append("no batched arm at >= 8 tenants to check "
                            "(widen --tenants/--widths)")
        for a in checked:
            if a["tok_per_s"] <= a["seq_tok_per_s"]:
                failures.append(
                    f"engine does not beat sequential at W={a['width']} "
                    f"T={a['tenants']}: {a['tok_per_s']:.1f} <= "
                    f"{a['seq_tok_per_s']:.1f} tok/s")
        if failures:
            for msg in failures:
                print(f"CHECK FAILED: {msg}")
            raise SystemExit(1)
        print("all serving acceptance checks passed")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--widths", type=int, nargs="+", default=[2, 4, 8],
                    help="lane widths W to sweep")
    ap.add_argument("--tenants", type=int, nargs="+", default=[8, 16],
                    help="concurrent tenant counts T to sweep")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--stagger", type=int, default=2,
                    help="ticks between consecutive arrivals")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-long CI mode: one batched width, "
                         "8 tenants, short generations")
    ap.add_argument("--nightly", action="store_true",
                    help="the full W x T grid at longer generations")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless every arm is bitwise "
                         "equal to its oracle and every batched arm "
                         "at >= 8 tenants beats sequential tok/s")
    ap.add_argument("--out", default="results/bench/BENCH_serving.json")
    args = ap.parse_args()
    if args.smoke:
        # Decode-bound lengths: prefill cost is identical in both arms,
        # so short generations understate the batching win.
        args.widths = [8]
        args.tenants = [8]
        args.gen = 48
    elif args.nightly:
        args.widths = [2, 4, 8]
        args.tenants = [8, 16]
        args.gen = 48
    run(args)


if __name__ == "__main__":
    main()
