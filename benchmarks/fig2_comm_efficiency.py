"""Paper Fig. 2: test accuracy vs cumulative uplink communication (MB)
for IFL (proposed), FSL, FL-1, FL-2.

Claim under test: IFL reaches ~90% at ~8.5 MB uplink while FSL is far
lower at the same budget and FL variants cost orders of magnitude more.
Prints CSV: scheme,round,uplink_mb,accuracy.
"""

from __future__ import annotations

import argparse

from benchmarks.paper_repro import run_scheme


def run(rounds: int = 60, force: bool = False, quiet: bool = False):
    rows = []
    for scheme in ["ifl", "fsl", "fl1", "fl2"]:
        out = run_scheme(scheme, rounds, eval_every=max(1, rounds // 40), force=force)
        for rec in out["records"]:
            rows.append((scheme, rec["round"], rec["uplink_mb"],
                         rec["acc_mean"]))
    if not quiet:
        print("scheme,round,uplink_mb,accuracy")
        for r in rows:
            print(f"{r[0]},{r[1]},{r[2]:.3f},{r[3]:.4f}")
    return rows


def headline(rows):
    """Accuracy of each scheme at IFL's 90%-crossing uplink budget."""
    ifl = [(mb, a) for s, _, mb, a in rows if s == "ifl"]
    budget = next((mb for mb, a in ifl if a >= 0.90), ifl[-1][0])
    out = {}
    for scheme in ["ifl", "fsl", "fl1", "fl2"]:
        pts = [(mb, a) for s, _, mb, a in rows if s == scheme]
        under = [a for mb, a in pts if mb <= budget]
        out[scheme] = max(under) if under else pts[0][1]
    return budget, out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    rows = run(args.rounds, args.force)
    budget, hl = headline(rows)
    print(f"# at IFL-90%% uplink budget {budget:.2f} MB: {hl}")
