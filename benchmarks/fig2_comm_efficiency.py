"""Paper Fig. 2: test accuracy vs cumulative uplink communication (MB)
for IFL (proposed), FSL, FL-1, FL-2 — plus the compressed-IFL curves.

Claim under test: IFL reaches ~90% at ~8.5 MB uplink while FSL is far
lower at the same budget and FL variants cost orders of magnitude more.
``--codec`` adds a compressed-IFL run (fusion payloads encoded with the
named wire codec from repro.core.codec — bf16 | fp16 | int8 |
int8_channel | int8_row | int4 | topk | topk<r> | sketch<r> |
ef(<codec>)) next to the fp32 baseline, e.g. ``--codec int8`` cuts
cumulative uplink ~4x at matched accuracy, and ``--codec "ef(int4)"``
adds EF21 error feedback on top of ~8x compression — same wire bytes as
int4, accuracy pulled back toward fp32.

``--participation`` runs EVERY scheme under a partial-participation
schedule (repro.core.rounds: k2 | bern0.5 | straggle(0.2,3) | ...) —
the HeteroFL regime where only K of N clients show up per round. IFL's
staleness-bounded fusion cache keeps modular updates training on up to
N pairs while the ledger only pays for the K fresh uploads.
``--broadcast delta`` switches the IFL schemes' downlink to the
delta-shipping policy (repro.core.exchange) — identical accuracy curve
(same decoded cache state by construction), so the figure's
total-MB variant shows the downlink saving directly.
``--mode async`` swaps the IFL curves onto the event-driven engine
(repro.core.rounds.AsyncRoundEngine): vendors upload on ``--trace``
arrival clocks, the server fuses every ``--tick`` simulated seconds.
FL/FSL keep the barrier — they have no fusion cache to fuse from, which
is the comparison the figure then makes.

``--smoke`` shrinks data/rounds to a seconds-long CI check of the full
axis grid. Prints CSV: scheme,round,uplink_mb,accuracy.
"""

from __future__ import annotations

import argparse
import json
import os

from repro.api import DataSpec, ExperimentSpec, PAPER_RESULTS, run_experiment


def run(rounds: int = 60, force: bool = False, quiet: bool = False,
        codec: str = "fp32", participation: str = "full",
        smoke: bool = False, broadcast: str = "full", mode: str = "sync",
        trace: str = "", tick: float = 1.0):
    if mode == "async" and not trace:
        trace = "pareto(1.2,0.5)"
    rows = []
    schemes = ["ifl", "fsl", "fl1", "fl2"]
    if codec != "fp32":
        schemes.insert(1, f"ifl+{codec}")
    base_spec = ExperimentSpec(
        rounds=rounds, eval_every=max(1, rounds // 40),
        participation=participation,
        **(dict(tau=2, data=DataSpec(n_train=800, n_test=200))
           if smoke else {}),
    )
    for scheme in schemes:
        base, _, cdc = scheme.partition("+")
        # The broadcast/mode axes only exist for fusion downlinks /
        # the fusion cache; keeping FL/FSL at the sync-full defaults
        # keeps their spec hashes (and cached curves) untouched.
        ifl = base.startswith("ifl")
        spec = base_spec.replace(
            scheme=base, codec=cdc or "fp32",
            broadcast=broadcast if ifl else "full",
            mode=mode if ifl else "sync",
            trace=trace if (ifl and mode == "async") else "",
            tick=tick if (ifl and mode == "async") else 1.0,
            # Async draws participants from the trace, not a schedule.
            participation=("full" if (ifl and mode == "async")
                           else participation),
        )
        out = run_experiment(spec, cache_dir=PAPER_RESULTS, force=force)
        for rec in out.records:
            rows.append((scheme, rec["round"], rec["uplink_mb"],
                         rec["acc_mean"]))
    if not quiet:
        print("scheme,round,uplink_mb,accuracy")
        for r in rows:
            print(f"{r[0]},{r[1]},{r[2]:.3f},{r[3]:.4f}")
    return rows


def headline(rows):
    """Accuracy of each scheme at IFL's 90%-crossing uplink budget."""
    ifl = [(mb, a) for s, _, mb, a in rows if s == "ifl"]
    budget = next((mb for mb, a in ifl if a >= 0.90), ifl[-1][0])
    out = {}
    for scheme in sorted({s for s, *_ in rows}):
        pts = [(mb, a) for s, _, mb, a in rows if s == scheme]
        under = [a for mb, a in pts if mb <= budget]
        out[scheme] = max(under) if under else pts[0][1]
    return budget, out


def codec_headline(rows, codec: str):
    """Compressed-IFL vs fp32 IFL at equal rounds: uplink ratio + final
    accuracy delta (the acceptance numbers for the codec axis)."""
    fp32 = {r: (mb, a) for s, r, mb, a in rows if s == "ifl"}
    comp = {r: (mb, a) for s, r, mb, a in rows if s == f"ifl+{codec}"}
    last = max(set(fp32) & set(comp))
    ratio = fp32[last][0] / max(comp[last][0], 1e-12)
    dacc = comp[last][1] - fp32[last][1]
    return last, ratio, dacc


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--codec", default="fp32",
                    help="wire codec for the compressed-IFL curve "
                         "(fp32 = baseline only; ef(<codec>) enables "
                         "error feedback, e.g. ef(topk0.1), ef(int4))")
    ap.add_argument("--participation", default="full",
                    help="client schedule for every scheme "
                         "(repro.core.rounds: full | k<K> | bern<p> | "
                         "straggle(<frac>,<period>), e.g. k2)")
    ap.add_argument("--broadcast", default="full",
                    choices=["full", "delta"],
                    help="downlink policy for the IFL curves "
                         "(repro.core.exchange): full cache per "
                         "participant, or delta mirror-sync")
    ap.add_argument("--mode", default="sync", choices=["sync", "async"],
                    help="round clocking for the IFL curves "
                         "(repro.core.rounds): sync barrier, or async "
                         "arrival-driven server ticks")
    ap.add_argument("--trace", default="",
                    help="async arrival trace, e.g. pareto(1.2,0.5) "
                         "(default under --mode async)")
    ap.add_argument("--tick", type=float, default=1.0,
                    help="async server fuse period in simulated seconds")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-long CI mode: tiny data, few rounds")
    ap.add_argument("--out-json", default="",
                    help="also write the rows + headline to this JSON "
                         "(the nightly workflow's BENCH_* artifact)")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        args.rounds = min(args.rounds, 4)
        args.force = True  # never serve a smoke run from the full cache
    rows = run(args.rounds, args.force, codec=args.codec,
               participation=args.participation, smoke=args.smoke,
               broadcast=args.broadcast, mode=args.mode, trace=args.trace,
               tick=args.tick)
    budget, hl = headline(rows)
    print(f"# at IFL-90% uplink budget {budget:.2f} MB: {hl}")
    if args.codec != "fp32":
        last, ratio, dacc = codec_headline(rows, args.codec)
        print(f"# ifl+{args.codec} @ round {last}: {ratio:.2f}x lower "
              f"cumulative uplink than fp32 IFL, "
              f"final acc delta {dacc*100:+.2f} pts")
    if args.out_json:
        os.makedirs(os.path.dirname(args.out_json) or ".", exist_ok=True)
        with open(args.out_json, "w") as f:
            json.dump({
                "axes": {"codec": args.codec, "broadcast": args.broadcast,
                         "mode": args.mode, "trace": args.trace,
                         "tick": args.tick,
                         "participation": args.participation,
                         "rounds": args.rounds, "smoke": args.smoke},
                "rows": [list(r) for r in rows],
                "ifl90_budget_mb": budget,
                "acc_at_budget": hl,
            }, f, indent=1)
        print(f"# wrote {args.out_json}")
