"""Generate EXPERIMENTS.md from results/dryrun + results/paper artifacts.

The §Perf narrative lives in benchmarks/perf_log.md (hand-authored,
hypothesis→change→measure cycles) and is embedded verbatim, so
regenerating tables never loses analysis.

  PYTHONPATH=src python -m benchmarks.gen_experiments
"""

from __future__ import annotations

import glob
import json
import os

import numpy as np

ROOT = os.path.join(os.path.dirname(__file__), "..")
DRYRUN = os.path.join(ROOT, "results", "dryrun")
PAPER = os.path.join(ROOT, "results", "paper")

MOVE_HINT = {
    # one sentence per dominant term on what would move it down
    "compute": "compute-bound: raise arithmetic efficiency (larger MXU "
               "tiles, fewer recomputed group bodies, lower remat factor).",
    "memory": "memory-bound: cut HBM round-trips — fuse epilogues, "
              "chunk losses/scans so intermediates stay in VMEM, bf16 "
              "residuals.",
    "collective": "collective-bound: reduce cross-chip bytes — drop FSDP "
                  "gathers where params fit, keep z model-sharded, batch "
                  "grad reduces once per τ loop.",
}


def _load(pattern):
    out = []
    for f in sorted(glob.glob(os.path.join(DRYRUN, pattern))):
        try:
            out.append(json.load(open(f)))
        except Exception:
            pass
    return out


def _paper_runs(rounds: int = 200):
    """Cached runs per scheme, selected by JSON *content* (the files are
    spec-hash-named now — and legacy tag-named fixtures embed the same
    scheme/rounds keys, so both generations are picked up)."""
    runs = {}
    for f in sorted(glob.glob(os.path.join(PAPER, "*.json"))):
        try:
            d = json.load(open(f))
        except Exception:
            continue
        if d.get("rounds") != rounds or not d.get("records"):
            continue
        # Paper baselines only: codec/participation variants (fig2's
        # compressed-IFL curves, k2 runs) are separate claims and must
        # not stand in for a scheme's headline numbers.
        if d.get("codec", "fp32") != "fp32":
            continue
        if d.get("participation", "full") != "full":
            continue
        # Delta-downlink runs have identical accuracy but different
        # total-MB trajectories; only the full-broadcast baseline may
        # stand in for a scheme's headline numbers. (The broadcast axis
        # is elided from the spec dict at its 'full' default.)
        if d.get("spec", {}).get("broadcast", "full") != "full":
            continue
        s = d.get("scheme")
        spec = d.get("spec", {})
        calibrated = (spec.get("lr", 0.05) != 0.01 if spec
                      else "lr" in os.path.basename(f))
        # prefer calibrated-lr runs when both exist for a scheme
        if s not in runs or (calibrated and not runs[s][0]):
            runs[s] = (calibrated, d["records"])
    return {s: recs for s, (_, recs) in runs.items()}


def paper_section(lines):
    lines.append("## §Paper — validation against the paper's own claims\n")
    runs = _paper_runs()
    if not runs:
        lines.append("_paper experiments not yet cached — run "
                     "`python -m benchmarks.run --rounds 200`_\n")
        return
    lines.append(
        "Setup: N=4 Table II clients, synthetic-KMNIST (offline stand-in, "
        "DESIGN.md §2), Dirichlet α=0.5, τ=10, B=32, SGD, 200 rounds.\n\n"
        "**Calibration note.** The paper trains real KMNIST at η=0.01. On "
        "the synthetic stand-in η=0.01 undertrains (58% mean acc after "
        "200 rounds — measured, cached as `*_r200_n20000_tau10_s0.json`), "
        "so all schemes run at the calibrated η=0.05 — identical across "
        "schemes, preserving every comparative claim under test.\n")
    # Fig 2 claim.
    ifl = runs["ifl"]
    cross = next((r for r in ifl if r["acc_mean"] >= 0.90), None)
    lines.append("**Fig. 2 (communication efficiency).** Paper: IFL hits "
                 "90% at ~8.5 MB uplink; FSL ~64% at that budget; FL "
                 "orders of magnitude more expensive.")
    def acc_at(rs, mb):
        under = [r["acc_mean"] for r in rs if r["uplink_mb"] <= mb]
        return max(under) if under else float("nan")

    if cross:
        budget = cross["uplink_mb"]
        lines.append(
            f"Measured: IFL reaches 90% at **{budget:.1f} MB** uplink "
            f"(round {cross['round']}); at that same budget FSL = "
            f"**{acc_at(runs.get('fsl', []), budget):.1%}**, FL-1 = "
            f"**{acc_at(runs.get('fl1', []), budget):.1%}**, FL-2 = "
            f"**{acc_at(runs.get('fl2', []), budget):.1%}**."
        )
    else:
        budget = ifl[-1]["uplink_mb"]
        lines.append(
            f"Measured (stand-in dataset, see calibration note — the "
            f"synthetic generator's global low-frequency structure favors "
            f"the MLP clients and slows the conv clients, so the absolute "
            f"90% level is not reached; the *comparative* ordering is): "
            f"at IFL's full 200-round uplink budget ({budget:.1f} MB), "
            f"IFL = **{ifl[-1]['acc_mean']:.1%}** vs FSL = "
            f"**{acc_at(runs.get('fsl', []), budget):.1%}** at the same "
            f"bytes; FL-1/FL-2 reach "
            f"**{acc_at(runs.get('fl1', []), 1e12):.1%}** / "
            f"**{acc_at(runs.get('fl2', []), 1e12):.1%}** only at "
            f"**{runs.get('fl1', [{}])[-1].get('uplink_mb', 0):.0f} / "
            f"{runs.get('fl2', [{}])[-1].get('uplink_mb', 0):.0f} MB** — "
            f"{runs.get('fl1', [{}])[-1].get('uplink_mb', 1) / max(budget, 1e-9):.0f}"
            f"× IFL's budget."
        )
    final = {s: runs[s][-1] for s in runs}
    lines.append("\n| scheme | final acc | uplink MB @200 rounds |")
    lines.append("|---|---|---|")
    for s in ["ifl", "fsl", "fl1", "fl2"]:
        if s in final:
            r = final[s]
            lines.append(f"| {s.upper()} | {r['acc_mean']:.3f} | "
                         f"{r['uplink_mb']:.1f} |")
    # Fig 3.
    sds = ifl[-1].get("sd_per_base")
    if sds:
        first_sds = next((r["sd_per_base"] for r in ifl
                          if r.get("sd_per_base")), sds)
        lines.append(
            "\n**Fig. 3 (heterogeneity robustness).** Paper: SD of "
            "accuracy across modular-block pairings < 0.6 points by end "
            "of training. Measured SD trajectory (points, per base "
            "block): start "
            + "/".join(f"{x:.1f}" for x in first_sds) + " → final "
            + "/".join(f"{x:.1f}" for x in sds)
            + ". Direction reproduces (modular blocks converge toward "
            "interchangeability as they train on the shared broadcast); "
            "the absolute <0.6-pt level is not reached at the stand-in "
            "dataset's 70% accuracy regime — SD scales with distance "
            "from convergence."
        )
    # Fig 4.
    mat = np.array(ifl[-1]["matrix"])
    local = np.diag(mat)
    n_ok = int(((mat - local[:, None]) >= -0.005).sum() - 4)
    lines.append(
        "\n**Fig. 4 (composability).** Accuracy matrix base×modular "
        "(rows = base block of A1..D1):\n"
    )
    lines.append("| base \\ mod | A2 | B2 | C2 | D2 |")
    lines.append("|---|---|---|---|---|")
    for i, n in enumerate("ABCD"):
        lines.append(f"| {n}1 | " + " | ".join(
            f"{mat[i, j]:.3f}" for j in range(4)) + " |")
    lines.append(
        f"\nLocal mean {local.mean():.3f}, cross mean "
        f"{mat[~np.eye(4, dtype=bool)].mean():.3f}; {n_ok}/12 cross "
        "pairings within 0.5 pt of (or above) the local pairing — the "
        "paper's interchangeability claim."
    )
    lines.append("\n**Table I** — quantified per-round costs: see "
                 "`python -m benchmarks.table1_comm_costs`.\n")


def dryrun_section(lines):
    lines.append("\n## §Dry-run — lower+compile across (arch × shape × mesh)\n")
    lines.append("Every supported combination lowers AND compiles on the "
                 "single-pod (16×16 = 256 chips) and multi-pod (2×16×16 = "
                 "512 chips) meshes. long_500k is skipped for pure "
                 "full-attention archs (DESIGN.md §4). Collective bytes "
                 "are per-chip link traffic from trip-count-corrected "
                 "HLO accounting (see §Method note).\n")
    rows = [r for r in _load("*.json")
            if r.get("variant") in (None, "baseline") and r["step"] != "dp"]
    lines.append("| arch | shape | mesh | step | compile s | peak GB/chip "
                 "| args GB/chip | coll MB/chip | whiles |")
    lines.append("|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        mem = r["memory"]
        peak = mem.get("peak_bytes")
        peak_s = f"{peak/1e9:.1f}" if peak else "-"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['step']} | "
            f"{r['timing']['compile_s']:.0f} | {peak_s} | "
            f"{(mem['argument_bytes'] or 0)/1e9:.1f} | "
            f"{r['collectives']['total']/1e6:.0f} | "
            f"{r.get('n_while', '-')} |"
        )
    over = [r for r in rows if (r["memory"].get("peak_bytes") or 0) > 16e9
            and r["mesh"] == "16x16"]
    if over:
        lines.append(
            "\n⚠ rows with peak > 16 GB HBM (v5e): "
            + ", ".join(f"{r['arch']}/{r['shape']}" for r in over)
            + " — addressed in §Perf."
        )
    lines.append(
        "\n**Method note.** XLA's `cost_analysis()` counts `while` (scan) "
        "bodies once — verified: a scanned 8-step matmul reports 1/8 of "
        "unrolled FLOPs. All FLOPs/bytes/collective numbers here are "
        "re-derived from `compiled.as_text()` with while-trip-count "
        "multipliers (`repro/roofline/hlo_accounting.py`); raw XLA "
        "numbers are kept in each JSON as `cost_raw_xla`.\n"
    )


def roofline_section(lines):
    lines.append("\n## §Roofline — single-pod (256 × v5e: 197 TF bf16, "
                 "819 GB/s HBM, 50 GB/s ICI)\n")
    rows = [r for r in _load("*__16x16__*.json")
            if r.get("variant") in (None, "baseline") and r["step"] != "dp"]
    lines.append("| arch | shape | compute s | memory s | collective s | "
                 "dominant | model TFLOPs | useful ratio | MFU@bound |")
    lines.append("|---|---|---|---|---|---|---|---|---|")
    agg = {}
    for r in rows:
        t = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3f} | "
            f"{t['memory_s']:.3f} | {t['collective_s']:.3f} | "
            f"**{t['dominant']}** | "
            f"{t.get('model_flops_total', 0)/1e12:.1f} | "
            f"{t.get('useful_flops_ratio', 0):.2f} | "
            f"{t.get('mfu_bound', 0):.3f} |"
        )
        agg.setdefault(t["dominant"], []).append((r["arch"], r["shape"]))
    lines.append("\nPer-row bottleneck guidance:")
    for dom, hint in MOVE_HINT.items():
        n = len(agg.get(dom, []))
        lines.append(f"- **{dom}** ({n} rows): {hint}")
    lines.append(
        "\nIFL-specific note: `useful ratio` counts the N× modular-block "
        "redundancy (every client trains on all clients' z) as useful "
        "work, per the algorithm's definition; the compute the paper's "
        "scheme *saves* is cross-boundary communication, not FLOPs — "
        "see the IFL-vs-DP table."
    )
    lines.append(
        "\n**Memory-term caveat.** The dry-run necessarily compiles with "
        "XLA:CPU backend fusion choices, which *materialize* attention "
        "score tensors that XLA:TPU (or our Pallas flash kernel) would "
        "keep in VMEM — so memory terms at long sequence lengths are "
        "upper bounds dominated by score traffic. The Pallas kernels in "
        "`repro/kernels/` are the TPU-side answer; they validate in "
        "interpret mode but cannot lower through the CPU dry-run."
    )


def ifl_vs_dp_section(lines):
    lines.append("\n\n## §IFL vs FL-equivalent (dense DP) — cross-boundary "
                 "traffic at train_4k\n")
    rows = []
    for r in _load("*__train_4k__16x16__dp.json"):
        ifl = os.path.join(DRYRUN,
                           f"{r['arch']}__train_4k__16x16__ifl.json")
        if os.path.exists(ifl):
            i = json.load(open(ifl))
            rows.append((r["arch"], i, r))
    if rows:
        lines.append("| arch | IFL coll MB/chip/round | DP coll MB/chip/step "
                     "| IFL z-exchange MB (all-gather) |")
        lines.append("|---|---|---|---|")
        for arch, i, d in rows:
            lines.append(
                f"| {arch} | {i['collectives']['total']/1e6:.0f} | "
                f"{d['collectives']['total']/1e6:.0f} | "
                f"{i['collectives']['all-gather']/1e6:.0f} |"
            )


def perf_section(lines):
    p = os.path.join(os.path.dirname(__file__), "perf_log.md")
    lines.append("\n## §Perf — hypothesis → change → measure log\n")
    if os.path.exists(p):
        lines.append(open(p).read())
    else:
        lines.append("_perf_log.md not written yet_")


def main():
    lines = ["# EXPERIMENTS",
             "",
             "Reproduction of *Communication-Efficient and Interoperable "
             "Distributed Learning* (IFL) + framework-scale dry-run/"
             "roofline/perf results. All numbers regenerate via the "
             "commands noted per section.",
             ""]
    paper_section(lines)
    dryrun_section(lines)
    roofline_section(lines)
    ifl_vs_dp_section(lines)
    perf_section(lines)
    out = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(out, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"wrote {out} ({len(lines)} blocks)")


if __name__ == "__main__":
    main()
