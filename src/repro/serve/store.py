"""The composition store — the serving plane's deployable artifact.

Maps tenant -> (base arch, personalized base-block params [, fusion
cache state]) plus ONE shared modular block per arch, mirroring the
paper's deployment story: clients personalize f_b, the standardized
fusion interface lets any base compose with the shared f_m, and the
server's trained ``FusionCache`` is what ships.

On disk the artifact is a ``repro.checkpoint`` .npz + JSON manifest
(same format as trainer checkpoints): the manifest's ``extra`` carries
the tenant -> arch routing table and per-arch config provenance, so
``CompositionStore.load`` reconstructs the tree from the '/'-joined npz
keys alone — no shape template needed, which is what lets a serving box
load an artifact it did not train.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_extra, save_checkpoint
from repro.config import ModelConfig

__all__ = ["TenantEntry", "CompositionStore"]

_ARTIFACT_VERSION = 1


def _resolve_cfg(arch: str, *, reduced: bool,
                 d_fusion: Optional[int]) -> ModelConfig:
    """Arch name -> ModelConfig, by the same rules the trainers use."""
    if arch == "spmd-smoke":
        from repro.api.spmd import smoke_model_config

        cfg = smoke_model_config()
    else:
        from repro.configs import get_config

        cfg = get_config(arch)
        if reduced:
            cfg = cfg.reduced()
    if d_fusion is not None and cfg.d_fusion != int(d_fusion):
        cfg = cfg.replace(d_fusion=int(d_fusion)).validate()
    return cfg


def _unflatten(flat: Dict[str, np.ndarray]) -> Dict[str, Any]:
    """Inverse of ``repro.checkpoint``'s '/'-joined flattening for
    dict-only trees (LM param/cache trees are all-dict)."""
    root: Dict[str, Any] = {}
    for key in sorted(flat):
        parts = key.split("/")
        d = root
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = jnp.asarray(flat[key])
    return root


@dataclass
class TenantEntry:
    """One tenant's routing row: which arch pair, and its base block."""

    tenant: str
    arch: str          # base-block architecture (lane routing key, 1/2)
    modular_arch: str  # shared modular block's arch (routing key, 2/2)
    base: Any          # personalized base-half params
    fusion: Optional[Any] = None  # last fusion-cache state {z_hat, y[, payload]}


class CompositionStore:
    """Tenant -> composed-model registry behind the serving engine.

    Archs are registered once (name + config); tenants attach a
    personalized base block under a registered arch; each arch carries
    ONE shared modular block reused by every tenant routed to it.
    Cross-arch composition (base of one family, modular of another) is
    just ``modular_arch != arch`` — validated to agree on d_fusion.
    """

    def __init__(self):
        self._cfgs: Dict[str, ModelConfig] = {}
        self._meta: Dict[str, Dict[str, Any]] = {}  # arch -> provenance
        self._modular: Dict[str, Any] = {}
        self._tenants: Dict[str, TenantEntry] = {}

    # ----------------------------------------------------------- archs

    def add_arch(self, arch, *, reduced: bool = True,
                 d_fusion: Optional[int] = None) -> str:
        """Register an architecture by name (resolvable on load) or by
        explicit ``ModelConfig`` (in-memory only — ``save`` refuses,
        except for the 'spmd-smoke' config which resolves by name)."""
        if isinstance(arch, ModelConfig):
            cfg, name = arch, arch.name
            custom = name != "spmd-smoke"
            meta = {"reduced": bool(reduced), "d_fusion": cfg.d_fusion,
                    "custom": custom}
        else:
            name = str(arch)
            cfg = _resolve_cfg(name, reduced=reduced, d_fusion=d_fusion)
            meta = {"reduced": bool(reduced), "d_fusion": cfg.d_fusion,
                    "custom": False}
        if name in self._cfgs and self._cfgs[name] != cfg:
            raise ValueError(f"arch {name!r} already registered with a "
                             "different config")
        self._cfgs[name] = cfg
        self._meta[name] = meta
        return name

    def set_modular(self, arch: str, params: Any) -> None:
        """Attach the shared modular block for ``arch`` (one instance,
        reused by every tenant whose ``modular_arch`` is this arch)."""
        if arch not in self._cfgs:
            raise KeyError(f"unregistered arch {arch!r}")
        self._modular[arch] = params

    def cfg(self, arch: str) -> ModelConfig:
        return self._cfgs[arch]

    def modular(self, arch: str) -> Any:
        return self._modular[arch]

    # --------------------------------------------------------- tenants

    def add_tenant(self, tenant: str, arch: str, base: Any, *,
                   modular_arch: Optional[str] = None,
                   fusion: Optional[Any] = None) -> TenantEntry:
        if "/" in tenant:
            raise ValueError(
                f"tenant id {tenant!r} must not contain '/' (it is a "
                "checkpoint key path segment)"
            )
        mod_arch = modular_arch or arch
        for a in (arch, mod_arch):
            if a not in self._cfgs:
                raise KeyError(f"unregistered arch {a!r}")
        if mod_arch not in self._modular:
            raise KeyError(f"arch {mod_arch!r} has no shared modular block")
        bc, mc = self._cfgs[arch], self._cfgs[mod_arch]
        if bc.d_fusion != mc.d_fusion:
            raise ValueError(
                f"tenant {tenant!r}: base {arch!r} d_fusion "
                f"{bc.d_fusion} != modular {mod_arch!r} d_fusion "
                f"{mc.d_fusion}"
            )
        entry = TenantEntry(tenant=tenant, arch=arch,
                            modular_arch=mod_arch, base=base,
                            fusion=fusion)
        self._tenants[tenant] = entry
        return entry

    def tenants(self) -> List[str]:
        return sorted(self._tenants)

    def entry(self, tenant: str) -> TenantEntry:
        if tenant not in self._tenants:
            raise KeyError(f"unknown tenant {tenant!r}")
        return self._tenants[tenant]

    def __contains__(self, tenant: str) -> bool:
        return tenant in self._tenants

    def __len__(self) -> int:
        return len(self._tenants)

    # --------------------------------------------------- save / load

    def save(self, path: str) -> None:
        """Write the artifact (.npz + manifest).  Every registered arch
        must be name-resolvable on a fresh box."""
        for name, meta in self._meta.items():
            if meta.get("custom"):
                raise ValueError(
                    f"arch {name!r} was registered from an explicit "
                    "ModelConfig and cannot be serialized — register "
                    "a named arch for saveable artifacts"
                )
        tree: Dict[str, Any] = {
            "tenants": {
                t: ({"base": e.base, "fusion": e.fusion}
                    if e.fusion is not None else {"base": e.base})
                for t, e in self._tenants.items()
            },
            "modular": dict(self._modular),
        }
        extra = {
            "serve_artifact": _ARTIFACT_VERSION,
            "archs": {n: {"reduced": m["reduced"],
                          "d_fusion": m["d_fusion"]}
                      for n, m in self._meta.items()},
            "tenants": {t: {"arch": e.arch,
                            "modular_arch": e.modular_arch}
                        for t, e in self._tenants.items()},
        }
        save_checkpoint(path, tree, extra=extra)

    @classmethod
    def load(cls, path: str) -> "CompositionStore":
        extra = load_extra(path)
        if "serve_artifact" not in extra:
            raise ValueError(f"{path} is not a serving artifact (no "
                             "'serve_artifact' manifest key)")
        npz = np.load(path if path.endswith(".npz") else path + ".npz")
        tree = _unflatten(dict(npz))
        store = cls()
        for name, m in extra["archs"].items():
            store.add_arch(name, reduced=bool(m["reduced"]),
                           d_fusion=m["d_fusion"])
        for arch, params in tree.get("modular", {}).items():
            store.set_modular(arch, params)
        for tenant, m in extra["tenants"].items():
            sub = tree["tenants"][tenant]
            store.add_tenant(tenant, m["arch"], sub["base"],
                             modular_arch=m["modular_arch"],
                             fusion=sub.get("fusion"))
        return store

    # -------------------------------------------------- trainer export

    @classmethod
    def from_spmd_trainer(cls, trainer, *, tenants=None,
                          modular_slot: int = 0) -> "CompositionStore":
        """Export a trained ``SPMDIFLTrainer`` run as a serving artifact.

        One tenant per client slot (default ids ``client<k>``); the
        shared modular block is ``modular_slot``'s trained modular half.
        The plane's carried payload cache — the trained ``FusionCache``
        — rides along per tenant as decoded ``{z_hat, y}`` state (valid
        slots only), so the artifact is the composition store the ISSUE
        names: tenant -> base params + fusion state.

        Population runs export the *materialized working set* (the
        slots the cohorts actually trained), paging each through the
        host-side ``PopulationStore``.
        """
        cfg = trainer.model_cfg
        # Registry key: the spec's resolvable arch id (the trainer's
        # cfg.name carries reduced()'s '-smoke' suffix, which get_config
        # cannot resolve back); '' means the smoke config.
        arch_name = trainer.spec.model or cfg.name
        reduced = bool(trainer.spec.model)  # named archs load reduced()
        store = cls()
        store.add_arch(arch_name, reduced=reduced, d_fusion=cfg.d_fusion)

        if trainer._population:
            slots = trainer.store.slots()
            if not slots:
                raise ValueError("population run has no materialized "
                                 "slots to export — train a round first")
            get_params = lambda k: trainer.store.get(k)["params"]
        else:
            slots = list(range(trainer.n_clients))
            get_params = lambda k: jax.tree.map(
                lambda a: a[k], trainer.params)
        if tenants is None:
            tenants = [f"client{k}" for k in slots]
        if len(tenants) != len(slots):
            raise ValueError(f"{len(tenants)} tenant ids for "
                             f"{len(slots)} exported slots")

        mslot = modular_slot if modular_slot in slots else slots[0]
        store.set_modular(arch_name, get_params(mslot)["modular"])

        # Fusion state: the carried payload cache, decoded slot-wise
        # (legacy partial-participation runs carry it; population runs
        # rebuild it fresh each round, so there is nothing durable).
        fusion_by_slot: Dict[int, Any] = {}
        if not trainer._population and getattr(trainer, "cache", None) is not None:
            z_shape = (trainer.spec.batch_size, trainer.seq, cfg.d_fusion)
            ctree = trainer.exchange.cache_tree(trainer.cache, z_shape)
            ages = np.asarray(ctree["age"])
            for k in slots:
                if ages[k] <= trainer.exchange.age_bound:
                    fusion_by_slot[k] = {
                        "z_hat": ctree["z_hat"][k],
                        "y": ctree["y"][k],
                    }
        for tid, k in zip(tenants, slots):
            store.add_tenant(tid, arch_name, get_params(k)["base"],
                             fusion=fusion_by_slot.get(k))
        return store
