"""The serving plane: multi-tenant composed-model inference with
continuous batching (see ``engine.ServeEngine``)."""

from repro.serve.engine import ServeEngine
from repro.serve.lanes import Lane
from repro.serve.store import CompositionStore, TenantEntry
from repro.serve.types import Completion, Request

__all__ = [
    "CompositionStore",
    "Completion",
    "Lane",
    "Request",
    "ServeEngine",
    "TenantEntry",
]
