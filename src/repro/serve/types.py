"""Request/response types of the serving plane.

A request names a *tenant* — the unit of personalization: the engine
routes it to that tenant's trained base block composed with the shared
modular block of the tenant's (base_arch, modular_arch) pair, and
continuously batches it with other in-flight requests of the same pair.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence

__all__ = ["Request", "Completion"]


@dataclass(frozen=True)
class Request:
    """One generation request against a tenant's composed model.

    ``arrival`` is the engine tick (the step-count clock) at which the
    request becomes admissible — the simulation analogue of a wall-clock
    arrival time, so staggered traffic is deterministic and testable.
    ``eos_id`` < 0 disables EOS eviction (run to ``max_new_tokens``).

    Sampling: ``temperature == 0`` (the default) is greedy argmax —
    bitwise the historical decode path.  ``temperature > 0`` draws from
    the softmax at that temperature, restricted to the ``top_k`` largest
    logits when ``top_k > 0`` (0 = full vocab).  ``seed`` plus ``rid``
    derive the request's PRNG key, so a sampled request is exactly
    reproducible — and bitwise equal between the continuously-batched
    engine and its single-request oracle (the key chain is per-slot).
    """

    rid: int
    tenant: str
    prompt: Sequence[int]
    max_new_tokens: int = 16
    arrival: int = 0
    eos_id: int = -1
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0

    def __post_init__(self):
        if len(self.prompt) < 1:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(
                f"request {self.rid}: max_new_tokens must be >= 1"
            )
        if self.temperature < 0:
            raise ValueError(
                f"request {self.rid}: temperature must be >= 0"
            )
        if self.top_k < 0:
            raise ValueError(f"request {self.rid}: top_k must be >= 0")


@dataclass
class Completion:
    """A finished request: the generated continuation + timing marks.

    ``tokens`` are the NEW tokens only (no prompt echo).  All *_tick
    fields are engine step-clock stamps; the benchmark harness converts
    them to wall time by timing each tick.
    """

    rid: int
    tenant: str
    tokens: List[int] = field(default_factory=list)
    finish_reason: str = "length"  # 'length' | 'eos'
    prompt_len: int = 0
    arrival: int = 0
    admitted_tick: int = -1
    finished_tick: int = -1
    # Tick stamp of every emitted token (first one = prefill tick).
    token_ticks: List[int] = field(default_factory=list)
