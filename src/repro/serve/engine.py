"""`ServeEngine` — multi-tenant composed-model inference with
continuous batching and a device-resident hot loop.

Each request names a tenant; the engine routes it to that tenant's
personalized base block + the shared modular block (from the
``CompositionStore``) and batches it into the per-arch lane of its
(base_arch, modular_arch) pair.  One engine *step* advances every lane
``horizon`` ticks in a single fused device launch (``lax.scan`` over
the per-slot decode step — see ``lanes.py``), fetches every lane's
emitted-token window plus the previous boundary's admission outputs in
ONE coalesced ``jax.device_get``, evicts finished requests, and admits
waiting arrivals into freed slots with bucketed batch prefill.  The
host therefore syncs once per ``horizon`` ticks, not once per token.

Admissions land only at horizon boundaries (the last tick of a step),
so ``horizon=1`` reproduces the historical tick-exact engine: decode
one tick, evict, admit at that same tick.  The one intentional
relaxation at any horizon is admission *discovery* granularity — a
request whose prefill token already completes it (EOS on first token,
or ``max_new_tokens == 1``) is detected on device at admission but
reported at the next step's coalesced transfer, holding its slot for
one step.  Token streams are unaffected (lane row-independence).

The step-count clock is the engine's time base: request arrivals,
admissions, and per-token stamps are all measured in ticks, making
staggered traffic deterministic (and the benchmark's wall-clock
attribution exact — time the steps, map tokens to steps).

Correctness contract: ``oracle(request)`` replays the request alone in
an otherwise-empty lane of the SAME width with the SAME compiled
horizon/admission programs — by the lane's row-independence, a
continuously-batched served output is bitwise equal to its oracle.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple

import jax

from repro.serve.lanes import Lane
from repro.serve.store import CompositionStore
from repro.serve.types import Completion, Request

__all__ = ["ServeEngine"]


class ServeEngine:
    """Continuous-batching server over a ``CompositionStore``.

    ``horizon`` is the fused-decode span S (ticks per engine step);
    ``"auto"`` reads the persisted serve-plan autotuner cache
    (``repro.kernels.ops.serve_plan``) for this (device, arch pairs,
    width, cache_len) and falls back to 8.  ``bucket_edges`` overrides
    the padded prompt-length buckets of batch admission (default:
    powers of two up to ``cache_len``).
    """

    def __init__(self, store: CompositionStore, *, width: int = 8,
                 cache_len: int = 128, horizon: Any = 1,
                 bucket_edges: Optional[Sequence[int]] = None):
        if width < 1:
            raise ValueError(f"lane width must be >= 1, got {width}")
        self.store = store
        self.width = int(width)
        self.cache_len = int(cache_len)
        self.bucket_edges = list(bucket_edges) if bucket_edges else None
        if horizon == "auto":
            from repro.kernels import ops as _ops
            plan = _ops.serve_plan(self.plan_key())
            horizon = plan.get("horizon", 8)
            if self.bucket_edges is None and plan.get("bucket_edges"):
                self.bucket_edges = [int(e) for e in plan["bucket_edges"]]
        self.horizon = int(horizon)
        if self.horizon < 1:
            raise ValueError(f"horizon must be >= 1, got {horizon}")
        self._lanes: Dict[Tuple[str, str], Lane] = {}
        # Pending queues carry (request, base params) so admission does
        # not repeat the store.entry() lookup submit already paid.
        self._pending: Dict[Tuple[str, str], Deque[Tuple[Request, Any]]] \
            = {}
        self._tick = 0
        self._inflight = 0

    # ---------------------------------------------------------- lanes

    def plan_key(self) -> str:
        """Autotuner cache key: every (base_arch, modular_arch) pair the
        store can serve, plus lane geometry."""
        pairs = sorted({(e.arch, e.modular_arch)
                        for e in (self.store.entry(t)
                                  for t in self.store.tenants())})
        tag = ",".join(f"{a}+{m}" for a, m in pairs)
        return f"{tag}|W{self.width}|L{self.cache_len}"

    def _lane_key(self, request: Request) -> Tuple[str, str]:
        e = self.store.entry(request.tenant)
        return (e.arch, e.modular_arch)

    def _lane(self, key: Tuple[str, str]) -> Lane:
        if key not in self._lanes:
            arch, mod_arch = key
            some_tenant = next(
                e for e in (self.store.entry(t) for t in
                            self.store.tenants())
                if e.arch == arch and e.modular_arch == mod_arch
            )
            self._lanes[key] = Lane(
                self.store.cfg(arch), self.store.cfg(mod_arch),
                self.store.modular(mod_arch), some_tenant.base,
                width=self.width, cache_len=self.cache_len,
                bucket_edges=self.bucket_edges,
            )
        return self._lanes[key]

    # --------------------------------------------------------- submit

    def submit(self, request: Request) -> None:
        e = self.store.entry(request.tenant)  # the ONE tenant lookup
        bc = self.store.cfg(e.arch)
        if len(request.prompt) + request.max_new_tokens > self.cache_len:
            raise ValueError(
                f"request {request.rid}: prompt({len(request.prompt)}) "
                f"+ max_new({request.max_new_tokens}) exceeds cache_len "
                f"{self.cache_len}"
            )
        if max(request.prompt) >= bc.vocab_size or min(request.prompt) < 0:
            raise ValueError(
                f"request {request.rid}: prompt token out of vocab "
                f"range [0, {bc.vocab_size})"
            )
        key = (e.arch, e.modular_arch)
        q = self._pending.setdefault(key, deque())
        q.append((request, e.base))
        # FIFO by (arrival, submission order): keep the deque sorted —
        # admission must not let a late-arriving request jump the queue.
        if len(q) > 1 and request.arrival < q[-2][0].arrival:
            self._pending[key] = deque(
                sorted(q, key=lambda rb: rb[0].arrival))
        self._inflight += 1

    # ----------------------------------------------------------- step

    @property
    def tick(self) -> int:
        return self._tick

    @property
    def inflight(self) -> int:
        return self._inflight

    def queue_depth(self) -> int:
        """Requests submitted but not yet admitted to a slot."""
        return sum(len(q) for q in self._pending.values())

    def step(self) -> List[Completion]:
        """One engine step == ``horizon`` ticks: launch the fused decode
        on every occupied lane, fetch all lanes' windows + pending
        admission outputs in ONE ``jax.device_get``, evict finished
        requests, then admit waiting arrivals at the boundary tick.
        Returns the completions finished this step."""
        now, S = self._tick, self.horizon
        for lane in self._lanes.values():
            if lane.n_active > 0:
                lane.launch_horizon(S, now)
        # The single host sync of the step — every lane's (S, W) token
        # window and every pending admission's (first, done) arrays come
        # back in one coalesced transfer.
        payload = {k: lane.pending_transfer()
                   for k, lane in self._lanes.items()}
        host = jax.device_get(payload)
        done: List[Completion] = []
        for k, lane in self._lanes.items():
            done.extend(lane.absorb(host[k]))
        # Boundary admission: bucketed batch prefill of everything
        # admissible into the slots now free, one launch per bucket.
        boundary = now + S - 1
        for key, q in self._pending.items():
            lane = self._lane(key)
            free = len(lane.free_slots())
            admits: List[Tuple[Request, Any]] = []
            while q and q[0][0].arrival <= boundary and len(admits) < free:
                admits.append(q.popleft())
            lane.admit_batch(admits, boundary)
        self._inflight -= len(done)
        self._tick += S
        return done

    # ------------------------------------------------------------ run

    def step_budget(self) -> int:
        """An exact upper bound on the engine steps needed to drain the
        current queues + in-flight slots (no further submissions).

        Worst case every request of a lane serializes through one slot:
        admission at one boundary, first token landing the next step,
        ``ceil((m-1)/S)`` fused windows for the remaining tokens, and
        the freed slot re-admitting at that same step's boundary —
        ``ceil((m-1)/S) + 2`` steps per request covers the chain with
        slack.  Arrivals gate admission for at most
        ``ceil(max_arrival/S) + 1`` leading steps.  Lanes drain in the
        same global steps, so the busiest lane dominates.
        """
        S = self.horizon
        per_lane: Dict[Tuple[str, str], int] = {}
        max_arr = 0
        for key, q in self._pending.items():
            for req, _ in q:
                per_lane[key] = per_lane.get(key, 0) + \
                    (max(req.max_new_tokens - 1, 0) + S - 1) // S + 2
                max_arr = max(max_arr, req.arrival)
        for key, lane in self._lanes.items():
            for s in lane.slots:
                if s is None:
                    continue
                owed = (s.request.max_new_tokens if s.awaiting_first
                        else max(s.remaining, 0))
                per_lane[key] = per_lane.get(key, 0) + \
                    (max(owed - 1, 0) + S - 1) // S + 2
        busiest = max(per_lane.values()) if per_lane else 0
        return (max_arr + S - 1) // S + 1 + busiest

    def run(self, requests: List[Request],
            max_ticks: Optional[int] = None) -> List[Completion]:
        """Drive submitted + given requests to completion; returns all
        completions sorted by rid.  The default budget is the exact
        :meth:`step_budget` bound — exceeding it is a scheduler bug,
        not a workload property."""
        for r in requests:
            self.submit(r)
        budget = (max_ticks + self.horizon - 1) // self.horizon \
            if max_ticks is not None else self.step_budget()
        out: List[Completion] = []
        while self._inflight > 0:
            if budget <= 0:
                raise RuntimeError("engine did not drain within the "
                                   "step budget — scheduler stall?")
            out.extend(self.step())
            budget -= 1
        return sorted(out, key=lambda c: c.rid)

    def fresh_clone(self) -> "ServeEngine":
        """An empty engine over the same store whose lanes share this
        engine's compiled horizon/admission programs — the warm twin
        the benchmark times after a throwaway compile run."""
        clone = ServeEngine(self.store, width=self.width,
                            cache_len=self.cache_len,
                            horizon=self.horizon,
                            bucket_edges=self.bucket_edges)
        clone._lanes = {k: lane.fresh_clone()
                        for k, lane in self._lanes.items()}
        return clone

    # --------------------------------------------------------- oracle

    def oracle(self, request: Request) -> Completion:
        """The fixed-batch correctness twin: serve ``request`` ALONE in
        an empty lane of the same width, same compiled programs, same
        horizon.  The engine's continuously-batched output must be
        bitwise equal."""
        key = self._lane_key(request)
        lane = self._lane(key).fresh_clone()
        base = self.store.entry(request.tenant).base
        req0 = dataclasses.replace(request, arrival=0)
        S = self.horizon
        lane.admit_batch([(req0, base)], S - 1)
        t0 = S
        budget = (max(request.max_new_tokens - 1, 0) + S - 1) // S + 3
        for _ in range(budget):
            if lane.n_active > 0:
                lane.launch_horizon(S, t0)
            finished = lane.absorb(jax.device_get(
                lane.pending_transfer()))
            if finished:
                return finished[0]
            t0 += S
        raise RuntimeError("oracle did not finish")

    # ------------------------------------------------------- autotune

    def autotune(self, requests: List[Request], *,
                 horizons: Sequence[int] = (1, 2, 4, 8, 16),
                 edge_sets: Optional[Sequence[Sequence[int]]] = None,
                 force: bool = False) -> Dict[str, Any]:
        """Wall-clock autotune of (horizon, bucket edges) for this
        store/width/cache_len on this device, persisted to the JSON
        serve-plan cache (``repro.kernels.ops``).  Times a warm
        fresh-clone run of ``requests`` per candidate."""
        import time as _time

        from repro.kernels import ops as _ops
        from repro.serve.lanes import default_bucket_edges

        if edge_sets is None:
            edge_sets = (default_bucket_edges(self.cache_len),
                         [self.cache_len])

        def timer(h: int, edges: Sequence[int]) -> float:
            eng = ServeEngine(self.store, width=self.width,
                              cache_len=self.cache_len, horizon=h,
                              bucket_edges=list(edges))
            eng.run(list(requests))      # compile pass
            warm = eng.fresh_clone()
            t0 = _time.perf_counter()
            warm.run(list(requests))
            return _time.perf_counter() - t0

        return _ops.autotune_serve_plan(
            self.plan_key(), timer, horizons=horizons,
            edge_sets=edge_sets, force=force)
