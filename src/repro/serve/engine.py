"""`ServeEngine` — multi-tenant composed-model inference with
continuous batching.

Each request names a tenant; the engine routes it to that tenant's
personalized base block + the shared modular block (from the
``CompositionStore``) and batches it into the per-arch lane of its
(base_arch, modular_arch) pair.  There is no global barrier between
requests: each tick, every lane decodes its occupied slots by one
token, evicts finished ones, and admits waiting requests into freed
slots (admit-on-slot-free).  Prefill is ONE jitted scan call per
request (``composed_prefill``), not O(prompt) dispatches.

The step-count clock is the engine's time base: request arrivals,
admissions, and per-token stamps are all measured in ticks, making
staggered traffic deterministic (and the benchmark's wall-clock
attribution exact — time the ticks, map tokens to ticks).

Correctness contract: ``oracle(request)`` replays the request alone in
an otherwise-empty lane of the SAME width with the SAME compiled step
functions — by the lane's row-independence (see ``lanes.py``), a
continuously-batched served output is bitwise equal to its oracle.
"""

from __future__ import annotations

from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

from repro.serve.lanes import Lane
from repro.serve.store import CompositionStore
from repro.serve.types import Completion, Request

__all__ = ["ServeEngine"]


class ServeEngine:
    """Continuous-batching server over a ``CompositionStore``."""

    def __init__(self, store: CompositionStore, *, width: int = 8,
                 cache_len: int = 128):
        if width < 1:
            raise ValueError(f"lane width must be >= 1, got {width}")
        self.store = store
        self.width = int(width)
        self.cache_len = int(cache_len)
        self._lanes: Dict[Tuple[str, str], Lane] = {}
        self._pending: Dict[Tuple[str, str], Deque[Request]] = {}
        self._tick = 0
        self._inflight = 0

    # ---------------------------------------------------------- lanes

    def _lane_key(self, request: Request) -> Tuple[str, str]:
        e = self.store.entry(request.tenant)
        return (e.arch, e.modular_arch)

    def _lane(self, key: Tuple[str, str]) -> Lane:
        if key not in self._lanes:
            arch, mod_arch = key
            some_tenant = next(
                e for e in (self.store.entry(t) for t in
                            self.store.tenants())
                if e.arch == arch and e.modular_arch == mod_arch
            )
            self._lanes[key] = Lane(
                self.store.cfg(arch), self.store.cfg(mod_arch),
                self.store.modular(mod_arch), some_tenant.base,
                width=self.width, cache_len=self.cache_len,
            )
        return self._lanes[key]

    # --------------------------------------------------------- submit

    def submit(self, request: Request) -> None:
        e = self.store.entry(request.tenant)  # validates the tenant
        bc = self.store.cfg(e.arch)
        if len(request.prompt) + request.max_new_tokens > self.cache_len:
            raise ValueError(
                f"request {request.rid}: prompt({len(request.prompt)}) "
                f"+ max_new({request.max_new_tokens}) exceeds cache_len "
                f"{self.cache_len}"
            )
        if max(request.prompt) >= bc.vocab_size or min(request.prompt) < 0:
            raise ValueError(
                f"request {request.rid}: prompt token out of vocab "
                f"range [0, {bc.vocab_size})"
            )
        key = self._lane_key(request)
        q = self._pending.setdefault(key, deque())
        q.append(request)
        # FIFO by (arrival, submission order): keep the deque sorted —
        # admission must not let a late-arriving request jump the queue.
        if len(q) > 1 and request.arrival < q[-2].arrival:
            self._pending[key] = deque(
                sorted(q, key=lambda r: r.arrival))
        self._inflight += 1

    # ----------------------------------------------------------- tick

    @property
    def tick(self) -> int:
        return self._tick

    @property
    def inflight(self) -> int:
        return self._inflight

    def step(self) -> List[Completion]:
        """One engine tick: decode every lane's occupied slots, evict
        finished requests, then admit waiting arrivals into freed slots.
        Returns the completions finished this tick."""
        now = self._tick
        done: List[Completion] = []
        for lane in self._lanes.values():
            done.extend(lane.decode_tick(now))
        for key, q in self._pending.items():
            lane = self._lane(key)
            while q and q[0].arrival <= now and lane.free_slot() is not None:
                req = q.popleft()
                comp = lane.admit(
                    req, self.store.entry(req.tenant).base, now)
                if comp is not None:  # finished on the prefill token
                    done.append(comp)
        self._inflight -= len(done)
        self._tick += 1
        return done

    def run(self, requests: List[Request],
            max_ticks: Optional[int] = None) -> List[Completion]:
        """Drive submitted + given requests to completion; returns all
        completions sorted by rid."""
        for r in requests:
            self.submit(r)
        budget = max_ticks if max_ticks is not None else (
            10 * sum(r.max_new_tokens for r in requests)
            + max((r.arrival for r in requests), default=0) + 10
        )
        out: List[Completion] = []
        while self._inflight > 0:
            if budget <= 0:
                raise RuntimeError("engine did not drain within the "
                                   "tick budget — scheduler stall?")
            out.extend(self.step())
            budget -= 1
        return sorted(out, key=lambda c: c.rid)

    def fresh_clone(self) -> "ServeEngine":
        """An empty engine over the same store whose lanes share this
        engine's compiled step/prefill/insert programs — the warm twin
        the benchmark times after a throwaway compile run."""
        clone = ServeEngine(self.store, width=self.width,
                            cache_len=self.cache_len)
        clone._lanes = {k: lane.fresh_clone()
                        for k, lane in self._lanes.items()}
        return clone

    # --------------------------------------------------------- oracle

    def oracle(self, request: Request) -> Completion:
        """The fixed-batch correctness twin: serve ``request`` ALONE in
        an empty lane of the same width, same compiled programs.  The
        engine's continuously-batched output must be bitwise equal."""
        key = self._lane_key(request)
        lane = self._lane(key).fresh_clone()
        base = self.store.entry(request.tenant).base
        comp = lane.admit(request, base, tick=0)
        t = 0
        while comp is None:
            t += 1
            finished = lane.decode_tick(t)
            if finished:
                comp = finished[0]
            if t > 10 * request.max_new_tokens + 10:
                raise RuntimeError("oracle did not finish")
        return comp
