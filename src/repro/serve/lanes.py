"""Per-architecture batch lanes — the continuous-batching substrate.

A lane is a fixed-width W vector of independent decode slots for ONE
(base_arch, modular_arch) pair: stacked per-slot base params (each slot
a different tenant), ONE shared modular block (vmap ``in_axes=None`` —
instantiated once, reused by every slot), stacked per-slot B=1 decode
caches, and per-slot decode positions.  One lane tick advances every
occupied slot by one token in a single jitted dispatch; admission
writes a prefilled request into a free slot with ``.at[i].set`` (pure
data movement); eviction is host-side bookkeeping only.

Bitwise contract (the oracle leans on it, and test_serve verifies it
end-to-end): at fixed width W, a slot's decoded tokens are a function
of that slot's (params, cache, token, pos) ONLY — ``vmap`` maps each
slot through the same per-slot program, so other slots' contents,
admissions and evictions cannot perturb it.  An engine-served request
is therefore bitwise equal to the same request served alone in an
otherwise-empty width-W lane (``ServeEngine.oracle``).  Empty slots
carry zero params + a fresh cache, which decodes to finite garbage
(fresh attention caches are fully-invalid -> zero context) that nobody
reads.

Argmax sampling happens INSIDE the jitted step, so engine and oracle
share tie-breaking exactly.
"""

from __future__ import annotations

from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models.transformer import (
    composed_decode_step,
    composed_prefill,
    init_composed_cache,
)
from repro.serve.types import Completion, Request

__all__ = ["Lane", "SlotState"]


class SlotState:
    """Host bookkeeping for one occupied slot."""

    def __init__(self, request: Request, completion: Completion):
        self.request = request
        self.completion = completion
        self.remaining = request.max_new_tokens - len(completion.tokens)


class Lane:
    """Width-W continuous batch of one (base_cfg, mod_cfg) pair."""

    def __init__(self, base_cfg: ModelConfig, mod_cfg: ModelConfig,
                 modular_params: Any, base_template: Any, *,
                 width: int, cache_len: int):
        if base_cfg.d_fusion != mod_cfg.d_fusion:
            raise ValueError("lane arch pair disagrees on d_fusion")
        self.base_cfg = base_cfg
        self.mod_cfg = mod_cfg
        self.width = int(width)
        self.cache_len = int(cache_len)
        self.modular = modular_params
        # Device state: zeros-params filler for empty slots; every cache
        # leaf gets a uniform leading W axis ((W,) + B=1-leaf shape), so
        # vmap(in_axes=0) hands each slot an ordinary B=1 cache.
        zero_base = jax.tree.map(jnp.zeros_like, base_template)
        self.base_stack = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (self.width,) + a.shape),
            zero_base,
        )
        cache1 = init_composed_cache(base_cfg, mod_cfg, 1, self.cache_len)
        self.cache = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None],
                                       (self.width,) + a.shape).copy(),
            cache1,
        )
        self.tok = jnp.zeros((self.width,), jnp.int32)
        self.pos = jnp.zeros((self.width,), jnp.int32)
        self.slots: List[Optional[SlotState]] = [None] * self.width
        self._build()

    # ------------------------------------------------------ jitted fns

    def _build(self):
        base_cfg, mod_cfg, cache_len = \
            self.base_cfg, self.mod_cfg, self.cache_len

        def one_slot(base, mod, cache, tok, pos):
            logits, cache = composed_decode_step(
                base, base_cfg, mod, mod_cfg, cache,
                tok.reshape(1, 1), pos,
            )
            nxt = jnp.argmax(logits[0, -1], axis=-1).astype(jnp.int32)
            return nxt, cache, pos + 1

        self._step = jax.jit(jax.vmap(one_slot, in_axes=(0, None, 0, 0, 0)))

        def prefill(base, mod, tokens):
            cache = init_composed_cache(base_cfg, mod_cfg, 1, cache_len)
            logits, cache = composed_prefill(
                base, base_cfg, mod, mod_cfg, cache, tokens,
            )
            first = jnp.argmax(logits[0, -1], axis=-1).astype(jnp.int32)
            return first, cache

        self._prefill = jax.jit(prefill)

        def insert(i, stack, cache, tok, pos, base_one, cache_one,
                   first_tok, start_pos):
            stack = jax.tree.map(lambda s, o: s.at[i].set(o),
                                 stack, base_one)
            cache = jax.tree.map(lambda s, o: s.at[i].set(o),
                                 cache, cache_one)
            return (stack, cache, tok.at[i].set(first_tok),
                    pos.at[i].set(start_pos))

        self._insert = jax.jit(insert)

    def fresh_clone(self) -> "Lane":
        """An empty lane sharing this lane's compiled step/prefill/
        insert programs — the oracle's fixed-batch twin."""
        clone = object.__new__(Lane)
        clone.base_cfg, clone.mod_cfg = self.base_cfg, self.mod_cfg
        clone.width, clone.cache_len = self.width, self.cache_len
        clone.modular = self.modular
        clone.base_stack = jax.tree.map(jnp.zeros_like, self.base_stack)
        cache1 = init_composed_cache(self.base_cfg, self.mod_cfg, 1,
                                     self.cache_len)
        clone.cache = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None],
                                       (self.width,) + a.shape).copy(),
            cache1,
        )
        clone.tok = jnp.zeros((self.width,), jnp.int32)
        clone.pos = jnp.zeros((self.width,), jnp.int32)
        clone.slots = [None] * self.width
        clone._step = self._step
        clone._prefill = self._prefill
        clone._insert = self._insert
        return clone

    # ------------------------------------------------------- occupancy

    def free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self.slots)

    # -------------------------------------------------------- admit

    def admit(self, request: Request, base_params: Any,
              tick: int) -> Optional[Completion]:
        """Prefill the request and write it into a free slot.

        Returns the Completion immediately if the FIRST token already
        finishes it (eos, or max_new_tokens == 1) — the slot is not
        occupied in that case.  Raises if no slot is free (the engine
        checks ``free_slot()`` before calling).
        """
        i = self.free_slot()
        if i is None:
            raise RuntimeError("admit() with no free slot")
        prompt = jnp.asarray([list(request.prompt)], jnp.int32)
        first, cache_one = self._prefill(base_params, self.modular, prompt)
        first_tok = int(first)
        comp = Completion(
            rid=request.rid, tenant=request.tenant,
            tokens=[first_tok], prompt_len=prompt.shape[1],
            arrival=request.arrival, admitted_tick=tick,
            token_ticks=[tick],
        )
        if first_tok == request.eos_id:
            comp.finish_reason = "eos"
            comp.finished_tick = tick
            return comp
        if request.max_new_tokens == 1:
            comp.finish_reason = "length"
            comp.finished_tick = tick
            return comp
        self.base_stack, self.cache, self.tok, self.pos = self._insert(
            jnp.int32(i), self.base_stack, self.cache, self.tok,
            self.pos, base_params, cache_one, first,
            jnp.int32(prompt.shape[1]),
        )
        self.slots[i] = SlotState(request, comp)
        return None

    # -------------------------------------------------------- decode

    def decode_tick(self, tick: int) -> List[Completion]:
        """One lane step: every occupied slot emits one token; slots
        that hit EOS or their length budget are evicted (freed)."""
        if self.n_active == 0:
            return []
        nxt, self.cache, self.pos = self._step(
            self.base_stack, self.modular, self.cache, self.tok, self.pos,
        )
        self.tok = nxt
        toks = np.asarray(nxt)
        done: List[Completion] = []
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            t = int(toks[i])
            s.completion.tokens.append(t)
            s.completion.token_ticks.append(tick)
            s.remaining -= 1
            if t == s.request.eos_id:
                s.completion.finish_reason = "eos"
            elif s.remaining > 0:
                continue
            s.completion.finished_tick = tick
            done.append(s.completion)
            self.slots[i] = None  # evict: the slot is free next admit
        return done
