"""Per-architecture batch lanes — the device-resident continuous-batching
substrate.

A lane is a fixed-width W vector of independent decode slots for ONE
(base_arch, modular_arch) pair: stacked per-slot base params (each slot
a different tenant), ONE shared modular block (vmap ``in_axes=None`` —
instantiated once, reused by every slot), stacked per-slot B=1 decode
caches, and per-slot decode positions.

The hot loop is device-resident (ISSUE 10): one *horizon* launch
advances every slot S ticks — a ``lax.scan`` of the same vmapped
per-slot step the tick engine always ran — with per-slot stop state
(remaining-length counters and EOS ids) carried in device arrays.
Post-stop slots keep being decoded (fixed-width vmap) but their tokens
are dead: the host walks each slot's emitted window only up to its own
stop point.  The host never blocks inside the lane — the engine fetches
every lane's window (and the previous boundary's admission outputs) in
ONE coalesced ``jax.device_get`` per engine step.

Admission is bucketed batch prefill: the engine hands the lane a list
of requests at a horizon boundary, the lane groups them into padded
prompt-length buckets and runs ONE vmapped ragged prefill + slot
scatter per bucket (``composed_prefill_ragged`` freezes the padded
positions, so a row's cache is bitwise its unpadded prefill's).  The
admission batch is always W rows (pad rows scatter into slot index W —
dropped), so the compiled program is identical however many requests
are admitted, and identical to the oracle's single-request admission.
EOS/length-1 completion of the prefill token is checked ON DEVICE (the
slot's remaining counter starts at 0) and the host read of the first
token is deferred to the next boundary's coalesced transfer.

Bitwise contract (the oracle leans on it, and test_serve verifies it
end-to-end): at fixed width W, a slot's decoded tokens are a function
of that slot's (params, cache, token, pos, key) ONLY — ``vmap`` maps
each slot through the same per-slot program, so other slots' contents,
admissions and evictions cannot perturb it.  An engine-served request
is therefore bitwise equal to the same request served alone in an
otherwise-empty width-W lane (``ServeEngine.oracle``).  Empty slots
carry zero params + a fresh cache, which decodes to finite garbage
(fresh attention caches are fully-invalid -> zero context) that nobody
reads.

Sampling happens INSIDE the jitted step, so engine and oracle share
tie-breaking (greedy argmax) and the per-slot PRNG key chain
(temperature/top-k) exactly.  A lane compiles the cheap greedy-only
program until the first non-greedy request is admitted, then upgrades
to the sampling program — token streams are unchanged either way
(greedy slots select the argmax branch).
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models.transformer import (
    composed_decode_step,
    composed_prefill_ragged,
    init_composed_cache,
)
from repro.serve.types import Completion, Request

__all__ = ["Lane", "SlotState", "default_bucket_edges", "sample_token"]


def default_bucket_edges(cache_len: int) -> List[int]:
    """Power-of-two prompt-length buckets from 8 up to ``cache_len``."""
    edges, e = [], 8
    while e < cache_len:
        edges.append(e)
        e *= 2
    edges.append(int(cache_len))
    return edges


def request_key(request: Request) -> np.ndarray:
    """The request's raw (2,)-uint32 PRNG key, derived on the HOST from
    (seed, rid) — no device op per request, and the oracle rebuilds the
    identical key from the same request."""
    return np.array([request.seed & 0xFFFFFFFF, request.rid & 0xFFFFFFFF],
                    dtype=np.uint32)


def sample_token(logits: jnp.ndarray, key: jnp.ndarray,
                 temperature: jnp.ndarray, top_k: jnp.ndarray):
    """One token from (V,) logits: greedy argmax when ``temperature``
    is 0 (bitwise the historical path), else temperature softmax over
    the top ``top_k`` logits (0 = full vocab).  ``top_k`` is a traced
    per-slot value, so the filter is threshold-based (the k-th largest
    logit), not a static ``lax.top_k``."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    v = logits.shape[-1]
    desc = jnp.sort(logits)[::-1]
    thresh = jnp.where(top_k > 0, desc[jnp.clip(top_k - 1, 0, v - 1)],
                       -jnp.inf)
    filt = jnp.where(logits >= thresh, logits, -jnp.inf)
    t = jnp.where(temperature > 0, temperature, 1.0)
    drawn = jax.random.categorical(key, filt / t).astype(jnp.int32)
    return jnp.where(temperature > 0, drawn, greedy)


class SlotState:
    """Host bookkeeping for one occupied slot."""

    def __init__(self, request: Request, completion: Completion):
        self.request = request
        self.completion = completion
        # Decode tokens still owed AFTER the prefill token; mirrors the
        # device-side ``rem`` counter. Set when the first token lands.
        self.remaining = request.max_new_tokens - 1
        self.awaiting_first = True


class _AdmitGroup:
    """One bucketed admission launch awaiting its boundary transfer."""

    def __init__(self, rows: List[Tuple[int, int]], first: Any, done: Any,
                 tick: int):
        self.rows = rows          # [(row index in batch, slot index)]
        self.first = first        # (W,) int32 device array
        self.done = done          # (W,) bool device array
        self.tick = tick          # boundary tick the admission happened


class Lane:
    """Width-W continuous batch of one (base_cfg, mod_cfg) pair."""

    def __init__(self, base_cfg: ModelConfig, mod_cfg: ModelConfig,
                 modular_params: Any, base_template: Any, *,
                 width: int, cache_len: int,
                 bucket_edges: Optional[Sequence[int]] = None):
        if base_cfg.d_fusion != mod_cfg.d_fusion:
            raise ValueError("lane arch pair disagrees on d_fusion")
        self.base_cfg = base_cfg
        self.mod_cfg = mod_cfg
        self.width = int(width)
        self.cache_len = int(cache_len)
        self.modular = modular_params
        self.bucket_edges = sorted(
            int(e) for e in (bucket_edges or
                             default_bucket_edges(self.cache_len)))
        if self.bucket_edges[-1] < self.cache_len:
            self.bucket_edges.append(self.cache_len)
        # Device state: zeros-params filler for empty slots; every cache
        # leaf gets a uniform leading W axis ((W,) + B=1-leaf shape), so
        # vmap(in_axes=0) hands each slot an ordinary B=1 cache.
        self._zero_base = jax.tree.map(jnp.zeros_like, base_template)
        self.base_stack = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (self.width,) + a.shape),
            self._zero_base,
        )
        cache1 = init_composed_cache(base_cfg, mod_cfg, 1, self.cache_len)
        self.cache = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None],
                                       (self.width,) + a.shape).copy(),
            cache1,
        )
        self.tok = jnp.zeros((self.width,), jnp.int32)
        self.pos = jnp.zeros((self.width,), jnp.int32)
        # On-device stop state: rem = decode tokens still owed (0 =
        # stopped or empty), eos = per-slot eos id (-1 disables).
        self.rem = jnp.zeros((self.width,), jnp.int32)
        self.eos = jnp.full((self.width,), -1, jnp.int32)
        self.temp = jnp.zeros((self.width,), jnp.float32)
        self.topk = jnp.zeros((self.width,), jnp.int32)
        self.keys = jnp.zeros((self.width, 2), jnp.uint32)
        # Host bookkeeping.
        self.slots: List[Optional[SlotState]] = [None] * self.width
        self._admits: List[_AdmitGroup] = []
        self._window: Optional[Any] = None  # (S, W) device tokens
        self._window_span: Tuple[int, int] = (0, 0)  # (tick0, S)
        self.sampling = False  # upgraded on first non-greedy admit
        # Compiled-program caches, shared with every fresh_clone so the
        # oracle and the benchmark's hot twin reuse warm programs.
        self._hstep: Dict[Tuple[int, bool], Any] = {}
        self._admit_fns: Dict[Tuple[int, bool], Any] = {}

    # ------------------------------------------------------ jitted fns

    def _one_slot_fn(self, sampling: bool):
        base_cfg, mod_cfg = self.base_cfg, self.mod_cfg

        def one_slot(base, mod, cache, tok, pos, key, temp, topk):
            logits, cache = composed_decode_step(
                base, base_cfg, mod, mod_cfg, cache,
                tok.reshape(1, 1), pos,
            )
            if sampling:
                key, sub = jax.random.split(key)
                nxt = sample_token(logits[0, -1], sub, temp, topk)
            else:
                nxt = jnp.argmax(logits[0, -1], axis=-1).astype(jnp.int32)
            return nxt, cache, key

        return one_slot

    def _horizon_fn(self, S: int, sampling: bool):
        """The fused S-tick decode: ``lax.scan`` of the vmapped per-slot
        step with the stop state carried on device.  Post-stop slots
        keep stepping (their tokens are masked by the host walk), so the
        scan body is exactly the per-slot program S times — row
        independence, and bitwise equality with S separate horizon=1
        launches, hold by construction."""
        key = (int(S), bool(sampling))
        if key not in self._hstep:
            vstep = jax.vmap(self._one_slot_fn(sampling),
                             in_axes=(0, None, 0, 0, 0, 0, 0, 0))

            @jax.jit
            def hstep(stack, mod, cache, tok, pos, rem, eos, temp, topk,
                      keys):
                def body(carry, _):
                    cache, tok, pos, rem, keys = carry
                    nxt, cache, keys = vstep(stack, mod, cache, tok, pos,
                                             keys, temp, topk)
                    live = rem > 0
                    stop = (nxt == eos) | (rem == 1)
                    rem = jnp.where(live & ~stop, rem - 1, 0)
                    return (cache, nxt, pos + 1, rem, keys), nxt

                carry = (cache, tok, pos, rem, keys)
                (cache, tok, pos, rem, keys), toks = jax.lax.scan(
                    body, carry, None, length=S)
                return cache, tok, pos, rem, keys, toks

            self._hstep[key] = hstep
        return self._hstep[key]

    def _admit_fn(self, P: int, sampling: bool):
        """Bucketed batch admission for bucket length ``P``: a vmapped
        ragged prefill over a FIXED W-row batch (pad rows are dummies
        scattered to slot index W — dropped), then one scatter writing
        the admitted rows' params/cache/first-token/stop-state into
        their slots.  EOS/length-1 completion is decided on device
        (``done`` -> rem 0); the host reads ``first``/``done`` at the
        next boundary's coalesced transfer."""
        fkey = (int(P), bool(sampling))
        if fkey not in self._admit_fns:
            base_cfg, mod_cfg, cache_len = \
                self.base_cfg, self.mod_cfg, self.cache_len

            def prefill_one(base_one, mod, prompt, ln, key, temp, topk):
                cache1 = init_composed_cache(base_cfg, mod_cfg, 1,
                                             cache_len)
                last, cache1 = composed_prefill_ragged(
                    base_one, base_cfg, mod, mod_cfg, cache1, prompt, ln,
                )
                if sampling:
                    key, sub = jax.random.split(key)
                    first = sample_token(last, sub, temp, topk)
                else:
                    first = jnp.argmax(last, axis=-1).astype(jnp.int32)
                return first, cache1, key

            vprefill = jax.vmap(prefill_one,
                                in_axes=(0, None, 0, 0, 0, 0, 0))

            @jax.jit
            def admit(stack, mod, cache, tok, pos, rem, eos, temp, topk,
                      keys, base_rows, prompts, lens, slot_idx, max_new,
                      eos_rows, temp_rows, topk_rows, key_rows):
                first, cache_rows, key_out = vprefill(
                    base_rows, mod, prompts, lens, key_rows, temp_rows,
                    topk_rows,
                )
                done = (first == eos_rows) | (max_new <= 1)
                rem_rows = jnp.where(done, 0, max_new - 1)

                def scat(s, o):
                    return s.at[slot_idx].set(o, mode="drop")

                stack = jax.tree.map(scat, stack, base_rows)
                cache = jax.tree.map(scat, cache, cache_rows)
                return (stack, cache, scat(tok, first), scat(pos, lens),
                        scat(rem, rem_rows), scat(eos, eos_rows),
                        scat(temp, temp_rows), scat(topk, topk_rows),
                        scat(keys, key_out), first, done)

            self._admit_fns[fkey] = admit
        return self._admit_fns[fkey]

    def fresh_clone(self) -> "Lane":
        """An empty lane sharing this lane's compiled horizon/admission
        programs — the oracle's fixed-batch twin."""
        clone = object.__new__(Lane)
        clone.base_cfg, clone.mod_cfg = self.base_cfg, self.mod_cfg
        clone.width, clone.cache_len = self.width, self.cache_len
        clone.modular = self.modular
        clone.bucket_edges = list(self.bucket_edges)
        clone._zero_base = self._zero_base
        clone.base_stack = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (self.width,) + a.shape),
            self._zero_base,
        )
        cache1 = init_composed_cache(self.base_cfg, self.mod_cfg, 1,
                                     self.cache_len)
        clone.cache = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None],
                                       (self.width,) + a.shape).copy(),
            cache1,
        )
        clone.tok = jnp.zeros((self.width,), jnp.int32)
        clone.pos = jnp.zeros((self.width,), jnp.int32)
        clone.rem = jnp.zeros((self.width,), jnp.int32)
        clone.eos = jnp.full((self.width,), -1, jnp.int32)
        clone.temp = jnp.zeros((self.width,), jnp.float32)
        clone.topk = jnp.zeros((self.width,), jnp.int32)
        clone.keys = jnp.zeros((self.width, 2), jnp.uint32)
        clone.slots = [None] * self.width
        clone._admits = []
        clone._window = None
        clone._window_span = (0, 0)
        clone.sampling = self.sampling
        clone._hstep = self._hstep        # shared: stays warm
        clone._admit_fns = self._admit_fns
        return clone

    # ------------------------------------------------------- occupancy

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self.slots)

    def bucket(self, prompt_len: int) -> int:
        for e in self.bucket_edges:
            if prompt_len <= e:
                return e
        return self.cache_len

    # -------------------------------------------------------- admit

    def admit_batch(self, admits: List[Tuple[Request, Any]],
                    tick: int) -> None:
        """Admit up to ``len(free_slots())`` requests at a horizon
        boundary: group by prompt-length bucket and launch ONE vmapped
        prefill + scatter per bucket.  No host sync — the first tokens
        (and device-side EOS/length-1 completion flags) are fetched by
        the engine's next coalesced transfer."""
        if not admits:
            return
        free = self.free_slots()
        if len(admits) > len(free):
            raise RuntimeError("admit_batch() with too few free slots")
        if any(r.temperature > 0 for r, _ in admits):
            self.sampling = True
        W = self.width
        by_bucket: Dict[int, List[Tuple[Request, Any, int]]] = {}
        for (req, base), slot in zip(admits, free):
            by_bucket.setdefault(self.bucket(len(req.prompt)), []).append(
                (req, base, slot))
        for P, group in by_bucket.items():
            prompts = np.zeros((W, P), np.int32)
            lens = np.zeros((W,), np.int32)
            slot_idx = np.full((W,), W, np.int32)  # W = dropped pad row
            max_new = np.ones((W,), np.int32)
            eos_rows = np.full((W,), -1, np.int32)
            temp_rows = np.zeros((W,), np.float32)
            topk_rows = np.zeros((W,), np.int32)
            key_rows = np.zeros((W, 2), np.uint32)
            rows: List[Tuple[int, int]] = []
            trees = []
            for r, (req, base, slot) in enumerate(group):
                prompts[r, : len(req.prompt)] = req.prompt
                lens[r] = len(req.prompt)
                slot_idx[r] = slot
                max_new[r] = req.max_new_tokens
                eos_rows[r] = req.eos_id
                temp_rows[r] = req.temperature
                topk_rows[r] = req.top_k
                key_rows[r] = request_key(req)
                rows.append((r, slot))
                trees.append(base)
                comp = Completion(
                    rid=req.rid, tenant=req.tenant,
                    prompt_len=len(req.prompt), arrival=req.arrival,
                    admitted_tick=tick,
                )
                self.slots[slot] = SlotState(req, comp)
            trees.extend([self._zero_base] * (W - len(group)))
            base_rows = jax.tree.map(lambda *xs: jnp.stack(xs), *trees)
            admit = self._admit_fn(P, self.sampling)
            (self.base_stack, self.cache, self.tok, self.pos, self.rem,
             self.eos, self.temp, self.topk, self.keys, first, done) = \
                admit(self.base_stack, self.modular, self.cache, self.tok,
                      self.pos, self.rem, self.eos, self.temp, self.topk,
                      self.keys, base_rows, jnp.asarray(prompts),
                      jnp.asarray(lens), jnp.asarray(slot_idx),
                      jnp.asarray(max_new), jnp.asarray(eos_rows),
                      jnp.asarray(temp_rows), jnp.asarray(topk_rows),
                      jnp.asarray(key_rows))
            self._admits.append(_AdmitGroup(rows, first, done, tick))

    # -------------------------------------------------------- decode

    def launch_horizon(self, S: int, tick0: int) -> None:
        """Launch the fused S-tick decode (no host sync).  The emitted
        (S, W) token window is handed to the engine's coalesced
        transfer via :meth:`pending_transfer`."""
        hstep = self._horizon_fn(S, self.sampling)
        (self.cache, self.tok, self.pos, self.rem, self.keys,
         window) = hstep(self.base_stack, self.modular, self.cache,
                         self.tok, self.pos, self.rem, self.eos,
                         self.temp, self.topk, self.keys)
        self._window = window
        self._window_span = (tick0, S)

    def pending_transfer(self) -> Dict[str, Any]:
        """Device arrays the engine must fetch this step: the horizon
        window just launched plus any admission outputs (first tokens +
        device-side done flags) from the previous boundary."""
        out: Dict[str, Any] = {}
        if self._window is not None:
            out["window"] = self._window
        if self._admits:
            out["admit"] = [(g.first, g.done) for g in self._admits]
        return out

    def absorb(self, host: Dict[str, Any]) -> List[Completion]:
        """Host bookkeeping for one fetched step: land the previous
        boundary's first tokens (evicting prefill-completed slots), then
        walk each occupied slot's emitted window up to its stop point.
        Pure numpy — the single device sync already happened in the
        engine's coalesced ``jax.device_get``."""
        done: List[Completion] = []
        for group, (first, done_flags) in zip(self._admits,
                                              host.get("admit", [])):
            for row, slot in group.rows:
                s = self.slots[slot]
                t = int(first[row])
                s.completion.tokens.append(t)
                s.completion.token_ticks.append(group.tick)
                s.awaiting_first = False
                if bool(done_flags[row]):
                    s.completion.finish_reason = (
                        "eos" if t == s.request.eos_id else "length")
                    s.completion.finished_tick = group.tick
                    done.append(s.completion)
                    self.slots[slot] = None
        self._admits = []
        window = host.get("window")
        if window is not None:
            tick0, S = self._window_span
            for i, s in enumerate(self.slots):
                if s is None or s.awaiting_first:
                    continue
                for step in range(S):
                    t = int(window[step][i])
                    s.completion.tokens.append(t)
                    s.completion.token_ticks.append(tick0 + step)
                    s.remaining -= 1
                    if t == s.request.eos_id:
                        s.completion.finish_reason = "eos"
                    elif s.remaining > 0:
                        continue
                    s.completion.finished_tick = tick0 + step
                    done.append(s.completion)
                    self.slots[i] = None
                    break
            self._window = None
        return done
