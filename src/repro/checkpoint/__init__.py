from repro.checkpoint.ckpt import (  # noqa: F401
    load_checkpoint,
    load_extra,
    manifest_path,
    save_checkpoint,
)
