"""Flattened-pytree .npz checkpointing with a JSON manifest.

No orbax in this environment; keys are '/'-joined tree paths so the
format is stable, diffable, and partially loadable (e.g. restore only
the modular block for composition experiments).
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
            # npz has no bf16 codec; widen losslessly to fp32 (restore
            # casts back via the template dtype).
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def manifest_path(path: str) -> str:
    """The JSON manifest that rides next to a checkpoint's .npz — the
    one naming rule shared by writer and readers (repro.api resume
    reads ``extra`` back out of it)."""
    return (path[:-4] if path.endswith(".npz") else path) + ".json"


def load_extra(path: str) -> Dict[str, Any]:
    """The ``extra`` dict save_checkpoint recorded in the manifest."""
    with open(manifest_path(path)) as f:
        return json.load(f).get("extra", {})


def save_checkpoint(path: str, tree, *, step: Optional[int] = None,
                    extra: Optional[Dict[str, Any]] = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(path if path.endswith(".npz") else path + ".npz", **flat)
    manifest = {
        "step": step,
        "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                 for k, v in flat.items()},
        "extra": extra or {},
    }
    with open(manifest_path(path), "w") as f:
        json.dump(manifest, f, indent=1)


def load_checkpoint(path: str, template) -> Any:
    """Restore into the structure of ``template`` (shape/dtype-checked)."""
    npz = np.load(path if path.endswith(".npz") else path + ".npz")
    flat = dict(npz)

    def restore(p, leaf):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
        arr = flat[key]
        assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        return arr.astype(leaf.dtype)

    return jax.tree_util.tree_map_with_path(restore, template)
