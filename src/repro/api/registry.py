"""Scheme registry — collaborative-learning schemes as pluggable entries.

Mirrors the codec registry (``repro.core.codec.register``): FL-1/FL-2,
FSL, IFL and the SPMD IFL adapter are *looked up*, not if/elif'd, so a
new scheme (a FedMD-style distillation exchange, a HeteroFL width-sliced
FedAvg, ...) is one ``@register_scheme("name")`` away from every
benchmark, example, and the ``run_experiment`` runner — exactly how new
codecs already inherit the property suite and the ``ef(...)`` wrapper.

A *builder* is a callable ``(spec, data) -> Trainer``: it receives the
full :class:`~repro.api.spec.ExperimentSpec` plus the loaded
:class:`~repro.api.schemes.DataBundle` and returns an object satisfying
the :class:`~repro.api.trainer.Trainer` protocol.  Construction order
inside a builder is part of the reproducibility contract — the rng draws
it makes (param init keys, dirichlet partition) pin the run.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Tuple

__all__ = [
    "SchemeEntry",
    "register_scheme",
    "get_scheme",
    "available_schemes",
    "SCHEMES",
]


@dataclass(frozen=True)
class SchemeEntry:
    """One registered scheme: its name, builder, and one-line summary."""

    name: str
    builder: Callable  # (ExperimentSpec, DataBundle) -> Trainer
    summary: str = ""

    def build(self, spec, data):
        return self.builder(spec, data)


SCHEMES: Dict[str, SchemeEntry] = {}


def register_scheme(name: str, *, summary: str = ""):
    """Decorator: ``@register_scheme("ifl")`` over a builder callable."""

    def deco(builder):
        SCHEMES[name] = SchemeEntry(name, builder, summary)
        return builder

    return deco


def available_schemes() -> Tuple[str, ...]:
    return tuple(sorted(SCHEMES))


def get_scheme(name: str) -> SchemeEntry:
    """Resolve a scheme name; unknown names list what IS registered."""
    try:
        return SCHEMES[name]
    except KeyError:
        raise ValueError(
            f"unknown scheme {name!r}; available: "
            f"{', '.join(available_schemes()) or '(none registered)'}"
        ) from None
