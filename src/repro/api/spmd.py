"""`SPMDIFLTrainer` — the jitted SPMD round step behind the Trainer protocol.

Adapts ``repro.core.ifl_spmd.make_ifl_round_step`` (one jitted program =
one communication round, stacked-client params on a
('client','data','model') mesh) to the same front-door interface as the
eager trainers, so ``run_experiment(spec.replace(scheme="ifl_spmd"))``
drives the LM-scale path with the exact scheduling, staleness, and
byte-accounting semantics of the eager engine:

  - participation masks come from the SAME ``RoundEngine`` (one rng
    stream pins schedule draws to the seed),
  - byte accounting is the exchange plane's
    (``SPMDFusionExchange.account_round``): the codec's analytic
    ``encoded_nbytes`` per fresh upload — the quantity the property
    suite pins to measured wire bytes for every registered codec — plus
    int32 token labels, and the downlink under the spec's broadcast
    policy (``full``: participants x valid cache entries; ``delta``:
    mirror-sync shipping, each entry at most once plus the slot-index
    sidecar — same formula ``ifl_round_bytes(broadcast=)`` models),
  - ``snapshot/restore`` captures params, optimizer state, and the
    carried EF residual / payload cache (plus the plane's host mirror
    state in the aux), so resume is bitwise.

Data streams from a seeded ``SyntheticLM`` (the 'synth_tokens'
dataset): minibatch t of round r is a pure function of (seed, r, t,
client), so there is nothing to checkpoint on the data side.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.api.spec import ExperimentSpec
from repro.config import ModelConfig
from repro.core.exchange import SPMDFusionExchange
from repro.core.ifl_spmd import (
    init_ef_state,
    init_ifl_slot_state,
    init_ifl_state,
    init_payload_cache,
    make_ifl_round_step,
)
from repro.core.population import PopulationStore
from repro.core.report import RoundReport
from repro.core.rounds import AsyncRoundEngine, FullParticipation, RoundEngine
from repro.data.synthetic import SyntheticLM
from repro.models.transformer import base_forward, modular_forward

__all__ = ["SPMDIFLTrainer", "smoke_model_config"]

_EVAL_STEP = 999_983  # SyntheticLM step reserved for held-out eval data


def smoke_model_config() -> ModelConfig:
    """CPU-scale LM config the scheme defaults to (spec.model == '')."""
    return ModelConfig(
        name="spmd-smoke", num_layers=4, d_model=48, num_heads=2,
        num_kv_heads=2, d_ff=96, vocab_size=128, d_fusion=32, q_block=16,
        compute_dtype="float32", remat="none",
    ).validate()


def _one_device_mesh() -> Mesh:
    dev = np.array(jax.devices()[:1]).reshape(1, 1, 1)
    return Mesh(dev, ("client", "data", "model"))


class SPMDIFLTrainer:
    """IFL on the production mesh, one spec -> one resumable run.

    ``spec.batch_size`` is the per-client fusion/base minibatch Bc;
    ``seq`` is the LM context (kept small — this adapter's job is the
    front door, the 256-chip shapes live in ``repro.launch``).
    """

    def __init__(self, spec: ExperimentSpec, *, mesh: Optional[Mesh] = None,
                 seq: int = 32):
        if spec.model:
            from repro.configs import get_config

            self.model_cfg = get_config(spec.model).reduced()
        else:
            self.model_cfg = smoke_model_config()
        # The spec is the single source of truth for the fusion
        # interface — override whatever the model config carries, so a
        # hashed field is never silently ignored.
        self.model_cfg = self.model_cfg.replace(
            d_fusion=spec.d_fusion).validate()
        self.spec = spec
        self.seq = seq
        self.mesh = mesh or _one_device_mesh()
        # Population (cohort) regime: the fleet is N = fleet.population
        # slots, the device program is C-shaped (C = fleet.cohort), and
        # per-slot params/opt/EF page through host-side population
        # stores around each round.  Legacy (cohort=0): device width ==
        # fleet size, everything carried on-device as before.
        self._population = bool(spec.fleet.cohort)
        self.n_clients = spec.fleet.population
        self.width = (spec.fleet.cohort if self._population
                      else spec.fleet.n_clients)
        # The exchange plane owns both halves of the wire: the
        # jit-traceable pipeline the round step runs, and the host-side
        # analytic ledger (same codec, staleness, and broadcast policy
        # by construction).  Sized at N — accounting tracks population
        # slots, only the device program is cohort-shaped.
        self.exchange = SPMDFusionExchange(
            spec.codec, self.mesh, n_clients=self.n_clients,
            max_staleness=spec.max_staleness, broadcast=spec.broadcast,
            population=self._population,
        )
        # spec.mode='async': one engine round == one server tick; the
        # participant set is whoever's trace arrivals landed in the tick
        # (coalesced), which the jitted step sees as an ordinary partial-
        # participation mask — so the SPMD program itself is mode-blind.
        cohort = spec.fleet.cohort_size
        if spec.mode == "async":
            self.engine = AsyncRoundEngine(
                self.n_clients, spec.trace, tick=spec.tick,
                seed=spec.seed, exchange=self.exchange, cohort=cohort,
            )
        else:
            self.engine = RoundEngine(
                self.n_clients, spec.participation, seed=spec.seed,
                exchange=self.exchange, cohort=cohort,
            )
        self.ledger = self.engine.ledger
        self.codec = self.exchange.codec
        self.partial = (self._population or spec.mode == "async" or
                        not isinstance(self.engine.schedule,
                                       FullParticipation))

        z_shape = (self.width, spec.batch_size, seq,
                   self.model_cfg.d_fusion)
        tok_shape = (self.width, spec.batch_size, seq)
        if self._population:
            # Host-side stores, paged per round.  Params/opt never age
            # (a real client holds its own model on-device; the
            # simulation's analogue is lazy materialization); EF
            # residuals — payload-sized client state the *protocol*
            # carries — age by max_staleness, re-initializing to zeros
            # on rejoin exactly like a fresh slot.
            init_key = jax.random.PRNGKey(spec.seed)
            model_cfg = self.model_cfg

            def init_slot(slot: int):
                params, opt = init_ifl_slot_state(
                    init_key, model_cfg, slot=slot)
                return {"params": params, "opt": opt}

            self.store = PopulationStore(self.n_clients, init_slot)
            slot_z = z_shape[1:]
            self.ef_store = (
                PopulationStore(
                    self.n_clients,
                    lambda slot: self.codec.init_state(slot_z),
                    max_staleness=spec.max_staleness,
                )
                if self.codec.has_state else None
            )
            self.params = self.opt_state = self.ef_state = None
            self._last_cohort: List[int] = []
        else:
            self.store = self.ef_store = None
            self.params, self.opt_state = init_ifl_state(
                jax.random.PRNGKey(spec.seed), self.model_cfg,
                n_clients=self.n_clients,
            )
            self.ef_state = (init_ef_state(spec.codec, z_shape)
                             if self.codec.has_state else None)
        self._step = jax.jit(make_ifl_round_step(
            self.model_cfg, self.mesh, n_clients=self.width,
            tau=spec.tau, lr_base=spec.lr, lr_modular=spec.lr,
            partial_participation=self.partial,
            exchange=self.exchange,
        ))
        # In population mode the carried payload cache is rebuilt fresh
        # (all ages _NEVER) every round: cohort positions are re-bound
        # to different slots each round, so carrying a previous cohort's
        # payloads would misattribute them.
        self.cache = (init_payload_cache(spec.codec, z_shape, tok_shape)
                      if self.partial else None)
        self._stream = SyntheticLM(self.model_cfg.vocab_size, seed=spec.seed)
        # Analytic wire bytes of one client's fusion payload (+ labels):
        # encoded_nbytes is pinned to measured bytes by the codec
        # property suite, so the ledger stays honest without pulling
        # payloads out of the jitted program.
        self._entry_bytes = (
            self.codec.encoded_nbytes(z_shape[1:])
            + spec.batch_size * seq * 4
        )
        self._eval_acc = jax.jit(self._eval_acc_impl)

    # ------------------------------------------------------------- data

    def _round_batch(self, round_idx: int,
                     slots: Optional[List[int]] = None
                     ) -> Dict[str, jnp.ndarray]:
        spec = self.spec
        # ``slots`` (population mode) names the cohort's population slot
        # ids — data identity follows the slot, not the cohort position,
        # so a client sees its own stream whichever position it lands in.
        ids = slots if slots is not None else list(range(self.n_clients))
        toks = np.stack([
            np.stack([
                self._stream.sample(spec.batch_size, self.seq,
                                    step=round_idx * (spec.tau + 1) + t,
                                    client=k)
                for t in range(spec.tau + 1)
            ])
            for k in ids
        ])  # (width, tau+1, Bc, S)
        return {"tokens": jnp.asarray(toks)}

    # ------------------------------------------------------------ round

    def run_round(self) -> RoundReport:
        if self._population:
            return self._run_round_population()
        eng = self.engine
        participants = eng.participants()
        batch = self._round_batch(eng.round_idx)

        with self.mesh:
            if self.partial:
                host_mask = np.zeros(self.n_clients, bool)
                host_mask[participants] = True
                mask = jnp.asarray(host_mask)
                if self.codec.has_state:
                    (self.params, self.opt_state, m, self.cache,
                     self.ef_state) = self._step(
                        self.params, self.opt_state, batch, mask,
                        self.cache, self.ef_state)
                else:
                    self.params, self.opt_state, m, self.cache = self._step(
                        self.params, self.opt_state, batch, mask, self.cache)
            elif self.codec.has_state:
                self.params, self.opt_state, m, self.ef_state = self._step(
                    self.params, self.opt_state, batch, self.ef_state)
            else:
                self.params, self.opt_state, m = self._step(
                    self.params, self.opt_state, batch)

        # Bytes that crossed the client boundary, by the plane's host
        # accounting: K fresh uploads, downlink under the broadcast
        # policy — the same split ifl_round_bytes(participating=,
        # broadcast_entries=, broadcast=, delta_entries=) proves against
        # the eager ledger. Its valid-entry replay of the mask stream
        # matches the in-program cache_valid metric exactly.
        entries, shipped = self.exchange.account_round(
            [int(i) for i in participants], eng.round_idx,
            self._entry_bytes)

        metrics = {
            "base_loss": float(m["base_loss"]),
            "mod_loss": float(m["mod_loss"]),
            "participants": [int(i) for i in participants],
            "cache_size": entries,
        }
        if self.exchange.broadcast == "delta":
            metrics["shipped_entries"] = shipped
        return eng.end_round(metrics)

    def _run_round_population(self) -> RoundReport:
        """One cohort-shaped round: draw <=C slots, page their state
        into the fixed C-wide device cohort, run the masked step, page
        the trained positions back out.  Device arrays never see N."""
        eng = self.engine
        slots = [int(s) for s in eng.participants()]
        base_loss = mod_loss = float("nan")
        if slots:
            # Pad the cohort to the fixed device width by repeating a
            # real slot under a False mask: the padded positions pass
            # through the masked step untouched and are never paged out.
            pad = self.width - len(slots)
            cohort = slots + [slots[0]] * pad
            mask = jnp.asarray(np.arange(self.width) < len(slots))
            state = self.store.page_in(cohort)
            batch = self._round_batch(eng.round_idx, cohort)
            with self.mesh:
                if self.codec.has_state:
                    ef_in = self.ef_store.page_in(cohort)
                    params, opt, m, _, ef_out = self._step(
                        state["params"], state["opt"], batch, mask,
                        self.cache, ef_in)
                else:
                    params, opt, m, _ = self._step(
                        state["params"], state["opt"], batch, mask,
                        self.cache)
            self.store.page_out(
                slots, {"params": params, "opt": opt}, eng.round_idx)
            if self.codec.has_state:
                self.ef_store.page_out(slots, ef_out, eng.round_idx)
                self.ef_store.prune(eng.round_idx)
            base_loss = float(m["base_loss"])
            mod_loss = float(m["mod_loss"])
            self._last_cohort = slots

        entries, shipped = self.exchange.account_round(
            slots, eng.round_idx, self._entry_bytes)
        metrics = {
            "base_loss": base_loss,
            "mod_loss": mod_loss,
            "participants": slots,
            "cache_size": entries,
        }
        if self.exchange.broadcast == "delta":
            metrics["shipped_entries"] = shipped
        return eng.end_round(metrics)

    # ------------------------------------------------------------- eval

    def _eval_acc_impl(self, params, toks):
        cfg = self.model_cfg

        def one_client(p_k):
            z, _ = base_forward(p_k["base"], cfg, {"tokens": toks})
            logits, _ = modular_forward(p_k["modular"], cfg, z)
            pred = jnp.argmax(logits[:, :-1], axis=-1)
            return jnp.mean((pred == toks[:, 1:]).astype(jnp.float32))

        return jax.vmap(one_client)(params)

    def evaluate(self, test_x=None, test_y=None) -> List[float]:
        """Per-client next-token accuracy.

        ``test_x`` may be an (B, S) int token array; None — or a
        non-token array from an image DataSpec — uses the held-out
        SyntheticLM batch (step ``_EVAL_STEP``, never drawn in
        training), sized from ``spec.data.n_test`` (capped for CPU).
        ``test_y`` is ignored — LM targets are the shifted tokens.
        """
        if test_x is not None:
            arr = np.asarray(test_x)
            if arr.ndim != 2 or not np.issubdtype(arr.dtype, np.integer):
                test_x = None
        if test_x is None:
            n = max(1, min(self.spec.data.n_test, 64))
            test_x = self._stream.sample(n, self.seq, step=_EVAL_STEP,
                                         client=0)
        toks = jnp.asarray(np.asarray(test_x), jnp.int32)
        if self._population:
            # Probe the last cohort's freshly-trained slots (first
            # min(width, N) slots before any round has run).
            slots = (self._last_cohort
                     or list(range(min(self.width, self.n_clients))))
            params = self.store.page_in(slots)["params"]
        else:
            params = self.params
        with self.mesh:
            accs = self._eval_acc(params, toks)
        return [float(a) for a in accs]

    # ------------------------------------------------- snapshot/restore

    def snapshot(self):
        """(array pytree, JSON-able aux) — Trainer-protocol state.

        Legacy (cohort=0): the payload cache is fixed-shape carried
        state, so it checkpoints exactly; resume is bitwise even
        mid-partial-participation.  Population mode: a SPARSE slot
        snapshot — only the slots the cohorts actually materialized in
        the host-side ``PopulationStore`` (params/opt, plus aged EF
        residuals) are written, keyed by slot id, with the slot list and
        last-seen rounds riding in the aux.  Restore pages them back in
        bitwise; untouched slots re-materialize through the store's
        deterministic ``init_fn``, exactly as they would have in the
        original run — which is also what makes a trained population
        run exportable as a serving artifact
        (``CompositionStore.from_spmd_trainer``)."""
        if self._population:
            state, last_seen = self.store.snapshot_state()
            tree = {"slots": {str(s): t for s, t in state.items()}}
            pop = {
                "slots": sorted(state),
                "last_seen": {str(s): r for s, r in last_seen.items()},
                "last_cohort": list(self._last_cohort),
            }
            if self.ef_store is not None:
                ef_state, ef_seen = self.ef_store.snapshot_state()
                tree["ef_slots"] = {str(s): t
                                    for s, t in ef_state.items()}
                pop["ef_slots"] = sorted(ef_state)
                pop["ef_last_seen"] = {str(s): r
                                       for s, r in ef_seen.items()}
            aux = self.engine.aux_state()
            aux["population"] = pop
            return tree, aux
        tree = {"params": self.params, "opt": self.opt_state}
        if self.ef_state is not None:
            tree["ef"] = self.ef_state
        if self.cache is not None:
            tree["cache"] = self.cache
        return tree, self.engine.aux_state()

    def snapshot_template(self, extra):
        """Shape/dtype template matching a SAVED checkpoint — consulted
        by ``load_trainer`` BEFORE restore.  Sparse population
        checkpoints depend on which slots the saved run had touched, so
        a fresh trainer cannot use its own (empty) snapshot as the
        template; materialize exactly the saved slot list through the
        store's deterministic ``init_fn`` instead."""
        if not self._population:
            return self.snapshot()[0]
        pop = extra.get("population", {})
        tree = {"slots": {
            str(int(s)): jax.tree.map(np.asarray, self.store.init_fn(int(s)))
            for s in pop.get("slots", [])
        }}
        if self.ef_store is not None:
            tree["ef_slots"] = {
                str(int(s)): jax.tree.map(np.asarray,
                                          self.ef_store.init_fn(int(s)))
                for s in pop.get("ef_slots", [])
            }
        return tree

    def restore(self, tree, aux) -> None:
        if self._population:
            pop = aux["population"]
            self.store.restore_state(tree["slots"], pop["last_seen"])
            if self.ef_store is not None:
                self.ef_store.restore_state(tree.get("ef_slots", {}),
                                            pop.get("ef_last_seen", {}))
            self._last_cohort = [int(s) for s in pop.get("last_cohort", [])]
            self.engine.restore_aux(aux)
            return
        self.params = tree["params"]
        self.opt_state = tree["opt"]
        if self.ef_state is not None:
            self.ef_state = tree["ef"]
        if self.cache is not None:
            self.cache = tree["cache"]
        self.engine.restore_aux(aux)
        if "exchange" not in aux and self.cache is not None:
            # Pre-exchange-plane checkpoint: the carried cache comes
            # back warm, so the host accounting must not come back cold
            # (it would under-ledger the broadcasts the program really
            # runs). Rebuild the age replica from the restored ages:
            # a slot with age a last uploaded at (round_idx - 1) - a.
            from repro.core.exchange import _NEVER

            last = self.engine.round_idx - 1
            self.exchange._last_upload = [
                None if int(a) >= _NEVER else last - int(a)
                for a in np.asarray(self.cache["age"])
            ]
