"""repro.api — the front door.

One import gives you the whole comparative apparatus of the paper:

    from repro.api import ExperimentSpec, run_experiment

    result = run_experiment(ExperimentSpec(
        scheme="ifl", rounds=20, codec="int8", participation="k2",
    ))
    print(result.final["acc_mean"], result.uplink_mb)

Pieces (each its own module, all re-exported here):

  ExperimentSpec / DataSpec / FleetSpec   what to run (frozen, hashable:
                                          ``spec_hash()`` content-keys
                                          the result cache)
  register_scheme / get_scheme /          scheme registry — FL-1, FL-2,
  available_schemes                       FSL, IFL, ifl_spmd today;
                                          FedMD/HeteroFL-style baselines
                                          are one entry away
  Trainer / RoundReport / RunResult       the unified protocol and its
                                          structured outputs
  run_experiment / build_trainer          the runner (spec-hash caching)
  save_trainer / load_trainer             mid-run checkpoint + resume
                                          (repro.checkpoint format)
"""

from repro.api.spec import DataSpec, ExperimentSpec, FleetSpec  # noqa: F401
from repro.api.registry import (  # noqa: F401
    SchemeEntry,
    available_schemes,
    get_scheme,
    register_scheme,
)
from repro.core.report import RoundReport  # noqa: F401
from repro.api.result import RunResult  # noqa: F401
from repro.api.trainer import Trainer, load_trainer, save_trainer  # noqa: F401
from repro.api import schemes  # noqa: F401  (registers the builtin schemes)
from repro.api.schemes import DataBundle, build_fleet, load_data  # noqa: F401
from repro.api.runner import (  # noqa: F401
    PAPER_RESULTS,
    build_trainer,
    run_experiment,
)
