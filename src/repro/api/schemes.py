"""Registered schemes: data loading, fleet construction, trainer builders.

This is the one home of the make-data -> dirichlet-partition -> build-
Client-list -> construct-trainer sequence that used to be copy-pasted
across benchmarks/paper_repro.py, both training examples, and every
figure script.  Construction is bit-for-bit the sequence the original
``run_scheme`` performed (same dirichlet seed, same ``PRNGKey(100+k)``
param init keys, same trainer seeds), so a spec replays the exact
cached trajectories.

Adding a scheme == adding a builder here (or in your own module):

    @register_scheme("fedmd", summary="distillation exchange baseline")
    def build_fedmd(spec, data):
        ...
        return trainer  # anything satisfying repro.api.Trainer

and every benchmark/example/CLI axis picks it up — the same way new
codecs inherit ``ef(...)`` and the property suite.
"""

from __future__ import annotations

import functools
from typing import Callable, Dict, NamedTuple, Optional

import jax
import numpy as np

from repro.api.registry import register_scheme
from repro.api.spec import ExperimentSpec
from repro.core import Client, FLTrainer, FSLTrainer, IFLTrainer
from repro.core.population import LazyFleet
from repro.data import dirichlet_partition, make_synth_kmnist
from repro.models.small import (
    client_base_apply,
    client_modular_apply,
    init_client_model,
)

__all__ = ["DataBundle", "load_data", "build_fleet", "apply_fns"]


# ---------------------------------------------------------------- datasets


class DataBundle(NamedTuple):
    """Loaded train/test arrays (token schemes stream internally: None)."""

    train_x: Optional[np.ndarray]
    train_y: Optional[np.ndarray]
    test_x: Optional[np.ndarray]
    test_y: Optional[np.ndarray]


def _load_synth_kmnist(spec: ExperimentSpec) -> DataBundle:
    return DataBundle(*make_synth_kmnist(spec.data.n_train, spec.data.n_test))


def _load_synth_tokens(spec: ExperimentSpec) -> DataBundle:
    # LM schemes stream minibatches from a seeded SyntheticLM inside the
    # trainer (the data IS the generator); nothing to materialize here.
    return DataBundle(None, None, None, None)


DATASETS: Dict[str, Callable[[ExperimentSpec], DataBundle]] = {
    "synth_kmnist": _load_synth_kmnist,
    "synth_tokens": _load_synth_tokens,
}


def load_data(spec: ExperimentSpec) -> DataBundle:
    try:
        loader = DATASETS[spec.data.dataset]
    except KeyError:
        raise ValueError(
            f"unknown dataset {spec.data.dataset!r}; available: "
            f"{', '.join(sorted(DATASETS))}"
        ) from None
    return loader(spec)


# ------------------------------------------------------------------ fleet


def apply_fns(cid: int):
    """(base_apply, modular_apply) closures for Table-II arch ``cid``."""
    return (
        functools.partial(
            lambda p, x, c: client_base_apply({"base": p}, c, x), c=cid),
        functools.partial(
            lambda p, z, c: client_modular_apply({"modular": p}, c, z), c=cid),
    )


def build_fleet(spec: ExperimentSpec, data: DataBundle, *,
                heterogeneous: Optional[bool] = None,
                arch: Optional[int] = None):
    """Dirichlet-shard the data and build the Client list.

    Reproduces the original harness draw-for-draw: shard seed =
    ``spec.seed``, param init key = ``PRNGKey(100 + k)`` for slot k.
    Heterogeneous fleets cycle the paper's four Table-II architectures;
    homogeneous ones (the FL regime) clone ``arch`` everywhere.
    Population specs (``fleet.n_population`` set) return a
    :class:`repro.core.population.LazyFleet` of N clients built on
    first touch instead of an eager list.
    """
    fleet = spec.fleet
    if heterogeneous is None:
        heterogeneous = fleet.heterogeneous
    arch = fleet.arch if arch is None else arch
    n = fleet.population
    shards = dirichlet_partition(data.train_y, n,
                                 alpha=fleet.alpha, seed=spec.seed)

    def build_client(k: int) -> Client:
        cid = (k % 4 + 1) if heterogeneous else arch
        base_fn, mod_fn = apply_fns(cid)
        return Client(
            cid=cid,
            params=init_client_model(jax.random.PRNGKey(100 + k), cid),
            base_apply=base_fn, modular_apply=mod_fn,
            data_x=data.train_x[shards[k]], data_y=data.train_y[shards[k]],
        )

    if fleet.n_population:
        # Population fleet: shards are cheap index views, but N model
        # inits are not — materialize client k on first cohort touch
        # (deterministic in k, so lazy == eager bitwise).
        return LazyFleet(n, build_client)
    return [build_client(k) for k in range(n)]


# ----------------------------------------------------------------- schemes


@register_scheme("ifl", summary="Interoperable FL (the paper, Algorithm 1): "
                                "heterogeneous fleet, fusion-output exchange")
def build_ifl(spec: ExperimentSpec, data: DataBundle) -> IFLTrainer:
    return IFLTrainer(build_fleet(spec, data), spec.run_config(),
                      seed=spec.seed)


def _require_sync(spec: ExperimentSpec, scheme: str) -> None:
    # FedAvg and split learning aggregate a *shared* block, which is
    # only well-defined at a round barrier; the staleness-bounded
    # fusion cache that makes async fusion sound (ISSUE 6) has no
    # analogue there. Fail at build time, not mid-run.
    if spec.mode != "sync":
        raise ValueError(
            f"scheme {scheme!r} only supports mode='sync' — async "
            "arrival-driven rounds need the IFL fusion cache "
            "(use scheme='ifl' or 'ifl_spmd')"
        )


def _require_no_population(spec: ExperimentSpec, scheme: str) -> None:
    # The cohort-shaped path pages per-slot carried state through the
    # population store, which only the IFL fusion planes implement;
    # FedAvg/FSL cohort baselines are future work (ROADMAP).
    if spec.fleet.n_population or spec.fleet.cohort:
        raise ValueError(
            f"scheme {scheme!r} has no cohort-shaped path yet — "
            "population fleets (n_population/cohort) need the IFL "
            "fusion cache (use scheme='ifl' or 'ifl_spmd')"
        )


@register_scheme("fsl", summary="federated split learning baseline "
                                "(SplitFed-style shared server block)")
def build_fsl(spec: ExperimentSpec, data: DataBundle) -> FSLTrainer:
    _require_sync(spec, "fsl")
    _require_no_population(spec, "fsl")
    clients = build_fleet(spec, data)
    server = init_client_model(jax.random.PRNGKey(999), 1)["modular"]
    _, server_apply = apply_fns(1)
    return FSLTrainer(clients, spec.run_config(), server, server_apply,
                      seed=spec.seed)


def _build_fl(spec: ExperimentSpec, data: DataBundle, arch: int) -> FLTrainer:
    _require_sync(spec, f"fl{arch}")
    _require_no_population(spec, f"fl{arch}")
    clients = build_fleet(spec, data, heterogeneous=False, arch=arch)
    return FLTrainer(clients, spec.run_config(), seed=spec.seed)


@register_scheme("fl1", summary="FedAvg, client 1's smallest arch cloned "
                                "fleet-wide (paper FL-1)")
def build_fl1(spec: ExperimentSpec, data: DataBundle) -> FLTrainer:
    return _build_fl(spec, data, arch=1)


@register_scheme("fl2", summary="FedAvg, client 2's larger arch cloned "
                                "fleet-wide (paper FL-2)")
def build_fl2(spec: ExperimentSpec, data: DataBundle) -> FLTrainer:
    return _build_fl(spec, data, arch=2)


@register_scheme("ifl_spmd", summary="IFL as one jitted SPMD round step "
                                     "(LM-scale, stacked-client mesh)")
def build_ifl_spmd(spec: ExperimentSpec, data: DataBundle):
    from repro.api.spmd import SPMDIFLTrainer  # jax-heavy; import lazily

    return SPMDIFLTrainer(spec)
