"""`RunResult` — the structured outcome of `run_experiment`.

Bundles the spec that produced it, the eval-cadence ``records`` (the
exact per-figure payload the benchmarks consume: round, cumulative
uplink/total MB, accuracies, composition matrix for IFL), the per-round
``reports`` (serialized :class:`~repro.core.report.RoundReport`:
losses, participants, ledger MB both legs), and the final ledger
totals.  JSON round-trips losslessly — ``to_dict`` is also the cache
file format, self-describing via the embedded spec (no more decoding
hyper-parameters out of filenames).
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from repro.api.spec import ExperimentSpec

__all__ = ["RunResult"]


@dataclass
class RunResult:
    spec: ExperimentSpec
    records: List[Dict[str, Any]] = field(default_factory=list)
    reports: List[Dict[str, Any]] = field(default_factory=list)
    uplink_mb: float = 0.0
    downlink_mb: float = 0.0
    # Set by run_experiment(keep_trainer=True); never serialized.
    trainer: Optional[Any] = None

    # ------------------------------------------------------------- dicts

    def to_dict(self) -> Dict[str, Any]:
        """JSON-able dict; keeps the legacy top-level keys (scheme,
        rounds, tau, codec, participation) so pre-existing consumers of
        ``run_scheme``'s return shape read it unchanged."""
        return {
            "scheme": self.spec.scheme,
            "rounds": self.spec.rounds,
            "tau": self.spec.tau,
            "codec": self.spec.codec,
            "participation": self.spec.participation,
            "spec": self.spec.to_dict(),
            "spec_hash": self.spec.spec_hash(),
            "records": self.records,
            "reports": self.reports,
            "uplink_mb": self.uplink_mb,
            "downlink_mb": self.downlink_mb,
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any],
                  spec: Optional[ExperimentSpec] = None) -> "RunResult":
        """Rebuild from ``to_dict()`` output — or from a legacy cache
        dict (no embedded spec: records only), given the spec that
        located it."""
        if spec is None:
            spec = ExperimentSpec.from_dict(d["spec"])
        return cls(
            spec=spec,
            records=list(d.get("records", [])),
            reports=list(d.get("reports", [])),
            uplink_mb=float(d.get("uplink_mb", 0.0)),
            downlink_mb=float(d.get("downlink_mb", 0.0)),
        )

    # -------------------------------------------------------------- json

    def to_json(self, path: Optional[str] = None, *, indent: int = 1) -> str:
        s = json.dumps(self.to_dict(), indent=indent)
        if path:
            with open(path, "w") as f:
                f.write(s)
        return s

    @classmethod
    def from_json(cls, src: str) -> "RunResult":
        """``src`` is a path or a JSON string (must embed its spec)."""
        if src.lstrip().startswith("{"):
            return cls.from_dict(json.loads(src))
        with open(src) as f:
            return cls.from_dict(json.load(f))

    # ------------------------------------------------------- convenience

    @property
    def final(self) -> Dict[str, Any]:
        """Last eval record (the end-of-training numbers)."""
        return self.records[-1] if self.records else {}
