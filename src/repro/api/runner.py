"""`run_experiment(spec)` — the one way to run any scheme.

Replaces the five hand-rolled copies of the build-and-loop harness:
resolve the scheme from the registry, load the data, build the trainer,
run ``spec.rounds`` rounds collecting :class:`RoundReport`s, evaluate on
the spec's cadence, and return (and optionally cache) a
:class:`RunResult`.

Caching is content-addressed: the file is ``<scheme>_<spec_hash>.json``
— shell-safe, collision-free, self-describing (the spec rides inside
the JSON).  Legacy filename-tag caches (``ifl_r20_..._cef(int4).json``)
are still *read* when the hash file is absent, so the tracked fixtures
under results/paper/ keep serving the long 200-round runs, but nothing
new is ever written under the old fragile keys.
"""

from __future__ import annotations

import json
import os
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.api import schemes  # noqa: F401  (populates the registry)
from repro.api.registry import get_scheme
from repro.api.result import RunResult
from repro.api.spec import ExperimentSpec
from repro.api.trainer import Trainer
from repro.core.report import RoundReport

__all__ = ["run_experiment", "build_trainer", "PAPER_RESULTS"]

PAPER_RESULTS = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "results", "paper"
)


def build_trainer(spec: ExperimentSpec) -> Trainer:
    """Registry lookup + data load + scheme build (no rounds run)."""
    return get_scheme(spec.scheme).build(spec, schemes.load_data(spec))


def _eval_record(trainer, data, report: RoundReport) -> Dict[str, Any]:
    """One eval-cadence record — the exact shape the figure benchmarks
    (and the pre-API cache files) established per scheme."""
    rec: Dict[str, Any] = {
        "round": report.round,
        "uplink_mb": trainer.ledger.uplink_mb,
        "total_mb": trainer.ledger.total_mb,
    }
    # Async runs carry the event clock: simulated seconds at this tick
    # and cumulative uploads/sec absorbed (the AsyncRoundEngine injects
    # both into every report; sync reports have neither).
    for key in ("sim_time", "uploads_per_sec"):
        if key in report.metrics:
            rec[key] = report.metrics[key]
    accs = trainer.evaluate(data.test_x, data.test_y)
    if isinstance(accs, (list, tuple)):
        rec["acc_mean"] = float(np.mean(accs))
        rec["accs"] = list(accs)
    else:
        rec["acc_mean"] = float(accs)
    if hasattr(trainer, "accuracy_matrix") and getattr(
            trainer, "eval_matrix", True):
        # ``eval_matrix=False`` (population fleets): the N x N
        # cross-composition sweep is unaffordable and off-thesis there.
        mat = trainer.accuracy_matrix(data.test_x[:2000], data.test_y[:2000])
        rec["matrix"] = mat.tolist()
        # Fig 3: per-base-block SD across modular compositions.
        rec["sd_per_base"] = np.std(mat * 100, axis=1).tolist()
    return rec


def _legacy_tag(spec: ExperimentSpec) -> str:
    """The pre-hash filename tag — READ-ONLY back compat with tracked
    fixtures (this is the naming scheme spec_hash() retires)."""
    d, f = spec.data, spec.fleet
    tag = f"{spec.scheme}_r{spec.rounds}_n{d.n_train}_tau{spec.tau}_s{spec.seed}"
    if spec.lr != 0.01:
        tag += f"_lr{spec.lr}"
    if spec.codec != "fp32":
        tag += f"_c{spec.codec}"
    if spec.participation != "full":
        tag += f"_p{spec.participation}"
        if spec.max_staleness is not None:
            tag += f"_st{spec.max_staleness}"
    return tag + ".json"


def run_experiment(
    spec: ExperimentSpec,
    *,
    cache_dir: Optional[str] = None,
    force: bool = False,
    keep_trainer: bool = False,
    on_record: Optional[Callable[[Dict[str, Any], RoundReport], None]] = None,
) -> RunResult:
    """Run (or serve from cache) the experiment ``spec`` describes.

    ``cache_dir`` enables spec-hash result caching (the benchmarks pass
    ``PAPER_RESULTS``); ``force`` re-runs and overwrites.  With
    ``keep_trainer`` the live trainer rides on ``result.trainer`` for
    post-hoc analysis (composition matrices, ledger forensics, further
    rounds) — a live trainer only exists for a live run, so
    ``keep_trainer`` bypasses cache hits.  ``on_record(record, report)``
    fires at every eval point — progress printing without re-owning the
    loop; on a cache hit it replays over the cached records (with the
    matching cached RoundReport when the file carries reports).
    """
    if cache_dir and not force and not keep_trainer:
        cached = None
        path = os.path.join(cache_dir,
                            f"{spec.scheme}_{spec.spec_hash()}.json")
        if os.path.exists(path):
            cached = RunResult.from_json(path)
        elif (spec.broadcast == "full" and spec.mode == "sync"
              and not spec.fleet.n_population and not spec.fleet.cohort):
            # The legacy tags predate the broadcast, mode, and
            # population axes (every legacy fixture is a sync
            # full-broadcast fixed-fleet run), so a non-default policy
            # must never match one — a delta, async, or cohort spec
            # served the tracked sync file would silently report the
            # wrong bytes and clock.
            legacy = os.path.join(cache_dir, _legacy_tag(spec))
            if os.path.exists(legacy):
                with open(legacy) as f:
                    cached = RunResult.from_dict(json.load(f), spec=spec)
        if cached is not None:
            if on_record:
                by_round = {rep.get("round"): rep for rep in cached.reports}
                for rec in cached.records:
                    on_record(rec, RoundReport.from_dict(
                        by_round.get(rec.get("round"), rec)))
            return cached

    data = schemes.load_data(spec)
    trainer = get_scheme(spec.scheme).build(spec, data)

    records: List[Dict[str, Any]] = []
    reports: List[Dict[str, Any]] = []
    for r in range(spec.rounds):
        report = trainer.run_round()
        reports.append(report.to_dict())
        if (spec.eval_every > 0 and r % spec.eval_every == 0) \
                or r == spec.rounds - 1:
            rec = _eval_record(trainer, data, report)
            records.append(rec)
            if on_record:
                on_record(rec, report)

    result = RunResult(
        spec=spec,
        records=records,
        reports=reports,
        uplink_mb=trainer.ledger.uplink_mb,
        downlink_mb=trainer.ledger.downlink_mb,
    )
    if cache_dir:
        os.makedirs(cache_dir, exist_ok=True)
        out_path = os.path.join(cache_dir,
                                f"{spec.scheme}_{spec.spec_hash()}.json")
        # Only ``force`` may clobber an existing cache entry (a
        # keep_trainer live run must not silently rewrite fixtures).
        if force or not os.path.exists(out_path):
            result.to_json(out_path)
    if keep_trainer:
        result.trainer = trainer
    return result
