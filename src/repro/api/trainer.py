"""The unified `Trainer` protocol + checkpoint plumbing for resume.

Every scheme the registry can build — eager IFL/FSL/FL and the SPMD IFL
adapter — satisfies one structural interface:

  run_round()  -> RoundReport     one communication round
  evaluate(test_x, test_y)        scalar (global-model schemes) or
                                  per-client list (personalized schemes)
  snapshot()   -> (tree, aux)     array pytree + JSON-able aux state
  restore(tree, aux)              inverse of snapshot
  ledger       : CommLedger       bytes that crossed the client boundary

``snapshot``/``restore`` split state the way ``repro.checkpoint``
stores it: the *tree* is arrays only (flattened into the .npz), the
*aux* is small JSON (round counter, rng bit-generator state, ledger
totals — written into the manifest's ``extra``).  ``save_trainer`` /
``load_trainer`` wire the two together so any Trainer resumes
bit-for-bit mid-run.  Async-mode trainers (spec.mode='async') ride
their event clock in the same aux — the arrival-trace cursor and
upload counters live under ``aux['async']`` — so an async run resumes
on the exact same arrival stream, not a reseeded one.
"""

from __future__ import annotations

from typing import Any, Dict, Protocol, Tuple, runtime_checkable

from repro.checkpoint import load_checkpoint, load_extra, save_checkpoint
from repro.core.comm import CommLedger
from repro.core.report import RoundReport

__all__ = ["Trainer", "save_trainer", "load_trainer"]


@runtime_checkable
class Trainer(Protocol):
    """Structural interface every registered scheme's trainer satisfies."""

    ledger: CommLedger

    def run_round(self) -> RoundReport: ...

    def evaluate(self, test_x, test_y): ...

    def snapshot(self) -> Tuple[Any, Dict[str, Any]]: ...

    def restore(self, tree, aux) -> None: ...


def save_trainer(path: str, trainer: Trainer) -> None:
    """Checkpoint a mid-run trainer (repro.checkpoint .npz + manifest)."""
    tree, aux = trainer.snapshot()
    save_checkpoint(path, tree, step=int(aux.get("round_idx", 0)), extra=aux)


def load_trainer(path: str, trainer: Trainer) -> Trainer:
    """Restore ``trainer`` (freshly built from the same spec) in place.

    The trainer's own ``snapshot()`` tree is the shape/dtype template
    the flattened checkpoint is validated against — restoring across a
    different spec (other fleet, other codec state shape) fails loudly
    instead of silently mixing states.

    Trainers whose snapshot STRUCTURE depends on run history — the
    population trainers' sparse slot snapshots — expose
    ``snapshot_template(extra)``: the manifest's aux is read FIRST so
    the template can materialize exactly the slots the saved run had
    touched.
    """
    extra = load_extra(path)
    if hasattr(trainer, "snapshot_template"):
        template = trainer.snapshot_template(extra)
    else:
        template, _ = trainer.snapshot()
    tree = load_checkpoint(path, template)
    trainer.restore(tree, extra)
    return trainer
