"""`ExperimentSpec` — the single declarative description of one run.

The paper's argument is comparative (IFL vs FSL vs FL at matched
budgets), so the unit of work is "scheme X under codec Y and schedule Z
on data D with fleet F, seeded": that tuple IS the spec.  It is frozen,
dict-round-trippable, and content-addressed — ``spec_hash()`` is a
stable digest of the canonical dict, used by ``run_experiment`` to key
its result cache (replacing the old filename tags that embedded raw
codec strings like ``..._cef(int4).json``: shell-hostile parentheses,
float-formatting collisions on lr, and silently non-unique once a field
didn't make it into the tag).

``ExperimentSpec.run_config()`` lowers the spec onto the trainers'
:class:`repro.config.RunConfig`; the scheme builders in
``repro.api.schemes`` consume the rest (data + fleet).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

from repro.config import RunConfig

__all__ = ["DataSpec", "FleetSpec", "ExperimentSpec"]


@dataclass(frozen=True)
class DataSpec:
    """What the fleet trains on.

    ``dataset`` names a loader in ``repro.api.schemes`` ('synth_kmnist'
    for the paper's Table-II image setup, 'synth_tokens' for the
    LM-scale SPMD scheme).  Sizes are in samples (images) or eval
    sequences (tokens); token schemes stream training data from a
    seeded generator, so ``n_train`` only applies to materialized
    datasets.
    """

    dataset: str = "synth_kmnist"
    n_train: int = 20000
    n_test: int = 4000


@dataclass(frozen=True)
class FleetSpec:
    """Who trains: the client fleet.

    ``heterogeneous=True`` assigns the paper's Table-II architectures
    round-robin (client k gets arch ``k % 4 + 1``); ``False`` clones
    ``arch`` everywhere (the FL-1/FL-2 regime — FedAvg cannot serve a
    heterogeneous fleet, which is the limitation the paper targets).
    ``alpha`` is the Dirichlet non-IID concentration of the shards.

    Population regime (the FedAvg/HeteroFL deployment shape): setting
    ``n_population=N`` with ``cohort=C`` sizes the fleet at N clients of
    which at most C are admitted per round — device state stays
    C-shaped, per-slot carried state lives in the host-side population
    store, and the downlink serves the cohort's fresh uploads only.
    Both default to 0 (off: the fleet is ``n_clients`` and every
    pre-population spec hash is unchanged — the fields are elided from
    the canonical dict at their defaults).
    """

    n_clients: int = 4
    heterogeneous: bool = True
    arch: int = 1
    alpha: float = 0.5
    n_population: int = 0
    cohort: int = 0

    def __post_init__(self):
        if self.n_population < 0 or self.cohort < 0:
            raise ValueError(
                f"n_population/cohort must be >= 0, got "
                f"{self.n_population}/{self.cohort}"
            )
        if self.n_population and not self.cohort:
            raise ValueError(
                f"n_population={self.n_population} needs a cohort size "
                "(cohort=C, the per-round admission cap)"
            )
        pop = self.n_population or self.n_clients
        if self.cohort > pop:
            raise ValueError(
                f"cohort ({self.cohort}) cannot exceed the population "
                f"({pop} clients)"
            )

    @property
    def population(self) -> int:
        """The actual fleet size: ``n_population`` when set, else
        ``n_clients``."""
        return self.n_population or self.n_clients

    @property
    def cohort_size(self) -> Optional[int]:
        """The per-round admission cap (None when uncapped)."""
        return self.cohort or None


@dataclass(frozen=True)
class ExperimentSpec:
    """One experiment, fully pinned: same spec + same seed = same run.

    ``lr`` applies to both blocks (the paper uses one η; the calibrated
    synthetic-stand-in default is 0.05 — see benchmarks/paper_repro.py).
    ``model`` names an LM config (repro.configs) for the SPMD scheme,
    reduced to smoke scale; empty = that scheme's builtin tiny config.
    """

    scheme: str = "ifl"
    rounds: int = 20
    tau: int = 10
    lr: float = 0.05
    batch_size: int = 32
    d_fusion: int = 432
    codec: str = "fp32"
    participation: str = "full"
    max_staleness: Optional[int] = None
    # Downlink policy for the fusion broadcast: 'full' | 'delta'
    # (repro.core.exchange). Ignored by schemes without a fusion
    # downlink (FL/FSL).
    broadcast: str = "full"
    # Round clocking: 'sync' is the paper's barriered loop; 'async'
    # drives the engine from an ArrivalTrace (``trace``, e.g.
    # 'pareto(1.2,0.5)' or 'replay:<path>') with a server fuse every
    # ``tick`` simulated seconds (repro.core.rounds.AsyncRoundEngine).
    # Only the IFL schemes support async — FedAvg/FSL need the barrier.
    mode: str = "sync"
    trace: str = ""
    tick: float = 1.0
    eval_every: int = 5  # <=0: evaluate on the final round only
    seed: int = 0
    model: str = ""
    data: DataSpec = field(default_factory=DataSpec)
    fleet: FleetSpec = field(default_factory=FleetSpec)

    # Axes added after the canonical form was pinned, elided from
    # ``to_dict`` at their compat default: every pre-existing spec hash
    # (including the tracked results/paper fixtures) stays addressable,
    # and only a non-default value hashes as a new experiment.
    _ELIDE_AT_DEFAULT = (
        ("broadcast", "full"),
        ("mode", "sync"),
        ("trace", ""),
        ("tick", 1.0),
    )
    # Same compat contract for axes nested under the fleet dict.
    _ELIDE_FLEET_AT_DEFAULT = (
        ("n_population", 0),
        ("cohort", 0),
    )

    def __post_init__(self):
        if self.mode not in ("sync", "async"):
            raise ValueError(
                f"mode={self.mode!r}: expected 'sync' or 'async'"
            )
        if self.mode == "async":
            if not self.trace:
                raise ValueError(
                    "mode='async' needs an arrival trace — e.g. "
                    "trace='poisson(0.5)', 'pareto(1.2,0.5)', or "
                    "'replay:<path>' (see repro.core.rounds.parse_trace)"
                )
            if self.participation != "full":
                raise ValueError(
                    "mode='async' draws participants from the arrival "
                    "trace; participation schedules only apply to sync "
                    f"mode (got participation={self.participation!r})"
                )
            if self.tick <= 0:
                raise ValueError(f"tick={self.tick}: must be > 0")
        elif self.trace:
            raise ValueError(
                f"trace={self.trace!r} set but mode='sync' — arrival "
                "traces only drive async mode (use participation= for "
                "sync schedules)"
            )

    # ------------------------------------------------------- conversions

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        for name, default in self._ELIDE_AT_DEFAULT:
            if d[name] == default:
                del d[name]
        for name, default in self._ELIDE_FLEET_AT_DEFAULT:
            if d["fleet"][name] == default:
                del d["fleet"][name]
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ExperimentSpec":
        d = dict(d)
        data = d.pop("data", {}) or {}
        fleet = d.pop("fleet", {}) or {}
        known = {f.name for f in dataclasses.fields(cls)} - {"data", "fleet"}
        unknown = set(d) - known
        if unknown:
            # Strict on purpose: a typo'd field ('round' for 'rounds')
            # silently falling back to defaults would run — and cache —
            # a different experiment than the caller believes.
            raise ValueError(
                f"unknown ExperimentSpec field(s) {sorted(unknown)}; "
                f"known: {sorted(known | {'data', 'fleet'})}"
            )
        return cls(data=DataSpec(**data), fleet=FleetSpec(**fleet), **d)

    def replace(self, **kw) -> "ExperimentSpec":
        return dataclasses.replace(self, **kw)

    def run_config(self) -> RunConfig:
        """Lower onto the trainers' RunConfig (lr drives both blocks)."""
        return RunConfig(
            n_clients=self.fleet.population,
            n_population=self.fleet.n_population,
            cohort=self.fleet.cohort,
            tau=self.tau,
            rounds=self.rounds,
            batch_size=self.batch_size,
            lr_base=self.lr,
            lr_modular=self.lr,
            d_fusion=self.d_fusion,
            dirichlet_alpha=self.fleet.alpha,
            codec=self.codec,
            participation=self.participation,
            max_staleness=self.max_staleness,
            broadcast=self.broadcast,
            mode=self.mode,
            trace=self.trace,
            tick=self.tick,
        )

    # ------------------------------------------------------------ hashing

    def canonical_json(self) -> str:
        """Key-sorted, whitespace-free JSON of ``to_dict()`` — the bytes
        ``spec_hash`` digests.  json round-trips every field type used
        here (str/int/float/bool/None) exactly, so the hash is stable
        across processes and platforms."""
        return json.dumps(self.to_dict(), sort_keys=True,
                          separators=(",", ":"))

    def spec_hash(self) -> str:
        """12-hex content address of the spec (sha256 prefix).

        Filesystem- and shell-safe by construction — this replaces the
        free-form filename tags as the results-cache key."""
        digest = hashlib.sha256(self.canonical_json().encode("utf-8"))
        return digest.hexdigest()[:12]
