"""Central configuration for the IFL framework.

ModelConfig describes every assigned architecture via a *layer program*:
an optional unstacked ``prefix`` of layers followed by ``num_groups``
repetitions of a ``group_pattern`` (a tuple of LayerSpec). The repeated
groups are parameterized with a stacked leading ``(num_groups,)`` dim and
executed with ``lax.scan`` so HLO size stays O(pattern), not O(layers) —
required to keep 126-layer/512-device compiles tractable.

The IFL fusion layer (the paper's core interface) cuts the layer program at
a *group boundary* (``fusion_cut_groups``): everything below (embedding,
prefix, groups[:cut], fusion in-projection) is the personalized *base
block*; everything above (fusion out-projection, groups[cut:], final norm,
LM head) is the generalized *modular block*. ``d_fusion`` is standardized
across clients (paper: 432; LLM default: 2048).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Layer program
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LayerSpec:
    """One layer of the network: a sequence mixer plus a channel mixer."""

    mixer: str = "attn"  # 'attn' | 'mamba' | 'mlstm' | 'slstm'
    ffn: str = "dense"  # 'dense' | 'moe' | 'none'
    window: int = -1  # -1 = global causal attention; >0 = sliding window
    use_rope: bool = True  # False => NoPE (llama4 global layers)
    cross_attn: bool = False  # decoder cross-attention (enc-dec only)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input shape."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


INPUT_SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"  # dense | moe | ssm | hybrid | vlm | audio
    source: str = ""  # citation for the assigned config

    # Transformer core.
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0  # 0 => d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 1024
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm: str = "rmsnorm"  # rmsnorm | layernorm | nonparam_ln  (olmo)
    act: str = "silu"  # silu | gelu
    rope_theta: float = 10000.0
    rope_type: str = "rope"  # rope | mrope | none
    mrope_sections: Tuple[int, ...] = ()  # qwen2-vl: (t, h, w) head_dim split

    # Layer program (see module docstring). The base/modular boundary IS
    # the IFL fusion cut: layers = prefix (unstacked, base) +
    # base_pattern×base_groups (stacked, base) + mod_pattern×mod_groups
    # (stacked, modular). Empty patterns => uniform ('attn','dense')
    # program split evenly at num_layers//2.
    prefix_pattern: Tuple[LayerSpec, ...] = ()
    base_pattern: Tuple[LayerSpec, ...] = ()
    base_groups: int = 0
    mod_pattern: Tuple[LayerSpec, ...] = ()
    mod_groups: int = 0

    use_qk_norm: bool = False  # gemma3-style per-head q/k RMSNorm

    # MoE.
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0  # expert hidden dim (deepseek: 2048)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # MLA (deepseek-v3).
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # SSM / xLSTM.
    ssm_d_state: int = 16
    ssm_d_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0  # 0 => ceil(d_model/16)
    mlstm_qk_dim: int = 0  # 0 => d_model // 2
    mlstm_chunk: int = 64

    # Encoder-decoder (seamless).
    is_encdec: bool = False
    enc_layers: int = 0
    enc_seq_len: int = 0  # stub frontend frame count at train shapes

    # Multimodal stub frontends (the one permitted carve-out).
    num_image_tokens: int = 0  # qwen2-vl: leading patch-embedding tokens

    # Multi-token prediction aux head (deepseek-v3 optional feature).
    use_mtp: bool = False
    mtp_depth: int = 1

    # IFL fusion interface.
    d_fusion: int = 2048

    # Numerics.
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    logit_softcap: float = 0.0  # gemma-style final-logit softcapping
    remat: str = "group"  # 'none' | 'group' | 'layer' (checkpoint granularity)
    ce_chunk: int = 0  # >0: chunked cross-entropy (never materialize the
    # full (tokens, vocab) logits — §Perf lever for 128k-262k vocabs)

    # Attention blocking (memory control; also the Pallas kernel tile).
    q_block: int = 512
    kv_block: int = 512

    # ----------------------------------------------------------------- utils

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def resolved_dt_rank(self) -> int:
        return self.ssm_dt_rank or -(-self.d_model // 16)

    @property
    def resolved_mlstm_qk(self) -> int:
        return self.mlstm_qk_dim or self.d_model // 2

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def _resolved_program(self):
        """(prefix, base_pattern, base_groups, mod_pattern, mod_groups)."""
        if not self.base_pattern and not self.mod_pattern:
            bg = max(1, self.num_layers // 2)
            return (), (LayerSpec(),), bg, (LayerSpec(),), self.num_layers - bg
        return (
            self.prefix_pattern,
            self.base_pattern,
            self.base_groups,
            self.mod_pattern,
            self.mod_groups,
        )

    def layer_specs(self) -> Tuple[LayerSpec, ...]:
        """Full per-layer program: prefix, base groups, modular groups."""
        pre, bp, bg, mp, mg = self._resolved_program()
        return pre + bp * bg + mp * mg

    @property
    def fusion_cut_layer(self) -> int:
        """Index of the first modular layer (= number of base layers)."""
        pre, bp, bg, _, _ = self._resolved_program()
        return len(pre) + len(bp) * bg

    def validate(self) -> "ModelConfig":
        specs = self.layer_specs()
        assert len(specs) == self.num_layers, (
            f"{self.name}: layer program covers {len(specs)} layers, "
            f"config says {self.num_layers}"
        )
        if any(s.ffn == "moe" for s in specs):
            assert self.num_experts > 0 and self.num_experts_per_tok > 0
        if self.use_mla:
            assert self.kv_lora_rank > 0 and self.qk_rope_head_dim > 0
        # IFL privacy: cross-attention (needs client-local encoder output)
        # may only appear below the fusion cut.
        _, _, _, mp, _ = self._resolved_program()
        assert not any(s.cross_attn for s in mp), (
            f"{self.name}: cross-attn layers above the fusion cut would "
            "leak encoder activations across the IFL boundary"
        )
        return self

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # A reduced variant of the same family for CPU smoke tests:
    # 1 base + 1 modular pattern-group, d_model<=256, <=4 experts.
    def reduced(self) -> "ModelConfig":
        d_model = min(self.d_model, 256)
        num_heads = min(self.num_heads, 4)
        num_kv = max(1, min(self.num_kv_heads, num_heads))
        num_kv = num_heads // max(1, num_heads // num_kv)  # keep divisibility
        pre, bp, _, mp, _ = self._resolved_program()
        kw = dict(
            name=self.name + "-smoke",
            num_layers=len(pre) + len(bp) + len(mp),
            d_model=d_model,
            num_heads=num_heads,
            num_kv_heads=num_kv,
            head_dim=min(self.resolved_head_dim, 64),
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            d_fusion=min(self.d_fusion, 128),
            q_block=64,
            kv_block=64,
            mlstm_chunk=16,
            compute_dtype="float32",
            remat="none",
        )
        if self.num_experts:
            kw.update(
                num_experts=min(self.num_experts, 4),
                num_experts_per_tok=min(self.num_experts_per_tok, 2),
                moe_d_ff=min(self.moe_d_ff or self.d_ff, 256) or 256,
            )
        if self.use_mla:
            kw.update(
                q_lora_rank=min(self.q_lora_rank, 96) or 0,
                kv_lora_rank=min(self.kv_lora_rank, 64),
                qk_nope_head_dim=32,
                qk_rope_head_dim=16,
                v_head_dim=32,
                head_dim=0,
            )
        if self.is_encdec:
            kw.update(enc_layers=2, enc_seq_len=min(self.enc_seq_len, 64))
        if self.num_image_tokens:
            kw.update(num_image_tokens=16)
        if self.mrope_sections:
            hd = min(self.resolved_head_dim, 64)
            kw.update(mrope_sections=(hd // 4, hd // 8, hd // 8))
        # Shrink windows so sliding-window layers differ from global even
        # at smoke sequence lengths.
        def shrink(s: LayerSpec) -> LayerSpec:
            return dataclasses.replace(s, window=32 if s.window > 0 else s.window)

        kw["prefix_pattern"] = tuple(shrink(s) for s in pre)
        kw["base_pattern"] = tuple(shrink(s) for s in bp)
        kw["base_groups"] = 1
        kw["mod_pattern"] = tuple(shrink(s) for s in mp)
        kw["mod_groups"] = 1
        return self.replace(**kw).validate()


# ---------------------------------------------------------------------------
# Run config (paper hyper-parameters live here)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RunConfig:
    """Hyper-parameters of one collaborative-training run.

    Shared by EVERY scheme (IFL, FSL, FL-1/FL-2, SPMD IFL) — it used to
    be named ``IFLConfig``, which was misleading precisely because the
    non-IFL trainers consume it too.  ``IFLConfig`` remains available as
    a deprecated alias (module ``__getattr__``); new code — and the
    ``repro.api.ExperimentSpec`` front door, which builds one of these
    per run — should say ``RunConfig``.
    """

    n_clients: int = 4  # paper: N = 4
    tau: int = 10  # paper: τ = 10 local base-block steps per round
    rounds: int = 200  # paper: T = 200
    batch_size: int = 32  # paper: B = 32
    lr_base: float = 0.01  # paper: η_b
    lr_modular: float = 0.01  # paper: η_m
    d_fusion: int = 432  # paper's standardized fusion output dim
    dirichlet_alpha: float = 0.5  # paper's non-IID concentration
    optimizer: str = "sgd"  # paper uses plain SGD
    codec: str = "fp32"  # wire codec for z (see repro.core.codec)
    # Participation schedule for the round engine (repro.core.rounds):
    # 'full' | 'k<K>' | 'bern<p>' | 'straggle(<frac>,<period>)'.
    participation: str = "full"
    # Fusion-cache staleness bound in rounds (None = never evict;
    # 0 = fresh uploads only). See rounds.py for the exact semantics.
    max_staleness: Optional[int] = None
    # Downlink policy for the fusion broadcast (repro.core.exchange):
    # 'full' ships the whole valid cache to every participant; 'delta'
    # ships each entry once — clients mirror the server cache, so the
    # decoded training signal is identical at a fraction of the bytes.
    broadcast: str = "full"
    # Round clocking (repro.core.rounds). 'sync' is the paper's barriered
    # round loop; 'async' drives the engine from an ArrivalTrace: clients
    # upload on their own clocks, the server fuses whatever arrived each
    # fixed ``tick`` of simulated time. Async requires ``trace`` (e.g.
    # 'poisson(0.5)', 'pareto(1.2,0.5)', 'replay:<path>') and uses the
    # trace — not ``participation`` — to decide who shows up.
    mode: str = "sync"
    trace: str = ""
    tick: float = 1.0
    # Population regime (repro.core.population): n_population sizes the
    # fleet at N (0 = off, fleet is n_clients) and cohort caps per-round
    # admission at C — device state stays C-shaped, per-slot carried
    # state pages through the host-side population store.  When
    # n_population is set, n_clients is lowered to N by the spec front
    # door (the trainers still size everything off the clients handed
    # to them).
    n_population: int = 0
    cohort: int = 0


def __getattr__(name: str):
    """PEP 562 deprecated alias: ``IFLConfig`` -> :class:`RunConfig`.

    The old name configured the FL/FSL baselines too, which is exactly
    why it was renamed; keep it importable so external call sites and
    cached scripts don't break, but tell them.
    """
    if name == "IFLConfig":
        import warnings

        warnings.warn(
            "repro.config.IFLConfig is deprecated: it configures every "
            "scheme (FL/FSL/IFL), not just IFL — use repro.config."
            "RunConfig (same fields) or the repro.api.ExperimentSpec "
            "front door.",
            DeprecationWarning,
            stacklevel=2,
        )
        return RunConfig
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
