"""Host-side population store — paged per-slot state behind fixed-shape cohorts.

The device side of a population-scale run must never see N: every SPMD
array stays cohort-shaped (``P('client', ...)`` sized C), and the eager
trainers must never materialize N clients up front.  This module owns
the two host-side pieces that make that possible:

  PopulationStore   slot -> pytree mapping with lazy deterministic init,
                    a gather (``page_in``: stack C slots into one
                    cohort-shaped device tree) and a scatter
                    (``page_out``: unstack the cohort back into exactly
                    the slots that ran — untouched slots are bitwise
                    untouched), plus ``max_staleness`` aging so memory
                    is bounded by the working set, not the population.
  LazyFleet         a Sequence of clients materialized on first touch —
                    the eager trainers' population fleet (N=10^4 cannot
                    afford N param inits when only C slots train/round).

Determinism contract: ``init_fn(slot)`` must be a pure function of the
slot index (e.g. ``fold_in(key, slot)``), so an entry evicted by aging
re-initializes to exactly the state a never-seen slot would get — a
client that ages out and rejoins is indistinguishable from a fresh one.

The trainers keep *model parameters* un-aged (a real deployment holds
them on-device at the client; the simulation's analogue is the lazy
fleet) and age the *exchange-plane* carried state — EF residuals, delta
mirrors, fusion-cache entries — which is what actually scales with the
payload size (see ``repro.core.exchange``).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["PopulationStore", "LazyFleet"]


class PopulationStore:
    """Slot-indexed host store of per-slot pytrees with paged cohorts.

    ``init_fn(slot)`` materializes a slot's state on first access and
    after aging eviction; it must be deterministic in ``slot``.  Leaves
    are stored as host numpy arrays (decoupled copies — paging out a
    cohort never pins the cohort-shaped device buffer in memory).
    """

    def __init__(self, n_population: int,
                 init_fn: Callable[[int], Any], *,
                 max_staleness: Optional[int] = None):
        if n_population < 1:
            raise ValueError(
                f"n_population must be >= 1, got {n_population}"
            )
        if max_staleness is not None and max_staleness < 0:
            raise ValueError(
                f"max_staleness must be >= 0, got {max_staleness}"
            )
        self.n_population = int(n_population)
        self.init_fn = init_fn
        self.max_staleness = max_staleness
        self._state: Dict[int, Any] = {}
        self._last_seen: Dict[int, int] = {}

    # -- dict-ish surface ------------------------------------------------

    def __len__(self) -> int:
        return len(self._state)

    def __contains__(self, slot: int) -> bool:
        return int(slot) in self._state

    def slots(self) -> List[int]:
        """Sorted slot indices currently materialized."""
        return sorted(self._state)

    def get(self, slot: int) -> Any:
        """This slot's state, materializing it on first access."""
        slot = self._check(slot)
        if slot not in self._state:
            self._state[slot] = jax.tree.map(
                np.asarray, self.init_fn(slot)
            )
        return self._state[slot]

    def put(self, slot: int, state: Any,
            round_idx: Optional[int] = None) -> None:
        slot = self._check(slot)
        self._state[slot] = jax.tree.map(np.asarray, state)
        if round_idx is not None:
            self._last_seen[slot] = int(round_idx)

    def _check(self, slot: int) -> int:
        slot = int(slot)
        if not 0 <= slot < self.n_population:
            raise IndexError(
                f"slot {slot} out of range for a population of "
                f"{self.n_population}"
            )
        return slot

    # -- gather / scatter ------------------------------------------------

    def page_in(self, slots: Sequence[int]) -> Any:
        """Gather: stack the given slots' trees into one cohort-shaped
        tree with leading axis ``len(slots)`` (position i <- slots[i]).
        Repeated slots are legal — cohort padding repeats a slot under a
        False mask."""
        trees = [self.get(s) for s in slots]
        if not trees:
            raise ValueError("page_in needs at least one slot")
        return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)

    def page_out(self, slots: Sequence[int], cohort_tree: Any,
                 round_idx: Optional[int] = None) -> None:
        """Scatter: unstack cohort positions back into the store —
        position i -> slots[i], for exactly ``len(slots)`` leading
        positions.  Trailing cohort padding (positions beyond
        ``len(slots)``) is dropped; slots not named are untouched.
        Leaves are copied so no slot's state aliases the (C, ...)
        cohort buffer."""
        host = jax.tree.map(np.asarray, cohort_tree)
        for i, s in enumerate(slots):
            self.put(s, jax.tree.map(lambda a, i=i: np.array(a[i]), host),
                     round_idx)

    # -- aging -----------------------------------------------------------

    def touch(self, slot: int, round_idx: int) -> None:
        self._last_seen[self._check(slot)] = int(round_idx)

    def prune(self, round_idx: int) -> List[int]:
        """Evict slots not seen within ``max_staleness`` rounds; returns
        the evicted slot indices.  Evicted slots re-materialize through
        ``init_fn`` on next access (deterministic, so rejoin == fresh).
        Slots never stamped with a round are kept — aging only applies
        to paged traffic."""
        if self.max_staleness is None:
            return []
        stale = [s for s, r in self._last_seen.items()
                 if round_idx - r > self.max_staleness]
        for s in stale:
            del self._last_seen[s]
            self._state.pop(s, None)
        return sorted(stale)

    # -- snapshot / restore ---------------------------------------------

    def snapshot_state(self):
        """Sparse host view for checkpointing: ``({slot: tree}, {slot:
        last_seen_round})`` over exactly the materialized slots.  The
        trees are the store's own numpy copies — serialize before
        mutating further."""
        return ({s: self._state[s] for s in sorted(self._state)},
                dict(self._last_seen))

    def restore_state(self, state, last_seen) -> None:
        """Inverse of ``snapshot_state`` (slot keys may arrive as str —
        JSON round-trips them that way)."""
        self._state = {
            self._check(int(s)): jax.tree.map(np.asarray, t)
            for s, t in state.items()
        }
        self._last_seen = {
            self._check(int(s)): int(r) for s, r in last_seen.items()
        }

    def memory_bytes(self) -> int:
        """Total bytes of materialized leaf arrays — what the bounded-
        memory acceptance tests measure."""
        total = 0
        for tree in self._state.values():
            total += sum(int(leaf.nbytes)
                         for leaf in jax.tree.leaves(tree))
        return total


class LazyFleet(Sequence):
    """A client list materialized on first touch.

    ``build_fn(slot)`` constructs client ``slot`` (deterministic in the
    slot index); ``len()`` reports the full population so every
    engine/plane sized off the fleet sees N, while only the slots a
    cohort actually draws ever pay model init.
    """

    def __init__(self, n: int, build_fn: Callable[[int], Any]):
        if n < 1:
            raise ValueError(f"fleet size must be >= 1, got {n}")
        self._n = int(n)
        self._build = build_fn
        self._cache: Dict[int, Any] = {}

    def __len__(self) -> int:
        return self._n

    def __getitem__(self, k):
        if isinstance(k, slice):
            return [self[i] for i in range(*k.indices(self._n))]
        k = int(k)
        if k < 0:
            k += self._n
        if not 0 <= k < self._n:
            raise IndexError(
                f"client {k} out of range for a fleet of {self._n}"
            )
        if k not in self._cache:
            self._cache[k] = self._build(k)
        return self._cache[k]

    @property
    def materialized(self) -> List[int]:
        """Sorted slot indices built so far (the touched working set)."""
        return sorted(self._cache)
