"""Structured per-round reports — the Trainer protocol's return type.

Every trainer's ``run_round`` returns a :class:`RoundReport` instead of
an ad-hoc dict: the cross-scheme fields every consumer needs (round
index, cumulative ledger bytes on both legs, who participated) are
typed attributes, while scheme-specific metrics (``base_loss`` /
``mod_loss`` for IFL, ``loss`` for FL/FSL, cache occupancy, ...) ride in
``metrics``.

``RoundReport`` is also a read-only :class:`~collections.abc.Mapping`
over the union of both, so every pre-existing consumer of the old dicts
(``report["base_loss"]``, ``report["participants"]``) keeps working
unchanged — the mapping view is exactly what ``to_dict()`` serializes.

This lives in ``repro.core`` (not ``repro.api``) because the trainers
construct it; ``repro.api`` re-exports it as part of the front door.
"""

from __future__ import annotations

from collections.abc import Mapping
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List


@dataclass
class RoundReport(Mapping):
    """One communication round, as every scheme reports it.

    ``uplink_mb`` / ``downlink_mb`` are the *cumulative* ledger totals
    after this round (the paper's Fig.-2 x-axis is cumulative MB), so a
    round's own cost is the delta between consecutive reports — or
    ``CommLedger.per_round`` for the exact byte split.
    """

    round: int
    uplink_mb: float
    downlink_mb: float
    participants: List[int] = field(default_factory=list)
    metrics: Dict[str, Any] = field(default_factory=dict)

    _FIELDS = ("round", "uplink_mb", "downlink_mb", "participants")

    # -- Mapping view over fields + metrics (back-compat with the dicts
    # -- the trainers used to return) ----------------------------------

    def __getitem__(self, key: str) -> Any:
        if key in self._FIELDS:
            return getattr(self, key)
        return self.metrics[key]

    def __iter__(self) -> Iterator[str]:
        yield from self._FIELDS
        for k in self.metrics:
            if k not in self._FIELDS:
                yield k

    def __len__(self) -> int:
        return len(list(iter(self)))

    # -- serialization -------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        """Plain JSON-able dict (the Mapping view, materialized)."""
        return {k: self[k] for k in self}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "RoundReport":
        d = dict(d)
        return cls(
            round=int(d.pop("round", -1)),
            uplink_mb=float(d.pop("uplink_mb", 0.0)),
            downlink_mb=float(d.pop("downlink_mb", 0.0)),
            participants=[int(k) for k in d.pop("participants", [])],
            metrics=d,
        )
