"""Communication accounting for FL / FSL / IFL.

Analytic per-round byte formulas (paper §IV measures cumulative MB on the
x-axis of Fig. 2) plus a ledger that trainers feed with the *actual* array
sizes they transmit, so the benchmark never drifts from the
implementation. Only bytes that cross the client boundary count —
client-local compute is free, exactly as in the paper.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import numpy as np


def nbytes(tree) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree.leaves(tree))


@dataclass
class CommLedger:
    """Cumulative uplink/downlink bytes, per client and total."""

    uplink: int = 0
    downlink: int = 0
    per_round: List[Dict[str, int]] = field(default_factory=list)
    _round_up: int = 0
    _round_down: int = 0

    def send_up(self, tree):
        self.send_up_bytes(nbytes(tree))

    def send_down(self, tree):
        self.send_down_bytes(nbytes(tree))

    def send_up_bytes(self, b: int):
        """Account ``b`` uplink bytes directly — for trainers whose
        payloads never materialize as host arrays (the SPMD round step
        encodes inside jit; its adapter ledgers the codec's analytic
        ``encoded_nbytes``, which byte-parity tests pin to measured)."""
        self.uplink += b
        self._round_up += b

    def send_down_bytes(self, b: int):
        self.downlink += b
        self._round_down += b

    def end_round(self):
        self.per_round.append(
            {"up": self._round_up, "down": self._round_down}
        )
        self._round_up = 0
        self._round_down = 0

    @property
    def total(self) -> int:
        return self.uplink + self.downlink

    @property
    def uplink_mb(self) -> float:
        return self.uplink / 1e6

    @property
    def downlink_mb(self) -> float:
        return self.downlink / 1e6

    @property
    def total_mb(self) -> float:
        return self.total / 1e6

    def round_mb(self, i: int) -> float:
        """Total (up + down) MB of closed round ``i`` — negative indices
        count from the most recent round, list-style."""
        r = self.per_round[i]
        return (r["up"] + r["down"]) / 1e6


# ------------------------------------------------------------ analytic


# Per shipped delta-broadcast entry: one int32 slot index plus one int32
# upload-round (the staleness anchor a client mirror needs to apply the
# server's eviction rule locally). See repro.core.exchange.
DELTA_SIDECAR_BYTES = 8


def ifl_round_bytes(n_clients: int, batch: int, d_fusion: int,
                    label_bytes: int = 4, act_bytes: int = 4,
                    codec=None, participating: Optional[int] = None,
                    broadcast_entries: Optional[int] = None,
                    broadcast: str = "full",
                    delta_entries: Optional[int] = None,
                    ) -> Dict[str, int]:
    """One IFL round: each participating client uploads (z_k, y_k); the
    server broadcasts the valid fusion-cache entries to the participants.
    Eq.-level match to Algorithm 1 lines 13-21 at full participation.

    ``codec`` (name or ``repro.core.codec.Codec``) switches z to its
    compressed wire format; the formula stays exact — it is the codec's
    own analytic ``encoded_nbytes``, so ledger parity holds per codec.
    ``ef(<codec>)`` error-feedback wrappers change what is IN the
    payload, not its size: identical bytes to the inner codec (the
    residual is client-private and never transmitted). Labels always
    ride uncompressed (int32).

    ``participating`` is the number K of clients that showed up this
    round (default: all N); ``broadcast_entries`` is the number M of
    valid FusionCache entries the server re-broadcasts (default: N —
    the steady state of an unbounded cache).  Uplink is K fresh
    payloads; absent clients transmit and receive nothing (see
    ``repro.core.rounds`` for the cache-staleness semantics).

    ``broadcast`` selects the downlink policy (repro.core.exchange):

      ``"full"``   the M-entry cache goes to each of the K participants
                   (the unicast baseline): ``down = K * M * (z + y)``.
      ``"delta"``  clients mirror the server cache, so the server ships
                   each (slot, payload, y) entry at most ONCE per round
                   — the E entries some participant's mirror lacks, plus
                   a ``DELTA_SIDECAR_BYTES`` slot-index sidecar each:
                   ``down = E * (z + y + sidecar)``.  ``delta_entries``
                   is E — per-round, read it off the trainer's
                   ``shipped_entries`` metric; analytically, it defaults
                   to K, which is exact ONLY at full participation
                   (partial schedules add rejoin catch-up entries — use
                   ``repro.core.exchange.expected_delta_entries`` for an
                   honest schedule-dependent mean)."""
    if codec is not None:
        from repro.core.codec import get_codec

        z = get_codec(codec).encoded_nbytes((batch, d_fusion))
    else:
        z = batch * d_fusion * act_bytes
    y = batch * label_bytes
    k = n_clients if participating is None else participating
    m = n_clients if broadcast_entries is None else broadcast_entries
    up = k * (z + y)
    if broadcast == "full":
        down = k * m * (z + y)  # each participant receives the valid cache
    elif broadcast == "delta":
        e = k if delta_entries is None else delta_entries
        down = e * (z + y + DELTA_SIDECAR_BYTES)
    else:
        raise ValueError(
            f"unknown broadcast policy {broadcast!r}; expected 'full' or "
            "'delta'"
        )
    return {"up": up, "down": down}


def fl_round_bytes(n_clients: int, model_bytes: int,
                   participating: Optional[int] = None) -> Dict[str, int]:
    """FedAvg: full model up per participating client, global model down
    per participating client (absent clients move nothing)."""
    k = n_clients if participating is None else participating
    return {"up": k * model_bytes, "down": k * model_bytes}


def fsl_round_bytes(n_clients: int, batch: int, cut_dim: int,
                    label_bytes: int = 4, act_bytes: int = 4,
                    participating: Optional[int] = None) -> Dict[str, int]:
    """FSL: cut activations + labels up; activation gradients down.
    One client-side update per round (the paper's FSL limitation);
    only the K participating clients exchange anything."""
    k = n_clients if participating is None else participating
    h = batch * cut_dim * act_bytes
    y = batch * label_bytes
    return {"up": k * (h + y), "down": k * h}
