"""The paper's contribution: Interoperable Federated Learning (IFL).

Submodules:
  comm        — communication ledgers + analytic per-round byte formulas
  report      — RoundReport, the structured run_round() return type
                (repro.api is the user-facing front door over all this)
  codec       — fusion-payload wire codecs (fp32/bf16/fp16/int8/int4/
                topk/sketch) + EF21 error-feedback wrapping (ef(<codec>))
  exchange    — the exchange plane: ONE uplink/downlink wire pipeline
                (codec + EF state + FusionCache + ledger + full/delta
                broadcast policy) with an eager and an SPMD backend
  rounds      — participation schedules (full/k-of-N/Bernoulli/straggler),
                arrival traces (periodic/poisson/pareto/replayed logs),
                and the sync RoundEngine / event-driven AsyncRoundEngine
                shared by all trainers
  ifl         — the two-stage IFL algorithm (eager, heterogeneous clients)
  ifl_spmd    — IFL as a single SPMD train_step on the production mesh
  fl          — FedAvg baseline (paper's FL-1/FL-2)
  fsl         — federated split learning baseline
  composition — cross-client modular composition + accuracy matrix
"""

from repro.core.comm import (  # noqa: F401
    DELTA_SIDECAR_BYTES,
    CommLedger,
    ifl_round_bytes,
    fl_round_bytes,
    fsl_round_bytes,
)
from repro.core.exchange import (  # noqa: F401
    ExchangePlane,
    FusionExchange,
    SPMDFusionExchange,
    parse_broadcast,
)
from repro.core.report import RoundReport  # noqa: F401
from repro.core.rounds import (  # noqa: F401
    ArrivalTrace,
    AsyncRoundEngine,
    BernoulliSchedule,
    FullParticipation,
    FusionCache,
    ParetoTrace,
    ParticipationSchedule,
    PeriodicTrace,
    PoissonTrace,
    ReplayTrace,
    RoundEngine,
    StragglerSchedule,
    UniformK,
    expected_async_participants,
    parse_participation,
    parse_trace,
    simulate_sync_wall_clock,
)
from repro.core.codec import (  # noqa: F401
    Codec,
    EFCodec,
    available_codecs,
    get_codec,
)
from repro.core.ifl import Client, IFLTrainer, composition_accuracy  # noqa: F401
from repro.core.fl import FLTrainer  # noqa: F401
from repro.core.fsl import FSLTrainer  # noqa: F401
