"""Interoperable Federated Learning — Algorithm 1, faithfully.

Eager multi-client trainer: each client owns a *different architecture*
(paper Table II), private parameters, and a private non-IID shard. Per
communication round t:

  1. Base-block update  — τ local SGD steps on θ_b only (eq. 7), modular
     frozen, client-local minibatches.
  2. Fusion exchange    — fresh minibatch -> z_k = f_b,k(x_k); client
     *encodes* z_k with the configured wire codec (cfg.codec: fp32 |
     bf16 | fp16 | int8 | int4 | topk | ef(...) | ... — see
     repro.core.codec), uploads (payload_k, y_k); server concatenates
     the encoded payloads and broadcasts (lines 13-21). The ledger
     records exactly the encoded payload bytes — compressed bytes are
     what cross the boundary. Stateful ``ef(...)`` codecs keep an EF21
     residual per client (``self.ef_state[slot]``) that flows through the
     jitted encode: the client transmits encode(z + e) and carries
     e' = (z + e) - decode(...) to the next round, recovering fp32-level
     accuracy under aggressive compression at identical wire bytes.
  3. Modular update     — sequential SGD steps on θ_m, one per cached
     (decode(payload_i), y_i) pair, as pseudocode lines 24-28 (the
     sequential form of eq. 9). The learning signal sees the same
     lossy z_hat every receiver would reconstruct.

Nothing else ever crosses the client boundary: parameters, gradients and
architectures stay private (Table I's last three rows).

Partial participation (cfg.participation: 'full' | 'k<K>' | 'bern<p>' |
'straggle(<frac>,<period>)' — repro.core.rounds) makes rounds honest
about intermittent availability: only participating clients run local
steps, upload fresh payloads, receive the broadcast, and update their
modular blocks. The server's staleness-bounded FusionCache keeps every
client's last-decoded (z_hat, y) so modular updates still train on up
to N pairs when only K upload — absent clients' EF residuals stay
frozen and their bytes never hit the ledger.

The whole wire side — encode/EF/cache/ledger/broadcast-policy — lives
on the exchange plane (repro.core.exchange.FusionExchange); this
trainer's job is the learning steps. cfg.broadcast='delta' switches the
downlink to mirror-sync delta shipping (same decoded training signal,
K entries instead of K×M on the wire).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import RunConfig
from repro.core.exchange import FusionExchange
from repro.core.population import LazyFleet
from repro.core.report import RoundReport
from repro.core.rounds import AsyncRoundEngine, RoundEngine


def softmax_xent(logits, labels):
    lp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(lp, labels[:, None], axis=-1))


@dataclass
class Client:
    """One vendor: private architecture + params + data shard."""

    cid: int
    params: Dict[str, Any]  # {'base': ..., 'modular': ...}
    base_apply: Callable[[Any, jnp.ndarray], jnp.ndarray]
    modular_apply: Callable[[Any, jnp.ndarray], jnp.ndarray]
    data_x: np.ndarray
    data_y: np.ndarray
    loss_fn: Callable = softmax_xent

    @property
    def num_samples(self) -> int:
        return len(self.data_y)


class IFLTrainer:
    def __init__(self, clients: Sequence[Client], cfg: RunConfig,
                 seed: int = 0):
        # A LazyFleet stays lazy (population fleets must never pay N
        # model inits up front); concrete sequences are copied as before.
        self.clients = (clients if isinstance(clients, LazyFleet)
                        else list(clients))
        self.cfg = cfg
        # Population (cohort) regime: cfg.cohort > 0 caps per-round
        # admission at C of the N-client fleet; the plane serves the
        # cohort's fresh uploads only and ages EF residuals/mirrors by
        # max_staleness, so memory follows the working set, not N.
        cohort = getattr(cfg, "cohort", 0) or None
        self._population = cohort is not None
        # The exchange plane owns the wire side (codec + per-client EF
        # residuals + FusionCache + ledger + broadcast policy); the
        # engine owns scheduling (one rng stream for minibatch sampling
        # AND schedule draws, round counter, metrics history).
        self.exchange = FusionExchange(
            cfg.codec, len(self.clients),
            (cfg.batch_size, cfg.d_fusion),
            max_staleness=cfg.max_staleness, broadcast=cfg.broadcast,
            population=self._population,
        )
        # cfg.mode='async' swaps the engine — participants come from an
        # arrival trace coalesced per server tick instead of a schedule
        # draw; run_round() below is clock-agnostic and stays shared.
        if getattr(cfg, "mode", "sync") == "async":
            self.engine = AsyncRoundEngine(
                len(self.clients), cfg.trace, tick=cfg.tick, seed=seed,
                exchange=self.exchange, cohort=cohort,
            )
        else:
            self.engine = RoundEngine(
                len(self.clients), cfg.participation, seed=seed,
                exchange=self.exchange, cohort=cohort,
            )
        self.ledger = self.engine.ledger
        self.rng = self.engine.rng
        self.codec = self.exchange.codec
        # Jitted per-arch steps, built on a client's first participation
        # (keyed by cid: clients sharing an arch share the jit cache) —
        # a population fleet only ever compiles the archs its cohorts
        # actually draw.
        self._base_step = {}
        self._mod_step = {}
        self._fwd_z = {}

    def _ensure_steps(self, c: Client) -> None:
        if c.cid in self._base_step:
            return
        self._base_step[c.cid] = jax.jit(
            functools.partial(self._base_step_impl, c.base_apply,
                              c.modular_apply, c.loss_fn)
        )
        self._mod_step[c.cid] = jax.jit(
            functools.partial(self._mod_step_impl, c.modular_apply,
                              c.loss_fn)
        )
        self._fwd_z[c.cid] = jax.jit(c.base_apply)

    # -- wire-pipeline views (the plane owns them; parity tests and the
    # -- quickstart's EF forensics read them here) ----------------------

    @property
    def ef_state(self):
        return self.exchange.ef_state

    @property
    def _encode_state(self):
        return self.exchange._encode_state

    @property
    def _decode(self):
        return self.exchange._decode

    # ------------------------------------------------------------ steps

    @staticmethod
    def _base_step_impl(base_apply, modular_apply, loss_fn, params, x, y, lr):
        def loss_of_base(base):
            z = base_apply(base, x)
            return loss_fn(modular_apply(params["modular"], z), y)

        loss, g = jax.value_and_grad(loss_of_base)(params["base"])
        new_base = jax.tree.map(lambda p, gg: p - lr * gg, params["base"], g)
        return {"base": new_base, "modular": params["modular"]}, loss

    @staticmethod
    def _mod_step_impl(modular_apply, loss_fn, mod_params, z, y, lr):
        def loss_of_mod(m):
            return loss_fn(modular_apply(m, z), y)

        loss, g = jax.value_and_grad(loss_of_mod)(mod_params)
        return jax.tree.map(lambda p, gg: p - lr * gg, mod_params, g), loss

    # ------------------------------------------------------------ data

    def _sample(self, c: Client) -> Tuple[jnp.ndarray, jnp.ndarray]:
        return self.engine.sample(c, self.cfg.batch_size)

    # ------------------------------------------------------------ round

    def run_round(self) -> RoundReport:
        cfg = self.cfg
        eng = self.engine
        participants = eng.participants()  # sorted client slots, this round
        losses = []
        # --- Step 1: τ local base-block updates per participating client
        # (eq. 7), reporting the mean loss over the τ steps (τ=0 is a
        # legal fusion-only round: no base steps, loss is NaN by
        # convention). Absent clients are offline: no compute, no bytes.
        for k in participants:
            c = self.clients[k]
            self._ensure_steps(c)
            step_losses = []
            for _ in range(cfg.tau):
                x, y = self._sample(c)
                c.params, loss = self._base_step[c.cid](
                    c.params, x, y, cfg.lr_base
                )
                step_losses.append(loss)
            losses.append(
                float(jnp.mean(jnp.stack(step_losses)))
                if step_losses else float("nan")
            )

        # --- Steps 2-3: fusion-layer outputs on a fresh minibatch, then
        # the exchange plane runs the whole wire pipeline: EF-threaded
        # encode, uplink ledger, decode-once into the server cache.
        # Absent clients' EF residuals stay frozen.
        for k in participants:
            c = self.clients[k]
            x, y = self._sample(c)
            z = self._fwd_z[c.cid](c.params["base"], x)
            assert z.shape[-1] == cfg.d_fusion, (
                f"client {c.cid} fusion dim {z.shape[-1]} != {cfg.d_fusion}"
            )
            self.exchange.upload(int(k), z, y, eng.round_idx)

        # --- Steps 4-5: the server serves the valid cache entries
        # (fresh uploads + absent clients' last payloads within the
        # staleness bound) to the PARTICIPANTS under the configured
        # broadcast policy — full unicast, or delta mirror-sync (same
        # decoded pairs, far fewer downlink bytes). Absent clients are
        # offline and receive nothing.
        Z, Y, entries, shipped = self.exchange.broadcast_round(
            participants, eng.round_idx
        )

        # --- Step 6: modular updates on every cached (z_i, y_i),
        # sequentially, for the participants.
        mod_losses = []
        for k in participants:
            c = self.clients[k]
            mod, ml = c.params["modular"], None
            for z_i, y_i in zip(Z, Y):
                mod, ml = self._mod_step[c.cid](mod, z_i, y_i, cfg.lr_modular)
            if ml is not None:
                c.params = {"base": c.params["base"], "modular": mod}
                mod_losses.append(float(ml))

        staleness = eng.cache.staleness(eng.round_idx)
        metrics = {
            "base_loss": float(np.mean(losses)) if losses else float("nan"),
            "mod_loss": (float(np.mean(mod_losses)) if mod_losses
                         else float("nan")),
            "participants": [int(k) for k in participants],
            "cache_size": len(entries),
            "max_staleness_seen": max(staleness.values(), default=0),
        }
        if self.exchange.broadcast == "delta":
            # E in ifl_round_bytes(broadcast='delta', delta_entries=E):
            # the entries actually shipped this round (fresh + catch-up).
            metrics["shipped_entries"] = len(shipped)
        return eng.end_round(metrics)

    # ---------------------------------------------------- snapshot/restore

    def snapshot(self) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        """(array pytree, JSON-able aux) — the Trainer-protocol state.

        The pytree holds every client's params, the per-client EF
        residuals (slot order), and the server FusionCache as a
        fixed-shape stacked snapshot (``FusionExchange.cache_tree``:
        empty slots carry encode(zeros), the per-slot upload rounds ride
        in the aux) — so a restored run replays the exact byte/metric
        trajectory INCLUDING mid-staleness broadcasts, instead of
        cold-starting the cache. The aux carries the round counter, rng
        bit-generator state, ledger totals, and the plane's delta-mirror
        versions. Persist with ``repro.api.save_trainer``
        (repro.checkpoint).
        """
        if self._population:
            return self._snapshot_population()
        tree = {
            "clients": [c.params for c in self.clients],
            "ef": [self.ef_state[k] for k in range(len(self.clients))],
            "cache": self.exchange.cache_tree(),
        }
        return tree, self.engine.aux_state()

    def _snapshot_population(self):
        """Sparse population snapshot: only the materialized working
        set — touched clients' params, their EF residuals, and the
        server cache's live entries — keyed by slot id, with slot lists
        and entry rounds in the aux.  Memory and checkpoint size follow
        the working set, never N."""
        touched = (self.clients.materialized
                   if isinstance(self.clients, LazyFleet)
                   else list(range(len(self.clients))))
        ef_slots = sorted(int(k) for k in self.ef_state)
        entries = self.exchange.cache._entries
        tree = {
            "clients": {str(k): self.clients[k].params for k in touched},
            "ef": {str(k): self.ef_state[k] for k in ef_slots},
            "cache": {str(s): {"payload": e.payload, "z_hat": e.z_hat,
                               "y": e.y}
                      for s, e in sorted(entries.items())},
        }
        aux = self.engine.aux_state()
        aux["population"] = {
            "clients": [int(k) for k in touched],
            "ef": ef_slots,
            "cache_rounds": {str(s): int(e.round_idx)
                             for s, e in entries.items()},
            "last_upload": {str(s): int(r)
                            for s, r in self.exchange._last_upload.items()},
        }
        return tree, aux

    def snapshot_template(self, extra):
        """Shape template matching a SAVED checkpoint (``load_trainer``
        hook).  A fresh population trainer has touched nothing, so its
        own snapshot cannot serve as the template — materialize exactly
        the saved slot lists instead (lazy init is deterministic, so
        the shapes are the saved run's shapes)."""
        if not self._population:
            return self.snapshot()[0]
        pop = extra.get("population", {})
        z0 = jnp.zeros(self.exchange.z_shape, jnp.float32)
        empty_payload = self.codec.encode(z0)
        y0 = np.zeros((self.exchange.z_shape[0],), np.int64)
        return {
            "clients": {str(int(k)): self.clients[int(k)].params
                        for k in pop.get("clients", [])},
            "ef": {str(int(k)): self.ef_state[int(k)]
                   for k in pop.get("ef", [])},
            "cache": {str(int(s)): {"payload": empty_payload,
                                    "z_hat": z0, "y": y0}
                      for s in pop.get("cache_rounds", {})},
        }

    def restore(self, tree, aux) -> None:
        if self._population:
            self._restore_population(tree, aux)
            return
        for k, (c, p, e) in enumerate(
                zip(self.clients, tree["clients"], tree["ef"])):
            c.params = p
            self.ef_state[k] = e
        self.engine.restore_aux(aux)  # clears the cache (in place) ...
        # ... then the snapshot refills it. Pre-exchange-plane
        # checkpoints carry neither part: degrade to the old cold-cache
        # semantics rather than crashing on the missing keys.
        cache_rounds = aux.get("exchange", {}).get("cache_rounds")
        if tree.get("cache") is not None and cache_rounds is not None:
            self.exchange.restore_cache(tree["cache"], cache_rounds)

    def _restore_population(self, tree, aux) -> None:
        from repro.core.exchange import CacheEntry

        for k, p in tree["clients"].items():
            self.clients[int(k)].params = p
        for k, e in tree.get("ef", {}).items():
            self.ef_state[int(k)] = e
        self.engine.restore_aux(aux)  # clears the cache in place ...
        pop = aux["population"]
        rounds = pop.get("cache_rounds", {})
        self.exchange.cache._entries = {
            int(s): CacheEntry(payload=sub["payload"],
                               z_hat=sub["z_hat"], y=sub["y"],
                               round_idx=int(rounds[s]))
            for s, sub in tree.get("cache", {}).items()
        }
        self.exchange._last_upload = {
            int(s): int(r)
            for s, r in pop.get("last_upload", {}).items()
        }

    # ------------------------------------------------------------ eval

    def _eval_slots(self, cap: int = 16) -> List[int]:
        """Which clients to evaluate: everyone for a concrete fleet;
        for a population fleet, a bounded probe of the touched working
        set (evaluating 10^4 lazily-built clients would materialize
        them all)."""
        n = len(self.clients)
        if not self._population:
            return list(range(n))
        touched = (self.clients.materialized
                   if isinstance(self.clients, LazyFleet) else [])
        slots = touched[:cap]
        return slots if slots else list(range(min(cap, n)))

    @property
    def eval_matrix(self) -> bool:
        """Whether the N x N cross-composition matrix is affordable —
        the runner skips Fig-4 matrices for population fleets."""
        return not self._population

    def evaluate(self, test_x, test_y, batch: int = 512) -> List[float]:
        """Local end-to-end accuracy per client (eq. 10).  Population
        fleets evaluate a bounded probe of touched slots (_eval_slots)."""
        return [
            composition_accuracy(self.clients[k], self.clients[k],
                                 test_x, test_y, batch)
            for k in self._eval_slots()
        ]

    def accuracy_matrix(self, test_x, test_y, batch: int = 512) -> np.ndarray:
        """Fig. 4: entry [k, i] = acc of base_k composed with modular_i.
        Population fleets probe the bounded ``_eval_slots`` subset."""
        slots = self._eval_slots()
        out = np.zeros((len(slots), len(slots)))
        for a, ka in enumerate(slots):
            for b, kb in enumerate(slots):
                out[a, b] = composition_accuracy(
                    self.clients[ka], self.clients[kb], test_x, test_y,
                    batch,
                )
        return out


@functools.lru_cache(maxsize=64)
def _compose_jit(base_apply, modular_apply):
    def fwd(base_params, mod_params, x):
        return modular_apply(mod_params, base_apply(base_params, x))

    return jax.jit(fwd)


def composition_accuracy(base_client: Client, mod_client: Client,
                         test_x, test_y, batch: int = 512) -> float:
    """Accuracy of f_m,i(f_b,k(x)) — eq. (11) cross-vendor inference."""
    fwd = _compose_jit(base_client.base_apply, mod_client.modular_apply)
    correct, total = 0, 0
    for s in range(0, len(test_y), batch):
        x = jnp.asarray(test_x[s : s + batch])
        y = np.asarray(test_y[s : s + batch])
        logits = np.asarray(
            fwd(base_client.params["base"], mod_client.params["modular"], x)
        )
        correct += int((logits.argmax(-1) == y).sum())
        total += len(y)
    return correct / max(total, 1)
