"""Interoperable Federated Learning — Algorithm 1, faithfully.

Eager multi-client trainer: each client owns a *different architecture*
(paper Table II), private parameters, and a private non-IID shard. Per
communication round t:

  1. Base-block update  — τ local SGD steps on θ_b only (eq. 7), modular
     frozen, client-local minibatches.
  2. Fusion exchange    — fresh minibatch -> z_k = f_b,k(x_k); client
     *encodes* z_k with the configured wire codec (cfg.codec: fp32 |
     bf16 | fp16 | int8 | int4 | topk | ef(...) | ... — see
     repro.core.codec), uploads (payload_k, y_k); server concatenates
     the encoded payloads and broadcasts (lines 13-21). The ledger
     records exactly the encoded payload bytes — compressed bytes are
     what cross the boundary. Stateful ``ef(...)`` codecs keep an EF21
     residual per client (``self.ef_state[cid]``) that flows through the
     jitted encode: the client transmits encode(z + e) and carries
     e' = (z + e) - decode(...) to the next round, recovering fp32-level
     accuracy under aggressive compression at identical wire bytes.
  3. Modular update     — N sequential SGD steps on θ_m, one per
     (decode(payload_i), y_i) pair, as pseudocode lines 24-28 (the
     sequential form of eq. 9). The learning signal sees the same
     lossy z_hat every receiver would reconstruct.

Nothing else ever crosses the client boundary: parameters, gradients and
architectures stay private (Table I's last three rows).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import IFLConfig
from repro.core.codec import get_codec
from repro.core.comm import CommLedger


def softmax_xent(logits, labels):
    lp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(lp, labels[:, None], axis=-1))


@dataclass
class Client:
    """One vendor: private architecture + params + data shard."""

    cid: int
    params: Dict[str, Any]  # {'base': ..., 'modular': ...}
    base_apply: Callable[[Any, jnp.ndarray], jnp.ndarray]
    modular_apply: Callable[[Any, jnp.ndarray], jnp.ndarray]
    data_x: np.ndarray
    data_y: np.ndarray
    loss_fn: Callable = softmax_xent

    @property
    def num_samples(self) -> int:
        return len(self.data_y)


class IFLTrainer:
    def __init__(self, clients: Sequence[Client], cfg: IFLConfig,
                 seed: int = 0):
        self.clients = list(clients)
        self.cfg = cfg
        self.ledger = CommLedger()
        self.codec = get_codec(cfg.codec)
        # encode_with_state is a stateless passthrough for plain codecs,
        # so ONE jitted encode path serves the whole registry.
        self._encode_state = jax.jit(self.codec.encode_with_state)
        self._decode = jax.jit(
            functools.partial(
                self.codec.decode,
                shape=(cfg.batch_size, cfg.d_fusion),
                dtype=jnp.float32,
            )
        )
        # Per-client EF residual (empty pytree for stateless codecs).
        # Client-private, never transmitted, never counted by the ledger.
        self.ef_state = {
            c.cid: self.codec.init_state((cfg.batch_size, cfg.d_fusion))
            for c in clients
        }
        self.rng = np.random.default_rng(seed)
        self._base_step = {}
        self._mod_step = {}
        for c in self.clients:
            self._base_step[c.cid] = jax.jit(
                functools.partial(self._base_step_impl, c.base_apply,
                                  c.modular_apply, c.loss_fn)
            )
            self._mod_step[c.cid] = jax.jit(
                functools.partial(self._mod_step_impl, c.modular_apply,
                                  c.loss_fn)
            )
            self._fwd_z = getattr(self, "_fwd_z", {})
            self._fwd_z[c.cid] = jax.jit(c.base_apply)

    # ------------------------------------------------------------ steps

    @staticmethod
    def _base_step_impl(base_apply, modular_apply, loss_fn, params, x, y, lr):
        def loss_of_base(base):
            z = base_apply(base, x)
            return loss_fn(modular_apply(params["modular"], z), y)

        loss, g = jax.value_and_grad(loss_of_base)(params["base"])
        new_base = jax.tree.map(lambda p, gg: p - lr * gg, params["base"], g)
        return {"base": new_base, "modular": params["modular"]}, loss

    @staticmethod
    def _mod_step_impl(modular_apply, loss_fn, mod_params, z, y, lr):
        def loss_of_mod(m):
            return loss_fn(modular_apply(m, z), y)

        loss, g = jax.value_and_grad(loss_of_mod)(mod_params)
        return jax.tree.map(lambda p, gg: p - lr * gg, mod_params, g), loss

    # ------------------------------------------------------------ data

    def _sample(self, c: Client) -> Tuple[jnp.ndarray, jnp.ndarray]:
        idx = self.rng.integers(0, c.num_samples, size=self.cfg.batch_size)
        return jnp.asarray(c.data_x[idx]), jnp.asarray(c.data_y[idx])

    # ------------------------------------------------------------ round

    def run_round(self) -> Dict[str, float]:
        cfg = self.cfg
        losses = []
        # --- Step 1: τ local base-block updates per client (eq. 7),
        # reporting the mean loss over the τ steps (τ=0 is a legal
        # fusion-only round: no base steps, loss is NaN by convention).
        for c in self.clients:
            step_losses = []
            for _ in range(cfg.tau):
                x, y = self._sample(c)
                c.params, loss = self._base_step[c.cid](
                    c.params, x, y, cfg.lr_base
                )
                step_losses.append(loss)
            losses.append(
                float(jnp.mean(jnp.stack(step_losses)))
                if step_losses else float("nan")
            )

        # --- Steps 2-3: fusion-layer outputs on a fresh minibatch, encode
        # with the wire codec (threading the client's EF residual, if the
        # codec carries one), upload the *encoded* payload.
        payloads, Z, Y = [], [], []
        for c in self.clients:
            x, y = self._sample(c)
            z = self._fwd_z[c.cid](c.params["base"], x)
            assert z.shape[-1] == cfg.d_fusion, (
                f"client {c.cid} fusion dim {z.shape[-1]} != {cfg.d_fusion}"
            )
            payload, self.ef_state[c.cid] = self._encode_state(
                z, self.ef_state[c.cid]
            )
            self.ledger.send_up((payload, y))  # the ONLY uplink bytes in IFL
            payloads.append(payload)
            # Every receiver reconstructs the same z_hat; decode once and
            # train the modular blocks on it so the learning signal sees
            # exactly what crossed the wire.
            Z.append(self._decode(payload))
            Y.append(y)

        # --- Steps 4-5: server concatenates the encoded payloads and
        # broadcasts them to all clients (downlink stays compressed too).
        for _ in self.clients:
            self.ledger.send_down((payloads, Y))

        # --- Step 6: modular updates on every (z_i, y_i), sequentially.
        mod_losses = []
        for c in self.clients:
            mod = c.params["modular"]
            for z_i, y_i in zip(Z, Y):
                mod, ml = self._mod_step[c.cid](mod, z_i, y_i, cfg.lr_modular)
            c.params = {"base": c.params["base"], "modular": mod}
            mod_losses.append(float(ml))

        self.ledger.end_round()
        return {
            "base_loss": float(np.mean(losses)),
            "mod_loss": float(np.mean(mod_losses)),
            "uplink_mb": self.ledger.uplink_mb,
        }

    # ------------------------------------------------------------ eval

    def evaluate(self, test_x, test_y, batch: int = 512) -> List[float]:
        """Local end-to-end accuracy per client (eq. 10)."""
        accs = []
        for c in self.clients:
            accs.append(
                composition_accuracy(c, c, test_x, test_y, batch)
            )
        return accs

    def accuracy_matrix(self, test_x, test_y, batch: int = 512) -> np.ndarray:
        """Fig. 4: entry [k, i] = acc of base_k composed with modular_i."""
        n = len(self.clients)
        out = np.zeros((n, n))
        for a, ck in enumerate(self.clients):
            for b, ci in enumerate(self.clients):
                out[a, b] = composition_accuracy(ck, ci, test_x, test_y, batch)
        return out


@functools.lru_cache(maxsize=64)
def _compose_jit(base_apply, modular_apply):
    def fwd(base_params, mod_params, x):
        return modular_apply(mod_params, base_apply(base_params, x))

    return jax.jit(fwd)


def composition_accuracy(base_client: Client, mod_client: Client,
                         test_x, test_y, batch: int = 512) -> float:
    """Accuracy of f_m,i(f_b,k(x)) — eq. (11) cross-vendor inference."""
    fwd = _compose_jit(base_client.base_apply, mod_client.modular_apply)
    correct, total = 0, 0
    for s in range(0, len(test_y), batch):
        x = jnp.asarray(test_x[s : s + batch])
        y = np.asarray(test_y[s : s + batch])
        logits = np.asarray(
            fwd(base_client.params["base"], mod_client.params["modular"], x)
        )
        correct += int((logits.argmax(-1) == y).sum())
        total += len(y)
    return correct / max(total, 1)
