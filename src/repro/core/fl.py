"""FedAvg baseline (paper's FL-1 / FL-2 variants).

All clients must share one architecture (the FL limitation the paper
highlights): FL-1 deploys client 1's smallest model everywhere, FL-2
client 2's larger one. Per round: τ local SGD steps on the full model,
full-model upload, weighted FedAvg (eq. 4), full-model download.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import IFLConfig
from repro.core.comm import CommLedger
from repro.core.ifl import Client, softmax_xent


class FLTrainer:
    """FedAvg over homogeneous clients (arch cloned from ``template_cid``)."""

    def __init__(self, clients: Sequence[Client], cfg: IFLConfig,
                 seed: int = 0):
        self.clients = list(clients)
        self.cfg = cfg
        self.ledger = CommLedger()
        self.rng = np.random.default_rng(seed)
        c0 = self.clients[0]
        self._step = jax.jit(
            functools.partial(self._step_impl, c0.base_apply,
                              c0.modular_apply, c0.loss_fn)
        )
        # Global model: start from client 0's params.
        self.global_params = jax.tree.map(jnp.copy, c0.params)

    @staticmethod
    def _step_impl(base_apply, modular_apply, loss_fn, params, x, y, lr):
        def loss_of(p):
            return loss_fn(modular_apply(p["modular"], base_apply(p["base"], x)), y)

        loss, g = jax.value_and_grad(loss_of)(params)
        return jax.tree.map(lambda p, gg: p - lr * gg, params, g), loss

    def run_round(self) -> Dict[str, float]:
        cfg = self.cfg
        d_total = sum(c.num_samples for c in self.clients)
        locals_, losses = [], []
        for c in self.clients:
            # server -> client: global model download.
            self.ledger.send_down(self.global_params)
            p = self.global_params
            for _ in range(cfg.tau):
                idx = self.rng.integers(0, c.num_samples, cfg.batch_size)
                x = jnp.asarray(c.data_x[idx])
                y = jnp.asarray(c.data_y[idx])
                p, loss = self._step(p, x, y, cfg.lr_base)
            locals_.append((c.num_samples / d_total, p))
            losses.append(float(loss))
            # client -> server: full model upload.
            self.ledger.send_up(p)
        # FedAvg (eq. 4).
        self.global_params = jax.tree.map(
            lambda *xs: sum(w * x for (w, _), x in zip(locals_, xs)),
            *[p for _, p in locals_],
        )
        self.ledger.end_round()
        return {"loss": float(np.mean(losses)),
                "uplink_mb": self.ledger.uplink_mb}

    def evaluate(self, test_x, test_y, batch: int = 512) -> float:
        c0 = self.clients[0]
        correct, total = 0, 0
        fwd = jax.jit(lambda p, x: c0.modular_apply(
            p["modular"], c0.base_apply(p["base"], x)))
        for s in range(0, len(test_y), batch):
            logits = np.asarray(fwd(self.global_params,
                                    jnp.asarray(test_x[s:s + batch])))
            y = np.asarray(test_y[s:s + batch])
            correct += int((logits.argmax(-1) == y).sum())
            total += len(y)
        return correct / max(total, 1)
