"""FedAvg baseline (paper's FL-1 / FL-2 variants).

All clients must share one architecture (the FL limitation the paper
highlights): FL-1 deploys client 1's smallest model everywhere, FL-2
client 2's larger one. Per round: τ local SGD steps on the full model,
full-model upload, weighted FedAvg (eq. 4), full-model download.

Partial participation (cfg.participation, via the shared round engine)
is classic sampled FedAvg: only the K participating clients download
the global model, train, and upload; aggregation weights are sample
counts normalized over the participants.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import RunConfig
from repro.core.ifl import Client, softmax_xent
from repro.core.report import RoundReport
from repro.core.rounds import RoundEngine


class FLTrainer:
    """FedAvg over homogeneous clients (arch cloned from ``template_cid``)."""

    def __init__(self, clients: Sequence[Client], cfg: RunConfig,
                 seed: int = 0):
        self.clients = list(clients)
        self.cfg = cfg
        self.engine = RoundEngine(len(self.clients), cfg.participation,
                                  seed=seed)
        # FedAvg's exchange is the base plane: full model trees up and
        # down, no codec/cache/policy — but every boundary byte still
        # routes through the one accounting surface.
        self.exchange = self.engine.exchange
        self.ledger = self.engine.ledger
        self.rng = self.engine.rng
        c0 = self.clients[0]
        self._step = jax.jit(
            functools.partial(self._step_impl, c0.base_apply,
                              c0.modular_apply, c0.loss_fn)
        )
        # Global model: start from client 0's params.
        self.global_params = jax.tree.map(jnp.copy, c0.params)

    @staticmethod
    def _step_impl(base_apply, modular_apply, loss_fn, params, x, y, lr):
        def loss_of(p):
            return loss_fn(modular_apply(p["modular"], base_apply(p["base"], x)), y)

        loss, g = jax.value_and_grad(loss_of)(params)
        return jax.tree.map(lambda p, gg: p - lr * gg, params, g), loss

    def run_round(self) -> RoundReport:
        cfg = self.cfg
        eng = self.engine
        participants = eng.participants()
        chosen = [self.clients[k] for k in participants]
        d_total = sum(c.num_samples for c in chosen)
        locals_, losses = [], []
        for c in chosen:
            # server -> client: global model download.
            self.exchange.down(self.global_params)
            p = self.global_params
            step_losses = []
            for _ in range(cfg.tau):
                x, y = eng.sample(c, cfg.batch_size)
                p, loss = self._step(p, x, y, cfg.lr_base)
                step_losses.append(loss)
            locals_.append((c.num_samples / d_total, p))
            # τ=0 is a legal no-op round for a client: no local steps,
            # loss NaN by convention (regression: `loss` used to be
            # unbound here and raised NameError).
            losses.append(
                float(jnp.mean(jnp.stack(step_losses)))
                if step_losses else float("nan")
            )
            # client -> server: full model upload.
            self.exchange.up(p)
        # FedAvg (eq. 4) over the participants. Nothing trained (no
        # participants, or τ=0) => the global model is exactly unchanged
        # rather than re-averaged through float round-off.
        if locals_ and cfg.tau > 0:
            self.global_params = jax.tree.map(
                lambda *xs: sum(w * x for (w, _), x in zip(locals_, xs)),
                *[p for _, p in locals_],
            )
        return eng.end_round({
            "loss": float(np.mean(losses)) if losses else float("nan"),
            "participants": [int(k) for k in participants],
        })

    def snapshot(self):
        """(array pytree, JSON-able aux) — Trainer-protocol state.

        FedAvg's only learned state is the global model; client shards
        and apply fns are reconstructed by the builder, and the engine
        aux (round counter, rng, ledger totals) makes the resumed
        trajectory bitwise identical."""
        return {"global": self.global_params}, self.engine.aux_state()

    def restore(self, tree, aux) -> None:
        self.global_params = tree["global"]
        self.engine.restore_aux(aux)

    def evaluate(self, test_x, test_y, batch: int = 512) -> float:
        c0 = self.clients[0]
        correct, total = 0, 0
        fwd = jax.jit(lambda p, x: c0.modular_apply(
            p["modular"], c0.base_apply(p["base"], x)))
        for s in range(0, len(test_y), batch):
            logits = np.asarray(fwd(self.global_params,
                                    jnp.asarray(test_x[s:s + batch])))
            y = np.asarray(test_y[s:s + batch])
            correct += int((logits.argmax(-1) == y).sum())
            total += len(y)
        return correct / max(total, 1)
