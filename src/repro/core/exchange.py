"""The exchange plane — one uplink/downlink wire pipeline for every trainer.

Every byte that crosses the client boundary in this codebase goes
through one of three exchanges: IFL's fusion-payload pipeline
(encode -> EF residual -> upload -> FusionCache -> broadcast -> decode),
FedAvg's model up/down, and FSL's activation/gradient split. Before this
module, the IFL pipeline was copy-threaded through four trainers
(``ifl.py``, ``ifl_spmd.py``, plus the ``repro.api.spmd`` adapter and
the scheduling engine in ``rounds.py``), so every wire-level change was
a four-site edit. The exchange plane extracts it:

  ``ExchangePlane``        the base plane: the :class:`CommLedger` every
                           trainer routes its boundary bytes through
                           (FL/FSL use it directly — their wire format
                           is just "the pytree you hand it").
  ``FusionExchange``       the eager IFL backend: codec + per-client
                           EF21 residuals + the staleness-bounded
                           :class:`FusionCache` + broadcast policy, with
                           the jitted encode/decode the trainers used to
                           build privately.  Snapshot/restore covers the
                           cache (fixed-shape stacked arrays), so resume
                           no longer cold-starts it.
  ``SPMDFusionExchange``   the SPMD backend: the SAME pipeline as
                           jit-traceable fixed-shape ops — masked encode
                           over carried ``P('client', ...)``-sharded
                           cache/EF state, ONE all-gather along
                           'client', in-program decode — plus host-side
                           analytic byte accounting (the codec's
                           ``encoded_nbytes``, pinned to measured wire
                           bytes by the registry property suite).

Broadcast policy (the downlink axis)
------------------------------------
``broadcast="full"`` is the unicast baseline: every participant receives
the full M-entry valid cache, ``K * M`` entry-sized downlink units per
round.  ``broadcast="delta"`` gives every client a *mirror* of the
server's fusion cache: the server ships each (slot, payload, y) entry at
most once per round — exactly the entries some participant's mirror
lacks (normally the K fresh uploads; catch-up entries when a client
rejoins after missing rounds) — plus a
:data:`repro.core.comm.DELTA_SIDECAR_BYTES` slot-index sidecar per
entry.  Mirror bookkeeping is versioned by upload round and applies the
server's staleness eviction locally, so after every sync a participant's
mirror equals the server's valid cache *by construction*: the decoded
(z_hat, y) pairs the modular update trains on are identical under both
policies, and delta broadcast changes only the downlink bytes.  The
analytic side is ``comm.ifl_round_bytes(..., broadcast=,
delta_entries=)``, in exact per-round parity with the ledger.

Both backends share the mirror/accounting logic (``_DeltaMirrors``), so
eager and SPMD cannot drift on what a round costs.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.codec import Codec, get_codec
from repro.core.comm import DELTA_SIDECAR_BYTES, CommLedger
from repro.kernels import wire_fused

__all__ = [
    "BROADCAST_POLICIES",
    "parse_broadcast",
    "ExchangePlane",
    "CacheEntry",
    "FusionCache",
    "FusionExchange",
    "SPMDFusionExchange",
    "init_ef_state",
    "init_payload_cache",
]


BROADCAST_POLICIES = ("full", "delta")


def parse_broadcast(spec: Optional[str]) -> str:
    """Validate a broadcast-policy spec: ``full`` | ``delta``."""
    if spec is None:
        return "full"
    if spec not in BROADCAST_POLICIES:
        raise ValueError(
            f"unknown broadcast policy {spec!r}; expected one of "
            f"{BROADCAST_POLICIES}"
        )
    return spec


# --------------------------------------------------------------- base plane


class ExchangePlane:
    """Base plane: the one ledger every boundary byte routes through.

    FL/FSL consume it directly — their exchange has no codec, cache, or
    policy, just trees crossing the boundary.  The fusion backends below
    extend it with the full wire pipeline.
    """

    def __init__(self, ledger: Optional[CommLedger] = None):
        self.ledger = ledger if ledger is not None else CommLedger()

    def up(self, tree) -> None:
        """Client -> server: ledger the measured bytes of ``tree``."""
        self.ledger.send_up(tree)

    def down(self, tree) -> None:
        """Server -> client: ledger the measured bytes of ``tree``."""
        self.ledger.send_down(tree)

    def up_bytes(self, b: int) -> None:
        self.ledger.send_up_bytes(b)

    def down_bytes(self, b: int) -> None:
        self.ledger.send_down_bytes(b)

    # -- checkpoint hooks (planes with host state override) -------------

    def aux_state(self) -> Dict[str, Any]:
        """JSON-able plane state beyond the ledger (which the engine aux
        already carries). Empty for the base plane."""
        return {}

    def restore_aux(self, aux: Dict[str, Any]) -> None:
        pass

    # -- aging hook (population-regime planes override) ------------------

    def prune(self, round_idx: int) -> None:
        """Age per-client carried state out of memory.  The round engine
        calls this every ``end_round``; a no-op except for population-
        regime fusion planes (which bound EF residuals and delta mirrors
        by ``max_staleness``)."""
        return None


# ----------------------------------------------------------- fusion cache


@dataclass
class CacheEntry:
    """Last upload of one client slot, as the server decoded it."""

    payload: Any  # the encoded wire payload (what a broadcast re-ships)
    z_hat: Any  # decoded fusion output — what modular updates train on
    y: Any  # labels (ride uncompressed)
    round_idx: int  # round the payload was uploaded (staleness anchor)


class FusionCache:
    """Server-side staleness-bounded cache of decoded fusion payloads.

    One entry per client *slot* (index into the trainer's client list),
    holding the last (payload, z_hat, y) that slot uploaded and the
    round it did so.  ``valid_entries`` returns the slots whose entry is
    at most ``max_staleness`` rounds old — and evicts the rest, so the
    cache never re-serves an expired payload.  See ``repro.core.rounds``
    for the full staleness semantics.
    """

    def __init__(self, max_staleness: Optional[int] = None):
        if max_staleness is not None and max_staleness < 0:
            raise ValueError("max_staleness must be >= 0 or None")
        self.max_staleness = max_staleness
        self._entries: Dict[int, CacheEntry] = {}

    def put(self, slot: int, *, payload, z_hat, y, round_idx: int) -> None:
        self._entries[slot] = CacheEntry(payload, z_hat, y, round_idx)

    def prune(self, round_idx: int) -> List[int]:
        """Evict entries older than ``max_staleness`` from server MEMORY
        (payload + decoded arrays freed, not merely masked out of the
        broadcast) and return the evicted slots.  The broadcast path
        prunes as it reads (:meth:`valid_entries`); the round engine
        also prunes at every ``end_round`` so a long event-driven run
        with idle ticks cannot retain expired payloads just because no
        broadcast consulted the cache."""
        if self.max_staleness is None:
            return []
        expired = [
            s for s, e in self._entries.items()
            if round_idx - e.round_idx > self.max_staleness
        ]
        for s in expired:
            del self._entries[s]
        return expired

    def valid_entries(self, round_idx: int) -> List[Tuple[int, CacheEntry]]:
        """(slot, entry) pairs within the staleness bound, slot-ordered;
        expired entries are evicted as a side effect."""
        self.prune(round_idx)
        return sorted(self._entries.items())

    def staleness(self, round_idx: int) -> Dict[int, int]:
        """Per-slot age (rounds since upload) of the current entries."""
        return {s: round_idx - e.round_idx
                for s, e in sorted(self._entries.items())}

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, slot: int) -> bool:
        return slot in self._entries


# ----------------------------------------------------------- delta mirrors


class _DeltaMirrors:
    """Per-client mirrors of the server fusion cache, versions only.

    A mirror maps slot -> upload round of the entry the client holds
    (the version; one upload per slot per round makes the round a
    monotone version number).  ``sync`` computes, per participant, the
    valid entries its mirror lacks or holds stale, ships the UNION once
    (the delta multicast), and sets every participant's mirror to the
    server's valid cache — which is what makes "same decoded cache state
    as full broadcast" true by construction.  Absent clients' mirrors
    are untouched; their catch-up happens the round they rejoin.
    """

    def __init__(self, n_clients: int):
        self.versions: List[Dict[int, int]] = [{} for _ in range(n_clients)]

    def note_upload(self, slot: int, round_idx: int) -> None:
        """The uploader produced this payload locally — its own mirror
        entry is current without any downlink."""
        self.versions[slot][slot] = int(round_idx)

    def sync(self, participants: Sequence[int],
             valid: Sequence[Tuple[int, int]]) -> List[int]:
        """Ship the delta: slots some participant's mirror lacks at the
        current version.  Returns the sorted shipped slots; every
        participant's mirror becomes the server's valid cache."""
        valid_d = {int(s): int(v) for s, v in valid}
        shipped: set = set()
        for p in participants:
            mine = self.versions[int(p)]
            shipped.update(
                s for s, v in valid_d.items() if mine.get(s) != v
            )
            self.versions[int(p)] = dict(valid_d)
        return sorted(shipped)

    # JSON-able state (manifest ``extra`` turns int keys into strings).

    def aux_state(self) -> List[Dict[str, int]]:
        return [{str(s): int(v) for s, v in m.items()}
                for m in self.versions]

    def restore_aux(self, aux: List[Dict[str, int]]) -> None:
        self.versions = [{int(s): int(v) for s, v in m.items()}
                         for m in aux]


# ------------------------------------------------------------ eager backend


class _LazySlotState(dict):
    """slot -> state dict that materializes entries on first access.

    Population fleets cannot afford N eager ``codec.init_state`` calls
    when only the cohort's slots ever carry a residual; ``init_fn`` must
    be deterministic in the slot (EF init is zeros), so lazy vs eager
    materialization is bitwise-indistinguishable."""

    def __init__(self, init_fn):
        super().__init__()
        self._init = init_fn

    def __missing__(self, slot):
        state = self._init(slot)
        self[slot] = state
        return state


class FusionExchange(ExchangePlane):
    """Eager IFL wire pipeline: codec + EF residuals + cache + policy.

    ``z_shape`` is one client's fusion-output shape
    ``(batch_size, d_fusion)`` — the jitted decode and the EF residuals
    are shape-static per plane.  ``upload`` runs the client-side half
    (EF-threaded encode, uplink ledger, server-side decode-once into the
    cache); ``broadcast_round`` runs the server-side half (staleness
    filter, downlink ledger under the configured policy) and returns the
    decoded (z_hat, y) lists the modular updates train on — identical
    under both policies by construction.
    """

    def __init__(self, codec: Union[str, Codec, None], n_clients: int,
                 z_shape: Tuple[int, ...], *,
                 max_staleness: Optional[int] = None,
                 broadcast: str = "full",
                 ledger: Optional[CommLedger] = None,
                 population: bool = False,
                 fused: Optional[bool] = None):
        super().__init__(ledger)
        self.codec = get_codec(codec)
        self.n_clients = n_clients
        self.z_shape = tuple(z_shape)
        self.broadcast = parse_broadcast(broadcast)
        # Population (cohort) regime: the broadcast serves the round's
        # FRESH cohort uploads only (the device cohort is C-shaped, not
        # N-shaped), and ``prune`` ages EF residuals and delta mirrors
        # out of host memory by ``max_staleness`` — the knobs that keep
        # server AND client memory bounded by the working set at N >> C.
        self.population = bool(population)
        self.cache = FusionCache(max_staleness)
        self.mirrors = _DeltaMirrors(n_clients)
        self._last_upload: Dict[int, int] = {}
        # encode_with_state is a stateless passthrough for plain codecs,
        # so ONE jitted encode path serves the whole registry.  With
        # ``fused`` (None = auto: TPU only), the encode half dispatches
        # to the codec's Pallas epilogue kernel; codecs without a fused
        # scheme return None and silently keep the jnp oracle — the
        # fallback is never an error, and payload structure/bytes are
        # identical either way, so cache, ledger, and decode don't care.
        self.fused, self._fused_interpret = wire_fused.resolve_fused(fused)
        self._encode_state = jax.jit(self._encode_with_state)
        self._decode = jax.jit(
            functools.partial(
                self.codec.decode, shape=self.z_shape, dtype=jnp.float32
            )
        )
        # Per-client EF residual (empty pytree for stateless codecs).
        # Client-private, never transmitted, never counted by the ledger.
        # Keyed by client *slot*, not cid: cids name architectures and
        # repeat when a fleet larger than the four Table-II archs cycles
        # them — each client still owns its own residual.  Materialized
        # lazily (init is zeros, so lazy == eager bitwise): a population
        # fleet only ever pays for the slots that actually upload.
        self.ef_state: Dict[int, Any] = _LazySlotState(
            lambda slot: self.codec.init_state(self.z_shape)
        )

    def _encode_with_state(self, z, state):
        """EF-threaded encode, fused when enabled and supported."""
        if self.fused:
            out = self.codec.fused_encode_with_state(
                z, state, interpret=self._fused_interpret
            )
            if out is not None:
                return out
        return self.codec.encode_with_state(z, state)

    # ------------------------------------------------------------ uplink

    def upload(self, slot: int, z, y, round_idx: int) -> None:
        """One client's fresh fusion upload: EF-threaded encode, ledger
        the encoded payload (+ labels), decode once at the server into
        the cache so every receiver trains on exactly what crossed the
        wire — and so later partial rounds can re-serve it."""
        slot = int(slot)
        payload, self.ef_state[slot] = self._encode_state(
            z, self.ef_state[slot]
        )
        self.up((payload, y))  # the ONLY uplink bytes in IFL
        self.cache.put(slot, payload=payload, z_hat=self._decode(payload),
                       y=y, round_idx=round_idx)
        self.mirrors.note_upload(slot, round_idx)
        self._last_upload[slot] = int(round_idx)

    # ---------------------------------------------------------- downlink

    def broadcast_round(self, participants: Sequence[int], round_idx: int):
        """Serve the valid cache to the participants under the policy.

        Returns ``(Z, Y, entries, shipped)``: the decoded pairs the
        modular updates consume (policy-independent), the (slot, entry)
        list behind them, and the slots the delta policy actually
        shipped (empty under ``full``)."""
        entries = self.cache.valid_entries(round_idx)
        if self.population:
            # Cohort-fresh semantics: the device cohort is C-shaped, so
            # a round trains on (and ships) the cohort's fresh uploads
            # only — the downlink scales in C, never in N.
            entries = [(s, e) for s, e in entries
                       if e.round_idx == round_idx]
        Z = [e.z_hat for _, e in entries]
        Y = [e.y for _, e in entries]
        shipped: List[int] = []
        if self.broadcast == "full":
            payloads = [e.payload for _, e in entries]
            for _ in participants:
                self.down((payloads, Y))
        else:
            shipped = self.mirrors.sync(
                participants, [(s, e.round_idx) for s, e in entries]
            )
            if shipped:
                by_slot = dict(entries)
                self.down(([by_slot[s].payload for s in shipped],
                           [by_slot[s].y for s in shipped]))
                self.down_bytes(len(shipped) * DELTA_SIDECAR_BYTES)
        return Z, Y, entries, shipped

    # ----------------------------------------------------------- aging

    def prune(self, round_idx: int) -> None:
        """Population regime only: age EF residuals and delta mirrors of
        clients whose last upload is older than ``max_staleness`` out of
        host memory.  A re-joining client re-inits its residual to zeros
        (exactly the never-seen state) and its cleared mirror triggers
        the normal delta catch-up, so aging changes memory, not
        semantics.  Legacy (non-population) planes keep every residual
        frozen across absences — bit-for-bit preserved."""
        if not self.population or self.cache.max_staleness is None:
            return
        bound = self.cache.max_staleness
        stale = [s for s, r in self._last_upload.items()
                 if round_idx - r > bound]
        for s in stale:
            del self._last_upload[s]
            self.ef_state.pop(s, None)
            self.mirrors.versions[s].clear()

    # ------------------------------------------------- snapshot / restore

    def cache_tree(self) -> Dict[str, Any]:
        """Fixed-shape array snapshot of the fusion cache.

        The cache's dict-of-slots structure varies round to round, which
        a shape-checked checkpoint template cannot hold; stack all N
        slots instead (empty slots carry ``encode(zeros)`` — the payload
        structure is deterministic from codec + z_shape, exactly like
        the SPMD carried cache), with the per-slot upload rounds riding
        in ``aux_state()`` to mark which slots are real."""
        z0 = jnp.zeros(self.z_shape, jnp.float32)
        empty_payload = self.codec.encode(z0)
        y0 = jnp.zeros((self.z_shape[0],), jnp.int32)
        pays, zhs, ys = [], [], []
        for s in range(self.n_clients):
            e = self.cache._entries.get(s)
            pays.append(e.payload if e is not None else empty_payload)
            zhs.append(jnp.asarray(e.z_hat) if e is not None else z0)
            ys.append(jnp.asarray(e.y) if e is not None else y0)
        return {
            "payload": jax.tree.map(lambda *xs: jnp.stack(xs), *pays),
            "z_hat": jnp.stack(zhs),
            "y": jnp.stack(ys),
        }

    def restore_cache(self, tree: Dict[str, Any],
                      cache_rounds: Sequence[Optional[int]]) -> None:
        """Inverse of ``cache_tree``: rebuild the entries in place (the
        engine and trainer hold references to this cache object)."""
        self.cache._entries = {
            s: CacheEntry(
                payload=jax.tree.map(lambda a: a[s], tree["payload"]),
                z_hat=tree["z_hat"][s],
                y=tree["y"][s],
                round_idx=int(r),
            )
            for s, r in enumerate(cache_rounds) if r is not None
        }

    def aux_state(self) -> Dict[str, Any]:
        return {
            "cache_rounds": [
                int(self.cache._entries[s].round_idx)
                if s in self.cache._entries else None
                for s in range(self.n_clients)
            ],
            "mirrors": self.mirrors.aux_state(),
        }

    def restore_aux(self, aux: Dict[str, Any]) -> None:
        self.mirrors.restore_aux(aux["mirrors"])
        # Entries themselves are arrays: the trainer passes its snapshot
        # tree to ``restore_cache`` (with aux["cache_rounds"]) right
        # after the engine aux restore.


# ------------------------------------------------------------ SPMD backend


_NEVER = 2 ** 30  # age of a never-filled cache slot (always invalid)


def _tree_where(mask, new, old):
    """Per-client select over pytrees whose leaves lead with (N, ...)."""

    def pick(n, o):
        m = mask.reshape(mask.shape + (1,) * (n.ndim - 1))
        return jnp.where(m, n, o)

    return jax.tree.map(pick, new, old)


class SPMDFusionExchange(ExchangePlane):
    """The fusion wire pipeline as one jit-traceable SPMD block.

    ``wire`` is the in-program half — the exact encode -> masked cache
    refresh -> ONE 'client'-axis all-gather -> decode block the jitted
    round step (``ifl_spmd.make_ifl_round_step``) runs; every carried
    leaf (payload cache, EF residual) stays ``P('client', ...)``-sharded
    and fixed-shape, so it checkpoints exactly.  ``account_round`` is
    the host half: it replays the mask stream against a host replica of
    the cache ages (bit-identical to the in-program ``age`` vector, both
    are pure functions of the mask history) and ledgers the codec's
    analytic ``encoded_nbytes`` per boundary crossing — the quantity the
    property suite pins to measured wire bytes — under the same
    full/delta policy and the same ``_DeltaMirrors`` bookkeeping as the
    eager backend.
    """

    def __init__(self, codec: Union[str, Codec, None], mesh, *,
                 n_clients: int, max_staleness: Optional[int] = None,
                 broadcast: str = "full",
                 ledger: Optional[CommLedger] = None,
                 population: bool = False,
                 fused: Optional[bool] = None):
        super().__init__(ledger)
        self.codec = get_codec(codec)
        self.mesh = mesh
        # Fused wire-path dispatch (None = auto: TPU only).  The fused
        # encode flattens the (client, batch) leading axes into kernel
        # rows — for the row-wise scheme family that is exactly the
        # vmapped per-client encode, so payload leaves keep identical
        # shapes/dtypes/bytes and the gather/cache specs are unchanged.
        self.fused, self._fused_interpret = wire_fused.resolve_fused(fused)
        self.n_clients = n_clients
        self.max_staleness = max_staleness
        self.broadcast = parse_broadcast(broadcast)
        # Population (cohort) regime: accounting serves the round's
        # fresh cohort only (valid == participants — the device cohort
        # is C-shaped), and ``prune`` bounds mirror memory by aging.
        self.population = bool(population)
        self.age_bound = (_NEVER - 1 if max_staleness is None
                          else int(max_staleness))
        self.mirrors = _DeltaMirrors(n_clients)
        # Host replica of each slot's last upload round (None = never):
        # the ledger's staleness view, deterministic from the mask
        # stream, matching the carried ``age`` vector in-program.
        self._last_upload: List[Optional[int]] = [None] * n_clients

    # ------------------------------------------------ sharding specs

    def _repl(self, spec_tail):
        from jax.sharding import NamedSharding, PartitionSpec as P

        return NamedSharding(self.mesh, P(*spec_tail))

    def _gather_payload(self, enc, z_ndim, d_fusion):
        """Replicate every payload leaf along 'client' — the all-gather.

        Full-rank leaves (quantized z, top-k values/indices) keep 'data'
        on the per-client batch axis and 'model' on a full-d_fusion last
        axis; sidecars (scales, zero points) are tiny and replicate.
        """

        def spec_of(leaf):
            if leaf.ndim == z_ndim:
                tail = [None] * (leaf.ndim - 1)
                tail[0] = "data"
                if leaf.shape[-1] == d_fusion:
                    tail[-1] = "model"
                return self._repl((None, *tail))
            return self._repl((None,) * leaf.ndim)

        return jax.tree.map(
            lambda a: jax.lax.with_sharding_constraint(a, spec_of(a)), enc
        )

    def _ef_constrain(self, e):
        """Keep the EF residual sharded exactly like z: client-private
        (P leads with 'client'), batch on 'data', features on 'model' —
        no collective ever touches it."""
        tail = [None] * (e.ndim - 1)
        if tail:
            tail[0] = "data"
        if len(tail) >= 2:
            tail[-1] = "model"
        return jax.lax.with_sharding_constraint(
            e, self._repl(("client", *tail))
        )

    def _cache_constrain(self, enc, z_ndim, d_fusion):
        """Keep the carried payload cache sharded like the wire format
        *before* the gather: leading 'client', per-client batch on
        'data', full-d_fusion last axis on 'model'; sidecars client-
        sharded only. The all-gather is what replicates it."""

        def spec_of(leaf):
            if leaf.ndim == z_ndim:
                tail = [None] * (leaf.ndim - 1)
                tail[0] = "data"
                if leaf.shape[-1] == d_fusion:
                    tail[-1] = "model"
                return self._repl(("client", *tail))
            return self._repl(("client",) + (None,) * (leaf.ndim - 1))

        return jax.tree.map(
            lambda a: jax.lax.with_sharding_constraint(a, spec_of(a)), enc
        )

    # ------------------------------------------------ in-program wire

    def wire(self, z, tokens, mask, cache, ef_state):
        """The fusion exchange, traceable inside the jitted round step.

        Quantize-before-all-gather: encode per client, THEN run THE IFL
        collective (all-gather along 'client' = upload+concat+broadcast)
        on the encoded payload, so the cross-client hop moves the
        codec's wire bytes. d_fusion stays 'model'-sharded to keep the
        gathered copy small per device. Decode reconstructs z_hat for
        the modular updates — the learning signal sees the wire loss.
        EF codecs fold the carried residual into the encode and emit
        the next-round residual here, before the gather, so it stays
        client-local. Under partial participation (``mask`` not None)
        the masked encode refreshes participants' cache slots only;
        absent clients' residuals and cache slots pass through
        untouched, and an ``age`` vector weights expired slots 0 — the
        fixed-shape analogue of the eager cache's eviction.

        Returns ``(zg, yg, valid, new_cache, ef_state)`` where ``zg`` /
        ``yg`` are the gathered decoded pairs, ``valid`` the (N,) 0/1
        staleness weights (None at full participation), and
        ``new_cache`` the refreshed carried cache (None likewise).
        """
        wire = self.codec
        if wire.has_state:
            out = (wire.fused_encode_with_state(
                z, ef_state, interpret=self._fused_interpret)
                if self.fused else None)
            if out is None:
                out = jax.vmap(wire.encode_with_state)(z, ef_state)
            enc_new, ef_new = out
            if mask is not None:
                ef_new = _tree_where(mask, ef_new, ef_state)
            ef_state = jax.tree.map(self._ef_constrain, ef_new)
        else:
            enc_new = (wire.fused_encode(
                z, interpret=self._fused_interpret)
                if self.fused else None)
            if enc_new is None:
                enc_new = jax.vmap(wire.encode)(z)
        if mask is None:
            enc = enc_new
            yg_src = tokens
            new_cache = None
            valid = None
        else:
            enc = _tree_where(mask, enc_new, cache["payload"])
            yg_src = jnp.where(
                mask.reshape((-1,) + (1,) * (cache["tokens"].ndim - 1)),
                tokens, cache["tokens"],
            )
            age = jnp.where(
                mask, 0, jnp.minimum(cache["age"], _NEVER - 1) + 1
            ).astype(cache["age"].dtype)
            new_cache = self._cache_constrain(
                {"payload": enc, "tokens": yg_src, "age": age},
                z.ndim, z.shape[-1],
            )
            enc, yg_src = new_cache["payload"], new_cache["tokens"]
            valid = (age <= self.age_bound).astype(jnp.float32)
        enc = self._gather_payload(enc, z.ndim, z.shape[-1])
        zg = jax.vmap(
            lambda p: wire.decode(p, shape=z.shape[1:], dtype=z.dtype)
        )(enc)
        yg = jax.lax.with_sharding_constraint(
            yg_src, self._repl((None, "data", None))
        )
        return zg, yg, valid, new_cache, ef_state

    # ------------------------------------------------ host accounting

    def account_round(self, participants: Sequence[int], round_idx: int,
                      entry_bytes: int) -> Tuple[int, int]:
        """Ledger one round's boundary bytes analytically.

        ``entry_bytes`` is one client's (encoded payload + labels) size.
        Uplink: K fresh entries.  Downlink under ``full``: the M valid
        cache entries to each of the K participants; under ``delta``:
        the mirror-sync union once, each entry plus the slot-index
        sidecar.  Returns ``(valid_entries, shipped_entries)`` —
        ``valid_entries`` matches the in-program ``cache_valid`` metric
        exactly (both replay the same mask stream)."""
        parts = [int(k) for k in participants]
        for k in parts:
            self._last_upload[k] = int(round_idx)
            # As in the eager upload path: the uploader produced this
            # payload locally, so its own mirror entry is current
            # without any downlink (matters for K=1 rounds, where the
            # sole fresh entry must not be shipped back to its producer).
            self.mirrors.note_upload(k, round_idx)
        bound = 0 if self.population else self.age_bound
        valid = [(s, r) for s, r in enumerate(self._last_upload)
                 if r is not None and round_idx - r <= bound]
        self.up_bytes(len(parts) * entry_bytes)
        shipped: List[int] = []
        if self.broadcast == "full":
            self.down_bytes(len(parts) * len(valid) * entry_bytes)
        else:
            shipped = self.mirrors.sync(parts, valid)
            self.down_bytes(
                len(shipped) * (entry_bytes + DELTA_SIDECAR_BYTES)
            )
        return len(valid), len(shipped)

    # ----------------------------------------------------------- aging

    def prune(self, round_idx: int) -> None:
        """Population regime only: forget the mirrors (and upload
        stamps) of clients whose last upload is older than
        ``max_staleness`` — mirror memory stays bounded by the working
        set, and a re-joining client's cleared mirror just triggers the
        normal delta catch-up."""
        if not self.population or self.max_staleness is None:
            return
        for s, r in enumerate(self._last_upload):
            if r is not None and round_idx - r > self.max_staleness:
                self._last_upload[s] = None
                self.mirrors.versions[s].clear()

    # ------------------------------------------------- snapshot / restore

    def cache_tree(self, cache: Dict[str, Any],
                   z_shape: Tuple[int, ...]) -> Dict[str, Any]:
        """Eager-style view of the carried payload cache — the serving
        plane's deployable fusion state.

        ``cache`` is the in-program carry (``init_payload_cache``
        layout: encoded payload + token labels + ages); ``z_shape`` one
        client's fusion-output shape.  Decodes every slot's payload to
        ``z_hat`` so the artifact matches ``FusionExchange.cache_tree``
        ({payload, z_hat, y}) with the ``age`` vector riding along to
        mark which slots are real (age <= ``age_bound``)."""
        zg = jax.vmap(
            lambda p: self.codec.decode(p, shape=tuple(z_shape),
                                        dtype=jnp.float32)
        )(cache["payload"])
        return {
            "payload": cache["payload"],
            "z_hat": zg,
            "y": cache["tokens"],
            "age": cache["age"],
        }

    def aux_state(self) -> Dict[str, Any]:
        return {
            "last_upload": list(self._last_upload),
            "mirrors": self.mirrors.aux_state(),
        }

    def restore_aux(self, aux: Dict[str, Any]) -> None:
        self._last_upload = [
            None if r is None else int(r) for r in aux["last_upload"]
        ]
        self.mirrors.restore_aux(aux["mirrors"])


# ------------------------------------------------------ analytic helpers


def expected_delta_entries(schedule, n_clients: int, *,
                           max_staleness: Optional[int] = None,
                           cohort: Optional[int] = None,
                           rounds: int = 256, seed: int = 0) -> float:
    """Mean entries shipped per delta-broadcast round under ``schedule``.

    Under full participation the steady state is exactly K (this round's
    fresh uploads); under partial participation rejoining clients pull
    catch-up entries, so the true mean sits between K and N and depends
    on the schedule. This replays the schedule's mask stream through a
    real ``SPMDFusionExchange.account_round`` — the exact bookkeeping
    the trainers ledger with — so analytic reports (e.g. the dry-run's
    ``client_boundary`` section) price the delta downlink honestly and
    cannot drift from the implementation.

    With ``cohort=C`` the replay applies the engine's exact cohort draw
    (uniform C-of-available) and accounts through a *population-regime*
    plane, pricing the fresh-cohort downlink the cohort trainers ship.
    """
    rng = np.random.default_rng(seed)
    plane = SPMDFusionExchange(None, None, n_clients=n_clients,
                               max_staleness=max_staleness,
                               broadcast="delta",
                               population=cohort is not None)
    total = 0
    for t in range(rounds):
        parts = np.flatnonzero(schedule.mask(t, n_clients, rng))
        if cohort is not None and len(parts) > cohort:
            parts = np.sort(rng.choice(parts, size=cohort, replace=False))
        total += plane.account_round(parts, t, entry_bytes=0)[1]
        plane.prune(t)
    return total / max(rounds, 1)


# ------------------------------------------------------ carried-state init


def init_ef_state(codec, z_shape: Tuple[int, ...]):
    """Initial carried EF residual for ``make_ifl_round_step``.

    ``z_shape`` is the full stacked fusion-output shape
    (n_clients, Bc, S, d_fusion). Stateless codecs yield an empty
    pytree; their round step does not take the argument at all."""
    return get_codec(codec).init_state(z_shape)


def init_payload_cache(codec, z_shape: Tuple[int, ...],
                       token_shape: Tuple[int, ...], *,
                       dtype=jnp.float32):
    """Initial carried payload cache for a partial-participation step.

    ``z_shape`` is the stacked fusion-output shape (N, Bc, S, d_fusion)
    and ``token_shape`` the stacked fusion-minibatch token shape
    (N, Bc, S). The payload structure/dtypes come from encoding a zero
    z with the wire codec (so the carry signature matches the masked
    encode exactly); every slot starts at age ``_NEVER`` — invalid until
    its client first uploads, regardless of the staleness bound."""
    wire = get_codec(codec)
    payload = jax.vmap(wire.encode)(jnp.zeros(z_shape, dtype))
    return {
        "payload": payload,
        "tokens": jnp.zeros(token_shape, jnp.int32),
        "age": jnp.full((z_shape[0],), _NEVER, jnp.int32),
    }
