"""IFL as a single SPMD program on the production mesh.

One jitted ``ifl_round_step`` = one communication round of Algorithm 1,
on a derived mesh ('client', 'data', 'model'):

  - Every param leaf carries a stacked leading (N,) client dim sharded on
    'client' — heterogeneous *weights* per client by construction (one
    SPMD program implies one architecture; see DESIGN.md §2).
  - Phase 1 (eq. 7): ``lax.scan`` over τ local minibatches; per-client
    grads wrt base only (vmap over the client dim). Gradient all-reduces
    stay INSIDE a client's ('data','model') subgroup.
  - Phase 2 (alg. lines 13-21): fusion outputs z (N, Bc, S, d_fusion) are
    *encoded with the wire codec* (``codec=``: fp32 | bf16 | int8 |
    int8_row | int4 | topk | ef(...) | ... — repro.core.codec), then
    every payload leaf is re-constrained from P('client',...) to
    P(None,...) — ONE all-gather along 'client', moving the *compressed*
    bytes (int8 + fp32 sidecars instead of fp32 activations). That
    collective IS the paper's upload+concat+broadcast, and the only
    traffic crossing the client boundary (= the only inter-pod traffic
    when clients align with pods). Receivers decode in-program, so
    modular updates train on the same lossy z_hat that crossed the wire.
    The int8_row scheme is exactly what the fused Pallas kernel
    (kernels.fusion_proj.fusion_proj_quant_pallas) emits from the
    projection epilogue on TPU.

    Stateful ``ef(...)`` codecs (EF21 error feedback) make the residual
    part of the *carried round state*: the round step takes and returns
    an ``ef_state`` pytree of shape (N, Bc, S, d_fusion) sharded
    P('client', ...), updated INSIDE the jitted program by the same
    encode that produces the payload — encode -> all-gather -> decode
    stays one program with zero extra collectives (the residual is
    client-local and never crosses the 'client' axis). Build the initial
    state with ``init_ef_state``.

    Partial participation (``partial_participation=True``) threads a
    per-round (N,) bool ``mask`` through the same jitted program: the
    gathered payload becomes carried round state — a ``payload_cache``
    holding each client's last encoded payload, its fusion labels, and
    an ``age`` counter, every leaf sharded P('client', ...) exactly like
    the wire format (build it with ``init_payload_cache``). One
    ``jnp.where``-masked encode refreshes participants' cache slots and
    leaves absent clients' slots (and their EF residuals, base/modular
    params, and optimizer state) bitwise frozen; the ONE all-gather then
    moves the cache, so absent clients contribute their last payload at
    zero fresh uplink. Cached entries older than ``max_staleness``
    rounds get weight 0 in the modular update (the eager FusionCache
    evicts them — same staleness semantics, fixed SPMD shapes), and
    never-filled slots are invalid until first upload.
  - Phase 3 (alg. lines 22-31): scan over the N gathered chunks (z_i, y_i),
    each a sequential SGD step on the modular block — the pseudocode's
    per-i update order, which also microbatches the N× modular compute.

The wire pipeline of phase 2 (encode/EF/cache/all-gather/decode) is the
exchange plane's SPMD backend
(``repro.core.exchange.SPMDFusionExchange.wire``); this module composes
it with the learning phases. The same plane's host-side
``account_round`` does the analytic byte ledger for the ``repro.api``
adapter — full or delta-broadcast downlink — so eager and SPMD cannot
drift on what a round costs.

``dp_train_step`` is the FL-equivalent dense baseline (same model, plain
data-parallel step; its grad all-reduce crosses all boundaries) used for
the communication-efficiency comparison. ``prefill_step``/``serve_step``
cover the inference shapes.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.config import ModelConfig
from repro.core.exchange import (  # noqa: F401  (re-exported for callers)
    SPMDFusionExchange,
    _NEVER,
    _tree_where,
    init_ef_state,
    init_payload_cache,
)
from repro.models import modules as nn
from repro.models.transformer import (
    base_forward,
    init_decode_cache,
    init_lm,
    lm_apply,
    lm_decode_step,
    lm_loss,
    modular_forward,
)
from repro.optim import make_optimizer


# ------------------------------------------------------------------ losses


def _modular_loss(mod, cfg: ModelConfig, z, tokens):
    start = cfg.num_image_tokens
    if cfg.ce_chunk:
        from repro.models.transformer import chunked_ce, modular_trunk, mtp_hidden

        h, aux, positions = modular_trunk(mod, cfg, z)
        loss = chunked_ce(mod, cfg, h, tokens, offset=1, start=start)
        if cfg.use_mtp:
            h2 = mtp_hidden(mod, cfg, h, positions)
            loss = loss + 0.3 * chunked_ce(mod, cfg, h2, tokens,
                                           offset=2, start=start)
        return loss + aux
    out = modular_forward(mod, cfg, z)
    if cfg.use_mtp:
        logits, aux, mtp_logits = out
    else:
        logits, aux = out
        mtp_logits = None
    lp = jax.nn.log_softmax(logits[:, start:-1], axis=-1)
    tgt = tokens[:, start + 1 :]
    loss = -jnp.mean(jnp.take_along_axis(lp, tgt[..., None], axis=-1))
    if mtp_logits is not None:
        lp2 = jax.nn.log_softmax(mtp_logits[:, start:-2], axis=-1)
        loss = loss + 0.3 * -jnp.mean(
            jnp.take_along_axis(lp2, tokens[:, start + 2 :][..., None], axis=-1)
        )
    return loss + aux


def _full_loss_wrt_base(base, mod, cfg: ModelConfig, batch):
    z, aux_b = base_forward(base, cfg, batch)
    return _modular_loss(mod, cfg, z, batch["tokens"]) + aux_b


# ------------------------------------------------------------------ round


def make_ifl_round_step(
    cfg: ModelConfig,
    mesh: Mesh,
    *,
    n_clients: int,
    tau: int,
    lr_base: float = 1e-3,
    lr_modular: float = 1e-3,
    optimizer: str = "sgd",
    codec: Optional[str] = None,
    debug_return_zhat: bool = False,
    partial_participation: bool = False,
    max_staleness: Optional[int] = None,
    exchange: Optional[SPMDFusionExchange] = None,
) -> Callable:
    """Build the jittable one-round IFL step for stacked-client params.

    batch leaves: (N, tau+1, Bc, ...) — τ base minibatches + 1 fusion
    minibatch per client. params leaves: (N, ...). ``codec`` selects the
    wire format the 'client'-axis all-gather moves (see module docstring).

    Stateless codecs:  step(params, opt_state, batch)
                         -> (params', opt_state', metrics)
    Stateful  codecs:  step(params, opt_state, batch, ef_state)
                         -> (params', opt_state', metrics, ef_state')
    where ``ef_state`` comes from ``init_ef_state`` and is sharded
    P('client', ...) — the per-client EF21 residual carried round to
    round. ``debug_return_zhat`` adds the pre-encode ``z`` and decoded
    ``z_hat`` to metrics (tests/parity only; never at production shapes).

    ``partial_participation=True`` inserts a bool (N,) ``mask`` and a
    ``payload_cache`` (from ``init_payload_cache``) after ``batch``:

    Stateless: step(params, opt_state, batch, mask, cache)
                 -> (params', opt_state', metrics, cache')
    Stateful : step(params, opt_state, batch, mask, cache, ef_state)
                 -> (params', opt_state', metrics, cache', ef_state')

    Absent clients (mask False) are bitwise frozen — base/modular
    params, optimizer state, and EF residual all keep their previous
    values via ``jnp.where`` — and their cache slot re-enters the
    all-gather unchanged at zero fresh uplink. ``max_staleness`` bounds
    the cache ages admitted to the modular update (None = unbounded;
    matches the eager FusionCache semantics, see repro.core.rounds).

    The wire pipeline itself (encode/EF/cache/gather/decode) is the
    exchange plane's: pass an ``exchange``
    (:class:`repro.core.exchange.SPMDFusionExchange`, as the
    ``repro.api.spmd`` adapter does — its host-side ``account_round``
    then shares codec and staleness semantics with this program by
    construction) or let one be built from ``codec``/``max_staleness``.
    """
    opt = make_optimizer(optimizer)
    if exchange is None:
        # codec=None means fp32 here (get_codec's own default).
        exchange = SPMDFusionExchange(
            codec, mesh, n_clients=n_clients, max_staleness=max_staleness
        )
    else:
        # The plane owns the wire regime; a caller that ALSO passes a
        # conflicting codec/max_staleness would silently get the
        # plane's — fail loudly instead (None = inherit from the plane,
        # so an EXPLICIT codec="fp32" against an int8 plane is caught).
        from repro.core.codec import get_codec

        if (codec is not None
                and get_codec(codec).name != exchange.codec.name):
            raise ValueError(
                f"make_ifl_round_step: codec={codec!r} conflicts with the "
                f"exchange plane's {exchange.codec.name!r}; configure the "
                "codec on the plane"
            )
        if (max_staleness is not None
                and max_staleness != exchange.max_staleness):
            raise ValueError(
                f"make_ifl_round_step: max_staleness={max_staleness!r} "
                f"conflicts with the exchange plane's "
                f"{exchange.max_staleness!r}; configure it on the plane"
            )
    wire = exchange.codec

    def _round_impl(params, opt_state, batch, ef_state, mask, cache):
        base_p, mod_p = params["base"], params["modular"]
        maskf = None if mask is None else mask.astype(jnp.float32)
        n_part = None if mask is None else jnp.maximum(maskf.sum(), 1.0)

        def client_mean(losses):
            """Mean loss over participating clients only."""
            if maskf is None:
                return jnp.mean(losses)
            return (losses * maskf).sum() / n_part

        # ---------------- Phase 1: τ local base-block updates (eq. 7).
        def tau_batch(i_slice):
            return jax.tree.map(lambda a: a[:, i_slice], batch)

        base_batches = jax.tree.map(
            lambda a: jnp.moveaxis(a[:, :tau], 1, 0), batch
        )  # (tau, N, Bc, ...)

        def base_step(carry, mb):
            bp, ost = carry

            def one_client(bp_k, mod_k, mb_k):
                loss, g = jax.value_and_grad(_full_loss_wrt_base)(
                    bp_k, mod_k, cfg, mb_k
                )
                return loss, g

            losses, grads = jax.vmap(one_client)(bp, mod_p, mb)
            new_bp, new_ost = jax.vmap(
                lambda p, g, s: opt.update(p, g, s, lr_base)
            )(bp, grads, ost)
            return (new_bp, new_ost), client_mean(losses)

        (base_new, ost_b), base_losses = jax.lax.scan(
            base_step, (base_p, opt_state["base"]), base_batches
        )
        if mask is None:
            base_p = base_new
        else:
            # Absent clients' base params and optimizer state stay
            # bitwise frozen (they are offline, not just unsampled).
            base_p = _tree_where(mask, base_new, params["base"])
            ost_b = _tree_where(mask, ost_b, opt_state["base"])

        # ---------------- Phase 2: fusion exchange (lines 13-21) — the
        # exchange plane's jit-traceable wire block: EF-threaded masked
        # encode, carried-cache refresh with the staleness weights, THE
        # 'client'-axis all-gather on the encoded payload, in-program
        # decode. See SPMDFusionExchange.wire for the full semantics.
        fusion_mb = jax.tree.map(lambda a: a[:, tau], batch)  # (N, Bc, ...)
        z, _ = jax.vmap(lambda bp_k, mb_k: base_forward(bp_k, cfg, mb_k))(
            base_p, fusion_mb
        )  # (N, Bc, S, d_fusion), sharded P('client','data',...)
        zg, yg, valid, new_cache, ef_state = exchange.wire(
            z, fusion_mb["tokens"], mask, cache, ef_state
        )

        # ---------------- Phase 3: modular updates (lines 22-31).
        def mod_step(carry, chunk):
            mp, ost = carry
            if valid is None:
                z_i, y_i = chunk  # (Bc, S, dF) replicated over 'client'
                w_i = 1.0
            else:
                z_i, y_i, w_i = chunk  # w_i: 0.0 for stale/empty slots

            def one_client(mp_k):
                return jax.value_and_grad(_modular_loss)(mp_k, cfg, z_i, y_i)

            losses, grads = jax.vmap(one_client)(mp)
            new_mp, new_ost = jax.vmap(
                lambda p, g, s: opt.update(p, g, s, lr_modular)
            )(mp, grads, ost)
            if valid is not None:
                # A stale/never-filled chunk must be a true no-op — the
                # fixed-shape analogue of the eager cache's eviction.
                # Select, don't zero the grads: a zero-grad update is
                # NOT identity for stateful optimizers (adamw's
                # bias-corrected momentum still moves params).
                new_mp = jax.tree.map(
                    lambda n, o: jnp.where(w_i > 0, n, o), new_mp, mp)
                new_ost = jax.tree.map(
                    lambda n, o: jnp.where(w_i > 0, n, o), new_ost, ost)
            return (new_mp, new_ost), w_i * client_mean(losses)

        chunks = (zg, yg) if valid is None else (zg, yg, valid)
        (mod_new, ost_m), mod_losses = jax.lax.scan(
            mod_step, (params["modular"], opt_state["modular"]), chunks
        )
        base_loss = jnp.mean(base_losses)
        if mask is None:
            mod_p = mod_new
            mod_loss = jnp.mean(mod_losses)
        else:
            mod_p = _tree_where(mask, mod_new, params["modular"])
            ost_m = _tree_where(mask, ost_m, opt_state["modular"])
            mod_loss = mod_losses.sum() / jnp.maximum(valid.sum(), 1.0)
            # Empty rounds (nobody up / nothing valid) report NaN, the
            # eager trainers' convention — not a spurious 0.0 loss.
            empty = maskf.sum() == 0
            base_loss = jnp.where(empty, jnp.nan, base_loss)
            mod_loss = jnp.where(
                empty | (valid.sum() == 0), jnp.nan, mod_loss)

        new_params = {"base": base_p, "modular": mod_p}
        new_opt = {"base": ost_b, "modular": ost_m}
        metrics = {
            "base_loss": base_loss,
            "mod_loss": mod_loss,
        }
        if mask is not None:
            metrics["participating"] = maskf.sum()
            metrics["cache_valid"] = valid.sum()
        if debug_return_zhat:
            metrics["z"] = z
            metrics["z_hat"] = zg
        return new_params, new_opt, metrics, new_cache, ef_state

    if partial_participation and wire.has_state:
        def round_step(params, opt_state, batch, mask, cache, ef_state):
            p, o, m, c2, e2 = _round_impl(
                params, opt_state, batch, ef_state, mask, cache)
            return p, o, m, c2, e2
    elif partial_participation:
        def round_step(params, opt_state, batch, mask, cache):
            p, o, m, c2, _ = _round_impl(
                params, opt_state, batch, (), mask, cache)
            return p, o, m, c2
    elif wire.has_state:
        def round_step(params, opt_state, batch, ef_state):
            p, o, m, _, e2 = _round_impl(
                params, opt_state, batch, ef_state, None, None)
            return p, o, m, e2
    else:
        def round_step(params, opt_state, batch):
            p, o, m, _, _ = _round_impl(
                params, opt_state, batch, (), None, None)
            return p, o, m

    return round_step


def init_ifl_state(key, cfg: ModelConfig, *, n_clients: int,
                   optimizer: str = "sgd"):
    """Stacked-client params + per-block optimizer state.

    The optimizer init is vmapped over the client axis so EVERY state
    leaf leads with (N, ...) — adamw's scalar step counter included —
    matching the per-client vmap the round step applies to opt.update."""
    opt = make_optimizer(optimizer)
    keys = jax.random.split(key, n_clients)
    params = jax.vmap(lambda k: init_lm(k, cfg))(keys)
    pdt = nn.dtype_of(cfg.param_dtype)
    params = jax.tree.map(lambda a: a.astype(pdt), params)
    opt_state = {
        "base": jax.vmap(opt.init)(params["base"]),
        "modular": jax.vmap(opt.init)(params["modular"]),
    }
    return params, opt_state


def init_ifl_slot_state(key, cfg: ModelConfig, *, slot: int,
                        optimizer: str = "sgd"):
    """ONE population slot's unstacked params + optimizer state.

    The per-slot init the host-side population store
    (``repro.core.population.PopulationStore``) pages cohorts from:
    ``fold_in(key, slot)`` makes it a pure function of (key, slot) —
    independent of fleet size and of every other slot — so lazy
    materialization and post-aging re-init both reproduce exactly the
    state a fresh slot would get.  The cohort gather stacks C of these
    into the (C, ...) leaves the round step carries."""
    opt = make_optimizer(optimizer)
    params = init_lm(jax.random.fold_in(key, slot), cfg)
    pdt = nn.dtype_of(cfg.param_dtype)
    params = jax.tree.map(lambda a: a.astype(pdt), params)
    opt_state = {
        "base": opt.init(params["base"]),
        "modular": opt.init(params["modular"]),
    }
    return params, opt_state


# ------------------------------------------------------------------ dense


def make_dp_train_step(cfg: ModelConfig, *, lr: float = 1e-3,
                       optimizer: str = "sgd") -> Callable:
    """FL-equivalent plain data-parallel step (grad sync ∝ |params|)."""
    opt = make_optimizer(optimizer)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: lm_loss(p, cfg, batch)
        )(params)
        new_params, new_opt = opt.update(params, grads, opt_state, lr)
        return new_params, new_opt, {"loss": loss}

    return train_step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    def prefill_step(params, batch):
        logits, aux, _ = lm_apply(params, cfg, batch)
        return logits

    return prefill_step


def make_serve_step(cfg: ModelConfig) -> Callable:
    def serve_step(params, cache, token, pos, cross_kvs=None):
        return lm_decode_step(params, cfg, cache, token, pos, cross_kvs)

    return serve_step
