"""Federated Split Learning baseline (after [9] / SplitFed).

Clients keep a personalized client-side block up to the cut layer (same
432-dim cut as IFL for a like-for-like comparison); the *server* owns the
single shared server-side model. Per communication round each client
performs ONE update (the FSL limitation the paper contrasts with IFL's τ
local steps):

  client k: minibatch -> h_k = f_c(x_k)      (upload h_k + labels)
  server  : ŷ = f_s(h_k), loss, backward     (keeps θ_s, averages grads)
  server  : sends ∂loss/∂h_k back            (download)
  client k: backprops into its client-side block.

Server-side grads are averaged across clients each round (SplitFed-style).
Inference REQUIRES the server (no local end-to-end path) — Table I row 2.

Partial participation (cfg.participation, via the shared round engine):
only the K participating clients run the split exchange; the server
averages gradients over the K contributors. Absent clients move nothing.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import RunConfig
from repro.core.ifl import Client
from repro.core.report import RoundReport
from repro.core.rounds import RoundEngine


class FSLTrainer:
    def __init__(self, clients: Sequence[Client], cfg: RunConfig,
                 server_params: Any, server_apply, seed: int = 0):
        self.clients = list(clients)
        self.cfg = cfg
        self.engine = RoundEngine(len(self.clients), cfg.participation,
                                  seed=seed)
        # FSL's exchange is the base plane: cut activations (+labels) up,
        # activation gradients down — no codec/cache/policy, but the
        # boundary bytes route through the one accounting surface.
        self.exchange = self.engine.exchange
        self.ledger = self.engine.ledger
        self.rng = self.engine.rng
        self.server_params = server_params
        self.server_apply = server_apply
        self._client_fwd = {
            c.cid: jax.jit(c.base_apply) for c in self.clients
        }
        self._client_bwd = {}
        for c in self.clients:
            self._client_bwd[c.cid] = jax.jit(
                functools.partial(self._client_bwd_impl, c.base_apply)
            )
        self._server_step = jax.jit(self._server_step_impl)

    # ---------------------------------------------------------- pieces

    def _server_step_impl(self, server_params, h, y, lr):
        """Returns (server grads applied later, dL/dh, loss)."""

        def loss_of(sp, hh):
            logits = self.server_apply(sp, hh)
            lp = jax.nn.log_softmax(logits, axis=-1)
            return -jnp.mean(jnp.take_along_axis(lp, y[:, None], axis=-1))

        loss, (gs, gh) = jax.value_and_grad(loss_of, argnums=(0, 1))(
            server_params, h
        )
        return gs, gh, loss

    @staticmethod
    def _client_bwd_impl(base_apply, base_params, x, gh, lr):
        """VJP of the client-side block with the server-sent activation grad."""
        _, vjp = jax.vjp(lambda bp: base_apply(bp, x), base_params)
        (g,) = vjp(gh)
        return jax.tree.map(lambda p, gg: p - lr * gg, base_params, g)

    # ---------------------------------------------------------- round

    def run_round(self) -> RoundReport:
        cfg = self.cfg
        eng = self.engine
        participants = eng.participants()
        losses = []
        server_grads = []
        for k in participants:
            c = self.clients[k]
            x, y = eng.sample(c, cfg.batch_size)
            h = self._client_fwd[c.cid](c.params["base"], x)
            self.exchange.up((h, y))  # cut activations + labels up
            gs, gh, loss = self._server_step(self.server_params, h, y,
                                             cfg.lr_modular)
            self.exchange.down(gh)  # activation gradients down
            c.params = {
                "base": self._client_bwd[c.cid](c.params["base"], x, gh,
                                                cfg.lr_base),
                "modular": c.params["modular"],
            }
            server_grads.append(gs)
            losses.append(float(loss))
        # Average server-side grads over the participants, single server
        # update (an empty round updates nothing).
        if server_grads:
            n = len(server_grads)
            avg = jax.tree.map(lambda *gs_: sum(gs_) / n, *server_grads)
            self.server_params = jax.tree.map(
                lambda p, g: p - cfg.lr_modular * g, self.server_params, avg
            )
        return eng.end_round({
            "loss": float(np.mean(losses)) if losses else float("nan"),
            "participants": [int(k) for k in participants],
        })

    # ------------------------------------------------- snapshot/restore

    def snapshot(self):
        """(array pytree, JSON-able aux) — Trainer-protocol state:
        every client's cut-layer block plus the server-side model."""
        tree = {
            "clients": [c.params for c in self.clients],
            "server": self.server_params,
        }
        return tree, self.engine.aux_state()

    def restore(self, tree, aux) -> None:
        for c, p in zip(self.clients, tree["clients"]):
            c.params = p
        self.server_params = tree["server"]
        self.engine.restore_aux(aux)

    # ---------------------------------------------------------- eval

    def evaluate(self, test_x, test_y, batch: int = 512):
        """Server-dependent inference (FSL has no local e2e path)."""
        accs = []
        for c in self.clients:
            correct, total = 0, 0
            f = jax.jit(lambda bp, sp, x, c=c: self.server_apply(
                sp, c.base_apply(bp, x)))
            for s in range(0, len(test_y), batch):
                logits = np.asarray(
                    f(c.params["base"], self.server_params,
                      jnp.asarray(test_x[s:s + batch]))
                )
                y = np.asarray(test_y[s:s + batch])
                correct += int((logits.argmax(-1) == y).sum())
                total += len(y)
            accs.append(correct / max(total, 1))
        return accs
