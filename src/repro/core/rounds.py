"""Participation-aware round engine — one scheduling core for FL/FSL/IFL.

The paper's Algorithm 1 (and the FL/FSL baselines) assume every client
shows up every round.  Real federated deployments are exactly the
opposite regime (HeteroFL, SCAFFOLD reference implementations sample
client subsets per round), and communication efficiency at the client
boundary matters *most* when clients are intermittently available.  This
module owns everything the three eager trainers used to triplicate:

  ParticipationSchedule   who shows up in round t
    - FullParticipation         everyone, every round (the seed behavior)
    - UniformK(k)               uniform K-of-N sampling without
                                replacement (the SCAFFOLD/FedAvg regime)
    - BernoulliSchedule(p)      independent per-client availability
    - StragglerSchedule(f, m)   deterministic straggler trace: a fixed
                                fraction f of the fleet only uploads
                                every m-th round
  FusionCache             server-side staleness-bounded payload cache
                          (defined on the exchange plane, re-exported)
  RoundEngine             rng + schedule + metrics history, driving an
                          exchange plane (repro.core.exchange)

Parse schedules from strings (the benchmarks' ``--participation`` axis):
``full`` | ``k2`` | ``bern0.5`` | ``straggle(0.2,3)``.

Cache-staleness semantics
-------------------------
IFL's modular update (Algorithm 1 lines 24-28) wants N ``(z_hat, y)``
pairs per round, one per client.  Under partial participation only K
clients upload fresh payloads; the server's ``FusionCache`` retains each
client's *last decoded* payload so the broadcast still carries up to N
pairs — absent clients are represented by their most recent upload.  An
entry's **staleness** is ``current_round - round_uploaded`` (0 for a
fresh upload).  Entries older than ``max_staleness`` rounds are evicted
and simply drop out of the broadcast: training degrades gracefully to
fewer pairs rather than learning from arbitrarily old activations
(``max_staleness=None`` never evicts; ``max_staleness=0`` broadcasts
fresh uploads only, disabling the cache).  Byte accounting is honest on
both legs: only participants upload (absent clients' EF residuals stay
frozen and their bytes never hit the ledger), and the downlink goes to
*participants only* — under the default ``broadcast='full'`` policy
each receives the full valid cache, so one round costs ``K * (z + y)``
up and ``K * M * (z + y)`` down, where M is the number of valid cache
entries; under ``broadcast='delta'`` clients mirror the cache and each
entry ships at most once (see ``repro.core.exchange``).  Either way
``comm.ifl_round_bytes(participating=, broadcast_entries=, broadcast=,
delta_entries=)`` stays in exact parity with the ledger.

The SPMD trainer threads the same semantics through one jitted program:
the gathered payload becomes carried round state updated by a masked
encode, with an ``age`` vector enforcing the staleness bound (see
``ifl_spmd.make_ifl_round_step(partial_participation=True)``).

The wire pipeline itself — codec, EF residuals, the FusionCache, ledger
accounting, and the full/delta broadcast policy — lives on the
*exchange plane* (``repro.core.exchange``); the engine schedules rounds
against whatever plane it is handed (``FusionCache``/``CacheEntry`` are
re-exported here for back compat).
"""

from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.core.comm import CommLedger
from repro.core.exchange import CacheEntry, ExchangePlane, FusionCache  # noqa: F401  (re-export)
from repro.core.report import RoundReport

__all__ = [
    "ParticipationSchedule",
    "FullParticipation",
    "UniformK",
    "BernoulliSchedule",
    "StragglerSchedule",
    "parse_participation",
    "FusionCache",
    "CacheEntry",
    "RoundEngine",
]


# ------------------------------------------------------------- schedules


class ParticipationSchedule:
    """Who participates in round t.  ``mask`` returns a bool (n,) array.

    Schedules that need randomness draw from the generator they are
    handed (the engine's); deterministic schedules must not touch it, so
    a ``full`` run consumes exactly the same rng stream as the
    pre-engine trainers (bitwise-reproducible seeds).
    """

    name: str = "abstract"

    def mask(self, round_idx: int, n: int,
             rng: np.random.Generator) -> np.ndarray:
        raise NotImplementedError

    def expected_participants(self, n: int) -> float:
        """E[K] per round for an n-client fleet — what the dry-run's
        analytic client-boundary accounting plugs into
        ``ifl_round_bytes(participating=)`` (launch.dryrun)."""
        raise NotImplementedError

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self.name!r})"


@dataclass(frozen=True, repr=False)
class FullParticipation(ParticipationSchedule):
    """Every client, every round — Algorithm 1 as written."""

    name: str = "full"

    def mask(self, round_idx, n, rng):
        return np.ones(n, bool)

    def expected_participants(self, n):
        return float(n)


@dataclass(frozen=True, repr=False)
class UniformK(ParticipationSchedule):
    """Uniform K-of-N sampling without replacement, fresh each round."""

    k: int = 1
    name: str = ""

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"k must be >= 1, got {self.k}")
        if not self.name:
            object.__setattr__(self, "name", f"k{self.k}")

    def mask(self, round_idx, n, rng):
        m = np.zeros(n, bool)
        m[rng.choice(n, size=min(self.k, n), replace=False)] = True
        return m

    def expected_participants(self, n):
        return float(min(self.k, n))


@dataclass(frozen=True, repr=False)
class BernoulliSchedule(ParticipationSchedule):
    """Independent per-client availability: P(client up) = p.

    Rounds with zero participants are legal (nothing is transmitted,
    nothing trains); the engine reports them as empty rounds.
    """

    p: float = 0.5
    name: str = ""

    def __post_init__(self):
        if not 0.0 < self.p <= 1.0:
            raise ValueError(f"p must be in (0, 1], got {self.p}")
        if not self.name:
            object.__setattr__(self, "name", f"bern{self.p:g}")

    def mask(self, round_idx, n, rng):
        return rng.random(n) < self.p

    def expected_participants(self, n):
        return self.p * n


@dataclass(frozen=True, repr=False)
class StragglerSchedule(ParticipationSchedule):
    """Deterministic straggler/dropout trace (no rng draws at all).

    The last ``ceil(frac * n)`` client slots are stragglers; straggler
    slot i only participates in rounds with ``t % period == i % period``
    (staggered by slot index, so straggler upload rounds spread across
    the period — though slots sharing a residue mod ``period`` still
    miss the same rounds).  Everyone else is always up.  Reproducible
    from (round_idx, n) alone — the trace a deployment postmortem would
    replay.
    """

    frac: float = 0.2
    period: int = 3
    name: str = ""

    def __post_init__(self):
        if not 0.0 <= self.frac <= 1.0:
            raise ValueError(f"frac must be in [0, 1], got {self.frac}")
        if self.period < 1:
            raise ValueError(f"period must be >= 1, got {self.period}")
        if not self.name:
            object.__setattr__(
                self, "name", f"straggle({self.frac:g},{self.period})"
            )

    def mask(self, round_idx, n, rng):
        m = np.ones(n, bool)
        n_strag = int(np.ceil(self.frac * n))
        for i in range(n - n_strag, n):
            m[i] = (round_idx % self.period) == (i % self.period)
        return m

    def expected_participants(self, n):
        n_strag = int(np.ceil(self.frac * n))
        return (n - n_strag) + n_strag / self.period


_STRAGGLE_RE = re.compile(r"^straggle\(([^,]+),(\d+)\)$")


def parse_participation(
    spec: Union[str, ParticipationSchedule, None],
) -> ParticipationSchedule:
    """Resolve a schedule spec: ``full`` | ``k<K>`` | ``bern<p>`` |
    ``straggle(<frac>,<period>)`` — or pass a schedule through."""
    if spec is None:
        return FullParticipation()
    if isinstance(spec, ParticipationSchedule):
        return spec
    if spec == "full":
        return FullParticipation()
    if spec.startswith("k"):
        try:
            k = int(spec[1:])
        except ValueError:
            k = None
        if k is not None:
            return UniformK(k)  # constructor errors (k<1) propagate
    if spec.startswith("bern"):
        try:
            p = float(spec[len("bern"):])
        except ValueError:
            p = None
        if p is not None:
            return BernoulliSchedule(p)  # p-range errors propagate
    m = _STRAGGLE_RE.match(spec)
    if m:
        return StragglerSchedule(float(m.group(1)), int(m.group(2)))
    raise ValueError(
        f"unknown participation spec {spec!r}; expected 'full', 'k<K>' "
        "(e.g. k2), 'bern<p>' (e.g. bern0.5), or "
        "'straggle(<frac>,<period>)' (e.g. straggle(0.2,3))"
    )


# ------------------------------------------------------------ round engine


class RoundEngine:
    """The scheduling core shared by FL / FSL / IFL trainers.

    Owns the pieces every trainer used to hand-roll: the rng (one stream
    for minibatch sampling AND schedule draws, so a seed pins the whole
    run), the participation schedule, the round counter, and a metrics
    history — scheduled against an *exchange plane*
    (``repro.core.exchange``) that owns the wire side: the CommLedger,
    and (for the fusion backends) codec, EF state, FusionCache, and
    broadcast policy.  Trainers call ``participants()`` once per round,
    transmit through the plane, and finish with ``end_round(metrics)``.
    """

    def __init__(self, n_clients: int,
                 participation: Union[str, ParticipationSchedule, None] = None,
                 *, seed: int = 0, max_staleness: Optional[int] = None,
                 exchange: Optional[ExchangePlane] = None):
        self.n_clients = n_clients
        self.schedule = parse_participation(participation)
        self.rng = np.random.default_rng(seed)
        if exchange is not None and max_staleness is not None:
            raise ValueError(
                "RoundEngine: max_staleness is the exchange plane's "
                "setting — configure it on the plane, not the engine"
            )
        self.exchange = ExchangePlane() if exchange is None else exchange
        self.ledger = self.exchange.ledger
        # The fusion cache lives on the plane when the plane carries one
        # (IFL backends); engine-local otherwise — back compat for
        # direct constructions and the FL/FSL baselines (which never
        # touch it).
        self.cache = getattr(self.exchange, "cache", None)
        if self.cache is None:
            self.cache = FusionCache(max_staleness)
        self.round_idx = 0
        self.history: List[Dict[str, Any]] = []

    # -- per-round API ---------------------------------------------------

    def participants(self) -> np.ndarray:
        """Sorted slot indices participating in the current round."""
        mask = self.schedule.mask(self.round_idx, self.n_clients, self.rng)
        return np.flatnonzero(mask)

    def sample(self, client, batch_size: int):
        """One private minibatch from ``client`` (needs .data_x/.data_y
        /.num_samples) — the exact draw order the seed trainers used."""
        idx = self.rng.integers(0, client.num_samples, size=batch_size)
        return jnp.asarray(client.data_x[idx]), jnp.asarray(client.data_y[idx])

    def aux_state(self) -> Dict[str, Any]:
        """JSON-able engine state for checkpoint resume: round counter,
        rng bit-generator state, ledger totals — plus the exchange
        plane's host state (``aux["exchange"]``: cache entry rounds and
        delta-mirror versions for the eager fusion plane, the
        age-replica for the SPMD one).  The cache's *arrays* ride in the
        trainer's snapshot tree (``FusionExchange.cache_tree``), so a
        restored run no longer cold-starts the fusion cache."""
        aux = {
            "round_idx": self.round_idx,
            "rng": self.rng.bit_generator.state,
            "ledger": {"uplink": self.ledger.uplink,
                       "downlink": self.ledger.downlink},
        }
        ex = self.exchange.aux_state()
        if ex:
            aux["exchange"] = ex
        return aux

    def restore_aux(self, aux: Dict[str, Any]) -> None:
        self.round_idx = int(aux["round_idx"])
        self.rng.bit_generator.state = aux["rng"]
        self.ledger.uplink = int(aux["ledger"]["uplink"])
        self.ledger.downlink = int(aux["ledger"]["downlink"])
        if "exchange" in aux:
            self.exchange.restore_aux(aux["exchange"])
        # Clear the cache in place (the plane and trainer alias it): an
        # in-place rewind may hold payloads uploaded AFTER the snapshot
        # round, which would look negative-staleness (never expiring) to
        # the rewound counter.  A FusionExchange-backed trainer then
        # repopulates it from the snapshot tree (``restore_cache``);
        # legacy engine-owned caches stay cold, as before.  Truncate the
        # history/per-round trails past the restored round either way.
        self.cache._entries.clear()
        del self.history[self.round_idx:]
        del self.ledger.per_round[self.round_idx:]

    def end_round(self, metrics: Dict[str, Any]) -> RoundReport:
        """Close the ledger round, log metrics, advance the counter.

        Returns a structured :class:`RoundReport` (the Trainer-protocol
        return type): cross-scheme fields — round index, cumulative
        ledger MB both legs, participants — are typed attributes, and
        everything else in ``metrics`` rides in ``report.metrics``. The
        report is a read-only Mapping over both, so dict-style consumers
        of the old ad-hoc metrics keep working unchanged.
        """
        self.ledger.end_round()
        metrics = dict(metrics)
        metrics.pop("uplink_mb", None)  # a ledger fact, not a metric
        report = RoundReport(
            round=int(metrics.pop("round", self.round_idx)),
            uplink_mb=self.ledger.uplink_mb,
            downlink_mb=self.ledger.downlink_mb,
            participants=[int(k) for k in metrics.pop("participants", [])],
            metrics=metrics,
        )
        self.history.append(report)
        self.round_idx += 1
        return report
