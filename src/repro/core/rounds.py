"""Participation-aware round engine — one scheduling core for FL/FSL/IFL.

The paper's Algorithm 1 (and the FL/FSL baselines) assume every client
shows up every round.  Real federated deployments are exactly the
opposite regime (HeteroFL, SCAFFOLD reference implementations sample
client subsets per round), and communication efficiency at the client
boundary matters *most* when clients are intermittently available.  This
module owns everything the three eager trainers used to triplicate:

  ParticipationSchedule   who shows up in round t
    - FullParticipation         everyone, every round (the seed behavior)
    - UniformK(k)               uniform K-of-N sampling without
                                replacement (the SCAFFOLD/FedAvg regime)
    - BernoulliSchedule(p)      independent per-client availability
    - StragglerSchedule(f, m)   deterministic straggler trace: a fixed
                                fraction f of the fleet only uploads
                                every m-th round
  FusionCache             server-side staleness-bounded payload cache
                          (defined on the exchange plane, re-exported)
  RoundEngine             rng + schedule + metrics history, driving an
                          exchange plane (repro.core.exchange)

Parse schedules from strings (the benchmarks' ``--participation`` axis):
``full`` | ``k2`` | ``bern0.5`` | ``straggle(0.2,3)`` | ``zipf(1.1)`` |
``diurnal(24,4)``.

Population regime (cohort draws)
--------------------------------
Real deployments sample a *cohort* of C from a population of N >> C per
round (the FedAvg/HeteroFL regime).  Both engines take a ``cohort=C``
cap: the schedule (or arrival trace) decides who is AVAILABLE, and the
engine admits at most C of them — a uniform draw from the available set
in the sync engine, the C earliest distinct arrivals in the async one.
``cohort=None`` (the default) draws nothing extra from the rng stream,
so every pre-cohort run stays bitwise reproducible.  The population-
scale availability schedules live here too: ``zipf(<a>)`` (popularity-
skewed: slot k is up with probability ``(k+1)^-a``) and
``diurnal(<period>[,<zones>])`` (deterministic time-zone waves: the
fleet splits into equal zones, each awake for half of every
``period``-round day, phase-shifted by zone).

Event-driven (async) mode
-------------------------
The synchronous engine is a barrier: a round closes when every scheduled
participant has uploaded, so wall-clock is pinned to the slowest
straggler.  The staleness-bounded :class:`FusionCache` is already the
data structure of *asynchronous* FL — a server that fuses whatever
payloads have arrived — so this module also owns the event-driven mode:

  ArrivalTrace            each client's upload clock on a simulated
                          timeline — synthetic samplers
                          (``periodic(<p>)`` | ``poisson(<rate>)`` |
                          heavy-tail ``pareto(<alpha>,<scale>)``) or a
                          replayed real log (``replay:<path>``, the
                          PR-3 remnant of extending ``straggle(...)``
                          parsing), via :func:`parse_trace`.
  AsyncRoundEngine        clients upload on their own clocks into the
                          exchange plane; the server runs one modular
                          update pass on the current valid cache at a
                          fixed ``tick`` interval.  One engine round ==
                          one server tick: the participants are the
                          clients with >= 1 arrival in the tick window
                          (multiple arrivals coalesce — the client
                          uploads its freshest state once), so byte
                          accounting reuses the synchronous
                          ``ifl_round_bytes(participating=K)`` parity
                          exactly.  Empty ticks are legal (the server
                          ticks, nothing moves).  Reports gain
                          ``sim_time`` / ``arrivals`` /
                          ``uploads_per_sec`` — throughput measured in
                          uploads/sec absorbed, not rounds.
  simulate_sync_wall_clock  what the SAME trace costs a barrier run:
                          per-round duration = waiting for the slowest
                          scheduled participant's next arrival — the
                          baseline the async-vs-sync benchmark compares
                          wall-clock against.

Cache-staleness semantics
-------------------------
IFL's modular update (Algorithm 1 lines 24-28) wants N ``(z_hat, y)``
pairs per round, one per client.  Under partial participation only K
clients upload fresh payloads; the server's ``FusionCache`` retains each
client's *last decoded* payload so the broadcast still carries up to N
pairs — absent clients are represented by their most recent upload.  An
entry's **staleness** is ``current_round - round_uploaded`` (0 for a
fresh upload).  Entries older than ``max_staleness`` rounds are evicted
and simply drop out of the broadcast: training degrades gracefully to
fewer pairs rather than learning from arbitrarily old activations
(``max_staleness=None`` never evicts; ``max_staleness=0`` broadcasts
fresh uploads only, disabling the cache).  Byte accounting is honest on
both legs: only participants upload (absent clients' EF residuals stay
frozen and their bytes never hit the ledger), and the downlink goes to
*participants only* — under the default ``broadcast='full'`` policy
each receives the full valid cache, so one round costs ``K * (z + y)``
up and ``K * M * (z + y)`` down, where M is the number of valid cache
entries; under ``broadcast='delta'`` clients mirror the cache and each
entry ships at most once (see ``repro.core.exchange``).  Either way
``comm.ifl_round_bytes(participating=, broadcast_entries=, broadcast=,
delta_entries=)`` stays in exact parity with the ledger.

The SPMD trainer threads the same semantics through one jitted program:
the gathered payload becomes carried round state updated by a masked
encode, with an ``age`` vector enforcing the staleness bound (see
``ifl_spmd.make_ifl_round_step(partial_participation=True)``).

The wire pipeline itself — codec, EF residuals, the FusionCache, ledger
accounting, and the full/delta broadcast policy — lives on the
*exchange plane* (``repro.core.exchange``); the engine schedules rounds
against whatever plane it is handed (``FusionCache``/``CacheEntry`` are
re-exported here for back compat).
"""

from __future__ import annotations

import json
import math
import re
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.core.comm import CommLedger
from repro.core.exchange import CacheEntry, ExchangePlane, FusionCache  # noqa: F401  (re-export)
from repro.core.report import RoundReport

__all__ = [
    "ParticipationSchedule",
    "FullParticipation",
    "UniformK",
    "BernoulliSchedule",
    "StragglerSchedule",
    "ZipfSchedule",
    "DiurnalSchedule",
    "parse_participation",
    "expected_cohort_participants",
    "ArrivalTrace",
    "PeriodicTrace",
    "PoissonTrace",
    "ParetoTrace",
    "ReplayTrace",
    "parse_trace",
    "FusionCache",
    "CacheEntry",
    "RoundEngine",
    "AsyncRoundEngine",
    "simulate_sync_wall_clock",
    "expected_async_participants",
]


# ------------------------------------------------------------- schedules


class ParticipationSchedule:
    """Who participates in round t.  ``mask`` returns a bool (n,) array.

    Schedules that need randomness draw from the generator they are
    handed (the engine's); deterministic schedules must not touch it, so
    a ``full`` run consumes exactly the same rng stream as the
    pre-engine trainers (bitwise-reproducible seeds).
    """

    name: str = "abstract"

    def mask(self, round_idx: int, n: int,
             rng: np.random.Generator) -> np.ndarray:
        raise NotImplementedError

    def expected_participants(self, n: int) -> float:
        """E[K] per round for an n-client fleet — what the dry-run's
        analytic client-boundary accounting plugs into
        ``ifl_round_bytes(participating=)`` (launch.dryrun)."""
        raise NotImplementedError

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self.name!r})"


@dataclass(frozen=True, repr=False)
class FullParticipation(ParticipationSchedule):
    """Every client, every round — Algorithm 1 as written."""

    name: str = "full"

    def mask(self, round_idx, n, rng):
        return np.ones(n, bool)

    def expected_participants(self, n):
        return float(n)


@dataclass(frozen=True, repr=False)
class UniformK(ParticipationSchedule):
    """Uniform K-of-N sampling without replacement, fresh each round."""

    k: int = 1
    name: str = ""

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"k must be >= 1, got {self.k}")
        if not self.name:
            object.__setattr__(self, "name", f"k{self.k}")

    def mask(self, round_idx, n, rng):
        m = np.zeros(n, bool)
        m[rng.choice(n, size=min(self.k, n), replace=False)] = True
        return m

    def expected_participants(self, n):
        return float(min(self.k, n))


@dataclass(frozen=True, repr=False)
class BernoulliSchedule(ParticipationSchedule):
    """Independent per-client availability: P(client up) = p.

    Rounds with zero participants are legal (nothing is transmitted,
    nothing trains); the engine reports them as empty rounds.
    """

    p: float = 0.5
    name: str = ""

    def __post_init__(self):
        if not 0.0 < self.p <= 1.0:
            raise ValueError(f"p must be in (0, 1], got {self.p}")
        if not self.name:
            object.__setattr__(self, "name", f"bern{self.p:g}")

    def mask(self, round_idx, n, rng):
        return rng.random(n) < self.p

    def expected_participants(self, n):
        return self.p * n


@dataclass(frozen=True, repr=False)
class StragglerSchedule(ParticipationSchedule):
    """Deterministic straggler/dropout trace (no rng draws at all).

    The last ``ceil(frac * n)`` client slots are stragglers; straggler
    slot i only participates in rounds with ``t % period == i % period``
    (staggered by slot index, so straggler upload rounds spread across
    the period — though slots sharing a residue mod ``period`` still
    miss the same rounds).  Everyone else is always up.  Reproducible
    from (round_idx, n) alone — the trace a deployment postmortem would
    replay.
    """

    frac: float = 0.2
    period: int = 3
    name: str = ""

    def __post_init__(self):
        if not 0.0 <= self.frac <= 1.0:
            raise ValueError(f"frac must be in [0, 1], got {self.frac}")
        if self.period < 1:
            raise ValueError(f"period must be >= 1, got {self.period}")
        if not self.name:
            object.__setattr__(
                self, "name", f"straggle({self.frac:g},{self.period})"
            )

    def mask(self, round_idx, n, rng):
        m = np.ones(n, bool)
        n_strag = int(np.ceil(self.frac * n))
        for i in range(n - n_strag, n):
            m[i] = (round_idx % self.period) == (i % self.period)
        return m

    def expected_participants(self, n):
        n_strag = int(np.ceil(self.frac * n))
        return (n - n_strag) + n_strag / self.period


@dataclass(frozen=True, repr=False)
class ZipfSchedule(ParticipationSchedule):
    """Popularity-skewed availability — the population regime's shape:
    slot k is up independently with probability ``(k+1)^-a``, so slot 0
    is (almost) always available and the long tail almost never is.
    ``a=0`` degenerates to full participation; larger ``a`` thins the
    tail faster.  Rounds with zero participants are legal."""

    a: float = 1.0
    name: str = ""

    def __post_init__(self):
        if not self.a >= 0.0:
            raise ValueError(f"a must be >= 0, got {self.a}")
        if not self.name:
            object.__setattr__(self, "name", f"zipf({self.a:g})")

    def mask(self, round_idx, n, rng):
        p = (np.arange(n) + 1.0) ** (-self.a)
        return rng.random(n) < p

    def expected_participants(self, n):
        return float(((np.arange(n) + 1.0) ** (-self.a)).sum())


@dataclass(frozen=True, repr=False)
class DiurnalSchedule(ParticipationSchedule):
    """Deterministic time-zone waves (no rng draws at all): the fleet
    splits into ``zones`` equal contiguous slices; zone z is awake for
    the first ``ceil(period/2)`` rounds of every ``period``-round day,
    phase-shifted by ``z * period / zones`` rounds — availability
    sweeps around the fleet the way daylight sweeps time zones.
    Reproducible from (round_idx, n) alone."""

    period: int = 24
    zones: int = 4
    name: str = ""

    def __post_init__(self):
        if self.period < 2:
            raise ValueError(f"period must be >= 2, got {self.period}")
        if self.zones < 1:
            raise ValueError(f"zones must be >= 1, got {self.zones}")
        if not self.name:
            object.__setattr__(
                self, "name", f"diurnal({self.period},{self.zones})"
            )

    def mask(self, round_idx, n, rng):
        zone = (np.arange(n) * self.zones) // max(n, 1)
        phase = (round_idx - zone * self.period // self.zones) % self.period
        return phase < (self.period + 1) // 2

    def expected_participants(self, n):
        return n * ((self.period + 1) // 2) / self.period


# One normalization point for every ``--participation`` surface (CLI,
# spec strings, engine constructors): strip padding once, then match
# each family with a strict pattern so near-misses fail loudly instead
# of int()/float() quietly accepting signs and inner whitespace
# ('k+2' used to parse as UniformK(2) while 'k0' raised).
_K_RE = re.compile(r"^k(\d+)$")
_BERN_RE = re.compile(r"^bern(\d+(?:\.\d*)?(?:[eE][-+]?\d+)?)$")
_STRAGGLE_RE = re.compile(r"^straggle\(\s*([^,\s]+)\s*,\s*(\d+)\s*\)$")
_ZIPF_RE = re.compile(r"^zipf\(\s*([^,\s)]+)\s*\)$")
_DIURNAL_RE = re.compile(r"^diurnal\(\s*(\d+)\s*(?:,\s*(\d+)\s*)?\)$")


def parse_participation(
    spec: Union[str, ParticipationSchedule, None],
) -> ParticipationSchedule:
    """Resolve a schedule spec: ``full`` | ``k<K>`` | ``bern<p>`` |
    ``straggle(<frac>,<period>)`` | ``zipf(<a>)`` |
    ``diurnal(<period>[,<zones>])`` — or pass a schedule through.
    Surrounding whitespace is stripped; anything else malformed raises
    with the exact offending spec."""
    if spec is None:
        return FullParticipation()
    if isinstance(spec, ParticipationSchedule):
        return spec
    spec = spec.strip()
    if spec == "full":
        return FullParticipation()
    m = _K_RE.match(spec)
    if m:
        return UniformK(int(m.group(1)))  # constructor errors (k<1) propagate
    if spec.startswith("k"):
        try:
            int(spec[1:])
        except ValueError:
            pass  # not int-like at all: fall through to the unknown error
        else:
            raise ValueError(
                f"participation spec {spec!r}: K must be a plain positive "
                "integer with no sign or padding (e.g. 'k2')"
            )
    m = _BERN_RE.match(spec)
    if m:
        return BernoulliSchedule(float(m.group(1)))  # p-range errors propagate
    if spec.startswith("bern"):
        try:
            float(spec[len("bern"):])
        except ValueError:
            pass
        else:
            raise ValueError(
                f"participation spec {spec!r}: p must be a plain decimal "
                "with no sign or padding (e.g. 'bern0.5')"
            )
    m = _STRAGGLE_RE.match(spec)
    if m:
        return StragglerSchedule(float(m.group(1)), int(m.group(2)))
    m = _ZIPF_RE.match(spec)
    if m:
        return ZipfSchedule(float(m.group(1)))
    m = _DIURNAL_RE.match(spec)
    if m:
        return DiurnalSchedule(
            int(m.group(1)), int(m.group(2)) if m.group(2) else 4
        )
    raise ValueError(
        f"unknown participation spec {spec!r}; expected 'full', 'k<K>' "
        "(e.g. k2), 'bern<p>' (e.g. bern0.5), "
        "'straggle(<frac>,<period>)' (e.g. straggle(0.2,3)), "
        "'zipf(<a>)' (e.g. zipf(1.1)), or "
        "'diurnal(<period>[,<zones>])' (e.g. diurnal(24,4))"
    )


def expected_cohort_participants(
    schedule: Union[str, ParticipationSchedule, None], n_clients: int,
    cohort: Optional[int] = None, *, rounds: int = 256, seed: int = 0,
) -> float:
    """E[participants/round] under a cohort cap, by replaying the
    schedule's own mask draws — the population analogue of
    ``ParticipationSchedule.expected_participants`` for the dry-run's
    analytic client-boundary accounting (``min(K_avail, C)`` has no
    clean closed form for the random schedules)."""
    schedule = parse_participation(schedule)
    rng = np.random.default_rng(seed)
    total = 0
    for t in range(max(rounds, 1)):
        k = int(schedule.mask(t, n_clients, rng).sum())
        total += min(k, cohort) if cohort is not None else k
    return total / max(rounds, 1)


# ---------------------------------------------------------- arrival traces


class TraceCursor:
    """Consumable view of one fleet's arrival stream.

    Two consumers share this interface: the async engine pops every
    event up to its next tick boundary (:meth:`pop_until`), and the
    sync-barrier wall-clock simulation asks for one client's next
    arrival after a round starts (:meth:`next_after`).  ``state()`` /
    ``restore()`` make the cursor checkpointable — together with the
    engine's rng bit-generator state, an async run resumes bitwise.
    """

    def pop_until(self, t_end: float,
                  rng: np.random.Generator) -> List[Tuple[float, int]]:
        """Consume and return every (time, slot) event with
        ``time <= t_end``, sorted by (time, slot)."""
        raise NotImplementedError

    def next_after(self, slot: int, t: float,
                   rng: np.random.Generator) -> float:
        """Consume ``slot``'s arrivals through its first one strictly
        after ``t`` and return that time (``inf`` if the trace is
        exhausted — a replayed log where the client never returns)."""
        raise NotImplementedError

    def state(self) -> Dict[str, Any]:
        raise NotImplementedError

    def restore(self, state: Dict[str, Any]) -> None:
        raise NotImplementedError


class _SamplerCursor(TraceCursor):
    """Cursor over per-client renewal processes (``trace.gap`` draws).

    Holds each slot's next pending arrival; gaps are drawn lazily from
    the generator it is handed (the engine's single rng stream), slot-
    order deterministic, so a seed pins the whole event sequence."""

    def __init__(self, trace: "ArrivalTrace", n: int,
                 rng: np.random.Generator):
        self.trace = trace
        self.next = [trace.first(k, n, rng) for k in range(n)]

    def pop_until(self, t_end, rng):
        events: List[Tuple[float, int]] = []
        for k in range(len(self.next)):
            while self.next[k] <= t_end:
                events.append((self.next[k], k))
                self.next[k] += self.trace.gap(k, rng)
        return sorted(events)

    def next_after(self, slot, t, rng):
        while self.next[slot] <= t:
            self.next[slot] += self.trace.gap(slot, rng)
        arrival = self.next[slot]
        self.next[slot] += self.trace.gap(slot, rng)
        return arrival

    def state(self):
        return {"next": [float(t) for t in self.next]}

    def restore(self, state):
        self.next = [float(t) for t in state["next"]]


class _ReplayCursor(TraceCursor):
    """Cursor over a recorded event list (per-slot position indices)."""

    def __init__(self, times_by_slot: List[List[float]]):
        self.times = times_by_slot
        self.pos = [0] * len(times_by_slot)

    def pop_until(self, t_end, rng):
        events: List[Tuple[float, int]] = []
        for k, ts in enumerate(self.times):
            p = self.pos[k]
            while p < len(ts) and ts[p] <= t_end:
                events.append((ts[p], k))
                p += 1
            self.pos[k] = p
        return sorted(events)

    def next_after(self, slot, t, rng):
        ts, p = self.times[slot], self.pos[slot]
        while p < len(ts) and ts[p] <= t:
            p += 1
        if p >= len(ts):
            self.pos[slot] = p
            return math.inf
        self.pos[slot] = p + 1
        return ts[p]

    def state(self):
        return {"pos": [int(p) for p in self.pos]}

    def restore(self, state):
        self.pos = [int(p) for p in state["pos"]]


class ArrivalTrace:
    """Each client's upload clock on the simulated timeline.

    Sampler traces are per-client renewal processes: override ``gap``
    (inter-arrival time after an upload) and optionally ``first`` (time
    of the first upload; defaults to one gap from t=0).  Replayed real
    logs override ``cursor`` wholesale.  ``name`` round-trips through
    :func:`parse_trace` (the benchmarks' ``--trace`` axis), exactly like
    the participation schedules' ``name``.
    """

    name: str = "abstract"

    def first(self, slot: int, n: int, rng: np.random.Generator) -> float:
        return self.gap(slot, rng)

    def gap(self, slot: int, rng: np.random.Generator) -> float:
        raise NotImplementedError

    def cursor(self, n: int, rng: np.random.Generator) -> TraceCursor:
        return _SamplerCursor(self, n, rng)

    def mean_gap(self) -> float:
        """Analytic E[inter-arrival] (``inf`` when the mean diverges) —
        what matched-uplink planning sizes tick counts with."""
        raise NotImplementedError

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self.name!r})"


@dataclass(frozen=True, repr=False)
class PeriodicTrace(ArrivalTrace):
    """Deterministic clocks: client k uploads every ``period`` seconds,
    phase-staggered by slot (k's first upload at ``(k+1)/n * period``)
    so the fleet's uploads spread across the period instead of arriving
    as a thundering herd.  Draws no rng at all."""

    period: float = 1.0
    name: str = ""

    def __post_init__(self):
        if not self.period > 0:
            raise ValueError(f"period must be > 0, got {self.period}")
        if not self.name:
            object.__setattr__(self, "name", f"periodic({self.period:g})")

    def first(self, slot, n, rng):
        return self.period * (slot + 1) / max(n, 1)

    def gap(self, slot, rng):
        return self.period

    def mean_gap(self):
        return self.period


@dataclass(frozen=True, repr=False)
class PoissonTrace(ArrivalTrace):
    """Memoryless clocks: exponential inter-arrivals at ``rate``
    uploads/sec per client (a Poisson process per client)."""

    rate: float = 1.0
    name: str = ""

    def __post_init__(self):
        if not self.rate > 0:
            raise ValueError(f"rate must be > 0, got {self.rate}")
        if not self.name:
            object.__setattr__(self, "name", f"poisson({self.rate:g})")

    def gap(self, slot, rng):
        return float(rng.exponential(1.0 / self.rate))

    def mean_gap(self):
        return 1.0 / self.rate


@dataclass(frozen=True, repr=False)
class ParetoTrace(ArrivalTrace):
    """Heavy-tailed clocks — the regime HeteroFL/FedMD-style populations
    live in: inter-arrival = ``scale * U^(-1/alpha)`` (Pareto with
    minimum ``scale`` and tail index ``alpha``).  Small ``alpha`` makes
    stragglers arbitrarily late (``alpha <= 1`` has infinite mean), so a
    synchronous barrier's round time — the MAX over clients — is pinned
    by the tail while the async tick keeps absorbing the fast majority.
    """

    alpha: float = 1.5
    scale: float = 0.5
    name: str = ""

    def __post_init__(self):
        if not self.alpha > 0:
            raise ValueError(f"alpha must be > 0, got {self.alpha}")
        if not self.scale > 0:
            raise ValueError(f"scale must be > 0, got {self.scale}")
        if not self.name:
            object.__setattr__(
                self, "name", f"pareto({self.alpha:g},{self.scale:g})"
            )

    def gap(self, slot, rng):
        u = 1.0 - rng.random()  # (0, 1]: bounds the draw away from inf
        return float(self.scale * u ** (-1.0 / self.alpha))

    def mean_gap(self):
        if self.alpha <= 1.0:
            return math.inf
        return self.alpha * self.scale / (self.alpha - 1.0)


class ReplayTrace(ArrivalTrace):
    """A replayed real upload log: explicit (time, slot) events.

    ``events`` may arrive unsorted; they are ordered by (time, slot) —
    duplicate timestamps are legal (two clients at the same instant, or
    one client's back-to-back uploads) and keep a stable order.  An
    empty log is legal too: every tick is simply empty.  ``from_file``
    parses the on-disk formats a deployment postmortem would export:
    JSON lines (``{"t": 3.2, "client": 1}``) or CSV (``time,slot``),
    ``#`` comments and blank lines skipped.
    """

    def __init__(self, events: Sequence[Tuple[float, int]],
                 n_clients: Optional[int] = None, *, path: str = ""):
        evs = []
        for i, (t, s) in enumerate(events):
            t, s = float(t), int(s)
            if not math.isfinite(t) or t < 0:
                raise ValueError(
                    f"replay trace event {i}: time must be finite and "
                    f">= 0, got {t}"
                )
            if s < 0:
                raise ValueError(
                    f"replay trace event {i}: client slot must be >= 0, "
                    f"got {s}"
                )
            evs.append((t, s))
        self.events = sorted(evs)
        self.n_slots = max((s for _, s in self.events), default=-1) + 1
        if n_clients is not None and self.n_slots > n_clients:
            raise ValueError(
                f"replay trace names client slot {self.n_slots - 1} but "
                f"the fleet has only {n_clients} clients"
            )
        self.name = f"replay:{path}" if path else "replay"

    @classmethod
    def from_file(cls, path: str,
                  n_clients: Optional[int] = None) -> "ReplayTrace":
        events = []
        with open(path) as f:
            for lineno, line in enumerate(f, 1):
                line = line.strip()
                if not line or line.startswith("#"):
                    continue
                try:
                    if line.startswith("{"):
                        rec = json.loads(line)
                        events.append((rec["t"], rec["client"]))
                    else:
                        t_s, s_s = line.split(",")
                        events.append((float(t_s), int(s_s)))
                except (ValueError, KeyError, TypeError) as e:
                    raise ValueError(
                        f"{path}:{lineno}: malformed arrival-log line "
                        f"{line!r} (expected JSON {{'t':..,'client':..}} "
                        f"or CSV 'time,slot'): {e}"
                    ) from None
        return cls(events, n_clients, path=path)

    def cursor(self, n, rng):
        # A trace built without n_clients skipped the constructor's
        # slot-range check; enforce it here instead of silently dropping
        # the out-of-range slots' arrivals (which made a mis-sized fleet
        # look like a quiet one).
        if self.n_slots > n:
            raise ValueError(
                f"replay trace names client slot {self.n_slots - 1} but "
                f"the fleet has only {n} clients"
            )
        times: List[List[float]] = [[] for _ in range(n)]
        for t, s in self.events:
            times[s].append(t)
        return _ReplayCursor(times)

    def mean_gap(self):
        """Empirical mean inter-arrival across the log's clients."""
        gaps = []
        by_slot: Dict[int, List[float]] = {}
        for t, s in self.events:
            by_slot.setdefault(s, []).append(t)
        for ts in by_slot.values():
            gaps.extend(b - a for a, b in zip(ts, ts[1:]))
        return float(np.mean(gaps)) if gaps else math.inf

    def __repr__(self) -> str:
        return f"ReplayTrace({len(self.events)} events, {self.name!r})"


_TRACE_RES = {
    re.compile(r"^periodic\(([^,)]+)\)$"):
        lambda m: PeriodicTrace(float(m.group(1))),
    re.compile(r"^poisson\(([^,)]+)\)$"):
        lambda m: PoissonTrace(float(m.group(1))),
    re.compile(r"^pareto\(([^,)]+),([^,)]+)\)$"):
        lambda m: ParetoTrace(float(m.group(1)), float(m.group(2))),
}


def parse_trace(spec: Union[str, ArrivalTrace],
                n_clients: Optional[int] = None) -> ArrivalTrace:
    """Resolve an arrival-trace spec — ``periodic(<period>)`` |
    ``poisson(<rate>)`` | ``pareto(<alpha>,<scale>)`` |
    ``replay:<path>`` — or pass a trace through.  The spec strings are
    the traces' own ``name``s, so parsing round-trips."""
    if isinstance(spec, ArrivalTrace):
        return spec
    if not spec:
        raise ValueError(
            "async mode needs an arrival trace: 'periodic(<period>)', "
            "'poisson(<rate>)', 'pareto(<alpha>,<scale>)', or "
            "'replay:<path>'"
        )
    if spec.startswith("replay:"):
        return ReplayTrace.from_file(spec[len("replay:"):], n_clients)
    for pat, build in _TRACE_RES.items():
        m = pat.match(spec)
        if m:
            try:
                return build(m)  # range errors (rate<=0, ...) propagate
            except ValueError as e:
                if "could not convert" not in str(e):
                    raise
                break
    raise ValueError(
        f"unknown arrival-trace spec {spec!r}; expected "
        "'periodic(<period>)' (e.g. periodic(1)), 'poisson(<rate>)' "
        "(e.g. poisson(0.5)), 'pareto(<alpha>,<scale>)' (e.g. "
        "pareto(1.5,0.5)), or 'replay:<path>'"
    )


# ------------------------------------------------------------ round engine


class RoundEngine:
    """The scheduling core shared by FL / FSL / IFL trainers.

    Owns the pieces every trainer used to hand-roll: the rng (one stream
    for minibatch sampling AND schedule draws, so a seed pins the whole
    run), the participation schedule, the round counter, and a metrics
    history — scheduled against an *exchange plane*
    (``repro.core.exchange``) that owns the wire side: the CommLedger,
    and (for the fusion backends) codec, EF state, FusionCache, and
    broadcast policy.  Trainers call ``participants()`` once per round,
    transmit through the plane, and finish with ``end_round(metrics)``.
    """

    def __init__(self, n_clients: int,
                 participation: Union[str, ParticipationSchedule, None] = None,
                 *, seed: int = 0, max_staleness: Optional[int] = None,
                 exchange: Optional[ExchangePlane] = None,
                 cohort: Optional[int] = None):
        self.n_clients = n_clients
        self.schedule = parse_participation(participation)
        self.rng = np.random.default_rng(seed)
        if cohort is not None:
            cohort = int(cohort)
            if cohort < 1:
                raise ValueError(f"cohort must be >= 1, got {cohort}")
            if cohort > n_clients:
                raise ValueError(
                    f"cohort ({cohort}) cannot exceed the population "
                    f"({n_clients} clients)"
                )
        self.cohort = cohort
        if exchange is not None and max_staleness is not None:
            raise ValueError(
                "RoundEngine: max_staleness is the exchange plane's "
                "setting — configure it on the plane, not the engine"
            )
        self.exchange = ExchangePlane() if exchange is None else exchange
        self.ledger = self.exchange.ledger
        # The fusion cache lives on the plane when the plane carries one
        # (IFL backends); engine-local otherwise — back compat for
        # direct constructions and the FL/FSL baselines (which never
        # touch it).
        self.cache = getattr(self.exchange, "cache", None)
        if self.cache is None:
            self.cache = FusionCache(max_staleness)
        self.round_idx = 0
        self.history: List[Dict[str, Any]] = []

    # -- per-round API ---------------------------------------------------

    def participants(self) -> np.ndarray:
        """Sorted slot indices participating in the current round.

        With a ``cohort`` cap, the schedule decides who is *available*
        and the engine admits a uniform C-of-available draw (the FedAvg
        cohort regime).  ``cohort=None`` draws nothing extra from the
        rng stream, so pre-cohort runs stay bitwise reproducible.
        """
        mask = self.schedule.mask(self.round_idx, self.n_clients, self.rng)
        avail = np.flatnonzero(mask)
        if self.cohort is not None and len(avail) > self.cohort:
            avail = np.sort(
                self.rng.choice(avail, size=self.cohort, replace=False)
            )
        return avail

    def sample(self, client, batch_size: int):
        """One private minibatch from ``client`` (needs .data_x/.data_y
        /.num_samples) — the exact draw order the seed trainers used."""
        idx = self.rng.integers(0, client.num_samples, size=batch_size)
        return jnp.asarray(client.data_x[idx]), jnp.asarray(client.data_y[idx])

    def aux_state(self) -> Dict[str, Any]:
        """JSON-able engine state for checkpoint resume: round counter,
        rng bit-generator state, ledger totals — plus the exchange
        plane's host state (``aux["exchange"]``: cache entry rounds and
        delta-mirror versions for the eager fusion plane, the
        age-replica for the SPMD one).  The cache's *arrays* ride in the
        trainer's snapshot tree (``FusionExchange.cache_tree``), so a
        restored run no longer cold-starts the fusion cache."""
        aux = {
            "round_idx": self.round_idx,
            "rng": self.rng.bit_generator.state,
            "ledger": {"uplink": self.ledger.uplink,
                       "downlink": self.ledger.downlink},
        }
        ex = self.exchange.aux_state()
        if ex:
            aux["exchange"] = ex
        return aux

    def restore_aux(self, aux: Dict[str, Any]) -> None:
        self.round_idx = int(aux["round_idx"])
        self.rng.bit_generator.state = aux["rng"]
        self.ledger.uplink = int(aux["ledger"]["uplink"])
        self.ledger.downlink = int(aux["ledger"]["downlink"])
        if "exchange" in aux:
            self.exchange.restore_aux(aux["exchange"])
        # Clear the cache in place (the plane and trainer alias it): an
        # in-place rewind may hold payloads uploaded AFTER the snapshot
        # round, which would look negative-staleness (never expiring) to
        # the rewound counter.  A FusionExchange-backed trainer then
        # repopulates it from the snapshot tree (``restore_cache``);
        # legacy engine-owned caches stay cold, as before.  Truncate the
        # history/per-round trails past the restored round either way.
        self.cache._entries.clear()
        del self.history[self.round_idx:]
        del self.ledger.per_round[self.round_idx:]

    def end_round(self, metrics: Dict[str, Any]) -> RoundReport:
        """Close the ledger round, log metrics, advance the counter.

        Returns a structured :class:`RoundReport` (the Trainer-protocol
        return type): cross-scheme fields — round index, cumulative
        ledger MB both legs, participants — are typed attributes, and
        everything else in ``metrics`` rides in ``report.metrics``. The
        report is a read-only Mapping over both, so dict-style consumers
        of the old ad-hoc metrics keep working unchanged.
        """
        self.ledger.end_round()
        # Age expired cache entries out of server MEMORY every round —
        # not just out of the broadcast. ``valid_entries`` already
        # evicts when the broadcast path consults it, so this is a
        # no-op for the synchronous trainers (bit-for-bit preserved);
        # it is what bounds the cache on long event-driven runs, where
        # eviction must not be contingent on a tick having traffic.
        self.cache.prune(self.round_idx)
        # Population-regime planes also age per-client carried state
        # (EF residuals, delta mirrors) out of memory; a no-op on every
        # legacy plane.
        self.exchange.prune(self.round_idx)
        metrics = dict(metrics)
        metrics.pop("uplink_mb", None)  # a ledger fact, not a metric
        report = RoundReport(
            round=int(metrics.pop("round", self.round_idx)),
            uplink_mb=self.ledger.uplink_mb,
            downlink_mb=self.ledger.downlink_mb,
            participants=[int(k) for k in metrics.pop("participants", [])],
            metrics=metrics,
        )
        self.history.append(report)
        self.round_idx += 1
        return report


class AsyncRoundEngine(RoundEngine):
    """Event-driven scheduling: arrivals on client clocks, server ticks.

    One engine round == one server tick of ``tick`` simulated seconds.
    Clients upload whenever their :class:`ArrivalTrace` clock fires;
    the server collects everything that arrived in the tick window and
    runs the round's fusion/modular pass on the current valid cache.
    ``participants()`` therefore returns the clients with >= 1 arrival
    in ``(round_idx * tick, (round_idx + 1) * tick]`` — multiple
    arrivals from one client coalesce into one upload of its freshest
    state (the raw event count rides in the report's ``arrivals``), so
    a tick prices exactly like a synchronous round with K participants
    and every analytic↔ledger parity carries over unchanged.

    Stragglers simply miss ticks: the staleness-bounded fusion cache
    (and, under ``broadcast='delta'``, the mirror catch-up machinery)
    already owns absence and rejoin — asynchrony is a schedule, not a
    new wire protocol.  Empty ticks are legal and cost nothing.

    The participation axis is owned by the trace (a schedule on top of
    arrivals would double-count availability), so the engine pins the
    schedule to ``full`` internally.
    """

    def __init__(self, n_clients: int, trace: Union[str, ArrivalTrace],
                 *, tick: float = 1.0, seed: int = 0,
                 max_staleness: Optional[int] = None,
                 exchange: Optional[ExchangePlane] = None,
                 cohort: Optional[int] = None):
        super().__init__(n_clients, "full", seed=seed,
                         max_staleness=max_staleness, exchange=exchange,
                         cohort=cohort)
        if not tick > 0:
            raise ValueError(f"tick must be > 0, got {tick}")
        self.trace = parse_trace(trace, n_clients)
        self.tick = float(tick)
        # The cursor draws its gaps from the engine's single rng stream,
        # interleaved with minibatch draws — one seed pins the run.
        self.cursor = self.trace.cursor(n_clients, self.rng)
        self.total_uploads = 0
        self.total_arrivals = 0
        self._pending: Optional[Tuple[np.ndarray, int]] = None

    @property
    def sim_time(self) -> float:
        """Simulated seconds elapsed through the last closed tick."""
        return self.round_idx * self.tick

    def participants(self) -> np.ndarray:
        """Clients with >= 1 arrival in the current tick window
        (coalesced; idempotent until ``end_round`` advances the tick)."""
        if self._pending is None:
            t_end = (self.round_idx + 1) * self.tick
            events = self.cursor.pop_until(t_end, self.rng)
            if self.cohort is None:
                slots = sorted({s for _, s in events})
            else:
                # Server at capacity: the C earliest distinct arrivals
                # win the tick; later arrivals are turned away (their
                # raw events still count in ``arrivals``).  Events come
                # (time, slot)-sorted, so first-seen order IS arrival
                # order.
                admitted: List[int] = []
                seen = set()
                for _, s in events:
                    if s not in seen:
                        seen.add(s)
                        admitted.append(s)
                slots = sorted(admitted[:self.cohort])
            self._pending = (np.asarray(slots, dtype=np.int64),
                             len(events))
        return self._pending[0]

    def end_round(self, metrics: Dict[str, Any]) -> RoundReport:
        parts, arrivals = (self._pending if self._pending is not None
                           else (np.zeros(0, np.int64), 0))
        self._pending = None
        self.total_uploads += len(parts)
        self.total_arrivals += arrivals
        t_end = (self.round_idx + 1) * self.tick
        metrics = dict(metrics)
        metrics["sim_time"] = t_end
        metrics["arrivals"] = int(arrivals)
        metrics["uploads_per_sec"] = self.total_uploads / t_end
        return super().end_round(metrics)

    # -- checkpoint resume (bitwise: rng state rides in the base aux,
    # -- the trace cursor and throughput counters ride here) ------------

    def aux_state(self) -> Dict[str, Any]:
        aux = super().aux_state()
        aux["async"] = {
            "cursor": self.cursor.state(),
            "uploads": int(self.total_uploads),
            "arrivals": int(self.total_arrivals),
        }
        return aux

    def restore_aux(self, aux: Dict[str, Any]) -> None:
        super().restore_aux(aux)
        a = aux["async"]
        self.cursor.restore(a["cursor"])
        self.total_uploads = int(a["uploads"])
        self.total_arrivals = int(a["arrivals"])
        self._pending = None


# ------------------------------------------------------ wall-clock models


def simulate_sync_wall_clock(
    trace: Union[str, ArrivalTrace], n_clients: int, rounds: int, *,
    participation: Union[str, ParticipationSchedule, None] = None,
    seed: int = 0,
) -> List[float]:
    """Per-round barrier durations of a SYNCHRONOUS run under ``trace``.

    The synchronous trainers have no clock (a round is a round), so the
    async-vs-sync comparison prices their barrier from the same arrival
    model: round r starts when round r-1's slowest participant landed,
    and closes at ``max`` over this round's scheduled participants of
    each one's next arrival — wall-clock pinned to the straggler tail,
    which is exactly what the event-driven engine retires.  Uses its own
    rng stream (seeded) so the simulation never perturbs a training
    run's draws; rounds whose barrier never closes (a replayed log that
    ends) report ``inf``, and empty-participant rounds cost 0.
    """
    trace = parse_trace(trace, n_clients)
    schedule = parse_participation(participation)
    rng = np.random.default_rng(seed)
    cursor = trace.cursor(n_clients, rng)
    t = 0.0
    durations: List[float] = []
    for r in range(rounds):
        parts = np.flatnonzero(schedule.mask(r, n_clients, rng))
        if len(parts) == 0:
            durations.append(0.0)
            continue
        landing = max(cursor.next_after(int(p), t, rng) for p in parts)
        if not math.isfinite(landing):
            # The barrier never closes (e.g. a replayed log that ends
            # mid-run): every subsequent round is stuck behind it, so
            # the whole tail is inf — leaving t unadvanced used to make
            # later rounds with livelier participants look finite.
            durations.extend([math.inf] * (rounds - r))
            break
        durations.append(landing - t)
        t = landing
    return durations


def expected_async_participants(
    trace: Union[str, ArrivalTrace], n_clients: int, tick: float, *,
    ticks: int = 256, seed: int = 0,
) -> Tuple[float, float]:
    """(mean coalesced uploads, mean raw arrivals) per tick.

    Replays the trace through the exact tick-coalescing the engine runs,
    so analytic reports (the dry-run's ``client_boundary`` section)
    price the async uplink with the same bookkeeping the ledger uses —
    the async analogue of ``expected_delta_entries``."""
    rng = np.random.default_rng(seed)
    cursor = parse_trace(trace, n_clients).cursor(n_clients, rng)
    uploads = arrivals = 0
    for t in range(ticks):
        events = cursor.pop_until((t + 1) * tick, rng)
        uploads += len({s for _, s in events})
        arrivals += len(events)
    return uploads / max(ticks, 1), arrivals / max(ticks, 1)
