"""Fusion-payload wire codecs — the compression layer of the IFL boundary.

The only bytes that ever cross the client boundary are fusion-layer
outputs ``(z_k, y_k)`` (Algorithm 1 lines 13-21). This module owns how
``z`` is represented *on the wire*: a registry of codecs, each exposing

  encode(z)                 -> payload   (a pytree of arrays; exactly the
                                          bytes that would be transmitted)
  decode(payload, shape=, dtype=) -> z_hat  (what the receiver trains on)
  wire_bytes(payload)       -> int       (measured payload bytes)
  encoded_nbytes(shape)     -> int       (analytic bytes for a z of
                                          ``shape`` — must equal
                                          wire_bytes(encode(z)) exactly,
                                          so ledger parity holds per codec)

Codecs:

  fp32          identity (the paper's baseline wire format)
  bf16 / fp16   half-precision cast (2x)
  int8          per-tensor affine quantization, fp32 scale+zero sidecar (~4x)
  int8_channel  per-channel affine (scale/zero per fusion feature)
  int8_row      symmetric per-row absmax — the scheme the fused Pallas
                kernel (`kernels.fusion_proj.fusion_proj_quant_pallas`)
                produces directly from the projection epilogue
  topk / topk<r>  magnitude top-k sparsification along the fusion dim,
                int32 index sidecar (r = kept fraction, default 0.25)

Every encode/decode is a shape-static pure function, so trainers can
``jax.jit`` them (the SPMD trainer runs encode -> all-gather -> decode
inside one jitted round step; the eager trainer jits them per client).
Labels ride alongside uncompressed — they are int32 and already tiny.

Registry is the extension point for future sketching / error-feedback
(EF21-style residual) codecs: subclass ``Codec``, call ``register``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.comm import nbytes

__all__ = [
    "Codec",
    "CODECS",
    "get_codec",
    "register",
    "available_codecs",
]


class Codec:
    """Base wire codec. Subclasses define the representation of z."""

    name: str = "abstract"

    def encode(self, z: jnp.ndarray):
        raise NotImplementedError

    def decode(self, payload, *, shape: Optional[Tuple[int, ...]] = None,
               dtype=None) -> jnp.ndarray:
        raise NotImplementedError

    def wire_bytes(self, payload) -> int:
        """Measured bytes of an encoded payload — the same ``nbytes``
        the CommLedger counts, so parity is by construction."""
        return nbytes(payload)

    def encoded_nbytes(self, shape: Tuple[int, ...]) -> int:
        """Analytic wire bytes for a z of ``shape`` — exact, not estimated."""
        raise NotImplementedError

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self.name!r})"


@dataclass(frozen=True, repr=False)
class IdentityCodec(Codec):
    """fp32 baseline: ship z exactly as produced — a true identity, so
    the SPMD path keeps bf16 activations at their native width instead
    of upcasting before the collective. ``encoded_nbytes`` models the
    paper's fp32 wire format (the eager trainer's z is fp32)."""

    name: str = "fp32"

    def encode(self, z):
        return {"z": z}

    def decode(self, payload, *, shape=None, dtype=None):
        z = payload["z"]
        return z if dtype is None else z.astype(dtype)

    def encoded_nbytes(self, shape):
        return int(np.prod(shape)) * 4


@dataclass(frozen=True, repr=False)
class CastCodec(Codec):
    """Lossy dtype cast (bf16 / fp16): 2x fewer wire bytes, no sidecar."""

    name: str = "bf16"
    wire_dtype: str = "bfloat16"

    def encode(self, z):
        return {"z": z.astype(jnp.dtype(self.wire_dtype))}

    def decode(self, payload, *, shape=None, dtype=None):
        return payload["z"].astype(dtype or jnp.float32)

    def encoded_nbytes(self, shape):
        return int(np.prod(shape)) * jnp.dtype(self.wire_dtype).itemsize


@dataclass(frozen=True, repr=False)
class Int8AffineCodec(Codec):
    """Affine uint-style int8: q = round((z - min) / scale) - 128.

    ``per_channel=False``: one fp32 (scale, zero) pair per tensor.
    ``per_channel=True``:  one pair per fusion feature (last axis).
    Round-trip error is bounded by scale/2 = (max - min) / 510.
    """

    name: str = "int8"
    per_channel: bool = False

    def _axes(self, ndim: int):
        return tuple(range(ndim - 1)) if self.per_channel else None

    def encode(self, z):
        zf = z.astype(jnp.float32)
        axes = self._axes(zf.ndim)
        zmin = jnp.min(zf, axis=axes)
        zmax = jnp.max(zf, axis=axes)
        scale = jnp.maximum((zmax - zmin) / 255.0, 1e-12)
        q = jnp.round((zf - zmin) / scale) - 128.0
        q = jnp.clip(q, -128, 127).astype(jnp.int8)
        return {"q": q, "scale": scale.astype(jnp.float32),
                "zero": zmin.astype(jnp.float32)}

    def decode(self, payload, *, shape=None, dtype=None):
        q = payload["q"].astype(jnp.float32)
        z = (q + 128.0) * payload["scale"] + payload["zero"]
        return z.astype(dtype or jnp.float32)

    def encoded_nbytes(self, shape):
        sidecar = (shape[-1] if self.per_channel else 1) * 2 * 4
        return int(np.prod(shape)) * 1 + sidecar


def quantize_rows_sym(y: jnp.ndarray):
    """Symmetric per-row absmax int8: q = round(y / (absmax/127)).

    THE single definition of the int8_row wire scheme — shared by
    ``Int8RowCodec``, the jnp kernel oracle (``kernels.ref``), and the
    fused Pallas epilogue (``kernels.fusion_proj``), so the three paths
    cannot drift. -> (q int8, scale fp32 (..., 1))."""
    yf = y.astype(jnp.float32)
    scale = jnp.maximum(
        jnp.max(jnp.abs(yf), axis=-1, keepdims=True) / 127.0, 1e-12
    )
    q = jnp.clip(jnp.round(yf / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


@dataclass(frozen=True, repr=False)
class Int8RowCodec(Codec):
    """Symmetric per-row absmax int8 (see ``quantize_rows_sym``).

    One fp32 scale per row of the flattened (rows, d_fusion) view — the
    exact scheme ``fusion_proj_quant_pallas`` emits from the fused
    projection epilogue, so the TPU path can produce wire payloads with
    zero extra HBM round-trips.
    """

    name: str = "int8_row"

    def encode(self, z):
        q, scale = quantize_rows_sym(z)
        return {"q": q, "scale": scale}

    def decode(self, payload, *, shape=None, dtype=None):
        z = payload["q"].astype(jnp.float32) * payload["scale"]
        return z.astype(dtype or jnp.float32)

    def encoded_nbytes(self, shape):
        rows = int(np.prod(shape[:-1])) if len(shape) > 1 else 1
        return int(np.prod(shape)) * 1 + rows * 4


@dataclass(frozen=True, repr=False)
class TopKCodec(Codec):
    """Magnitude top-k along the fusion dim; values fp32 + int32 indices.

    Keeps ``ratio`` of the d_fusion features per sample (at least 1);
    everything else decodes to exactly zero. Decode needs the original
    ``shape`` (the payload only carries the kept entries).
    """

    name: str = "topk"
    ratio: float = 0.25

    def k_of(self, d: int) -> int:
        return max(1, min(d, int(round(self.ratio * d))))

    def encode(self, z):
        zf = z.astype(jnp.float32)
        d = zf.shape[-1]
        k = self.k_of(d)
        flat = zf.reshape(-1, d)
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
        vals = jnp.take_along_axis(flat, idx, axis=-1)
        lead = z.shape[:-1]
        return {"values": vals.reshape(*lead, k),
                "indices": idx.astype(jnp.int32).reshape(*lead, k)}

    def decode(self, payload, *, shape=None, dtype=None):
        vals, idx = payload["values"], payload["indices"]
        if shape is None:
            raise ValueError("topk decode requires the original z shape")
        d = shape[-1]
        k = vals.shape[-1]
        rows = int(np.prod(shape[:-1])) if len(shape) > 1 else 1
        flat = jnp.zeros((rows, d), jnp.float32)
        r = jnp.arange(rows)[:, None]
        flat = flat.at[r, idx.reshape(rows, k)].set(vals.reshape(rows, k))
        return flat.reshape(shape).astype(dtype or jnp.float32)

    def encoded_nbytes(self, shape):
        rows = int(np.prod(shape[:-1])) if len(shape) > 1 else 1
        return rows * self.k_of(shape[-1]) * (4 + 4)


# ------------------------------------------------------------------ registry


CODECS: Dict[str, Codec] = {}


def register(codec: Codec) -> Codec:
    CODECS[codec.name] = codec
    return codec


register(IdentityCodec())
register(CastCodec("bf16", "bfloat16"))
register(CastCodec("fp16", "float16"))
register(Int8AffineCodec("int8", per_channel=False))
register(Int8AffineCodec("int8_channel", per_channel=True))
register(Int8RowCodec())
register(TopKCodec())


def available_codecs() -> Tuple[str, ...]:
    return tuple(sorted(CODECS))


def get_codec(codec: Union[str, Codec, None]) -> Codec:
    """Resolve a codec name (or pass a Codec through).

    ``topk<r>`` parameterizes the kept fraction, e.g. ``topk0.1``.
    """
    if codec is None:
        return CODECS["fp32"]
    if isinstance(codec, Codec):
        return codec
    if codec in CODECS:
        return CODECS[codec]
    if codec.startswith("topk"):
        try:
            ratio = float(codec[len("topk"):])
        except ValueError:
            ratio = None
        if ratio is not None and 0.0 < ratio <= 1.0:
            return TopKCodec(name=codec, ratio=ratio)
    raise ValueError(
        f"unknown codec {codec!r}; available: {available_codecs()} "
        "(or 'topk<ratio>' e.g. topk0.1)"
    )
