"""Fusion-payload wire codecs — the compression layer of the IFL boundary.

The only bytes that ever cross the client boundary are fusion-layer
outputs ``(z_k, y_k)`` (Algorithm 1 lines 13-21). This module owns how
``z`` is represented *on the wire*: a registry of codecs, each exposing

  encode(z)                 -> payload   (a pytree of arrays; exactly the
                                          bytes that would be transmitted)
  decode(payload, shape=, dtype=) -> z_hat  (what the receiver trains on)
  wire_bytes(payload)       -> int       (measured payload bytes)
  encoded_nbytes(shape)     -> int       (analytic bytes for a z of
                                          ``shape`` — must equal
                                          wire_bytes(encode(z)) exactly,
                                          so ledger parity holds per codec)

Codecs:

  fp32          identity (the paper's baseline wire format)
  bf16 / fp16   half-precision cast (2x)
  int8          per-tensor affine quantization, fp32 scale+zero sidecar (~4x)
  int8_channel  per-channel affine (scale/zero per fusion feature)
  int8_row      symmetric per-row absmax — the scheme the fused Pallas
                kernel (`kernels.fusion_proj.fusion_proj_quant_pallas`)
                produces directly from the projection epilogue
  topk / topk<r>  magnitude top-k sparsification along the fusion dim,
                int32 index sidecar (r = kept fraction, default 0.25)
  int4          packed symmetric per-row absmax int4 — two nibbles per
                byte, fp32 row-scale sidecar (~8x vs fp32)
  sketch / sketch<r>  count-sketch along d_fusion: signed hash into
                round(r * d) fp32 buckets (default r = 0.25), bucket-mean
                decode. No index sidecar at all (the hash is a shared
                seed), unlike top-k — 1/r compression with dense wire
                bytes.
  ef(<codec>)   EF21 error feedback around ANY registered codec
                (``ef(topk0.1)``, ``ef(int8_row)``, ``ef(sketch0.25)``...)

Stateful codecs (error feedback) extend the protocol with an optional
state API, defaulting to a stateless passthrough so plain codecs are
untouched:

  init_state(shape) -> e0              (per-client residual, zeros)
  encode_with_state(z, e) -> (payload, e')

``EFCodec`` implements Richtárik et al.'s EF21 recurrence: the client
transmits ``encode(z + e)`` and keeps the compression residual
``e' = (z + e) - decode(encode(z + e))`` for the next round, which turns
any contractive compressor into one whose bias vanishes in the limit —
aggressive codecs (topk, int4) recover fp32-level accuracy. EF changes
what is *in* the payload, never its size: ``encoded_nbytes`` delegates
to the wrapped codec, so analytic↔ledger byte parity is preserved.

Every encode/decode is a shape-static pure function, so trainers can
``jax.jit`` them (the SPMD trainer runs encode -> all-gather -> decode
inside one jitted round step, carrying the EF residual as sharded round
state; the eager trainer jits them per client and keeps the residual in
a per-client dict). Labels ride alongside uncompressed — they are int32
and already tiny.

Registry is the extension point for future codecs: subclass ``Codec``,
call ``register`` — ``ef(...)`` wrapping and the property-test suite
(tests/test_codec_properties.py) pick new codecs up automatically, as
``CountSketchCodec`` demonstrates.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.comm import nbytes

__all__ = [
    "Codec",
    "CODECS",
    "CountSketchCodec",
    "EFCodec",
    "Int4RowCodec",
    "ef_residual_update",
    "get_codec",
    "quantize_rows_sym",
    "register",
    "available_codecs",
]


class Codec:
    """Base wire codec. Subclasses define the representation of z."""

    name: str = "abstract"
    has_state: bool = False  # True for EF-style codecs carrying a residual

    def encode(self, z: jnp.ndarray):
        raise NotImplementedError

    def decode(self, payload, *, shape: Optional[Tuple[int, ...]] = None,
               dtype=None) -> jnp.ndarray:
        raise NotImplementedError

    # ---- optional state API (EF residuals); stateless by default ----

    def init_state(self, shape: Tuple[int, ...], dtype=jnp.float32):
        """Initial per-client codec state for a z of ``shape``.

        Stateless codecs carry none (an empty pytree), so trainers can
        thread the state unconditionally through jit/vmap/scan."""
        return ()

    def encode_with_state(self, z: jnp.ndarray, state):
        """Encode one round's z given carried state -> (payload, state').

        Stateless default: ignore and return the state unchanged, so
        every existing codec works under the stateful calling
        convention without modification."""
        return self.encode(z), state

    # ---- optional fused (Pallas) encode path -------------------------

    def fused_spec(self, shape: Tuple[int, ...]):
        """Describe the fused Pallas encode for a z of ``shape``.

        Returns a dict (kernel name, block sizes, payload leaves) when
        ``kernels.wire_fused`` has a single-launch encode kernel for
        this codec at this shape, else None — the fallback rule is
        always the jnp path, never an error. Host-level and static:
        exchange planes and the dryrun report both key off it."""
        from repro.kernels import wire_fused

        return wire_fused.encode_spec(self, shape)

    def fused_encode(self, z: jnp.ndarray, *, block_rows: Optional[int] = None,
                     interpret: bool = False):
        """Encode z in one Pallas kernel launch, or None if unsupported.

        The payload pytree is bitwise-identical to ``encode(z)`` (leaf
        names, shapes, dtypes, and values) — the jnp codec stays the
        oracle and the ground truth for ``encoded_nbytes``/ledger
        parity. Callers treat None as "use the jnp path"."""
        from repro.kernels import wire_fused

        return wire_fused.wire_encode(
            z, self, block_rows=block_rows, interpret=interpret
        )

    def fused_encode_with_state(self, z: jnp.ndarray, state, *,
                                block_rows: Optional[int] = None,
                                interpret: bool = False):
        """Stateful twin of ``fused_encode`` -> (payload, state') or None.

        Stateless codecs pass the state through unchanged, mirroring
        ``encode_with_state``; ``EFCodec`` overrides this with the
        fused EF21 epilogue (residual update inside the kernel)."""
        payload = self.fused_encode(
            z, block_rows=block_rows, interpret=interpret
        )
        return None if payload is None else (payload, state)

    # ---- byte accounting ----

    def wire_bytes(self, payload) -> int:
        """Measured bytes of an encoded payload — the same ``nbytes``
        the CommLedger counts, so parity is by construction."""
        return nbytes(payload)

    def encoded_nbytes(self, shape: Tuple[int, ...]) -> int:
        """Analytic wire bytes for a z of ``shape`` — exact, not estimated."""
        raise NotImplementedError

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self.name!r})"


@dataclass(frozen=True, repr=False)
class IdentityCodec(Codec):
    """fp32 baseline: ship z exactly as produced — a true identity, so
    the SPMD path keeps bf16 activations at their native width instead
    of upcasting before the collective. ``encoded_nbytes`` models the
    paper's fp32 wire format (the eager trainer's z is fp32)."""

    name: str = "fp32"

    def encode(self, z):
        return {"z": z}

    def decode(self, payload, *, shape=None, dtype=None):
        z = payload["z"]
        return z if dtype is None else z.astype(dtype)

    def encoded_nbytes(self, shape):
        return int(np.prod(shape)) * 4


@dataclass(frozen=True, repr=False)
class CastCodec(Codec):
    """Lossy dtype cast (bf16 / fp16): 2x fewer wire bytes, no sidecar."""

    name: str = "bf16"
    wire_dtype: str = "bfloat16"

    def encode(self, z):
        return {"z": z.astype(jnp.dtype(self.wire_dtype))}

    def decode(self, payload, *, shape=None, dtype=None):
        return payload["z"].astype(dtype or jnp.float32)

    def encoded_nbytes(self, shape):
        return int(np.prod(shape)) * jnp.dtype(self.wire_dtype).itemsize


@dataclass(frozen=True, repr=False)
class Int8AffineCodec(Codec):
    """Affine uint-style int8: q = round((z - min) / scale) - 128.

    ``per_channel=False``: one fp32 (scale, zero) pair per tensor.
    ``per_channel=True``:  one pair per fusion feature (last axis).
    Round-trip error is bounded by scale/2 = (max - min) / 510.
    """

    name: str = "int8"
    per_channel: bool = False

    def _axes(self, ndim: int):
        return tuple(range(ndim - 1)) if self.per_channel else None

    def encode(self, z):
        zf = z.astype(jnp.float32)
        axes = self._axes(zf.ndim)
        zmin = jnp.min(zf, axis=axes)
        zmax = jnp.max(zf, axis=axes)
        scale = jnp.maximum((zmax - zmin) / 255.0, 1e-12)
        q = jnp.round((zf - zmin) / scale) - 128.0
        q = jnp.clip(q, -128, 127).astype(jnp.int8)
        return {"q": q, "scale": scale.astype(jnp.float32),
                "zero": zmin.astype(jnp.float32)}

    def decode(self, payload, *, shape=None, dtype=None):
        q = payload["q"].astype(jnp.float32)
        z = (q + 128.0) * payload["scale"] + payload["zero"]
        return z.astype(dtype or jnp.float32)

    def encoded_nbytes(self, shape):
        sidecar = (shape[-1] if self.per_channel else 1) * 2 * 4
        return int(np.prod(shape)) * 1 + sidecar


def quantize_rows_sym(y: jnp.ndarray, qmax: int = 127):
    """Symmetric per-row absmax quantization: q = round(y / (absmax/qmax)).

    THE single definition of the symmetric row schemes — shared by
    ``Int8RowCodec`` (qmax=127), ``Int4RowCodec`` (qmax=7), the jnp
    kernel oracles (``kernels.ref``), and the fused Pallas epilogues
    (``kernels.fusion_proj`` / ``kernels.wire_fused``), so the paths
    cannot drift. -> (q int8 in [-qmax, qmax], scale fp32 (..., 1)).

    An all-zero row (dead ReLU row, or the payload cache's
    encode(zeros) empty-slot convention) has absmax 0: its scale is
    pinned to 1.0 so 0/scale stays an exact 0 at any compute precision
    — never a 0/0 or a subnormal blow-up. Every path that quantizes
    rows inherits the guard from here."""
    yf = y.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(yf), axis=-1, keepdims=True)
    # absmax * (1/qmax), NOT absmax / qmax: XLA rewrites division by a
    # constant into multiply-by-reciprocal inside compiled kernels but
    # not in op-by-op execution — writing the multiply in the source is
    # what keeps eager oracle and fused Pallas path bitwise equal.
    scale = jnp.where(
        absmax > 0.0, jnp.maximum(absmax * (1.0 / qmax), 1e-12), 1.0
    )
    q = jnp.clip(jnp.round(yf / scale), -qmax, qmax).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def ef_residual_update(zf: jnp.ndarray, c: jnp.ndarray, z_hat: jnp.ndarray,
                       max_ratio: Optional[float]) -> jnp.ndarray:
    """EF21 residual + per-row trust-region clip (see ``EFCodec``).

    ``zf`` is the raw fp32 fusion signal, ``c = zf + e`` the compressed
    quantity, ``z_hat = decode(encode(c))``. Shared by
    ``EFCodec.encode_with_state`` and the fused Pallas epilogues so the
    two paths compute the recurrence with the exact same ops (bitwise
    parity in interpret mode is a test gate, not a hope)."""
    e = c - z_hat
    if max_ratio is not None and np.isfinite(max_ratio):
        z_norm = jnp.linalg.norm(zf, axis=-1, keepdims=True)
        e_norm = jnp.linalg.norm(e, axis=-1, keepdims=True)
        e = e * jnp.minimum(
            1.0, max_ratio * z_norm / jnp.maximum(e_norm, 1e-12)
        )
    return e


@dataclass(frozen=True, repr=False)
class Int8RowCodec(Codec):
    """Symmetric per-row absmax int8 (see ``quantize_rows_sym``).

    One fp32 scale per row of the flattened (rows, d_fusion) view — the
    exact scheme ``fusion_proj_quant_pallas`` emits from the fused
    projection epilogue, so the TPU path can produce wire payloads with
    zero extra HBM round-trips.
    """

    name: str = "int8_row"

    def encode(self, z):
        q, scale = quantize_rows_sym(z)
        return {"q": q, "scale": scale}

    def decode(self, payload, *, shape=None, dtype=None):
        z = payload["q"].astype(jnp.float32) * payload["scale"]
        return z.astype(dtype or jnp.float32)

    def encoded_nbytes(self, shape):
        rows = int(np.prod(shape[:-1])) if len(shape) > 1 else 1
        return int(np.prod(shape)) * 1 + rows * 4


@dataclass(frozen=True, repr=False)
class TopKCodec(Codec):
    """Magnitude top-k along the fusion dim; values fp32 + int32 indices.

    Keeps ``ratio`` of the d_fusion features per sample (at least 1);
    everything else decodes to exactly zero. Decode needs the original
    ``shape`` (the payload only carries the kept entries).
    """

    name: str = "topk"
    ratio: float = 0.25

    def k_of(self, d: int) -> int:
        return max(1, min(d, int(round(self.ratio * d))))

    def encode(self, z):
        zf = z.astype(jnp.float32)
        d = zf.shape[-1]
        k = self.k_of(d)
        flat = zf.reshape(-1, d)
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
        vals = jnp.take_along_axis(flat, idx, axis=-1)
        lead = z.shape[:-1]
        return {"values": vals.reshape(*lead, k),
                "indices": idx.astype(jnp.int32).reshape(*lead, k)}

    def decode(self, payload, *, shape=None, dtype=None):
        vals, idx = payload["values"], payload["indices"]
        if shape is None:
            raise ValueError("topk decode requires the original z shape")
        d = shape[-1]
        k = vals.shape[-1]
        rows = int(np.prod(shape[:-1])) if len(shape) > 1 else 1
        flat = jnp.zeros((rows, d), jnp.float32)
        r = jnp.arange(rows)[:, None]
        flat = flat.at[r, idx.reshape(rows, k)].set(vals.reshape(rows, k))
        return flat.reshape(shape).astype(dtype or jnp.float32)

    def encoded_nbytes(self, shape):
        rows = int(np.prod(shape[:-1])) if len(shape) > 1 else 1
        return rows * self.k_of(shape[-1]) * (4 + 4)


@dataclass(frozen=True, repr=False)
class Int4RowCodec(Codec):
    """Packed symmetric per-row absmax int4: q = round(z / (absmax/7)),
    clipped to [-7, 7], two nibbles per byte, fp32 scale per row.

    ~8x fewer wire bytes than fp32 with one sidecar float per row of the
    flattened (rows, d_fusion) view. An odd last dim is padded with a
    zero nibble inside the packed byte — ``encoded_nbytes`` counts
    ceil(d/2) bytes per row, exactly what ``encode`` emits. Aggressive
    enough to want error feedback: pair as ``ef(int4)``.
    """

    name: str = "int4"

    def encode(self, z):
        q, scale = quantize_rows_sym(z, qmax=7)
        if q.shape[-1] % 2:
            pad = [(0, 0)] * (q.ndim - 1) + [(0, 1)]
            q = jnp.pad(q, pad)  # zero nibble; sliced off on decode
        u = (q + 8).astype(jnp.uint8)  # [-7,7] -> [1,15]; pad -> 8
        packed = u[..., 0::2] | (u[..., 1::2] << 4)
        return {"q4": packed, "scale": scale.astype(jnp.float32)}

    def decode(self, payload, *, shape=None, dtype=None):
        if shape is None:
            # The packed width is ceil(d/2) bytes — an odd d is
            # indistinguishable from d+1 without the original shape.
            raise ValueError("int4 decode requires the original z shape")
        packed, scale = payload["q4"], payload["scale"]
        lo = (packed & jnp.uint8(0xF)).astype(jnp.int32) - 8
        hi = (packed >> 4).astype(jnp.int32) - 8
        q = jnp.stack([lo, hi], axis=-1).reshape(
            *packed.shape[:-1], packed.shape[-1] * 2
        )
        z = q[..., : shape[-1]].astype(jnp.float32) * scale
        return z.astype(dtype or jnp.float32)

    def encoded_nbytes(self, shape):
        rows = int(np.prod(shape[:-1])) if len(shape) > 1 else 1
        return rows * ((shape[-1] + 1) // 2) + rows * 4


@functools.lru_cache(maxsize=256)
def _sketch_tables(d: int, w: int, seed: int):
    """Shared (hash, sign, 1/bucket-count) tables for a (d -> w) sketch.

    Derived deterministically from (d, w, seed) with numpy at trace
    time, so encoder and decoder agree without any index sidecar on the
    wire — the whole point of sketching vs top-k. The bucket counts are
    returned pre-inverted: decode multiplies by 1/count instead of
    dividing, because the table is a baked constant in the jnp oracle
    but a runtime input to the fused kernels — XLA folds a constant
    divisor into a reciprocal-multiply, so only a shared precomputed
    reciprocal keeps the two paths bitwise equal."""
    rng = np.random.default_rng(seed + 1_000_003 * d + w)
    h = rng.integers(0, w, size=d)
    s = (rng.integers(0, 2, size=d) * 2 - 1).astype(np.float32)
    counts = np.maximum(np.bincount(h, minlength=w), 1)
    inv_counts = (1.0 / counts).astype(np.float32)
    # Cache NUMPY arrays only: converting here would capture per-trace
    # constants (tracers) in the lru_cache and leak them across jits.
    return h.astype(np.int32), s, inv_counts


@dataclass(frozen=True, repr=False)
class CountSketchCodec(Codec):
    """Count-sketch along the fusion dim (Charikar-Chen-Farach-Colton).

    Encode: each of the d fusion features is assigned a fixed bucket
    h(i) in [0, w) and sign s(i); the wire payload is the w bucket sums
    of the signed features — ``w = round(ratio * d)`` fp32 values per
    row, nothing else. Decode: z_hat[i] = s(i) * sketch[h(i)] / |bucket|
    — the *bucket-mean* estimator, which within every bucket is the
    orthogonal projection of the signed feature values onto the all-ones
    direction. That makes the codec deterministically non-expansive
    (||z_hat - z|| <= ||z|| always, not just in expectation), so the
    registry-wide energy bound holds and ``ef(sketch...)`` inherits a
    contractive compressor, exactly what EF21 assumes.

    The hash/sign tables are derived from (d, w, shared seed): both ends
    compute them locally, so unlike top-k there is no index sidecar on
    the wire — pure 1/ratio compression at fp32 bucket precision.
    """

    name: str = "sketch"
    ratio: float = 0.25
    seed: int = 0x5EED

    def w_of(self, d: int) -> int:
        return max(1, min(d, int(round(self.ratio * d))))

    def encode(self, z):
        zf = z.astype(jnp.float32)
        d = zf.shape[-1]
        h, s, _ = _sketch_tables(d, self.w_of(d), self.seed)
        flat = (zf * s).reshape(-1, d)
        sk = jnp.zeros((flat.shape[0], self.w_of(d)), jnp.float32)
        sk = sk.at[:, h].add(flat)
        return {"sketch": sk.reshape(*z.shape[:-1], self.w_of(d))}

    def decode(self, payload, *, shape=None, dtype=None):
        if shape is None:
            # w = round(ratio * d) is not invertible (rounding), and the
            # hash tables are keyed by d — the original shape is required.
            raise ValueError("sketch decode requires the original z shape")
        d = shape[-1]
        h, s, inv_counts = _sketch_tables(d, self.w_of(d), self.seed)
        vals = payload["sketch"] * inv_counts  # bucket means
        zh = vals[..., h] * s
        return zh.reshape(shape).astype(dtype or jnp.float32)

    def encoded_nbytes(self, shape):
        rows = int(np.prod(shape[:-1])) if len(shape) > 1 else 1
        return rows * self.w_of(shape[-1]) * 4


@dataclass(frozen=True, repr=False)
class EFCodec(Codec):
    """EF21 error feedback around any inner codec (Richtárik et al.).

    Per client, per round:  c = z + e;  payload = inner.encode(c);
    e' = c - inner.decode(payload).  The residual re-injects everything
    the compressor dropped, so the *cumulative* transmitted signal is
    unbiased and topk/int4 converge at fp32 accuracy. The wire format is
    exactly the inner codec's — ``encode``/``decode``/``encoded_nbytes``
    delegate, so byte parity and every downstream consumer (ledger,
    analytic formulas, gather specs) are untouched. Only
    ``encode_with_state`` differs, and the residual never leaves the
    client (it is not part of the payload).

    ``max_ratio`` is a per-row trust region on the carried residual:
    ||e'||_row <= max_ratio * ||z||_row. Classic EF analyses assume the
    SAME signal is compressed each step; IFL transmits a fresh fusion
    minibatch per round, so for aggressive sparsifiers (topk0.1 drops
    ~56% of the energy per row) the stationary residual grows to ~1.3x
    the signal norm and stale cross-sample mass dominates both top-k
    selection and the decoded values — measured on synth-KMNIST, raw EF
    then *underperforms* plain topk. The clip bounds that staleness
    noise while keeping the bias correction; for high-fidelity inner
    codecs (int8*, int4, casts) the residual is far inside the trust
    region and the recurrence stays the textbook one exactly."""

    inner: Codec = None
    name: str = ""
    max_ratio: float = 0.3
    has_state = True

    def __post_init__(self):
        if not self.name:
            object.__setattr__(self, "name", f"ef({self.inner.name})")

    def encode(self, z):
        return self.inner.encode(z)

    def decode(self, payload, *, shape=None, dtype=None):
        return self.inner.decode(payload, shape=shape, dtype=dtype)

    def init_state(self, shape, dtype=jnp.float32):
        return jnp.zeros(shape, dtype)

    def encode_with_state(self, z, state):
        zf = z.astype(jnp.float32)
        c = zf + state
        payload = self.inner.encode(c)
        z_hat = self.inner.decode(payload, shape=c.shape, dtype=jnp.float32)
        return payload, ef_residual_update(zf, c, z_hat, self.max_ratio)

    # EF's stateless wire format IS the inner codec's, so the fused
    # stateless encode delegates; the stateful one runs the EF21
    # epilogue (inner encode + in-register decode + residual update)
    # inside the same single kernel launch.

    def fused_spec(self, shape):
        spec = self.inner.fused_spec(shape)
        if spec is not None:
            spec = dict(spec, kernel=f"wire_encode[{self.name}]", ef=True)
        return spec

    def fused_encode(self, z, *, block_rows=None, interpret=False):
        return self.inner.fused_encode(
            z, block_rows=block_rows, interpret=interpret
        )

    def fused_encode_with_state(self, z, state, *, block_rows=None,
                                interpret=False):
        from repro.kernels import wire_fused

        return wire_fused.wire_encode_ef(
            z, state, self, block_rows=block_rows, interpret=interpret
        )

    def encoded_nbytes(self, shape):
        return self.inner.encoded_nbytes(shape)


# ------------------------------------------------------------------ registry


CODECS: Dict[str, Codec] = {}


def register(codec: Codec) -> Codec:
    CODECS[codec.name] = codec
    return codec


register(IdentityCodec())
register(CastCodec("bf16", "bfloat16"))
register(CastCodec("fp16", "float16"))
register(Int8AffineCodec("int8", per_channel=False))
register(Int8AffineCodec("int8_channel", per_channel=True))
register(Int8RowCodec())
register(TopKCodec())
register(Int4RowCodec())
register(CountSketchCodec())


def available_codecs() -> Tuple[str, ...]:
    return tuple(sorted(CODECS))


def get_codec(codec: Union[str, Codec, None]) -> Codec:
    """Resolve a codec name (or pass a Codec through).

    ``topk<r>`` parameterizes the kept fraction, e.g. ``topk0.1``.
    ``sketch<r>`` parameterizes the bucket fraction, e.g. ``sketch0.25``.
    ``ef(<codec>)`` wraps any resolvable codec with EF21 error feedback,
    e.g. ``ef(topk0.1)``, ``ef(int8_row)``, ``ef(sketch0.25)``.
    """
    if codec is None:
        return CODECS["fp32"]
    if isinstance(codec, Codec):
        return codec
    if codec in CODECS:
        return CODECS[codec]
    if codec.startswith("ef(") and codec.endswith(")"):
        return EFCodec(inner=get_codec(codec[len("ef("):-1]))
    if codec.startswith("topk"):
        try:
            ratio = float(codec[len("topk"):])
        except ValueError:
            ratio = None
        if ratio is not None and 0.0 < ratio <= 1.0:
            return TopKCodec(name=codec, ratio=ratio)
    if codec.startswith("sketch"):
        try:
            ratio = float(codec[len("sketch"):])
        except ValueError:
            ratio = None
        if ratio is not None and 0.0 < ratio <= 1.0:
            return CountSketchCodec(name=codec, ratio=ratio)
    raise ValueError(
        f"unknown codec {codec!r}; available: {available_codecs()} "
        "(or 'topk<ratio>' e.g. topk0.1, 'sketch<ratio>' e.g. sketch0.25, "
        "or 'ef(<codec>)' e.g. ef(int4))"
    )
