from repro.sharding.rules import (  # noqa: F401
    param_pspecs,
    batch_pspec,
    cache_pspecs,
    tree_shardings,
)
