"""Logical-axis sharding rules: param-tree path -> PartitionSpec.

Conventions (mesh axes: optional 'client', optional 'pod', 'data', 'model'):
  - Column-parallel weights (d_model -> parallel): last dim on 'model'.
  - Row-parallel weights (parallel -> d_model): first matmul dim on 'model'.
  - MoE expert stacks: expert dim on 'model' (expert parallelism).
  - Embedding/vocab: vocab dim on 'model'.
  - FSDP (ZeRO-3-style, enabled per-arch when params/chip would not fit):
    the *other* matmul dim additionally on 'data'; GSPMD inserts the
    all-gather at use / reduce-scatter on grads.
  - Stacked leading dims (layer groups, IFL client stacking) are prepended:
    groups -> None (scan slices it), clients -> 'client'.
  - 1-D leaves (norm scales, biases) are replicated: tiny, and replication
    avoids collective churn inside every layer.

The rules match on leaf *path names*, not positions, so new modules get
sane defaults (largest divisible dim on 'model') without editing a table.
"""

from __future__ import annotations

import os
import re
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# name-pattern -> spec template over the trailing (matmul) dims.
# 'M' = model axis, 'F' = fsdp axis (data, only when fsdp enabled), '-' = None.
_RULES = [
    # embeddings / heads
    (r"embed/table$", ("M", "F")),
    (r"lm_head/w$", ("F", "M")),
    # attention (GQA + cross + qwen bias)
    (r"(attn|cross)/w[qkv]/w$", ("F", "M")),
    (r"(attn|cross)/w[qkv]/b$", ("M",)),
    (r"(attn|cross)/wo/w$", ("M", "F")),
    # MLA
    (r"wq_a/w$", ("F", "-")),
    (r"wq_b/w$", ("-", "M")),
    (r"wkv_a/w$", ("F", "-")),
    (r"wkv_b/w$", ("-", "M")),
    # dense MLP
    (r"ffn/w_(gate|up)/w$", ("F", "M")),
    (r"ffn/w_down/w$", ("M", "F")),
    # MoE: expert-parallel stacks + router
    (r"moe/experts/w_(gate|up)/w$", ("M", "F", "-")),
    (r"moe/experts/w_down/w$", ("M", "-", "F")),
    (r"moe/router/w$", ("F", "-")),
    (r"moe/shared/w_(gate|up)/w$", ("F", "M")),
    (r"moe/shared/w_down/w$", ("M", "F")),
    # mamba
    (r"mamba/in_proj/w$", ("F", "M")),
    (r"mamba/conv_[wb]$", ("-", "M")),
    (r"mamba/x_proj/w$", ("M", "-")),
    (r"mamba/dt_proj/w$", ("-", "M")),
    (r"mamba/dt_proj/b$", ("M",)),
    (r"mamba/a_log$", ("M", "-")),
    (r"mamba/d_skip$", ("M",)),
    (r"mamba/out_proj/w$", ("M", "F")),
    # mlstm
    (r"mlstm/up/w$", ("F", "M")),
    (r"mlstm/conv_[wb]$", ("-", "M")),
    (r"mlstm/w[qkv]/w$", ("M", "-")),
    (r"mlstm/w_if/w$", ("M", "-")),
    (r"mlstm/skip$", ("M",)),
    (r"mlstm/down/w$", ("M", "F")),
    # slstm: small scalar-memory block, replicate
    (r"slstm/", ()),
    # fusion interface: keep z model-sharded on d_fusion
    (r"fusion_in/w$", ("F", "M")),
    (r"fusion_out/w$", ("M", "F")),
    (r"img_proj/w$", ("F", "M")),
]


def _leaf_spec(path: str, ndim: int, fsdp: bool):
    # §Perf probe lever: vocab-sharded embedding tables force an SPMD
    # gather that replicates (B, S, d) per device ("involuntary full
    # rematerialization" warnings); REPRO_EMBED_SHARD=dmodel shards the
    # table on d_model instead so the lookup stays local.
    if re.search(r"embed/table$", path) and \
            os.environ.get("REPRO_EMBED_SHARD") == "dmodel":
        axes = [None, "model"]
        return [None] * (ndim - 2) + axes
    for pat, tmpl in _RULES:
        if re.search(pat, path):
            axes = []
            for t in tmpl:
                if t == "M":
                    axes.append("model")
                elif t == "F":
                    axes.append("data" if fsdp else None)
                else:
                    axes.append(None)
            # left-pad with Nones for stacked leading dims (layer groups).
            pad = ndim - len(axes)
            if pad < 0:  # conv_b matched a 2-dim template with 1-dim leaf
                axes = axes[-ndim:] if ndim else []
                pad = ndim - len(axes)
            return [None] * pad + axes
    if ndim <= 1:
        return [None] * ndim
    return [None] * ndim  # default: replicate (norms, small misc)


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_pspecs(params, *, fsdp: bool = False, client_axis: bool = False):
    """PartitionSpec pytree matching ``params``.

    client_axis: params leaves carry a leading stacked client dim that
    goes on the 'client' mesh axis (IFL stacked-client layout).
    """

    def spec_for(path, leaf):
        p = _path_str(path)
        ndim = leaf.ndim if hasattr(leaf, "ndim") else len(leaf.shape)
        if client_axis:
            axes = _leaf_spec(p, ndim - 1, fsdp)
            return P("client", *axes)
        return P(*_leaf_spec(p, ndim, fsdp))

    return jax.tree_util.tree_map_with_path(spec_for, params)


def batch_pspec(batch, *, client_axis: bool = False, data_axes=("data",)):
    """Shard every batch leaf's batch dim. Layouts:
    client_axis: leading dim = clients -> 'client', next dim -> data.
    """

    def spec_for(path, leaf):
        ndim = len(leaf.shape)
        if client_axis:
            # (N, [tau,] B, ...): client dim -> 'client', per-client batch
            # dim -> data axes, tau (scanned) and trailing dims unsharded.
            if ndim == 2:
                return P("client", data_axes)
            if ndim == 3:
                return P("client", data_axes, None)
            if ndim >= 4:  # (N, tau, B, ...trailing feature dims)
                return P("client", None, data_axes, *([None] * (ndim - 3)))
            return P("client", *([None] * (ndim - 1)))
        if ndim == 0:
            return P()
        return P(data_axes, *([None] * (ndim - 1)))

    return jax.tree_util.tree_map_with_path(spec_for, batch)


# Decode-cache rules: (regex, semantic_rank, tail builder). Leading dims
# beyond the semantic rank are layer-group stacks -> None. Cache key names
# are mixer-prefixed (ssm_/mlstm_/slstm_) so rules are unambiguous.
def _cache_rules(seq_shard: bool):
    b_ax = "data"
    s_ax = "data" if seq_shard else None
    if seq_shard:
        b_ax = None  # batch ~1: context-parallel over the cache seq dim
    return [
        (r"/slot_pos$", 1, (None,)),
        (r"/(k|v)$", 4, (b_ax, s_ax, "model", None)),  # (B, S, KVH, hd)
        (r"/(ckv|krope)$", 3, (b_ax, s_ax, None)),  # MLA latent stream
        (r"/ssm_h$", 3, (b_ax, "model", None)),  # (B, d_inner, d_state)
        (r"/ssm_conv$", 3, (b_ax, None, "model")),  # (B, K-1, d_inner)
        (r"/mlstm_C$", 4, (b_ax, None, None, "model")),  # (B, nh, dk, dv)
        (r"/mlstm_n$", 3, (b_ax, None, "model")),
        (r"/mlstm_m$", 2, (b_ax, None)),
        (r"/mlstm_conv$", 3, (b_ax, None, "model")),
        (r"/slstm_[cnmh]$", 2, (b_ax, "model")),  # (B, d)
    ]


def cache_pspecs(cache, *, seq_shard: bool = False):
    """Decode-cache shardings: batch on 'data'; KV heads / state channels
    on 'model'; optionally the cache sequence dim on 'data' (context-
    parallel decode for batch~1 long-context). Axes that do not divide a
    dim are dropped by the sanitizer in ``tree_shardings``."""
    rules = _cache_rules(seq_shard)

    def spec_for(path, leaf):
        name = _path_str(path)
        ndim = len(leaf.shape)
        for pat, rank, tail in rules:
            if re.search(pat, name):
                lead = [None] * (ndim - rank)
                return P(*lead, *tail)
        return P(*([None] * ndim))

    return jax.tree_util.tree_map_with_path(spec_for, cache)


def sanitize_pspec(spec: P, shape, mesh: Mesh) -> P:
    """Drop mesh axes that do not divide the corresponding dim (e.g.
    batch=1 long-context decode, 4-head smoke models on a 16-way axis)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for i, ax in enumerate(spec):
        if ax is None or i >= len(shape):
            out.append(None if i >= len(shape) else ax)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        n = 1
        for a in axes:
            n *= sizes[a]
        out.append(ax if shape[i] % n == 0 else None)
    return P(*out[: len(shape)], *([None] * max(0, len(shape) - len(out))))


def tree_shardings(mesh: Mesh, pspecs, shapes=None):
    """NamedShardings for a pspec tree; if ``shapes`` (a matching tree of
    arrays/structs) is given, every spec is divisibility-sanitized."""
    if shapes is None:
        return jax.tree.map(
            lambda s: NamedSharding(mesh, s),
            pspecs,
            is_leaf=lambda x: isinstance(x, P),
        )
    return jax.tree.map(
        lambda s, l: NamedSharding(mesh, sanitize_pspec(s, l.shape, mesh)),
        pspecs,
        shapes,
        is_leaf=lambda x: isinstance(x, P),
    )
