"""Three-term roofline from a compiled dry-run artifact (deliverable g).

    compute    = HLO_FLOPs_total   / (chips * peak_FLOPs)
    memory     = HLO_bytes_total   / (chips * HBM_bw)
    collective = link_bytes_total  / (chips * link_bw)

Sources: ``compiled.cost_analysis()`` for FLOPs / bytes (XLA reports
per-partition numbers post-SPMD; we scale by chip count for the totals),
and the post-SPMD HLO text for collective traffic — cost_analysis does
not model collectives at all. Per-op link bytes use the ring model on
per-partition shard shapes (the shapes printed in partitioned HLO):

    all-gather       out_bytes * (g-1)/g        (recv volume per chip)
    all-reduce       2 * bytes * (g-1)/g        (reduce-scatter + gather)
    reduce-scatter   out_bytes * (g-1)          (input = out * g)
    all-to-all       bytes * (g-1)/g
    collective-permute  bytes                   (one hop)
"""

from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np


@dataclass(frozen=True)
class Hardware:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12  # bf16 / chip
    hbm_bw: float = 819e9  # bytes/s / chip
    link_bw: float = 50e9  # bytes/s / link (ICI)


HW = Hardware()

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

# result types like: bf16[4,64,512]{2,1,0} or tuple (f32[8], f32[8])
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\})")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[\d+\]")
_PAIRS_RE = re.compile(r"source_target_pairs=\{")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))  # [n_groups, group_size] <= [n]
    m = _GROUPS_RE.search(line)
    if m:
        return max(1, m.group(1).count(",") + 1)
    return 2  # conservative default when groups are implicit


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, float]:
    """Per-chip link bytes by collective kind, from partitioned HLO."""
    out: Dict[str, float] = {
        "all-gather": 0.0, "all-reduce": 0.0, "reduce-scatter": 0.0,
        "all-to-all": 0.0, "collective-permute": 0.0,
    }
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        type_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(type_str)
        g = _group_size(line)
        if kind == "all-gather":
            out[kind] += b * (g - 1) / g
        elif kind == "all-reduce":
            out[kind] += 2 * b * (g - 1) / g
        elif kind == "reduce-scatter":
            out[kind] += b * (g - 1)
        elif kind == "all-to-all":
            out[kind] += b * (g - 1) / g
        else:  # collective-permute
            out[kind] += b
    out["total"] = sum(out.values())
    return out


def roofline_terms(cost: Dict[str, float], coll_bytes_per_chip: float,
                   n_chips: int, hw: Hardware = HW,
                   model_flops_total: Optional[float] = None) -> Dict:
    """cost: compiled.cost_analysis() (per-partition numbers)."""
    flops_pp = float(cost.get("flops", 0.0))
    bytes_pp = float(cost.get("bytes accessed", 0.0))
    t_compute = flops_pp / hw.peak_flops
    t_memory = bytes_pp / hw.hbm_bw
    t_coll = coll_bytes_per_chip / hw.link_bw
    terms = {
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_coll,
        "hlo_flops_total": flops_pp * n_chips,
        "hlo_bytes_total": bytes_pp * n_chips,
        "collective_bytes_total": coll_bytes_per_chip * n_chips,
        "n_chips": n_chips,
    }
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: terms[k])
    terms["dominant"] = dom.replace("_s", "")
    step = max(t_compute, t_memory, t_coll)
    terms["bound_step_s"] = step
    if model_flops_total is not None:
        terms["model_flops_total"] = model_flops_total
        terms["useful_flops_ratio"] = (
            model_flops_total / max(terms["hlo_flops_total"], 1.0)
        )
        # MFU if the step ran at the roofline-bound time.
        terms["mfu_bound"] = model_flops_total / (
            max(step, 1e-12) * n_chips * hw.peak_flops
        )
    return terms


# ------------------------------------------------------------ model flops


def model_flops(kind: str, *, params_base: float, params_mod: float,
                params_embed: float = 0.0, tokens: float,
                tau: int = 0, n_clients: int = 0) -> float:
    """Analytic 'useful' FLOPs (the 6·N·D convention; N = active params).

    kind:
      'dp_train'  — 6·N·D.
      'ifl_round' — base phase: τ steps of fwd(full) + bwd(base) =
                    τ·(2(Nb+Nm) + 4Nb)·D_c summed over clients; fusion
                    fwd pass 2·Nb·D_c; modular phase: each client trains
                    on ALL N·D_c tokens: 6·Nm·N·D_c per client.
      'prefill'   — 2·N·D.
      'decode'    — 2·N·D (D = batch tokens for one step).
    D/tokens = global tokens for the step; D_c = tokens per client.
    """
    N = params_base + params_mod
    if kind == "dp_train":
        return 6.0 * N * tokens
    if kind == "prefill" or kind == "decode":
        return 2.0 * N * tokens
    if kind == "ifl_round":
        dc = tokens / max(n_clients, 1)
        base_phase = n_clients * tau * (2 * N + 4 * params_base) * dc
        fusion = n_clients * 2 * params_base * dc
        modular = n_clients * 6 * params_mod * (n_clients * dc)
        return base_phase + fusion + modular
    raise ValueError(kind)
