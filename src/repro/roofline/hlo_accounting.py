"""Trip-count-aware roofline accounting from post-SPMD HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, not
×trip-count (verified empirically: a scanned 8-step matmul reports 1/8 of
the unrolled FLOPs). Every layer stack in this framework is a scan — as
are the τ-local-step loop, attention query blocks, Mamba/mLSTM chunks —
so raw cost_analysis undercounts big models by 1-2 orders of magnitude,
and the same text-level blindness hits collective bytes.

This module re-derives the three roofline inputs from ``compiled.as_text()``:

  1. Parse computations and the call graph (while body/condition,
     fusion ``calls=``, ``to_apply=``, conditional branches).
  2. Infer each while's trip count from the largest s32 constant in its
     condition computation (jax scans lower to ``i < N``).
  3. Propagate execution multipliers (products of enclosing trip counts).
  4. FLOPs: every ``dot`` op contributes 2·prod(result)·prod(contracted)
     × multiplier. (Matmul-dominated models; elementwise flops are noise
     at roofline granularity.) ``convolution`` handled analogously.
  5. HBM bytes: post-fusion top-level ops read operands and write results
     once per execution — sum (operands + result) sizes × multiplier for
     materializing ops, skipping free ops (bitcast/tuple/gte/parameter)
     and the *insides* of fusion subcomputations (the fusion op already
     accounts for them).
  6. Collectives: per-kind ring-model link bytes × multiplier.

Shard shapes in partitioned HLO are per-device, so all outputs are
per-chip numbers.
"""

from __future__ import annotations

import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\-.]+)\s*\(.*\)\s*->.*\{\s*$")
# result types may contain '=' inside /*index=N*/ comments, so match the
# op kind as the first bare `word(` token after the type.
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\-.]+)\s*=\s*(.*?)\s([\w\-]+)\((.*)$"
)
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_WHILE_ATTR = re.compile(r"condition=%([\w\-.]+),\s*body=%([\w\-.]+)")
_CALLS_ATTR = re.compile(r"(?:calls|to_apply)=%([\w\-.]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_S32 = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_OPERAND = re.compile(r"%([\w\-.]+)")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[\d+\]")
_GROUPS_BRACE = re.compile(r"replica_groups=\{(\{[^}]*\})")
_DOT_CDIMS = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


class _Op:
    __slots__ = ("name", "rtype", "kind", "rest")

    def __init__(self, name, rtype, kind, rest):
        self.name, self.rtype, self.kind, self.rest = name, rtype, kind, rest


def _parse_computations(text: str) -> Dict[str, List[_Op]]:
    comps: Dict[str, List[_Op]] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    comps["__entry__"] = comps[cur]
                    entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_LINE.match(line)
        if m:
            comps[cur].append(_Op(m.group(1), m.group(2), m.group(3),
                                  m.group(4)))
    comps["__entry_name__"] = entry  # type: ignore
    return comps


def _trip_count(cond_ops: List[_Op]) -> int:
    best = 1
    for op in cond_ops:
        if op.kind == "constant":
            m = re.match(r"(\d+)\)", op.rest)
            if m and "s32[]" in op.rtype:
                best = max(best, int(m.group(1)))
        # constants may also hide in tiny compare fusions' text
    return best


def _group_size(rest: str) -> int:
    m = _GROUPS_IOTA.search(rest)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE.search(rest)
    if m:
        return max(1, m.group(1).count(",") + 1)
    return 2


def analyze_hlo(text: str) -> Dict:
    comps = _parse_computations(text)
    entry = comps.pop("__entry_name__", None)  # type: ignore
    comps.pop("__entry__", None)

    # name -> result type, for resolving dot operand shapes.
    def_type: Dict[str, str] = {}
    for ops in comps.values():
        for op in ops:
            def_type[op.name] = op.rtype

    # Which computations are fusion-called (their ops don't touch HBM and
    # their dots are counted via multiplier of the *caller* computation).
    fusion_called = set()
    for ops in comps.values():
        for op in ops:
            if op.kind == "fusion":
                m = _CALLS_ATTR.search(op.rest)
                if m:
                    fusion_called.add(m.group(1))

    # Multiplier propagation over the call graph.
    mult: Dict[str, float] = defaultdict(float)
    if entry is None or entry not in comps:
        entry = next(iter(comps))
    mult[entry] = 1.0
    # topological-ish fixed point (call graph is a DAG).
    for _ in range(64):
        changed = False
        for cname, ops in comps.items():
            m0 = mult.get(cname, 0.0)
            if m0 == 0.0:
                continue
            for op in ops:
                targets: List[Tuple[str, float]] = []
                if op.kind == "while":
                    wm = _WHILE_ATTR.search(op.rest)
                    if wm:
                        cond, body = wm.group(1), wm.group(2)
                        trip = _trip_count(comps.get(cond, []))
                        targets.append((body, m0 * trip))
                        targets.append((cond, m0 * (trip + 1)))
                elif op.kind == "conditional":
                    bm = _BRANCHES.search(op.rest)
                    if bm:
                        for t in _OPERAND.findall(bm.group(1)):
                            targets.append((t, m0))
                else:
                    cm = _CALLS_ATTR.search(op.rest)
                    if cm:
                        targets.append((cm.group(1), m0))
                for tgt, val in targets:
                    if tgt in comps and mult.get(tgt, 0.0) < val:
                        mult[tgt] = val
                        changed = True
        if not changed:
            break

    flops = 0.0
    hbm = 0.0
    coll = {k: 0.0 for k in _COLL_KINDS}
    n_while = 0

    for cname, ops in comps.items():
        m0 = mult.get(cname, 0.0)
        if m0 == 0.0:
            continue
        in_fusion = cname in fusion_called
        for op in ops:
            kind = op.kind
            if kind == "while":
                n_while += 1
            # ---- FLOPs: dots & convs anywhere (incl. inside fusions).
            if kind == "dot":
                rdims = _shape_dims(op.rtype)
                cd = _DOT_CDIMS.search(op.rest)
                lhs_name = _OPERAND.search(op.rest)
                csize = 1
                if cd and lhs_name and lhs_name.group(1) in def_type:
                    ldims = _shape_dims(def_type[lhs_name.group(1)])
                    for idx in (cd.group(1).split(",") if cd.group(1) else []):
                        i = int(idx)
                        if i < len(ldims):
                            csize *= ldims[i]
                flops += m0 * 2.0 * math.prod(rdims or [1]) * csize
            elif kind == "convolution":
                rdims = _shape_dims(op.rtype)
                # conservative: 2 * out_elems * (kernel elems) — resolve rhs
                names = _OPERAND.findall(op.rest)
                kelems = 1
                if len(names) >= 2 and names[1] in def_type:
                    kd = _shape_dims(def_type[names[1]])
                    kelems = math.prod(kd or [1]) // max(rdims[-1] if rdims else 1, 1)
                flops += m0 * 2.0 * math.prod(rdims or [1]) * max(kelems, 1)
            # ---- collectives (top-level or in loop bodies; fusions never
            # contain collectives).
            for ck in _COLL_KINDS:
                if kind == ck or kind == ck + "-start":
                    b = _shape_bytes(op.rtype)
                    g = _group_size(op.rest)
                    if ck == "all-gather":
                        coll[ck] += m0 * b * (g - 1) / g
                    elif ck == "all-reduce":
                        coll[ck] += m0 * 2 * b * (g - 1) / g
                    elif ck == "reduce-scatter":
                        coll[ck] += m0 * b * (g - 1)
                    elif ck == "all-to-all":
                        coll[ck] += m0 * b * (g - 1) / g
                    else:
                        coll[ck] += m0 * b
                    break
            # ---- HBM traffic: materializing top-level ops only.
            if in_fusion or kind in _FREE_OPS or kind == "while" \
                    or kind == "conditional" or kind.endswith("-done"):
                continue
            out_b = _shape_bytes(op.rtype)
            in_b = 0
            for oname in _OPERAND.findall(op.rest.split(", calls=")[0]
                                          .split(", condition=")[0]):
                if oname in def_type:
                    in_b += _shape_bytes(def_type[oname])
            hbm += m0 * (out_b + in_b)

    coll_total = sum(coll.values())
    return {
        "flops": flops,
        "hbm_bytes": hbm,
        "collectives": {**coll, "total": coll_total},
        "n_while": n_while,
    }
