"""Pallas TPU kernel: fused fusion-layer projection y = act(x @ w + b).

The fusion projection (d_model -> d_fusion) sits on IFL's hot path: it
runs on every token of every client every round, and its output is the
bytes that cross the client boundary. Fusing bias + activation into the
matmul epilogue removes two HBM round-trips of the (M, N) output.

TPU mapping: grid (M/bm, N/bn, K/bk) with an fp32 VMEM accumulator
scratch; K is the innermost (sequential) grid dim so the accumulator
lives across K steps and the epilogue fires once on the last K step.
Default blocks are (256, 256, 512) — multiples of the (8, 128) MXU tile,
~1.1 MB working set (x-tile 256x512x2B + w-tile 512x256x2B + acc
256x256x4B), comfortably inside the 128 MB v5e VMEM with room for
double-buffering.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _epilogue(y, b, act: str):
    if b is not None:
        y = y + b.astype(jnp.float32)
    if act == "relu":
        y = jnp.maximum(y, 0.0)
    elif act == "silu":
        y = y * jax.nn.sigmoid(y)
    elif act != "none":
        raise ValueError(act)
    return y


def _kernel_bias(x_ref, w_ref, b_ref, o_ref, acc_ref, *, act: str, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = _epilogue(acc_ref[...], b_ref[...], act).astype(o_ref.dtype)


def _kernel_nobias(x_ref, w_ref, o_ref, acc_ref, *, act: str, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = _epilogue(acc_ref[...], None, act).astype(o_ref.dtype)


def fusion_proj_pallas(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: Optional[jnp.ndarray] = None,
    act: str = "none",
    *,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """x: (M, K), w: (K, N), b: (N,) -> (M, N). Dims must tile evenly
    (the ops.py wrapper pads arbitrary shapes)."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (K, K2)
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    nk = K // bk

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
        pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
    ]
    args = [x, w]
    if b is not None:
        in_specs.append(pl.BlockSpec((bn,), lambda i, j, k: (j,)))
        args.append(b)
        kern = functools.partial(_kernel_bias, act=act, nk=nk)
    else:
        kern = functools.partial(_kernel_nobias, act=act, nk=nk)

    return pl.pallas_call(
        kern,
        grid=(M // bm, N // bn, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(*args)
