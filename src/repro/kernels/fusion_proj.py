"""Pallas TPU kernels: fused fusion-layer projection y = act(x @ w + b),
plus the fused-quantize variant that emits the int8 wire payload.

The fusion projection (d_model -> d_fusion) sits on IFL's hot path: it
runs on every token of every client every round, and its output is the
bytes that cross the client boundary. Fusing bias + activation into the
matmul epilogue removes two HBM round-trips of the (M, N) output.

``fusion_proj_quant_pallas`` goes one step further for compressed IFL
(codec 'int8_row'): the epilogue also computes the per-row absmax scale
and casts to int8 *inside the kernel*, so the fp32 activation tile never
touches HBM at all — the only output traffic is the int8 payload plus a
(M, 1) fp32 scale sidecar, exactly the bytes the 'client' all-gather
moves. It tiles M and K only and keeps the full N (= d_fusion, 432-2048)
in-block, which is what makes the row reduction free in the epilogue;
acc tile 256x2048x4B = 2 MB still fits VMEM comfortably.

TPU mapping: grid (M/bm, N/bn, K/bk) with an fp32 VMEM accumulator
scratch; K is the innermost (sequential) grid dim so the accumulator
lives across K steps and the epilogue fires once on the last K step.
Default blocks are (256, 256, 512) — multiples of the (8, 128) MXU tile,
~1.1 MB working set (x-tile 256x512x2B + w-tile 512x256x2B + acc
256x256x4B), comfortably inside the 128 MB v5e VMEM with room for
double-buffering.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.codec import quantize_rows_sym


def _epilogue(y, b, act: str):
    if b is not None:
        y = y + b.astype(jnp.float32)
    if act == "relu":
        y = jnp.maximum(y, 0.0)
    elif act == "silu":
        y = y * jax.nn.sigmoid(y)
    elif act != "none":
        raise ValueError(act)
    return y


def _kernel_bias(x_ref, w_ref, b_ref, o_ref, acc_ref, *, act: str, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = _epilogue(acc_ref[...], b_ref[...], act).astype(o_ref.dtype)


def _kernel_nobias(x_ref, w_ref, o_ref, acc_ref, *, act: str, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = _epilogue(acc_ref[...], None, act).astype(o_ref.dtype)


def fusion_proj_pallas(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: Optional[jnp.ndarray] = None,
    act: str = "none",
    *,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """x: (M, K), w: (K, N), b: (N,) -> (M, N). Dims must tile evenly
    (the ops.py wrapper pads arbitrary shapes)."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (K, K2)
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    nk = K // bk

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
        pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
    ]
    args = [x, w]
    if b is not None:
        in_specs.append(pl.BlockSpec((bn,), lambda i, j, k: (j,)))
        args.append(b)
        kern = functools.partial(_kernel_bias, act=act, nk=nk)
    else:
        kern = functools.partial(_kernel_nobias, act=act, nk=nk)

    return pl.pallas_call(
        kern,
        grid=(M // bm, N // bn, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(*args)


# ------------------------------------------------------------ fused quant


def _kernel_quant(x_ref, w_ref, b_ref, q_ref, s_ref, acc_ref, *, act: str,
                  nk: int, has_bias: bool):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(1) == nk - 1)
    def _flush():
        y = _epilogue(acc_ref[...], b_ref[...] if has_bias else None, act)
        q, scale = quantize_rows_sym(y)  # the canonical int8_row scheme
        q_ref[...] = q
        s_ref[...] = scale


def fusion_proj_quant_pallas(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: Optional[jnp.ndarray] = None,
    act: str = "none",
    *,
    bm: int = 256,
    bk: int = 512,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (M, K), w: (K, N), b: (N,) -> (q int8 (M, N), scale fp32 (M, 1)).

    Grid (M/bm, K/bk) with full N per block (the per-row absmax needs the
    whole row, and d_fusion is small); K is the sequential innermost dim
    so the fp32 accumulator lives across K steps and the quantizing
    epilogue fires once. M must tile evenly (the ops.py wrapper pads
    rows); any K works — it is zero-padded up to a bk multiple (padded
    x columns / w rows are zero, contributing nothing to the dot), so
    tiles stay full-size even for odd or prime K.
    """
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (K, K2)
    bm = min(bm, M)
    bk = min(bk, K)
    rem = K % bk
    if rem:
        x = jnp.pad(x, ((0, 0), (0, bk - rem)))
        w = jnp.pad(w, ((0, bk - rem), (0, 0)))
        K += bk - rem
    assert M % bm == 0, (M, bm)
    nk = K // bk

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, k: (i, k)),
        pl.BlockSpec((bk, N), lambda i, k: (k, 0)),
    ]
    args = [x, w]
    has_bias = b is not None
    if has_bias:
        in_specs.append(pl.BlockSpec((N,), lambda i, k: (0,)))
        args.append(b)
        kern = functools.partial(_kernel_quant, act=act, nk=nk, has_bias=True)
    else:
        kern = functools.partial(
            _kernel_quant_nobias, act=act, nk=nk
        )

    return pl.pallas_call(
        kern,
        grid=(M // bm, nk),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((bm, N), lambda i, k: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i, k: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, N), jnp.int8),
            jax.ShapeDtypeStruct((M, 1), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bm, N), jnp.float32)],
        interpret=interpret,
    )(*args)


def _kernel_quant_nobias(x_ref, w_ref, q_ref, s_ref, acc_ref, *, act: str,
                         nk: int):
    _kernel_quant(x_ref, w_ref, None, q_ref, s_ref, acc_ref, act=act,
                  nk=nk, has_bias=False)


# ------------------------------------------------- generic codec epilogue


def _kernel_encode(x_ref, w_ref, *refs, act: str, nk: int, has_bias: bool,
                   ef: bool, scheme, max_ratio):
    """Matmul with any wire scheme as the flush epilogue (+ EF21).

    ``refs`` layout: [b_ref]? [e_ref]? scheme-const refs..
    payload-leaf refs.. [e'_ref]? acc scratch last — the projection
    result is encoded (and the EF residual updated) in-register on the
    final K step, so the fp32 activation tile never leaves VMEM.
    """
    from repro.core.codec import ef_residual_update

    i = 0
    b_ref = refs[0] if has_bias else None
    i += int(has_bias)
    e_ref = refs[i] if ef else None
    i += int(ef)
    consts = {
        name: refs[i + j][...] for j, name in enumerate(scheme.consts)
    }
    i += len(consts)
    out_refs = refs[i:-1]
    acc_ref = refs[-1]

    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(1) == nk - 1)
    def _flush():
        y = _epilogue(acc_ref[...], b_ref[...] if has_bias else None, act)
        c = y + e_ref[...] if ef else y
        payload, z_hat = scheme.encode_block(c, consts)
        for ref, name in zip(out_refs, scheme.leaf_names):
            ref[...] = payload[name]
        if ef:
            out_refs[len(scheme.leaf_names)][...] = ef_residual_update(
                y, c, z_hat, max_ratio
            )


def fusion_proj_encode_pallas(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: Optional[jnp.ndarray] = None,
    act: str = "none",
    *,
    scheme,
    e: Optional[jnp.ndarray] = None,
    max_ratio: Optional[float] = None,
    bm: int = 256,
    bk: int = 512,
    interpret: bool = False,
):
    """Projection + wire encode (+ EF21 residual update) in one launch.

    The ``fusion_proj_quant_pallas`` pattern generalized over the
    ``wire_fused`` scheme family: int4 nibble-pack, top-k select,
    count-sketch scatter — and, with ``e`` (the carried EF residual,
    (M, N)), the EF21 epilogue ``c = y + e``, payload = encode(c),
    ``e' = clip(c - decode(payload))`` as an extra output. Same grid as
    the quant kernel: (M/bm, K/bk) with the full N in-block, K
    zero-padded to a bk multiple. Returns the payload leaf arrays in
    scheme order (+ e' last when ``e`` is given).
    """
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (K, K2)
    assert scheme.d == N, (scheme.d, N)
    bm = min(bm, M)
    bk = min(bk, K)
    rem = K % bk
    if rem:
        x = jnp.pad(x, ((0, 0), (0, bk - rem)))
        w = jnp.pad(w, ((0, bk - rem), (0, 0)))
        K += bk - rem
    assert M % bm == 0, (M, bm)
    nk = K // bk
    ef = e is not None

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, k: (i, k)),
        pl.BlockSpec((bk, N), lambda i, k: (k, 0)),
    ]
    args = [x, w]
    has_bias = b is not None
    if has_bias:
        in_specs.append(pl.BlockSpec((N,), lambda i, k: (0,)))
        args.append(b)
    if ef:
        in_specs.append(pl.BlockSpec((bm, N), lambda i, k: (i, 0)))
        args.append(e)
    for tbl in scheme.consts.values():
        arr = jnp.asarray(tbl)
        in_specs.append(
            pl.BlockSpec(arr.shape, lambda i, k, _n=arr.ndim: (0,) * _n)
        )
        args.append(arr)

    out_specs = [
        pl.BlockSpec((bm, *tail), lambda i, k, _n=len(tail): (i,) + (0,) * _n)
        for tail, _ in scheme.leaves.values()
    ]
    out_shape = [
        jax.ShapeDtypeStruct((M, *tail), dt)
        for tail, dt in scheme.leaves.values()
    ]
    if ef:
        out_specs.append(pl.BlockSpec((bm, N), lambda i, k: (i, 0)))
        out_shape.append(jax.ShapeDtypeStruct((M, N), jnp.float32))

    return pl.pallas_call(
        functools.partial(_kernel_encode, act=act, nk=nk,
                          has_bias=has_bias, ef=ef, scheme=scheme,
                          max_ratio=max_ratio),
        grid=(M // bm, nk),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((bm, N), jnp.float32)],
        interpret=interpret,
    )(*args)
