"""Pallas TPU kernel: RMSNorm over the last dim.

Memory-bound op: one pass, fp32 reduction in-register, row-block tiling
(rows are tokens). Fusing scale multiply avoids a second HBM pass. Runs
before every mixer/FFN in every assigned arch, so at train_4k it touches
~2 * num_layers * tokens * d_model bytes per step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * s_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm_pallas(x: jnp.ndarray, scale: jnp.ndarray, *, eps: float = 1e-6,
                   block_rows: int = 256, interpret: bool = False):
    """x: (M, D); scale: (D,)."""
    M, D = x.shape
    br = min(block_rows, M)
    assert M % br == 0, (M, br)
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(M // br,),
        in_specs=[
            pl.BlockSpec((br, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, D), x.dtype),
        interpret=interpret,
    )(x, scale)
