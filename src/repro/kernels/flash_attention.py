"""Pallas TPU kernel: blocked causal (optionally sliding-window) flash
attention with online softmax.

TPU mapping: grid (batch*heads, S/bq, S/bk) — kv innermost so the fp32
running (m, l, acc) scratch carries across kv steps; output flushes on
the last kv block. Causal + out-of-window kv blocks are skipped with
``pl.when`` (no MXU work issued), giving ~2x savings for causal and
linear-in-S work for windowed layers. Masked lanes are zeroed via an
explicit multiply (robust for fully-masked rows, which sliding windows
produce). Block sizes default to (bq, bk) = (256, 256): q-tile + kv-tiles
+ acc ≈ 256·128·(2+2+2)B + 256·(256+128)·4B ≈ 0.6 MB of VMEM at hd=128.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int, bq: int, bk: int,
            nk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq
    kv_start = ki * bk

    # Block-level skip: entirely above the diagonal, or entirely left of
    # the sliding window.
    live = True
    if causal:
        live = kv_start <= q_start + bq - 1
    if window > 0:
        live = jnp.logical_and(live, kv_start + bk - 1 > q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[...]
        s = jnp.dot(
            q, k_ref[...].T, preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = kv_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= cols <= rows
        if window > 0:
            mask &= cols > rows - window
        s = jnp.where(mask, s, NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        # Explicit zeroing keeps fully-masked rows exact (p would be
        # exp(0)=1 there otherwise).
        p = jnp.exp(s - m_new[:, None]) * mask.astype(jnp.float32)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
            p.astype(v_ref.dtype), v_ref[...],
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _flush():
        o_ref[...] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        ).astype(o_ref.dtype)


def _decode_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, scale: float, nk: int):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...]  # (1, hd)
    s = jnp.dot(
        q, k_ref[...].T, preferred_element_type=jnp.float32
    ) * scale  # (1, bk)
    # Causality and the ring-buffer window arrive pre-folded into the
    # validity row (slot_pos semantics) — no index arithmetic here.
    mask = (valid_ref[...] != 0).reshape(1, -1)
    s = jnp.where(mask, s, NEG)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None]) * mask.astype(jnp.float32)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p.astype(v_ref.dtype), v_ref[...],
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _flush():
        o_ref[...] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        ).astype(o_ref.dtype)


def flash_decode_pallas(
    q: jnp.ndarray,      # (BH, hd) — one query row per batch*head
    k: jnp.ndarray,      # (BH, L, hd) KV cache
    v: jnp.ndarray,      # (BH, L, hd)
    valid: jnp.ndarray,  # (BH, L) int32/bool — live cache rows
    *,
    scale: Optional[float] = None,
    bk: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """Serving decode step as a flash kernel: one query token attends to
    the whole KV cache, grid (BH, L/bk) with the fp32 (m, l, acc) running
    scratch carried across kv blocks exactly as in the full-sequence
    kernel above.  Fully-masked rows flush zeros (the jnp oracle returns
    the uniform mean of v there instead — in real decode the row is
    unreachable because ``attn_decode`` always marks the just-written
    token valid, and empty serving slots carry an all-zero cache)."""
    BH, hd = q.shape
    L = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    bk = min(bk, L)
    assert L % bk == 0, (L, bk)
    nk = L // bk

    kern = functools.partial(_decode_kernel, scale=scale, nk=nk)
    out = pl.pallas_call(
        kern,
        grid=(BH, nk),
        in_specs=[
            pl.BlockSpec((None, 1, hd), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((None, bk, hd), lambda b, j: (b, j, 0)),
            pl.BlockSpec((None, bk, hd), lambda b, j: (b, j, 0)),
            pl.BlockSpec((None, bk), lambda b, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((None, 1, hd), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, 1, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q[:, None, :], k, v, valid.astype(jnp.int32))
    return out[:, 0]


def flash_attention_pallas(
    q: jnp.ndarray,  # (BH, S, hd)
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = -1,
    scale: Optional[float] = None,
    bq: int = 256,
    bk: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    BH, S, hd = q.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    bq, bk = min(bq, S), min(bk, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    nq, nk = S // bq, S // bk

    kern = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, nk=nk,
    )
    return pl.pallas_call(
        kern,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((None, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((None, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((None, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
