"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def fusion_proj_ref(x: jnp.ndarray, w: jnp.ndarray,
                    b: Optional[jnp.ndarray] = None,
                    act: str = "none") -> jnp.ndarray:
    """Fusion-layer projection: y = act(x @ w + b). x: (M, K), w: (K, N)."""
    y = jnp.dot(x, w, preferred_element_type=jnp.float32)
    if b is not None:
        y = y + b.astype(jnp.float32)
    if act == "relu":
        y = jax.nn.relu(y)
    elif act == "silu":
        y = jax.nn.silu(y)
    elif act != "none":
        raise ValueError(act)
    return y.astype(x.dtype)


def fusion_proj_quant_ref(x: jnp.ndarray, w: jnp.ndarray,
                          b: Optional[jnp.ndarray] = None,
                          act: str = "none"):
    """Projection + symmetric per-row absmax int8 quantization.

    -> (q int8 (M, N), scale fp32 (M, 1)); q * scale ~= act(x @ w + b).
    Composes fusion_proj_ref with the canonical int8_row wire scheme
    (codec.quantize_rows_sym) so oracle, codec and kernel can't drift."""
    from repro.core.codec import quantize_rows_sym

    y = jnp.dot(x, w, preferred_element_type=jnp.float32)
    if b is not None:
        y = y + b.astype(jnp.float32)
    if act == "relu":
        y = jax.nn.relu(y)
    elif act == "silu":
        y = jax.nn.silu(y)
    elif act != "none":
        raise ValueError(act)
    return quantize_rows_sym(y)


def fusion_proj_encode_ref(x: jnp.ndarray, w: jnp.ndarray,
                           b: Optional[jnp.ndarray] = None,
                           act: str = "none", *, codec, e=None):
    """Projection + any registered wire codec (+ EF21), unfused.

    The two-graph jnp path the fused epilogue kernels are benchmarked
    against: the fp32 activation is materialized, then encoded by the
    codec itself (the oracle), threading the EF residual when ``e`` is
    given. -> payload, or (payload, e')."""
    y = fusion_proj_ref(x, w, b, act).astype(jnp.float32)
    if e is not None:
        return codec.encode_with_state(y, e)
    return codec.encode(y)


def decode_proj_ref(payload, w: jnp.ndarray,
                    b: Optional[jnp.ndarray] = None, act: str = "none", *,
                    codec, shape):
    """Unfused consumer path: decode the wire payload, then project.

    act(codec.decode(payload) @ w + b) with the fp32 reconstruction
    materialized — what ``wire_fused.decode_proj_pallas`` folds into
    one launch. -> (*shape[:-1], N) fp32."""
    z_hat = codec.decode(payload, shape=shape, dtype=jnp.float32)
    return fusion_proj_ref(
        z_hat.reshape(-1, shape[-1]), w, b, act
    ).reshape(*shape[:-1], w.shape[-1])


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool = True, window: int = -1,
                        scale: Optional[float] = None) -> jnp.ndarray:
    """Plain softmax attention. q,k,v: (B, H, S, hd)."""
    B, H, S, hd = q.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    qi = jnp.arange(S)[:, None]
    ki = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= ki <= qi
    if window > 0:
        mask &= ki > qi - window
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)


NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def cached_attn_decode_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           valid: jnp.ndarray,
                           scale: Optional[float] = None) -> jnp.ndarray:
    """Single-token attention against a KV cache — the serving decode
    oracle, math-identical to the historical in-line form in
    ``repro.models.attention.attn_decode``.

    q: (B, 1, KVH, G, hd) grouped query; k, v: (B, L, KVH, hd) cache;
    valid: (B, L) bool — which cache rows are live for each batch row
    (slot_pos semantics: causal + ring-buffer window already folded in).
    Returns (B, 1, KVH, G, hd).
    """
    hd = q.shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, k).astype(jnp.float32) * scale
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v)


def rmsnorm_ref(x: jnp.ndarray, scale: jnp.ndarray,
                eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)
