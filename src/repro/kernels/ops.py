"""Jitted public wrappers around the Pallas kernels, plus the wire-path
block-size autotuner.

Each op dispatches: Pallas kernel on TPU (or when ``interpret=True`` for
CPU validation), pure-jnp oracle otherwise — so the same model code runs
everywhere and tests can assert kernel == oracle. Wrappers also handle
layout adaptation (padding to tile multiples, GQA head expansion,
flattening leading dims).

The autotuner (``autotune_wire_blocks``) does a power-of-two search
over (bm, bk) per (device kind, d_fusion, codec, kernel kind) and
persists the winners to an on-disk JSON cache
(``$REPRO_WIRE_BLOCKS_CACHE`` or ~/.cache/repro_kernels/
wire_blocks.json). ``wire_blocks`` is the cheap read side every fused
wrapper consults, falling back to the defaults when nothing was tuned —
tuning is an optimization, never a requirement.
"""

from __future__ import annotations

import functools
import json
import os
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref, wire_fused
from repro.kernels.flash_attention import (
    flash_attention_pallas,
    flash_decode_pallas,
)
from repro.kernels.fusion_proj import (
    fusion_proj_encode_pallas,
    fusion_proj_pallas,
    fusion_proj_quant_pallas,
)
from repro.kernels.rmsnorm import rmsnorm_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_rows(x, max_block: int):
    """Pad rows so they tile evenly; returns (padded, block, n_orig)."""
    m = x.shape[0]
    if m >= max_block:
        block = max_block
    else:
        block = -(-m // 8) * 8  # round up to sublane multiple
    r = m % block
    if r:
        x = jnp.pad(x, ((0, block - r), (0, 0)))
    return x, block, m


@functools.partial(jax.jit, static_argnames=("act", "use_kernel", "interpret"))
def fusion_proj(x, w, b=None, act: str = "none", *, use_kernel: bool = True,
                interpret: bool = False):
    """y = act(x @ w + b); x: (..., K), w: (K, N)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if use_kernel and (interpret or _on_tpu()):
        xp, bm, m = _pad_rows(x2, 256)
        y = fusion_proj_pallas(xp, w, b, act, bm=bm, interpret=interpret)
        y = y[:m]
    else:
        y = ref.fusion_proj_ref(x2, w, b, act)
    return y.reshape(*lead, w.shape[-1])


@functools.partial(jax.jit, static_argnames=("act", "use_kernel", "interpret"))
def fusion_proj_quant(x, w, b=None, act: str = "none", *,
                      use_kernel: bool = True, interpret: bool = False):
    """Fused projection + int8_row wire encode: the TPU path for
    producing compressed IFL payloads with no fp32 HBM round-trip.

    x: (..., K), w: (K, N) -> (q int8 (..., N), scale fp32 (..., 1)).
    """
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if use_kernel and (interpret or _on_tpu()):
        xp, bm, m = _pad_rows(x2, 256)
        q, s = fusion_proj_quant_pallas(xp, w, b, act, bm=bm,
                                        interpret=interpret)
        q, s = q[:m], s[:m]
    else:
        q, s = ref.fusion_proj_quant_ref(x2, w, b, act)
    return q.reshape(*lead, w.shape[-1]), s.reshape(*lead, 1)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "use_kernel", "interpret"),
)
def flash_attention(q, k, v, *, causal: bool = True, window: int = -1,
                    use_kernel: bool = True, interpret: bool = False):
    """q: (B, H, S, hd); k, v: (B, KVH, S, hd) with H % KVH == 0."""
    B, H, S, hd = q.shape
    kvh = k.shape[1]
    if kvh != H:  # GQA: expand kv heads to match
        g = H // kvh
        k = jnp.repeat(k, g, axis=1)
        v = jnp.repeat(v, g, axis=1)
    if use_kernel and (interpret or _on_tpu()):
        qf = q.reshape(B * H, S, hd)
        out = flash_attention_pallas(
            qf, k.reshape(B * H, S, hd), v.reshape(B * H, S, hd),
            causal=causal, window=window,
            bq=min(256, S), bk=min(256, S), interpret=interpret,
        )
        return out.reshape(B, H, S, hd)
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window)


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def cached_attn_decode(q, k, v, valid, *, use_kernel: bool = True,
                       interpret: bool = False):
    """Single-token attention against a KV cache — the serving decode
    path's dispatch point.

    q: (B, 1, KVH, G, hd) grouped query (G = H/KVH); k, v: (B, L, KVH,
    hd) cache; valid: (B, L) bool live-row mask (causality and the
    ring-buffer window pre-folded via slot_pos).  Pallas flash-decode
    kernel on TPU (or ``interpret=True`` for CPU validation) when the
    cache tiles align; pure-jnp oracle otherwise — which on CPU is
    bit-for-bit the historical ``attn_decode`` math, so the serving
    plane's bitwise parity contract holds on the fallback path.
    """
    B, _, kvh, g, hd = q.shape
    L = k.shape[1]
    bk = min(256, L)
    eligible = (
        use_kernel
        and (interpret or (_on_tpu() and hd in (64, 128, 256)))
        and L % bk == 0
    )
    if eligible:
        H = kvh * g
        qf = q.reshape(B, 1, H, hd).transpose(0, 2, 1, 3)  # (B,H,1,hd)
        kf = jnp.repeat(k, g, axis=2).transpose(0, 2, 1, 3)  # (B,H,L,hd)
        vf = jnp.repeat(v, g, axis=2).transpose(0, 2, 1, 3)
        validf = jnp.broadcast_to(valid[:, None], (B, H, L))
        out = flash_decode_pallas(
            qf.reshape(B * H, hd),
            kf.reshape(B * H, L, hd),
            vf.reshape(B * H, L, hd),
            validf.reshape(B * H, L),
            bk=bk, interpret=interpret,
        )
        return out.reshape(B, H, hd).reshape(B, kvh, g, hd)[:, None]
    return ref.cached_attn_decode_ref(q, k, v, valid)


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def rmsnorm(x, scale, *, use_kernel: bool = True, interpret: bool = False):
    """x: (..., D)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if use_kernel and (interpret or _on_tpu()):
        xp, br, m = _pad_rows(x2, 256)
        y = rmsnorm_pallas(xp, scale, block_rows=br, interpret=interpret)
        y = y[:m]
    else:
        y = ref.rmsnorm_ref(x2, scale)
    return y.reshape(*lead, x.shape[-1])


# ---------------------------------------------------------- wire path


@functools.partial(
    jax.jit, static_argnames=("codec", "use_kernel", "interpret")
)
def wire_encode(z, *, codec, use_kernel: bool = True,
                interpret: bool = False):
    """One-launch wire encode; jnp codec when unfused/unsupported.

    Payloads are bitwise-identical across the dispatch (the codec is
    the oracle), so callers never need to know which path ran.
    """
    if use_kernel and (interpret or _on_tpu()):
        blocks = wire_blocks(codec.name, z.shape[-1])
        payload = codec.fused_encode(
            z, block_rows=blocks.get("bm"), interpret=interpret
        )
        if payload is not None:
            return payload
    return codec.encode(z)


@functools.partial(
    jax.jit,
    static_argnames=("act", "codec", "shape", "use_kernel", "interpret"),
)
def decode_proj(payload, w, b=None, act: str = "none", *, codec, shape,
                use_kernel: bool = True, interpret: bool = False):
    """Decode-as-prologue: act(codec.decode(payload) @ w + b).

    The modular-block consumer's first matmul, with the broadcast
    payload dequantized in-register — the fp32 (rows, d_fusion)
    reconstruction never touches HBM. ``shape`` is the original z
    shape; returns (*shape[:-1], N) fp32.
    """
    d = shape[-1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    fusable = (use_kernel and (interpret or _on_tpu())
               and wire_fused.scheme_for(codec, d) is not None
               and wire_fused.scheme_for(codec, d).d == d
               and w.shape[-1] % min(256, w.shape[-1]) == 0)
    if fusable:
        flat = {k: v.reshape(rows, -1) for k, v in payload.items()}
        blocks = wire_blocks(codec.name, d, kind="decode_proj")
        y = wire_fused.decode_proj_pallas(
            flat, w, b, act, codec=codec, rows=rows, d=d,
            block_rows=blocks.get("bm"),
            bn=min(blocks.get("bn", 256), w.shape[-1]),
            interpret=interpret,
        )
    else:
        y = ref.decode_proj_ref(payload, w, b, act, codec=codec,
                                shape=shape)
        y = y.reshape(rows, -1)
    return y.reshape(*shape[:-1], w.shape[-1])


@functools.partial(
    jax.jit,
    static_argnames=("act", "codec", "use_kernel", "interpret"),
)
def fusion_proj_encode(x, w, b=None, act: str = "none", *, codec,
                       ef_state=None, use_kernel: bool = True,
                       interpret: bool = False):
    """Projection + wire encode (+ EF21) as ONE kernel launch.

    x: (..., K), w: (K, d_fusion) -> (payload, e') with ``ef_state``
    (an EF codec's carried residual, shaped like the output), or just
    the payload when ``ef_state`` is None. The fp32 activation tile
    never reaches HBM — only the wire payload (and the residual) do.
    Falls back to oracle projection + jnp encode when no fused scheme
    exists for the codec at d_fusion.
    """
    from repro.core.codec import EFCodec

    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    N = w.shape[-1]
    inner = codec.inner if isinstance(codec, EFCodec) else codec
    scheme = wire_fused.scheme_for(inner, N)
    ef = ef_state is not None
    e2 = ef_state.reshape(-1, N) if ef else None
    if (use_kernel and (interpret or _on_tpu()) and scheme is not None
            and scheme.d == N):
        blocks = wire_blocks(codec.name, N, kind="proj_encode")
        xp, bm, m = _pad_rows(x2, blocks.get("bm", 256))
        ep = None
        if ef:
            ep = jnp.pad(e2, ((0, xp.shape[0] - m), (0, 0)))
        outs = fusion_proj_encode_pallas(
            xp, w, b, act, scheme=scheme, e=ep,
            max_ratio=getattr(codec, "max_ratio", None),
            bm=bm, bk=blocks.get("bk", 512), interpret=interpret,
        )
        outs = [o[:m] for o in outs]
        payload = {
            name: o.reshape(*lead, *tail)
            for o, (name, (tail, _)) in zip(outs, scheme.leaves.items())
        }
        if ef:
            return payload, outs[len(scheme.leaves)].reshape(*lead, N)
        return payload
    y = ref.fusion_proj_ref(x2, w, b, act).astype(jnp.float32)
    if ef:
        payload, e_new = codec.encode_with_state(y, e2)
        payload = {k: v.reshape(*lead, *v.shape[1:])
                   for k, v in payload.items()}
        return payload, e_new.reshape(*lead, N)
    payload = codec.encode(y)
    return {k: v.reshape(*lead, *v.shape[1:]) for k, v in payload.items()}


# ------------------------------------------------------------ autotuner


_WIRE_BLOCK_DEFAULTS = {
    "encode": {"bm": 256},
    "proj_encode": {"bm": 256, "bk": 512},
    "decode_proj": {"bm": 256, "bn": 256},
}
_wire_cache_mem: Optional[dict] = None


def _wire_cache_path() -> str:
    return os.environ.get(
        "REPRO_WIRE_BLOCKS_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro_kernels",
                     "wire_blocks.json"),
    )


def _load_wire_cache(refresh: bool = False) -> dict:
    global _wire_cache_mem
    if _wire_cache_mem is None or refresh:
        try:
            with open(_wire_cache_path()) as f:
                _wire_cache_mem = json.load(f)
        except (OSError, ValueError):
            _wire_cache_mem = {}
    return _wire_cache_mem


def _wire_key(codec_name: str, d: int, kind: str) -> str:
    dev = jax.devices()[0].device_kind.replace(" ", "_")
    return f"{dev}|{kind}|{codec_name}|d{d}"


def wire_blocks(codec_name: str, d: int, kind: str = "encode") -> dict:
    """Block sizes for a fused wire kernel: tuned if cached, defaults
    otherwise. Pure read side — never times anything."""
    entry = _load_wire_cache().get(_wire_key(codec_name, d, kind))
    if entry:
        return {k: v for k, v in entry.items() if k in ("bm", "bn", "bk")}
    return dict(_WIRE_BLOCK_DEFAULTS[kind])


def autotune_wire_blocks(codec, d: int, *, kind: str = "encode",
                         rows: int = 512, reps: int = 3,
                         candidates=None, interpret: Optional[bool] = None,
                         force: bool = False) -> dict:
    """Power-of-two block search for one (codec, d_fusion, kernel kind).

    Times each candidate on synthetic data (best of ``reps``) and
    persists the winner keyed by (device kind, kind, codec, d) so later
    runs — and other processes — get it from ``wire_blocks`` for free.
    Returns the winning entry (also on cache hit, unless ``force``).
    """
    from repro.core.codec import get_codec

    codec = get_codec(codec)
    key = _wire_key(codec.name, d, kind)
    cache = _load_wire_cache(refresh=True)
    if key in cache and not force:
        return cache[key]
    if interpret is None:
        interpret = not _on_tpu()
    if candidates is None:
        bms, cap = [], min(1024, max(8, rows))
        b = 8
        while b <= cap:
            bms.append(b)
            b *= 2
        candidates = [{"bm": bm} for bm in bms]
        if kind == "proj_encode":
            candidates = [{"bm": bm, "bk": bk}
                          for bm in bms for bk in (128, 256, 512)]

    z = jax.random.normal(jax.random.PRNGKey(0), (rows, d), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (rows, 128), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(2), (128, d),
                          jnp.float32) * 0.05
    best = None
    for cand in candidates:
        try:
            if kind == "encode":
                fn = jax.jit(functools.partial(
                    wire_fused.wire_encode, codec=codec,
                    block_rows=cand["bm"], interpret=interpret))
                args = (z,)
            elif kind == "proj_encode":
                scheme = wire_fused.scheme_for(
                    getattr(codec, "inner", codec), d)
                if scheme is None or scheme.d != d:
                    break
                fn = jax.jit(functools.partial(
                    fusion_proj_encode_pallas, act="none", scheme=scheme,
                    bm=cand["bm"], bk=cand["bk"], interpret=interpret))
                args = (x, w)
            else:  # decode_proj
                scheme = wire_fused.scheme_for(codec, d)
                if scheme is None or scheme.d != d:
                    break
                payload = codec.encode(z)
                wd = jax.random.normal(jax.random.PRNGKey(3), (d, 256),
                                       jnp.float32) * 0.05
                fn = jax.jit(functools.partial(
                    wire_fused.decode_proj_pallas, act="none", codec=codec,
                    rows=rows, d=d, block_rows=cand["bm"],
                    interpret=interpret))
                args = (payload, wd)
            jax.block_until_ready(fn(*args))  # compile outside the clock
            t = min(
                _timeit(fn, args) for _ in range(reps)
            )
        except Exception:
            continue
        if best is None or t < best["us"]:
            best = dict(cand, us=round(t * 1e6, 2))
    if best is None:
        return dict(_WIRE_BLOCK_DEFAULTS[kind], us=None)
    cache[key] = best
    path = _wire_cache_path()
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(cache, f, indent=1, sort_keys=True)
    return best


def _timeit(fn, args) -> float:
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    return time.perf_counter() - t0


# ------------------------------------------------- serve-plan autotuner
#
# Wall-clock tuner for the serving hot loop (ISSUE 10): picks the fused
# decode horizon S and the prompt-length bucket edges of batch admission
# per (device kind, arch pairs, lane width, cache_len), persisted to a
# JSON cache exactly like the wire-block tuner above.  The read side
# (`serve_plan`) never times anything — `ServeEngine(horizon="auto")`
# consults it and falls back to defaults when untuned.

_serve_cache_mem: Optional[dict] = None


def _serve_cache_path() -> str:
    return os.environ.get(
        "REPRO_SERVE_PLAN_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro_kernels",
                     "serve_plan.json"),
    )


def _load_serve_cache(refresh: bool = False) -> dict:
    global _serve_cache_mem
    if _serve_cache_mem is None or refresh:
        try:
            with open(_serve_cache_path()) as f:
                _serve_cache_mem = json.load(f)
        except (OSError, ValueError):
            _serve_cache_mem = {}
    return _serve_cache_mem


def _serve_key(plan_key: str) -> str:
    dev = jax.devices()[0].device_kind.replace(" ", "_")
    return f"{dev}|serve|{plan_key}"


def serve_plan(plan_key: str) -> dict:
    """The tuned (horizon, bucket_edges) for one engine geometry, or
    ``{}`` when untuned.  Pure read side — never times anything."""
    return dict(_load_serve_cache().get(_serve_key(plan_key), {}))


def autotune_serve_plan(plan_key: str, timer, *,
                        horizons=(1, 2, 4, 8, 16),
                        edge_sets=((8, 16, 32, 64, 128),),
                        force: bool = False) -> dict:
    """Grid search over (horizon, bucket edges) with a caller-supplied
    ``timer(horizon, edges) -> seconds`` (the engine times a warm
    fresh-clone run of a representative workload).  Persists the winner
    keyed by (device kind, plan_key) so later runs — and other
    processes — get it from ``serve_plan`` for free.  Returns the
    winning entry (also on cache hit, unless ``force``)."""
    key = _serve_key(plan_key)
    cache = _load_serve_cache(refresh=True)
    if key in cache and not force:
        return cache[key]
    best = None
    for edges in edge_sets:
        for h in horizons:
            try:
                t = timer(int(h), [int(e) for e in edges])
            except Exception:
                continue
            if best is None or t < best["seconds"]:
                best = {"horizon": int(h),
                        "bucket_edges": [int(e) for e in edges],
                        "seconds": round(float(t), 6)}
    if best is None:
        return {}
    cache[key] = best
    path = _serve_cache_path()
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(cache, f, indent=1, sort_keys=True)
    return best


def fused_wire_report(codec, z_shape, *, fused: bool = True) -> dict:
    """Which wire path a spec lowers, for the dryrun client_boundary.

    ``fused=False`` (or no scheme) reports the jnp oracle path; either
    way the payload bytes and decoded values are identical, so this is
    pure lowering metadata.
    """
    from repro.core.codec import get_codec

    codec = get_codec(codec)
    spec = codec.fused_spec(tuple(z_shape)) if fused else None
    if spec is None:
        return {
            "fused": False,
            "path": "jnp",
            "kernel": None,
            "fallback": (None if fused else "--no-fused")
            or f"no fused scheme for codec {codec.name!r} at "
               f"d={z_shape[-1]}",
        }
    traffic = wire_fused.encode_hbm_bytes(codec, tuple(z_shape)) or {}
    return {
        "fused": True,
        "path": "pallas",
        "kernel": spec["kernel"],
        "scheme": spec["scheme"],
        "block_rows": spec["block_rows"],
        "grid": list(spec["grid"]),
        "payload_leaves": spec["leaves"],
        "hbm_bytes_fused": traffic.get("fused_bytes"),
        "hbm_bytes_unfused": traffic.get("unfused_bytes"),
        "proj_epilogue_blocks": wire_blocks(
            codec.name, z_shape[-1], kind="proj_encode"),
        "fallback": None,
    }
