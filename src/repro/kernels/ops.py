"""Jitted public wrappers around the Pallas kernels.

Each op dispatches: Pallas kernel on TPU (or when ``interpret=True`` for
CPU validation), pure-jnp oracle otherwise — so the same model code runs
everywhere and tests can assert kernel == oracle. Wrappers also handle
layout adaptation (padding to tile multiples, GQA head expansion,
flattening leading dims).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.fusion_proj import (
    fusion_proj_pallas,
    fusion_proj_quant_pallas,
)
from repro.kernels.rmsnorm import rmsnorm_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_rows(x, max_block: int):
    """Pad rows so they tile evenly; returns (padded, block, n_orig)."""
    m = x.shape[0]
    if m >= max_block:
        block = max_block
    else:
        block = -(-m // 8) * 8  # round up to sublane multiple
    r = m % block
    if r:
        x = jnp.pad(x, ((0, block - r), (0, 0)))
    return x, block, m


@functools.partial(jax.jit, static_argnames=("act", "use_kernel", "interpret"))
def fusion_proj(x, w, b=None, act: str = "none", *, use_kernel: bool = True,
                interpret: bool = False):
    """y = act(x @ w + b); x: (..., K), w: (K, N)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if use_kernel and (interpret or _on_tpu()):
        xp, bm, m = _pad_rows(x2, 256)
        y = fusion_proj_pallas(xp, w, b, act, bm=bm, interpret=interpret)
        y = y[:m]
    else:
        y = ref.fusion_proj_ref(x2, w, b, act)
    return y.reshape(*lead, w.shape[-1])


@functools.partial(jax.jit, static_argnames=("act", "use_kernel", "interpret"))
def fusion_proj_quant(x, w, b=None, act: str = "none", *,
                      use_kernel: bool = True, interpret: bool = False):
    """Fused projection + int8_row wire encode: the TPU path for
    producing compressed IFL payloads with no fp32 HBM round-trip.

    x: (..., K), w: (K, N) -> (q int8 (..., N), scale fp32 (..., 1)).
    """
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if use_kernel and (interpret or _on_tpu()):
        xp, bm, m = _pad_rows(x2, 256)
        q, s = fusion_proj_quant_pallas(xp, w, b, act, bm=bm,
                                        interpret=interpret)
        q, s = q[:m], s[:m]
    else:
        q, s = ref.fusion_proj_quant_ref(x2, w, b, act)
    return q.reshape(*lead, w.shape[-1]), s.reshape(*lead, 1)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "use_kernel", "interpret"),
)
def flash_attention(q, k, v, *, causal: bool = True, window: int = -1,
                    use_kernel: bool = True, interpret: bool = False):
    """q: (B, H, S, hd); k, v: (B, KVH, S, hd) with H % KVH == 0."""
    B, H, S, hd = q.shape
    kvh = k.shape[1]
    if kvh != H:  # GQA: expand kv heads to match
        g = H // kvh
        k = jnp.repeat(k, g, axis=1)
        v = jnp.repeat(v, g, axis=1)
    if use_kernel and (interpret or _on_tpu()):
        qf = q.reshape(B * H, S, hd)
        out = flash_attention_pallas(
            qf, k.reshape(B * H, S, hd), v.reshape(B * H, S, hd),
            causal=causal, window=window,
            bq=min(256, S), bk=min(256, S), interpret=interpret,
        )
        return out.reshape(B, H, S, hd)
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window)


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def rmsnorm(x, scale, *, use_kernel: bool = True, interpret: bool = False):
    """x: (..., D)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if use_kernel and (interpret or _on_tpu()):
        xp, br, m = _pad_rows(x2, 256)
        y = rmsnorm_pallas(xp, scale, block_rows=br, interpret=interpret)
        y = y[:m]
    else:
        y = ref.rmsnorm_ref(x2, scale)
    return y.reshape(*lead, x.shape[-1])
