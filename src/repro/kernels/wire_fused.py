"""Fused wire-path kernels: every codec's encode as ONE Pallas launch.

This is the encode side of the exchange plane folded into kernels. The
jnp codecs in ``repro.core.codec`` stay the oracles (and the ground
truth for ``encoded_nbytes``/ledger parity); the kernels here produce
bitwise-identical payloads without round-tripping the fp32 (rows,
d_fusion) fusion signal through HBM between the pointwise stages:

  wire_encode      z -> payload           (int8_row / int4 nibble-pack /
                                           top-k select / count-sketch
                                           scatter, in-register)
  wire_encode_ef   (z, e) -> (payload, e')  the EF21 epilogue: c = z+e,
                                           inner encode, in-register
                                           decode, trust-region-clipped
                                           residual as a second output
  decode_proj      payload @ w             decode-as-prologue for the
                                           modular-block consumer: the
                                           broadcast payload is
                                           dequantized inside the first
                                           matmul that reads it

Each codec is described by a ``_WireScheme``: the payload leaf layout
per row-block plus ``encode_block`` (which also returns the in-register
reconstruction ``z_hat`` so the EF epilogue never re-reads the payload)
and ``decode_block``. Scheme bodies are built from the SAME shared
helpers the jnp codecs use (``quantize_rows_sym``,
``ef_residual_update``, ``_sketch_tables``) and the same lax ops
(``top_k``, scatter), so in interpret mode the fused path is bitwise
equal to the oracle — a test gate, not a tolerance.

Fallback rule: anything without a scheme (fp32/bf16/fp16/int8 affine)
or outside the supported shape envelope returns None from
``encode_spec``/``wire_encode`` and the caller uses the jnp path.
Unsupported is never an error.

Block sizes come from the caller (``ops.wire_blocks`` consults the
on-disk autotuner cache); row counts that don't tile are zero-padded
and sliced, which is exact for every scheme (padded rows never leak:
their payload rows are dropped, and appending zero rows changes no
per-row reduction).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import codec as codec_mod
from repro.core.codec import ef_residual_update, quantize_rows_sym

__all__ = [
    "MAX_FUSED_D",
    "decode_proj_pallas",
    "encode_hbm_bytes",
    "encode_spec",
    "proj_encode_hbm_bytes",
    "resolve_fused",
    "scheme_for",
    "wire_encode",
    "wire_encode_ef",
]

# Full d_fusion stays in-block (row reductions need whole rows); a
# (256, 8192) fp32 block is 8 MB of VMEM — past that, fall back to jnp.
MAX_FUSED_D = 8192


def resolve_fused(fused: Optional[bool]) -> Tuple[bool, bool]:
    """Resolve a plane's ``fused`` knob -> (enabled, interpret).

    None = auto: fused on TPU (compiled), jnp elsewhere. True forces
    the fused path everywhere — off-TPU it runs in pallas interpret
    mode, which is the bitwise-parity test configuration, not a fast
    path. False always takes the jnp oracle.
    """
    on_tpu = jax.default_backend() == "tpu"
    if fused is None:
        return on_tpu, False
    return bool(fused), bool(fused) and not on_tpu


# --------------------------------------------------------------- schemes


class _WireScheme:
    """One codec's in-kernel wire representation.

    ``d`` is the (possibly pad-adjusted) last-dim the kernel sees;
    ``leaves`` maps payload leaf name -> (per-row tail shape, dtype) in
    the codec's own payload dict layout.
    """

    name: str = ""

    def __init__(self, d: int):
        self.d = d

    @property
    def leaves(self):
        raise NotImplementedError

    @property
    def leaf_names(self) -> Tuple[str, ...]:
        return tuple(self.leaves)

    @property
    def consts(self):
        """Trace-time constant tables the kernel needs (name -> np
        array). Pallas kernels may not close over array constants, so
        these ride in as extra (whole-array) inputs to every block."""
        return {}

    def encode_block(self, c: jnp.ndarray, consts=None):
        """(bm, d) fp32 -> (payload dict, z_hat (bm, d) fp32)."""
        raise NotImplementedError

    def decode_block(self, payload, consts=None) -> jnp.ndarray:
        """Payload blocks -> (bm, d) fp32 reconstruction (= codec.decode)."""
        raise NotImplementedError

    def payload_bytes(self, rows: int) -> int:
        return sum(
            rows * int(np.prod(tail)) * jnp.dtype(dt).itemsize
            for tail, dt in self.leaves.values()
        )


class _Int8RowScheme(_WireScheme):
    name = "int8_row"

    @property
    def leaves(self):
        return {"q": ((self.d,), jnp.int8), "scale": ((1,), jnp.float32)}

    def encode_block(self, c, consts=None):
        q, scale = quantize_rows_sym(c)
        return {"q": q, "scale": scale}, q.astype(jnp.float32) * scale

    def decode_block(self, payload, consts=None):
        return payload["q"].astype(jnp.float32) * payload["scale"]


class _Int4RowScheme(_WireScheme):
    """Nibble-pack in-register: two int4 values per stored byte.

    The kernel always sees an even ``d`` (an odd d_fusion is padded
    with one zero column by the wrapper — the same zero nibble the jnp
    codec pads with, and a zero column changes no row absmax), so the
    packed width is exactly the codec's ceil(d/2) bytes per row.
    """

    name = "int4"

    @property
    def leaves(self):
        return {"q4": ((self.d // 2,), jnp.uint8),
                "scale": ((1,), jnp.float32)}

    def encode_block(self, c, consts=None):
        q, scale = quantize_rows_sym(c, qmax=7)
        u = (q + 8).astype(jnp.uint8)  # [-7,7] -> [1,15]; pad col -> 8
        u2 = u.reshape(u.shape[0], -1, 2)
        packed = u2[..., 0] | (u2[..., 1] << 4)
        # q is exactly what unpacking recovers, so q*scale IS the
        # codec's decode — no unpack round-trip needed for z_hat.
        return ({"q4": packed, "scale": scale},
                q.astype(jnp.float32) * scale)

    def decode_block(self, payload, consts=None):
        packed, scale = payload["q4"], payload["scale"]
        lo = (packed & jnp.uint8(0xF)).astype(jnp.int32) - 8
        hi = (packed >> 4).astype(jnp.int32) - 8
        q = jnp.stack([lo, hi], axis=-1).reshape(
            packed.shape[0], packed.shape[-1] * 2
        )
        return q.astype(jnp.float32) * scale


class _TopKScheme(_WireScheme):
    """Per-row magnitude top-k select: values + int32 index sidecar.

    Uses the same ``lax.top_k`` as the codec (stable lowest-index
    tie-break), so the index sidecar matches the oracle bitwise.
    """

    name = "topk"

    def __init__(self, d: int, k: int):
        super().__init__(d)
        self.k = k

    @property
    def leaves(self):
        return {"values": ((self.k,), jnp.float32),
                "indices": ((self.k,), jnp.int32)}

    def encode_block(self, c, consts=None):
        _, idx = jax.lax.top_k(jnp.abs(c), self.k)
        vals = jnp.take_along_axis(c, idx, axis=-1)
        payload = {"values": vals, "indices": idx.astype(jnp.int32)}
        return payload, self.decode_block(payload)

    def decode_block(self, payload, consts=None):
        vals, idx = payload["values"], payload["indices"]
        rows = vals.shape[0]
        flat = jnp.zeros((rows, self.d), jnp.float32)
        r = jnp.arange(rows)[:, None]
        return flat.at[r, idx].set(vals)


class _SketchScheme(_WireScheme):
    """Count-sketch scatter-add into w signed buckets, in-register.

    The hash/sign/inverse-count tables are the codec's own
    ``_sketch_tables`` numpy arrays, passed to the kernel as extra
    inputs (pallas kernels may not close over array constants) —
    encoder, decoder, and kernel share one seed and zero wire sidecar.
    """

    name = "sketch"

    def __init__(self, d: int, w: int, seed: int):
        super().__init__(d)
        self.w = w
        self.h, self.s, self.inv_counts = codec_mod._sketch_tables(
            d, w, seed
        )

    @property
    def leaves(self):
        return {"sketch": ((self.w,), jnp.float32)}

    @property
    def consts(self):
        return {"h": self.h, "s": self.s, "inv_counts": self.inv_counts}

    def encode_block(self, c, consts=None):
        h, s = consts["h"], consts["s"]
        flat = c * s
        sk = jnp.zeros((c.shape[0], self.w), jnp.float32)
        sk = sk.at[:, h].add(flat)
        payload = {"sketch": sk}
        return payload, self.decode_block(payload, consts)

    def decode_block(self, payload, consts=None):
        h, s = consts["h"], consts["s"]
        vals = payload["sketch"] * consts["inv_counts"]  # bucket means
        return vals[..., h] * s


def scheme_for(codec, d: int) -> Optional[_WireScheme]:
    """The wire scheme for ``codec`` at last-dim ``d``, or None.

    EF is not a scheme — it is an epilogue around its inner scheme
    (``wire_encode_ef``); its stateless encode delegates to the inner
    codec upstream (``EFCodec.fused_encode``).
    """
    if d < 1 or d > MAX_FUSED_D:
        return None
    if isinstance(codec, codec_mod.Int8RowCodec):
        return _Int8RowScheme(d)
    if isinstance(codec, codec_mod.Int4RowCodec):
        return _Int4RowScheme(d + d % 2)
    if isinstance(codec, codec_mod.TopKCodec):
        return _TopKScheme(d, codec.k_of(d))
    if isinstance(codec, codec_mod.CountSketchCodec):
        return _SketchScheme(d, codec.w_of(d), codec.seed)
    return None


# ---------------------------------------------------------- encode kernel


def _encode_kernel(z_ref, *refs, scheme: _WireScheme, ef: bool,
                   max_ratio: Optional[float]):
    i = 0
    zf = z_ref[...].astype(jnp.float32)
    if ef:
        c = zf + refs[i][...]
        i += 1
    else:
        c = zf
    const_names = tuple(scheme.consts)
    consts = {name: refs[i + j][...] for j, name in enumerate(const_names)}
    outs = refs[i + len(const_names):]
    payload, z_hat = scheme.encode_block(c, consts)
    for ref, name in zip(outs, scheme.leaf_names):
        ref[...] = payload[name]
    if ef:
        outs[len(scheme.leaf_names)][...] = ef_residual_update(
            zf, c, z_hat, max_ratio
        )


def _round_rows(rows: int, block_rows: Optional[int]) -> int:
    if block_rows:
        return max(8, min(int(block_rows), 1024))
    if rows >= 256:
        return 256
    return -(-rows // 8) * 8  # round up to the sublane multiple


def _encode_call(z2, scheme: _WireScheme, *, e2=None,
                 max_ratio: Optional[float] = None,
                 block_rows: Optional[int] = None, interpret: bool = False):
    """Run the single-launch encode on a 2-D (rows, d) view."""
    rows = z2.shape[0]
    bm = _round_rows(rows, block_rows)
    pad = -rows % bm
    if pad:
        z2 = jnp.pad(z2, ((0, pad), (0, 0)))
        if e2 is not None:
            e2 = jnp.pad(e2, ((0, pad), (0, 0)))
    m = z2.shape[0]
    d = z2.shape[1]
    ef = e2 is not None

    row_spec = pl.BlockSpec((bm, d), lambda i: (i, 0))
    in_specs = [row_spec]
    args = [z2]
    if ef:
        in_specs.append(row_spec)
        args.append(e2)
    for tbl in scheme.consts.values():
        arr = jnp.asarray(tbl)
        in_specs.append(
            pl.BlockSpec(arr.shape, lambda i, _n=arr.ndim: (0,) * _n)
        )
        args.append(arr)
    out_specs = [
        pl.BlockSpec((bm, *tail), lambda i, _n=len(tail): (i,) + (0,) * _n)
        for tail, _ in scheme.leaves.values()
    ]
    out_shape = [
        jax.ShapeDtypeStruct((m, *tail), dt)
        for tail, dt in scheme.leaves.values()
    ]
    if ef:
        out_specs.append(row_spec)
        out_shape.append(jax.ShapeDtypeStruct((m, d), jnp.float32))

    outs = pl.pallas_call(
        functools.partial(_encode_kernel, scheme=scheme, ef=ef,
                          max_ratio=max_ratio),
        grid=(m // bm,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*args)
    return [o[:rows] for o in outs]


def _prep_rows(z, codec):
    """Flatten leading dims; int4 pads an odd last dim with a zero col
    (the codec's own pad-nibble convention, scale-neutral)."""
    d = z.shape[-1]
    z2 = z.reshape(-1, d)
    scheme = scheme_for(codec, d)
    if scheme is not None and scheme.d != d:
        z2 = jnp.pad(z2, ((0, 0), (0, scheme.d - d)))
    return z2, scheme, d


def wire_encode(z, codec, *, block_rows: Optional[int] = None,
                interpret: bool = False):
    """Encode z in one kernel launch -> codec payload dict, or None.

    Bitwise-identical to ``codec.encode(z)`` (leaf names, shapes,
    dtypes, values); None when the codec/shape has no fused scheme.
    """
    z2, scheme, _ = _prep_rows(z, codec)
    if scheme is None:
        return None
    outs = _encode_call(z2, scheme, block_rows=block_rows,
                        interpret=interpret)
    lead = z.shape[:-1]
    return {
        name: o.reshape(*lead, *tail)
        for o, (name, (tail, _)) in zip(outs, scheme.leaves.items())
    }


def wire_encode_ef(z, state, ef_codec, *,
                   block_rows: Optional[int] = None,
                   interpret: bool = False):
    """The fused EF21 epilogue -> (payload, e'), or None.

    One launch computes c = z + e, the inner encode, the in-register
    decode, and the trust-region-clipped residual — bitwise equal to
    ``EFCodec.encode_with_state`` (both build on ``quantize_rows_sym``
    and ``ef_residual_update``).
    """
    z2, scheme, d = _prep_rows(z, ef_codec.inner)
    if scheme is None:
        return None
    e2 = state.astype(jnp.float32).reshape(-1, d)
    if scheme.d != d:
        e2 = jnp.pad(e2, ((0, 0), (0, scheme.d - d)))
    outs = _encode_call(z2, scheme, e2=e2, max_ratio=ef_codec.max_ratio,
                        block_rows=block_rows, interpret=interpret)
    lead = z.shape[:-1]
    payload = {
        name: o.reshape(*lead, *tail)
        for o, (name, (tail, _)) in zip(outs, scheme.leaves.items())
    }
    e_new = outs[len(scheme.leaves)][..., :d].reshape(z.shape)
    return payload, e_new


# ------------------------------------------------------ decode-as-prologue


def _decode_proj_kernel(*refs, scheme: _WireScheme, act: str,
                        has_bias: bool, n_leaves: int):
    payload = {
        name: refs[i][...] for i, name in enumerate(scheme.leaf_names)
    }
    consts = {
        name: refs[n_leaves + j][...]
        for j, name in enumerate(scheme.consts)
    }
    i = n_leaves + len(consts)
    w_ref = refs[i]
    b_ref = refs[i + 1] if has_bias else None
    o_ref = refs[-1]
    z_hat = scheme.decode_block(payload, consts)
    y = jnp.dot(z_hat, w_ref[...], preferred_element_type=jnp.float32)
    if has_bias:
        y = y + b_ref[...].astype(jnp.float32)
    if act == "relu":
        y = jnp.maximum(y, 0.0)
    elif act == "silu":
        y = y * jax.nn.sigmoid(y)
    elif act != "none":
        raise ValueError(act)
    o_ref[...] = y.astype(o_ref.dtype)


def decode_proj_pallas(payload, w, b=None, act: str = "none", *, codec,
                       rows: int, d: int,
                       block_rows: Optional[int] = None, bn: int = 256,
                       interpret: bool = False):
    """Decode-as-prologue: act(decode(payload) @ w + b) in one launch.

    The broadcast payload is dequantized/scattered in-register inside
    the first modular-block matmul that consumes it, so the fp32
    (rows, d_fusion) reconstruction never exists in HBM. ``payload``
    leaves must be 2-D (rows, tail) views; returns (rows, N) fp32.
    Caller guarantees a scheme exists (via ``encode_spec``).
    """
    scheme = scheme_for(codec, d)
    assert scheme is not None and scheme.d == d, (codec, d)
    N = w.shape[-1]
    bm = _round_rows(rows, block_rows)
    bn = min(bn, N)
    assert N % bn == 0, (N, bn)
    pad = -rows % bm
    leaves = [payload[name] for name in scheme.leaf_names]
    if pad:
        leaves = [jnp.pad(v, ((0, pad), (0, 0))) for v in leaves]
    m = rows + pad

    in_specs = [
        pl.BlockSpec((bm, *tail), lambda i, j: (i, 0))
        for tail, _ in scheme.leaves.values()
    ]
    args = list(leaves)
    for tbl in scheme.consts.values():
        arr = jnp.asarray(tbl)
        in_specs.append(
            pl.BlockSpec(arr.shape, lambda i, j, _n=arr.ndim: (0,) * _n)
        )
        args.append(arr)
    in_specs.append(pl.BlockSpec((d, bn), lambda i, j: (0, j)))
    args.append(w)
    has_bias = b is not None
    if has_bias:
        in_specs.append(pl.BlockSpec((bn,), lambda i, j: (j,)))
        args.append(b)

    out = pl.pallas_call(
        functools.partial(_decode_proj_kernel, scheme=scheme, act=act,
                          has_bias=has_bias, n_leaves=len(scheme.leaves)),
        grid=(m // bm, N // bn),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, N), jnp.float32),
        interpret=interpret,
    )(*args)
    return out[:rows]


# ------------------------------------------------------ HBM accounting


def encode_hbm_bytes(codec, shape, *, ef: Optional[bool] = None) -> Optional[dict]:
    """Exact HBM traffic of the fused encode vs the unfused jnp path.

    The kernel's traffic is its DMA schedule, read off the BlockSpecs
    (each input block enters VMEM once per grid visit, each output
    leaves once): z in + payload out (+ residual in/out for EF). The
    unfused path materializes every pointwise stage: z is read, the
    fp32 intermediate (c, or the dequantized z_hat for EF) round-trips
    HBM between graphs, and the payload is written. Returns None when
    no fused scheme exists.
    """
    inner = codec.inner if isinstance(codec, codec_mod.EFCodec) else codec
    if ef is None:
        ef = isinstance(codec, codec_mod.EFCodec) and codec.has_state
    d = shape[-1]
    scheme = scheme_for(inner, d)
    if scheme is None:
        return None
    rows = int(np.prod(shape[:-1])) if len(shape) > 1 else 1
    z_bytes = rows * d * 4
    payload = scheme.payload_bytes(rows)
    fused = z_bytes + payload + (ef * 2 * z_bytes)
    # Unfused: encode reads z and writes payload, plus for EF the
    # residual read/write, the c = z+e intermediate, and the decode's
    # z_hat reconstruction — each a full fp32 HBM round-trip between
    # the separate jnp stages.
    unfused = z_bytes + payload + (ef * 2 * z_bytes) + (ef * 4 * z_bytes)
    return {
        "kernel": f"wire_encode[{codec.name}]",
        "fused_bytes": int(fused),
        "unfused_bytes": int(unfused),
        "payload_bytes": int(payload),
    }


def proj_encode_hbm_bytes(codec, m: int, k: int, n: int, *,
                          bm: int = 256,
                          ef: Optional[bool] = None) -> Optional[dict]:
    """Analytic DMA bytes of the fused projection+encode epilogue.

    Read off the kernel's BlockSpecs over the (M/bm, K/bk) grid: x
    blocks enter VMEM once each (M*K), the full w once per row-block
    (revisited blocks stay resident across the inner K loop), the
    payload (+ EF residual in/out) moves once per row-block. The fp32
    (M, N) activation never touches HBM — that round-trip is the
    unfused oracle's extra traffic. Returns None when no fused scheme
    exists.
    """
    inner = codec.inner if isinstance(codec, codec_mod.EFCodec) else codec
    if ef is None:
        ef = isinstance(codec, codec_mod.EFCodec) and codec.has_state
    scheme = scheme_for(inner, n)
    if scheme is None:
        return None
    bm = min(bm, m)
    row_blocks = -(-m // bm)
    payload = scheme.payload_bytes(m)
    act_bytes = m * n * 4
    fused = (m * k * 4 + row_blocks * k * n * 4 + payload
             + (ef * 2 * act_bytes))
    return {
        "kernel": f"fusion_proj_encode[{codec.name}]",
        "fused_bytes": int(fused),
        "payload_bytes": int(payload),
    }


def encode_spec(codec, shape) -> Optional[dict]:
    """Static description of the fused encode lowering for ``shape``.

    The host-level decision the exchange planes and the dryrun
    ``client_boundary`` report key off: kernel name, payload leaves,
    resolved block rows (autotuner cache via ``ops.wire_blocks``), and
    the exact DMA bytes. None => the jnp path lowers.
    """
    d = shape[-1]
    scheme = scheme_for(codec, d)
    if scheme is None:
        return None
    rows = int(np.prod(shape[:-1])) if len(shape) > 1 else 1
    from repro.kernels import ops  # lazy: ops imports this module

    blocks = ops.wire_blocks(codec.name, d)
    bm = _round_rows(rows, blocks.get("bm"))
    traffic = encode_hbm_bytes(codec, shape, ef=False) or {}
    return {
        "kernel": f"wire_encode[{codec.name}]",
        "scheme": scheme.name,
        "leaves": list(scheme.leaf_names),
        "block_rows": bm,
        "grid": (-(-rows // bm),),
        "ef": False,
        "hbm_bytes_fused": traffic.get("fused_bytes"),
        "hbm_bytes_unfused": traffic.get("unfused_bytes"),
    }
