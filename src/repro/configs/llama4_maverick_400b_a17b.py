"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128 experts top-1 + 1 shared, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E family card]

Layer program (period 4): iRoPE-style 3 chunked-local(8192) : 1
NoPE-global attention, with MoE FFN on every other layer (Maverick's
interleaved dense/MoE). Chunked attention is realized as sliding-window
8192 (TPU adaptation note in DESIGN.md); local layers' bounded caches
qualify this arch for long_500k.
"""

from repro.config import LayerSpec, ModelConfig

_PAT = (
    LayerSpec(window=8192, ffn="dense"),
    LayerSpec(window=8192, ffn="moe"),
    LayerSpec(window=8192, ffn="dense"),
    LayerSpec(use_rope=False, ffn="moe"),  # NoPE global layer
)

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    source="hf:meta-llama/Llama-4-Maverick-17B-128E (card: Scout-17B-16E)",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    norm="rmsnorm",
    act="silu",
    rope_theta=5e5,
    use_qk_norm=True,
    num_experts=128,
    num_experts_per_tok=1,
    num_shared_experts=1,
    moe_d_ff=8192,
    base_pattern=_PAT,
    base_groups=6,
    mod_pattern=_PAT,
    mod_groups=6,
    d_fusion=4096,
    param_dtype="bfloat16",
)
