"""xlstm-350m [ssm] — 24L d_model=1024 4H d_ff=0 vocab=50304,
sLSTM + mLSTM blocks. [arXiv:2405.04517]

Paper-style xLSTM[7:1]-ish interleave approximated at period 4
(3 mLSTM : 1 sLSTM); blocks carry their own up/down projections
(d_ff=0: no separate FFN; sLSTM blocks append the paper's gated FFN
internally). mLSTM trains chunkwise-parallel; both decode O(1), which is
why this arch runs long_500k.
"""

from repro.config import LayerSpec, ModelConfig

_PAT = (
    LayerSpec(mixer="mlstm", ffn="none"),
    LayerSpec(mixer="mlstm", ffn="none"),
    LayerSpec(mixer="mlstm", ffn="none"),
    LayerSpec(mixer="slstm", ffn="none"),
)

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    source="arXiv:2405.04517",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    norm="layernorm",
    rope_type="none",
    ssm_expand=2,
    mlstm_chunk=64,
    base_pattern=_PAT,
    base_groups=3,
    mod_pattern=_PAT,
    mod_groups=3,
    d_fusion=1024,
)
