"""deepseek-v3-671b [moe] — 61L d_model=7168 128H d_ff(expert)=2048
vocab=129280, MLA (q_lora 1536 / kv_lora 512 / nope 128 / rope 64 /
v 128), 1 shared + 256 routed experts top-8, MTP aux head.
[arXiv:2412.19437]

Layer program: 3 dense-FFN prefix layers (d_ff 18432) then 58 MoE
layers. MLA decode uses the absorbed-latent form (cache = 576/token).
Router: softmax + Switch aux loss stands in for the paper's
aux-loss-free sigmoid+bias scheme (DESIGN.md adaptation table).
"""

from repro.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    source="arXiv:2412.19437",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,  # MLA: per-head latent expansion, no GQA grouping
    d_ff=18432,  # dense prefix layers
    vocab_size=129280,
    norm="rmsnorm",
    act="silu",
    rope_theta=1e4,
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    num_experts=256,
    num_experts_per_tok=8,
    num_shared_experts=1,
    moe_d_ff=2048,
    use_mtp=True,
    prefix_pattern=(LayerSpec(ffn="dense"),) * 3,
    base_pattern=(LayerSpec(ffn="moe"),),
    base_groups=29,
    mod_pattern=(LayerSpec(ffn="moe"),),
    mod_groups=29,
    d_fusion=4096,
    param_dtype="bfloat16",
)
