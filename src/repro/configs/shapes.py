"""ShapeDtypeStruct input stand-ins for every (arch × shape × step kind).

Nothing here allocates: decode caches and params come from
``jax.eval_shape`` over the real constructors, so the dry-run lowers the
exact structures the runtime would use.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.config import INPUT_SHAPES, ModelConfig, ShapeConfig
from repro.models.transformer import init_decode_cache, init_lm


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def enc_frames_for(cfg: ModelConfig, seq: int) -> int:
    """Stub audio frontend: ~4 tokens of speech per text token budget,
    capped so encoder self-attention stays lowerable."""
    return min(max(cfg.enc_seq_len, 1), max(seq // 4, 64))


def _modal_extras(cfg: ModelConfig, lead, seq, compute_dtype) -> Dict[str, Any]:
    ex: Dict[str, Any] = {}
    if cfg.num_image_tokens:
        ex["image_embeds"] = _sds(
            (*lead, cfg.num_image_tokens, cfg.d_model), compute_dtype
        )
    if cfg.is_encdec:
        ex["frame_embeds"] = _sds(
            (*lead, enc_frames_for(cfg, seq), cfg.d_model), compute_dtype
        )
    return ex


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig, *,
                      n_clients: int = 0, tau: int = 2) -> Dict[str, Any]:
    """IFL round batch (n_clients > 0): leaves (N, tau+1, B/N, ...).
    Plain DP batch (n_clients == 0): leaves (B, ...)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    B, S = shape.global_batch, shape.seq_len
    if n_clients:
        assert B % n_clients == 0, (B, n_clients)
        lead = (n_clients, tau + 1, B // n_clients)
    else:
        lead = (B,)
    batch = {"tokens": _sds((*lead, S), jnp.int32)}
    batch.update(_modal_extras(cfg, lead, S, cdt))
    return batch


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    cdt = jnp.dtype(cfg.compute_dtype)
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": _sds((B, S), jnp.int32)}
    batch.update(_modal_extras(cfg, (B,), S, cdt))
    return batch


def decode_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """serve_step inputs: one new token + a cache of length seq_len (plus
    precomputed encoder cross-K/V for enc-dec archs)."""
    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(
        functools.partial(init_decode_cache, cfg, B, S)
    )
    out = {
        "cache": cache,
        "token": _sds((B, 1), jnp.int32),
        "pos": _sds((), jnp.int32),
        "cross_kvs": None,
    }
    if cfg.is_encdec:
        from repro.models.transformer import build_cross_caches

        def build():
            params = init_lm(jax.random.PRNGKey(0), cfg)
            enc_out = jnp.zeros(
                (B, enc_frames_for(cfg, S), cfg.d_model),
                jnp.dtype(cfg.compute_dtype),
            )
            return build_cross_caches(params, cfg, enc_out)

        out["cross_kvs"] = jax.eval_shape(build)
    return out


def param_specs(cfg: ModelConfig, *, n_clients: int = 0):
    """eval_shape of the real initializer (stacked over clients if IFL)."""

    def build():
        p = init_lm(jax.random.PRNGKey(0), cfg)
        return jax.tree.map(
            lambda a: a.astype(jnp.dtype(cfg.param_dtype)), p
        )

    if n_clients:
        def build_stacked():
            return jax.vmap(lambda k: init_lm(k, cfg))(
                jax.random.split(jax.random.PRNGKey(0), n_clients)
            )

        p = jax.eval_shape(build_stacked)
        return jax.tree.map(
            lambda s: _sds(s.shape, jnp.dtype(cfg.param_dtype)), p
        )
    return jax.eval_shape(build)


def shape_by_name(name: str) -> ShapeConfig:
    return INPUT_SHAPES[name]
