"""Assigned-architecture registry: ``get_config('<arch-id>')``."""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.config import ModelConfig

ARCH_IDS: List[str] = [
    "qwen1.5-0.5b",
    "qwen2-vl-2b",
    "xlstm-350m",
    "gemma3-27b",
    "seamless-m4t-large-v2",
    "llama3-405b",
    "olmo-1b",
    "llama4-maverick-400b-a17b",
    "jamba-1.5-large-398b",
    "deepseek-v3-671b",
]

_MODULES: Dict[str, str] = {
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "xlstm-350m": "xlstm_350m",
    "gemma3-27b": "gemma3_27b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "llama3-405b": "llama3_405b",
    "olmo-1b": "olmo_1b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "deepseek-v3-671b": "deepseek_v3_671b",
}

# Pure full-attention archs where long_500k (decode @ 524288 context) is
# skipped: no sub-quadratic / windowed variant in the source model.
# See DESIGN.md §4.
LONG_CONTEXT_OK = {
    "xlstm-350m",  # recurrent state, O(1) decode
    "jamba-1.5-large-398b",  # mamba state + 9 windowless attn layers
    "gemma3-27b",  # 5:1 sliding-window(1024):global
    "llama4-maverick-400b-a17b",  # 3:1 chunked(8192):global (iRoPE)
}


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG.validate()


def supports_shape(arch_id: str, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return arch_id in LONG_CONTEXT_OK
    return True
