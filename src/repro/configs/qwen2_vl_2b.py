"""qwen2-vl-2b [vlm] — 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936, M-RoPE, dynamic resolution. [arXiv:2409.12191]

Vision frontend (ViT + merger) is the permitted stub: input_specs
provides precomputed patch embeddings (B, num_image_tokens, d_model);
the M-RoPE text/image position grid is built by the model. head_dim 128
=> M-RoPE frequency sections (16, 24, 24) over the 64 freq bands.
"""

from repro.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    source="arXiv:2409.12191 (hf:Qwen/Qwen2-VL-2B-Instruct)",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    norm="rmsnorm",
    act="silu",
    rope_theta=1e6,
    rope_type="mrope",
    mrope_sections=(16, 24, 24),
    num_image_tokens=256,  # stubbed "dynamic resolution" budget per sample
    base_pattern=(LayerSpec(),),
    base_groups=14,
    mod_pattern=(LayerSpec(),),
    mod_groups=14,
    d_fusion=1536,
)
