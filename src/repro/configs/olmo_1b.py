"""olmo-1b [dense] — 16L d_model=2048 16H (GQA kv=16) d_ff=8192
vocab=50304, non-parametric LayerNorm. [arXiv:2402.00838]"""

from repro.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    source="arXiv:2402.00838",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=50304,
    tie_embeddings=True,
    norm="nonparam_ln",  # OLMo's distinguishing choice
    act="silu",
    rope_theta=1e4,
    base_pattern=(LayerSpec(),),
    base_groups=8,
    mod_pattern=(LayerSpec(),),
    mod_groups=8,
    d_fusion=2048,
)
