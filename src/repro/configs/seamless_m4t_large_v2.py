"""seamless-m4t-large-v2 [audio] — 24L d_model=1024 16H d_ff=8192
vocab=256206, enc-dec, multimodal. [arXiv:2308.11596]

Backbone only, per the carve-out: the mel-spectrogram + conformer
feature frontend is stubbed — input_specs provides precomputed frame
embeddings (B, S_enc, d_model) feeding a 24L bidirectional encoder
(w2v-BERT 2.0 depth); the 24L decoder consumes them via cross-attention.
IFL privacy constraint: cross-attention only below the fusion cut
(modular block is pure self-attention), see DESIGN.md.
"""

from repro.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    source="arXiv:2308.11596 (hf:facebook/seamless-m4t-v2-large)",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    norm="layernorm",
    act="gelu",
    is_encdec=True,
    enc_layers=24,
    enc_seq_len=1024,  # default stub frame budget (overridden per shape)
    base_pattern=(LayerSpec(cross_attn=True),),
    base_groups=12,
    mod_pattern=(LayerSpec(),),
    mod_groups=12,
    d_fusion=1024,
)
