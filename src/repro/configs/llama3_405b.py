"""llama3-405b [dense] — 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256, 128k context. [arXiv:2407.21783]"""

from repro.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    source="arXiv:2407.21783",
    num_layers=126,
    d_model=16384,
    num_heads=128,
    num_kv_heads=8,
    head_dim=128,
    d_ff=53248,
    vocab_size=128256,
    norm="rmsnorm",
    act="silu",
    rope_theta=5e5,
    base_pattern=(LayerSpec(),),
    base_groups=63,
    mod_pattern=(LayerSpec(),),
    mod_groups=63,
    d_fusion=4096,
    param_dtype="bfloat16",  # params+grads only (SGD) to fit 256 chips
)
