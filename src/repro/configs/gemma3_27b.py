"""gemma3-27b [dense] — 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144, 5:1 local(sliding-window-1024):global, 128k context.
[hf:google/gemma-3-1b-pt family card, 27B scale]

Layer program: 2 local prefix layers + 10 groups of (5 local + 1 global)
= 62. QK-norm per gemma3; sliding-window layers give the sub-quadratic
cache that qualifies this dense arch for long_500k (global layers keep
full caches — linear memory, O(S) decode compute).
"""

from repro.config import LayerSpec, ModelConfig

_LOCAL = LayerSpec(window=1024)
_GLOBAL = LayerSpec()
_PAT = (_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL)

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    source="hf:google/gemma-3-27b-pt (card: google/gemma-3-1b-pt)",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    tie_embeddings=True,
    norm="rmsnorm",
    act="gelu",
    rope_theta=1e6,
    use_qk_norm=True,
    prefix_pattern=(_LOCAL, _LOCAL),
    base_pattern=_PAT,
    base_groups=5,
    mod_pattern=_PAT,
    mod_groups=5,
    d_fusion=4096,
    param_dtype="bfloat16",
)
