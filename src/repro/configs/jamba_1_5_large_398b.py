"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, Mamba:attention 7:1 interleave, MoE 16 experts
top-2 every other layer. [arXiv:2403.19887]

Layer program (Jamba period 8): attention at position 3, Mamba
elsewhere; MoE FFN on odd positions (every other layer). Mamba state
decode is O(1), so long_500k runs (the 9 attention layers keep full
caches — linear memory at batch 1).
"""

from repro.config import LayerSpec, ModelConfig


def _layer(i: int) -> LayerSpec:
    mixer = "attn" if i == 3 else "mamba"
    ffn = "moe" if i % 2 == 1 else "dense"
    return LayerSpec(mixer=mixer, ffn=ffn, use_rope=False)  # Jamba: no RoPE


_PAT = tuple(_layer(i) for i in range(8))

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    source="arXiv:2403.19887 (hf:ai21labs/AI21-Jamba-1.5-Large)",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    norm="rmsnorm",
    act="silu",
    rope_type="none",
    num_experts=16,
    num_experts_per_tok=2,
    moe_d_ff=24576,
    ssm_d_state=16,
    ssm_d_conv=4,
    ssm_expand=2,
    base_pattern=_PAT,
    base_groups=4,
    mod_pattern=_PAT,
    mod_groups=5,
    d_fusion=4096,
    param_dtype="bfloat16",
)
