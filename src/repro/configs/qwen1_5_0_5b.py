"""qwen1.5-0.5b [dense] — 24L d_model=1024 16H (GQA kv=16) d_ff=2816
vocab=151936, QKV bias. [hf:Qwen/Qwen1.5-0.5B]"""

from repro.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    source="hf:Qwen/Qwen1.5-0.5B",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=2816,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,  # recorded; IFL forces untied head (DESIGN.md)
    norm="rmsnorm",
    act="silu",
    rope_theta=1e6,
    base_pattern=(LayerSpec(),),
    base_groups=12,
    mod_pattern=(LayerSpec(),),
    mod_groups=12,
    d_fusion=1024,
)
