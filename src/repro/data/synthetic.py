"""Synthetic LM token pipeline.

Deterministic Zipfian n-gram stream with latent per-client "dialects":
a shared trigram skeleton plus client-specific bigram perturbations, so
IFL's personalization/generalization split is observable on language data
too (per-client base blocks fit the dialect, modular blocks fit the
shared structure). Streams are reproducible from (seed, client, step) —
no state to checkpoint beyond the step counter.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass
class SyntheticLM:
    vocab_size: int
    seed: int = 0
    n_latent: int = 64  # latent markov states

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        V, L = self.vocab_size, self.n_latent
        # latent chain + per-state zipf-ish emissions
        self.trans = rng.dirichlet(np.full(L, 0.3), size=L).astype(np.float32)
        ranks = np.arange(1, V + 1)
        zipf = (1.0 / ranks**1.1).astype(np.float32)
        emis = []
        for s in range(L):
            perm = np.random.default_rng(self.seed + 7 * s).permutation(V)
            emis.append(zipf[np.argsort(perm)])
        self.emis = np.stack(emis)
        self.emis /= self.emis.sum(-1, keepdims=True)

    def sample(self, batch: int, seq: int, *, step: int,
               client: int = 0) -> np.ndarray:
        """(batch, seq) int32, deterministic in (seed, client, step)."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + client * 9176 + step) % (2**63)
        )
        L, V = self.trans.shape[0], self.vocab_size
        # client dialect: biased initial latent distribution
        init = np.zeros(L, np.float32)
        init[(client * 13) % L] = 0.7
        init += 0.3 / L
        init /= init.sum()
        out = np.empty((batch, seq), np.int64)
        state = rng.choice(L, size=batch, p=init)
        for t in range(seq):
            # vectorized: sample emission then next latent
            u = rng.random(batch)
            cdf = np.cumsum(self.emis[state], axis=1)
            out[:, t] = (u[:, None] < cdf).argmax(1)
            un = rng.random(batch)
            cdfn = np.cumsum(self.trans[state], axis=1)
            state = (un[:, None] < cdfn).argmax(1)
        return out.astype(np.int32)


def lm_batches(vocab_size: int, batch: int, seq: int, *, seed: int = 0,
               client: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    """Infinite iterator of {'tokens': (batch, seq)} batches."""
    stream = SyntheticLM(vocab_size, seed=seed)
    step = 0
    while True:
        yield {"tokens": stream.sample(batch, seq, step=step, client=client)}
        step += 1
