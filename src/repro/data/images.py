"""Synthetic KMNIST stand-in (the container is offline).

Deterministic class-conditional generator with the same cardinality as
Kuzushiji-MNIST (28x28 grayscale, 10 classes, 50k train / 10k test).
Each class is a mixture of 3 prototype "strokes" (random low-frequency
fields, fixed per class) plus per-sample elastic jitter and noise, so:
  - classes are separable but NOT linearly trivial (a linear probe gets
    ~70-80%, CNN/MLPs in Table II reach the 90%+ regime like the paper),
  - per-class distributions are unimodal enough for Dirichlet non-IID
    splits to actually skew difficulty, as in the paper's setup.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np


def _prototypes(rng: np.random.Generator, n_classes: int, n_proto: int = 3):
    """Low-frequency class prototypes, (C, P, 28, 28)."""
    freqs = rng.normal(size=(n_classes, n_proto, 4, 4))
    protos = np.zeros((n_classes, n_proto, 28, 28), np.float32)
    xs = np.linspace(0, 1, 28)
    gx, gy = np.meshgrid(xs, xs, indexing="ij")
    for c in range(n_classes):
        for p in range(n_proto):
            field = np.zeros((28, 28))
            for i in range(4):
                for j in range(4):
                    field += freqs[c, p, i, j] * np.sin(
                        np.pi * (i + 1) * gx + 1.3 * c
                    ) * np.cos(np.pi * (j + 1) * gy + 0.7 * p)
            field = (field - field.min()) / (np.ptp(field) + 1e-6)
            protos[c, p] = field
    return protos


def make_synth_kmnist(
    n_train: int = 50_000,
    n_test: int = 10_000,
    n_classes: int = 10,
    seed: int = 1871,  # Kuzushiji-era
    noise: float = 0.25,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Returns (train_x, train_y, test_x, test_y); x: (N, 28, 28, 1) fp32."""
    rng = np.random.default_rng(seed)
    protos = _prototypes(rng, n_classes)

    def gen(n, rng):
        y = rng.integers(0, n_classes, size=n)
        mix = rng.dirichlet(np.ones(protos.shape[1]) * 0.7, size=n)
        base = np.einsum("np,nphw->nhw", mix, protos[y]).astype(np.float32)
        # per-sample global shift jitter (cheap elastic proxy)
        sx = rng.integers(-2, 3, size=n)
        sy = rng.integers(-2, 3, size=n)
        out = np.empty_like(base)
        for i in range(n):
            out[i] = np.roll(np.roll(base[i], sx[i], 0), sy[i], 1)
        out += rng.normal(scale=noise, size=out.shape).astype(np.float32)
        out = np.clip(out, 0.0, 1.5)
        return out[..., None], y.astype(np.int32)

    train_x, train_y = gen(n_train, rng)
    test_x, test_y = gen(n_test, rng)
    return train_x, train_y, test_x, test_y
