"""Dirichlet non-IID label-skew partitioner (paper §IV, α = 0.5)."""

from __future__ import annotations

from typing import List, Tuple

import numpy as np


def dirichlet_partition(
    labels: np.ndarray,
    n_clients: int,
    alpha: float = 0.5,
    seed: int = 0,
    min_per_client: int = 16,
) -> List[np.ndarray]:
    """Split sample indices across clients with per-class Dirichlet(α)
    proportions. Small α => highly skewed shards. Returns index arrays."""
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    shards: List[List[int]] = [[] for _ in range(n_clients)]
    for c in range(n_classes):
        idx = np.where(labels == c)[0]
        rng.shuffle(idx)
        props = rng.dirichlet(np.full(n_clients, alpha))
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for k, part in enumerate(np.split(idx, cuts)):
            shards[k].extend(part.tolist())
    out = []
    for k in range(n_clients):
        if len(shards[k]) < min_per_client:  # top up from the global pool
            extra = rng.integers(0, len(labels), min_per_client)
            shards[k].extend(extra.tolist())
        arr = np.array(shards[k])
        rng.shuffle(arr)
        out.append(arr)
    return out
