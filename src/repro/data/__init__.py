from repro.data.images import make_synth_kmnist  # noqa: F401
from repro.data.dirichlet import dirichlet_partition  # noqa: F401
from repro.data.synthetic import SyntheticLM, lm_batches  # noqa: F401
