"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory)
[arXiv:2405.04517].

mLSTM train/prefill uses the chunkwise-parallel form — quadratic attention
*within* a chunk, recurrent (C, n, m) state *across* chunks — with the
log-space stabilizer from the paper, so neither the (S, S) decay matrix
nor the per-step (dk, dv) states are ever materialized for the full
sequence. Decode is the O(1) recurrent update (this is what makes
xlstm-350m runnable at long_500k).

sLSTM has no parallel form (recurrent weights break associativity); it is
a ``lax.scan`` over time, exactly as the paper computes it.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import modules as nn


def _logsig(x):
    return -jax.nn.softplus(-x)


# =========================================================================
# mLSTM
# =========================================================================


def init_mlstm(key, cfg: ModelConfig):
    d = cfg.d_model
    di = cfg.ssm_expand * d  # projection factor 2 (paper block design)
    dqk = di // 2
    nh = cfg.num_heads
    ks = jax.random.split(key, 8)
    return {
        "up": nn.init_linear(ks[0], d, 2 * di),
        "conv_w": jax.random.normal(ks[1], (4, di)) * 0.2,
        "conv_b": jnp.zeros((di,)),
        "wq": nn.init_linear(ks[2], di, dqk),
        "wk": nn.init_linear(ks[3], di, dqk),
        "wv": nn.init_linear(ks[4], di, di),
        "w_if": nn.init_linear(ks[5], di, 2 * nh, bias=True),
        "skip": jnp.ones((di,)),
        "out_norm": nn.init_norm(ks[6], di, "rmsnorm"),
        "down": nn.init_linear(ks[7], di, d),
    }


def _conv_silu(x, w, b):
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, k : k + x.shape[1], :] * w[k].astype(x.dtype) for k in range(K))
    return jax.nn.silu(out + b.astype(x.dtype))


def _mlstm_qkvif(p, cfg: ModelConfig, x):
    """x: (B, S, d) -> per-head q,k,v and gate preacts."""
    B, S, _ = x.shape
    di = cfg.ssm_expand * cfg.d_model
    nh = cfg.num_heads
    u = nn.linear(p["up"], x)
    xm, z = u[..., :di], u[..., di:]
    xc = _conv_silu(xm, p["conv_w"], p["conv_b"])
    dqk_h = (di // 2) // nh
    dv_h = di // nh
    q = nn.linear(p["wq"], xc).reshape(B, S, nh, dqk_h)
    k = nn.linear(p["wk"], xc).reshape(B, S, nh, dqk_h) / jnp.sqrt(
        jnp.array(dqk_h, x.dtype)
    )
    v = nn.linear(p["wv"], xm).reshape(B, S, nh, dv_h)
    gates = nn.linear(p["w_if"], xm).astype(jnp.float32)  # (B,S,2nh)
    li = gates[..., :nh]  # input gate preact (exp gating)
    lf = _logsig(gates[..., nh:])  # log forget gate
    return q, k, v, li, lf, z, xc


def _mlstm_chunk(carry, inp):
    """One chunk of the chunkwise-parallel mLSTM.

    carry: C (B,H,dk,dv), n (B,H,dk), m (B,H)
    inp: q,k,v (L,B,H,*), li,lf (L,B,H)
    """
    C, n_state, m = carry
    q, k, v, li, lf = inp
    L = q.shape[0]
    b = jnp.cumsum(lf, axis=0)  # (L,B,H) inclusive log-decay within chunk
    btot = b[-1]

    # Intra-chunk decay matrix D[j,l] = b_j - b_l + li_l  (l <= j).
    D = b[:, None] - b[None, :] + li[None, :]  # (L,L,B,H)
    tri = jnp.tril(jnp.ones((L, L), bool))
    D = jnp.where(tri[:, :, None, None], D, -jnp.inf)
    m_intra = jnp.max(D, axis=1)  # (L,B,H)
    m_inter = b + m[None]  # decayed previous stabilizer
    m_j = jnp.maximum(m_inter, m_intra)  # (L,B,H)

    S_w = jnp.exp(D - m_j[:, None])  # (L,L,B,H) stabilized decay weights
    qk = jnp.einsum("jbhd,lbhd->jlbh", q.astype(jnp.float32), k.astype(jnp.float32))
    A = qk * S_w  # masked by S_w's -inf -> 0
    num = jnp.einsum("jlbh,lbhv->jbhv", A, v.astype(jnp.float32))
    den = jnp.sum(A, axis=1)  # (L,B,H)

    inter_scale = jnp.exp(m_inter - m_j)  # (L,B,H)
    num = num + inter_scale[..., None] * jnp.einsum(
        "jbhd,bhdv->jbhv", q.astype(jnp.float32), C
    )
    den = den + inter_scale * jnp.einsum("jbhd,bhd->jbh", q.astype(jnp.float32), n_state)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_j))[..., None]  # (L,B,H,dv)

    # State update to chunk end.
    w_l = btot[None] - b + li  # (L,B,H) log-weight of each token in new state
    m_next = jnp.maximum(btot + m, jnp.max(w_l, axis=0))
    kw = jnp.exp(w_l - m_next[None])[..., None] * k.astype(jnp.float32)
    C_next = jnp.exp(btot + m - m_next)[..., None, None] * C + jnp.einsum(
        "lbhd,lbhv->bhdv", kw, v.astype(jnp.float32)
    )
    n_next = jnp.exp(btot + m - m_next)[..., None] * n_state + jnp.sum(kw, axis=0)
    return (C_next, n_next, m_next), h


def mlstm_forward(p, cfg: ModelConfig, x):
    """x: (B, S, d) -> (B, S, d)."""
    B, S, d = x.shape
    nh = cfg.num_heads
    di = cfg.ssm_expand * d
    q, k, v, li, lf, z, xc = _mlstm_qkvif(p, cfg, x)
    L = min(cfg.mlstm_chunk, S)
    nck = S // L
    assert nck * L == S, f"seq {S} % mlstm_chunk {L} != 0"

    def to_chunks(a):  # (B,S,H,*) -> (nck, L, B, H, *)
        a = a.reshape((B, nck, L) + a.shape[2:])
        return jnp.moveaxis(a, 0, 2)

    dqk_h = (di // 2) // nh
    dv_h = di // nh
    carry = (
        jnp.zeros((B, nh, dqk_h, dv_h), jnp.float32),
        jnp.zeros((B, nh, dqk_h), jnp.float32),
        jnp.full((B, nh), -jnp.inf, jnp.float32),
    )
    _, hs = jax.lax.scan(
        _mlstm_chunk,
        carry,
        (to_chunks(q), to_chunks(k), to_chunks(v),
         jnp.moveaxis(li.reshape(B, nck, L, nh), 0, 2),
         jnp.moveaxis(lf.reshape(B, nck, L, nh), 0, 2)),
    )  # (nck, L, B, H, dv)
    h = jnp.moveaxis(hs, 2, 0).reshape(B, S, di).astype(x.dtype)
    h = nn.apply_norm(p["out_norm"], h, "rmsnorm")
    h = h + xc * p["skip"].astype(x.dtype)  # learnable skip of conv path
    h = h * jax.nn.silu(z)
    return nn.linear(p["down"], h)


def init_mlstm_cache(cfg: ModelConfig, batch: int, dtype):
    del dtype  # state kept in fp32 for gate stability
    d = cfg.d_model
    di = cfg.ssm_expand * d
    nh = cfg.num_heads
    return {
        "mlstm_C": jnp.zeros((batch, nh, (di // 2) // nh, di // nh), jnp.float32),
        "mlstm_n": jnp.zeros((batch, nh, (di // 2) // nh), jnp.float32),
        "mlstm_m": jnp.full((batch, nh), -jnp.inf, jnp.float32),
        "mlstm_conv": jnp.zeros((batch, 3, di), jnp.float32),
    }


def mlstm_decode(p, cfg: ModelConfig, x, cache):
    """x: (B, 1, d). O(1) recurrent step."""
    B = x.shape[0]
    d = cfg.d_model
    di = cfg.ssm_expand * d
    nh = cfg.num_heads
    u = nn.linear(p["up"], x)
    xm, z = u[..., :di], u[..., di:]
    window = jnp.concatenate([cache["mlstm_conv"].astype(x.dtype), xm], axis=1)
    xc = jnp.sum(window * p["conv_w"].astype(x.dtype)[None], axis=1, keepdims=True)
    xc = jax.nn.silu(xc + p["conv_b"].astype(x.dtype))
    dqk_h = (di // 2) // nh
    q = nn.linear(p["wq"], xc).reshape(B, nh, dqk_h).astype(jnp.float32)
    k = nn.linear(p["wk"], xc).reshape(B, nh, dqk_h).astype(jnp.float32)
    k = k / jnp.sqrt(jnp.array(dqk_h, jnp.float32))
    v = nn.linear(p["wv"], xm).reshape(B, nh, di // nh).astype(jnp.float32)
    gates = nn.linear(p["w_if"], xm)[:, 0].astype(jnp.float32)
    li, lf = gates[..., :nh], _logsig(gates[..., nh:])

    m_new = jnp.maximum(lf + cache["mlstm_m"], li)
    dec = jnp.exp(lf + cache["mlstm_m"] - m_new)
    inp = jnp.exp(li - m_new)
    C = dec[..., None, None] * cache["mlstm_C"] + inp[..., None, None] * (
        k[..., :, None] * v[..., None, :]
    )
    n_state = dec[..., None] * cache["mlstm_n"] + inp[..., None] * k
    num = jnp.einsum("bhd,bhdv->bhv", q, C)
    den = jnp.einsum("bhd,bhd->bh", q, n_state)
    h = (num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]).reshape(B, 1, di)
    h = nn.apply_norm(p["out_norm"], h.astype(x.dtype), "rmsnorm")
    h = h + xc * p["skip"].astype(x.dtype)
    h = h * jax.nn.silu(z)
    y = nn.linear(p["down"], h)
    return y, {"mlstm_C": C, "mlstm_n": n_state, "mlstm_m": m_new,
               "mlstm_conv": window[:, 1:].astype(jnp.float32)}


# =========================================================================
# sLSTM
# =========================================================================


def init_slstm(key, cfg: ModelConfig):
    d = cfg.d_model
    nh = cfg.num_heads
    hd = d // nh
    ks = jax.random.split(key, 4)
    wx = jax.random.normal(ks[0], (4, d, d)) / jnp.sqrt(jnp.array(d, jnp.float32))
    wr = jax.random.normal(ks[1], (4, nh, hd, hd)) / jnp.sqrt(
        jnp.array(hd, jnp.float32)
    )
    dff = (8 * d // 3 + 63) // 64 * 64  # gated FFN, pf ~4/3 * 2
    return {
        "wx": wx,  # (4:[z,i,f,o], d, d)
        "wr": wr,  # block-diagonal recurrent weights per head
        "b": jnp.zeros((4, d)),
        "gn": nn.init_norm(ks[2], d, "rmsnorm"),
        "ffn": {
            "w_gate": nn.init_linear(ks[3], d, dff),
            "w_up": nn.init_linear(jax.random.fold_in(ks[3], 1), d, dff),
            "w_down": nn.init_linear(jax.random.fold_in(ks[3], 2), dff, d),
        },
    }


def _slstm_step(p, cfg: ModelConfig, carry, xt):
    """carry: (c, n, m, h) each (B, d); xt: (B, d)."""
    nh = cfg.num_heads
    B, d = xt.shape
    hd = d // nh
    c, n_s, m, h = carry
    hx = h.reshape(B, nh, hd)
    rec = jnp.einsum("bnh,gnhk->gbnk", hx, p["wr"].astype(xt.dtype)).reshape(4, B, d)
    pre = (
        jnp.einsum("bd,gdk->gbk", xt, p["wx"].astype(xt.dtype))
        + rec
        + p["b"].astype(xt.dtype)[:, None]
    ).astype(jnp.float32)
    zt = jnp.tanh(pre[0])
    li = pre[1]
    lf = _logsig(pre[2])
    ot = jax.nn.sigmoid(pre[3])
    m_new = jnp.maximum(lf + m, li)
    c_new = jnp.exp(lf + m - m_new) * c + jnp.exp(li - m_new) * zt
    n_new = jnp.exp(lf + m - m_new) * n_s + jnp.exp(li - m_new)
    h_new = (ot * c_new / jnp.maximum(n_new, 1e-6)).astype(xt.dtype)
    return (c_new, n_new, m_new, h_new), h_new


def slstm_forward(p, cfg: ModelConfig, x):
    """x: (B, S, d). Strictly sequential scan over time."""
    B, S, d = x.shape
    carry = (
        jnp.zeros((B, d), jnp.float32),
        jnp.zeros((B, d), jnp.float32),
        jnp.full((B, d), -jnp.inf, jnp.float32),
        jnp.zeros((B, d), x.dtype),
    )
    (_, _, _, _), hs = jax.lax.scan(
        lambda c, xt: _slstm_step(p, cfg, c, xt), carry, jnp.moveaxis(x, 1, 0)
    )
    h = jnp.moveaxis(hs, 0, 1)
    h = nn.apply_norm(p["gn"], h, "rmsnorm")
    f = p["ffn"]
    y = nn.linear(
        f["w_down"], jax.nn.gelu(nn.linear(f["w_gate"], h)) * nn.linear(f["w_up"], h)
    )
    return y


def init_slstm_cache(cfg: ModelConfig, batch: int, dtype):
    d = cfg.d_model
    return {
        "slstm_c": jnp.zeros((batch, d), jnp.float32),
        "slstm_n": jnp.zeros((batch, d), jnp.float32),
        "slstm_m": jnp.full((batch, d), -jnp.inf, jnp.float32),
        "slstm_h": jnp.zeros((batch, d), dtype),
    }


def slstm_decode(p, cfg: ModelConfig, x, cache):
    carry = (cache["slstm_c"], cache["slstm_n"], cache["slstm_m"], cache["slstm_h"])
    carry, h = _slstm_step(p, cfg, carry, x[:, 0])
    h = nn.apply_norm(p["gn"], h[:, None], "rmsnorm")
    f = p["ffn"]
    y = nn.linear(
        f["w_down"], jax.nn.gelu(nn.linear(f["w_gate"], h)) * nn.linear(f["w_up"], h)
    )
    return y, {"slstm_c": carry[0], "slstm_n": carry[1],
               "slstm_m": carry[2], "slstm_h": carry[3]}
