"""Mixture-of-Experts channel mixer with sort-based dispatch.

Expert-parallel friendly: expert weights carry a leading ``(E,)`` dim that
the sharding rules place on the ``model`` mesh axis. Dispatch avoids the
classic GShard ``(tokens, E, capacity)`` one-hot entirely — at the assigned
scales (deepseek-v3 @ train_4k routes 1M tokens × 256 experts) that tensor
is ~1e13 elements. Instead we rank tokens within their expert via a stable
argsort over expert ids (O(T·K) memory) and move activations with
gather/scatter; XLA SPMD lowers the cross-shard gathers to the
all-to-all-style collectives the roofline then measures.

Covers: llama4-maverick (128e top-1), jamba-1.5 (16e top-2),
deepseek-v3 (1 shared + 256 routed top-8; the paper's sigmoid+bias router
is approximated by softmax + Switch aux loss, noted in DESIGN.md).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import modules as nn
from repro.models.mlp import init_mlp, mlp_forward


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    cap = int(tokens * cfg.num_experts_per_tok * cfg.capacity_factor
              / cfg.num_experts)
    return max(cap, 4)


def init_moe(key, cfg: ModelConfig):
    d, dff = cfg.d_model, (cfg.moe_d_ff or cfg.d_ff)
    ks = jax.random.split(key, 3)
    p = {
        "router": nn.init_linear(ks[0], d, cfg.num_experts),
        "experts": nn.stack_init(
            lambda k: init_mlp(k, d, dff), ks[1], cfg.num_experts
        ),
    }
    if cfg.num_shared_experts > 0:
        p["shared"] = init_mlp(ks[2], d, dff * cfg.num_shared_experts)
    return p


def _expert_ffn(experts, x, act: str):
    """x: (E, C, d) -> (E, C, d), batched over the expert dim."""
    a = nn.activation(act)
    h = a(jnp.einsum("ecd,edf->ecf", x, experts["w_gate"]["w"].astype(x.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", x, experts["w_up"]["w"].astype(x.dtype))
    return jnp.einsum("ecf,efd->ecd", h, experts["w_down"]["w"].astype(x.dtype))


def _rank_in_expert(e_flat: jnp.ndarray, E: int) -> jnp.ndarray:
    """Position of each (token, choice) within its expert's arrival order.

    e_flat: (T*K,) int32 expert assignments. Returns (T*K,) int32 ranks.
    Stable-sort ranking: rank = index-in-sorted-run. O(T·K log) and no
    (T·K, E) one-hot.
    """
    n = e_flat.shape[0]
    order = jnp.argsort(e_flat, stable=True)  # (n,)
    e_sorted = e_flat[order]
    hist = jnp.zeros((E,), jnp.int32).at[e_flat].add(1)
    starts = jnp.cumsum(hist) - hist  # exclusive prefix sum
    pos_sorted = jnp.arange(n, dtype=jnp.int32) - starts[e_sorted]
    return jnp.zeros((n,), jnp.int32).at[order].set(pos_sorted)


def moe_forward(p, cfg: ModelConfig, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d). Returns (y, aux_loss)."""
    B, S, d = x.shape
    T = B * S
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    C = _capacity(T, cfg)
    xt = x.reshape(T, d)

    logits = nn.linear(p["router"], xt).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, K)  # (T, K)
    gate_vals = gate_vals / (jnp.sum(gate_vals, axis=-1, keepdims=True) + 1e-9)

    e_flat = idx.reshape(T * K).astype(jnp.int32)
    pos_flat = _rank_in_expert(e_flat, E)  # (T*K,)
    keep = pos_flat < C

    # Load-balance auxiliary loss (Switch-style): E * sum(f_e * p_e).
    frac_tokens = (
        jnp.zeros((E,), jnp.float32).at[e_flat].add(1.0) / (T * K)
    )
    frac_probs = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs) * cfg.router_aux_coef

    # Dispatch: scatter token rows into (E*C) expert slots, then gather.
    token_of = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)  # (T*K,)
    slot = e_flat * C + pos_flat  # unique among kept entries
    slot_safe = jnp.where(keep, slot, E * C)  # OOB -> dropped by scatter
    slot_to_token = (
        jnp.zeros((E * C,), jnp.int32)
        .at[slot_safe]
        .set(token_of, mode="drop")
    )
    slot_used = (
        jnp.zeros((E * C,), jnp.bool_).at[slot_safe].set(True, mode="drop")
    )
    xe = jnp.take(xt, slot_to_token, axis=0)  # (E*C, d)
    xe = jnp.where(slot_used[:, None], xe, 0).reshape(E, C, d)

    ye = _expert_ffn(p["experts"], xe, cfg.act).reshape(E * C, d)

    # Combine: gather each (token, choice)'s expert output, weight, sum.
    gath = jnp.take(ye, jnp.minimum(slot, E * C - 1), axis=0)  # (T*K, d)
    w = (gate_vals.reshape(T * K) * keep.astype(jnp.float32)).astype(x.dtype)
    yt = jnp.sum((gath * w[:, None]).reshape(T, K, d), axis=1)

    if "shared" in p:
        yt = yt + mlp_forward(p["shared"], xt, cfg.act)
    return yt.reshape(B, S, d), aux.astype(jnp.float32)
