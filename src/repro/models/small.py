"""Paper Table II: the four heterogeneous client models (KMNIST-scale).

Every model is partitioned at the fusion layer with the paper's common
output dimension d_fusion = 432; base/modular blocks follow Table II
exactly. Conv layers are 3x3 SAME + ReLU + 2x2 max-pool; FC layers are
followed by ReLU except the output layer. Client 1's fusion layer is
conv-based, the rest FC-based — heterogeneous fusion *types* with a
standardized output dim, as the paper stresses.
"""

from __future__ import annotations

import math
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.models import modules as nn

D_FUSION = 432
NUM_CLASSES = 10

# Layer descriptors: ('conv', cin, cout) | ('fc', din, dout).
CLIENT_ARCHS: Dict[int, Dict[str, List[Tuple]]] = {
    1: {
        "base": [("conv", 1, 16), ("conv", 16, 32), ("conv", 32, 48)],
        "modular": [("fc", 432, 256), ("fc", 256, 128), ("fc", 128, 64),
                    ("fc", 64, 10)],
    },
    2: {
        "base": [("conv", 1, 16), ("conv", 16, 32), ("fc", 1568, 432)],
        "modular": [("fc", 432, 128), ("fc", 128, 10)],
    },
    3: {
        "base": [("fc", 784, 432)],
        "modular": [("fc", 432, 256), ("fc", 256, 128), ("fc", 128, 64),
                    ("fc", 64, 10)],
    },
    4: {
        "base": [("fc", 784, 1024), ("fc", 1024, 512), ("fc", 512, 432)],
        "modular": [("fc", 432, 10)],
    },
}


def _init_layers(key, descs) -> List[Dict[str, Any]]:
    out = []
    for i, d in enumerate(descs):
        k = jax.random.fold_in(key, i)
        if d[0] == "conv":
            _, cin, cout = d
            fan_in = 9 * cin
            out.append({
                "w": jax.random.normal(k, (3, 3, cin, cout)) / math.sqrt(fan_in),
                "b": jnp.zeros((cout,)),
            })
        else:
            _, din, dout = d
            out.append(nn.init_linear(k, din, dout, bias=True))
    return out


def init_client_model(key, client_id: int) -> Dict[str, Any]:
    arch = CLIENT_ARCHS[client_id]
    kb, km = jax.random.split(key)
    return {
        "base": _init_layers(kb, arch["base"]),
        "modular": _init_layers(km, arch["modular"]),
    }


def _conv_pool_relu(p, x):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    ) + p["b"]
    y = jax.nn.relu(y)
    return jax.lax.reduce_window(
        y, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def _apply_layers(layers, descs, x, *, is_output_block: bool):
    """x: (B, 28, 28, 1) images or (B, d) features."""
    n = len(descs)
    for i, (p, d) in enumerate(zip(layers, descs)):
        if d[0] == "conv":
            if x.ndim == 2:
                raise ValueError("conv after flatten")
            x = _conv_pool_relu(p, x)
        else:
            if x.ndim == 4:
                x = x.reshape(x.shape[0], -1)
            x = nn.linear(p, x)
            last = is_output_block and i == n - 1
            if not last:
                x = jax.nn.relu(x)
    if x.ndim == 4:  # conv-based fusion layer (client 1): flatten + ReLU
        x = jax.nn.relu(x.reshape(x.shape[0], -1))
    return x


def client_base_apply(params, client_id: int, x) -> jnp.ndarray:
    """x: (B, 28, 28, 1) -> z: (B, 432). The fusion-layer output z_k."""
    z = _apply_layers(
        params["base"], CLIENT_ARCHS[client_id]["base"], x, is_output_block=False
    )
    assert z.shape[-1] == D_FUSION, z.shape
    return z


def client_modular_apply(params, client_id: int, z) -> jnp.ndarray:
    """z: (B, 432) -> logits: (B, 10)."""
    return _apply_layers(
        params["modular"], CLIENT_ARCHS[client_id]["modular"], z,
        is_output_block=True,
    )


def client_apply(params, client_id: int, x) -> jnp.ndarray:
    """Local end-to-end inference, eq. (10)."""
    return client_modular_apply(params, client_id, client_base_apply(params, client_id, x))


def compose_apply(base_params, base_id: int, mod_params, mod_id: int, x):
    """Cross-vendor composition, eq. (11): base of k + modular of i."""
    z = client_base_apply(base_params, base_id, x)
    return client_modular_apply(mod_params, mod_id, z)


def model_bytes(params, block: str = None) -> int:
    tree = params if block is None else params[block]
    return nn.param_bytes(tree)
