"""Pure-JAX model substrate: param-tree init fns + pure apply fns."""

from repro.models.transformer import (  # noqa: F401
    init_lm,
    lm_apply,
    lm_loss,
    init_decode_cache,
    lm_decode_step,
)
