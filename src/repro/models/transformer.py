"""LM assembly: layer program → {base, modular} param partition.

The top-level param tree is ``{'base': ..., 'modular': ...}`` — the IFL
partition is structural, not an afterthought:

    base    = embed (+ modality projectors + encoder) + prefix layers
              + base groups + fusion in-projection       -> z (B,S,d_fusion)
    modular = fusion out-projection + modular groups
              + final norm + LM head                     -> logits

Repeated layer groups are scanned (``lax.scan`` over a stacked leading
group dim) so HLO size is O(|pattern|); optional ``jax.checkpoint`` on the
scan body gives layer-group remat for training. Decode threads a per-layer
cache pytree through the same structure.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import LayerSpec, ModelConfig
from repro.models import modules as nn
from repro.models.attention import (
    attn_decode,
    attn_forward,
    cross_attn_cache,
    cross_attn_decode,
    cross_attn_forward,
    init_attn,
    init_attn_cache,
    init_cross_attn,
)
from repro.models.mlp import init_mlp, mlp_forward
from repro.models.moe import init_moe, moe_forward
from repro.models.rope import default_mrope_positions
from repro.models.ssm import (
    init_mamba,
    init_mamba_cache,
    mamba_decode,
    mamba_forward,
)
from repro.models.xlstm import (
    init_mlstm,
    init_mlstm_cache,
    init_slstm,
    init_slstm_cache,
    mlstm_decode,
    mlstm_forward,
    slstm_decode,
    slstm_forward,
)

Params = Dict[str, Any]


# =========================================================================
# Single layer
# =========================================================================


def init_layer(key, cfg: ModelConfig, spec: LayerSpec) -> Params:
    ks = jax.random.split(key, 6)
    p: Params = {"norm1": nn.init_norm(ks[0], cfg.d_model, cfg.norm)}
    if spec.mixer == "attn":
        p["attn"] = init_attn(ks[1], cfg, spec)
    elif spec.mixer == "mamba":
        p["mamba"] = init_mamba(ks[1], cfg)
    elif spec.mixer == "mlstm":
        p["mlstm"] = init_mlstm(ks[1], cfg)
    elif spec.mixer == "slstm":
        p["slstm"] = init_slstm(ks[1], cfg)
    else:
        raise ValueError(spec.mixer)
    if spec.cross_attn:
        p["norm_x"] = nn.init_norm(ks[2], cfg.d_model, cfg.norm)
        p["cross"] = init_cross_attn(ks[3], cfg)
    if spec.ffn == "dense":
        p["norm2"] = nn.init_norm(ks[4], cfg.d_model, cfg.norm)
        p["ffn"] = init_mlp(ks[5], cfg.d_model, cfg.d_ff)
    elif spec.ffn == "moe":
        p["norm2"] = nn.init_norm(ks[4], cfg.d_model, cfg.norm)
        p["moe"] = init_moe(ks[5], cfg)
    return p


def apply_layer(p, cfg: ModelConfig, spec: LayerSpec, x, positions, enc_out):
    aux = jnp.zeros((), jnp.float32)
    h = nn.apply_norm(p["norm1"], x, cfg.norm)
    if spec.mixer == "attn":
        y = attn_forward(p["attn"], cfg, spec, h, positions)
    elif spec.mixer == "mamba":
        y = mamba_forward(p["mamba"], cfg, h)
    elif spec.mixer == "mlstm":
        y = mlstm_forward(p["mlstm"], cfg, h)
    else:  # slstm (block includes its own gated FFN)
        y = slstm_forward(p["slstm"], cfg, h)
    x = x + y
    if spec.cross_attn:
        h = nn.apply_norm(p["norm_x"], x, cfg.norm)
        x = x + cross_attn_forward(p["cross"], cfg, h, enc_out)
    if spec.ffn == "dense":
        x = x + mlp_forward(p["ffn"], nn.apply_norm(p["norm2"], x, cfg.norm), cfg.act)
    elif spec.ffn == "moe":
        y, a = moe_forward(p["moe"], cfg, nn.apply_norm(p["norm2"], x, cfg.norm))
        x = x + y
        aux = aux + a
    return x, aux


def decode_layer(p, cfg: ModelConfig, spec: LayerSpec, x, lcache, pos,
                 positions=None, cross_kv=None):
    aux_cache = dict(lcache)
    h = nn.apply_norm(p["norm1"], x, cfg.norm)
    if spec.mixer == "attn":
        y, aux_cache["mix"] = attn_decode(
            p["attn"], cfg, spec, h, lcache["mix"], pos, positions
        )
    elif spec.mixer == "mamba":
        y, aux_cache["mix"] = mamba_decode(p["mamba"], cfg, h, lcache["mix"])
    elif spec.mixer == "mlstm":
        y, aux_cache["mix"] = mlstm_decode(p["mlstm"], cfg, h, lcache["mix"])
    else:
        y, aux_cache["mix"] = slstm_decode(p["slstm"], cfg, h, lcache["mix"])
    x = x + y
    if spec.cross_attn:
        h = nn.apply_norm(p["norm_x"], x, cfg.norm)
        x = x + cross_attn_decode(p["cross"], cfg, h, cross_kv)
    if spec.ffn == "dense":
        x = x + mlp_forward(p["ffn"], nn.apply_norm(p["norm2"], x, cfg.norm), cfg.act)
    elif spec.ffn == "moe":
        y, _ = moe_forward(p["moe"], cfg, nn.apply_norm(p["norm2"], x, cfg.norm))
        x = x + y
    return x, aux_cache


def init_layer_cache(cfg: ModelConfig, spec: LayerSpec, batch: int,
                     cache_len: int, dtype) -> Params:
    if spec.mixer == "attn":
        mix = init_attn_cache(cfg, spec, batch, cache_len, dtype)
    elif spec.mixer == "mamba":
        mix = init_mamba_cache(cfg, batch, dtype)
    elif spec.mixer == "mlstm":
        mix = init_mlstm_cache(cfg, batch, dtype)
    else:
        mix = init_slstm_cache(cfg, batch, dtype)
    return {"mix": mix}


# =========================================================================
# Layer groups (scanned)
# =========================================================================


def init_group(key, cfg: ModelConfig, pattern) -> Params:
    ks = jax.random.split(key, len(pattern))
    return {f"l{i}": init_layer(ks[i], cfg, s) for i, s in enumerate(pattern)}


def apply_group(p, cfg: ModelConfig, pattern, x, positions, enc_out):
    aux = jnp.zeros((), jnp.float32)
    for i, spec in enumerate(pattern):
        x, a = apply_layer(p[f"l{i}"], cfg, spec, x, positions, enc_out)
        aux = aux + a
    return x, aux


def scan_groups(groups_p, cfg: ModelConfig, pattern, x, positions, enc_out):
    """Scan a stacked group stack. groups_p leaves: (n_groups, ...).

    remat='group' checkpoints the whole group body (one residual per
    group live during backward); remat='layer' checkpoints each layer
    individually — smaller recompute granularity, lower peak memory for
    wide-pattern groups (jamba's 8-layer period), at ~equal FLOPs.
    """

    def body(carry, gp):
        x, aux = carry
        if cfg.remat == "layer":
            for i, spec in enumerate(pattern):
                layer_fn = jax.checkpoint(
                    functools.partial(apply_layer, cfg=cfg, spec=spec),
                    static_argnums=(),
                )
                x, a = layer_fn(gp[f"l{i}"], x=x, positions=positions,
                                enc_out=enc_out)
                aux = aux + a
        else:
            x, a = apply_group(gp, cfg, pattern, x, positions, enc_out)
            aux = aux + a
        return (x, aux), None

    if cfg.remat == "group":
        body = jax.checkpoint(body)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), groups_p)
    return x, aux


def decode_scan_groups(groups_p, caches, cfg, pattern, x, pos, positions,
                       cross_kvs=None):
    def body(x, inp):
        gp, gc, ckv = inp
        new_gc = {}
        for i, spec in enumerate(pattern):
            x, new_gc[f"l{i}"] = decode_layer(
                gp[f"l{i}"], cfg, spec, x, gc[f"l{i}"], pos, positions,
                None if ckv is None else ckv.get(f"l{i}"),
            )
        return x, new_gc

    xs = (groups_p, caches, cross_kvs)
    x, new_caches = jax.lax.scan(body, x, xs)
    return x, new_caches


# =========================================================================
# Encoder (enc-dec archs; consumes stub frontend embeddings)
# =========================================================================


def _init_enc_layer(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    return {
        "norm1": nn.init_norm(ks[0], cfg.d_model, cfg.norm),
        "attn": init_cross_attn(ks[1], cfg),  # bidirectional self-attn
        "norm2": nn.init_norm(ks[2], cfg.d_model, cfg.norm),
        "ffn": init_mlp(ks[3], cfg.d_model, cfg.d_ff),
    }


def init_encoder(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 2)
    return {
        "groups": nn.stack_init(
            lambda k: _init_enc_layer(k, cfg), ks[0], cfg.enc_layers
        ),
        "final_norm": nn.init_norm(ks[1], cfg.d_model, cfg.norm),
    }


def encoder_forward(p, cfg: ModelConfig, frames):
    """frames: (B, S_enc, d_model) stub frontend output."""
    x = frames.astype(nn.dtype_of(cfg.compute_dtype))

    def body(x, lp):
        h = nn.apply_norm(lp["norm1"], x, cfg.norm)
        x = x + cross_attn_forward(lp["attn"], cfg, h, h)  # bidirectional
        h = nn.apply_norm(lp["norm2"], x, cfg.norm)
        return x + mlp_forward(lp["ffn"], h, cfg.act), None

    x, _ = jax.lax.scan(body, x, p["groups"])
    return nn.apply_norm(p["final_norm"], x, cfg.norm)


# =========================================================================
# Full LM
# =========================================================================


def init_lm(key, cfg: ModelConfig) -> Params:
    cfg.validate()
    pre, bp, bg, mp, mg = cfg._resolved_program()
    ks = jax.random.split(key, 10)
    base: Params = {"embed": nn.init_embedding(ks[0], cfg.vocab_size, cfg.d_model)}
    if cfg.num_image_tokens:
        base["img_proj"] = nn.init_linear(ks[1], cfg.d_model, cfg.d_model)
    if cfg.is_encdec:
        base["encoder"] = init_encoder(ks[2], cfg)
    if pre:
        base["prefix"] = {
            f"l{i}": init_layer(jax.random.fold_in(ks[3], i), cfg, s)
            for i, s in enumerate(pre)
        }
    if bg:
        base["groups"] = nn.stack_init(
            lambda k: init_group(k, cfg, bp), ks[4], bg
        )
    base["fusion_in"] = nn.init_linear(ks[5], cfg.d_model, cfg.d_fusion)

    modular: Params = {
        "fusion_out": nn.init_linear(ks[6], cfg.d_fusion, cfg.d_model)
    }
    if mg:
        modular["groups"] = nn.stack_init(
            lambda k: init_group(k, cfg, mp), ks[7], mg
        )
    modular["final_norm"] = nn.init_norm(ks[8], cfg.d_model, cfg.norm)
    # NOTE: tie_embeddings is recorded in the configs but the IFL partition
    # forces an untied head (embed lives in base, head in modular — tying
    # would leak base parameters across the privacy boundary). See DESIGN.md.
    modular["lm_head"] = nn.init_linear(ks[9], cfg.d_model, cfg.vocab_size)
    if cfg.use_mtp:
        mk = jax.random.fold_in(ks[9], 1)
        modular["mtp"] = {
            "layer": init_layer(mk, cfg, LayerSpec()),
            "norm": nn.init_norm(jax.random.fold_in(mk, 1), cfg.d_model, cfg.norm),
        }
    return {"base": base, "modular": modular}


def _positions(cfg: ModelConfig, batch_size: int, seq: int, batch=None):
    if cfg.rope_type == "mrope":
        if batch is not None and "mrope_positions" in batch:
            return batch["mrope_positions"]
        return default_mrope_positions(batch_size, seq, cfg.num_image_tokens)
    return jnp.broadcast_to(
        jnp.arange(seq, dtype=jnp.int32)[None], (batch_size, seq)
    )


def base_forward(base: Params, cfg: ModelConfig, batch) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """-> (z, aux). z: (B, S, d_fusion) — the fusion-layer output that IFL
    shares; the ONLY activation crossing the client boundary."""
    pre, bp, bg, mp, mg = cfg._resolved_program()
    tokens = batch["tokens"]
    B, S = tokens.shape
    cdt = nn.dtype_of(cfg.compute_dtype)
    x = nn.embedding(base["embed"], tokens, compute_dtype=cdt)
    if cfg.num_image_tokens:
        img = nn.linear(base["img_proj"], batch["image_embeds"].astype(cdt))
        x = jnp.concatenate([img, x[:, cfg.num_image_tokens :]], axis=1)
    positions = _positions(cfg, B, S, batch)
    enc_out = None
    if cfg.is_encdec:
        enc_out = encoder_forward(base["encoder"], cfg, batch["frame_embeds"])
    aux = jnp.zeros((), jnp.float32)
    for i, spec in enumerate(pre):
        x, a = apply_layer(base["prefix"][f"l{i}"], cfg, spec, x, positions, enc_out)
        aux = aux + a
    if bg:
        x, a = scan_groups(base["groups"], cfg, bp, x, positions, enc_out)
        aux = aux + a
    z = nn.linear(base["fusion_in"], x)
    return z.astype(cdt), aux


def modular_trunk(mod: Params, cfg: ModelConfig, z):
    """z -> (final normed hidden, aux, positions) — everything above the
    fusion interface except the LM head."""
    _, _, _, mp, mg = cfg._resolved_program()
    B, S, _ = z.shape
    x = nn.linear(mod["fusion_out"], z.astype(nn.dtype_of(cfg.compute_dtype)))
    positions = _positions(cfg, B, S)
    aux = jnp.zeros((), jnp.float32)
    if mg:
        x, aux = scan_groups(mod["groups"], cfg, mp, x, positions, None)
    x = nn.apply_norm(mod["final_norm"], x, cfg.norm)
    return x, aux, positions


def _head_logits(mod: Params, cfg: ModelConfig, x):
    logits = nn.linear(mod["lm_head"], x).astype(jnp.float32)
    if cfg.logit_softcap > 0:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits / c) * c
    return logits


def mtp_hidden(mod: Params, cfg: ModelConfig, x, positions):
    h2, _ = apply_layer(mod["mtp"]["layer"], cfg, LayerSpec(), x, positions,
                        None)
    return nn.apply_norm(mod["mtp"]["norm"], h2, cfg.norm)


def modular_forward(mod: Params, cfg: ModelConfig, z) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """z: (B, S, d_fusion) -> (logits fp32, aux)."""
    x, aux, positions = modular_trunk(mod, cfg, z)
    logits = _head_logits(mod, cfg, x)
    if cfg.use_mtp:
        mtp_logits = _head_logits(mod, cfg, mtp_hidden(mod, cfg, x, positions))
        return logits, aux, mtp_logits
    return logits, aux


def chunked_ce(mod: Params, cfg: ModelConfig, h, tokens, *, offset: int,
               start: int) -> jnp.ndarray:
    """Mean next-token CE without ever materializing (tokens, vocab)
    logits: scan over position chunks, head matmul + softmax per chunk,
    checkpointed so backward recomputes chunk logits instead of storing
    them. At gemma3 train_4k (262k vocab) the full logits buffer is
    ~4.3 GB/chip fp32 — this caps it at chunk/S of that (§Perf)."""
    B, S, _ = h.shape
    C = cfg.ce_chunk
    T = S - offset - start  # scoreable positions
    n = -(-T // C)
    pad = n * C - T
    hs = jax.lax.dynamic_slice_in_dim(h, start, T, axis=1)
    hs = jnp.pad(hs, ((0, 0), (0, pad), (0, 0)))
    tgt = jnp.pad(tokens[:, start + offset : start + offset + T],
                  ((0, 0), (0, pad)))
    mask = jnp.pad(jnp.ones((B, T), jnp.float32), ((0, 0), (0, pad)))
    hs = hs.reshape(B, n, C, -1).swapaxes(0, 1)
    tgt = tgt.reshape(B, n, C).swapaxes(0, 1)
    mask = mask.reshape(B, n, C).swapaxes(0, 1)

    @jax.checkpoint
    def chunk_nll(hc, tc, mc):
        logits = _head_logits(mod, cfg, hc)
        lp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(lp, tc[..., None], axis=-1)[..., 0]
        return jnp.sum(nll * mc)

    def body(tot, inp):
        hc, tc, mc = inp
        return tot + chunk_nll(hc, tc, mc), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                            (hs, tgt, mask))
    return total / (B * T)


def lm_apply(params: Params, cfg: ModelConfig, batch):
    z, aux_b = base_forward(params["base"], cfg, batch)
    out = modular_forward(params["modular"], cfg, z)
    if cfg.use_mtp:
        logits, aux_m, mtp_logits = out
        return logits, aux_b + aux_m, mtp_logits
    logits, aux_m = out
    return logits, aux_b + aux_m, None


def _next_token_ce(logits, tokens, offset: int, start: int):
    """Mean CE of predicting tokens[t + offset] from position t."""
    lp = jax.nn.log_softmax(logits[:, start : logits.shape[1] - offset], axis=-1)
    tgt = tokens[:, start + offset :]
    nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def lm_loss(params: Params, cfg: ModelConfig, batch) -> jnp.ndarray:
    start = cfg.num_image_tokens  # no LM loss on stub image positions
    if cfg.ce_chunk:
        z, aux_b = base_forward(params["base"], cfg, batch)
        h, aux_m, positions = modular_trunk(params["modular"], cfg, z)
        loss = chunked_ce(params["modular"], cfg, h, batch["tokens"],
                          offset=1, start=start)
        if cfg.use_mtp:
            h2 = mtp_hidden(params["modular"], cfg, h, positions)
            loss = loss + 0.3 * chunked_ce(
                params["modular"], cfg, h2, batch["tokens"],
                offset=2, start=start,
            )
        return loss + aux_b + aux_m
    logits, aux, mtp_logits = lm_apply(params, cfg, batch)
    loss = _next_token_ce(logits, batch["tokens"], 1, start)
    if mtp_logits is not None:
        loss = loss + 0.3 * _next_token_ce(mtp_logits, batch["tokens"], 2, start)
    return loss + aux


# =========================================================================
# Decode (serve_step): one token against a cache of length cache_len
# =========================================================================


def _stack_cache(tree, n):
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (n,) + a.shape).copy()
        if hasattr(a, "shape") else a,
        tree,
    )


def init_base_decode_cache(cfg: ModelConfig, batch: int, cache_len: int,
                           dtype=None) -> Params:
    """The base half's decode cache: prefix layers + base groups."""
    dtype = dtype or nn.dtype_of(cfg.compute_dtype)
    pre, bp, bg, mp, mg = cfg._resolved_program()
    cache: Params = {}
    if pre:
        cache["prefix"] = {
            f"l{i}": init_layer_cache(cfg, s, batch, cache_len, dtype)
            for i, s in enumerate(pre)
        }
    if bg:
        one = {
            f"l{i}": init_layer_cache(cfg, s, batch, cache_len, dtype)
            for i, s in enumerate(bp)
        }
        cache["base"] = _stack_cache(one, bg)
    return cache


def init_modular_decode_cache(cfg: ModelConfig, batch: int, cache_len: int,
                              dtype=None) -> Params:
    """The modular half's decode cache: modular groups only."""
    dtype = dtype or nn.dtype_of(cfg.compute_dtype)
    pre, bp, bg, mp, mg = cfg._resolved_program()
    cache: Params = {}
    if mg:
        one = {
            f"l{i}": init_layer_cache(cfg, s, batch, cache_len, dtype)
            for i, s in enumerate(mp)
        }
        cache["mod"] = _stack_cache(one, mg)
    return cache


def init_decode_cache(cfg: ModelConfig, batch: int, cache_len: int,
                      dtype=None) -> Params:
    cache = init_base_decode_cache(cfg, batch, cache_len, dtype)
    cache.update(init_modular_decode_cache(cfg, batch, cache_len, dtype))
    return cache


def init_composed_cache(base_cfg: ModelConfig, mod_cfg: ModelConfig,
                        batch: int, cache_len: int, dtype=None) -> Params:
    """Decode cache for a cross-arch composition: the base half's layers
    come from ``base_cfg``, the modular half's from ``mod_cfg``. The two
    halves share the standardized fusion interface, so the configs only
    have to agree on ``d_fusion`` (and vocab, for the sampling loop)."""
    if base_cfg.d_fusion != mod_cfg.d_fusion:
        raise ValueError(
            f"fusion dim mismatch: base {base_cfg.d_fusion} != "
            f"modular {mod_cfg.d_fusion}"
        )
    cache = init_base_decode_cache(base_cfg, batch, cache_len, dtype)
    cache.update(init_modular_decode_cache(mod_cfg, batch, cache_len, dtype))
    return cache


def build_cross_caches(params: Params, cfg: ModelConfig, enc_out) -> Params:
    """Precompute encoder K/V for every cross-attn layer."""
    pre, bp, bg, mp, mg = cfg._resolved_program()
    out: Params = {}
    if pre:
        out["prefix"] = {
            f"l{i}": cross_attn_cache(
                params["base"]["prefix"][f"l{i}"]["cross"], cfg, enc_out
            )
            for i, s in enumerate(pre)
            if s.cross_attn
        }
    if bg and any(s.cross_attn for s in bp):
        def per_group(gp):
            return {
                f"l{i}": cross_attn_cache(gp[f"l{i}"]["cross"], cfg, enc_out)
                for i, s in enumerate(bp)
                if s.cross_attn
            }

        out["base"] = jax.vmap(per_group, in_axes=0)(params["base"]["groups"])
    return out


def _decode_positions(cfg: ModelConfig, pos, B: int):
    if cfg.rope_type == "mrope":
        # Text continuation: all three M-RoPE axes share the running id.
        n_img = cfg.num_image_tokens
        grid = max(1, int(n_img**0.5)) if n_img else 0
        tid = jnp.maximum(pos - n_img, 0) + grid
        positions = jnp.broadcast_to(tid[None, None], (B, 1)).astype(jnp.int32)
        return jnp.stack([positions] * 3)
    return None


def base_decode_step(base: Params, cfg: ModelConfig, cache: Params,
                     token: jnp.ndarray, pos: jnp.ndarray,
                     cross_kvs: Optional[Params] = None):
    """The base half of one decode step: embed -> prefix -> base groups
    -> fusion in-projection.  token: (B, 1) int32; pos: scalar int32.

    Returns (z (B, 1, d_fusion), new_cache with the base half's keys) —
    ``z`` is the only activation crossing the client boundary, exactly
    as in ``base_forward``.
    """
    pre, bp, bg, mp, mg = cfg._resolved_program()
    B = token.shape[0]
    cdt = nn.dtype_of(cfg.compute_dtype)
    x = nn.embedding(base["embed"], token, compute_dtype=cdt)
    positions = _decode_positions(cfg, pos, B)

    new_cache: Params = {}
    if pre:
        new_cache["prefix"] = {}
        for i, spec in enumerate(pre):
            ckv = None
            if spec.cross_attn and cross_kvs is not None:
                ckv = cross_kvs["prefix"][f"l{i}"]
            x, new_cache["prefix"][f"l{i}"] = decode_layer(
                base["prefix"][f"l{i}"], cfg, spec, x,
                cache["prefix"][f"l{i}"], pos, positions, ckv,
            )
    if bg:
        x, new_cache["base"] = decode_scan_groups(
            base["groups"], cache["base"], cfg, bp, x, pos,
            positions, None if cross_kvs is None else cross_kvs.get("base"),
        )
    z = nn.linear(base["fusion_in"], x).astype(cdt)
    return z, new_cache


def modular_decode_step(mod: Params, cfg: ModelConfig, cache: Params,
                        z: jnp.ndarray, pos: jnp.ndarray):
    """The modular half of one decode step: fusion out-projection ->
    modular groups -> final norm -> LM head.  z: (B, 1, d_fusion).

    Returns (logits (B, 1, V) fp32, new_cache with the modular half's
    keys).  ``cfg`` here is the *modular* arch's config — composing a
    base of one family with a modular block of another is just calling
    the two halves with their own configs (see ``composed_decode_step``).
    """
    pre, bp, bg, mp, mg = cfg._resolved_program()
    B = z.shape[0]
    positions = _decode_positions(cfg, pos, B)
    x = nn.linear(mod["fusion_out"], z)
    new_cache: Params = {}
    if mg:
        x, new_cache["mod"] = decode_scan_groups(
            mod["groups"], cache["mod"], cfg, mp, x, pos,
            positions, None,
        )
    x = nn.apply_norm(mod["final_norm"], x, cfg.norm)
    logits = nn.linear(mod["lm_head"], x).astype(jnp.float32)
    if cfg.logit_softcap > 0:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits, new_cache


def lm_decode_step(params: Params, cfg: ModelConfig, cache: Params,
                   token: jnp.ndarray, pos: jnp.ndarray,
                   cross_kvs: Optional[Params] = None):
    """token: (B, 1) int32; pos: scalar int32 index of this token.

    Returns (logits (B, 1, V), new_cache).  Recomposed from the
    base/modular halves — bitwise identical to the pre-split fused form.
    """
    return composed_decode_step(
        params["base"], cfg, params["modular"], cfg, cache, token, pos,
        cross_kvs,
    )


def composed_decode_step(base: Params, base_cfg: ModelConfig,
                         mod: Params, mod_cfg: ModelConfig, cache: Params,
                         token: jnp.ndarray, pos: jnp.ndarray,
                         cross_kvs: Optional[Params] = None):
    """One decode step of a cross-arch composition f_m(f_b(.)): the base
    half runs under ``base_cfg``, the modular half under ``mod_cfg``.
    The cache is the merged dict from ``init_composed_cache`` (the two
    halves own disjoint keys)."""
    z, new_cache = base_decode_step(base, base_cfg, cache, token, pos,
                                    cross_kvs)
    logits, mod_cache = modular_decode_step(mod, mod_cfg, cache, z, pos)
    new_cache.update(mod_cache)
    return logits, new_cache


# =========================================================================
# Prefill: one jitted scan over the prompt through the cached decode path
# =========================================================================


def composed_prefill(base: Params, base_cfg: ModelConfig, mod: Params,
                     mod_cfg: ModelConfig, cache: Params,
                     tokens: jnp.ndarray,
                     cross_kvs: Optional[Params] = None, start: int = 0):
    """Batched cached prefill as a SINGLE call: a ``lax.scan`` over the
    prompt positions of the composed decode step, so the whole prompt is
    one jitted dispatch instead of O(P) separate ones — and the cache it
    leaves behind is bitwise the cache O(P) sequential decode steps
    would have written (scan iterations are the same program).

    tokens: (B, P) int32, positions ``start .. start+P-1``.
    Returns (logits of the last position (B, 1, V) fp32, cache).
    """
    B, P = tokens.shape
    start = jnp.int32(start)

    def body(carry, inp):
        cache, _ = carry
        t, tok = inp
        logits, cache = composed_decode_step(
            base, base_cfg, mod, mod_cfg, cache, tok[:, None],
            start + t, cross_kvs,
        )
        return (cache, logits), None

    logits0 = jnp.zeros((B, 1, mod_cfg.vocab_size), jnp.float32)
    (cache, logits), _ = jax.lax.scan(
        body, (cache, logits0),
        (jnp.arange(P, dtype=jnp.int32), tokens.T),
    )
    return logits, cache


def lm_prefill(params: Params, cfg: ModelConfig, cache: Params,
               tokens: jnp.ndarray, cross_kvs: Optional[Params] = None,
               start: int = 0):
    """Single-call batched cached prefill of one LM (see
    ``composed_prefill``)."""
    return composed_prefill(params["base"], cfg, params["modular"], cfg,
                            cache, tokens, cross_kvs, start)


def composed_prefill_ragged(base: Params, base_cfg: ModelConfig,
                            mod: Params, mod_cfg: ModelConfig,
                            cache: Params, tokens: jnp.ndarray,
                            length: jnp.ndarray):
    """Cached prefill of ONE row padded to a bucket length: a scan over
    all P padded positions where steps at ``t >= length`` are frozen —
    the computed cache/logits are discarded via ``jnp.where``, so the
    cache (and the last live position's logits) are bitwise what an
    unpadded ``composed_prefill`` of the first ``length`` tokens would
    have produced.  This is what makes prompt-length *buckets* exact:
    the serving plane vmaps this over a stacked admission batch, every
    row carrying its own true length, and a row's result depends only on
    its own (params, tokens, length) — pad rows and pad positions
    cannot perturb it.

    tokens: (P,) int32 (positions ``0..length-1`` real, rest pad);
    length: scalar int32.  Returns (last real position's logits (V,)
    fp32, cache).  The cache must be a fresh B=1 ``init_composed_cache``
    tree (frozen steps keep its untouched rows bitwise).
    """
    P = tokens.shape[0]

    def body(carry, inp):
        cache, last = carry
        t, tok = inp
        logits, new_cache = composed_decode_step(
            base, base_cfg, mod, mod_cfg, cache, tok.reshape(1, 1), t,
        )
        live = t < length
        cache = jax.tree.map(lambda o, n: jnp.where(live, n, o),
                             cache, new_cache)
        last = jnp.where(live, logits[0, -1], last)
        return (cache, last), None

    last0 = jnp.zeros((mod_cfg.vocab_size,), jnp.float32)
    (cache, last), _ = jax.lax.scan(
        body, (cache, last0),
        (jnp.arange(P, dtype=jnp.int32), tokens),
    )
    return last, cache
