"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE."""

from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies, shape (head_dim // 2,)."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def _rotate(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """Rotate pairs laid out as [x1 | x2] halves (HF 'neox' layout)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    inv = rope_freqs(x.shape[-1], theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    return _rotate(x, cos, sin)


def apply_mrope(
    x: jnp.ndarray,
    positions: jnp.ndarray,
    theta: float,
    sections: Tuple[int, ...],
) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE [arXiv:2409.12191].

    x: (B, S, H, hd); positions: (3, B, S) — (temporal, height, width)
    position ids. ``sections`` splits the hd/2 frequency bands among the
    three axes; text tokens carry identical ids on all three axes, making
    M-RoPE coincide with 1-D RoPE for pure text.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    inv = rope_freqs(x.shape[-1], theta)  # (half,)
    # (3, B, S, half) angles, then select the section owner per band.
    ang_all = positions[..., None].astype(jnp.float32) * inv
    owner = jnp.repeat(
        jnp.arange(len(sections)), jnp.array(sections), total_repeat_length=half
    )  # (half,) static
    ang = jnp.take_along_axis(
        jnp.moveaxis(ang_all, 0, -1), owner[None, None, :, None], axis=-1
    )[..., 0]  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    return _rotate(x, cos, sin)


def default_mrope_positions(batch: int, seq: int, num_image_tokens: int,
                            image_hw: Optional[Tuple[int, int]] = None) -> jnp.ndarray:
    """(3, B, S) position ids: a 2-D grid over the leading image tokens,
    then text ids continuing from the grid maximum (Qwen2-VL scheme)."""
    if num_image_tokens == 0:
        p = jnp.broadcast_to(jnp.arange(seq)[None], (batch, seq))
        return jnp.stack([p, p, p]).astype(jnp.int32)
    if image_hw is None:
        h = max(1, int(num_image_tokens**0.5))
        while num_image_tokens % h:
            h -= 1
        image_hw = (h, num_image_tokens // h)
    h, w = image_hw
    grid_h = jnp.repeat(jnp.arange(h), w)
    grid_w = jnp.tile(jnp.arange(w), h)
    t_img = jnp.zeros((num_image_tokens,), jnp.int32)
    start = max(h, w)
    n_text = seq - num_image_tokens
    text = start + jnp.arange(n_text)
    pos = jnp.stack(
        [
            jnp.concatenate([t_img, text]),
            jnp.concatenate([grid_h, text]),
            jnp.concatenate([grid_w, text]),
        ]
    ).astype(jnp.int32)
    return jnp.broadcast_to(pos[:, None, :], (3, batch, seq))
