"""Mamba selective-SSM mixer (as used by Jamba [arXiv:2403.19887]).

Prefill/train uses a *chunked* parallel scan: ``lax.scan`` over sequence
chunks carrying the SSM state, ``associative_scan`` inside each chunk.
A monolithic associative scan would materialize the full
``(B, S, d_inner, d_state)`` element tensor (~17 GB/device at jamba
prefill_32k); chunking caps it at the chunk length. Decode is the O(1)
recurrent step (state + conv ring buffer), which is what makes
``long_500k`` runnable for the hybrid archs.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import modules as nn

CHUNK = 256


def init_mamba(key, cfg: ModelConfig):
    d, di = cfg.d_model, cfg.ssm_d_inner
    ds, dtr = cfg.ssm_d_state, cfg.resolved_dt_rank
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A.
    a_init = jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))
    return {
        "in_proj": nn.init_linear(ks[0], d, 2 * di),
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_d_conv, di)) * 0.2,
        "conv_b": jnp.zeros((di,)),
        "x_proj": nn.init_linear(ks[2], di, dtr + 2 * ds),
        "dt_proj": nn.init_linear(ks[3], dtr, di, bias=True),
        "a_log": jnp.log(a_init),
        "d_skip": jnp.ones((di,)),
        "out_proj": nn.init_linear(ks[4], di, d),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B, S, di); w: (K, di)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(
        xp[:, k : k + x.shape[1], :] * w[k].astype(x.dtype) for k in range(K)
    )
    return out + b.astype(x.dtype)


def _ssm_params(p, cfg: ModelConfig, xc):
    """xc: (B, S, di) post-conv activations -> (dt, Bmat, Cmat)."""
    ds, dtr = cfg.ssm_d_state, cfg.resolved_dt_rank
    proj = nn.linear(p["x_proj"], xc)
    dt = jax.nn.softplus(nn.linear(p["dt_proj"], proj[..., :dtr]))  # (B,S,di)
    Bm = proj[..., dtr : dtr + ds]  # (B,S,ds)
    Cm = proj[..., dtr + ds :]  # (B,S,ds)
    return dt, Bm, Cm


def _scan_chunk(h0, a, bx, Cm):
    """Linear recurrence h_t = a_t * h_{t-1} + bx_t within one chunk,
    contracted against C *inside* the chunk so the (L, B, di, ds) state
    tensor never escapes (16x activation-memory reduction vs emitting
    states — jamba's train_4k temp went from ~1.6 TB/chip to the working
    set of one chunk; see EXPERIMENTS.md §Perf iteration 1).

    a, bx: (L, B, di, ds); h0: (B, di, ds); Cm: (L, B, ds).
    Returns (h_last, y) with y: (L, B, di).
    """

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    a_all, b_all = jax.lax.associative_scan(combine, (a, bx), axis=0)
    all_h = a_all * h0[None] + b_all
    y = jnp.einsum("lbdn,lbn->lbd", all_h, Cm)
    return all_h[-1], y


def mamba_forward(p, cfg: ModelConfig, x):
    """x: (B, S, d) -> (B, S, d). Full-sequence (train/prefill)."""
    B, S, _ = x.shape
    di, ds = cfg.ssm_d_inner, cfg.ssm_d_state
    xz = nn.linear(p["in_proj"], x)
    xm, z = xz[..., :di], xz[..., di:]
    xc = jax.nn.silu(_causal_conv(xm, p["conv_w"], p["conv_b"]))
    dt, Bm, Cm = _ssm_params(p, cfg, xc)

    a = jnp.exp(-dt[..., None] * jnp.exp(p["a_log"]).astype(dt.dtype))  # (B,S,di,ds)
    # bx: (B,S,di,ds) = (dt*x) (B,S,di,1) * B (B,S,1,ds)
    bx = (dt * xc)[..., None] * Bm[:, :, None, :]

    L = min(CHUNK, S)
    n_chunks = S // L
    assert n_chunks * L == S, f"seq {S} % chunk {L} != 0"
    ar = a.reshape(B, n_chunks, L, di, ds).transpose(1, 2, 0, 3, 4)
    br = bx.reshape(B, n_chunks, L, di, ds).transpose(1, 2, 0, 3, 4)
    cr = Cm.reshape(B, n_chunks, L, ds).transpose(1, 2, 0, 3)

    def body(h, inp):
        ac, bc, cc = inp
        return _scan_chunk(h, ac, bc, cc)

    h0 = jnp.zeros((B, di, ds), x.dtype)
    _, ys = jax.lax.scan(body, h0, (ar, br, cr))  # (n_chunks, L, B, di)
    y = ys.transpose(2, 0, 1, 3).reshape(B, S, di)
    y = y + xc * p["d_skip"].astype(y.dtype)
    y = y * jax.nn.silu(z)
    return nn.linear(p["out_proj"], y)


# ----------------------------------------------------------------- decode


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype):
    di = cfg.ssm_d_inner
    return {
        "ssm_h": jnp.zeros((batch, di, cfg.ssm_d_state), dtype),
        "ssm_conv": jnp.zeros((batch, cfg.ssm_d_conv - 1, di), dtype),
    }


def mamba_decode(p, cfg: ModelConfig, x, cache):
    """x: (B, 1, d). O(1) recurrent step."""
    B = x.shape[0]
    di = cfg.ssm_d_inner
    xz = nn.linear(p["in_proj"], x)
    xm, z = xz[..., :di], xz[..., di:]  # (B,1,di)
    window = jnp.concatenate([cache["ssm_conv"], xm], axis=1)  # (B, K, di)
    xc = jnp.sum(window * p["conv_w"].astype(x.dtype)[None], axis=1, keepdims=True)
    xc = jax.nn.silu(xc + p["conv_b"].astype(x.dtype))
    dt, Bm, Cm = _ssm_params(p, cfg, xc)

    a = jnp.exp(-dt[:, 0, :, None] * jnp.exp(p["a_log"]).astype(dt.dtype))
    bx = (dt[:, 0] * xc[:, 0])[..., None] * Bm[:, 0, None, :]
    h = a * cache["ssm_h"] + bx  # (B, di, ds)
    y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0])[:, None]
    y = y + xc * p["d_skip"].astype(y.dtype)
    y = y * jax.nn.silu(z)
    return nn.linear(p["out_proj"], y), {"ssm_h": h, "ssm_conv": window[:, 1:]}
