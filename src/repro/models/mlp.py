"""Channel mixers: gated (SwiGLU/GeGLU) MLP."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import modules as nn


def init_mlp(key, d_model: int, d_ff: int):
    ks = jax.random.split(key, 3)
    return {
        "w_gate": nn.init_linear(ks[0], d_model, d_ff),
        "w_up": nn.init_linear(ks[1], d_model, d_ff),
        "w_down": nn.init_linear(ks[2], d_ff, d_model),
    }


def mlp_forward(p, x, act: str = "silu"):
    a = nn.activation(act)
    return nn.linear(p["w_down"], a(nn.linear(p["w_gate"], x)) * nn.linear(p["w_up"], x))
