"""Attention: GQA (optionally windowed / NoPE / biased), MLA, cross-attn.

Full-sequence attention is *blocked*: a ``lax.scan`` over query blocks with
an fp32 online numerically-safe softmax per block. This keeps the largest
live buffer at ``(B, KVH, G, q_block, kv_len)`` instead of materializing
``(B, H, S, S)`` — mandatory for the 32k prefill shapes. Sliding-window
layers additionally ``dynamic_slice`` the KV sequence to ``window +
q_block`` per query block, so their HLO FLOPs are linear in sequence
length, not quadratic (this is what makes gemma3/llama4 long-context
shapes lowerable).

Decode uses ring-buffer KV caches for windowed layers (O(window) memory)
and flat caches for global layers; MLA decode uses the absorbed-latent
form so the cache is the compressed ``(kv_lora + rope)`` stream.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import LayerSpec, ModelConfig
from repro.models import modules as nn
from repro.models.rope import apply_mrope, apply_rope

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


# =========================================================================
# Parameter init
# =========================================================================


def init_attn(key, cfg: ModelConfig, spec: LayerSpec):
    if cfg.use_mla:
        return _init_mla(key, cfg)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kvh = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 6)
    p = {
        "wq": nn.init_linear(ks[0], d, h * hd, bias=cfg.qkv_bias),
        "wk": nn.init_linear(ks[1], d, kvh * hd, bias=cfg.qkv_bias),
        "wv": nn.init_linear(ks[2], d, kvh * hd, bias=cfg.qkv_bias),
        "wo": nn.init_linear(ks[3], h * hd, d),
    }
    if getattr(cfg, "use_qk_norm", False):
        p["q_norm"] = nn.init_norm(ks[4], hd, "rmsnorm")
        p["k_norm"] = nn.init_norm(ks[5], hd, "rmsnorm")
    return p


def init_cross_attn(key, cfg: ModelConfig):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h = cfg.num_heads
    ks = jax.random.split(key, 4)
    return {
        "wq": nn.init_linear(ks[0], d, h * hd),
        "wk": nn.init_linear(ks[1], d, h * hd),
        "wv": nn.init_linear(ks[2], d, h * hd),
        "wo": nn.init_linear(ks[3], h * hd, d),
    }


def _init_mla(key, cfg: ModelConfig):
    d = cfg.d_model
    h = cfg.num_heads
    qk = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    ks = jax.random.split(key, 8)
    p = {}
    if cfg.q_lora_rank > 0:
        p["wq_a"] = nn.init_linear(ks[0], d, cfg.q_lora_rank)
        p["q_a_norm"] = nn.init_norm(ks[1], cfg.q_lora_rank, "rmsnorm")
        p["wq_b"] = nn.init_linear(ks[2], cfg.q_lora_rank, h * qk)
    else:
        p["wq"] = nn.init_linear(ks[2], d, h * qk)
    p["wkv_a"] = nn.init_linear(ks[3], d, cfg.kv_lora_rank + cfg.qk_rope_head_dim)
    p["kv_a_norm"] = nn.init_norm(ks[4], cfg.kv_lora_rank, "rmsnorm")
    p["wkv_b"] = nn.init_linear(
        ks[5], cfg.kv_lora_rank, h * (cfg.qk_nope_head_dim + cfg.v_head_dim)
    )
    p["wo"] = nn.init_linear(ks[6], h * cfg.v_head_dim, d)
    return p


# =========================================================================
# Blocked causal attention core
# =========================================================================


def _gqa_block(q, k, v, q_idx, k_idx, *, window: int, scale: float):
    """One query block vs a KV span, fp32 softmax.

    q: (B, qb, KVH, G, hd)   k, v: (B, L, KVH, hd)
    q_idx: (qb,) global token indices of the query rows
    k_idx: (L,) global token indices of the KV rows
    """
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, k).astype(jnp.float32) * scale
    mask = k_idx[None, :] <= q_idx[:, None]
    if window > 0:
        mask &= k_idx[None, :] > q_idx[:, None] - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - jax.lax.stop_gradient(m))
    p = p / (jnp.sum(p, axis=-1, keepdims=True) + 1e-30)
    return jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v)


def _pallas_eligible(q, k, v, scale) -> bool:
    """Dispatch to the Pallas flash kernel on TPU when tiles align
    (256-divisible seq, MXU-friendly head dim, default scaling, matching
    q/k/v head dims). CPU keeps the pure-jnp path the tests oracle."""
    B, S, H, hd = q.shape
    return (
        jax.default_backend() == "tpu"
        and scale is None
        and S % 256 == 0
        and hd in (64, 128, 256)
        and v.shape[-1] == hd
    )


def blocked_attention(
    q: jnp.ndarray,  # (B, S, H, hd)
    k: jnp.ndarray,  # (B, S, KVH, hd)
    v: jnp.ndarray,  # (B, S, KVH, hd)
    *,
    window: int = -1,
    q_block: int = 512,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Causal (optionally sliding-window) attention over a full sequence."""
    B, S, H, hd = q.shape
    kvh = k.shape[2]
    g = H // kvh
    if _pallas_eligible(q, k, v, scale):
        from repro.kernels import ops as kops

        out = kops.flash_attention(
            q.transpose(0, 2, 1, 3),
            k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3),
            causal=True,
            window=window,
        )
        return out.transpose(0, 2, 1, 3)
    scale = scale if scale is not None else 1.0 / math.sqrt(q.shape[-1])
    qb = min(q_block, S)
    n_blocks = S // qb
    assert n_blocks * qb == S, f"seq {S} not divisible by q_block {qb}"
    qr = q.reshape(B, n_blocks, qb, kvh, g, hd).transpose(1, 0, 2, 3, 4, 5)

    if window > 0:
        L = min(S, window + qb)

        def body(_, inp):
            qi, blk = inp
            q_start = qi * qb
            start = jnp.clip(q_start + qb - L, 0, S - L)
            ks_ = jax.lax.dynamic_slice_in_dim(k, start, L, axis=1)
            vs_ = jax.lax.dynamic_slice_in_dim(v, start, L, axis=1)
            q_idx = q_start + jnp.arange(qb)
            k_idx = start + jnp.arange(L)
            o = _gqa_block(blk, ks_, vs_, q_idx, k_idx, window=window, scale=scale)
            return None, o

        _, out = jax.lax.scan(body, None, (jnp.arange(n_blocks), qr))
    else:

        def body(_, inp):
            qi, blk = inp
            q_idx = qi * qb + jnp.arange(qb)
            k_idx = jnp.arange(S)
            o = _gqa_block(blk, k, v, q_idx, k_idx, window=-1, scale=scale)
            return None, o

        _, out = jax.lax.scan(body, None, (jnp.arange(n_blocks), qr))

    # v may carry a different head dim than q/k (MLA), hence the -1.
    return out.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, H, -1)


# =========================================================================
# GQA self-attention (train / prefill)
# =========================================================================


def _project_qkv(p, cfg: ModelConfig, spec: LayerSpec, x, positions):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = nn.linear(p["wq"], x).reshape(B, S, cfg.num_heads, hd)
    k = nn.linear(p["wk"], x).reshape(B, S, cfg.num_kv_heads, hd)
    v = nn.linear(p["wv"], x).reshape(B, S, cfg.num_kv_heads, hd)
    if "q_norm" in p:
        q = nn.apply_norm(p["q_norm"], q, "rmsnorm")
        k = nn.apply_norm(p["k_norm"], k, "rmsnorm")
    if spec.use_rope and cfg.rope_type != "none":
        if cfg.rope_type == "mrope":
            q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
            k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_forward(p, cfg: ModelConfig, spec: LayerSpec, x, positions):
    """Full-sequence causal self-attention. x: (B, S, d)."""
    if cfg.use_mla:
        return _mla_forward(p, cfg, x, positions)
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, cfg, spec, x, positions)
    out = blocked_attention(
        q, k, v, window=spec.window, q_block=cfg.q_block
    )
    return nn.linear(p["wo"], out.reshape(B, S, -1))


# =========================================================================
# Decode (single token, KV cache)
# =========================================================================


def init_attn_cache(cfg: ModelConfig, spec: LayerSpec, batch: int,
                    cache_len: int, dtype) -> dict:
    """Zeroed cache. Windowed layers get a ring buffer of len window."""
    if cfg.use_mla:
        return {
            "ckv": jnp.zeros((batch, cache_len, cfg.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, cache_len, cfg.qk_rope_head_dim), dtype),
        }
    L = min(cache_len, spec.window) if spec.window > 0 else cache_len
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, L, cfg.num_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, L, cfg.num_kv_heads, hd), dtype),
        "slot_pos": jnp.full((L,), -1, jnp.int32),
    }


def attn_decode(p, cfg: ModelConfig, spec: LayerSpec, x, cache, pos,
                positions=None):
    """x: (B, 1, d); pos: scalar int32 current index. Returns (y, cache)."""
    if cfg.use_mla:
        return _mla_decode(p, cfg, x, cache, pos)
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    if positions is None:
        positions = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)
    q, k, v = _project_qkv(p, cfg, spec, x, positions)
    L = cache["k"].shape[1]
    slot = jnp.where(spec.window > 0, pos % L, jnp.minimum(pos, L - 1))
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    spos = cache["slot_pos"].at[slot].set(pos)
    g = cfg.num_heads // cfg.num_kv_heads
    qh = q.reshape(B, 1, cfg.num_kv_heads, g, hd)
    valid = (spos >= 0) & (spos <= pos)
    if spec.window > 0:
        valid &= spos > pos - spec.window
    # The score/softmax/value contraction dispatches through the kernel
    # layer: Pallas flash-decode on TPU when tiles align, the jnp oracle
    # (the historical in-line math, bit-for-bit) everywhere else.
    from repro.kernels import ops as kops

    o = kops.cached_attn_decode(
        qh, ck, cv, jnp.broadcast_to(valid[None], (B, L))
    )
    y = nn.linear(p["wo"], o.reshape(B, 1, -1))
    return y, {"k": ck, "v": cv, "slot_pos": spos}


# =========================================================================
# MLA (DeepSeek-V3) [arXiv:2412.19437]
# =========================================================================


def _mla_q(p, cfg: ModelConfig, x):
    B, S, _ = x.shape
    qk = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    if "wq_a" in p:
        ql = nn.apply_norm(p["q_a_norm"], nn.linear(p["wq_a"], x), "rmsnorm")
        q = nn.linear(p["wq_b"], ql)
    else:
        q = nn.linear(p["wq"], x)
    q = q.reshape(B, S, cfg.num_heads, qk)
    return q[..., : cfg.qk_nope_head_dim], q[..., cfg.qk_nope_head_dim :]


def _mla_latent(p, cfg: ModelConfig, x, positions):
    kv = nn.linear(p["wkv_a"], x)
    ckv = nn.apply_norm(p["kv_a_norm"], kv[..., : cfg.kv_lora_rank], "rmsnorm")
    krope = kv[..., cfg.kv_lora_rank :][:, :, None, :]  # (B,S,1,rope_dim)
    krope = apply_rope(krope, positions, cfg.rope_theta)[:, :, 0]
    return ckv.astype(x.dtype), krope.astype(x.dtype)


def _mla_forward(p, cfg: ModelConfig, x, positions):
    """Expanded (non-absorbed) MLA for train/prefill."""
    B, S, _ = x.shape
    h = cfg.num_heads
    qn, qr = _mla_q(p, cfg, x)
    qr = apply_rope(qr, positions, cfg.rope_theta)
    ckv, krope = _mla_latent(p, cfg, x, positions)
    kvb = nn.linear(p["wkv_b"], ckv).reshape(
        B, S, h, cfg.qk_nope_head_dim + cfg.v_head_dim
    )
    kn = kvb[..., : cfg.qk_nope_head_dim]
    v = kvb[..., cfg.qk_nope_head_dim :]
    q = jnp.concatenate([qn, qr], axis=-1)
    k = jnp.concatenate(
        [kn, jnp.broadcast_to(krope[:, :, None], (B, S, h, cfg.qk_rope_head_dim))],
        axis=-1,
    )
    scale = 1.0 / math.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
    # Pad v to match q/k head_dim is unnecessary: blocked_attention only
    # assumes hd consistency between q and k; v carries its own dim.
    out = blocked_attention(q, k, v, q_block=cfg.q_block, scale=scale)
    return nn.linear(p["wo"], out.reshape(B, S, -1))


def _mla_decode(p, cfg: ModelConfig, x, cache, pos):
    """Absorbed MLA decode: scores and values live in the latent space, so
    per-token cost is O(S * (kv_lora + rope)) and the cache stays compressed.
    """
    B = x.shape[0]
    h = cfg.num_heads
    positions = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)
    qn, qr = _mla_q(p, cfg, x)  # (B,1,h,nope), (B,1,h,rope)
    qr = apply_rope(qr, positions, cfg.rope_theta)
    ckv_t, krope_t = _mla_latent(p, cfg, x, positions)  # (B,1,lora),(B,1,rope)
    ckv = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv_t, pos, axis=1)
    krope = jax.lax.dynamic_update_slice_in_dim(cache["krope"], krope_t, pos, axis=1)

    wkv_b = p["wkv_b"]["w"].reshape(
        cfg.kv_lora_rank, h, cfg.qk_nope_head_dim + cfg.v_head_dim
    ).astype(x.dtype)
    w_uk = wkv_b[..., : cfg.qk_nope_head_dim]  # (lora, h, nope)
    w_uv = wkv_b[..., cfg.qk_nope_head_dim :]  # (lora, h, v)

    q_lat = jnp.einsum("bqhn,lhn->bqhl", qn, w_uk)  # absorb k up-proj
    s = jnp.einsum("bqhl,bsl->bhqs", q_lat, ckv)
    s = s + jnp.einsum("bqhr,bsr->bhqs", qr, krope)
    scale = 1.0 / math.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
    s = s.astype(jnp.float32) * scale
    S_cache = ckv.shape[1]
    valid = jnp.arange(S_cache) <= pos
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1).astype(ckv.dtype)
    o_lat = jnp.einsum("bhqs,bsl->bqhl", pr, ckv)
    o = jnp.einsum("bqhl,lhv->bqhv", o_lat, w_uv)  # absorb v up-proj
    y = nn.linear(p["wo"], o.reshape(B, 1, -1))
    return y, {"ckv": ckv, "krope": krope}


# =========================================================================
# Cross-attention (enc-dec)
# =========================================================================


def bidir_blocked_attention(q, k, v, *, q_block: int = 512):
    """Unmasked attention, q-block scanned so (S_q, S_kv) scores never
    materialize for the full sequence (encoder self-attn / cross-attn at
    prefill lengths; see EXPERIMENTS.md §Perf iteration 2)."""
    B, S, H, hd = q.shape
    kvh = k.shape[2]
    g = H // kvh
    scale = 1.0 / math.sqrt(hd)
    qb = min(q_block, S)
    n_blocks = max(S // qb, 1)
    if n_blocks * qb != S:  # ragged: fall back to single block
        qb, n_blocks = S, 1
    qr = q.reshape(B, n_blocks, qb, kvh, g, hd).transpose(1, 0, 2, 3, 4, 5)

    def body(_, blk):
        s = jnp.einsum("bqkgh,bskh->bkgqs", blk, k).astype(jnp.float32)
        s = s * scale
        m = jnp.max(s, axis=-1, keepdims=True)
        pr = jnp.exp(s - jax.lax.stop_gradient(m))
        pr = pr / (jnp.sum(pr, axis=-1, keepdims=True) + 1e-30)
        return None, jnp.einsum("bkgqs,bskh->bqkgh", pr.astype(v.dtype), v)

    _, out = jax.lax.scan(body, None, qr)
    return out.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, H, -1)


def cross_attn_forward(p, cfg: ModelConfig, x, enc_out):
    """x: (B, S_dec, d); enc_out: (B, S_enc, d). Bidirectional over enc."""
    B, S, _ = x.shape
    Se = enc_out.shape[1]
    hd = cfg.resolved_head_dim
    h = cfg.num_heads
    q = nn.linear(p["wq"], x).reshape(B, S, h, hd)
    k = nn.linear(p["wk"], enc_out).reshape(B, Se, h, hd)
    v = nn.linear(p["wv"], enc_out).reshape(B, Se, h, hd)
    o = bidir_blocked_attention(q, k, v, q_block=cfg.q_block)
    return nn.linear(p["wo"], o.reshape(B, S, -1))


def cross_attn_cache(p, cfg: ModelConfig, enc_out):
    """Precompute encoder K/V once for decoding."""
    B, Se, _ = enc_out.shape
    hd, h = cfg.resolved_head_dim, cfg.num_heads
    return {
        "k": nn.linear(p["wk"], enc_out).reshape(B, Se, h, hd),
        "v": nn.linear(p["wv"], enc_out).reshape(B, Se, h, hd),
    }


def cross_attn_decode(p, cfg: ModelConfig, x, ccache):
    B = x.shape[0]
    hd, h = cfg.resolved_head_dim, cfg.num_heads
    q = nn.linear(p["wq"], x).reshape(B, 1, h, hd)
    s = jnp.einsum("bqhd,bshd->bhqs", q, ccache["k"]).astype(jnp.float32)
    s = s / math.sqrt(hd)
    pr = jax.nn.softmax(s, axis=-1).astype(ccache["v"].dtype)
    o = jnp.einsum("bhqs,bshd->bqhd", pr, ccache["v"])
    return nn.linear(p["wo"], o.reshape(B, 1, -1))
