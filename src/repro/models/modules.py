"""Basic parameterized layers as (init, apply) pure-function pairs.

Params are plain nested dicts of jnp arrays — trivially pytree-able,
shardable leaf-by-leaf, and sliceable along stacked leading dims (which the
IFL base/modular partition exploits).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def dtype_of(name: str):
    return jnp.dtype(name)


# ----------------------------------------------------------------- linear


def init_linear(key, d_in: int, d_out: int, *, bias: bool = False,
                dtype=jnp.float32, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x, *, compute_dtype=None):
    """Weights are cast to the activation (or compute) dtype: params may
    be fp32 masters while activations flow in bf16."""
    dt = compute_dtype or x.dtype
    y = x.astype(dt) @ p["w"].astype(dt)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# ----------------------------------------------------------------- embedding


def init_embedding(key, vocab: int, d_model: int, *, dtype=jnp.float32):
    return {"table": (jax.random.normal(key, (vocab, d_model)) * 0.02).astype(dtype)}


def embedding(p, ids, *, compute_dtype=None):
    t = p["table"]
    if compute_dtype is not None:
        t = t.astype(compute_dtype)
    return jnp.take(t, ids, axis=0)


def embedding_logits(p, x):
    """Tied-embedding readout."""
    return x @ p["table"].astype(x.dtype).T


# ----------------------------------------------------------------- norms


def init_norm(key, d: int, kind: str, *, dtype=jnp.float32):
    del key
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    if kind == "nonparam_ln":  # OLMo: LN without learnable affine
        return {}
    raise ValueError(kind)


def apply_norm(p, x, kind: str, *, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        y = y * p["scale"].astype(jnp.float32)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        if kind == "layernorm":
            y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ----------------------------------------------------------------- acts


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# ----------------------------------------------------------------- stacking


def stack_init(init_fn, key, n: int):
    """Initialize ``n`` copies of a module with a stacked leading dim."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def tree_slice(tree, start: int, stop: int):
    """Static slice along the stacked leading dim of every leaf."""
    return jax.tree.map(lambda a: a[start:stop], tree)


def tree_concat(trees, axis: int = 0):
    return jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=axis), *trees)


def param_count(tree) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(tree))


def param_bytes(tree) -> int:
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_cast(tree, dtype):
    return jax.tree.map(lambda a: a.astype(dtype), tree)
