"""Training launcher.

CPU-runnable end-to-end driver for IFL (and the DP baseline) on any
assigned architecture:

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
      --mode ifl --rounds 30 --tau 4 --batch 4 --seq 128

``--reduced`` uses the smoke-scale family variant; full configs are for
real hardware (exercised here only via the dry-run).
"""

from __future__ import annotations

import argparse
import json
import os

from repro.configs import ARCH_IDS, get_config
from repro.checkpoint import save_checkpoint
from repro.train.loop import train_dp_lm, train_ifl_lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="olmo-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mode", choices=["ifl", "dp"], default="ifl")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--tau", type=int, default=4)
    ap.add_argument("--n-clients", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="results/train")
    ap.add_argument("--save-ckpt", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    print(f"== {args.mode} training: {cfg.name} "
          f"({cfg.num_layers}L d={cfg.d_model}) ==")

    if args.mode == "ifl":
        out = train_ifl_lm(
            cfg, rounds=args.rounds, n_clients=args.n_clients,
            tau=args.tau, batch=args.batch, seq=args.seq,
            lr_base=args.lr, lr_modular=args.lr, seed=args.seed,
        )
    else:
        out = train_dp_lm(
            cfg, steps=args.rounds, batch=args.batch, seq=args.seq,
            lr=args.lr, seed=args.seed,
        )

    os.makedirs(args.out, exist_ok=True)
    tag = f"{cfg.name}__{args.mode}"
    with open(os.path.join(args.out, tag + ".json"), "w") as f:
        json.dump(out["history"], f, indent=1)
    if args.save_ckpt:
        save_checkpoint(os.path.join(args.out, tag + "_ckpt"),
                        out["params"], step=args.rounds)
    first, last = out["history"][0], out["history"][-1]
    key = "base_loss" if args.mode == "ifl" else "loss"
    print(f"loss {first[key]:.4f} -> {last[key]:.4f} "
          f"over {len(out['history'])} rounds")


if __name__ == "__main__":
    main()
