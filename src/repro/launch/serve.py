"""Serving launcher: batched greedy decoding with a KV/state cache.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
      --reduced --batch 4 --prompt-len 32 --gen 32

Prefill is executed through the same cached decode path the dry-run
lowers for decode_32k/long_500k (token-at-a-time), so serving semantics
match serve_step exactly; for the modular-composition serving demo see
examples/compose_inference.py.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.configs import ARCH_IDS, get_config
from repro.data.synthetic import SyntheticLM
from repro.models.transformer import (
    build_cross_caches,
    encoder_forward,
    init_decode_cache,
    init_lm,
    lm_decode_step,
)


def generate(params, cfg: ModelConfig, prompts: jnp.ndarray, gen: int,
             cross_kvs=None, greedy: bool = True, seed: int = 0):
    """prompts: (B, P) int32 -> (B, P + gen) tokens."""
    B, P = prompts.shape
    cache = init_decode_cache(cfg, B, P + gen)
    step = jax.jit(
        lambda pr, c, t, pos: lm_decode_step(pr, cfg, c, t, pos, cross_kvs)
    )
    toks = [prompts[:, i : i + 1] for i in range(P)]
    logits = None
    for i in range(P):  # prefill via the cached decode path
        logits, cache = step(params, cache, toks[i], jnp.int32(i))
    out = list(toks)
    key = jax.random.PRNGKey(seed)
    for g in range(gen):
        if greedy:
            nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        else:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, logits[:, -1])[:, None]
        out.append(nxt)
        logits, cache = step(params, cache, nxt, jnp.int32(P + g))
    return jnp.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    print(f"== serving {cfg.name}: batch={args.batch} "
          f"prompt={args.prompt_len} gen={args.gen} ==")
    params = init_lm(jax.random.PRNGKey(args.seed), cfg)

    cross_kvs = None
    if cfg.is_encdec:
        frames = jnp.asarray(np.random.default_rng(0).normal(
            size=(args.batch, cfg.enc_seq_len, cfg.d_model)
        ).astype(np.float32))
        enc_out = encoder_forward(params["base"]["encoder"], cfg, frames)
        cross_kvs = build_cross_caches(params, cfg, enc_out)

    stream = SyntheticLM(cfg.vocab_size, seed=args.seed)
    prompts = jnp.asarray(stream.sample(args.batch, args.prompt_len, step=0))

    t0 = time.time()
    out = generate(params, cfg, prompts, args.gen, cross_kvs)
    dt = time.time() - t0
    total_new = args.batch * args.gen
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s incl. prefill+compile)")
    print("sample continuation:", np.asarray(out[0, args.prompt_len:])[:16])


if __name__ == "__main__":
    main()
