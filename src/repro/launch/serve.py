"""Serving launcher: the multi-tenant continuous-batching engine CLI.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
      --reduced --tenants 4 --prompt-len 32 --gen 32

Decoder-only archs route through ``repro.serve.ServeEngine``: one
personalized base block per tenant + the shared modular block, per-arch
batch lanes, admit-on-slot-free. Enc-dec archs (cross-attention needs
per-request encoder K/V plumbing the lane model does not carry yet)
fall back to the fixed-batch ``generate`` path below, whose prefill is
now ONE jitted ``lm_prefill`` scan instead of O(prompt_len) dispatches.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.configs import ARCH_IDS, get_config
from repro.data.synthetic import SyntheticLM
from repro.models.transformer import (
    build_cross_caches,
    encoder_forward,
    init_decode_cache,
    init_lm,
    lm_decode_step,
    lm_prefill,
)


def generate(params, cfg: ModelConfig, prompts: jnp.ndarray, gen: int,
             cross_kvs=None, greedy: bool = True, seed: int = 0):
    """prompts: (B, P) int32 -> (B, P + gen) tokens.

    Prefill is a single batched cached-prefill call (``lm_prefill``:
    one jitted scan over the prompt) — bitwise the same cache and
    logits the old token-at-a-time loop produced, in one dispatch.
    """
    B, P = prompts.shape
    cache = init_decode_cache(cfg, B, P + gen)
    step = jax.jit(
        lambda pr, c, t, pos: lm_decode_step(pr, cfg, c, t, pos, cross_kvs)
    )
    prefill = jax.jit(
        lambda pr, c, toks: lm_prefill(pr, cfg, c, toks, cross_kvs)
    )
    logits, cache = prefill(params, cache, prompts)
    out = [prompts]
    key = jax.random.PRNGKey(seed)
    for g in range(gen):
        if greedy:
            nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        else:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, logits[:, -1])[:, None]
        out.append(nxt)
        logits, cache = step(params, cache, nxt, jnp.int32(P + g))
    return jnp.concatenate(out, axis=1)


def _serve_encdec(cfg: ModelConfig, args) -> None:
    """Legacy fixed-batch path for enc-dec archs."""
    params = init_lm(jax.random.PRNGKey(args.seed), cfg)
    frames = jnp.asarray(np.random.default_rng(0).normal(
        size=(args.tenants, cfg.enc_seq_len, cfg.d_model)
    ).astype(np.float32))
    enc_out = encoder_forward(params["base"]["encoder"], cfg, frames)
    cross_kvs = build_cross_caches(params, cfg, enc_out)
    stream = SyntheticLM(cfg.vocab_size, seed=args.seed)
    prompts = jnp.asarray(
        stream.sample(args.tenants, args.prompt_len, step=0))
    t0 = time.time()
    out = generate(params, cfg, prompts, args.gen, cross_kvs)
    dt = time.time() - t0
    total_new = args.tenants * args.gen
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s incl. prefill+compile)")
    print("sample continuation:", np.asarray(out[0, args.prompt_len:])[:16])


def build_demo_store(cfg: ModelConfig, arch: str, n_tenants: int,
                     seed: int = 0):
    """A CompositionStore of ``n_tenants`` per-tenant base blocks (each
    a different init — the stand-in for per-client personalization)
    sharing tenant 0's modular block."""
    from repro.serve import CompositionStore

    store = CompositionStore()
    if arch in ARCH_IDS:
        name = store.add_arch(arch, reduced=True, d_fusion=cfg.d_fusion)
    else:
        name = store.add_arch(cfg)
    key = jax.random.PRNGKey(seed)
    for k in range(n_tenants):
        params = init_lm(jax.random.fold_in(key, k), cfg)
        if k == 0:
            store.set_modular(name, params["modular"])
        store.add_tenant(f"tenant{k}", name, params["base"])
    return store


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--tenants", type=int, default=4,
                    help="concurrent tenants (= demo requests)")
    ap.add_argument("--width", type=int, default=4, help="lane width")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--stagger", type=int, default=2,
                    help="ticks between consecutive request arrivals")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--horizon", default="1",
                    help="fused decode ticks per engine step, or 'auto' "
                         "to read the serve-plan autotuner cache")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy argmax; >0 samples at this "
                         "temperature inside the jitted lane step")
    ap.add_argument("--top-k", type=int, default=0,
                    help="restrict sampling to the k largest logits "
                         "(0 = full vocab; needs --temperature > 0)")
    args = ap.parse_args()
    args.horizon = args.horizon if args.horizon == "auto" \
        else int(args.horizon)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    print(f"== serving {cfg.name}: tenants={args.tenants} "
          f"prompt={args.prompt_len} gen={args.gen} ==")
    if cfg.is_encdec:
        print("(enc-dec arch: fixed-batch fallback path)")
        _serve_encdec(cfg, args)
        return

    from repro.serve import Request, ServeEngine

    store = build_demo_store(cfg, args.arch, args.tenants, args.seed)
    engine = ServeEngine(store, width=args.width,
                         cache_len=args.prompt_len + args.gen,
                         horizon=args.horizon)
    stream = SyntheticLM(cfg.vocab_size, seed=args.seed)
    prompts = stream.sample(args.tenants, args.prompt_len, step=0)
    reqs = [
        Request(rid=i, tenant=f"tenant{i}",
                prompt=[int(t) for t in prompts[i]],
                max_new_tokens=args.gen, arrival=i * args.stagger,
                temperature=args.temperature, top_k=args.top_k,
                seed=args.seed)
        for i in range(args.tenants)
    ]
    t0 = time.time()
    comps = engine.run(reqs)
    dt = time.time() - t0
    total_new = sum(len(c.tokens) for c in comps)
    print(f"served {len(comps)} requests / {total_new} new tokens in "
          f"{dt:.2f}s over {engine.tick} ticks "
          f"({total_new / dt:.1f} tok/s incl. prefill+compile)")
    for c in comps[: min(3, len(comps))]:
        print(f"  {c.tenant}: admitted@t{c.admitted_tick} "
              f"finished@t{c.finished_tick} {c.tokens[:12]}")


if __name__ == "__main__":
    main()
