"""Production meshes.

Functions, not module-level constants: importing this module never
touches jax device state (jax locks the device count on first backend
init, and only dryrun.py is allowed to force 512 host devices).
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def derive_ifl_mesh(mesh: Mesh, n_clients: int) -> Mesh:
    """Reshape a production mesh into ('client', 'data', 'model').

    Clients tile the (pod ×) data axes contiguously, so in the multi-pod
    mesh a client never straddles a pod *unless* n_clients < n_pods; with
    n_clients a multiple of n_pods (default 4 clients / 2 pods), the only
    inter-pod collective left in an IFL round is the fusion all-gather —
    the paper's communication-efficiency claim restated for ICI/DCN.
    """
    devs = mesh.devices
    model = devs.shape[-1]
    flat = devs.reshape(-1, model)  # (pod*data, model), pod-major
    total_dp = flat.shape[0]
    assert total_dp % n_clients == 0, (total_dp, n_clients)
    grid = flat.reshape(n_clients, total_dp // n_clients, model)
    return Mesh(grid, ("client", "data", "model"))


def data_axes_of(mesh: Mesh):
    """The axes a plain (non-IFL) step shards its batch over."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
