import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first
# backend init, and the production meshes below need 512 host stand-ins.
# Only this entrypoint gets them — tests/benches see the real 1 device.

"""Multi-pod dry-run (deliverable e).

For every (arch × input-shape × mesh) combination:
  jit(step).lower(*ShapeDtypeStructs).compile()
on the single-pod (16, 16) and multi-pod (2, 16, 16) production meshes,
recording memory_analysis / cost_analysis / per-collective link bytes
into results/dryrun/*.json — the §Dry-run and §Roofline tables are
generated from these files.

Step kinds per shape:
  train_4k     -> ifl_round_step (the paper's technique; --step dp for the
                  FL-equivalent dense baseline comparison)
  prefill_32k  -> prefill_step
  decode_32k / long_500k -> serve_step (1 token vs seq_len cache)

The IFL rows also carry a ``client_boundary`` section: the analytic
per-round bytes crossing the client boundary under the configured
``--codec`` / ``--participation`` / ``--broadcast`` regime
(``comm.ifl_round_bytes`` — the same formula the trainers' ledgers are
pinned to), so 256/512-chip reports reflect the cached-payload and
delta-downlink reality, not just the full-participation fp32 collective.
``--participation`` other than ``full`` lowers the
partial-participation round step (mask + carried payload cache as
inputs), i.e. the HLO being costed IS the masked cached-payload
program.

Usage:
  python -m repro.launch.dryrun --arch qwen1.5-0.5b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--step ifl|dp]
  python -m repro.launch.dryrun --arch olmo-1b --shape train_4k \
      --codec int8_row --participation k2 --broadcast delta
"""

import argparse
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import INPUT_SHAPES, ModelConfig
from repro.configs import ARCH_IDS, get_config, supports_shape
from repro.configs.shapes import (
    decode_specs,
    param_specs,
    prefill_batch_specs,
    train_batch_specs,
)
from repro.core.codec import get_codec
from repro.core.comm import ifl_round_bytes
from repro.core.ifl_spmd import (
    init_ef_state,
    init_payload_cache,
    make_dp_train_step,
    make_ifl_round_step,
    make_prefill_step,
    make_serve_step,
)
from repro.core.rounds import (
    FullParticipation,
    expected_async_participants,
    expected_cohort_participants,
    parse_participation,
    parse_trace,
)
from repro.launch.mesh import data_axes_of, derive_ifl_mesh, make_production_mesh
from repro.roofline.analysis import (
    collective_bytes_from_hlo,
    model_flops,
    roofline_terms,
)
from repro.roofline.hlo_accounting import analyze_hlo
from repro.sharding.rules import (
    batch_pspec,
    cache_pspecs,
    param_pspecs,
    tree_shardings,
)

FSDP_THRESHOLD = 20e9  # params above this get ZeRO-3-style 'data' sharding


def _params_count(tree) -> float:
    import numpy as np

    return float(sum(np.prod(l.shape) for l in jax.tree.leaves(tree)))


def _block_params(cfg: ModelConfig):
    p = param_specs(cfg)
    return _params_count(p["base"]), _params_count(p["modular"])


def _active_params(cfg: ModelConfig, p_base: float, p_mod: float):
    """MoE: count only top-k + shared experts as active."""
    if not cfg.num_experts:
        return p_base, p_mod
    specs = cfg.layer_specs()
    dff = cfg.moe_d_ff or cfg.d_ff
    per_expert = 3 * cfg.d_model * dff
    cut = cfg.fusion_cut_layer
    dead_b = dead_m = 0.0
    active_frac = (cfg.num_experts_per_tok / cfg.num_experts)
    for i, s in enumerate(specs):
        if s.ffn == "moe":
            dead = cfg.num_experts * per_expert * (1 - active_frac)
            if i < cut:
                dead_b += dead
            else:
                dead_m += dead
    return p_base - dead_b, p_mod - dead_m


def _expected_async_delta_entries(trace: str, n_clients: int, tick: float,
                                  *, ticks: int = 256,
                                  seed: int = 0) -> float:
    """Mean delta-broadcast entries per server tick under ``trace``.

    The async analogue of ``expected_delta_entries``: replay the
    coalesced per-tick participant stream through a real
    ``SPMDFusionExchange.account_round`` so the dry-run prices rejoin
    catch-up shipping with the trainers' exact mirror bookkeeping.
    """
    import numpy as np

    from repro.core.exchange import SPMDFusionExchange

    rng = np.random.default_rng(seed)
    cursor = parse_trace(trace, n_clients).cursor(n_clients, rng)
    plane = SPMDFusionExchange(None, None, n_clients=n_clients,
                               broadcast="delta")
    total = 0
    for t in range(ticks):
        events = cursor.pop_until((t + 1) * tick, rng)
        parts = sorted({slot for _, slot in events})
        total += plane.account_round(parts, t, entry_bytes=0)[1]
    return total / max(ticks, 1)


def client_boundary_section(cfg: ModelConfig, shape, *, n_clients: int,
                            schedule, codec: str, broadcast: str,
                            mode: str, trace: str, tick: float,
                            n_population: int = 0, cohort: int = 0,
                            fused=None):
    """The analytic per-round client-boundary bytes — the exact formula
    the trainers' ledgers are pinned to.

    With ``cohort=C`` (population regime) the fleet is
    ``n_population or n_clients`` clients of which at most C
    participate per round, the lowered program is C-shaped, and the
    downlink serves only the round's fresh cohort uploads — so every
    byte here scales in C, never in N.  That flatness IS the scale-out
    claim, and this section is where the 10^4-client report states it.
    """
    from repro.core.exchange import expected_delta_entries

    fleet_n = (n_population or n_clients) if cohort else n_clients
    width = cohort or n_clients
    rows_per_client = (shape.global_batch // width) * shape.seq_len
    arrivals_exp = None
    if mode == "async":
        # Per-tick expectations come from the arrival trace, not the
        # participation schedule: mean coalesced uploads (= mask
        # popcount the lowered program sees) and raw arrival rate.
        k_exp, arrivals_exp = expected_async_participants(
            trace, fleet_n, tick)
        if cohort:
            # The engine admits the C earliest distinct arrivals;
            # min(E[k], C) upper-bounds E[min(k, C)] — close whenever
            # the trace is not straddling the cap.
            k_exp = min(k_exp, float(cohort))
    elif cohort:
        k_exp = expected_cohort_participants(schedule, fleet_n, cohort)
    else:
        k_exp = schedule.expected_participants(fleet_n)
    k_int = max(1, int(round(k_exp)))
    # Delta downlink: mean shipped entries from a mirror-sync replay
    # of the schedule — NOT the K-fresh best case, which only holds
    # at full participation (rejoining clients pull catch-up
    # entries, so partial schedules sit between K and N).
    if broadcast != "delta":
        e_exp = None
    elif mode == "async":
        e_exp = _expected_async_delta_entries(trace, fleet_n, tick)
    else:
        e_exp = expected_delta_entries(schedule, fleet_n,
                                       cohort=cohort or None)
    # Population downlink is cohort-fresh: the server broadcasts only
    # this round's K uploads (positions re-bind every round, so there
    # is no N-sized steady-state cache to re-ship).
    bcast_entries = k_int if cohort else fleet_n
    per_round = ifl_round_bytes(
        fleet_n, rows_per_client, cfg.d_fusion, codec=codec,
        participating=k_int, broadcast_entries=bcast_entries,
        broadcast=broadcast,
        delta_entries=(max(1, int(round(e_exp)))
                       if e_exp is not None else None),
    )
    full_down = ifl_round_bytes(
        fleet_n, rows_per_client, cfg.d_fusion, codec=codec,
        participating=k_int, broadcast_entries=bcast_entries,
    )["down"]
    # Which encode lowering serves this spec: the fused Pallas wire
    # kernel (name, scheme, autotuned block rows, exact DMA bytes) or
    # the jnp oracle — with the reason when it falls back. ``fused``
    # None = auto (TPU only); the payload bytes above are identical
    # either way, this is pure lowering metadata.
    from repro.kernels import ops as kernel_ops
    from repro.kernels.wire_fused import resolve_fused

    fused_on, _ = resolve_fused(fused)
    wire_path = kernel_ops.fused_wire_report(
        codec, (rows_per_client, cfg.d_fusion), fused=fused_on)
    return {
        "codec": get_codec(codec).name,
        "wire_path": wire_path,
        "participation": schedule.name,
        "broadcast": broadcast,
        "mode": mode,
        "trace": (parse_trace(trace, fleet_n).name
                  if mode == "async" else None),
        "tick": tick if mode == "async" else None,
        "n_population": fleet_n if cohort else None,
        "cohort": cohort or None,
        "expected_participants": k_exp,
        "expected_arrivals_per_tick": arrivals_exp,
        "expected_delta_entries": e_exp,
        "per_round_bytes": per_round,
        "full_broadcast_down_bytes": full_down,
        "downlink_saving_x": full_down / max(per_round["down"], 1),
    }


def run_one(arch: str, shape_name: str, *, multi_pod: bool, step_kind: str,
            n_clients: int, tau: int, variant: str, out_dir: str,
            force: bool = False, cfg_override=None, overrides=None,
            fsdp_override=None, codec: str = "fp32",
            participation: str = "full", broadcast: str = "full",
            mode: str = "sync", trace: str = "", tick: float = 1.0,
            n_population: int = 0, cohort: int = 0,
            accounting_only: bool = False, fused=None):
    import re as _re

    mesh_name = "2x16x16" if multi_pod else "16x16"
    tag = f"{arch}__{shape_name}__{mesh_name}__{step_kind}"
    # Non-default exchange axes key their own artifacts (sanitized:
    # codec strings like ef(int4) are shell-hostile) — but ONLY for the
    # ifl train step, the one program the axes affect; serve/prefill/dp
    # rows keep their baseline tags so an --all sweep with --codec
    # doesn't re-lower byte-identical programs past the existing-file
    # skip.
    shape_kind = INPUT_SHAPES[shape_name].kind
    if shape_kind == "train" and step_kind == "ifl":
        for prefix, value, default in (("c", codec, "fp32"),
                                       ("p", participation, "full"),
                                       ("b", broadcast, "full"),
                                       ("m", mode, "sync"),
                                       ("t", trace, ""),
                                       ("N", n_population, 0),
                                       ("C", cohort, 0)):
            if value != default:
                tag += "__" + prefix + _re.sub(r"[^\w.]+", "-", str(value))
    if accounting_only:
        tag += "__acct"
    if variant:
        tag += f"__{variant}"
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(out_path) and not force:
        print(f"[skip existing] {tag}")
        return json.load(open(out_path))

    shape = INPUT_SHAPES[shape_name]
    cfg = cfg_override if cfg_override is not None else get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides).validate()
    schedule = parse_participation(participation)

    if accounting_only:
        # Client-boundary bytes only, no HLO: the 10^4-client CI leg
        # prices the wire at N=10000/C=256 in seconds — the lowered
        # program is identical to the plain C-client masked step (the
        # fleet size N appears nowhere in the HLO; that IS the point),
        # so compiling it again here would measure nothing new.
        assert shape.kind == "train" and step_kind == "ifl", \
            "--accounting-only prices the IFL client boundary only"
        cb = client_boundary_section(
            cfg, shape, n_clients=n_clients, schedule=schedule,
            codec=codec, broadcast=broadcast, mode=mode, trace=trace,
            tick=tick, n_population=n_population, cohort=cohort,
            fused=fused)
        result = {"arch": arch, "shape": shape_name, "step": step_kind,
                  "accounting_only": True, "n_clients": n_clients,
                  "client_boundary": cb}
        with open(out_path, "w") as f:
            json.dump(result, f, indent=1)
        print(f"[ok] {tag}: accounting only — "
              f"fleet N={cb['n_population'] or n_clients} "
              f"cohort C={cb['cohort'] or '-'}: "
              f"up {cb['per_round_bytes']['up']/1e6:.2f}MB, "
              f"down {cb['per_round_bytes']['down']/1e6:.2f}MB/round")
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    fsdp = _params_count(param_specs(cfg)) > FSDP_THRESHOLD
    if fsdp_override is not None:
        fsdp = fsdp_override

    t0 = time.time()
    # In the population regime the device program is cohort-shaped:
    # C stacked client slots, always masked (the round's cohort draw is
    # a runtime mask over C positions, never a recompile), with N
    # appearing nowhere in the HLO.
    width = cohort or n_clients
    if shape.kind == "train" and step_kind == "ifl":
        ifl_mesh = derive_ifl_mesh(mesh, width)
        # Async mode is arrival-driven, so the lowered program is always
        # the masked cached-payload variant — the tick's participant set
        # is a runtime mask, never a recompile.
        partial = (cohort > 0 or mode == "async" or
                   not isinstance(schedule, FullParticipation))
        step = make_ifl_round_step(
            cfg, ifl_mesh, n_clients=width, tau=tau, codec=codec,
            partial_participation=partial,
        )
        params = param_specs(cfg, n_clients=width)
        opt_state = {"base": {}, "modular": {}}  # SGD: stateless
        batch = train_batch_specs(cfg, shape, n_clients=width, tau=tau)
        pspecs = param_pspecs(params, fsdp=fsdp, client_axis=True)
        in_sh = [
            tree_shardings(ifl_mesh, pspecs, params),
            {"base": {}, "modular": {}},
            tree_shardings(ifl_mesh, batch_pspec(batch, client_axis=True),
                           batch),
        ]
        lower_args = [params, opt_state, batch]
        Bc = shape.global_batch // width
        z_shape = (width, Bc, shape.seq_len, cfg.d_fusion)
        if partial:
            # The masked cached-payload program: a bool (N,) mask plus
            # the carried payload cache (shape/dtype only — eval_shape
            # never materializes the production-scale arrays). The cache
            # sharding is pinned in-program by the exchange plane's
            # with_sharding_constraint, so 'None' (unspecified) suffices
            # at the jit boundary.
            cache = jax.eval_shape(
                functools.partial(init_payload_cache, codec, z_shape,
                                  (width, Bc, shape.seq_len))
            )
            lower_args += [jax.ShapeDtypeStruct((width,), jnp.bool_),
                           cache]
            in_sh += [None, None]
        if get_codec(codec).has_state:
            # Stateful ef(...) codecs append the carried EF residual to
            # the step signature (last, after mask/cache when partial).
            lower_args += [jax.eval_shape(
                functools.partial(init_ef_state, codec, z_shape))]
            in_sh += [None]
        with ifl_mesh:
            lowered = jax.jit(step, in_shardings=tuple(in_sh)).lower(
                *lower_args
            )
    elif shape.kind == "train":  # dp baseline
        step = make_dp_train_step(cfg)
        params = param_specs(cfg)
        opt_state = {}
        batch = train_batch_specs(cfg, shape, n_clients=0)
        da = data_axes_of(mesh)
        pspecs = param_pspecs(params, fsdp=fsdp)
        in_sh = (
            tree_shardings(mesh, pspecs, params),
            {},
            tree_shardings(mesh, batch_pspec(batch, data_axes=da), batch),
        )
        with mesh:
            lowered = jax.jit(step, in_shardings=in_sh).lower(
                params, opt_state, batch
            )
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg)
        params = param_specs(cfg)
        batch = prefill_batch_specs(cfg, shape)
        da = data_axes_of(mesh)
        in_sh = (
            tree_shardings(mesh, param_pspecs(params, fsdp=fsdp), params),
            tree_shardings(mesh, batch_pspec(batch, data_axes=da), batch),
        )
        with mesh:
            lowered = jax.jit(step, in_shardings=in_sh).lower(params, batch)
    else:  # decode
        step = make_serve_step(cfg)
        params = param_specs(cfg)
        dec = decode_specs(cfg, shape)
        da = data_axes_of(mesh)
        seq_shard = shape.global_batch < 8  # context-parallel for batch~1
        cache_sh = tree_shardings(
            mesh, cache_pspecs(dec["cache"], seq_shard=seq_shard),
            dec["cache"],
        )
        tok_spec = P(da) if shape.global_batch >= 8 else P(None)
        cross_sh = None
        if dec.get("cross_kvs") is not None:
            cross_sh = tree_shardings(
                mesh, cache_pspecs(dec["cross_kvs"]), dec["cross_kvs"]
            )
        in_sh = (
            tree_shardings(mesh, param_pspecs(params, fsdp=fsdp), params),
            cache_sh,
            NamedSharding(mesh, P(*tok_spec, None)),
            NamedSharding(mesh, P()),
            cross_sh,
        )
        with mesh:
            lowered = jax.jit(step, in_shardings=in_sh).lower(
                params, dec["cache"], dec["token"], dec["pos"],
                dec["cross_kvs"],
            )
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost_raw = compiled.cost_analysis()
    if isinstance(cost_raw, list):  # newer jax: one dict per program
        cost_raw = cost_raw[0] if cost_raw else {}
    hlo_text = compiled.as_text()
    # Trip-count-aware accounting: XLA cost_analysis counts while (scan)
    # bodies once, which undercounts every layer stack here. See
    # repro/roofline/hlo_accounting.py.
    acc = analyze_hlo(hlo_text)
    cost = {"flops": acc["flops"], "bytes accessed": acc["hbm_bytes"]}
    coll = acc["collectives"]

    # Useful-FLOPs accounting.
    p_base, p_mod = _block_params(cfg)
    a_base, a_mod = _active_params(cfg, p_base, p_mod)
    tokens = shape.global_batch * (
        shape.seq_len if shape.kind != "decode" else 1
    )
    mf_kind = {
        "train": "ifl_round" if step_kind == "ifl" else "dp_train",
        "prefill": "prefill",
        "decode": "decode",
    }[shape.kind]
    mf = model_flops(
        mf_kind, params_base=a_base, params_mod=a_mod, tokens=tokens,
        tau=tau, n_clients=width,
    )
    terms = roofline_terms(cost, coll["total"], n_chips,
                           model_flops_total=mf)

    # Client-boundary accounting for IFL rows: the analytic per-round
    # bytes under the codec × participation × broadcast regime — the
    # exact formula the trainers' ledgers are pinned to, so the chip
    # report and the wire report cannot disagree.
    client_boundary = None
    if shape.kind == "train" and step_kind == "ifl":
        client_boundary = client_boundary_section(
            cfg, shape, n_clients=n_clients, schedule=schedule,
            codec=codec, broadcast=broadcast, mode=mode, trace=trace,
            tick=tick, n_population=n_population, cohort=cohort,
            fused=fused)

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "step": step_kind if shape.kind == "train" else shape.kind,
        "variant": variant or "baseline",
        "n_chips": n_chips,
        "fsdp": fsdp,
        "tau": tau if shape.kind == "train" and step_kind == "ifl" else None,
        "n_clients": width if step_kind == "ifl" else None,
        "client_boundary": client_boundary,
        "memory": {
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "cost": {k: float(v) for k, v in (cost or {}).items()
                 if isinstance(v, (int, float))},
        "cost_raw_xla": {k: float(v) for k, v in (cost_raw or {}).items()
                         if isinstance(v, (int, float))},
        "n_while": acc["n_while"],
        "collectives": coll,
        "roofline": terms,
        "timing": {"lower_s": t_lower, "compile_s": t_compile},
    }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=1)
    dom = terms["dominant"]
    print(
        f"[ok] {tag}: compile {t_compile:.1f}s, "
        f"compute {terms['compute_s']*1e3:.2f}ms / "
        f"memory {terms['memory_s']*1e3:.2f}ms / "
        f"collective {terms['collective_s']*1e3:.2f}ms -> {dom}-bound, "
        f"peak {(result['memory']['peak_bytes'] or 0)/1e9:.2f}GB/chip"
    )
    if client_boundary:
        cb = client_boundary
        regime = (f"async {cb['trace']} @tick {cb['tick']}"
                  if cb["mode"] == "async" else cb["participation"])
        print(
            f"     client boundary [{cb['codec']} / {regime}"
            f" / {cb['broadcast']}]: "
            f"up {cb['per_round_bytes']['up']/1e6:.2f}MB, "
            f"down {cb['per_round_bytes']['down']/1e6:.2f}MB/round "
            f"({cb['downlink_saving_x']:.2f}x below full broadcast)"
        )
        wp = cb["wire_path"]
        print(f"     wire path: {wp['path']}"
              + (f" {wp['kernel']} block_rows={wp['block_rows']}"
                 if wp["fused"] else f" ({wp['fallback']})"))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--step", choices=["ifl", "dp"], default="ifl")
    ap.add_argument("--n-clients", type=int, default=4)
    ap.add_argument("--n-population", type=int, default=0,
                    help="fleet size N in the population regime "
                         "(requires --cohort; 0 = fixed fleet)")
    ap.add_argument("--cohort", type=int, default=0,
                    help="cohort width C: the device program is C "
                         "client slots, drawn C-of-N per round "
                         "(0 = every client every round)")
    ap.add_argument("--accounting-only", action="store_true",
                    help="skip HLO lowering; emit only the analytic "
                         "client_boundary section (the lowered program "
                         "is C-shaped and N-independent, so the 10^4-"
                         "client wire report needs no compile)")
    ap.add_argument("--tau", type=int, default=2,
                    help="local base steps lowered per round (paper: 10; "
                         "2 keeps dry-run HLO small, τ is a scan)")
    ap.add_argument("--codec", default="fp32",
                    help="wire codec for the fusion exchange "
                         "(repro.core.codec), e.g. int8_row, ef(int4)")
    ap.add_argument("--participation", default="full",
                    help="client schedule (repro.core.rounds, e.g. k2): "
                         "non-full lowers the masked cached-payload "
                         "round step")
    ap.add_argument("--broadcast", default="full",
                    choices=["full", "delta"],
                    help="downlink policy for the client-boundary "
                         "accounting (repro.core.exchange)")
    ap.add_argument("--mode", default="sync", choices=["sync", "async"],
                    help="round clocking: async lowers the masked "
                         "cached-payload step and prices the boundary "
                         "per server tick from --trace")
    ap.add_argument("--trace", default="",
                    help="async arrival trace (repro.core.rounds), e.g. "
                         "pareto(1.2,0.5) — required with --mode async")
    ap.add_argument("--tick", type=float, default=1.0,
                    help="async server fuse period in simulated seconds")
    ap.add_argument("--fused", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="wire-path lowering for the client_boundary "
                         "report: --fused forces the Pallas encode "
                         "kernels, --no-fused the jnp oracle; default "
                         "auto (fused on TPU). Payload bytes are "
                         "identical either way")
    ap.add_argument("--variant", default="",
                    help="perf-iteration tag for §Perf experiments")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="config overrides for perf variants, e.g. "
                         "--set remat=layer --set ce_chunk=1024")
    ap.add_argument("--fsdp", choices=["on", "off", "auto"], default="auto")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            pass
        overrides[k] = v
    fsdp_override = {"on": True, "off": False, "auto": None}[args.fsdp]
    if args.mode == "async" and not args.trace:
        ap.error("--mode async requires --trace (e.g. pareto(1.2,0.5))")
    if args.n_population and not args.cohort:
        ap.error("--n-population requires --cohort (a 10^4-wide device "
                 "program is the thing the population regime avoids)")

    combos = []
    if args.all:
        for a in ARCH_IDS:
            for s in INPUT_SHAPES:
                if supports_shape(a, s):
                    combos.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    failures = []
    for arch, shape in combos:
        for mp in meshes:
            try:
                run_one(arch, shape, multi_pod=mp, step_kind=args.step,
                        n_clients=args.n_clients, tau=args.tau,
                        variant=args.variant, out_dir=args.out,
                        force=args.force, overrides=overrides,
                        fsdp_override=fsdp_override, codec=args.codec,
                        participation=args.participation,
                        broadcast=args.broadcast, mode=args.mode,
                        trace=args.trace, tick=args.tick,
                        n_population=args.n_population,
                        cohort=args.cohort,
                        accounting_only=args.accounting_only,
                        fused=args.fused)
            except Exception as e:  # noqa: BLE001
                failures.append((arch, shape, mp, repr(e)))
                print(f"[FAIL] {arch} {shape} multi_pod={mp}: {e}")
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nall dry-runs OK")


if __name__ == "__main__":
    main()
