"""Pure-JAX optimizers (no optax in this environment).

SGD is the paper-faithful optimizer (eqs. 3/7/9 are plain SGD) and also
the only one whose state fits the 400B+ archs without extra memory;
AdamW is the framework-grade option for the smaller archs. Both operate
on arbitrary param pytrees, so the IFL base/modular split is handled by
simply passing the relevant subtree.
"""

from __future__ import annotations

import math
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


# ----------------------------------------------------------------- SGD


def sgd_init(params, momentum: float = 0.0):
    if momentum == 0.0:
        return {}
    return {"mu": jax.tree.map(jnp.zeros_like, params)}


def sgd_update(params, grads, state, *, lr, momentum: float = 0.0,
               weight_decay: float = 0.0):
    if weight_decay:
        grads = jax.tree.map(lambda g, p: g + weight_decay * p, grads, params)
    if momentum == 0.0:
        new_params = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype),
                                  params, grads)
        return new_params, state
    mu = jax.tree.map(lambda m, g: momentum * m + g, state["mu"], grads)
    new_params = jax.tree.map(lambda p, m: p - lr * m.astype(p.dtype), params, mu)
    return new_params, {"mu": mu}


# ----------------------------------------------------------------- AdamW


def adamw_init(params):
    zeros = lambda: jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return {"m": zeros(), "v": zeros(), "step": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, state, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.0):
    step = state["step"] + 1
    m = jax.tree.map(
        lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
        state["m"], grads,
    )
    v = jax.tree.map(
        lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        state["v"], grads,
    )
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m_, v_):
        u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
        if weight_decay:
            u = u + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    return jax.tree.map(upd, params, m, v), {"m": m, "v": v, "step": step}


# ----------------------------------------------------------------- factory


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., Tuple[Any, Any]]  # (params, grads, state, lr=...)


def make_optimizer(name: str, **kw) -> Optimizer:
    if name == "sgd":
        mom = kw.get("momentum", 0.0)
        return Optimizer(
            init=lambda p: sgd_init(p, mom),
            update=lambda p, g, s, lr: sgd_update(
                p, g, s, lr=lr, momentum=mom,
                weight_decay=kw.get("weight_decay", 0.0),
            ),
        )
    if name == "adamw":
        return Optimizer(
            init=adamw_init,
            update=lambda p, g, s, lr: adamw_update(
                p, g, s, lr=lr,
                b1=kw.get("b1", 0.9), b2=kw.get("b2", 0.95),
                weight_decay=kw.get("weight_decay", 0.0),
            ),
        )
    raise ValueError(name)


# ----------------------------------------------------------------- schedule


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr_at(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1 + jnp.cos(math.pi * t))
        return jnp.where(step < warmup, warm, cos)

    return lr_at
