from repro.optim.optim import (  # noqa: F401
    sgd_init,
    sgd_update,
    adamw_init,
    adamw_update,
    make_optimizer,
    cosine_schedule,
)
