"""Training loops for LM-scale IFL (and the dense DP baseline).

Runs on whatever mesh it is given — the CPU examples use a 1-device
('client','data','model') = (1,1,1) mesh and the same jitted round step
the 256-chip dry-run lowers, so the code path is identical from laptop
to pod.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.config import ModelConfig
from repro.core.comm import CommLedger, ifl_round_bytes
from repro.core.ifl_spmd import (
    init_ifl_state,
    make_dp_train_step,
    make_ifl_round_step,
)
from repro.data.synthetic import SyntheticLM
from repro.models.transformer import init_lm
from repro.optim import make_optimizer


def _one_device_ifl_mesh() -> Mesh:
    dev = np.array(jax.devices()[:1]).reshape(1, 1, 1)
    return Mesh(dev, ("client", "data", "model"))


def _ifl_batch(stream: SyntheticLM, cfg: ModelConfig, n_clients: int,
               tau: int, batch: int, seq: int, step: int) -> Dict:
    toks = np.stack([
        np.stack([
            stream.sample(batch, seq, step=step * (tau + 1) + t, client=k)
            for t in range(tau + 1)
        ])
        for k in range(n_clients)
    ])  # (N, tau+1, B, S)
    out = {"tokens": jnp.asarray(toks)}
    if cfg.num_image_tokens:
        rng = np.random.default_rng(step)
        out["image_embeds"] = jnp.asarray(rng.normal(
            size=(n_clients, tau + 1, batch, cfg.num_image_tokens,
                  cfg.d_model)
        ).astype(np.float32))
    if cfg.is_encdec:
        rng = np.random.default_rng(step + 1)
        out["frame_embeds"] = jnp.asarray(rng.normal(
            size=(n_clients, tau + 1, batch, cfg.enc_seq_len, cfg.d_model)
        ).astype(np.float32))
    return out


def train_ifl_lm(
    cfg: ModelConfig,
    *,
    rounds: int = 20,
    n_clients: int = 4,
    tau: int = 4,
    batch: int = 8,
    seq: int = 128,
    lr_base: float = 3e-3,
    lr_modular: float = 3e-3,
    seed: int = 0,
    mesh: Optional[Mesh] = None,
    log_every: int = 5,
) -> Dict:
    """IFL rounds on an LM; returns history + comm ledger."""
    mesh = mesh or _one_device_ifl_mesh()
    params, opt_state = init_ifl_state(
        jax.random.PRNGKey(seed), cfg, n_clients=n_clients
    )
    step_fn = jax.jit(make_ifl_round_step(
        cfg, mesh, n_clients=n_clients, tau=tau,
        lr_base=lr_base, lr_modular=lr_modular,
    ))
    stream = SyntheticLM(cfg.vocab_size, seed=seed)
    ledger = CommLedger()
    z_bytes = batch * seq * cfg.d_fusion * 2  # bf16 fusion activations
    hist: List[Dict] = []
    t0 = time.time()
    with mesh:
        for r in range(rounds):
            b = _ifl_batch(stream, cfg, n_clients, tau, batch, seq, r)
            params, opt_state, m = step_fn(params, opt_state, b)
            # ledger: what crossed the client boundary this round.
            up = n_clients * (z_bytes + batch * seq * 4)
            ledger.uplink += up
            ledger.downlink += n_clients * up
            ledger.per_round.append({"up": up, "down": n_clients * up})
            rec = {
                "round": r,
                "base_loss": float(m["base_loss"]),
                "mod_loss": float(m["mod_loss"]),
                "uplink_mb": ledger.uplink_mb,
            }
            hist.append(rec)
            if r % log_every == 0:
                print(f"  round {r:4d}  base {rec['base_loss']:.4f}  "
                      f"mod {rec['mod_loss']:.4f}  "
                      f"uplink {rec['uplink_mb']:.2f} MB  "
                      f"({time.time()-t0:.0f}s)")
    return {"history": hist, "params": params, "ledger": ledger}


def train_dp_lm(cfg: ModelConfig, *, steps: int = 50, batch: int = 8,
                seq: int = 128, lr: float = 3e-3, seed: int = 0,
                log_every: int = 10) -> Dict:
    """Dense data-parallel baseline (FL-equivalent comm = |params|/step)."""
    params = init_lm(jax.random.PRNGKey(seed), cfg)
    opt = make_optimizer("sgd")
    opt_state = opt.init(params)
    step_fn = jax.jit(make_dp_train_step(cfg, lr=lr))
    stream = SyntheticLM(cfg.vocab_size, seed=seed)
    hist = []
    for s in range(steps):
        b = {"tokens": jnp.asarray(stream.sample(batch, seq, step=s))}
        if cfg.num_image_tokens:
            b["image_embeds"] = jnp.zeros(
                (batch, cfg.num_image_tokens, cfg.d_model))
        if cfg.is_encdec:
            b["frame_embeds"] = jnp.asarray(
                np.random.default_rng(s).normal(
                    size=(batch, cfg.enc_seq_len, cfg.d_model)
                ).astype(np.float32))
        params, opt_state, m = step_fn(params, opt_state, b)
        hist.append({"step": s, "loss": float(m["loss"])})
        if s % log_every == 0:
            print(f"  step {s:4d}  loss {hist[-1]['loss']:.4f}")
    return {"history": hist, "params": params}
