from repro.train.loop import train_ifl_lm, train_dp_lm  # noqa: F401
